"""Mixtral-8x22B [arXiv:2401.04088]: MoE 8e top-2, GQA(kv=8), SWA."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", n_layers=56, d_model=6144, n_heads=48, kv_heads=8,
    d_ff=16384, vocab=32768, head_dim=128, rope_theta=1e6,
    sliding_window=4096, n_experts=8, top_k=2,
    block_pattern=("attn",), mlp_pattern=("moe",))

REDUCED = ModelConfig(
    name="mixtral-8x22b-reduced", n_layers=2, d_model=64, n_heads=4,
    kv_heads=2, d_ff=128, vocab=256, head_dim=16, sliding_window=8,
    n_experts=4, top_k=2, block_pattern=("attn",), mlp_pattern=("moe",),
    compute_dtype=jnp.float32, loss_chunk=16)
