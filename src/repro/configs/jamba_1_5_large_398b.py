"""Jamba-1.5-Large 398B [arXiv:2403.19887]: Mamba+attn 1:7, MoE 16e top-2.

Period-8 group: attention at slot 4 (as in the released config), Mamba
elsewhere; MoE on every other layer."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", n_layers=72, d_model=8192, n_heads=64,
    kv_heads=8, d_ff=24576, vocab=65536, head_dim=128,
    n_experts=16, top_k=2, ssm_state=128, ssm_headdim=64,
    block_pattern=("ssm", "ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm"),
    mlp_pattern=("dense", "moe"))

REDUCED = ModelConfig(
    name="jamba-1.5-large-398b-reduced", n_layers=8, d_model=64, n_heads=4,
    kv_heads=2, d_ff=128, vocab=256, head_dim=16, n_experts=4, top_k=2,
    ssm_state=16, ssm_headdim=16, ssm_chunk=16,
    block_pattern=("ssm", "ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm"),
    mlp_pattern=("dense", "moe"),
    compute_dtype=jnp.float32, loss_chunk=16)
