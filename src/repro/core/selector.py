"""Algorithm auto-selection — the paper's §7 decision procedure as code.

The paper's conclusion: the best algorithm depends on (a) matrix density,
(b) row-length skew (the mawi case), (c) machine topology (UMA vs NUMA), and
(d) how many SpMVs will amortize the conversion cost (the "472
multiplications" rule for BCOHC on Sapphire Rapids).

TPU translation: "UMA" = a single device / single-core grid; "NUMA" = a
multi-device mesh where y-locality (static row bands, no collectives on y)
matters the way socket-locality did on CPU.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, NamedTuple, Optional, Tuple

import numpy as np

from .formats import COO


@dataclasses.dataclass(frozen=True)
class MatrixStats:
    m: int
    n: int
    nnz: int
    max_row_nnz: int
    row_var: float
    symmetric: bool = False    # A == A^T (pattern and values)

    @property
    def density(self) -> float:
        return self.nnz / max(self.m * self.n, 1)

    @property
    def has_dense_row(self) -> bool:
        """mawi_0130-style pathology: one row holding a large fraction of all
        nonzeros (paper Table 6.3)."""
        return self.max_row_nnz > max(0.01 * self.nnz, 10 * self.nnz /
                                      max(self.m, 1))


def _is_symmetric(coo: COO) -> bool:
    """Host-side ``A == A^T`` check (pattern exact after summing duplicate
    coordinates, values to fp-reassociation tolerance) — the same predicate
    ``coo_to_sellcs(structure='symmetric')`` enforces, so a True here means
    one-triangle storage is actually convertible."""
    m, n = coo.shape
    if m != n:
        return False
    rows = np.asarray(coo.rows, np.int64)
    cols = np.asarray(coo.cols, np.int64)
    if rows.size == 0:
        return True
    vals = np.asarray(coo.data, np.float64)

    def dedup(keys, v):
        order = np.argsort(keys, kind="stable")
        kk, vv = keys[order], v[order]
        uk, start = np.unique(kk, return_index=True)
        return uk, np.add.reduceat(vv, start)

    ka, va = dedup(rows * n + cols, vals)
    kb, vb = dedup(cols * n + rows, vals)
    if ka.shape != kb.shape or not np.array_equal(ka, kb):
        return False
    scale = float(np.abs(va).max()) if va.size else 1.0
    return bool(np.allclose(va, vb, rtol=1e-6, atol=1e-9 * max(scale, 1.0)))


def matrix_stats(coo: COO) -> MatrixStats:
    rows = np.asarray(coo.rows)
    counts = np.bincount(rows, minlength=coo.shape[0]) if rows.size else \
        np.zeros(coo.shape[0], np.int64)
    return MatrixStats(
        m=coo.shape[0], n=coo.shape[1], nnz=int(rows.size),
        max_row_nnz=int(counts.max()) if counts.size else 0,
        row_var=float(counts.var()) if counts.size else 0.0,
        symmetric=_is_symmetric(coo))


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    num_devices: int = 1          # mesh size; 1 == "UMA"
    fast_memory: bool = True      # HBM-class vs DDR-class bandwidth

    @property
    def numa_like(self) -> bool:
        return self.num_devices > 1


# Relative conversion cost in units of ParCRS SpMVs, averaged from the
# paper's Tables 6.4/6.5 (Sapphire Rapids column; used as priors when no
# measured table is supplied).
DEFAULT_CONVERSION_COST: Dict[str, float] = {
    "parcrs": 100.0, "merge": 98.0, "csb": 95.0, "csbh": 370.0,
    "bcoh": 230.0, "bcohc": 225.0, "bcohch": 520.0, "bcohchp": 520.0,
    "mergeb": 85.0, "mergebh": 480.0,
}

# Relative SpMV throughput priors (higher is better), from Tables 6.1/6.2:
# {(numa_like, low_density): {algo: speedup}}
DEFAULT_THROUGHPUT: Dict[tuple, Dict[str, float]] = {
    (True, True): {"parcrs": 42.2, "merge": 43.6, "csb": 29.4, "csbh": 30.4,
                   "bcoh": 45.8, "bcohc": 49.6, "bcohch": 49.7,
                   "bcohchp": 26.7, "mergeb": 22.6, "mergebh": 23.3},
    (True, False): {"parcrs": 55.2, "merge": 71.3, "csb": 33.7, "csbh": 37.1,
                    "bcoh": 59.5, "bcohc": 81.9, "bcohch": 84.6,
                    "bcohchp": 72.1, "mergeb": 33.3, "mergebh": 37.1},
    (False, True): {"parcrs": 18.8, "merge": 18.0, "csb": 18.9, "csbh": 19.1,
                    "bcoh": 13.7, "bcohc": 14.5, "bcohch": 14.2,
                    "bcohchp": 11.2, "mergeb": 15.0, "mergebh": 15.6},
    (False, False): {"parcrs": 25.8, "merge": 24.4, "csb": 20.5, "csbh": 21.3,
                     "bcoh": 18.0, "bcohc": 24.4, "bcohch": 25.6,
                     "bcohchp": 23.6, "mergeb": 14.8, "mergebh": 17.3},
}

# Algorithms able to split a single row across workers (paper Table 6.3).
ROW_SPLITTING = ("merge", "csb", "csbh")

# The serving path's zero-conversion start: merge-path CSR costs one
# coo_to_csr row-sort, so a matrix that never reaches break-even never
# pays for a format it did not need (launch.serve --migrate).
ZERO_CONVERSION_ALGO = "merge"

DENSITY_THRESHOLD = 1e-6   # the paper's low/high density split


def amortized_cost(algo: str, num_spmvs: int, *, numa_like: bool,
                   low_density: bool,
                   conversion_cost: Optional[Dict[str, float]] = None,
                   throughput: Optional[Dict[str, float]] = None) -> float:
    """Total cost of `num_spmvs` multiplications + one conversion, in units
    of ParCRS SpMV time (the paper's break-even arithmetic)."""
    conv = (conversion_cost or DEFAULT_CONVERSION_COST)[algo]
    thr = (throughput or DEFAULT_THROUGHPUT[(numa_like, low_density)])
    per_spmv = thr["parcrs"] / thr[algo]      # time relative to ParCRS
    return conv + num_spmvs * per_spmv


def break_even_spmvs(algo: str, *, numa_like: bool, low_density: bool,
                     baseline: str = "parcrs", **kw) -> float:
    """How many SpMVs before `algo` beats `baseline` including conversion
    (e.g. ~472 for bcohc on a NUMA/high-density setting in the paper)."""
    thr = kw.get("throughput") or DEFAULT_THROUGHPUT[(numa_like, low_density)]
    conv = kw.get("conversion_cost") or DEFAULT_CONVERSION_COST
    gain = thr["parcrs"] / thr[baseline] - thr["parcrs"] / thr[algo]
    if gain <= 0:
        return math.inf
    return max((conv[algo] - conv[baseline]) / gain, 0.0)


def select_algorithm(stats: MatrixStats, machine: MachineSpec,
                     num_spmvs: int = 1000,
                     conversion_cost: Optional[Dict[str, float]] = None,
                     throughput: Optional[Dict[str, float]] = None) -> str:
    """The §7 decision procedure."""
    low = stats.density < DENSITY_THRESHOLD
    key = (machine.numa_like, low)
    thr = throughput or DEFAULT_THROUGHPUT[key]
    candidates = list(thr)
    if stats.has_dense_row:
        # only row-splitting algorithms survive the mawi pathology
        candidates = [a for a in candidates if a in ROW_SPLITTING]
    best, best_cost = None, math.inf
    for algo in candidates:
        cost = amortized_cost(algo, num_spmvs, numa_like=machine.numa_like,
                              low_density=low,
                              conversion_cost=conversion_cost,
                              throughput=thr)
        if cost < best_cost:
            best, best_cost = algo, cost
    return best


# --------------------------------------------------------------------------
# Multi-RHS (SpMM) extension of the decision procedure — repro.spmm
# --------------------------------------------------------------------------
# Priors for SELL-C-σ (repro.spmm.sellcs), which the paper does not measure:
# conversion is a σ-window counting sort (CSB-like cost); throughput sits at
# the CSB level, with a bonus on skewed matrices where the row sorting
# removes the slice-padding/imbalance that penalizes the other formats.
# These are offline priors only — autotune(k=...) measures the real thing.
SELLCS_CONVERSION_COST = 95.0
SELLCS_SKEW_BONUS = 1.3
SELLCS_BASE_BONUS = 1.05

_VVAR_SKEW_THRESHOLD = 10.0     # squared coeff. of variation of row lengths


def _row_skew(stats: MatrixStats) -> float:
    mean = stats.nnz / max(stats.m, 1)
    return stats.row_var / max(mean * mean, 1e-12)


def _augment_sellcs(thr: Dict[str, float], conv: Dict[str, float],
                    stats: MatrixStats) -> Tuple[Dict[str, float],
                                                 Dict[str, float]]:
    """Extend a (throughput, conversion) table pair — the paper priors or a
    caller-measured table — with the SELL-C-σ entries: throughput at the
    CSB level with a skew bonus (the σ-sort removes the slice-padding
    imbalance that penalizes the other formats on skewed rows), conversion
    at the counting-sort cost. Shared by :func:`select`,
    :func:`select_distributed` and the serve migration controller's
    cold-start break-even so all three price the format identically.
    Mutates and returns ``(thr, conv)``."""
    if "sellcs" not in thr:
        skewed = stats.has_dense_row or _row_skew(stats) > _VVAR_SKEW_THRESHOLD
        bonus = SELLCS_SKEW_BONUS if skewed else SELLCS_BASE_BONUS
        thr["sellcs"] = thr.get("csb", min(thr.values())) * bonus
    conv.setdefault("sellcs", SELLCS_CONVERSION_COST)
    return thr, conv


def _matrix_bytes_est(algo: str, stats: MatrixStats,
                      dtype_bytes: int = 4) -> float:
    """Streamed matrix footprint of one multiply, per format family."""
    from repro.roofline.analysis import csr_stream_bytes   # no jax import
    nz = max(stats.nnz, 1)
    if algo in ("parcrs", "merge"):
        return csr_stream_bytes(nz, stats.m, dtype_bytes)
    if algo == "sellcs":
        # σ-sorting bounds slice padding; model residual fill-in by skew
        pad = 1.0 + min(0.25 * _row_skew(stats), 1.0)
        return nz * (4 + dtype_bytes) * pad
    # blocked families: 16+16 packed indices + block structure
    return nz * (4 + dtype_bytes)


def spmm_cost_scale(algo: str, stats: MatrixStats, k: int,
                    dtype_bytes: int = 4) -> float:
    """Cost of one k-RHS SpMM relative to one SpMV under the memory-bound
    roofline: the matrix stream is paid once, the vector slabs k times.
    Equals 1 at k = 1; grows sublinearly in k (that is the whole point)."""
    mat = _matrix_bytes_est(algo, stats, dtype_bytes)
    vec = (stats.m + stats.n) * dtype_bytes
    return (mat + k * vec) / (mat + vec)


def select(stats: MatrixStats, machine: Optional[MachineSpec] = None,
           num_spmvs: int = 1000, k: int = 1,
           conversion_cost: Optional[Dict[str, float]] = None,
           throughput: Optional[Dict[str, float]] = None, *,
           num_devices: Optional[int] = None) -> str:
    """k-aware decision procedure: which format should multiply ``A`` by a
    ``[n, k]`` block ``num_spmvs`` times?

    ``k = 1`` IS ``select_algorithm`` — identical candidates, identical
    economics. For ``k > 1`` the per-multiply term is rescaled by
    :func:`spmm_cost_scale` (the matrix stream amortizes over k columns)
    and SELL-C-σ joins the candidate set; on dense-row pathologies it
    survives alongside the row-splitting algorithms because the σ-sort plus
    slice padding turns the dense row into uniform work quanta.

    Passing ``num_devices`` switches to the *joint* (format × schedule × k)
    scoring of :func:`select_distributed` — format and cross-device
    schedule must be chosen together (replicated-X bytes and the merge
    psum both enter the modelled intensity), and the paper's NUMA prior
    alone cannot see either. A caller-measured ``throughput`` table is
    threaded through (it rescales each format's single-device multiply
    exactly as in :func:`amortized_cost`; the traffic model then carries it
    across the mesh). The return value stays a format name; call
    ``select_distributed`` directly when the schedule, mesh shape or
    chunking depth is needed too.
    """
    if num_devices is not None and num_devices > 1:
        return select_distributed(
            stats, k=k, num_devices=num_devices, num_spmvs=num_spmvs,
            conversion_cost=conversion_cost,
            throughput=throughput).algorithm
    if machine is None:
        machine = MachineSpec(num_devices or 1)
    if k <= 1:
        return select_algorithm(stats, machine, num_spmvs,
                                conversion_cost=conversion_cost,
                                throughput=throughput)
    low = stats.density < DENSITY_THRESHOLD
    thr = dict(throughput or DEFAULT_THROUGHPUT[(machine.numa_like, low)])
    conv = dict(conversion_cost or DEFAULT_CONVERSION_COST)
    _augment_sellcs(thr, conv, stats)
    candidates = list(thr)
    if stats.has_dense_row:
        candidates = [a for a in candidates
                      if a in ROW_SPLITTING or a == "sellcs"]
    best, best_cost = None, math.inf
    for algo in candidates:
        per_spmv = thr["parcrs"] / thr[algo]
        cost = conv[algo] + num_spmvs * per_spmv * spmm_cost_scale(
            algo, stats, k)
        if cost < best_cost:
            best, best_cost = algo, cost
    return best


# --------------------------------------------------------------------------
# Distributed extension:
# the (format × schedule × k × mesh shape × chunks) grid
# --------------------------------------------------------------------------
SCHEDULES = ("row", "merge")

# Candidate psum pipelining depths for the "merge" schedule (1 = the
# monolithic fixup). "row" has no collective, so its depth is always 1.
CHUNK_CANDIDATES = (1, 2, 4, 8)

# Candidate compact-X gather schedules (repro.spmm.distributed.GATHER_MODES):
# "upfront" materializes the slab ahead of the mesh region, "overlap" hides
# per-span slab rebuilds under the chunked merge span loop, "fused" rides
# col_map on the kernel's scalar prefetch. Executable only with
# compact_x=True on the SELL-C-σ stream.
GATHER_CANDIDATES = ("upfront", "overlap", "fused")


@dataclasses.dataclass(frozen=True)
class PlanSpec:
    """One carrier for the distributed-plan knobs that
    :func:`select_distributed`, :func:`core.autotune.autotune`,
    :func:`distributed_schedule_grid` and ``launch.serve`` used to re-spell
    as separate ``(num_devices, mesh_shape, num_chunks, compact_x)``
    kwargs.

    ``None`` means "unpinned — let the traffic model sweep this axis";
    a set field pins it, exactly like the old per-function kwargs (which
    remain as thin shims over this). ``num_chunks = 0`` is accepted as a
    synonym for unpinned (the serve ``--chunks 0`` convention).
    ``schedule`` / ``algorithm`` pins restrict the grid the same way;
    they also let a fully resolved spec name one executable plan — the
    form :meth:`repro.spmm.SparseOperator.swap` consumes.
    """
    num_devices: Optional[int] = None
    mesh_shape: Optional[Tuple[int, int]] = None
    num_chunks: Optional[int] = None
    compact_x: Optional[bool] = None
    schedule: Optional[str] = None
    algorithm: Optional[str] = None
    structure: Optional[str] = None     # "general" | "symmetric" | unpinned
    gather: Optional[str] = None        # "upfront"|"overlap"|"fused"|unpinned

    def canonical(self) -> "PlanSpec":
        """Validate and normalize: mesh factors must agree with
        ``num_devices`` (a set mesh implies it), ``num_chunks = 0`` maps
        to unpinned, an omitted device count means 1."""
        nd, mesh = self.num_devices, self.mesh_shape
        if mesh is not None:
            pd, pm = int(mesh[0]), int(mesh[1])
            if pd < 1 or pm < 1:
                raise ValueError(f"mesh_shape must be positive, got {mesh}")
            mesh = (pd, pm)
            if nd is None:
                nd = pd * pm
            elif int(nd) != pd * pm:
                raise ValueError(
                    f"mesh_shape {mesh} factors {pd * pm} devices but "
                    f"num_devices={nd}")
        nd = 1 if nd is None else int(nd)
        if nd < 1:
            raise ValueError(f"num_devices must be >= 1, got {nd}")
        nc = self.num_chunks
        if nc is not None:
            nc = int(nc)
            if nc == 0:
                nc = None
            elif nc < 0:
                raise ValueError(f"num_chunks must be >= 0, got {nc}")
        if self.schedule is not None and self.schedule not in SCHEDULES:
            raise ValueError(f"schedule must be one of {SCHEDULES}, got "
                             f"{self.schedule!r}")
        if self.structure is not None and \
                self.structure not in ("general", "symmetric"):
            raise ValueError(f"structure must be 'general' or 'symmetric', "
                             f"got {self.structure!r}")
        if self.gather is not None and self.gather not in GATHER_CANDIDATES:
            raise ValueError(f"gather must be one of {GATHER_CANDIDATES}, "
                             f"got {self.gather!r}")
        if self.gather not in (None, "upfront") and self.compact_x is False:
            raise ValueError(f"gather={self.gather!r} needs compact_x — "
                             f"a replicated-X plan has no X gather to hide")
        return dataclasses.replace(self, num_devices=nd, mesh_shape=mesh,
                                   num_chunks=nc)

    def labels(self, **extra) -> Dict[str, str]:
        """The spec's knobs as canonical residual-ledger labels
        (``obs.residuals.choice_labels``); unpinned (None) axes are
        omitted, which the ledger treats as wildcards."""
        from repro.obs.residuals import choice_labels
        if self.structure is not None:
            extra.setdefault("structure", self.structure)
        return choice_labels(schedule=self.schedule,
                             num_chunks=self.num_chunks,
                             mesh_shape=self.mesh_shape,
                             compact_x=self.compact_x,
                             gather=self.gather, **extra)


def mesh_factorizations(num_devices: int) -> list:
    """Every (P_data, P_model) factorization of ``num_devices``, pure-data
    first — ties in the scored grid then keep the 1-D mesh, which is the
    pre-2-D behavior."""
    if num_devices < 1:
        raise ValueError(f"num_devices must be >= 1, got {num_devices}")
    return [(num_devices // pm, pm) for pm in range(1, num_devices + 1)
            if num_devices % pm == 0]


def distributed_schedule_grid(num_devices: int = 1,
                              pinned_chunks: Optional[int] = None,
                              chunk_candidates: Tuple[int, ...] =
                              CHUNK_CANDIDATES,
                              pinned_mesh: Optional[Tuple[int, int]] = None,
                              spec: Optional[PlanSpec] = None
                              ) -> list:
    """The (schedule × mesh shape × psum-chunking) axes of the distributed
    grid, shared by :func:`select_distributed`, ``core.autotune`` and
    ``launch.serve`` so the merge-only chunk rule and the mesh sweep live
    in exactly one place. Entries are ``(schedule, num_chunks,
    (P_data, P_model))``: "merge" sweeps the pipelining depths (or a single
    pinned depth), "row" has no collective to chunk and always pairs with
    depth 1; the mesh axis sweeps every (P_data, P_model) factorization of
    ``num_devices`` unless ``pinned_mesh`` fixes one.

    ``spec`` carries every pin in one :class:`PlanSpec` (a set
    ``schedule`` restricts that axis too); the positional
    ``(num_devices, pinned_chunks, pinned_mesh)`` kwargs remain as thin
    shims over it — spec fields win where both are given."""
    schedules = SCHEDULES
    if spec is not None:
        spec = spec.canonical()
        num_devices = spec.num_devices
        if spec.num_chunks is not None:
            pinned_chunks = spec.num_chunks
        if spec.mesh_shape is not None:
            pinned_mesh = spec.mesh_shape
        if spec.schedule is not None:
            schedules = (spec.schedule,)
    if pinned_mesh is not None:
        pd, pm = int(pinned_mesh[0]), int(pinned_mesh[1])
        if pd < 1 or pm < 1:
            raise ValueError(f"pinned_mesh must be positive, got "
                             f"{pinned_mesh}")
        meshes = [(pd, pm)]
    else:
        meshes = mesh_factorizations(num_devices)
    grid = []
    for schedule in schedules:
        if schedule == "merge":
            chunks = ((int(pinned_chunks),) if pinned_chunks
                      else chunk_candidates)
        else:
            chunks = (1,)
        grid.extend((schedule, int(nc), mesh)
                    for mesh in meshes for nc in chunks)
    return grid

# Formats with an executable mesh multiply: "parcrs" drives the ShardedCOO
# path in core.distributed (its nonzero stream is the row-sorted COO both
# partitioners consume), "sellcs" the slice-stream path in
# repro.spmm.distributed. Other paper families are deliberately absent —
# recommending a format the mesh cannot run is worse than a slightly
# coarser prior.
DISTRIBUTED_ALGOS = ("parcrs", "sellcs")


class DistributedChoice(NamedTuple):
    """Winner of the joint (format × schedule × mesh × chunks × compact ×
    structure × gather) grid. Unpacks like the old ``(format, schedule,
    num_chunks)`` triple with ``mesh_shape`` — the chosen (P_data, P_model)
    factorization — riding fourth, ``compact_x`` — whether the
    sparsity-aware X gather beats replication — fifth, ``structure`` —
    ``"symmetric"`` when one-triangle storage wins on a symmetric matrix —
    sixth, and ``gather`` — how the compact-X slab build is scheduled
    (up-front / overlapped with the span loop / fused into the kernel) —
    seventh."""
    algorithm: str
    schedule: str
    num_chunks: int
    mesh_shape: Tuple[int, int] = (1, 1)
    compact_x: bool = False
    structure: str = "general"
    gather: str = "upfront"


def select_distributed(stats: MatrixStats, *, k: int = 1,
                       num_devices: int = 1, num_spmvs: int = 1000,
                       conversion_cost: Optional[Dict[str, float]] = None,
                       dtype_bytes: int = 4,
                       chunk_candidates: Tuple[int, ...] = CHUNK_CANDIDATES,
                       mesh_shape: Optional[Tuple[int, int]] = None,
                       throughput: Optional[Dict[str, float]] = None,
                       spec: Optional[PlanSpec] = None,
                       feedback=None,
                       n_touched: Optional[float] = None
                       ) -> DistributedChoice:
    """Joint (format, cross-device schedule, mesh shape, psum chunking)
    choice for ``num_devices`` devices multiplying a ``[n, k]`` block
    ``num_spmvs`` times.

    Scored entirely with the ``repro.roofline`` traffic model
    (:func:`repro.roofline.analysis.spmm_distributed_time`): each
    candidate's per-multiply time counts its streamed matrix bytes
    (per-format footprint, dense-row imbalance for the "row" schedule),
    the replicated-X read, the shard-local vs full-partial Y write, and —
    for "merge" — the *exposed* psum seconds after pipelining the fixup
    into ``num_chunks`` spans (chunked collectives hide under the slice
    stream; each chunk pays a launch, so the optimum depth is finite).
    The mesh axis sweeps every (P_data, P_model) factorization of
    ``num_devices`` (``mesh_shape`` pins one): a ``model`` axis divides
    every k-proportional byte term by P_model at the cost of a shallower
    matrix-stream split, so it starts paying once k is large enough that
    X/Y/psum bytes dominate the stream. For the SELL-C-σ mesh format the
    grid additionally scores the sparsity-aware X gather
    (``compact_x=True``): the replicated-X term becomes nnz-proportional
    (:func:`repro.roofline.analysis.spmm_touched_fraction`), so compaction
    wins exactly when the matrix's columns are sparse enough that a shard
    touches fewer than ``n`` of them — on near-dense columns the modelled
    terms tie and the strict comparison keeps replication (the gather
    would be a wash that still pays a col_map). Times are normalized to
    the single-device ParCRS stream so the paper's conversion-cost priors
    keep their units, then amortized exactly like :func:`amortized_cost`.

    A caller-measured ``throughput`` table (same schema as
    :func:`select_algorithm`'s) replaces the modelled single-device ratio
    between formats: per-multiply cost becomes ``thr["parcrs"] / thr[algo]``
    scaled by the *mesh ratio* of the traffic model — measured where a
    measurement exists, modelled only across the mesh the caller cannot
    run. Without it the model prices both axes alone.

    Returns a :class:`DistributedChoice`; ``num_devices = 1`` degrades to
    the single-device model where both schedules tie and "row" wins by
    order. The "row" schedule has no collective and always reports
    ``num_chunks = 1``.

    ``spec`` carries every pin in one :class:`PlanSpec` — the
    ``(num_devices, mesh_shape)`` kwargs remain as shims over it, and its
    ``algorithm`` / ``schedule`` / ``num_chunks`` / ``compact_x`` fields
    additionally restrict those axes. ``feedback`` is the online
    rescoring entry point: pass a ``repro.obs.ResidualLedger`` (e.g. the
    live one ``launch.serve --migrate`` feeds between flushes) and each
    candidate's modelled seconds are multiplied by the ledger's
    geometric-mean observed/modeled residual for its labels before the
    argmin — measured reality outvotes the streaming-bytes story wherever
    a measurement exists, exactly as in ``autotune(feedback=)``.

    For SELL-C-σ compact candidates the grid also scores the gather
    schedule (:data:`GATHER_CANDIDATES`): the exposed-gather-seconds term
    (:func:`repro.roofline.analysis.spmm_distributed_gather_s`) is fully
    paid up-front, partially hidden by the chunked span loop, or zero when
    fused into the kernel prefetch — strict-< keeps ``upfront`` whenever
    hiding buys nothing (row schedule, one chunk). ``n_touched`` is a
    measured per-shard mean touched-column count from a live plan (e.g.
    the serve path's ``chunk_plan``); without it the model falls back to
    the nnz-proportional bound.
    """
    from repro.roofline.analysis import spmm_distributed_time
    if spec is not None:
        spec = spec.canonical()
        num_devices = spec.num_devices
        if spec.mesh_shape is not None:
            mesh_shape = spec.mesh_shape
    if num_devices < 1:
        raise ValueError(f"num_devices must be >= 1, got {num_devices}")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    conv = dict(conversion_cost or DEFAULT_CONVERSION_COST)
    thr = None
    if throughput is not None:
        thr = dict(throughput)
        _augment_sellcs(thr, conv, stats)
    else:
        conv.setdefault("sellcs", SELLCS_CONVERSION_COST)
    base_s = spmm_distributed_time(
        stats.m, stats.n, 1, 1, "row",
        matrix_bytes=_matrix_bytes_est("parcrs", stats, dtype_bytes),
        dtype_bytes=dtype_bytes)
    grid = distributed_schedule_grid(num_devices,
                                     chunk_candidates=chunk_candidates,
                                     pinned_mesh=mesh_shape, spec=spec)
    algos = DISTRIBUTED_ALGOS
    if spec is not None and spec.algorithm is not None:
        if spec.algorithm not in DISTRIBUTED_ALGOS:
            raise ValueError(
                f"algorithm {spec.algorithm!r} has no executable mesh "
                f"multiply; pin one of {DISTRIBUTED_ALGOS}")
        algos = (spec.algorithm,)
    if feedback is not None:
        from repro.obs.residuals import choice_labels
    best, best_cost = None, math.inf
    for algo in algos:
        mat_bytes = _matrix_bytes_est(algo, stats, dtype_bytes)
        if thr is not None:
            # measured single-device multiply, carried across the mesh by
            # the model's (mesh time / single-device time) ratio per format
            algo_base_s = spmm_distributed_time(
                stats.m, stats.n, k, 1, "row", matrix_bytes=mat_bytes,
                dtype_bytes=dtype_bytes)
            measured = thr["parcrs"] / thr[algo] * spmm_cost_scale(
                algo, stats, k, dtype_bytes)
        # the compact-gather knob is executable only on the SELL-C-σ slice
        # stream; recommending it for a format that cannot run it would be
        # worse than a coarser score (same rule as DISTRIBUTED_ALGOS)
        compacts = (False, True) if algo == "sellcs" else (False,)
        if spec is not None and spec.compact_x is not None:
            compacts = ((spec.compact_x,) if algo == "sellcs" else (False,))
        # one-triangle storage is executable only on SELL-C-σ and only
        # convertible when the matrix actually satisfies A == A^T; the
        # general candidate is scored first so symmetry must strictly win
        structures = ("general",)
        if algo == "sellcs" and stats.symmetric:
            structures = ("general", "symmetric")
        if spec is not None and spec.structure is not None:
            structures = ((spec.structure,) if algo == "sellcs"
                          else ("general",))
        for schedule, nc, (pd, pm) in grid:
            for compact in compacts:
                # the gather schedule only exists where there is a gather:
                # compact SELL-C-σ. "upfront" is scored first so an
                # overlapped/fused candidate must strictly beat it.
                gathers = (GATHER_CANDIDATES
                           if compact and algo == "sellcs"
                           else ("upfront",))
                if spec is not None and spec.gather is not None:
                    gathers = ((spec.gather,)
                               if compact and algo == "sellcs"
                               else ("upfront",))
                for structure in structures:
                    for gmode in gathers:
                        sec = spmm_distributed_time(
                            stats.m, stats.n, k, pd, schedule,
                            matrix_bytes=mat_bytes, dtype_bytes=dtype_bytes,
                            max_row_nnz=stats.max_row_nnz, num_chunks=nc,
                            model_devices=pm, compact_x=compact,
                            nnz=stats.nnz, structure=structure,
                            n_touched=n_touched if compact else None,
                            gather=gmode)
                        if feedback is not None:
                            sec *= feedback.correction(**choice_labels(
                                schedule=schedule, num_chunks=nc,
                                mesh_shape=(pd, pm), compact_x=compact,
                                structure=structure, gather=gmode))
                        if thr is None:
                            per_spmv = sec / max(base_s, 1e-30)
                        else:
                            per_spmv = (measured * sec
                                        / max(algo_base_s, 1e-30))
                        cost = conv[algo] + num_spmvs * per_spmv
                        # "or best is None" keeps a valid choice even when
                        # every cost is inf (e.g. all-inf conversion
                        # priors); the strict "<" with compact=False /
                        # general / upfront scored first refuses
                        # compaction, one-triangle storage or gather
                        # hiding whenever they tie the plain candidate
                        if cost < best_cost or best is None:
                            best = DistributedChoice(algo, schedule, nc,
                                                     (pd, pm), compact,
                                                     structure, gmode)
                            best_cost = cost
    return best
