"""Serving entry points.

LM mode — batched prefill + greedy decode with KV caches (CPU-scale demo,
reduced config, real execution):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --batch 4 --prompt-len 32 --gen 16

SpMV mode — the repro.spmm request batcher serving single-vector requests:
queued ``A @ x`` requests aggregate into one SpMM per flush (matrix stream
amortized over the batch), measured against serving them one by one:
  PYTHONPATH=src python -m repro.launch.serve --mode spmv \
      --matrix mawi_like --requests 64 --max-batch 32

Mesh serving — ``--devices P`` answers each flush with a *distributed*
SpMM over a P-device mesh (``repro.spmm.distributed``); format,
cross-device schedule and the merge-psum pipelining depth come from the
``core.select_distributed`` grid (``--chunks c`` pins the depth).
``--mesh Pd,Pm`` pins a 2-D (data, model) factorization instead: the model
axis column-shards the X/Y k-slabs so per-device psum and replicated-X
bytes drop by Pm — the k ≫ 128 scaling axis. ``--compact-x on`` partitions
with per-shard column compaction (each data shard gathers only the X rows
its nonzeros touch instead of reading the replicated slab; ``auto`` asks
the traffic model whether the gather pays). ``--gather
upfront|overlap|fused`` schedules that gather's exposed latency — up-front
ahead of the mesh region, hidden under the chunked merge span loop, or
fused into the Pallas kernel's scalar prefetch (``auto`` lets the
exposed-gather-seconds roofline term pick). On CPU, force host-platform
devices first:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --mode spmv --matrix mawi_like \
      --requests 64 --max-batch 32 --mesh 4,2 --impl ref --chunks 4

Online migration — ``--migrate auto`` serves through one
``repro.spmm.SparseOperator`` handle that starts in the zero-conversion
merge-path format, counts served multiplies, and converts to the
SELL-C-σ target plan **in a background thread** once the live break-even
estimate (measured conversion cost over measured-and-residual-corrected
per-multiply saving, cold-started from the ``selector.break_even_spmvs``
priors — the paper's §7 "472 multiplications" economics) clears the
projected remaining traffic; the new plan is swapped in atomically
between flushes. ``force`` converts unconditionally (still off the flush
path), ``off`` (default) pins the start format forever. Decision inputs
land in the metrics document: ``serve/multiplies_total``,
``serve/breakeven_estimate``, ``serve/plan_swaps``,
``serve/swap_at_multiply``, ``serve/convert_s`` and the pre/post-swap
flush histograms.

Fleet mode — ``--mode fleet --tenants N`` serves N matrices from one
process through a :class:`repro.spmm.Fleet` (fingerprint-keyed plan cache;
returning tenants skip partitioning) and a
:class:`repro.spmm.FleetBatcher` (per-tenant queues; flushes scheduled by
SLO-deadline urgency × batch-efficiency under ``--slo-ms``).
``--fail-device auto`` kills a data-shard device mid-stream: the fleet
re-deals the lost shard's width-row spans across the survivors
(``redeal_sellcs`` — no re-conversion) and keeps serving:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --mode fleet --tenants 3 --slo-ms 50 \
      --matrix mawi_like --devices 8 --impl ref --fail-device auto \
      --metrics BENCH_serve_slo.json

Observability — ``--metrics out.json`` installs a ``repro.obs`` registry
for the run and dumps it at the end: per-flush phase spans (the
``batcher/*`` series plus, on a mesh, an eager phase-profile pass through
``spmm/gather_x`` / ``spmm/mesh`` / ``spmm/kernel`` / ``spmm/psum`` /
``spmm/fixup``), p50/p95/p99 flush latency (``serve/flush_s``, exact
order statistics at serve batch counts), and one ``ResidualLedger``
record per flush pairing the measured wall time with the roofline
prediction (``spmm_distributed_time``) for the chosen
``DistributedChoice`` — the observed-vs-modeled residuals that feed
``core.autotune(feedback=)``. Headline timings follow the paper's §5.2
min-of-N protocol (``--reps``), never a single ``perf_counter`` pair.
"""
from __future__ import annotations

import argparse
import math
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models.model import decode_step, init_params, prefill


class _MigrationController:
    """The online break-even loop — the paper's "472 multiplications" §7
    economics as a live control law over the serving traffic.

    Between flushes it (a) counts served multiplies (SpMV-equivalents —
    the unit of the paper's break-even), (b) feeds the live
    ``ResidualLedger`` back into ``select_distributed(feedback=)`` to
    re-pick the target plan's knobs with ledger-corrected scores, and (c)
    maintains the break-even estimate ``convert_cost_s / per-multiply
    saving``: the saving is the *measured* per-multiply latency of the
    current plan times the modeled (and residual-corrected) improvement
    ratio to the target, the conversion cost starts as the
    ``selector.break_even_spmvs`` priors (in measured seconds) and is
    replaced by the measured build time once the conversion runs. When
    the projected remaining traffic clears the estimate (``--migrate
    auto``; ``force`` skips the test, ``off`` disables the loop), the
    target plan is built in a **background thread** — never on the flush
    path — and installed through ``SparseOperator.swap`` between flushes.
    """

    def __init__(self, op, stats, args, target_spec, ledger, reg=None):
        from repro.core.selector import (DEFAULT_CONVERSION_COST,
                                         DEFAULT_THROUGHPUT,
                                         DENSITY_THRESHOLD,
                                         ZERO_CONVERSION_ALGO,
                                         _augment_sellcs, break_even_spmvs)
        self.op = op
        self.stats = stats
        self.mode = args.migrate
        self.max_batch = int(args.max_batch)
        self.projected_total = int(args.requests)
        self.target_spec = target_spec
        self.ledger = ledger
        self.reg = reg
        self.multiplies = 0
        self.swapped = False
        self.swap_unix_s = None
        self.swap_at_multiply = None
        self.convert_s = None
        self.error = None
        self._min_per_mul = math.inf
        self._last_saving = None
        self._target_choice = None
        self._worker = None
        self._pending = None
        # cold-start break-even from the paper's priors: the target is
        # SELL-C-σ, the baseline is the zero-conversion start whose
        # conversion is already paid (hence cost 0). Often inf on these
        # priors (the tables do not flatter sellcs) — the first flush
        # replaces it with the measured/ledger-corrected estimate.
        low = stats.density < DENSITY_THRESHOLD
        numa = (target_spec.num_devices or 1) > 1
        self._thr, self._conv = _augment_sellcs(
            dict(DEFAULT_THROUGHPUT[(numa, low)]),
            dict(DEFAULT_CONVERSION_COST), stats)
        self.breakeven = break_even_spmvs(
            "sellcs", baseline=ZERO_CONVERSION_ALGO, numa_like=numa,
            low_density=low, throughput=self._thr,
            conversion_cost={**self._conv, ZERO_CONVERSION_ALGO: 0.0})
        self._publish()

    def note_flush(self, k, dt, rp):
        """Called after every flush (k served columns in dt seconds on
        plan ``rp``): update counters and the break-even estimate, start
        the background build when the projection clears it, and install a
        finished build before the next flush."""
        k = int(k)
        self.multiplies += k
        if self.reg is not None:
            self.reg.counter("serve/multiplies_total").inc(k)
        if self.mode == "off" or self.error is not None:
            return
        if not self.swapped:
            self._min_per_mul = min(self._min_per_mul, dt / max(k, 1))
            self._update_estimate(rp)
            remaining = self.projected_total - self.multiplies
            if self._worker is None and (self.mode == "force"
                                         or remaining > self.breakeven):
                self._start_build()
        self._install_pending()
        self._publish()

    def finish(self):
        """End of the traffic: a build still in flight is joined and
        installed (a forced migration must land even when the traffic
        runs out first), and a background failure surfaces here instead
        of dying silently in the worker thread."""
        if self._worker is not None:
            self._worker.join()
        self._install_pending()
        self._publish()
        if self.error is not None:
            raise self.error

    def _update_estimate(self, rp):
        """Ledger-corrected live break-even: measured per-multiply on the
        current plan, modeled (and residual-corrected) per-multiply on
        the re-selected target, conversion priced by the priors until the
        build measures it."""
        if not math.isfinite(self._min_per_mul):
            return
        from repro.core.selector import (_matrix_bytes_est,
                                         select_distributed)
        from repro.obs import choice_labels
        from repro.roofline import spmm_distributed_time
        st, kb = self.stats, self.max_batch
        # the current plan's measured per-shard touched-column mean (None
        # when it has no compact plan) replaces the nnz-proportional bound
        # in the target's score — the same matrix, so the measurement
        # carries
        nt = rp.n_touched
        ch = select_distributed(st, k=kb,
                                num_spmvs=max(self.projected_total, 1),
                                spec=self.target_spec,
                                feedback=self.ledger, n_touched=nt)
        self._target_choice = ch
        pd, pm = ch.mesh_shape
        gx = ch.gather if ch.compact_x else "upfront"
        t_model = spmm_distributed_time(
            st.m, st.n, kb, pd, ch.schedule,
            matrix_bytes=_matrix_bytes_est(ch.algorithm, st),
            max_row_nnz=st.max_row_nnz, num_chunks=ch.num_chunks,
            model_devices=pm, compact_x=ch.compact_x, nnz=st.nnz,
            n_touched=nt if ch.compact_x else None, gather=gx)
        t_corr = self.ledger.correction(**choice_labels(
            schedule=ch.schedule, num_chunks=ch.num_chunks,
            mesh_shape=ch.mesh_shape, compact_x=ch.compact_x,
            gather=gx if ch.compact_x else None))
        c_model = rp.model_s(kb) * self.ledger.correction(**rp.labels())
        per_now = self._min_per_mul
        per_target = per_now * (t_model * t_corr) / max(c_model, 1e-30)
        saving = per_now - per_target        # seconds saved per multiply
        self._last_saving = saving
        if saving <= 0:
            self.breakeven = math.inf
            return
        convert_s = self.convert_s
        if convert_s is None:
            # prior units are ParCRS SpMVs; the current plan runs one
            # multiply at thr[parcrs]/thr[cur] of a ParCRS one
            cur = rp.spec.algorithm or "merge"
            per_parcrs = per_now * (
                self._thr.get(cur, self._thr["parcrs"])
                / self._thr["parcrs"])
            convert_s = self._conv["sellcs"] * per_parcrs
        self.breakeven = convert_s / saving

    def _start_build(self):
        from repro.core import PlanSpec
        ch = self._target_choice
        if ch is None:
            spec = self.target_spec
        else:
            spec = PlanSpec(num_devices=ch.mesh_shape[0] * ch.mesh_shape[1],
                            mesh_shape=ch.mesh_shape,
                            num_chunks=ch.num_chunks,
                            compact_x=ch.compact_x, schedule=ch.schedule,
                            algorithm=ch.algorithm,
                            gather=ch.gather if ch.compact_x else None)

        def build():
            try:
                t0 = time.perf_counter()
                rp = self.op.realize(spec, feedback=self.ledger)
                self.convert_s = time.perf_counter() - t0
                self._pending = rp
            except BaseException as e:       # surface in finish()
                self.error = e

        self._worker = threading.Thread(target=build, name="serve-migrate",
                                        daemon=True)
        self._worker.start()

    def _install_pending(self):
        rp = self._pending
        if rp is None:
            return
        self._pending = None
        self.op.swap(rp)
        self.swapped = True
        self.swap_unix_s = self.op.stats.last_swap_unix_s
        self.swap_at_multiply = self.multiplies
        if self.convert_s is not None and self._last_saving is not None \
                and self._last_saving > 0:
            # both sides measured now: real build seconds over real saving
            self.breakeven = self.convert_s / self._last_saving
        if self.reg is not None:
            self.reg.counter("serve/plan_swaps").inc()
            self.reg.gauge("serve/swap_unix_s").set(
                float(self.swap_unix_s))
            self.reg.gauge("serve/swap_at_multiply").set(
                float(self.swap_at_multiply))
            if self.convert_s is not None:
                self.reg.gauge("serve/convert_s").set(
                    float(self.convert_s))
        conv_ms = (self.convert_s or 0.0) * 1e3
        print(f"[serve-spmv] migrated to {rp.label} after "
              f"{self.swap_at_multiply} multiplies (convert "
              f"{conv_ms:.1f} ms in background, break-even "
              f"~{self.breakeven:.3g} multiplies)")

    def _publish(self):
        if self.reg is not None:
            self.reg.gauge("serve/breakeven_estimate").set(
                float(self.breakeven))


def _serving_pass(op, xs, args, reg=None, controller=None):
    """The flush-by-flush serving loop: per-flush wall times into the
    ``serve/flush_s`` histogram (split pre/post-migration when a
    controller runs), one :class:`~repro.obs.ResidualRecord` per flush
    pairing the measured latency with the roofline prediction of the plan
    that served it, and the migration controller's between-flush hook —
    the observed side of the selector's model AND the feedback signal the
    break-even decision consumes."""
    from repro.spmm import RequestBatcher

    batcher = RequestBatcher(op, max_batch=args.max_batch, impl=args.impl,
                             spmm_fn=lambda _m, X: op.matmul(X))
    for x in xs:
        batcher.submit(x)
    ledger = reg.ledger if reg is not None else (
        controller.ledger if controller is not None else None)
    while batcher.pending:
        rp = op.plan        # one read: the plan this flush executes on
        k = min(batcher.pending, args.max_batch)
        t0 = time.perf_counter()
        out = batcher.flush()
        jax.block_until_ready(list(out.values()))
        dt = time.perf_counter() - t0
        if reg is not None:
            reg.histogram("serve/flush_s").observe(dt)
            if controller is not None:
                phase = ("serve/flush_postmigrate_s" if controller.swapped
                         else "serve/flush_premigrate_s")
                reg.histogram(phase).observe(dt)
        if ledger is not None:
            ledger.record("serve/flush", dt, rp.model_s(k), k=k,
                          **rp.labels(matrix=args.matrix, algo=rp.label,
                                      backend=jax.default_backend()))
        if controller is not None:
            controller.note_flush(k, dt, rp)
    if controller is not None:
        controller.finish()


def _print_metrics_summary(reg):
    flush = reg.histogram("serve/flush_s")
    if flush.count:
        p = flush.percentiles()
        print(f"[serve-spmv] flush latency over {flush.count} flushes: "
              f"p50 {p['p50']*1e3:.2f} ms, p95 {p['p95']*1e3:.2f} ms, "
              f"p99 {p['p99']*1e3:.2f} ms"
              f"{' (exact)' if flush.exact else ''}")
    phases = [h for h in reg.histograms()
              if h.count and (h.name.startswith("spmm/")
                              or h.name.startswith("batcher/"))]
    for h in sorted(phases, key=lambda h: h.name):
        print(f"[serve-spmv]   phase {h.name:<24} n={h.count:<4} "
              f"mean {h.mean*1e3:8.3f} ms  p95 "
              f"{h.quantile(0.95)*1e3:8.3f} ms")
    ledger = reg.ledger
    if len(ledger):
        corr = ledger.correction()
        print(f"[serve-spmv] residual (observed/modeled) over "
              f"{len(ledger)} flushes: geomean {corr:.3g} — the factor "
              "autotune(feedback=) will apply to this config's score")


def serve_spmv(args):
    """Sparse serving demo: batched (one SpMM per flush) vs sequential,
    optionally over a --devices mesh, all through one
    :class:`repro.spmm.SparseOperator` handle. ``--migrate auto`` starts
    in the zero-conversion format and converts online once the measured
    break-even clears the remaining traffic (``force`` converts
    unconditionally, in the background either way). Headline numbers use
    the paper's §5.2 min-of-N discipline; ``--metrics`` additionally
    records phase spans, flush-latency percentiles, migration decision
    inputs and observed-vs-modeled residuals, then dumps them as one
    ``repro.obs/v1`` JSON document."""
    from repro import obs
    from repro.core import PlanSpec, matrix_stats, spmv
    from repro.core.selector import ZERO_CONVERSION_ALGO
    from repro.data import matrices
    from repro.roofline import spmm_arithmetic_intensity
    from repro.spmm import RequestBatcher, SparseOperator

    suite = matrices.test_suite(scale=args.scale)
    if args.matrix not in suite:
        raise SystemExit(f"--matrix must be one of {sorted(suite)}")
    coo = matrices.as_coo(suite[args.matrix].make())
    stats = matrix_stats(coo)
    # num_spmvs counts k-RHS multiplies: batching turns `requests` SpMVs
    # into ceil(requests / max_batch) SpMM calls
    num_spmms = -(-args.requests // args.max_batch)
    mesh_shape = None
    if args.mesh:
        from repro.launch.mesh import parse_mesh_shape
        mesh_shape = parse_mesh_shape(args.mesh)
        args.devices = mesh_shape[0] * mesh_shape[1]
    if args.devices > 1:
        ndev = len(jax.devices())
        if ndev < args.devices:
            raise SystemExit(
                f"the mesh needs {args.devices} devices but jax sees only "
                f"{ndev}; on CPU set XLA_FLAGS=--xla_force_host_platform_"
                f"device_count={args.devices} before launching")
        if args.algorithm and args.algorithm != "sellcs":
            raise SystemExit(
                f"--algorithm {args.algorithm} cannot be served on a mesh: "
                "the --devices path multiplies the SELL-C-σ slice stream "
                "(repro.spmm.distributed); drop --algorithm or pass sellcs")
    if args.migrate != "off" and args.algorithm:
        raise SystemExit(
            "--algorithm pins the format, --migrate lets the break-even "
            "economics choose it; drop one of the two")

    # the target the migration converts TO (and what --migrate off serves
    # directly): SELL-C-σ over the requested mesh, with --mesh / --chunks
    # / --compact-x pinning knobs the selector would otherwise sweep
    compact = {"auto": None, "on": True, "off": False}[args.compact_x]
    gather = None if args.gather == "auto" else args.gather
    if args.devices > 1:
        target_spec = PlanSpec(
            num_devices=args.devices,
            mesh_shape=mesh_shape or (args.devices, 1),
            num_chunks=args.chunks if args.chunks > 0 else None,
            compact_x=compact, algorithm="sellcs", gather=gather)
    else:
        target_spec = PlanSpec(num_devices=1, algorithm="sellcs")
    if args.migrate != "off":
        # zero-conversion start: merge-path CSR on one device; the
        # controller decides if/when the target plan pays for itself
        initial_spec = PlanSpec(num_devices=1,
                                algorithm=ZERO_CONVERSION_ALGO)
    elif args.devices > 1:
        initial_spec = target_spec
    else:
        initial_spec = PlanSpec(num_devices=1, algorithm=args.algorithm)

    op = SparseOperator.from_coo(coo, initial_spec, impl=args.impl,
                                 k_hint=args.max_batch,
                                 num_spmvs=num_spmms)
    algo = op.plan.label
    print(f"[serve-spmv] matrix={args.matrix} m={stats.m} n={stats.n} "
          f"nnz={stats.nnz} algo={algo} max_batch={args.max_batch}"
          + (f" migrate={args.migrate}" if args.migrate != "off" else ""))

    rng = np.random.default_rng(args.seed)
    xs = [jnp.asarray(rng.standard_normal(stats.n).astype(np.float32))
          for _ in range(args.requests)]

    reg = None
    if args.metrics:
        reg = obs.install(obs.MetricRegistry(
            backend=jax.default_backend(), mode="spmv",
            matrix=args.matrix, algo=algo, devices=args.devices,
            max_batch=args.max_batch, migrate=args.migrate,
            requests=args.requests))
    controller = None
    if args.migrate != "off":
        ledger = reg.ledger if reg is not None else obs.ResidualLedger()
        controller = _MigrationController(op, stats, args, target_spec,
                                          ledger, reg=reg)

    # headline timing, the paper's §5.2 way: min over --reps runs after a
    # warmup/compile run — never a single first-flush perf_counter pair
    def batched_run():
        b = RequestBatcher(op, max_batch=args.max_batch, impl=args.impl,
                           spmm_fn=lambda _m, X: op.matmul(X))
        rids = [b.submit(x) for x in xs]
        return b.drain(), rids, b.flushes

    t_b = obs.time_min_of_n(batched_run, reps=args.reps, warmup=1)
    out, rids, num_flushes = t_b.last_result
    t_batched = t_b.best_s

    t_s = obs.time_min_of_n(
        lambda: [spmv(op.plan.local_matrix, x, impl=args.impl)
                 for x in xs],
        reps=args.reps, warmup=1)
    seq, t_seq = t_s.last_result, t_s.best_s

    for rid, y in zip(rids, seq):
        np.testing.assert_allclose(np.asarray(out[rid]), np.asarray(y),
                                   rtol=2e-4, atol=2e-4)
    ai1 = spmm_arithmetic_intensity(stats.nnz, stats.m, stats.n, 1)
    aik = spmm_arithmetic_intensity(stats.nnz, stats.m, stats.n,
                                    args.max_batch)
    print(f"[serve-spmv] batched {t_batched*1e3:.1f} ms "
          f"({num_flushes} SpMM calls) vs sequential "
          f"{t_seq*1e3:.1f} ms ({len(xs)} SpMV calls) — "
          f"speedup {t_seq/max(t_batched, 1e-9):.2f}x "
          f"(min of {t_b.reps}, warmup {t_b.warmup})")
    print(f"[serve-spmv] modelled intensity {ai1:.3f} -> {aik:.3f} "
          f"flop/byte at k={args.max_batch}")
    _print_traffic_model(op.spec, op.plan.n_touched, stats, args)

    if reg is not None or controller is not None:
        # the measured side: per-flush latencies + residual ledger records
        # against the roofline prediction of the plan serving each flush,
        # and the migration controller's between-flush decision hook
        _serving_pass(op, xs, args, reg=reg, controller=controller)
    if reg is not None:
        if op.plan.eager is not None:
            # one eager pass so the spmm/* phase spans time real execution
            # (inside the jitted flush they only see tracing); op.plan is
            # the post-migration plan when a swap landed
            with obs.span("serve/eager_profile"):
                jax.block_until_ready(op.plan.eager(
                    jnp.stack([x for x in xs[:args.max_batch]], axis=1)))
        _print_metrics_summary(reg)
        reg.dump(args.metrics)
        print(f"[serve-spmv] metrics -> {args.metrics}")
        obs.uninstall()
    return t_batched, t_seq


def _print_traffic_model(sp, n_touched, stats, args):
    """The modelled per-device traffic printout for a distributed plan
    (no-op on a single device): HBM + collective bytes per flush, the
    compact-gather saving, and the merge psum pipelining win."""
    if (sp.num_devices or 1) <= 1:
        return
    from repro.roofline import (spmm_distributed_collective_s,
                                spmm_distributed_gather_s,
                                spmm_distributed_traffic)
    sched, chunks = sp.schedule, sp.num_chunks or 1
    compact = bool(sp.compact_x)
    gx = (sp.gather or "upfront") if compact else "upfront"
    pd, pm = sp.mesh_shape
    hbm, coll = spmm_distributed_traffic(
        stats.m, stats.n, args.max_batch, pd, sched,
        nnz=stats.nnz, max_row_nnz=stats.max_row_nnz, model_devices=pm,
        compact_x=compact, n_touched=n_touched)
    print(f"[serve-spmv] modelled per-device traffic: {hbm / 1e6:.2f} MB "
          f"HBM + {coll / 1e6:.2f} MB collective per flush "
          f"(mesh=({pd},{pm}), schedule={sched}, chunks={chunks}, "
          f"compact_x={'on' if compact else 'off'}"
          + (f", gather={gx}" if compact else "") + ")")
    if compact:
        hbm_rep, _ = spmm_distributed_traffic(
            stats.m, stats.n, args.max_batch, pd, sched,
            nnz=stats.nnz, max_row_nnz=stats.max_row_nnz,
            model_devices=pm)
        print(f"[serve-spmv] compact gather: mean n_touched "
              f"{n_touched:.0f} of n={stats.n} rows per shard — "
              f"{(hbm_rep - hbm) / 1e6:.2f} MB HBM saved vs "
              "replicated X per flush")
        up, here = (spmm_distributed_gather_s(
            stats.m, stats.n, args.max_batch, pd, sched,
            nnz=stats.nnz, max_row_nnz=stats.max_row_nnz,
            num_chunks=chunks, model_devices=pm, compact_x=True,
            n_touched=n_touched, gather=g)
            for g in ("upfront", gx))
        print(f"[serve-spmv] exposed gather_s: {up * 1e6:.2f} us up-front "
              f"-> {here * 1e6:.2f} us with gather={gx}")
    if sched == "merge":
        mono, over = (spmm_distributed_collective_s(
            stats.m, stats.n, args.max_batch, pd, sched,
            nnz=stats.nnz, max_row_nnz=stats.max_row_nnz, num_chunks=c,
            model_devices=pm)
            for c in (1, chunks))
        print(f"[serve-spmv] exposed collective_s: {mono * 1e6:.2f} us "
              f"monolithic -> {over * 1e6:.2f} us with {chunks} "
              "chunk(s) pipelined under the slice stream")


def _fleet_target_spec(args, mesh_shape):
    """The same distributed-knob plumbing serve_spmv uses, shared by every
    tenant registration."""
    from repro.core import PlanSpec
    compact = {"auto": None, "on": True, "off": False}[args.compact_x]
    gather = None if args.gather == "auto" else args.gather
    if args.devices > 1:
        return PlanSpec(num_devices=args.devices,
                        mesh_shape=mesh_shape or (args.devices, 1),
                        num_chunks=args.chunks if args.chunks > 0 else None,
                        compact_x=compact, algorithm="sellcs",
                        gather=gather)
    return PlanSpec(num_devices=1, algorithm="sellcs")


def serve_fleet(args):
    """Multi-tenant fault-tolerant serving: N tenants over a
    :class:`repro.spmm.Fleet` (fingerprint-keyed plan cache — tenants
    cycle over two distinct matrices, so with >= 3 tenants at least one
    registration is a cache hit) fronted by a
    :class:`repro.spmm.FleetBatcher` whose scheduler picks each flush by
    SLO-deadline urgency × batch-efficiency. ``--fail-device`` kills one
    data-shard device mid-stream: the fleet re-deals every distributed
    tenant's width-row stream across the survivors
    (``SparseOperator.shrink_to`` → ``redeal_sellcs``) and keeps serving;
    every request queued before, during and after the loss is answered
    and checked against the COO oracle. Per-tenant flush latency lands in
    ``fleet/flush_s`` (split ``fleet/flush_preloss_s`` /
    ``fleet/flush_postloss_s`` around the loss) — the
    ``BENCH_serve_slo.json`` series ``smoke_check.check_slo`` gates."""
    from repro import obs
    from repro.data import matrices
    from repro.spmm import Fleet, FleetBatcher, spmm_coo

    if args.tenants < 1:
        raise SystemExit("--tenants must be >= 1")
    suite = matrices.test_suite(scale=args.scale)
    if args.matrix not in suite:
        raise SystemExit(f"--matrix must be one of {sorted(suite)}")
    # two distinct matrices cycled across the tenants: same-matrix tenants
    # exercise the fingerprint plan cache, the other matrix proves the
    # fleet really multiplexes independent operators
    alt = "hhh_like" if args.matrix != "hhh_like" else "road_like"
    names = [args.matrix, alt]
    mesh_shape = None
    if args.mesh:
        from repro.launch.mesh import parse_mesh_shape
        mesh_shape = parse_mesh_shape(args.mesh)
        args.devices = mesh_shape[0] * mesh_shape[1]
    if args.devices > 1 and len(jax.devices()) < args.devices:
        raise SystemExit(
            f"the mesh needs {args.devices} devices but jax sees only "
            f"{len(jax.devices())}; on CPU set XLA_FLAGS=--xla_force_"
            f"host_platform_device_count={args.devices} before launching")
    per_tenant = max(1, args.requests // args.tenants)
    fail_device = None
    if args.fail_device is not None:
        fail_device = (args.devices - 1 if args.fail_device == "auto"
                       else int(args.fail_device))
        if args.devices <= 1:
            raise SystemExit("--fail-device needs a --devices mesh")

    reg = None
    if args.metrics:
        reg = obs.install(obs.MetricRegistry(
            backend=jax.default_backend(), mode="fleet",
            matrix=args.matrix, devices=args.devices,
            max_batch=args.max_batch, tenants=args.tenants,
            slo_ms=args.slo_ms, requests=per_tenant,
            fail_device="" if fail_device is None else fail_device))

    spec = _fleet_target_spec(args, mesh_shape)
    fleet = Fleet(impl=args.impl)
    front = FleetBatcher()
    coos = {}
    for i in range(args.tenants):
        tenant = f"t{i}"
        coo = matrices.as_coo(suite[names[i % len(names)]].make())
        coos[tenant] = coo
        op = fleet.register(tenant, coo, spec, k_hint=args.max_batch,
                            num_spmvs=-(-per_tenant // args.max_batch))
        front.add_tenant(tenant, op, max_batch=args.max_batch,
                         slo_s=args.slo_ms / 1e3,
                         max_pending=args.max_pending or None,
                         overflow="block")
        print(f"[serve-fleet] {tenant}: {names[i % len(names)]} "
              f"plan={op.plan.label} builds="
              f"(sellcs={op.stats.sellcs_builds}, "
              f"partition={op.stats.partition_builds})")
    print(f"[serve-fleet] plan cache: {fleet.stats.plan_cache_hits} hits, "
          f"{fleet.stats.plan_cache_misses} misses over "
          f"{fleet.stats.registered} registrations")

    rng = np.random.default_rng(args.seed)
    sent = {}                                # (tenant, rid) -> x
    for j in range(per_tenant):
        for i in range(args.tenants):
            tenant = f"t{i}"
            x = jnp.asarray(rng.standard_normal(
                coos[tenant].shape[1]).astype(np.float32))
            rid = front.submit(tenant, x)
            sent[(tenant, rid)] = x

    total = per_tenant * args.tenants
    half = total // 2
    served = 0
    lost = False
    results = {}                             # (tenant, rid) -> y
    while front.total_pending:
        if fail_device is not None and not lost and served >= half:
            t0 = time.perf_counter()
            redone = fleet.handle_device_loss([fail_device])
            dt = time.perf_counter() - t0
            lost = True
            print(f"[serve-fleet] device {fail_device} lost after "
                  f"{served}/{total} served — re-dealt "
                  f"{len(redone)} tenant plan(s) across "
                  f"{args.devices - 1} survivors in {dt*1e3:.1f} ms")
        t0 = time.perf_counter()
        tenant, out = front.flush_next()
        if tenant is None:
            break
        jax.block_until_ready(list(out.values()))
        dt = time.perf_counter() - t0
        served += len(out)
        for rid, y in out.items():
            results[(tenant, rid)] = y
        fleet.observe_flush(tenant, dt)
        if reg is not None:
            lab = {"tenant": tenant}
            reg.histogram("fleet/flush_s", lab).observe(dt)
            phase = ("fleet/flush_postloss_s" if lost
                     else "fleet/flush_preloss_s")
            reg.histogram(phase, lab).observe(dt)

    # the no-drop + correctness contract: every queued request answered,
    # every answer equal to the COO oracle of its tenant's matrix —
    # including everything served after the device loss
    assert len(results) == total, (len(results), total)
    for (tenant, rid), x in sent.items():
        y_ref = spmm_coo(coos[tenant], x[:, None])[:, 0]
        np.testing.assert_allclose(np.asarray(results[(tenant, rid)]),
                                   np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)
    print(f"[serve-fleet] {total} requests served across "
          f"{args.tenants} tenants, all oracle-checked"
          + (" (incl. post-loss traffic)" if lost else ""))

    for i in range(args.tenants):
        tenant = f"t{i}"
        lane = front.lane(tenant)
        line = (f"[serve-fleet] {tenant}: served={lane.served} "
                f"flushes={lane.flushes} "
                f"slo_violations={lane.slo_violations}")
        if reg is not None:
            h = reg.histogram("fleet/flush_s", {"tenant": tenant})
            if h.count:
                p = h.percentiles()
                line += (f" | flush p50 {p['p50']*1e3:.2f} ms "
                         f"p95 {p['p95']*1e3:.2f} ms "
                         f"p99 {p['p99']*1e3:.2f} ms")
        print(line)
    if reg is not None:
        reg.dump(args.metrics)
        print(f"[serve-fleet] metrics -> {args.metrics}")
        obs.uninstall()
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "spmv", "fleet"), default="lm")
    ap.add_argument("--arch")
    # spmv-mode arguments (repro.spmm request batching)
    ap.add_argument("--matrix", default="mawi_like")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--algorithm", default=None,
                    help="force a format (default: core.select with k)")
    ap.add_argument("--devices", type=int, default=1,
                    help="serve each flush with a distributed SpMM over a "
                         "1-D data mesh of this many devices (schedule "
                         "chosen by core.select_distributed)")
    ap.add_argument("--mesh", default=None, metavar="Pd,Pm",
                    help="pin a 2-D (data, model) mesh factorization for "
                         "the distributed SpMM, e.g. 4,2 — the model axis "
                         "column-shards the X/Y k-slabs so per-device psum "
                         "and replicated-X bytes drop by Pm (overrides "
                         "--devices with Pd*Pm)")
    ap.add_argument("--chunks", type=int, default=0,
                    help="pipeline the merge-schedule psum into this many "
                         "chunks (0 = pick by the roofline overlap model; "
                         "ignored by the row schedule)")
    ap.add_argument("--compact-x", default="auto",
                    choices=("auto", "on", "off"), dest="compact_x",
                    help="sparsity-aware X gather for the distributed SpMM:"
                         " partition with per-shard column compaction so "
                         "each data shard gathers only the X rows its "
                         "nonzeros touch (auto = let the traffic model "
                         "decide when the gather beats replication)")
    ap.add_argument("--gather", default="auto",
                    choices=("auto", "upfront", "overlap", "fused"),
                    help="compact-X gather schedule: materialize the slab "
                         "up-front ahead of the mesh region, hide per-span "
                         "rebuilds under the chunked merge span loop "
                         "(overlap), or fuse the gather into the Pallas "
                         "kernel's scalar prefetch (fused); auto = let the "
                         "exposed-gather-seconds roofline term pick")
    ap.add_argument("--impl", default="auto",
                    choices=("auto", "ref", "pallas", "pallas_interpret"))
    ap.add_argument("--migrate", default="off",
                    choices=("auto", "off", "force"),
                    help="online break-even format migration: start in the "
                         "zero-conversion merge-path format, count served "
                         "multiplies, and convert to the SELL-C-σ target "
                         "plan in a background thread once the measured "
                         "convert-cost / per-multiply-saving ratio clears "
                         "the projected remaining traffic (auto), "
                         "unconditionally (force), or never (off)")
    ap.add_argument("--metrics", default=None, metavar="OUT.json",
                    help="install a repro.obs registry for the run and dump "
                         "it here: phase spans, p50/p95/p99 flush latency, "
                         "and observed-vs-modeled residuals (repro.obs/v1)")
    ap.add_argument("--reps", type=int, default=5,
                    help="min-of-N repetitions for the headline batched-vs-"
                         "sequential timing (the paper's §5.2 protocol)")
    # fleet-mode arguments (multi-tenant serving with device-loss re-deal)
    ap.add_argument("--tenants", type=int, default=3,
                    help="fleet mode: number of tenants; they cycle over "
                         "two distinct matrices so >= 3 tenants exercise "
                         "the fingerprint plan cache")
    ap.add_argument("--slo-ms", type=float, default=50.0, dest="slo_ms",
                    help="fleet mode: per-request latency budget driving "
                         "the cross-tenant flush scheduler (urgency = "
                         "oldest queue wait / budget)")
    ap.add_argument("--fail-device", default=None, dest="fail_device",
                    help="fleet mode: kill this device index midway "
                         "through the stream ('auto' = the last mesh "
                         "device) and re-deal its spans across survivors")
    ap.add_argument("--max-pending", type=int, default=0,
                    dest="max_pending",
                    help="fleet mode: per-tenant queue bound (0 = "
                         "unbounded); submits past it block until a flush "
                         "makes room")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.mode == "spmv":
        return serve_spmv(args)
    if args.mode == "fleet":
        return serve_fleet(args)
    if not args.arch:
        ap.error("--arch is required in lm mode")

    cfg = get_config(args.arch, reduced=args.reduced)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    S_max = P + G + (cfg.vision_tokens if cfg.frontend == "vision" else 0)
    rng = jax.random.PRNGKey(args.seed + 1)
    prompts = jax.random.randint(rng, (B, P), 0, cfg.vocab)
    vis = None
    if cfg.frontend == "vision":
        vis = jax.random.normal(rng, (B, cfg.vision_tokens, cfg.vision_dim))

    prefill_fn = jax.jit(lambda p, t, v: prefill(
        p, cfg, t, S_max, cache_dtype=jnp.float32, vision_embeds=v))
    decode_fn = jax.jit(lambda p, tok, c, pos: decode_step(
        p, cfg, tok, c, pos))

    t0 = time.perf_counter()
    logits, caches = prefill_fn(params, prompts, vis)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    offset = cfg.vision_tokens if cfg.frontend == "vision" else 0
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(G - 1):
        pos = jnp.full((B,), offset + P + i, jnp.int32)
        logits, caches = decode_fn(params, tok, caches, pos)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    tok.block_until_ready()
    t_decode = time.perf_counter() - t0

    gen = np.asarray(jnp.concatenate(out_tokens, axis=1))
    tps = B * (G - 1) / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} batch={B} prompt={P} gen={G}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; decode "
          f"{t_decode*1e3:.1f} ms ({tps:.1f} tok/s incl. compile)")
    print(f"[serve] sample generations (first 2 rows): {gen[:2].tolist()}")
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab)
    return gen


if __name__ == "__main__":
    main()
