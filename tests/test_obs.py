"""repro.obs — metrics registry, phase tracing, the residual ledger, the
min-of-N timing helper, and every surface that consumes them: the
batcher's serve telemetry, ``autotune(feedback=)`` grid rescoring, the
harness's protocol stamping, and ``smoke_check``'s residual gates.

The two load-bearing guarantees locked down here:

* quantiles are EXACT order statistics while a histogram's count stays
  within its reservoir capacity (serve percentiles at real flush counts
  must not be estimates), checked against ``np.quantile``;
* the disabled path is free: with no registry installed, ``span()``
  returns a process-wide singleton and allocates nothing — asserted with
  ``tracemalloc`` — so the flush hot path can stay instrumented.
"""
import json
import math
import threading
import tracemalloc

import numpy as np
import pytest

from repro import obs
from repro.obs import (Histogram, MetricRegistry, ResidualLedger,
                       choice_labels, span, time_min_of_n)


@pytest.fixture(autouse=True)
def _no_registry():
    """Every test starts and ends with instrumentation disabled."""
    obs.uninstall()
    yield
    obs.uninstall()


# ---------------------------------------------------------------- metrics

def _hist(capacity=1024):
    return Histogram("t", (), capacity=capacity)


@pytest.mark.parametrize("values", [
    [1.0],                              # n=1: every quantile is the value
    [2.0, 1.0],                         # n=2: interpolation between both
    [3.0, 1.0, 2.0],                    # n=3
    [5.0] * 7,                          # constant stream
    list(range(100)),
    list(np.random.default_rng(0).standard_normal(257)),
])
def test_quantiles_exact_match_numpy(values):
    h = _hist()
    for v in values:
        h.observe(v)
    assert h.exact
    for q in (0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0):
        assert h.quantile(q) == pytest.approx(
            float(np.quantile(np.asarray(values, float), q)), rel=1e-12)
    assert h.count == len(values)
    assert h.total == pytest.approx(sum(values))
    assert h.min == min(values) and h.max == max(values)
    assert h.mean == pytest.approx(sum(values) / len(values))


def test_empty_histogram_quantiles_are_none():
    h = _hist()
    assert h.quantile(0.5) is None
    assert h.mean is None
    assert h.percentiles() == {"p50": None, "p95": None, "p99": None}


def test_reservoir_bounds_memory_and_keeps_minmax_exact():
    h = _hist(capacity=64)
    rng = np.random.default_rng(1)
    values = rng.standard_normal(10_000)
    for v in values:
        h.observe(float(v))
    assert not h.exact
    assert len(h._reservoir) == 64          # bounded, past capacity
    assert h.count == 10_000
    # min/max/sum track the FULL stream even after downsampling
    assert h.min == float(values.min()) and h.max == float(values.max())
    assert h.total == pytest.approx(float(values.sum()))
    # the estimate stays an estimate of the right distribution
    assert abs(h.quantile(0.5) - float(np.quantile(values, 0.5))) < 0.5


def test_reservoir_is_deterministic_across_instances():
    def fill():
        h = _hist(capacity=16)
        for v in range(1000):
            h.observe(float(v))
        return list(h._reservoir)
    assert fill() == fill()                  # crc32-seeded, not hash()


def test_quantile_rejects_out_of_range():
    h = _hist()
    h.observe(1.0)
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_registry_series_identity_and_labels():
    reg = MetricRegistry(backend="cpu")
    assert reg.counter("c") is reg.counter("c")
    assert reg.counter("c", {"k": 1}) is not reg.counter("c", {"k": 2})
    # label order must not mint a new series
    assert reg.histogram("h", {"a": 1, "b": 2}) is \
        reg.histogram("h", {"b": 2, "a": 1})


def test_registry_dump_schema(tmp_path):
    reg = MetricRegistry(backend="cpu", mesh="4x2")
    reg.counter("flushes").inc()
    reg.counter("flushes").inc(2)
    reg.gauge("pending").set(3)
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.histogram("flush_s", {"k": 8}).observe(v)
    reg.ledger.record("serve/flush", 2e-3, 1e-3, schedule="merge")
    path = tmp_path / "m.json"
    doc = reg.dump(str(path))
    assert json.loads(path.read_text()) == doc
    assert doc["schema"] == MetricRegistry.SCHEMA == "repro.obs/v1"
    assert doc["labels"] == {"backend": "cpu", "mesh": "4x2"}
    (c,) = doc["counters"]
    assert c["value"] == 3.0 and c["labels"]["backend"] == "cpu"
    (h,) = doc["histograms"]
    assert h["count"] == 4 and h["exact"] is True
    assert h["labels"] == {"backend": "cpu", "mesh": "4x2", "k": "8"}
    assert h["p50"] == pytest.approx(2.5)
    assert h["min"] == 1.0 and h["max"] == 4.0
    (r,) = doc["residuals"]
    assert r["residual"] == pytest.approx(2.0)
    assert r["labels"] == {"schedule": "merge"}


def test_install_uninstall_toggle_enabled():
    assert not obs.enabled() and obs.current_registry() is None
    reg = obs.install(MetricRegistry())
    assert obs.enabled() and obs.current_registry() is reg
    obs.uninstall()
    assert not obs.enabled()


# ------------------------------------------------------------------ spans

def test_span_records_wall_time():
    reg = obs.install(MetricRegistry())
    with span("phase"):
        pass
    h = reg.histogram("phase")
    assert h.count == 1 and 0 <= h.min < 1.0


def test_span_nesting_builds_slash_paths():
    reg = obs.install(MetricRegistry())
    with span("flush"):
        with span("pad"):
            pass
        with span("multiply"):
            pass
    names = {h.name for h in reg.histograms() if h.count}
    assert names == {"flush", "flush/pad", "flush/multiply"}


def test_absolute_span_names_ignore_the_stack():
    """Library instrumentation (spmm/kernel) keeps a stable series name no
    matter which caller spans are open — and does not extend the stack."""
    reg = obs.install(MetricRegistry())
    with span("flush"):
        with span("spmm/kernel"):
            with span("inner"):
                pass
    names = {h.name for h in reg.histograms() if h.count}
    assert "spmm/kernel" in names
    assert "flush/inner" in names           # kernel never joined the stack


def test_span_reentrancy_same_name():
    reg = obs.install(MetricRegistry())
    with span("a"):
        with span("a"):
            pass
    assert reg.histogram("a").count == 1
    assert reg.histogram("a/a").count == 1


def test_span_records_and_unwinds_on_exception():
    reg = obs.install(MetricRegistry())
    with pytest.raises(RuntimeError):
        with span("outer"):
            with span("dies"):
                raise RuntimeError("boom")
    assert reg.histogram("outer/dies").count == 1
    assert reg.histogram("outer").count == 1
    with span("outer"):                     # the stack fully unwound
        with span("next"):
            pass
    assert reg.histogram("outer/next").count == 1


def test_span_stack_is_per_thread():
    reg = obs.install(MetricRegistry())
    seen = []

    def worker():
        with span("w"):
            seen.append(True)

    with span("main"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert seen and reg.histogram("w").count == 1   # not "main/w"


def test_disabled_span_is_singleton_and_allocation_free():
    """The zero-overhead guarantee: with no registry installed, span()
    returns one shared object and the enter/exit cycle allocates zero
    bytes — the batcher can keep its instrumentation on the flush hot
    path unconditionally."""
    assert span("x") is span("y")           # shared null singleton

    def hot_loop(n):
        for _ in range(n):
            with span("hot"):
                pass

    hot_loop(10)                            # warm up lazy interning
    tracemalloc.start()
    hot_loop(1000)
    snap = tracemalloc.take_snapshot()
    tracemalloc.stop()
    # a per-call allocation would show up ~1000 times; one-shot constants
    # (the range object, the snapshot machinery itself) are fine
    per_iter = [s for s in snap.statistics("lineno")
                if s.traceback[0].filename == __file__ and s.count > 2]
    assert not per_iter, f"disabled span allocates per call: {per_iter}"


def test_maybe_block_passthrough_when_disabled():
    x = object()
    assert obs.maybe_block(x) is x


# ---------------------------------------------------------------- ledger

def test_ledger_residual_invariant():
    led = ResidualLedger()
    rec = led.record("r", 3e-3, 1.5e-3, schedule="row")
    assert rec.residual == pytest.approx(rec.observed_s / rec.modeled_s)
    assert rec.residual == pytest.approx(2.0)
    for r in led.records():
        assert math.isfinite(r.residual) and r.residual > 0


@pytest.mark.parametrize("obs_s,mod_s", [
    (0.0, 1.0), (-1.0, 1.0), (float("nan"), 1.0), (float("inf"), 1.0),
    (1.0, 0.0), (1.0, -2.0), (1.0, float("nan")),
])
def test_ledger_rejects_degenerate_pairs(obs_s, mod_s):
    with pytest.raises(ValueError):
        ResidualLedger().record("r", obs_s, mod_s)


def test_ledger_correction_geomean_and_default():
    led = ResidualLedger()
    assert led.correction(schedule="row") == 1.0        # no evidence
    assert led.correction(default=7.0, schedule="row") == 7.0
    led.record("a", 2.0, 1.0, schedule="row")           # residual 2
    led.record("b", 1.0, 2.0, schedule="row")           # residual 0.5
    assert led.correction(schedule="row") == pytest.approx(1.0)
    led.record("c", 8.0, 1.0, schedule="merge")
    assert led.correction(schedule="merge") == pytest.approx(8.0)


def test_ledger_absent_record_keys_are_wildcards():
    """A coarse record (schedule only) corrects every query that agrees on
    schedule, whatever its finer labels; a fully-labelled record only
    matches queries that agree on every label it carries."""
    led = ResidualLedger()
    led.record("coarse", 4.0, 1.0, schedule="merge")
    q = choice_labels(schedule="merge", num_chunks=4, mesh_shape=(4, 2),
                      compact_x=True)
    assert led.correction(**q) == pytest.approx(4.0)
    led2 = ResidualLedger()
    led2.record("fine", 4.0, 1.0, **q)
    assert led2.correction(**q) == pytest.approx(4.0)
    q_other = dict(q, num_chunks="8")
    assert led2.correction(**q_other) == 1.0            # label disagrees


def test_choice_labels_canonical_forms():
    lab = choice_labels(schedule="merge", num_chunks=4, mesh_shape=(4, 2),
                        compact_x=True, k=64)
    assert lab == {"schedule": "merge", "num_chunks": "4", "mesh": "4x2",
                   "compact_x": "on", "k": "64"}
    assert choice_labels(compact_x=False)["compact_x"] == "off"
    assert choice_labels() == {}


# ---------------------------------------------------------------- timing

def test_time_min_of_n_protocol_and_result():
    calls = []
    r = time_min_of_n(lambda: calls.append(1) or len(calls),
                      reps=4, warmup=2, block=False)
    assert len(calls) == 6                  # warmup + reps, all executed
    assert r.reps == 4 and r.warmup == 2
    assert r.best_s >= 0 and r.last_result == 6


def test_time_min_of_n_rejects_bad_protocol():
    with pytest.raises(ValueError):
        time_min_of_n(lambda: None, reps=0)
    with pytest.raises(ValueError):
        time_min_of_n(lambda: None, warmup=-1)


# ------------------------------------------------- batcher serve metrics

def _tiny_coo():
    from repro.core.formats import COO
    rng = np.random.default_rng(0)
    m = n = 64
    nnz = 300
    return COO(rng.integers(0, m, nnz).astype(np.int32),
               rng.integers(0, n, nnz).astype(np.int32),
               rng.standard_normal(nnz).astype(np.float32), (m, n))


def test_batcher_records_serve_metrics():
    from repro.core import convert
    from repro.spmm import RequestBatcher
    import jax.numpy as jnp
    mat = convert(_tiny_coo(), "sellcs")
    reg = obs.install(MetricRegistry())
    b = RequestBatcher(mat, max_batch=4, impl="ref")
    rng = np.random.default_rng(2)
    for _ in range(6):
        b.submit(jnp.asarray(rng.standard_normal(64).astype(np.float32)))
    assert reg.gauge("batcher/pending").value == 6
    out = b.drain()
    assert len(out) == 6
    assert reg.counter("batcher/submitted").value == 6
    assert reg.counter("batcher/served").value == 6
    assert reg.counter("batcher/flushes").value == 2
    assert reg.gauge("batcher/pending").value == 0
    assert reg.histogram("batcher/queue_wait_s").count == 6
    assert reg.histogram("batcher/flush").count == 2
    for phase in ("batcher/pad", "batcher/multiply", "batcher/scatter"):
        assert reg.histogram(phase).count == 2, phase
    assert not b._submit_t                  # timestamps fully consumed


def test_batcher_uninstrumented_results_identical():
    """Metrics must observe, never perturb: the served vectors are
    bitwise the same with and without a registry installed."""
    from repro.core import convert
    from repro.spmm import RequestBatcher
    import jax.numpy as jnp
    mat = convert(_tiny_coo(), "sellcs")
    xs = [np.random.default_rng(i).standard_normal(64).astype(np.float32)
          for i in range(5)]

    def serve():
        b = RequestBatcher(mat, max_batch=4, impl="ref")
        rids = [b.submit(jnp.asarray(x)) for x in xs]
        out = b.drain()
        return [np.asarray(out[r]) for r in rids]

    plain = serve()
    obs.install(MetricRegistry())
    instrumented = serve()
    obs.uninstall()
    for a, b_ in zip(plain, instrumented):
        np.testing.assert_array_equal(a, b_)


# ------------------------------------------------- autotune feedback loop

def test_autotune_feedback_reorders_rigged_grid():
    """A ledger claiming the model flatters the winner by 100x must flip
    the distributed grid to another candidate, and the correction the
    winner's score actually absorbed lands in TuneResult.residual."""
    from repro.core import autotune
    led_best, _ = autotune(_tiny_coo(), num_spmvs=10,
                           algorithms=("sellcs",), reps=1, k=8,
                           num_devices=8)
    assert led_best.residual is None        # no feedback, no correction
    led = ResidualLedger()
    led.record("rig", 100.0, 1.0, schedule=led_best.schedule)
    fb_best, _ = autotune(_tiny_coo(), num_spmvs=10,
                          algorithms=("sellcs",), reps=1, k=8,
                          num_devices=8, feedback=led)
    assert fb_best.schedule != led_best.schedule
    # the un-penalized winner carried no matching record -> no correction
    assert fb_best.residual is None
    # now penalize EVERY schedule; whoever wins absorbed its correction
    led.record("rig2", 100.0, 1.0, schedule=fb_best.schedule)
    all_best, results = autotune(_tiny_coo(), num_spmvs=10,
                                 algorithms=("sellcs",), reps=1, k=8,
                                 num_devices=8, feedback=led)
    assert all_best.residual == pytest.approx(100.0)
    assert all(r.residual == pytest.approx(100.0) for r in results)


# ------------------------------------------------- harness metadata stamp

def test_harness_stamps_backend_and_protocol(capsys):
    import jax
    from benchmarks import harness
    harness.reset_records()
    csv = harness.Csv("t")
    sec = harness.time_fn(lambda: 1, reps=2, warmup=1)
    csv.row("timed", sec, "gflops=1")
    csv.row("break_even.analytic", 0.0, "spmvs_to_amortize=inf")
    capsys.readouterr()
    timed, analytic = harness.records()
    assert timed["backend"] == jax.default_backend()
    assert timed["reps"] == 2 and timed["warmup"] == 1
    assert analytic["backend"] == jax.default_backend()
    assert "reps" not in analytic           # nothing timed the row
    harness.reset_records()


# -------------------------------------------------- smoke_check residuals

def test_smoke_check_residual_derived_field():
    import benchmarks.smoke_check as sk

    def row(residual, backend):
        return {"section": "s", "name": "m/sellcs+row@4dev/k=8",
                "us_per_call": 10.0,
                "derived": f"gflops=1;residual={residual};"
                           f"backend={backend}"}
    # finite-and-positive everywhere
    assert sk.check_residuals([row(2.5, "cpu")], "f") == []
    assert any("finite" in p
               for p in sk.check_residuals([row("nan", "cpu")], "f"))
    assert any("finite" in p
               for p in sk.check_residuals([row(0.0, "tpu")], "f"))
    # the 10x model-off flag arms off-cpu only
    assert sk.check_residuals([row(500.0, "cpu")], "f") == []
    bad = sk.check_residuals([row(500.0, "tpu")], "f")
    assert len(bad) == 1 and "more than 10x" in bad[0]
    assert sk.check_residuals([row(0.005, "tpu")], "f") != []
    assert sk.check_residuals([row(9.9, "tpu")], "f") == []


def test_smoke_check_obs_document(tmp_path):
    import benchmarks.smoke_check as sk
    reg = MetricRegistry(backend="cpu", mode="spmv")
    for v in (1e-3, 2e-3, 3e-3):
        reg.histogram("serve/flush_s").observe(v)
    reg.counter("batcher/flushes").inc(3)
    reg.ledger.record("serve/flush", 1.0, 1e-5, backend="cpu")
    assert sk.check_obs_document(reg.as_dict(), "m.json") == []
    # same huge residual on a tpu-labelled record -> flagged
    reg2 = MetricRegistry(backend="tpu")
    reg2.ledger.record("serve/flush", 1.0, 1e-5, backend="tpu")
    bad = sk.check_obs_document(reg2.as_dict(), "m.json")
    assert len(bad) == 1 and "more than 10x" in bad[0]
    # and main() dispatches a dumped document by its schema key
    path = tmp_path / "BENCH_serve_metrics.json"
    reg.dump(str(path))
    assert sk.main([str(path)]) == 0


def test_smoke_check_obs_document_structural():
    import benchmarks.smoke_check as sk
    doc = {"schema": "repro.obs/v1", "labels": {},
           "counters": [{"name": "c", "labels": {}, "value": -1.0}],
           "gauges": [],
           "histograms": [{"name": "h", "labels": {}, "count": 2,
                           "sum": 3.0, "min": 1.0, "max": 2.0,
                           "mean": 1.5, "exact": True,
                           "p50": 2.0, "p95": 1.5, "p99": 2.0}],
           "residuals": []}
    problems = sk.check_obs_document(doc, "m.json")
    assert any("counter/c" in p for p in problems)
    assert any("quantiles out of order" in p for p in problems)


# ----------------------------------------------------- serve e2e (1 dev)

def test_serve_spmv_metrics_end_to_end(tmp_path):
    """serve --mode spmv --metrics on one device: the dump is a valid
    repro.obs/v1 document with flush percentiles, batcher phase spans,
    and one residual record per flush."""
    import benchmarks.smoke_check as sk
    from repro.launch import serve
    path = tmp_path / "serve_metrics.json"
    serve.main(["--mode", "spmv", "--matrix", "mawi_like",
                "--requests", "8", "--max-batch", "4", "--impl", "ref",
                "--reps", "1", "--metrics", str(path)])
    doc = json.loads(path.read_text())
    assert doc["schema"] == "repro.obs/v1"
    assert doc["labels"]["mode"] == "spmv"
    hists = {h["name"]: h for h in doc["histograms"]}
    assert hists["serve/flush_s"]["count"] == 2        # 8 reqs / batch 4
    assert hists["serve/flush_s"]["exact"] is True
    assert hists["serve/flush_s"]["p50"] > 0
    assert hists["batcher/multiply"]["count"] >= 2
    assert len(doc["residuals"]) == 2
    for r in doc["residuals"]:
        assert r["name"] == "serve/flush"
        assert math.isfinite(r["residual"]) and r["residual"] > 0
        assert r["labels"]["schedule"] == "single"
    assert sk.check_obs_document(doc, str(path)) == []
    assert not obs.enabled()                # serve uninstalled on exit
