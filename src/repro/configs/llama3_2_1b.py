"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B]: GQA(kv=8), tied embeddings."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", n_layers=16, d_model=2048, n_heads=32, kv_heads=8,
    d_ff=8192, vocab=128256, head_dim=64, rope_theta=5e5,
    tie_embeddings=True,
    block_pattern=("attn",), mlp_pattern=("dense",))

REDUCED = ModelConfig(
    name="llama3.2-1b-reduced", n_layers=2, d_model=64, n_heads=4,
    kv_heads=2, d_ff=160, vocab=256, head_dim=16, tie_embeddings=True,
    block_pattern=("attn",), mlp_pattern=("dense",),
    compute_dtype=jnp.float32, loss_chunk=16)
