"""CI gate over benchmark JSON emissions (the ``BENCH_*.json`` trajectory).

A benchmark that runs but emits NaN timings or zero GFLOP/s rows is worse
than one that crashes — it seeds the perf history with garbage that later
regression checks would diff against. This checker fails the job instead:

  python -m benchmarks.smoke_check BENCH_*.json

Rules, per record ({"section", "name", "us_per_call", "derived"}):
  * ``us_per_call`` must be finite and >= 0 (exactly 0 is allowed only for
    analytic rows such as the break-even table, which report no timing);
  * every ``gflops=<v>`` field in ``derived`` must be finite and > 0;
  * a file with zero records fails (an empty emission means the benchmark
    silently did nothing).

Cross-row rule (the chunked-psum overlap gate): for every
``.../sellcs+merge@Pdev/chunks=<c>/k=<k>`` group emitted by
``benchmarks.spmm_sweep --chunks``, IF the sweep's own roofline
prediction (the ``model_us`` derived field) says some pipelined depth
should be at least as fast as the monolithic fixup, then the BEST
measured chunked row (c > 1) must not run more than
``CHUNK_REGRESSION_TOLERANCE`` slower than the ``chunks=1`` row — where
the model says overlap pays, pipelining must never cost real time, only
hide it. Groups where the model itself predicts chunking loses (tiny
smoke matrices, launch-dominated psums, host-platform meshes with no
async collectives) are recorded but not gated — failing them would
punish the code for physics the model already prices.

Second cross-row rule (the 2-D mesh gate): for every
``.../sellcs+<sched>@PdxPmmesh[/chunks=<c>]/k=<k>`` group emitted by
``benchmarks.spmm_sweep --mesh``, rows that factor the same device total
are compared across mesh shapes: IF the traffic model (``model_us``)
says some model-sharded shape (``Pm > 1``) is at least as fast as the
pure-data (``Pm = 1``) shape, the best measured model-sharded row must
not run more than ``MESH_REGRESSION_TOLERANCE`` slower than the pure-data
row — where the model says the model axis pays, column-sharding X/Y must
never cost real time. Groups where the model predicts the model axis
loses (small k, stream-dominated) are recorded but not gated, and so are
rows measured on a backend without per-device memory (``backend=cpu`` —
a host-platform mesh keeps "replicated" X as one shared buffer, so the
model-axis byte saving is physically unobservable there and a measured
loss is mesh overhead, not a bug).

Third cross-row rule (the compact-gather gate): for every
``.../sellcs+<sched>@<mesh>[/chunks=<c>]/cx=<on|off>/k=<k>`` pair emitted
by ``benchmarks.spmm_sweep --compact-x on,off``, IF the traffic model
(``model_us``, priced with the partitioner's measured mean ``n_touched``)
says the sparsity-aware X gather is STRICTLY faster than replication, the
measured ``cx=on`` row must not run more than
``COMPACT_REGRESSION_TOLERANCE`` slower than its ``cx=off`` twin — where
the model says the gather pays, compaction must never cost real time.
Rows where the model predicts the gather does not strictly win — losses
AND the exact tie of near-dense columns (``n_touched`` capped at ``n``
makes the modelled figures equal while the gather's unpriced overhead
remains) — are recorded but not gated, matching the selector's
tie-refusal; and — like the mesh gate — so are ``backend=cpu`` rows: a
host-platform mesh keeps X as one shared buffer, so the gather's byte
saving is physically unobservable there.

Fifth cross-row rule (the gather-overlap gate): for every compacted row
group differing only in the ``gx=<mode>`` segment emitted by
``benchmarks.spmm_sweep --gather`` (the up-front baseline keeps its
unsuffixed name), IF the exposed-gather roofline term (the
``exposed_gather_us`` derived field) says some hidden-gather schedule
(``overlap``'s per-span double-buffer, ``fused``'s in-kernel prefetch)
strictly shrinks the exposed gather time, the best measured hidden row
must not run more than ``GATHER_REGRESSION_TOLERANCE`` slower than the
up-front row — where the model says hiding the gather pays, hiding it
must never cost real time. Groups where the model prices the schedules
equally (row schedule, single chunk — overlap degenerates to up-front)
are recorded but not gated, and — like the mesh/compact gates — so are
``backend=cpu`` rows: a host-platform mesh shares one X buffer, so the
hidden bytes cannot show up in wall time.

Fourth cross-row rule (the transpose gate): for every
``.../op=N|T/k=<k>`` pair emitted by ``benchmarks.spmm_sweep --op N,T``,
the measured ``op=T`` row must stay within
``TRANSPOSE_REGRESSION_TOLERANCE`` of the op-aware traffic model's
predicted N-to-T slowdown applied to its ``op=N`` twin — the
scatter-accumulate transpose may cost what the extra priced traffic
costs, never more. ``backend=cpu`` rows are recorded but not gated (the
host-platform mesh shares one buffer, so the priced deltas cannot show
up in wall time).

Residual rule (the model-honesty gate): every ``residual=<v>`` derived
field (``benchmarks.spmm_sweep``) and every record in an ``repro.obs/v1``
document's ``"residuals"`` list (``launch.serve --metrics``) must be
finite and > 0 — a NaN/zero residual means one side of the
observed-vs-modeled pairing was garbage. On a backend with per-device
memory the gate additionally flags residuals outside
``[1/RESIDUAL_MAX_OFF, RESIDUAL_MAX_OFF]`` (model off by more than 10x
where it claims to apply); ``backend=cpu`` rows only get the finiteness
check — the traffic model prices HBM and ICI a host-platform mesh does
not have, so a huge cpu residual is expected, not a bug.

A ``repro.obs/v1`` document (a dict, not a record list — the schema
``launch.serve --metrics`` dumps) is validated structurally too: every
histogram's count/sum finite, quantiles ordered (p50 <= p95 <= p99), and
counters non-negative.

Migration rule (the online break-even gate): a document whose base labels
carry ``migrate=auto|force`` (``launch.serve --migrate``) must show the
controller actually ran — ``serve/multiplies_total`` present and at least
the stamped ``requests`` label (every served column counted), and the
``serve/breakeven_estimate`` gauge present. ``force`` mode additionally
requires the swap to have landed (``serve/plan_swaps`` >= 1, a positive
``serve/swap_unix_s``, finite positive ``serve/convert_s``) and a finite
positive break-even estimate (both of its sides were measured by then).
``auto`` mode gates neither the swap nor finiteness: below-break-even
traffic honestly never converts and an infinite estimate just means no
saving was found. The pre/post-migration flush latency comparison
(post-swap p50 must not regress past the pre-swap p99) is armed only off
``backend=cpu`` and only when both phase histograms are non-empty — a
forced swap can land after the last flush, and a host-platform mesh's
latencies do not reflect the byte model the migration optimizes.

Fleet rule (the serve-SLO gate): a document whose base labels carry
``mode=fleet`` (``launch.serve --mode fleet``) must show every tenant
actually served — a non-empty per-tenant ``fleet/flush_s`` histogram and
a per-tenant ``batcher/served`` counter of at least the stamped
``requests`` label (the flush stream never drops a queued request). When
the ``fail_device`` label is set, the device loss must have been handled
mid-stream: ``fleet/device_losses`` >= 1, at least one ``fleet/redeal_s``
re-deal latency observation, and at least one tenant with post-loss
flushes (``fleet/flush_postloss_s``). The SLO-attainment latency check
(per-tenant p50 flush within the ``slo_ms`` budget) is armed only off
``backend=cpu`` — host-platform flush latencies are compile- and
dispatch-dominated, not the byte economics the SLO budget prices.

``spmvs_to_amortize=inf`` and friends are legitimate (a format that never
breaks even), so only the keys named above are validated.
"""
from __future__ import annotations

import json
import math
import re
import sys
from typing import Dict, Iterator, List, Optional, Tuple

# derived keys that must be finite and strictly positive
_POSITIVE_KEYS = ("gflops",)
# row-name prefixes whose us_per_call is analytic (no timing collected)
_ANALYTIC_PREFIXES = ("break_even.",)

# best chunked merge row may be at most 10% slower than the monolithic one
CHUNK_REGRESSION_TOLERANCE = 1.10

# best model-sharded (Pm > 1) mesh row may be at most 10% slower than the
# pure-data (Pm = 1) row of the same device total, where the model says the
# model axis pays
MESH_REGRESSION_TOLERANCE = 1.10

# a cx=on (sparsity-aware X gather) row may be at most 10% slower than its
# cx=off twin, where the model says the gather pays
COMPACT_REGRESSION_TOLERANCE = 1.10

# the best hidden-gather (gx=overlap|fused) row may be at most 10% slower
# than its up-front twin, where the exposed-gather model says hiding pays
GATHER_REGRESSION_TOLERANCE = 1.10

# observed/modeled residuals outside [1/10, 10] flag the model as broken —
# on backends where the model claims to apply (never on cpu, where the
# traffic model prices memory systems the host platform does not have)
RESIDUAL_MAX_OFF = 10.0

# an op=T row may be at most this factor slower than the op-aware model's
# predicted N-to-T slowdown applied to its op=N twin (scatter fixups are
# noisier than the streaming forward rows, so the slack is wider than the
# 10% same-shape gates)
TRANSPOSE_REGRESSION_TOLERANCE = 1.25

_CHUNK_ROW_RE = re.compile(
    r"^(?P<base>.*sellcs\+merge@\d+dev)/chunks=(?P<c>\d+)"
    r"(?P<cx>/cx=(?:on|off))?(?P<gx>/gx=(?:upfront|overlap|fused))?"
    r"(?P<op>/op=[NT])?/k=(?P<k>\d+)$")

_MESH_ROW_RE = re.compile(
    r"^(?P<base>.*sellcs\+(?:row|merge))@(?P<pd>\d+)x(?P<pm>\d+)mesh"
    r"(?P<chunks>/chunks=\d+)?(?P<cx>/cx=(?:on|off))?"
    r"(?P<gx>/gx=(?:upfront|overlap|fused))?"
    r"(?P<op>/op=[NT])?/k=(?P<k>\d+)$")

_COMPACT_ROW_RE = re.compile(
    r"^(?P<base>.*sellcs\+(?:row|merge)@(?:\d+dev|\d+x\d+mesh)"
    r"(?:/chunks=\d+)?)/cx=(?P<cx>on|off)"
    r"(?P<gx>/gx=(?:upfront|overlap|fused))?"
    r"(?P<op>/op=[NT])?/k=(?P<k>\d+)$")

_TRANSPOSE_ROW_RE = re.compile(
    r"^(?P<base>.*sellcs\+(?:row|merge)@(?:\d+dev|\d+x\d+mesh)"
    r"(?:/chunks=\d+)?(?:/cx=(?:on|off))?"
    r"(?:/gx=(?:upfront|overlap|fused))?)/op=(?P<op>[NT])/k=(?P<k>\d+)$")

_GATHER_ROW_RE = re.compile(
    r"^(?P<base>.*sellcs\+(?:row|merge)@(?:\d+dev|\d+x\d+mesh)"
    r"(?:/chunks=\d+)?/cx=on)(?P<gx>/gx=(?:upfront|overlap|fused))?"
    r"(?P<op>/op=[NT])?/k=(?P<k>\d+)$")


def _derived_fields(derived: str) -> Iterator[Tuple[str, str]]:
    for part in derived.split(";"):
        if "=" in part:
            key, val = part.split("=", 1)
            yield key.strip(), val.strip()


def _derived_float(rec: dict, want: str) -> Optional[float]:
    for key, val in _derived_fields(str(rec.get("derived", ""))):
        if key == want:
            try:
                v = float(val)
            except ValueError:
                return None
            return v if math.isfinite(v) else None
    return None


def _model_us(rec: dict) -> Optional[float]:
    return _derived_float(rec, "model_us")


def _exposed_gather_us(rec: dict) -> Optional[float]:
    return _derived_float(rec, "exposed_gather_us")


def _backend(rec: dict) -> Optional[str]:
    for key, val in _derived_fields(str(rec.get("derived", ""))):
        if key == "backend":
            return val
    # harness.Csv stamps the backend as a top-level record key; the
    # derived field (older spmm_sweep rows) stays authoritative when both
    # are present since it names the backend the row actually timed
    b = rec.get("backend")
    return str(b) if b is not None else None


def _check_residual_value(v: float, backend: Optional[str], where: str
                          ) -> List[str]:
    """Shared residual validation: finite and > 0 everywhere; the 10x
    model-off flag only where the model claims to apply (not cpu)."""
    if not math.isfinite(v) or v <= 0:
        return [f"{where}: residual={v} must be finite and > 0"]
    if backend not in (None, "cpu") and \
            not (1.0 / RESIDUAL_MAX_OFF <= v <= RESIDUAL_MAX_OFF):
        return [f"{where}: residual={v:.4g} — model off by more than "
                f"{RESIDUAL_MAX_OFF:g}x on backend={backend} where it "
                "claims to apply"]
    return []


def check_residuals(records: List[dict], origin: str) -> List[str]:
    """The model-honesty gate over ``residual=`` derived fields."""
    problems = []
    for rec in records:
        name = f"{origin}:{rec.get('section', '?')}/{rec.get('name', '?')}"
        for key, val in _derived_fields(str(rec.get("derived", ""))):
            if key != "residual":
                continue
            try:
                v = float(val)
            except ValueError:
                problems.append(f"{name}: residual={val!r} is not a "
                                "number")
                continue
            problems.extend(
                _check_residual_value(v, _backend(rec), name))
    return problems


def check_obs_document(doc: dict, origin: str) -> List[str]:
    """Validate one ``repro.obs/v1`` document (``launch.serve --metrics``
    / ``MetricRegistry.dump``): structural sanity for every series plus
    the residual gate over the ledger's records."""
    problems = []
    base_backend = doc.get("labels", {}).get("backend")
    for c in doc.get("counters", []):
        v = c.get("value")
        if not isinstance(v, (int, float)) or not math.isfinite(v) or v < 0:
            problems.append(f"{origin}:counter/{c.get('name', '?')}: "
                            f"value={v!r} must be finite and >= 0")
    for h in doc.get("histograms", []):
        name = f"{origin}:histogram/{h.get('name', '?')}"
        count = h.get("count")
        if not isinstance(count, int) or count < 0:
            problems.append(f"{name}: count={count!r} must be an int >= 0")
            continue
        if count == 0:
            continue
        for key in ("sum", "min", "max", "mean", "p50", "p95", "p99"):
            v = h.get(key)
            if not isinstance(v, (int, float)) or not math.isfinite(v):
                problems.append(f"{name}: {key}={v!r} is not finite")
        qs = [h.get(k) for k in ("p50", "p95", "p99")]
        if all(isinstance(q, (int, float)) and math.isfinite(q)
               for q in qs) and not (qs[0] <= qs[1] <= qs[2]):
            problems.append(f"{name}: quantiles out of order "
                            f"(p50={qs[0]!r}, p95={qs[1]!r}, "
                            f"p99={qs[2]!r})")
    for r in doc.get("residuals", []):
        name = f"{origin}:residual/{r.get('name', '?')}"
        backend = r.get("labels", {}).get("backend", base_backend)
        v = r.get("residual")
        if not isinstance(v, (int, float)):
            problems.append(f"{name}: residual={v!r} is not a number")
            continue
        problems.extend(_check_residual_value(float(v), backend, name))
    problems.extend(check_migration(doc, origin))
    problems.extend(check_slo(doc, origin))
    return problems


def check_slo(doc: dict, origin: str) -> List[str]:
    """The serve-SLO gate over a ``launch.serve --mode fleet`` run's
    document. Armed only when the base labels carry ``mode=fleet`` (any
    other document passes untouched)."""
    labels = doc.get("labels", {})
    if labels.get("mode") != "fleet":
        return []
    problems = []
    try:
        tenants = int(labels.get("tenants", ""))
    except (TypeError, ValueError):
        return [f"{origin}: mode=fleet but the tenants label is missing "
                "or not an int"]
    try:
        requests = float(labels.get("requests", "nan"))
    except (TypeError, ValueError):
        requests = math.nan

    def by_tenant(coll):
        # per-series lookup on (name, tenant label); fleet-wide series
        # carry no tenant key and land under (name, None)
        return {(s.get("name"), s.get("labels", {}).get("tenant")): s
                for s in doc.get(coll, [])}

    counters = by_tenant("counters")
    hists = by_tenant("histograms")
    try:
        slo_s = float(labels.get("slo_ms", "nan")) / 1e3
    except (TypeError, ValueError):
        slo_s = math.nan
    gate_latency = labels.get("backend") not in (None, "cpu")
    for i in range(tenants):
        t = f"t{i}"
        h = hists.get(("fleet/flush_s", t))
        if not (h and h.get("count")):
            problems.append(f"{origin}: tenant {t}: fleet/flush_s "
                            "histogram missing or empty — the tenant "
                            "never served a flush")
            continue
        served = counters.get(("batcher/served", t), {}).get("value")
        if not isinstance(served, (int, float)) or \
                not math.isfinite(served):
            problems.append(f"{origin}: tenant {t}: batcher/served "
                            "counter missing — served requests went "
                            "uncounted")
        elif math.isfinite(requests) and served < requests:
            problems.append(f"{origin}: tenant {t}: served={served:g} < "
                            f"requests={requests:g} — the flush stream "
                            "dropped queued requests")
        p50 = h.get("p50")
        if gate_latency and math.isfinite(slo_s) and \
                isinstance(p50, (int, float)) and math.isfinite(p50) and \
                p50 > slo_s:
            problems.append(f"{origin}: tenant {t}: p50 flush latency "
                            f"{p50:.4g}s exceeds the slo_ms budget "
                            f"({slo_s:.4g}s) on backend="
                            f"{labels.get('backend')}")
    fail = labels.get("fail_device", "")
    if fail not in ("", "none", "None", None):
        losses = counters.get(("fleet/device_losses", None),
                              {}).get("value")
        if not (isinstance(losses, (int, float)) and losses >= 1):
            problems.append(f"{origin}: fail_device={fail} but "
                            f"fleet/device_losses={losses!r} — the "
                            "injected loss was never handled")
        redeals = sum(int(h.get("count") or 0)
                      for (name, _), h in hists.items()
                      if name == "fleet/redeal_s")
        if redeals < 1:
            problems.append(f"{origin}: fail_device={fail} but no "
                            "fleet/redeal_s observation — no plan was "
                            "re-dealt across the survivors")
        post = any(h.get("count") for (name, _), h in hists.items()
                   if name == "fleet/flush_postloss_s")
        if not post:
            problems.append(f"{origin}: fail_device={fail} but every "
                            "fleet/flush_postloss_s histogram is empty — "
                            "nothing was served after the loss")
    return problems


def check_migration(doc: dict, origin: str) -> List[str]:
    """The online break-even gate over a ``launch.serve --migrate`` run's
    document. Armed only when the base labels carry ``migrate=auto`` or
    ``migrate=force`` (any other document passes untouched)."""
    labels = doc.get("labels", {})
    mode = labels.get("migrate")
    if mode not in ("auto", "force"):
        return []
    problems = []
    counters = {c.get("name"): c.get("value")
                for c in doc.get("counters", [])}
    gauges = {g.get("name"): g.get("value") for g in doc.get("gauges", [])}
    hists = {h.get("name"): h for h in doc.get("histograms", [])}

    def num(v):
        return v if isinstance(v, (int, float)) else math.nan

    mult = num(counters.get("serve/multiplies_total", math.nan))
    try:
        requests = float(labels.get("requests", "nan"))
    except (TypeError, ValueError):
        requests = math.nan
    if not math.isfinite(mult):
        problems.append(f"{origin}: migrate={mode} but "
                        "serve/multiplies_total is missing — the "
                        "controller never counted the traffic")
    elif math.isfinite(requests) and mult < requests:
        problems.append(f"{origin}: serve/multiplies_total={mult:g} < "
                        f"requests={requests:g} — served columns went "
                        "uncounted")
    be = gauges.get("serve/breakeven_estimate")
    if be is None:
        problems.append(f"{origin}: migrate={mode} but "
                        "serve/breakeven_estimate gauge is missing")
    swaps = num(counters.get("serve/plan_swaps", 0.0))
    if mode == "force":
        # a forced run must have landed the swap and measured both sides
        # of the break-even; auto mode may honestly never convert
        if not (swaps >= 1):
            problems.append(f"{origin}: migrate=force but "
                            f"serve/plan_swaps={swaps:g} — the forced "
                            "migration never landed")
        if not (num(gauges.get("serve/swap_unix_s", math.nan)) > 0):
            problems.append(f"{origin}: migrate=force but "
                            "serve/swap_unix_s is missing or not > 0")
        conv = num(gauges.get("serve/convert_s", math.nan))
        if not (math.isfinite(conv) and conv > 0):
            problems.append(f"{origin}: migrate=force but "
                            f"serve/convert_s={conv!r} is not a finite "
                            "positive measured build time")
        if be is not None and not (math.isfinite(num(be)) and num(be) > 0):
            problems.append(f"{origin}: migrate=force but "
                            f"serve/breakeven_estimate={be!r} is not "
                            "finite and > 0 after a measured conversion")
    # latency sanity across the swap: only where per-device memory makes
    # the comparison physical, and only when the swap landed mid-traffic
    # (a force swap can land after the last flush -> empty post hist)
    pre = hists.get("serve/flush_premigrate_s")
    post = hists.get("serve/flush_postmigrate_s")
    if labels.get("backend") not in (None, "cpu") and swaps >= 1 and \
            pre and post and pre.get("count") and post.get("count"):
        p99_pre, p50_post = num(pre.get("p99")), num(post.get("p50"))
        if math.isfinite(p99_pre) and math.isfinite(p50_post) and \
                p50_post > p99_pre:
            problems.append(
                f"{origin}: post-migration p50 flush latency "
                f"({p50_post:.4g}s) exceeds the pre-migration p99 "
                f"({p99_pre:.4g}s) — the conversion the controller chose "
                "made serving slower")
    return problems


def check_chunk_regressions(records: List[dict], origin: str) -> List[str]:
    """The overlap gate: per (merge-row base, k) group whose own roofline
    prediction says some pipelined depth beats the monolithic fixup, the
    fastest measured chunked row must stay within
    CHUNK_REGRESSION_TOLERANCE of the chunks=1 row."""
    groups: Dict[Tuple[str, str, str],
                 Dict[int, Tuple[float, Optional[float]]]] = {}
    for rec in records:
        m = _CHUNK_ROW_RE.match(str(rec.get("name", "")))
        us = rec.get("us_per_call")
        if not m or not isinstance(us, (int, float)) or not \
                math.isfinite(us) or us <= 0:
            continue
        # a cx=on row only compares against chunked cx=on rows (and off
        # against off, gx against the same gx, op=T against op=T) —
        # compaction changes the X bytes under the stream, the gather
        # schedule moves them, and the transpose changes the fixup
        # direction
        groups.setdefault((m["base"], m["cx"] or "", m["gx"] or "",
                           m["op"] or "", m["k"]),
                          {})[int(m["c"])] = (float(us), _model_us(rec))
    problems = []
    for (base, cx, gxseg, opseg, k), rows in sorted(groups.items()):
        mono = rows.get(1)
        chunked = {c: r for c, r in rows.items() if c > 1}
        if mono is None or not chunked:
            continue                    # nothing to compare against
        # arm the gate only where the model predicts overlap pays at THIS
        # size (otherwise a measured loss is the physics, not a bug)
        models = [r[1] for r in chunked.values()]
        if mono[1] is None or any(mu is None for mu in models) or \
                min(models) > mono[1]:
            continue
        best_c, (best_us, _) = min(chunked.items(), key=lambda t: t[1][0])
        if best_us > CHUNK_REGRESSION_TOLERANCE * mono[0]:
            problems.append(
                f"{origin}:{base}{cx}{gxseg}{opseg}/k={k}: "
                f"best chunked merge row "
                f"(chunks={best_c}, {best_us:.4g} us) regresses "
                f"{best_us / mono[0]:.2f}x over the monolithic chunks=1 "
                f"row ({mono[0]:.4g} us) although the model predicts "
                f"overlap pays here; tolerance is "
                f"{CHUNK_REGRESSION_TOLERANCE:.2f}x")
    return problems


def check_mesh_regressions(records: List[dict], origin: str) -> List[str]:
    """The 2-D mesh gate: per (row base, device total, chunks, k) group
    whose own traffic model says some model-sharded (Pm > 1) factorization
    is at least as fast as the pure-data (Pm = 1) one, the best measured
    model-sharded row must stay within MESH_REGRESSION_TOLERANCE of the
    pure-data row. Rows measured on a ``backend=cpu`` host-platform mesh
    are never gated — there the replicated X is one shared buffer, so the
    model-axis saving cannot show up in wall time."""
    groups: Dict[Tuple[str, int, str, str, str],
                 Dict[Tuple[int, int], Tuple[float, Optional[float]]]] = {}
    for rec in records:
        m = _MESH_ROW_RE.match(str(rec.get("name", "")))
        us = rec.get("us_per_call")
        if not m or not isinstance(us, (int, float)) or not \
                math.isfinite(us) or us <= 0:
            continue
        if _backend(rec) in (None, "cpu"):
            continue            # no per-device memory -> nothing to gate
        pd, pm = int(m["pd"]), int(m["pm"])
        key = (m["base"], pd * pm, m["chunks"] or "", m["cx"] or "",
               m["gx"] or "", m["op"] or "", m["k"])
        groups.setdefault(key, {})[(pd, pm)] = (float(us), _model_us(rec))
    problems = []
    for (base, total, chunks, cx, gxseg, opseg, k), rows in \
            sorted(groups.items()):
        pure = next((r for (pd, pm), r in rows.items() if pm == 1), None)
        sharded = {s: r for s, r in rows.items() if s[1] > 1}
        if pure is None or not sharded:
            continue                    # nothing to compare against
        # arm the gate only where the model predicts the model axis pays
        # at THIS size (otherwise a measured loss is physics, not a bug)
        models = [r[1] for r in sharded.values()]
        if pure[1] is None or any(mu is None for mu in models) or \
                min(models) > pure[1]:
            continue
        (bpd, bpm), (best_us, _) = min(sharded.items(),
                                       key=lambda t: t[1][0])
        if best_us > MESH_REGRESSION_TOLERANCE * pure[0]:
            problems.append(
                f"{origin}:{base}@{total}dev{chunks}{cx}{gxseg}{opseg}"
                f"/k={k}: best "
                f"model-sharded mesh row ({bpd}x{bpm}, {best_us:.4g} us) "
                f"regresses {best_us / pure[0]:.2f}x over the pure-data "
                f"row ({pure[0]:.4g} us) although the model predicts the "
                f"model axis pays here; tolerance is "
                f"{MESH_REGRESSION_TOLERANCE:.2f}x")
    return problems


def check_compact_regressions(records: List[dict], origin: str
                              ) -> List[str]:
    """The sparsity-aware-gather gate: per distributed row pair differing
    only in ``cx=on|off``, if the traffic model (priced with the measured
    mean ``n_touched``) says the compacted gather is STRICTLY faster than
    replication, the measured ``cx=on`` row must stay within
    COMPACT_REGRESSION_TOLERANCE of the ``cx=off`` row. A modelled tie
    never arms the gate (dense columns cap ``n_touched`` at ``n``, so the
    byte model sees a wash while the gather's overhead stays unpriced),
    and neither do ``backend=cpu`` rows — a host-platform mesh keeps X as
    one shared buffer, so the gather's byte saving cannot show up in wall
    time and a measured loss there is gather overhead on zero upside, not
    a bug."""
    groups: Dict[Tuple[str, str],
                 Dict[str, Tuple[float, Optional[float]]]] = {}
    for rec in records:
        m = _COMPACT_ROW_RE.match(str(rec.get("name", "")))
        us = rec.get("us_per_call")
        if not m or not isinstance(us, (int, float)) or not \
                math.isfinite(us) or us <= 0:
            continue
        if _backend(rec) in (None, "cpu"):
            continue            # shared X buffer -> nothing to gate
        # a gx=overlap|fused row pairs with nothing here: the replicated
        # baseline has no gather to schedule, so only the up-front
        # (unsuffixed) cx=on row gets an off twin — hidden-gather rows
        # land in gx-keyed groups that never complete and are skipped
        groups.setdefault((m["base"], m["gx"] or "", m["op"] or "",
                           m["k"]),
                          {})[m["cx"]] = (float(us), _model_us(rec))
    problems = []
    for (base, gxseg, opseg, k), rows in sorted(groups.items()):
        off, on = rows.get("off"), rows.get("on")
        if off is None or on is None:
            continue                    # nothing to compare against
        # arm the gate only where the model predicts the gather STRICTLY
        # pays at THIS size: near-dense columns cap n_touched at n and
        # make the modelled figures exactly equal (the wash), and the
        # gather's own overhead is below the model's resolution — a
        # measured loss on the tie is physics, not a regression (the
        # selector refuses compaction on the same tie)
        if off[1] is None or on[1] is None or on[1] >= off[1]:
            continue
        if on[0] > COMPACT_REGRESSION_TOLERANCE * off[0]:
            problems.append(
                f"{origin}:{base}{gxseg}{opseg}/k={k}: "
                f"compacted-gather row (cx=on, "
                f"{on[0]:.4g} us) regresses {on[0] / off[0]:.2f}x over "
                f"the replicated-X row ({off[0]:.4g} us) although the "
                f"model predicts the gather pays here; tolerance is "
                f"{COMPACT_REGRESSION_TOLERANCE:.2f}x")
    return problems


def check_gather_overlap(records: List[dict], origin: str) -> List[str]:
    """The gather-overlap gate: per compacted row group differing only in
    the ``gx=<mode>`` segment (``benchmarks.spmm_sweep --gather``), if the
    exposed-gather roofline term (the ``exposed_gather_us`` derived field)
    says some hidden-gather schedule STRICTLY shrinks the exposed gather
    time, the best measured hidden row must stay within
    GATHER_REGRESSION_TOLERANCE of the up-front baseline — hiding the
    gather may only move bytes off the critical path, never add wall
    time. A modelled tie never arms the gate (the row schedule and the
    single-chunk merge degenerate overlap back to up-front, so the term
    is identical and a measured loss there is double-buffer overhead on
    zero upside), and neither do ``backend=cpu`` rows — a host-platform
    mesh shares one X buffer, so the hidden bytes cannot show up in wall
    time."""
    groups: Dict[Tuple[str, str, str],
                 Dict[str, Tuple[float, Optional[float]]]] = {}
    for rec in records:
        m = _GATHER_ROW_RE.match(str(rec.get("name", "")))
        us = rec.get("us_per_call")
        if not m or not isinstance(us, (int, float)) or not \
                math.isfinite(us) or us <= 0:
            continue
        if _backend(rec) in (None, "cpu"):
            continue            # shared X buffer -> nothing to gate
        mode = m["gx"][len("/gx="):] if m["gx"] else "upfront"
        groups.setdefault((m["base"], m["op"] or "", m["k"]),
                          {})[mode] = (float(us), _exposed_gather_us(rec))
    problems = []
    for (base, opseg, k), rows in sorted(groups.items()):
        up = rows.get("upfront")
        hidden = {g: r for g, r in rows.items() if g != "upfront"}
        if up is None or not hidden:
            continue                    # nothing to compare against
        # arm the gate only where the model predicts hiding STRICTLY
        # pays at THIS size (the degenerate schedules price identically
        # and a measured loss there is physics, not a regression)
        exposed = [r[1] for r in hidden.values()]
        if up[1] is None or any(e is None for e in exposed) or \
                min(exposed) >= up[1]:
            continue
        best_g, (best_us, _) = min(hidden.items(), key=lambda t: t[1][0])
        if best_us > GATHER_REGRESSION_TOLERANCE * up[0]:
            problems.append(
                f"{origin}:{base}{opseg}/k={k}: best hidden-gather row "
                f"(gx={best_g}, {best_us:.4g} us) regresses "
                f"{best_us / up[0]:.2f}x over the up-front gather row "
                f"({up[0]:.4g} us) although the model predicts hiding "
                f"pays here; tolerance is "
                f"{GATHER_REGRESSION_TOLERANCE:.2f}x")
    return problems


def check_transpose_regressions(records: List[dict], origin: str
                                ) -> List[str]:
    """The op-aware gate: per distributed row pair differing only in
    ``op=N|T`` (``benchmarks.spmm_sweep --op N,T``), the measured op=T
    row must stay within TRANSPOSE_REGRESSION_TOLERANCE of the op-aware
    model's predicted N-to-T slowdown applied to the measured op=N row —
    the scatter-accumulate transpose may cost what the extra traffic
    (dense slot-space X read, full-column partial, scatter psum) prices,
    but not more. ``backend=cpu`` rows are never gated: a host-platform
    mesh shares one buffer for everything, so the priced traffic deltas
    are physically unobservable there."""
    groups: Dict[Tuple[str, str],
                 Dict[str, Tuple[float, Optional[float]]]] = {}
    for rec in records:
        m = _TRANSPOSE_ROW_RE.match(str(rec.get("name", "")))
        us = rec.get("us_per_call")
        if not m or not isinstance(us, (int, float)) or not \
                math.isfinite(us) or us <= 0:
            continue
        if _backend(rec) in (None, "cpu"):
            continue            # no per-device memory -> nothing to gate
        groups.setdefault((m["base"], m["k"]), {})[m["op"]] = \
            (float(us), _model_us(rec))
    problems = []
    for (base, k), rows in sorted(groups.items()):
        fw, tr = rows.get("N"), rows.get("T")
        if fw is None or tr is None:
            continue                    # nothing to compare against
        if fw[1] is None or tr[1] is None or fw[1] <= 0:
            continue                    # no model prediction to arm on
        predicted = tr[1] / fw[1]       # the model's N-to-T slowdown
        allowed = TRANSPOSE_REGRESSION_TOLERANCE * predicted * fw[0]
        if tr[0] > allowed:
            problems.append(
                f"{origin}:{base}/k={k}: transpose row (op=T, "
                f"{tr[0]:.4g} us) runs {tr[0] / fw[0]:.2f}x the op=N row "
                f"({fw[0]:.4g} us) where the model predicts only "
                f"{predicted:.2f}x; tolerance is "
                f"{TRANSPOSE_REGRESSION_TOLERANCE:.2f}x the prediction")
    return problems


def check_records(records: List[dict], origin: str) -> List[str]:
    """Return a list of human-readable violations (empty == clean)."""
    problems = []
    if not records:
        problems.append(f"{origin}: no records — benchmark emitted nothing")
    for rec in records:
        name = f"{origin}:{rec.get('section', '?')}/{rec.get('name', '?')}"
        us = rec.get("us_per_call")
        if not isinstance(us, (int, float)) or not math.isfinite(us):
            problems.append(f"{name}: us_per_call={us!r} is not finite")
        elif us < 0:
            problems.append(f"{name}: us_per_call={us} is negative")
        elif us == 0 and not str(rec.get("name", "")).startswith(
                _ANALYTIC_PREFIXES):
            problems.append(f"{name}: us_per_call is 0 for a timed row")
        for key, val in _derived_fields(str(rec.get("derived", ""))):
            if key not in _POSITIVE_KEYS:
                continue
            try:
                v = float(val)
            except ValueError:
                problems.append(f"{name}: {key}={val!r} is not a number")
                continue
            if not math.isfinite(v) or v <= 0:
                problems.append(f"{name}: {key}={val} must be finite and "
                                "> 0")
    problems.extend(check_chunk_regressions(records, origin))
    problems.extend(check_mesh_regressions(records, origin))
    problems.extend(check_compact_regressions(records, origin))
    problems.extend(check_gather_overlap(records, origin))
    problems.extend(check_transpose_regressions(records, origin))
    problems.extend(check_residuals(records, origin))
    return problems


def main(argv=None) -> int:
    paths = list(argv if argv is not None else sys.argv[1:])
    if not paths:
        print("usage: python -m benchmarks.smoke_check BENCH_*.json",
              file=sys.stderr)
        return 2
    problems: List[str] = []
    total = 0
    for path in paths:
        try:
            with open(path) as f:
                records = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            problems.append(f"{path}: unreadable ({e})")
            continue
        if isinstance(records, dict) and \
                records.get("schema") == "repro.obs/v1":
            # a serve --metrics dump, not a harness record list
            total += (len(records.get("counters", []))
                      + len(records.get("gauges", []))
                      + len(records.get("histograms", []))
                      + len(records.get("residuals", [])))
            problems.extend(check_obs_document(records, path))
            continue
        total += len(records)
        problems.extend(check_records(records, path))
    if problems:
        print(f"smoke_check: {len(problems)} problem(s) in {len(paths)} "
              "file(s):", file=sys.stderr)
        for p in problems:
            print(f"  FAIL {p}", file=sys.stderr)
        return 1
    print(f"smoke_check: {total} records across {len(paths)} file(s) OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
