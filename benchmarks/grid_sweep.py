"""Fig 6.1 analogue: parallel speedup vs worker count.

On CPU the sweep axis is the merge-path span count P (the paper's thread
count): the same MergePlan machinery, jitted XLA, min-of-N timing. Also
sweeps tiles_per_step for the blocked kernel's roofline model (the TPU
grid-occupancy analogue of hyperthreading effects)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import coo_to_csr, spmv, to_coo
from repro.data import matrices
from repro.kernels import merge_plan
from repro.kernels.ref import merge_spmv_xla

from .harness import Csv, time_fn


def run(csv=None):
    csv = csv or Csv("Fig 6.1: speedup vs worker (span) count")
    coo = to_coo(*matrices.test_suite(0.12)["livejournal_like"].make())
    csr = coo_to_csr(coo)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        coo.shape[1]).astype(np.float32))
    xp = jnp.pad(x, (0, (-x.shape[0]) % 128))
    t_base = time_fn(lambda: spmv(csr, x, impl="ref"))
    csv.row("sweep.parcrs_baseline", t_base, "spans=1")
    for P in [4, 8, 16, 32, 64, 128, 256]:
        plan = merge_plan(csr, P)
        t = time_fn(lambda: merge_spmv_xla(
            plan.cols, plan.vals, plan.seg, plan.row_starts, xp,
            r_width=plan.r_width, m=csr.shape[0]))
        csv.row(f"sweep.merge.P{P}", t,
                f"spans={P};speedup_vs_parcrs={t_base / t:.3f};"
                f"span_nnz={plan.cols.shape[1]}")
