"""AdamW with global-norm clipping, as pure pytree functions.

Optimizer state mirrors the parameter sharding (the train loop places state
with the same PartitionSpecs as params + ZeRO extension over the data axis),
so m/v are automatically ZeRO-sharded on the production mesh."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


class Optimizer(NamedTuple):
    init: Callable
    update: Callable   # (grads, state, params) -> (params, state, metrics)


def adamw(lr_schedule: Callable[[jax.Array], jax.Array],
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: Optional[float] = 1.0
          ) -> Optimizer:
    def init(params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros, params))

    def update(grads, state: AdamWState, params):
        grad_norm = global_norm(grads)
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr = lr_schedule(step)
        b1c = 1 - b1 ** step.astype(jnp.float32)
        b2c = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / b1c
            vh = v / b2c
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay \
                * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
        new_params = jax.tree_util.tree_map(
            lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree_util.tree_map(
            lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree_util.tree_map(
            lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step, new_m, new_v), \
            {"lr": lr, "grad_norm": grad_norm}

    return Optimizer(init, update)
