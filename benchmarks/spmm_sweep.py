"""SpMM k-sweep: GFLOP/s and achieved arithmetic intensity vs the roofline
prediction, per format, for k in 1..256 (powers of two).

The point of the table: the matrix stream is paid once per multiply, so
intensity — and with it the attainable fraction of peak — must climb
monotonically with k until the ridge. ``ai`` uses each format's *actual*
``storage_bytes()`` (fill-in and padding included); ``ai_ideal`` is the
roofline model's ideal-CSR prediction from ``repro.roofline``.

  PYTHONPATH=src python -m benchmarks.spmm_sweep --scale 0.02 --json out.json

``--devices P`` additionally times the distributed SELL-C-σ schedules
(``repro.spmm.distributed``) on a P-device mesh per k; when jax has not
been imported yet the host-platform device count is forced automatically.
``--chunks 1,2,8`` sweeps the merge-psum pipelining depth too — one
``chunks=<c>`` row per count, so ``benchmarks.smoke_check`` can gate the
chunked rows against the monolithic (``chunks=1``) baseline.

Emits the same CSV columns and JSON schema as ``benchmarks.run``.
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np


def sweep_matrix(name: str, coo, ks, impl: str, reps: int, csv) -> None:
    import jax.numpy as jnp
    from repro.core import coo_to_csr
    from repro.kernels.tiling import coo_to_tiled
    from repro.roofline import (spmm_arithmetic_intensity,
                                spmm_roofline_gflops)
    from repro.spmm import coo_to_sellcs, spmm
    from . import harness

    m, n = coo.shape
    nnz = coo.nnz
    formats = {"csr": coo_to_csr(coo), "sellcs": coo_to_sellcs(coo)}
    try:
        formats["tiled_csb"] = coo_to_tiled(coo, "csb")
    except MemoryError:
        pass                       # too sparse for dense mini-tiles
    rng = np.random.default_rng(0)
    for fmt, mat in formats.items():
        for k in ks:
            X = jnp.asarray(rng.standard_normal((n, k)).astype(np.float32))
            sec = harness.time_fn(lambda: spmm(mat, X, impl=impl),
                                  reps=reps, warmup=1)
            flops = 2.0 * nnz * k
            gflops = flops / sec / 1e9
            ai = spmm_arithmetic_intensity(
                nnz, m, n, k, matrix_bytes=mat.storage_bytes())
            ai_ideal = spmm_arithmetic_intensity(nnz, m, n, k)
            roof = spmm_roofline_gflops(ai)
            csv.row(f"{name}/{fmt}/k={k}", sec,
                    f"gflops={gflops:.4g};ai={ai:.4f};"
                    f"ai_ideal={ai_ideal:.4f};roof_gflops={roof:.1f}")


def sweep_distributed(name: str, coo, ks, devices: int, reps: int,
                      csv, chunk_counts=(1,)) -> None:
    """Distributed schedules on a `devices`-wide mesh (ref impl bodies —
    the host-platform mesh has no TPU cores to feed the Pallas path).

    The merge schedule is swept once per entry of ``chunk_counts`` (the
    psum pipelining depth) so the BENCH trajectory records chunked rows
    next to the monolithic (``chunks=1``) one; the row schedule has no
    collective to chunk and appears once.
    """
    import jax
    import jax.numpy as jnp
    from repro.launch.mesh import make_mesh
    from repro.roofline import spmm_distributed_time, spmm_distributed_traffic
    from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                            partition_sellcs_rows, spmm_merge_distributed,
                            spmm_row_distributed)
    from . import harness

    m, n = coo.shape
    nnz = coo.nnz
    max_row = int(np.bincount(np.asarray(coo.rows), minlength=m).max()) \
        if nnz else 0
    mesh = make_mesh((devices,), ("data",))
    sc = coo_to_sellcs(coo)
    row_sharded = partition_sellcs_rows(sc, devices)
    # one shared merge partition for every depth: the span re-deal happens
    # at trace time inside the jitted closure, so no per-depth copies of
    # the base device-dealt arrays are kept alive for the whole sweep
    mrg_sharded = partition_sellcs_nnz(sc, devices)
    variants = [("row", None,
                 jax.jit(lambda X: spmm_row_distributed(
                     row_sharded, X, mesh)))]
    for c in chunk_counts:
        variants.append(("merge", int(c),
                         jax.jit(lambda X, c=int(c): spmm_merge_distributed(
                             mrg_sharded, X, mesh, num_chunks=c))))
    rng = np.random.default_rng(1)
    for sched, nc, jitted in variants:
        tag = f"{name}/sellcs+{sched}@{devices}dev" + \
            (f"/chunks={nc}" if nc is not None else "")
        for k in ks:
            X = jnp.asarray(rng.standard_normal((n, k)).astype(np.float32))
            sec = harness.time_fn(lambda: jitted(X), reps=reps, warmup=1)
            gflops = 2.0 * nnz * k / sec / 1e9
            hbm, coll = spmm_distributed_traffic(
                m, n, k, devices, sched, nnz=nnz, max_row_nnz=max_row)
            model_s = spmm_distributed_time(
                m, n, k, devices, sched, nnz=nnz, max_row_nnz=max_row,
                num_chunks=nc or 1)
            csv.row(f"{tag}/k={k}", sec,
                    f"gflops={gflops:.4g};hbm_mb={hbm / 1e6:.4g};"
                    f"coll_mb={coll / 1e6:.4g};model_us={model_s * 1e6:.4g}")


def run(suite_scale: float = 0.02, kmax: int = 256, impl: str = "ref",
        reps: int = 3, matrices_only=None, devices: int = 1,
        chunk_counts=(1,)) -> None:
    from repro.data import matrices
    from . import harness

    ks = []
    k = 1
    while k <= kmax:
        ks.append(k)
        k *= 2
    suite = matrices.test_suite(scale=suite_scale)
    names = matrices_only or ["hhh_like", "livejournal_like", "mawi_like"]
    title = f"SpMM k-sweep (impl={impl}, k in {ks}" + \
        (f", devices={devices}, chunks={list(chunk_counts)})"
         if devices > 1 else ")")
    csv = harness.Csv(title)
    for name in names:
        if name not in suite:
            raise SystemExit(f"unknown matrix {name}; one of {sorted(suite)}")
        coo = matrices.as_coo(suite[name].make())
        sweep_matrix(name, coo, ks, impl, reps, csv)
        if devices > 1:
            sweep_distributed(name, coo, ks, devices, reps, csv,
                              chunk_counts=chunk_counts)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--kmax", type=int, default=256)
    ap.add_argument("--impl", default="ref",
                    choices=("auto", "ref", "pallas", "pallas_interpret"))
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--matrices", default=None,
                    help="comma-separated subset of the matrix suite")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump all rows as JSON (harness schema)")
    ap.add_argument("--devices", type=int, default=1,
                    help="also sweep the distributed schedules over a mesh "
                         "of this many devices")
    ap.add_argument("--chunks", default="1",
                    help="comma-separated merge-psum pipelining depths to "
                         "sweep (with --devices); each count emits its own "
                         "chunks=<c> rows next to the monolithic chunks=1")
    args = ap.parse_args(argv)
    try:
        chunk_counts = tuple(int(c) for c in args.chunks.split(",") if c)
    except ValueError:
        raise SystemExit(f"--chunks must be comma-separated ints, got "
                         f"{args.chunks!r}")
    if not chunk_counts or any(c < 1 for c in chunk_counts):
        raise SystemExit(f"--chunks entries must be >= 1, got {args.chunks!r}")

    if args.devices > 1 and "jax" not in sys.modules:
        # must happen before the first jax import anywhere in the process
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))
    if args.devices > 1:
        import jax
        if len(jax.devices()) < args.devices:
            raise SystemExit(
                f"--devices {args.devices} but jax sees "
                f"{len(jax.devices())}; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={args.devices} "
                "before any jax import")

    from . import harness
    harness.reset_records()
    run(suite_scale=args.scale, kmax=args.kmax, impl=args.impl,
        reps=args.reps,
        matrices_only=args.matrices.split(",") if args.matrices else None,
        devices=args.devices, chunk_counts=chunk_counts)
    if args.json:
        harness.dump_json(args.json)


if __name__ == "__main__":
    main()
