"""Process-local metric registry: counters, gauges, streaming histograms.

The registry is the single sink every instrumented layer writes to —
``obs.trace.span`` phase timings, ``RequestBatcher`` serve telemetry, the
``ResidualLedger`` observed-vs-modeled pairs — and ``dump()`` serializes
all of it as one JSON document (schema ``repro.obs/v1``) so benchmark
gates (``benchmarks.smoke_check``) and humans read the same artifact.

Quantiles come from a bounded reservoir (Vitter's algorithm R with a
deterministic per-series RNG): with ``n <= capacity`` samples the
reservoir IS the full stream, so p50/p95/p99 are *exact* on small N;
past the capacity memory stays bounded and the quantiles are unbiased
estimates. Exactness-on-small-N matters because serve flushes number in
the tens — the SLO percentiles the serve path prints must be real order
statistics, not model output.

Everything here is pure stdlib — importable (and ``install``-able) before
jax, numpy, or any accelerator runtime exists in the process.

Zero-overhead default: nothing in this module runs unless a registry is
``install()``-ed; instrumented call sites guard on ``enabled()`` /
``current_registry()`` and the disabled path allocates nothing (see
``obs.trace.span`` and the micro-benchmark in ``tests/test_obs.py``).
"""
from __future__ import annotations

import json
import random
import threading
import zlib
from typing import Dict, List, Mapping, Optional, Tuple

# series label values are stringified at record time so a dumped document
# round-trips through JSON without surprises
Labels = Mapping[str, object]

_LOCK = threading.Lock()
_REGISTRY: Optional["MetricRegistry"] = None


def install(registry: "MetricRegistry") -> "MetricRegistry":
    """Make ``registry`` the process-wide sink every instrumented call
    site records into. Returns it (handy for one-liners)."""
    global _REGISTRY
    with _LOCK:
        _REGISTRY = registry
    return registry


def uninstall() -> None:
    """Disable all instrumentation (the default state)."""
    global _REGISTRY
    with _LOCK:
        _REGISTRY = None


def current_registry() -> Optional["MetricRegistry"]:
    return _REGISTRY


def enabled() -> bool:
    """True iff a registry is installed. Hot paths branch on this before
    doing ANY metrics work, so the disabled default costs one global
    load per call site."""
    return _REGISTRY is not None


def _labels_key(labels: Optional[Labels]) -> Tuple[Tuple[str, str], ...]:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonic event count (flushes served, requests queued, ...)."""
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter increment must be >= 0, got {v}")
        self.value += v


class Gauge:
    """Last-write-wins instantaneous value (queue depth, batch k, ...)."""
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming distribution with bounded memory and exact small-N
    quantiles.

    Reservoir sampling (algorithm R) keeps every sample while
    ``count <= capacity`` — quantiles over that prefix are exact order
    statistics — and an unbiased uniform subsample beyond it. The RNG is
    seeded from the series name so repeated runs of a deterministic
    workload dump identical documents.
    """
    __slots__ = ("name", "labels", "capacity", "count", "total",
                 "min", "max", "_reservoir", "_rng")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...],
                 capacity: int = 1024):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.name = name
        self.labels = labels
        self.capacity = capacity
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._reservoir: List[float] = []
        # hash() is salted per process; crc32 keeps the seed stable
        self._rng = random.Random(zlib.crc32(name.encode()))

    def observe(self, v: float) -> None:
        v = float(v)
        i = self.count
        self.count = i + 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self._reservoir) < self.capacity:
            self._reservoir.append(v)
        else:
            j = self._rng.randrange(i + 1)
            if j < self.capacity:
                self._reservoir[j] = v

    @property
    def exact(self) -> bool:
        """True while the reservoir still holds the complete stream."""
        return self.count <= self.capacity

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def quantile(self, q: float) -> Optional[float]:
        """Linear-interpolated quantile (numpy's default definition) over
        the reservoir; exact while ``count <= capacity``. None when the
        series is empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        s = sorted(self._reservoir)
        n = len(s)
        if n == 0:
            return None
        if n == 1:
            return s[0]
        h = (n - 1) * q
        lo = int(h)
        if lo + 1 >= n:
            return s[-1]
        frac = h - lo
        return s[lo] + frac * (s[lo + 1] - s[lo])

    def percentiles(self, ps=(50, 95, 99)) -> Dict[str, Optional[float]]:
        return {f"p{p:g}": self.quantile(p / 100.0) for p in ps}


class MetricRegistry:
    """Process-local series store. ``base_labels`` (backend, mesh, format,
    ...) stamp every series so one dumped document from a matrixed CI job
    stays attributable.

    >>> reg = install(MetricRegistry(backend="cpu"))
    >>> reg.counter("serve/flushes").inc()
    >>> reg.histogram("serve/flush_s").observe(1e-3)
    >>> reg.dump("metrics.json")
    """

    SCHEMA = "repro.obs/v1"

    def __init__(self, histogram_capacity: int = 1024, **base_labels):
        self.base_labels = {str(k): str(v) for k, v in base_labels.items()}
        self.histogram_capacity = histogram_capacity
        self._lock = threading.Lock()
        self._counters: Dict[Tuple, Counter] = {}
        self._gauges: Dict[Tuple, Gauge] = {}
        self._histograms: Dict[Tuple, Histogram] = {}
        self._ledger = None     # lazy: obs.residuals.ResidualLedger

    def _series(self, store, cls, name: str, labels: Optional[Labels],
                **kw):
        key = (name, _labels_key(labels))
        series = store.get(key)
        if series is None:
            with self._lock:
                series = store.get(key)
                if series is None:
                    series = store[key] = cls(name, key[1], **kw)
        return series

    def counter(self, name: str, labels: Optional[Labels] = None
                ) -> Counter:
        return self._series(self._counters, Counter, name, labels)

    def gauge(self, name: str, labels: Optional[Labels] = None) -> Gauge:
        return self._series(self._gauges, Gauge, name, labels)

    def histogram(self, name: str, labels: Optional[Labels] = None
                  ) -> Histogram:
        return self._series(self._histograms, Histogram, name, labels,
                            capacity=self.histogram_capacity)

    @property
    def ledger(self):
        """The registry's ``ResidualLedger`` (created on first use) —
        dumped under the ``"residuals"`` key next to the metric series."""
        if self._ledger is None:
            from .residuals import ResidualLedger
            with self._lock:
                if self._ledger is None:
                    self._ledger = ResidualLedger()
        return self._ledger

    def histograms(self) -> List[Histogram]:
        return list(self._histograms.values())

    def as_dict(self) -> dict:
        """The ``repro.obs/v1`` document: every series with merged
        labels, quantile summaries per histogram, and the residual
        ledger's records."""
        def with_labels(series):
            return dict(self.base_labels, **dict(series.labels))

        doc = {
            "schema": self.SCHEMA,
            "labels": dict(self.base_labels),
            "counters": [
                {"name": c.name, "labels": with_labels(c),
                 "value": c.value}
                for c in self._counters.values()],
            "gauges": [
                {"name": g.name, "labels": with_labels(g),
                 "value": g.value}
                for g in self._gauges.values()],
            "histograms": [
                {"name": h.name, "labels": with_labels(h),
                 "count": h.count, "sum": h.total,
                 "min": None if h.count == 0 else h.min,
                 "max": None if h.count == 0 else h.max,
                 "mean": h.mean, "exact": h.exact,
                 **h.percentiles()}
                for h in self._histograms.values()],
            "residuals": ([] if self._ledger is None
                          else self._ledger.as_dicts()),
        }
        return doc

    def dump(self, path: str) -> dict:
        """Serialize the whole registry to ``path`` and return the
        document."""
        doc = self.as_dict()
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return doc
