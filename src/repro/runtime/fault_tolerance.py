"""Fault tolerance + straggler instrumentation for the train loop.

``Supervisor`` wraps a step function with: periodic async checkpointing,
crash recovery (restore latest committed checkpoint, replay the step-keyed
data pipeline), heartbeat files (what a cluster manager would watch), and an
EMA step-time straggler detector.

On a real multi-host deployment the restart path is process-level (the
launcher re-execs and ``--resume auto`` picks up the latest commit); here the
same logic is exercised in-process by injecting failures
(tests/test_fault_tolerance.py), which proves the resume math is bit-exact.
Straggler *mitigation* at SpMV level is the paper's own contribution —
merge-path spans bound the slowest worker's excess work by one block row —
and at train-step level gradient accumulation keeps collective sizes fixed.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from repro.checkpoint import checkpoint as ckpt


@dataclass
class StragglerMonitor:
    """EMA-based step-time anomaly detector (the signal a 1000-node
    deployment uses to trigger hot-spare swaps)."""
    alpha: float = 0.1
    threshold: float = 2.0
    ema: Optional[float] = None
    slow_steps: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ema is not None and dt > self.threshold * self.ema
        self.ema = dt if self.ema is None else \
            (1 - self.alpha) * self.ema + self.alpha * dt
        if slow:
            self.slow_steps.append((step, dt, self.ema))
        return slow


@dataclass
class Supervisor:
    ckpt_dir: str
    save_every: int = 50
    keep: int = 3
    heartbeat_path: Optional[str] = None
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)
    _pending: Optional[Any] = None

    def resume_step(self) -> int:
        """Step to (re)start from. Checkpoints are labeled with the number
        of completed steps, so the label IS the next step index."""
        last = ckpt.latest_step(self.ckpt_dir)
        return 0 if last is None else last

    def restore(self, target_state):
        last = ckpt.latest_step(self.ckpt_dir)
        if last is None:
            return None, 0
        return ckpt.restore(self.ckpt_dir, last, target_state), last

    def heartbeat(self, step: int, metrics: Dict):
        if self.heartbeat_path:
            os.makedirs(os.path.dirname(self.heartbeat_path), exist_ok=True)
            tmp = self.heartbeat_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"step": step, "time": time.time(),
                           "metrics": {k: float(v) for k, v in
                                       metrics.items()}}, f)
            os.replace(tmp, self.heartbeat_path)

    def maybe_save(self, step: int, state, *, blocking: bool = False,
                   meta: Optional[Dict] = None):
        if step % self.save_every != 0:
            return
        if self._pending is not None:
            self._pending.join()          # backpressure: one in flight
        self._pending = ckpt.save(self.ckpt_dir, step, state,
                                  blocking=blocking, keep=self.keep,
                                  meta=meta or {})

    def finalize(self, step: int, state, meta: Optional[Dict] = None):
        if self._pending is not None:
            self._pending.join()
        ckpt.save(self.ckpt_dir, step, state, blocking=True,
                  keep=self.keep, meta=meta or {})

    def run(self, state, num_steps: int, step_fn: Callable,
            batch_fn: Callable, start_step: Optional[int] = None,
            fail_at: Optional[int] = None) -> Any:
        """Drive the loop; ``fail_at`` injects a crash (tests)."""
        step = self.resume_step() if start_step is None else start_step
        while step < num_steps:
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch_fn(step))
            dt = time.perf_counter() - t0
            self.monitor.observe(step, dt)
            self.heartbeat(step, metrics)
            step += 1
            self.maybe_save(step, state)
        self.finalize(step, state)
        return state
