"""Render the roofline table from the dry-run JSON records (§Roofline)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(results_dir: str = RESULTS) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table(recs: List[Dict], mesh: str = "16x16") -> str:
    hdr = (f"| arch | shape | compute s | memory s | collective s | "
           f"bottleneck | useful | roofline frac | HBM GiB/dev |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                         f"{r['error'][:60]} | | | | | | |")
            continue
        rf = r["roofline"]
        hbm = r.get("hbm_bytes_per_device", 0) / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"{rf['bottleneck']} | {rf['useful_flops_fraction']:.3f} | "
            f"{rf['roofline_fraction']:.4f} | {hbm:.2f} |")
    return "\n".join(lines)


def run(csv=None):
    from .harness import Csv
    csv = csv or Csv("Roofline terms per dry-run cell")
    for r in load():
        if "error" in r:
            csv.row(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}", 0.0,
                    "ERROR")
            continue
        rf = r["roofline"]
        csv.row(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}",
                rf["step_time_s"],
                f"bottleneck={rf['bottleneck']};"
                f"useful={rf['useful_flops_fraction']:.3f};"
                f"frac={rf['roofline_fraction']:.4f}")


if __name__ == "__main__":
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### mesh {mesh}\n")
        print(table(load(), mesh))
