"""repro.spmm — the multi-RHS SpMM engine (``Y = A @ X``, ``X: [n, k]``).

Layers (one module each):

  ``sellcs``     SELL-C-σ storage: lane-height slices, σ-window row sorting
  ``reference``  pure-jnp oracles per format (the XLA fallback path)
  ``kernels``    tiled Pallas kernels with a k-tile grid dimension
  ``batching``   request batching for the serve path (k SpMVs -> 1 SpMM)
  ``distributed``  shard_map schedules over a mesh (row bands / merge spans)
  ``operator``   SparseOperator: the stable partition-once/multiply-many
                 handle with an atomic plan swap (online format migration)
  ``fleet``      Fleet: multi-tenant operator registry — fingerprint-keyed
                 plan cache, device-loss re-deal onto the survivors

SpMV is the k = 1 special case throughout; ``repro.core.spmv`` remains the
single-vector entry point and routes SELL-C-σ matrices here.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.formats import COO, CSR, BlockedSparse
from . import reference
from .batching import (FleetBatcher, QueueFull, RequestBatcher,
                       SpmvRequest, batch_spmv)
from .distributed import (ShardedSellCS, partition_sellcs_nnz,
                          partition_sellcs_rows, rechunk_sellcs,
                          redeal_sellcs, spmm_merge_distributed,
                          spmm_row_distributed)
from .kernels import choose_k_tile, csr_spmm, sellcs_spmm, tiled_spmm
from .operator import (OperatorStats, RealizedPlan, SparseOperator,
                       coo_fingerprint)
from .fleet import Fleet, FleetStats
from .reference import (spmm_blocked, spmm_coo, spmm_csr, spmm_ref,
                        spmm_sellcs)
from .sellcs import SellCS, coo_to_sellcs


def spmm(mat, x: jax.Array, *, impl: str = "auto",
         k_tile: Optional[int] = None) -> jax.Array:
    """Multiply ``Y = A @ X`` for any supported format.

    impl in {"auto", "ref", "pallas", "pallas_interpret"} — same contract
    as ``core.spmv.spmv``: "auto" takes the Pallas path on TPU for formats
    with a kernel, the XLA reference otherwise.
    """
    from repro.kernels.tiling import TiledSparse
    if impl in ("pallas", "pallas_interpret"):
        interpret = impl == "pallas_interpret"
        x2 = x[:, None] if x.ndim == 1 else x
        if isinstance(mat, TiledSparse):
            y = tiled_spmm(mat, x2, k_tile=k_tile, interpret=interpret)
        elif isinstance(mat, CSR):
            y = csr_spmm(mat, x2, k_tile=k_tile, interpret=interpret)
        elif isinstance(mat, SellCS):
            y = sellcs_spmm(mat, x2, k_tile=k_tile, interpret=interpret)
        else:
            raise TypeError(
                f"no SpMM kernel for {type(mat).__name__}; convert with "
                "coo_to_sellcs / repro.kernels.coo_to_tiled / coo_to_csr")
        return y[:, 0] if x.ndim == 1 else y
    if impl == "auto":
        on_tpu = jax.default_backend() == "tpu"
        if on_tpu and isinstance(mat, (TiledSparse, CSR, SellCS)):
            return spmm(mat, x, impl="pallas", k_tile=k_tile)
    return spmm_ref(mat, x)


__all__ = [
    "SellCS", "coo_to_sellcs", "spmm", "choose_k_tile",
    "tiled_spmm", "csr_spmm", "sellcs_spmm",
    "spmm_ref", "spmm_coo", "spmm_csr", "spmm_blocked", "spmm_sellcs",
    "RequestBatcher", "FleetBatcher", "QueueFull", "SpmvRequest",
    "batch_spmv", "reference",
    "ShardedSellCS", "partition_sellcs_rows", "partition_sellcs_nnz",
    "rechunk_sellcs", "redeal_sellcs",
    "spmm_row_distributed", "spmm_merge_distributed",
    "SparseOperator", "RealizedPlan", "OperatorStats", "coo_fingerprint",
    "Fleet", "FleetStats",
    "COO", "CSR", "BlockedSparse",
]
