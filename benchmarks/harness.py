"""Benchmark harness: min-of-N timing (the paper times 550 executions and
reports the minimum, §5.2 — we use the same protocol with fewer reps on the
1-core container) + CSV emission, with an optional JSON sink shared by
every driver (``benchmarks.run --json``, ``benchmarks.spmm_sweep --json``).

JSON schema: a list of ``{"section": <table title>, "name": <row name>,
"us_per_call": <float>, "derived": <free-form string>}`` records — the same
columns the CSV prints."""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List

import jax

# module-level record sink shared by all Csv instances (reset per driver)
_RECORDS: List[Dict] = []


def reset_records() -> None:
    _RECORDS.clear()


def records() -> List[Dict]:
    return list(_RECORDS)


def dump_json(path: str) -> None:
    """Write every record emitted since reset_records() as JSON."""
    with open(path, "w") as f:
        json.dump(_RECORDS, f, indent=1)
    print(f"# wrote {len(_RECORDS)} records to {path}")


def time_fn(fn: Callable, *args, reps: int = 20, warmup: int = 3) -> float:
    """Min wall time in seconds of fn(*args) (jax outputs block)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def time_host(fn: Callable, *args, reps: int = 5) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


class Csv:
    def __init__(self, title: str):
        self.title = title
        self.rows: List[str] = []
        print(f"# === {title} ===")
        print("name,us_per_call,derived")

    def row(self, name: str, seconds: float, derived: str = ""):
        line = f"{name},{seconds * 1e6:.1f},{derived}"
        self.rows.append(line)
        _RECORDS.append({"section": self.title, "name": name,
                         "us_per_call": seconds * 1e6, "derived": derived})
        print(line)
