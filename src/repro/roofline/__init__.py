"""repro.roofline — roofline analysis from compiled dry-run artifacts."""
from . import analysis
from .analysis import (Roofline, collective_bytes_total, csr_stream_bytes,
                       from_compiled, parse_collective_bytes,
                       ridge_intensity, spmm_arithmetic_intensity,
                       spmm_distributed_collective_s,
                       spmm_distributed_gather_s, spmm_distributed_time,
                       spmm_distributed_traffic, spmm_roofline_gflops,
                       spmm_touched_fraction)

__all__ = ["analysis", "Roofline", "from_compiled",
           "parse_collective_bytes", "collective_bytes_total",
           "csr_stream_bytes", "ridge_intensity",
           "spmm_arithmetic_intensity", "spmm_roofline_gflops",
           "spmm_distributed_traffic", "spmm_distributed_time",
           "spmm_distributed_collective_s", "spmm_distributed_gather_s",
           "spmm_touched_fraction"]
