"""Pure-jnp oracles for every kernel in repro.kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .tiling import TILE_C, TILE_R, TiledSparse


@jax.jit
def bsr_spmv_ref(ts: TiledSparse, x: jax.Array) -> jax.Array:
    """Oracle for bsr_spmv: vmap the per-tile matvec, scatter-add slabs."""
    m, n = ts.shape
    mp, np_ = ts.padded_shape()
    x_pad = jnp.zeros((np_,), x.dtype).at[:n].set(x)
    xs = x_pad.reshape(np_ // TILE_C, TILE_C)[ts.tile_cols]   # (T, 128)
    contrib = jnp.einsum("trc,tc->tr", ts.tiles.astype(jnp.float32),
                         xs.astype(jnp.float32))              # (T, 8)
    y = jnp.zeros((mp // TILE_R, TILE_R), jnp.float32)
    y = y.at[ts.tile_rows].add(contrib)
    return y.reshape(mp)[:m]


@jax.jit
def merge_spmv_ref(csr, x: jax.Array) -> jax.Array:
    """Oracle for merge_spmv == plain CSR SpMV."""
    from repro.core.spmv import spmv_csr
    return spmv_csr(csr, x)


def moe_group_matmul_ref(tokens: jax.Array, weights: jax.Array,
                         group_sizes: jax.Array) -> jax.Array:
    """Oracle for the grouped GEMM: tokens [T, K] sorted by expert,
    group_sizes int32[E]; weights [E, K, N] -> out [T, N]."""
    T, K = tokens.shape
    E, _, N = weights.shape
    bounds = jnp.cumsum(group_sizes)
    expert_of_token = jnp.searchsorted(bounds,
                                       jnp.arange(T, dtype=group_sizes.dtype),
                                       side="right")
    w = weights[expert_of_token]                 # (T, K, N)
    return jnp.einsum("tk,tkn->tn", tokens.astype(jnp.float32),
                      w.astype(jnp.float32))


import functools


@functools.partial(jax.jit, static_argnames=("r_width", "m"))
def merge_spmv_xla(cols, vals, seg, row_starts, x_pad: jax.Array, *,
                   r_width: int, m: int) -> jax.Array:
    """XLA realization of the merge-path algorithm from the same MergePlan
    the Pallas kernel uses (vmap over spans + segment reduction + the
    sequential carry-out fixup). Used for wall-clock algorithm sweeps on
    CPU (Fig 6.1 analogue)."""
    xs = x_pad[cols]                               # [P, D] gather
    prod = vals.astype(jnp.float32) * xs.astype(jnp.float32)
    partials = jax.vmap(
        lambda pr, sg: jax.ops.segment_sum(pr, sg, num_segments=r_width)
    )(prod, seg)                                   # [P, R]
    idx = row_starts[:-1, None] + jnp.arange(r_width, dtype=jnp.int32)[None]
    y = jnp.zeros((m + r_width,), jnp.float32).at[idx].add(partials)
    return y[:m]


@jax.jit
def bsr_spmm_ref(ts: TiledSparse, x: jax.Array) -> jax.Array:
    """Oracle for bsr_spmm (multi-RHS)."""
    m, n = ts.shape
    mp, np_ = ts.padded_shape()
    R = x.shape[1]
    x_pad = jnp.zeros((np_, R), x.dtype).at[:n].set(x)
    xs = x_pad.reshape(np_ // TILE_C, TILE_C, R)[ts.tile_cols]  # (T,128,R)
    contrib = jnp.einsum("trc,tcf->trf", ts.tiles.astype(jnp.float32),
                         xs.astype(jnp.float32))                # (T,8,R)
    y = jnp.zeros((mp // TILE_R, TILE_R, R), jnp.float32)
    y = y.at[ts.tile_rows].add(contrib)
    return y.reshape(mp, R)[:m]
