"""Public jit'd wrappers around the Pallas kernels.

``interpret=True`` executes kernel bodies in Python on CPU (how this repo
validates them); on a TPU backend pass interpret=False for Mosaic lowering.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.formats import CSR
from . import merge_spmv as _merge
from . import moe_group_matmul as _moe
from .bsr_spmv import bsr_spmm as _bsr_spmm
from .bsr_spmv import bsr_spmv as _bsr_spmv
from .tiling import TiledSparse

M_TILE = _moe.M_TILE


def bsr_spmv(ts: TiledSparse, x: jax.Array, *, interpret: bool = False,
             tiles_per_step: int = 8) -> jax.Array:
    return _bsr_spmv(ts, x, tiles_per_step=tiles_per_step,
                     interpret=interpret)


def bsr_spmm(ts: TiledSparse, x: jax.Array, *, interpret: bool = False,
             tiles_per_step: int = 8) -> jax.Array:
    return _bsr_spmm(ts, x, tiles_per_step=tiles_per_step,
                     interpret=interpret)


def merge_spmv(csr: CSR, x: jax.Array, *, num_spans: Optional[int] = None,
               plan: Optional[_merge.MergePlan] = None,
               interpret: bool = False) -> jax.Array:
    """Merge-path SpMV. Build the plan once (convert time) and reuse it —
    that is the paper's conversion/multiplication split."""
    m, n = csr.shape
    if plan is None:
        if num_spans is None:
            num_spans = _merge.default_num_spans(m, csr.nnz)
        plan = _merge.merge_plan(csr, num_spans)
    np_ = -(-n // 128) * 128
    x_pad = jnp.zeros((np_,), x.dtype).at[:n].set(x)
    partials = _merge.merge_spmv_partials(
        plan.cols, plan.vals, plan.seg, x_pad, r_width=plan.r_width,
        interpret=interpret)                       # (P, R)
    return _merge.carry_out_fixup(partials, plan.row_starts, m)


def moe_group_matmul(tokens: jax.Array, weights: jax.Array,
                     group_sizes: jax.Array, *,
                     interpret: bool = False) -> jax.Array:
    """tokens f[T, K] sorted by expert; group_sizes int32[E]; weights
    [E, K, N] -> out f32[T, N].

    Handles group padding to M_TILE internally (static worst-case padded
    length T + E*M_TILE, zero-filled rows compute zeros)."""
    T, K = tokens.shape
    E, K2, N = weights.shape
    Kp = -(-K // _moe.K_TILE) * _moe.K_TILE
    Np = -(-N // _moe.N_TILE) * _moe.N_TILE
    if Kp != K:
        tokens = jnp.pad(tokens, ((0, 0), (0, Kp - K)))
        weights = jnp.pad(weights, ((0, 0), (0, Kp - K), (0, 0)))
    if Np != N:
        weights = jnp.pad(weights, ((0, 0), (0, 0), (0, Np - N)))
    T_pad = (-(-T // M_TILE) * M_TILE) + E * M_TILE

    sizes = group_sizes.astype(jnp.int32)
    ptr = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(sizes)])
    padded_sizes = -(-sizes // M_TILE) * M_TILE
    padded_ptr = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(padded_sizes)])

    tok_idx = jnp.arange(T, dtype=jnp.int32)
    expert_of_token = (jnp.searchsorted(ptr[1:], tok_idx, side="right")
                       ).astype(jnp.int32)
    pos = padded_ptr[expert_of_token] + (tok_idx - ptr[expert_of_token])
    lhs = jnp.zeros((T_pad, Kp), tokens.dtype).at[pos].set(tokens)

    tile_idx = jnp.arange(T_pad // M_TILE, dtype=jnp.int32)
    tile_expert = (jnp.searchsorted(padded_ptr[1:], tile_idx * M_TILE,
                                    side="right")).astype(jnp.int32)
    tile_expert = jnp.minimum(tile_expert, E - 1)

    out_pad = _moe.moe_group_matmul_padded(lhs, weights, tile_expert,
                                           interpret=interpret)
    return out_pad[pos, :N]
