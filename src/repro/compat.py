"""Version tolerance for the jax / Pallas API surface.

The repo targets whatever jax_pallas toolchain the container bakes in, and
that has straddled several renames:

  * ``jax.shard_map``            (new)  vs  ``jax.experimental.shard_map``
    — and the ``check_vma=`` kwarg (new) vs ``check_rep=`` (old);
  * ``jax.set_mesh``             (new)  vs  the ``with mesh:`` context;
  * ``pltpu.CompilerParams``     (new)  vs  ``pltpu.TPUCompilerParams``.

Everything else goes through these thin shims so a toolchain bump touches
one file.
"""
from __future__ import annotations

import jax


def shard_map(f=None, *, mesh=None, in_specs=None, out_specs=None,
              check_vma=None, **kw):
    """``jax.shard_map`` under either API generation.

    On old jax, a ``mesh=None`` call (new-style "use the ambient mesh")
    resolves the mesh from the ``with mesh:`` context that :func:`set_mesh`
    establishes there.
    """
    if hasattr(jax, "shard_map"):
        skw = dict(kw)
        if mesh is not None:
            skw["mesh"] = mesh
        if check_vma is not None:
            skw["check_vma"] = check_vma
        return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs,
                             **skw)
    from jax.experimental.shard_map import shard_map as _shard_map
    if mesh is None:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
        if mesh.empty:
            raise ValueError(
                "shard_map without mesh= needs an ambient mesh; wrap the "
                "call in `with repro.compat.set_mesh(mesh):`")
    skw = dict(kw)
    if check_vma is not None:
        skw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **skw)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh          # jax.sharding.Mesh is itself a context manager


def tpu_compiler_params(**kwargs):
    """Construct Pallas TPU compiler params under whichever name this jax
    release exports."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
