"""Selector decision procedure + analytic accounting sanity."""

from repro.core import (MachineSpec, MatrixStats, amortized_cost,
                        break_even_spmvs, matrix_stats, select_algorithm,
                        to_coo)
from repro.core.selector import ROW_SPLITTING
from repro.data import matrices


def _stats(m=100000, n=100000, nnz=300000, max_row=10, var=1.0):
    return MatrixStats(m, n, nnz, max_row, var)


def test_dense_row_forces_row_splitting():
    """The mawi rule (paper Table 6.3): only merge/CSB survive."""
    s = _stats(max_row=150000, nnz=300000)
    assert s.has_dense_row
    for numa in (1, 256):
        pick = select_algorithm(s, MachineSpec(num_devices=numa),
                                num_spmvs=5000)
        assert pick in ROW_SPLITTING


def test_selector_numa_prefers_bcoh_family_at_high_density():
    """Paper §7: NUMA + higher density + many SpMVs -> BCOHC(H)."""
    s = MatrixStats(3_000_000, 3_000_000, 80_000_000, 2000, 1e3)
    assert s.density > 1e-6
    pick = select_algorithm(s, MachineSpec(num_devices=256),
                            num_spmvs=100_000)
    assert pick in ("bcohc", "bcohch")


def test_selector_low_reuse_prefers_cheap_conversion():
    s = MatrixStats(3_000_000, 3_000_000, 80_000_000, 2000, 1e3)
    pick = select_algorithm(s, MachineSpec(num_devices=256), num_spmvs=1)
    # one multiplication can never amortize a Hilbert sort
    assert pick in ("parcrs", "merge", "mergeb")


def test_break_even_matches_paper_ballpark():
    n = break_even_spmvs("bcohc", numa_like=True, low_density=False)
    assert 200 < n < 800          # paper: 472 on Sapphire Rapids


def test_matrix_stats_on_real_matrix():
    coo = to_coo(*matrices.mawi_like(500, 500, 4000, 0.4, 0))
    s = matrix_stats(coo)
    assert s.has_dense_row
    coo2 = to_coo(*matrices.mesh2d(20))
    s2 = matrix_stats(coo2)
    assert not s2.has_dense_row and s2.max_row_nnz <= 5


def test_amortized_cost_monotone_in_reuse():
    c1 = amortized_cost("bcohch", 10, numa_like=True, low_density=False)
    c2 = amortized_cost("bcohch", 10_000, numa_like=True, low_density=False)
    assert c2 > c1


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------
def test_accounting_matches_instantiated_params():
    """Analytic count == actual leaf count for reduced configs."""
    import jax
    from repro.configs import get_config
    from repro.models.accounting import count_params
    from repro.models.model import init_params

    for arch in ["llama3.2-1b", "granite-moe-1b-a400m", "mamba2-1.3b",
                 "jamba-1.5-large-398b", "musicgen-large"]:
        cfg = get_config(arch, reduced=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
        analytic = count_params(cfg)
        assert abs(actual - analytic) / actual < 0.02, \
            f"{arch}: analytic {analytic} vs actual {actual}"


def test_decode_flops_scale_with_kv():
    from repro.configs import get_config
    from repro.models.accounting import decode_model_flops
    cfg = get_config("llama3.2-1b")
    f1 = decode_model_flops(cfg, batch=1, kv_len=1024)
    f2 = decode_model_flops(cfg, batch=1, kv_len=32768)
    assert f2 > f1
    # SWA bounds the attention term
    cfgw = get_config("mixtral-8x22b")
    f3 = decode_model_flops(cfgw, batch=1, kv_len=32768)
    f4 = decode_model_flops(cfgw, batch=1, kv_len=524288)
    att3 = f3 - 2 * 1 * __import__(
        "repro.models.accounting", fromlist=["count_params"]
    ).count_params(cfgw, active_only=True)
    assert (f4 - f3) / max(f3, 1) < 0.01   # window-capped
