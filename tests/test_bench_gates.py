"""benchmarks.smoke_check — the CI gates over BENCH_*.json emissions,
including the chunked-psum overlap gate added with the pipelined merge
schedule: where the sweep's own roofline prediction (model_us) says a
pipelined depth beats the monolithic fixup, the best measured chunked row
must not regress >10% vs the chunks=1 row; where the model predicts
chunking loses (launch-dominated smoke sizes), nothing is gated."""
import benchmarks.smoke_check as sk


def _row(name, us, model_us=None, gflops=1.0):
    derived = f"gflops={gflops}"
    if model_us is not None:
        derived += f";model_us={model_us}"
    return {"section": "s", "name": name, "us_per_call": us,
            "derived": derived}


MERGE = "mawi_like/sellcs+merge@4dev"


def test_chunk_gate_passes_when_chunked_is_fast():
    records = [_row(f"{MERGE}/chunks=1/k=8", 100.0, model_us=10.0),
               _row(f"{MERGE}/chunks=2/k=8", 105.0, model_us=6.0),
               _row(f"{MERGE}/chunks=4/k=8", 140.0, model_us=5.0)]
    assert sk.check_chunk_regressions(records, "f.json") == []
    assert sk.check_records(records, "f.json") == []


def test_chunk_gate_fails_on_regression_where_model_pays():
    records = [_row(f"{MERGE}/chunks=1/k=8", 100.0, model_us=10.0),
               _row(f"{MERGE}/chunks=2/k=8", 120.0, model_us=6.0),
               _row(f"{MERGE}/chunks=4/k=8", 150.0, model_us=5.0)]
    problems = sk.check_chunk_regressions(records, "f.json")
    assert len(problems) == 1 and "chunks=2" in problems[0] \
        and "1.20x" in problems[0]
    # and the per-record rules surface it through check_records too
    assert any("chunks=2" in p for p in sk.check_records(records, "f.json"))


def test_chunk_gate_disarmed_when_model_predicts_loss():
    """The smoke-scale case: launch-dominated psums make the model itself
    predict chunking loses (model_us grows with depth) — a measured loss
    is then the physics the model prices, not a regression."""
    records = [_row(f"{MERGE}/chunks=1/k=8", 100.0, model_us=1.1),
               _row(f"{MERGE}/chunks=2/k=8", 250.0, model_us=2.1),
               _row(f"{MERGE}/chunks=4/k=8", 400.0, model_us=4.1)]
    assert sk.check_chunk_regressions(records, "f.json") == []


def test_chunk_gate_groups_by_matrix_and_k():
    """k=16 regresses (model pays), k=8 does not; only k=16 is reported.
    Rows of other schedules / old-format names never join a group."""
    records = [_row(f"{MERGE}/chunks=1/k=16", 100.0, model_us=10.0),
               _row(f"{MERGE}/chunks=2/k=16", 250.0, model_us=6.0),
               _row(f"{MERGE}/chunks=1/k=8", 100.0, model_us=10.0),
               _row(f"{MERGE}/chunks=2/k=8", 101.0, model_us=6.0),
               _row("mawi_like/sellcs+row@4dev/k=16", 999.0, model_us=1.0),
               _row("mawi_like/sellcs+merge@4dev/k=16", 999.0,
                    model_us=1.0)]                           # PR-2 name
    problems = sk.check_chunk_regressions(records, "f.json")
    assert len(problems) == 1 and "/k=16" in problems[0]


def test_chunk_gate_needs_baseline_and_model():
    """Chunked rows without a chunks=1 row, or rows missing the model_us
    field, gate nothing."""
    assert sk.check_chunk_regressions(
        [_row(f"{MERGE}/chunks=2/k=8", 500.0, model_us=1.0)], "f") == []
    assert sk.check_chunk_regressions(
        [_row(f"{MERGE}/chunks=1/k=8", 1.0, model_us=9.0)], "f") == []
    assert sk.check_chunk_regressions(
        [_row(f"{MERGE}/chunks=1/k=8", 100.0),
         _row(f"{MERGE}/chunks=2/k=8", 500.0)], "f") == []   # no model_us


def test_basic_rules_still_hold():
    """The pre-existing NaN / zero-GFLOP/s rules are untouched."""
    assert sk.check_records([], "f.json")                 # empty emission
    bad = sk.check_records([_row("x/k=1", float("nan"))], "f.json")
    assert any("not finite" in p for p in bad)
    bad = sk.check_records([_row("x/k=1", 1.0, gflops=0)], "f.json")
    assert any("must be finite and" in p for p in bad)
