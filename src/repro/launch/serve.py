"""Serving entry point: batched prefill + greedy decode with KV caches.

CPU-scale demo (reduced config, real execution):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models.model import decode_step, init_params, prefill


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    S_max = P + G + (cfg.vision_tokens if cfg.frontend == "vision" else 0)
    rng = jax.random.PRNGKey(args.seed + 1)
    prompts = jax.random.randint(rng, (B, P), 0, cfg.vocab)
    vis = None
    if cfg.frontend == "vision":
        vis = jax.random.normal(rng, (B, cfg.vision_tokens, cfg.vision_dim))

    prefill_fn = jax.jit(lambda p, t, v: prefill(
        p, cfg, t, S_max, cache_dtype=jnp.float32, vision_embeds=v))
    decode_fn = jax.jit(lambda p, tok, c, pos: decode_step(
        p, cfg, tok, c, pos))

    t0 = time.perf_counter()
    logits, caches = prefill_fn(params, prompts, vis)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    offset = cfg.vision_tokens if cfg.frontend == "vision" else 0
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(G - 1):
        pos = jnp.full((B,), offset + P + i, jnp.int32)
        logits, caches = decode_fn(params, tok, caches, pos)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    tok.block_until_ready()
    t_decode = time.perf_counter() - t0

    gen = np.asarray(jnp.concatenate(out_tokens, axis=1))
    tps = B * (G - 1) / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} batch={B} prompt={P} gen={G}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; decode "
          f"{t_decode*1e3:.1f} ms ({tps:.1f} tok/s incl. compile)")
    print(f"[serve] sample generations (first 2 rows): {gen[:2].tolist()}")
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab)
    return gen


if __name__ == "__main__":
    main()
