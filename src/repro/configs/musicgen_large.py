"""MusicGen-large [arXiv:2306.05284]: decoder-only over EnCodec tokens.

Frontend stub per assignment: the EnCodec encoder/decoder is out of scope;
inputs are already discrete codes (vocab=2048). The released model predicts 4
codebooks with a delay pattern; we model the primary stream (noted in
DESIGN §4). Sinusoidal positions + LayerNorm + GELU, MHA (kv == heads)."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", n_layers=48, d_model=2048, n_heads=32,
    kv_heads=32, d_ff=8192, vocab=2048, head_dim=64, norm="ln",
    mlp_act="gelu", pos="sinusoidal", frontend="audio",
    block_pattern=("attn",), mlp_pattern=("dense",))

REDUCED = ModelConfig(
    name="musicgen-large-reduced", n_layers=2, d_model=64, n_heads=4,
    kv_heads=4, d_ff=160, vocab=64, head_dim=16, norm="ln", mlp_act="gelu",
    pos="sinusoidal", frontend="audio",
    block_pattern=("attn",), mlp_pattern=("dense",),
    compute_dtype=jnp.float32, loss_chunk=16)
