"""Adafactor [Shazeer & Stern 2018]: factored second moments, no momentum.

Selected for the largest configs (jamba-398B) where AdamW's 8 bytes/param of
optimizer state cannot fit v5e HBM even ZeRO-sharded over 256 chips
(DESIGN §5); factored state is O(rows + cols) per matrix."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .adamw import Optimizer, clip_by_global_norm, global_norm


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any     # row second-moment (or full v for <2D leaves)
    vc: Any     # col second-moment (zeros placeholder for <2D leaves)


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor(lr_schedule: Callable, decay: float = 0.8,
              eps: float = 1e-30, clip_norm: Optional[float] = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    def init(params) -> AdafactorState:
        def vr_init(p):
            return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) \
                else jnp.zeros(p.shape, jnp.float32)

        def vc_init(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) \
                if _factored(p) else jnp.zeros((1,), jnp.float32)

        return AdafactorState(
            step=jnp.zeros((), jnp.int32),
            vr=jax.tree_util.tree_map(vr_init, params),
            vc=jax.tree_util.tree_map(vc_init, params))

    def update(grads, state: AdafactorState, params):
        grad_norm = global_norm(grads)
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr = lr_schedule(step)
        beta = 1.0 - step.astype(jnp.float32) ** -decay

        def upd(p, g, vr, vc):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * vr + (1 - beta) * g2.mean(axis=-1)
                vc = beta * vc + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                precond = (vr[..., None] / denom[..., None]) * vc[..., None, :]
                u = g * jax.lax.rsqrt(jnp.maximum(precond, eps))
            else:
                vr = beta * vr + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(vr, eps))
            # update clipping (RMS <= 1), per the paper
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms)
            newp = p.astype(jnp.float32) - lr * (
                u + weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), vr, vc

        out = jax.tree_util.tree_map(upd, params, grads, state.vr, state.vc)
        pick = lambda i: jax.tree_util.tree_map(
            lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple))
        return pick(0), AdafactorState(step, pick(1), pick(2)), \
            {"lr": lr, "grad_norm": grad_norm}

    return Optimizer(init, update)
