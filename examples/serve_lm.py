"""Serving example: batched requests, prefill + KV-cache greedy decode on a
reduced hybrid (jamba-style) model — exercises attention KV caches and SSM
states in the same cache pytree.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch import serve as serve_cli

gen = serve_cli.main(["--arch", "jamba-1.5-large-398b", "--reduced",
                      "--batch", "4", "--prompt-len", "24", "--gen", "12"])
print(f"[example] generated shape {gen.shape}")
print("serve_lm OK")
