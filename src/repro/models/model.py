"""Composable decoder LM covering all assigned architectures.

A model is a cycled *group* of layer slots (e.g. jamba = 1 attn + 7 ssm per
group, MoE on every other slot); parameters are stacked over groups and the
stack runs under ``lax.scan`` so HLO size is O(group), not O(depth) — a
512-device jamba-72L compile stays tractable.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (AttnConfig, KVCache, attention, attention_decode,
                        attn_init, prefill_cache)
from .layers import (dense, dense_init, layernorm, layernorm_init, rmsnorm,
                     rmsnorm_init)
from .moe import (MoEConfig, moe_apply, moe_apply_ep,
                  moe_apply_ep_tp, moe_init)
from .ssm import SSMCache, SSMConfig, ssm_decode, ssm_forward, ssm_init

Array = jax.Array


from jax.sharding import PartitionSpec as _P


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int = 0
    norm: str = "rms"                      # "rms" | "ln"
    mlp_act: str = "swiglu"                # "swiglu" | "gelu" | "none"
    pos: str = "rope"                      # "rope" | "sinusoidal"
    tie_embeddings: bool = False
    block_pattern: Tuple[str, ...] = ("attn",)     # cycled mixer kinds
    mlp_pattern: Tuple[str, ...] = ("dense",)      # "dense"|"moe"|"none"
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_use_kernel: bool = False
    # SSM (mamba2)
    ssm_state: int = 128
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    # frontend stub
    frontend: str = "none"                 # "none" | "audio" | "vision"
    vision_tokens: int = 0
    vision_dim: int = 1024
    # numerics
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    loss_chunk: int = 512
    # distribution: when set (by launch.steps), activations are constrained
    # to shard their batch dim over these mesh axes — GSPMD propagation
    # alone replicates the batch (observed in the dry-run; EXPERIMENTS §Perf)
    batch_axes: Tuple[str, ...] = ()
    # expert-parallel MoE dispatch via shard_map (set by launch.steps when
    # n_experts divides the model axis; EXPERIMENTS §Perf iteration 1)
    moe_ep: str = ""            # "" | "ep" | "ep_tp"
    moe_capacity_factor: float = 1.3
    # sequence parallelism (context sharding) for long prefill: activations
    # shard dim 1 over these axes; flash attention switches its q-chunk loop
    # from scan to vmap so chunks stay device-local (§Perf iteration 3)
    seq_axes: Tuple[str, ...] = ()
    seq_axes_size: int = 1

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def group_size(self) -> int:
        return _lcm(len(self.block_pattern), len(self.mlp_pattern))

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0, \
            (self.n_layers, self.group_size)
        return self.n_layers // self.group_size

    def attn_config(self) -> AttnConfig:
        return AttnConfig(self.d_model, self.n_heads, self.kv_heads,
                          self.hd, self.rope_theta, self.qkv_bias,
                          self.qk_norm, self.sliding_window)

    def ssm_config(self) -> SSMConfig:
        return SSMConfig(self.d_model, self.ssm_state, 4, 2,
                         self.ssm_headdim, self.ssm_chunk)

    def moe_config(self) -> MoEConfig:
        return MoEConfig(self.d_model, self.d_ff, self.n_experts,
                         self.top_k, self.moe_use_kernel)

    def group_slots(self):
        """[(mixer_kind, mlp_kind)] for one group."""
        g = self.group_size
        return [(self.block_pattern[i % len(self.block_pattern)],
                 self.mlp_pattern[i % len(self.mlp_pattern)])
                for i in range(g)]

    def param_count(self, params=None) -> int:
        if params is None:
            return -1
        return sum(x.size for x in jax.tree_util.tree_leaves(params))


def _lcm(a, b):
    return a * b // math.gcd(a, b)


def _constrain_batch(cfg: ModelConfig, x: Array) -> Array:
    """Pin the leading (batch) dim of an activation to the DP axes, and —
    when sequence parallelism is on — dim 1 to the seq axes."""
    if not cfg.batch_axes and not cfg.seq_axes:
        return x
    batch = cfg.batch_axes or None
    rest = [None] * (x.ndim - 1)
    if cfg.seq_axes and x.ndim >= 2 and             x.shape[1] % max(cfg.seq_axes_size, 1) == 0 and x.shape[1] > 1:
        rest[0] = cfg.seq_axes
    return jax.lax.with_sharding_constraint(x, _P(batch, *rest))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _norm_init(cfg, d):
    return rmsnorm_init(d, cfg.param_dtype) if cfg.norm == "rms" \
        else layernorm_init(d, cfg.param_dtype)


def _norm(cfg, p, x):
    return rmsnorm(p, x) if cfg.norm == "rms" else layernorm(p, x)


def _mlp_init(key, cfg: ModelConfig):
    if cfg.mlp_act == "swiglu":
        ks = jax.random.split(key, 3)
        return {"w_gate": dense_init(ks[0], cfg.d_model, cfg.d_ff,
                                     dtype=cfg.param_dtype),
                "w_up": dense_init(ks[1], cfg.d_model, cfg.d_ff,
                                   dtype=cfg.param_dtype),
                "w_down": dense_init(ks[2], cfg.d_ff, cfg.d_model,
                                     dtype=cfg.param_dtype)}
    ks = jax.random.split(key, 2)
    return {"w_in": dense_init(ks[0], cfg.d_model, cfg.d_ff, bias=True,
                               dtype=cfg.param_dtype),
            "w_out": dense_init(ks[1], cfg.d_ff, cfg.d_model, bias=True,
                                dtype=cfg.param_dtype)}


def _mlp_apply(cfg: ModelConfig, p, x):
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(dense(p["w_gate"], x).astype(jnp.float32)) \
            * dense(p["w_up"], x).astype(jnp.float32)
        return dense(p["w_down"], h.astype(x.dtype))
    h = jax.nn.gelu(dense(p["w_in"], x).astype(jnp.float32))
    return dense(p["w_out"], h.astype(x.dtype))


def _slot_init(key, cfg: ModelConfig, mixer: str, mlp: str):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": _norm_init(cfg, cfg.d_model)}
    if mixer == "attn":
        p["mixer"] = attn_init(ks[0], cfg.attn_config(), cfg.param_dtype)
    elif mixer == "ssm":
        p["mixer"] = ssm_init(ks[0], cfg.ssm_config(), cfg.param_dtype)
    else:
        raise ValueError(mixer)
    if mlp != "none":
        p["norm2"] = _norm_init(cfg, cfg.d_model)
        if mlp == "moe":
            p["mlp"] = moe_init(ks[1], cfg.moe_config(), cfg.param_dtype)
        else:
            p["mlp"] = _mlp_init(ks[1], cfg)
    return p


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    slots = cfg.group_slots()

    def one_group(k):
        gks = jax.random.split(k, len(slots))
        return [_slot_init(gks[i], cfg, m, f)
                for i, (m, f) in enumerate(slots)]

    group_keys = jax.random.split(ks[0], cfg.n_groups)
    groups = jax.vmap(one_group)(group_keys)       # stacked over groups

    params: Dict[str, Any] = {
        "embed": jax.random.normal(
            ks[1], (cfg.vocab, cfg.d_model), cfg.param_dtype)
        * cfg.d_model ** -0.5,
        "final_norm": _norm_init(cfg, cfg.d_model),
        "groups": groups,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[2], cfg.d_model, cfg.vocab,
                                       dtype=cfg.param_dtype)
    if cfg.frontend == "vision":
        params["vision_proj"] = dense_init(ks[3], cfg.vision_dim,
                                           cfg.d_model,
                                           dtype=cfg.param_dtype)
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def _sinusoidal(S: int, d: int) -> Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _apply_slot(cfg: ModelConfig, slot_params, mixer: str, mlp: str,
                h: Array) -> Tuple[Array, Array]:
    aux = jnp.zeros((), jnp.float32)
    hn = _norm(cfg, slot_params["norm1"], h)
    if mixer == "attn":
        h = h + attention(slot_params["mixer"], cfg.attn_config(), hn,
                          vmap_q=bool(cfg.seq_axes))
    else:
        h = h + ssm_forward(slot_params["mixer"], cfg.ssm_config(), hn)
    if mlp != "none":
        hn = _norm(cfg, slot_params["norm2"], h)
        if mlp == "moe":
            out, aux = _moe(cfg, slot_params["mlp"], hn)
        else:
            out = _mlp_apply(cfg, slot_params["mlp"], hn)
        h = h + out
    return h, aux


def _moe(cfg: ModelConfig, p, hn):
    if cfg.moe_ep == "ep":
        return moe_apply_ep(p, cfg.moe_config(), hn,
                            batch_axes=cfg.batch_axes,
                            capacity_factor=cfg.moe_capacity_factor)
    if cfg.moe_ep == "ep_tp":
        return moe_apply_ep_tp(p, cfg.moe_config(), hn,
                               batch_axes=cfg.batch_axes)
    return moe_apply(p, cfg.moe_config(), hn)


def _run_groups(cfg: ModelConfig, params, h: Array) -> Tuple[Array, Array]:
    slots = cfg.group_slots()

    def group_fn(carry, group_params):
        h, aux = carry
        for i, (mixer, mlp) in enumerate(slots):
            h, a = _apply_slot(cfg, group_params[i], mixer, mlp, h)
            aux = aux + a
        return (_constrain_batch(cfg, h), aux), None

    if cfg.remat:
        group_fn = jax.checkpoint(
            group_fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    (h, aux), _ = jax.lax.scan(group_fn, (h, jnp.zeros((), jnp.float32)),
                               params["groups"])
    return h, aux


def embed_inputs(cfg: ModelConfig, params, tokens: Array,
                 vision_embeds: Optional[Array] = None) -> Array:
    h = params["embed"][tokens].astype(cfg.compute_dtype)
    if cfg.pos == "sinusoidal":
        h = h + _sinusoidal(h.shape[1], cfg.d_model)[None].astype(h.dtype)
    if cfg.frontend == "vision":
        assert vision_embeds is not None, "vision frontend needs embeds"
        v = dense(params["vision_proj"], vision_embeds.astype(
            cfg.compute_dtype))
        h = jnp.concatenate([v, h], axis=1)
    return _constrain_batch(cfg, h)


def forward(params, cfg: ModelConfig, tokens: Array,
            vision_embeds: Optional[Array] = None
            ) -> Tuple[Array, Array, Array]:
    """tokens [B, S] -> (hidden [B, S', d], final-normed, aux_loss)."""
    h = embed_inputs(cfg, params, tokens, vision_embeds)
    h, aux = _run_groups(cfg, params, h)
    h = _norm(cfg, params["final_norm"], h)
    return h, aux


def logits_from_hidden(params, cfg: ModelConfig, h: Array) -> Array:
    if cfg.tie_embeddings:
        return h.astype(jnp.float32) @ params["embed"].astype(
            jnp.float32).T
    return dense(params["unembed"], h, compute_dtype=cfg.compute_dtype
                 ).astype(jnp.float32)


def loss_fn(params, cfg: ModelConfig, tokens: Array,
            vision_embeds: Optional[Array] = None,
            loss_mask: Optional[Array] = None) -> Tuple[Array, Dict]:
    """Next-token CE, computed in sequence chunks so [B, S, V] logits are
    never materialized (vocab up to 152k)."""
    h, aux = forward(params, cfg, tokens, vision_embeds)
    if cfg.frontend == "vision":
        h = h[:, -tokens.shape[1]:]        # loss over text positions only
    B, S, _ = h.shape
    targets = tokens[:, 1:]                # predict t+1
    h = h[:, :-1]
    mask = jnp.ones_like(targets, jnp.float32) if loss_mask is None \
        else loss_mask[:, 1:].astype(jnp.float32)

    C = min(cfg.loss_chunk, S - 1)
    nchunk = -(-(S - 1) // C)
    pad = nchunk * C - (S - 1)
    h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    targets = jnp.pad(targets, ((0, 0), (0, pad)))
    mask = jnp.pad(mask, ((0, 0), (0, pad)))

    def chunk_loss(carry, inp):
        hc, tc, mc = inp                   # [B, C, d], [B, C], [B, C]
        lg = logits_from_hidden(params, cfg, hc)
        lse = jax.nn.logsumexp(lg, axis=-1)
        tok_lp = jnp.take_along_axis(lg, tc[..., None], axis=-1)[..., 0]
        nll = (lse - tok_lp) * mc
        return carry + nll.sum(), None

    hc = h.reshape(B, nchunk, C, -1).swapaxes(0, 1)
    tc = targets.reshape(B, nchunk, C).swapaxes(0, 1)
    mc = mask.reshape(B, nchunk, C).swapaxes(0, 1)
    # checkpoint: never keep a [B, C, vocab] logits chunk for backward
    total, _ = jax.lax.scan(jax.checkpoint(chunk_loss),
                            jnp.zeros((), jnp.float32), (hc, tc, mc))
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = total / denom + aux
    return loss, {"ce": total / denom, "aux": aux, "tokens": denom}


# ---------------------------------------------------------------------------
# serving: prefill + decode with per-slot caches
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, B: int, S_max: int, dtype=jnp.bfloat16):
    """Stacked per-group caches: list per slot of KVCache/SSMCache."""
    slots = cfg.group_slots()

    def one_group(_):
        caches = []
        for mixer, _mlp in slots:
            if mixer == "attn":
                caches.append(KVCache.init(B, S_max, cfg.attn_config(),
                                           dtype))
            else:
                caches.append(SSMCache.init(B, cfg.ssm_config(), dtype))
        return caches

    return jax.vmap(one_group)(jnp.arange(cfg.n_groups))


def decode_step(params, cfg: ModelConfig, token: Array, caches,
                pos: Array) -> Tuple[Array, Any]:
    """token [B, 1] int32; pos [B] int32 -> (logits [B, vocab], caches)."""
    h = params["embed"][token].astype(cfg.compute_dtype)
    if cfg.pos == "sinusoidal":
        d = cfg.d_model
        pf = pos.astype(jnp.float32)[:, None]
        dim = jnp.arange(d // 2, dtype=jnp.float32)[None]
        ang = pf / (10000.0 ** (2 * dim / d))
        h = h + jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                                axis=-1)[:, None].astype(h.dtype)
    slots = cfg.group_slots()

    def group_fn(h, inp):
        group_params, group_caches = inp
        new_caches = []
        for i, (mixer, mlp) in enumerate(slots):
            hn = _norm(cfg, group_params[i]["norm1"], h)
            if mixer == "attn":
                out, nc = attention_decode(group_params[i]["mixer"],
                                           cfg.attn_config(), hn,
                                           group_caches[i], pos)
            else:
                out, nc = ssm_decode(group_params[i]["mixer"],
                                     cfg.ssm_config(), hn, group_caches[i])
            h = h + out
            new_caches.append(nc)
            if mlp != "none":
                hn = _norm(cfg, group_params[i]["norm2"], h)
                if mlp == "moe":
                    out, _ = _moe(cfg, group_params[i]["mlp"], hn)
                else:
                    out = _mlp_apply(cfg, group_params[i]["mlp"], hn)
                h = h + out
        return _constrain_batch(cfg, h), new_caches

    h, new_caches = jax.lax.scan(group_fn, h,
                                 (params["groups"], caches))
    h = _norm(cfg, params["final_norm"], h)
    logits = logits_from_hidden(params, cfg, h)[:, 0]
    return logits, new_caches


def prefill(params, cfg: ModelConfig, tokens: Array, S_max: int,
            cache_dtype=jnp.bfloat16,
            vision_embeds: Optional[Array] = None):
    """Run the prompt, returning (last-token logits, primed caches)."""
    h = embed_inputs(cfg, params, tokens, vision_embeds)
    slots = cfg.group_slots()
    B, S = h.shape[0], h.shape[1]

    def group_fn(h, group_params):
        new_caches = []
        for i, (mixer, mlp) in enumerate(slots):
            hn = _norm(cfg, group_params[i]["norm1"], h)
            if mixer == "attn":
                out, nc = prefill_cache(group_params[i]["mixer"],
                                        cfg.attn_config(), hn, S_max,
                                        cache_dtype,
                                        vmap_q=bool(cfg.seq_axes))
            else:
                scfg = cfg.ssm_config()
                out = ssm_forward(group_params[i]["mixer"], scfg, hn)
                # recompute final state for the cache via a 1-shot decode
                # over the last token is incorrect; instead run the chunked
                # scan once more carrying state (cheap: reuse forward path)
                nc = _ssm_prefill_state(group_params[i]["mixer"], scfg, hn)
            h = h + out
            new_caches.append(nc)
            if mlp != "none":
                hn = _norm(cfg, group_params[i]["norm2"], h)
                if mlp == "moe":
                    out, _ = _moe(cfg, group_params[i]["mlp"], hn)
                else:
                    out = _mlp_apply(cfg, group_params[i]["mlp"], hn)
                h = h + out
        return h, new_caches

    h, caches = jax.lax.scan(group_fn, h, params["groups"])
    h = _norm(cfg, params["final_norm"], h)
    logits = logits_from_hidden(params, cfg, h[:, -1:])[:, 0]
    return logits, caches


def _ssm_prefill_state(p, scfg: SSMConfig, u: Array) -> SSMCache:
    """Final (conv_state, ssm_state) after consuming u (prefill)."""
    from .layers import causal_conv1d
    from .ssm import _split_proj
    B, S, _ = u.shape
    di, N, H, P = scfg.d_inner, scfg.d_state, scfg.nheads, scfg.headdim
    z, xBC, dt = _split_proj(p, scfg, u)
    xBC_conv, conv_state = causal_conv1d(p["conv"], xBC)
    xBC_act = jax.nn.silu(xBC_conv.astype(jnp.float32))
    x = xBC_act[..., :di].reshape(B, S, H, P)
    Bm = xBC_act[..., di:di + N]
    dtv = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    loga = dtv * A[None, None]
    cs = jnp.cumsum(loga, axis=1)
    tail = jnp.exp(cs[:, -1:] - cs) * dtv
    state = jnp.einsum("bjh,bjhp,bjn->bhpn", tail, x, Bm)
    return SSMCache(conv_state, state)
