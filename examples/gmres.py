"""Restarted GMRES over the sparse core — the paper's §1 motivating workload
("iterative methods for sparse linear systems such as GMRES").

Solves (I + 0.05·A_norm) x = b on an RMAT graph with GMRES(20); the operator
is a repro.core SpMV, so the conversion cost amortizes over all inner
iterations (the §7 economics again). The autotuner (paper §8 future work)
picks the format.

Run:  PYTHONPATH=src python examples/gmres.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import autotune, convert, spmv, to_coo
from repro.data import matrices

rows, cols, vals, shape = matrices.rmat(scale=12, edge_factor=10, seed=0)
n = shape[0]
deg = np.bincount(cols, minlength=n).astype(np.float32)
coo = to_coo(rows, cols, 1.0 / np.maximum(deg[cols], 1.0), shape)

best, _ = autotune(coo, num_spmvs=500, reps=3)
print(f"autotuner picked: {best.algorithm} (beta={best.beta})")
kw = {} if best.beta is None else {"beta": best.beta}
A = convert(coo, best.algorithm, **kw)


def op(v):
    """(I + 0.05 A) v — diagonally dominant, guaranteed convergence."""
    return v + 0.05 * spmv(A, v, impl="ref")


def gmres(op, b, m=20, restarts=10, tol=1e-8):
    x = jnp.zeros_like(b)
    for outer in range(restarts):
        r = b - op(x)
        beta = float(jnp.linalg.norm(r))
        if beta < tol:
            break
        V = [r / beta]
        H = np.zeros((m + 1, m))
        for j in range(m):
            w = op(V[j])
            for i in range(j + 1):                 # modified Gram-Schmidt
                H[i, j] = float(jnp.vdot(V[i], w))
                w = w - H[i, j] * V[i]
            H[j + 1, j] = float(jnp.linalg.norm(w))
            if H[j + 1, j] < 1e-12:
                m = j + 1
                break
            V.append(w / H[j + 1, j])
        e1 = np.zeros(m + 1)
        e1[0] = beta
        y, *_ = np.linalg.lstsq(H[: m + 1, :m], e1, rcond=None)
        x = x + jnp.stack(V[:m], axis=1) @ jnp.asarray(y, jnp.float32)
        res = float(jnp.linalg.norm(b - op(x)))
        print(f"  restart {outer}: residual {res:.3e}")
        if res < tol:
            break
    return x


b = jnp.asarray(np.random.default_rng(1).standard_normal(n)
                .astype(np.float32))
t0 = time.perf_counter()
x = gmres(op, b)
res = float(jnp.linalg.norm(b - op(x)) / jnp.linalg.norm(b))
print(f"GMRES done in {time.perf_counter() - t0:.2f}s, "
      f"relative residual {res:.2e}")
assert res < 1e-5
print("gmres OK")
