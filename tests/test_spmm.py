"""repro.spmm — multi-RHS engine: SELL-C-σ, kernels, selector-k, batching.

The core property (ISSUE acceptance): for every storage format and
k in {1, 8, 32, 128}, ``spmm(A, X)`` equals k stacked single-vector oracle
calls to fp32 tolerance — including the mawi-style skewed generator.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (MachineSpec, convert, coo_to_csr, matrix_stats,
                        select, select_algorithm, spmv, to_coo)
from repro.core.spmv import spmv_coo
from repro.data import matrices
from repro.kernels.tiling import coo_to_tiled
from repro import spmm as M

RTOL, ATOL = 2e-4, 2e-4


def _matrices():
    return {
        "uniform": to_coo(*matrices.uniform(230, 190, 2200, seed=0)),
        "mawi_like": to_coo(*matrices.mawi_like(260, 240, 2400, 0.3,
                                                seed=1)),
    }


def _make(fmt, coo):
    if fmt == "coo":
        return coo
    if fmt == "csr":
        return coo_to_csr(coo)
    if fmt == "blocked":
        return convert(coo, "bcohc", beta=64)
    if fmt == "tiled":
        return coo_to_tiled(coo, "csb", beta=128)
    if fmt == "sellcs":
        return M.coo_to_sellcs(coo, c=64, sigma=128)
    raise ValueError(fmt)


@pytest.mark.parametrize("k", [1, 8, 32, 128])
@pytest.mark.parametrize("fmt", ["coo", "csr", "blocked", "tiled",
                                 "sellcs"])
def test_spmm_equals_stacked_spmv(fmt, k):
    for name, coo in _matrices().items():
        mat = _make(fmt, coo)
        n = coo.shape[1]
        X = jnp.asarray(np.random.default_rng(k).standard_normal(
            (n, k)).astype(np.float32))
        Y = M.spmm(mat, X)
        stacked = jnp.stack([spmv_coo(coo, X[:, j]) for j in range(k)],
                            axis=1)
        np.testing.assert_allclose(np.asarray(Y), np.asarray(stacked),
                                   rtol=RTOL, atol=ATOL, err_msg=name)


def test_spmm_1d_input_is_spmv():
    coo = _matrices()["uniform"]
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        coo.shape[1]).astype(np.float32))
    y = M.spmm(coo_to_csr(coo), x)
    assert y.ndim == 1
    np.testing.assert_allclose(np.asarray(y), np.asarray(spmv_coo(coo, x)),
                               rtol=RTOL, atol=ATOL)


# --------------------------------------------------------------------------
# SELL-C-σ structure
# --------------------------------------------------------------------------
def test_sellcs_roundtrip_exact():
    for name, coo in _matrices().items():
        for c, sigma in ((8, 8), (64, 128), (128, 10 ** 6)):
            sc = M.coo_to_sellcs(coo, c=c, sigma=sigma)
            rt = sc.to_coo()
            assert rt.nnz == coo.nnz, (name, c, sigma)
            np.testing.assert_allclose(np.asarray(rt.todense()),
                                       np.asarray(coo.todense()),
                                       atol=1e-6, err_msg=name)


def test_sellcs_sigma_sorting_reduces_padding():
    """A global σ sort can only shrink (or keep) the padded footprint vs
    no sorting (σ = C): rows of similar length share slices."""
    coo = to_coo(*matrices.powerlaw(400, 300, 4000, 1.8, seed=2))
    unsorted = M.coo_to_sellcs(coo, c=32, sigma=32)
    glob = M.coo_to_sellcs(coo, c=32, sigma=10 ** 6)
    assert glob.padded_nnz <= unsorted.padded_nnz
    assert glob.fill_ratio >= unsorted.fill_ratio
    # and within each σ-window, slice widths are non-increasing
    widths = np.diff(np.asarray(glob.slice_ptr))
    assert np.all(np.diff(widths) <= 0)


def test_sellcs_convert_registration():
    coo = _matrices()["uniform"]
    sc = convert(coo, "sellcs", c=32, sigma=64)
    assert isinstance(sc, M.SellCS) and sc.chunk == 32
    x = jnp.asarray(np.random.default_rng(3).standard_normal(
        coo.shape[1]).astype(np.float32))
    np.testing.assert_allclose(np.asarray(spmv(sc, x)),
                               np.asarray(spmv_coo(coo, x)),
                               rtol=RTOL, atol=ATOL)


# --------------------------------------------------------------------------
# Pallas kernels (interpret mode), k-tiled grids
# --------------------------------------------------------------------------
@pytest.mark.parametrize("k,k_tile", [(8, 4), (5, 2), (8, 8)])
def test_kernels_interpret_match_reference(k, k_tile):
    coo = _matrices()["mawi_like"]
    n = coo.shape[1]
    X = jnp.asarray(np.random.default_rng(7).standard_normal(
        (n, k)).astype(np.float32))
    dense = np.asarray(coo.todense()) @ np.asarray(X)

    ts = coo_to_tiled(coo, "csb", beta=128)
    np.testing.assert_allclose(
        np.asarray(M.tiled_spmm(ts, X, k_tile=k_tile, interpret=True)),
        dense, rtol=RTOL, atol=ATOL)
    csr = coo_to_csr(coo)
    np.testing.assert_allclose(
        np.asarray(M.csr_spmm(csr, X, k_tile=k_tile, interpret=True)),
        dense, rtol=RTOL, atol=ATOL)
    sc = M.coo_to_sellcs(coo, c=64, sigma=128)
    np.testing.assert_allclose(
        np.asarray(M.sellcs_spmm(sc, X, k_tile=k_tile, interpret=True)),
        dense, rtol=RTOL, atol=ATOL)


def test_choose_k_tile_roofline():
    # never exceeds k, never below 1
    assert M.choose_k_tile((100, 100), 1) == 1
    assert 1 <= M.choose_k_tile((100, 100), 7) <= 7
    # VMEM bound: bigger matrices force smaller k-tiles
    small = M.choose_k_tile((1000, 1000), 256, nnz=10 ** 5)
    big = M.choose_k_tile((10 ** 6, 10 ** 6), 256, nnz=10 ** 7)
    assert big <= small
    # lane alignment once above one lane
    kt = M.choose_k_tile((1000, 1000), 256, nnz=10 ** 7)
    assert kt == 256 or kt % 128 == 0 or kt < 128


def test_arithmetic_intensity_monotone_in_k():
    from repro.roofline import ridge_intensity, spmm_arithmetic_intensity
    ais = [spmm_arithmetic_intensity(10 ** 6, 10 ** 5, 10 ** 5, k)
           for k in (1, 2, 4, 8, 16, 32, 64, 128, 256)]
    assert all(b > a for a, b in zip(ais, ais[1:]))
    assert ridge_intensity() > 0


# --------------------------------------------------------------------------
# selector / autotune k-integration
# --------------------------------------------------------------------------
def test_select_k1_unchanged():
    for name, coo in _matrices().items():
        s = matrix_stats(coo)
        for nd in (1, 256):
            mach = MachineSpec(num_devices=nd)
            for num in (1, 500, 50_000):
                assert select(s, mach, num, k=1) == \
                    select_algorithm(s, mach, num), (name, nd, num)


def test_select_k_accepts_and_returns_candidate():
    s = matrix_stats(_matrices()["mawi_like"])
    assert s.has_dense_row
    pick = select(s, MachineSpec(num_devices=1), 5000, k=64)
    from repro.core.selector import ROW_SPLITTING
    assert pick in ROW_SPLITTING + ("sellcs",)


def test_spmm_cost_scale_sublinear():
    from repro.core import spmm_cost_scale
    s = matrix_stats(_matrices()["uniform"])
    c1 = spmm_cost_scale("parcrs", s, 1)
    c64 = spmm_cost_scale("parcrs", s, 64)
    assert c1 == pytest.approx(1.0)
    assert 1.0 < c64 < 64.0          # the whole point of batching


def test_autotune_k_smoke():
    from repro.core import autotune
    coo = to_coo(*matrices.uniform(150, 150, 1500, seed=4))
    best, results = autotune(coo, num_spmvs=3, reps=1, k=8,
                             algorithms=("parcrs", "sellcs"))
    assert best.k == 8 and best.k_tile is not None and best.k_tile >= 1
    assert {r.algorithm for r in results} == {"parcrs", "sellcs"}


# --------------------------------------------------------------------------
# distributed (format x schedule x k) scoring
# --------------------------------------------------------------------------
def test_spmm_distributed_collective_s_chunked_overlap():
    """ISSUE 3 acceptance: the chunked merge model's exposed collective
    seconds are strictly below the monolithic model for k >= 8 on >= 2
    devices (the psum hides under the slice stream)."""
    from repro.roofline import (spmm_distributed_collective_s,
                                spmm_distributed_time)
    m = n = 100_000
    nnz = 10_000_000
    for k in (8, 64, 256):
        for P in (2, 8):
            mono = spmm_distributed_collective_s(m, n, k, P, "merge",
                                                 nnz=nnz, num_chunks=1)
            assert mono > 0.0
            for c in (2, 4, 8):
                over = spmm_distributed_collective_s(m, n, k, P, "merge",
                                                     nnz=nnz, num_chunks=c)
                assert 0.0 < over < mono, (k, P, c)
            # the time model inherits the same strict ordering
            assert spmm_distributed_time(m, n, k, P, "merge", nnz=nnz,
                                         num_chunks=4) < \
                spmm_distributed_time(m, n, k, P, "merge", nnz=nnz,
                                      num_chunks=1)
    # "row" has no collective to chunk; single device has no wire at all
    assert spmm_distributed_collective_s(m, n, 8, 8, "row", nnz=nnz,
                                         num_chunks=4) == 0.0
    assert spmm_distributed_collective_s(m, n, 8, 1, "merge", nnz=nnz,
                                         num_chunks=4) == 0.0
    # per-psum launch cost keeps the optimum finite: absurd depths lose
    tiny = spmm_distributed_collective_s(500, 500, 8, 8, "merge", nnz=4000,
                                         num_chunks=1)
    assert spmm_distributed_collective_s(500, 500, 8, 8, "merge", nnz=4000,
                                         num_chunks=10_000) > tiny
    import pytest as _pytest
    with _pytest.raises(ValueError):
        spmm_distributed_collective_s(m, n, 8, 8, "merge", nnz=nnz,
                                      num_chunks=0)


def test_spmm_distributed_traffic_model_properties():
    from repro.roofline import (spmm_distributed_time,
                                spmm_distributed_traffic)
    m = n = 100_000
    nnz = 10_000_000
    # merge is the only schedule with collective bytes, and they grow in k
    hbm_r, coll_r = spmm_distributed_traffic(m, n, 8, 8, "row", nnz=nnz)
    hbm_m, coll_m = spmm_distributed_traffic(m, n, 8, 8, "merge", nnz=nnz)
    assert coll_r == 0.0 and coll_m > 0.0
    _, coll_m64 = spmm_distributed_traffic(m, n, 64, 8, "merge", nnz=nnz)
    assert coll_m64 > coll_m
    # a dominant dense row bounds the row schedule's critical shard below
    hot = nnz // 2
    hbm_hot, _ = spmm_distributed_traffic(m, n, 8, 8, "row", nnz=nnz,
                                          max_row_nnz=hot)
    assert hbm_hot > hbm_r
    # one device degrades both schedules to the same single-device stream
    t1r = spmm_distributed_time(m, n, 8, 1, "row", nnz=nnz)
    t1m = spmm_distributed_time(m, n, 8, 1, "merge", nnz=nnz)
    assert t1r == pytest.approx(t1m)
    with pytest.raises(ValueError):
        spmm_distributed_traffic(m, n, 8, 8, "diagonal", nnz=nnz)


def test_select_distributed_schedule_tracks_skew_and_k():
    """The joint grid: heavy skew -> merge at small k (psum is cheap),
    row at large k (psum bytes scale with k); uniform -> always row. The
    chunking axis does not flip either crossover: even fully pipelined,
    the last chunk's psum drain keeps merge above row at large k."""
    from repro.core import select_distributed
    from repro.core.selector import MatrixStats
    mawi = MatrixStats(m=230_000, n=230_000, nnz=270_000_000,
                       max_row_nnz=120_000_000, row_var=1e9)
    uni = MatrixStats(m=230_000, n=230_000, nnz=270_000_000,
                      max_row_nnz=2_000, row_var=10.0)
    assert select_distributed(mawi, k=1, num_devices=8)[1] == "merge"
    assert select_distributed(mawi, k=64, num_devices=8)[1] == "row"
    for k in (1, 8, 64):
        assert select_distributed(uni, k=k, num_devices=8)[1] == "row"
    with pytest.raises(ValueError):
        select_distributed(uni, k=0, num_devices=8)
    with pytest.raises(ValueError):
        select_distributed(uni, k=1, num_devices=0)


def test_select_distributed_records_num_chunks():
    """The grid gained a chunking axis: the choice is a named 3-tuple, the
    row schedule always reports 1, and a merge-winning matrix with real
    psum bytes picks a pipelined depth > 1."""
    from repro.core import CHUNK_CANDIDATES, select_distributed
    from repro.core.selector import DistributedChoice, MatrixStats
    mawi = MatrixStats(m=230_000, n=230_000, nnz=270_000_000,
                       max_row_nnz=120_000_000, row_var=1e9)
    uni = MatrixStats(m=230_000, n=230_000, nnz=270_000_000,
                      max_row_nnz=2_000, row_var=10.0)
    choice = select_distributed(mawi, k=1, num_devices=8)
    assert isinstance(choice, DistributedChoice)
    assert choice.schedule == "merge" and choice.num_chunks in \
        CHUNK_CANDIDATES and choice.num_chunks > 1
    algo, sched, nc, mesh, cx, st, gx = choice   # unpacks like a tuple
    assert (algo, sched, nc, mesh, cx, st, gx) == tuple(choice)
    assert st == "general"                    # nothing symmetric here
    assert gx in ("upfront", "overlap", "fused")
    assert mesh[0] * mesh[1] == 8
    assert select_distributed(uni, k=8, num_devices=8).num_chunks == 1


def test_select_num_devices_keyword():
    """select(num_devices=P>1) routes through the joint grid and still
    returns a plain format name; num_devices=None keeps the old path."""
    from repro.core.selector import DISTRIBUTED_ALGOS
    for name, coo in _matrices().items():
        s = matrix_stats(coo)
        pick = select(s, num_spmvs=1000, k=64, num_devices=8)
        assert pick in DISTRIBUTED_ALGOS, (name, pick)
        assert select(s, MachineSpec(1), 1000, k=1) == \
            select_algorithm(s, MachineSpec(1), 1000)


def test_select_num_devices_threads_throughput_through():
    """Regression: select(num_devices>1) used to silently drop the
    caller's measured throughput table — the one path users tune. A table
    that makes one distributed-capable format overwhelmingly faster must
    flip the pick both ways, and omitting the table keeps the pure-model
    choice."""
    from repro.core import select_distributed
    s = matrix_stats(_matrices()["uniform"])
    fast_parcrs = {"parcrs": 100.0, "sellcs": 1.0}
    fast_sellcs = {"parcrs": 1.0, "sellcs": 100.0}
    assert select(s, num_spmvs=1000, k=64, num_devices=8,
                  throughput=fast_parcrs) == "parcrs"
    assert select(s, num_spmvs=1000, k=64, num_devices=8,
                  throughput=fast_sellcs) == "sellcs"
    # the DistributedChoice path accepts it too, and a missing sellcs
    # entry is defaulted from the csb prior like the 1-device selector
    c = select_distributed(s, k=64, num_devices=8,
                           throughput={"parcrs": 1.0, "csb": 100.0})
    assert c.algorithm == "sellcs"
    # no table -> unchanged pure-model scoring
    assert select(s, num_spmvs=1000, k=64, num_devices=8) == \
        select_distributed(s, k=64, num_devices=8).algorithm


def test_sellcs_storage_bytes_counts_every_array():
    """ISSUE 4 satellite: storage_bytes claimed "faithful SELL-C-σ cost"
    while omitting the slice_of and row_len int32 arrays; it must equal
    the summed nbytes of every member array exactly."""
    for coo in _matrices().values():
        sc = M.coo_to_sellcs(coo)
        actual = (sc.data.nbytes + sc.cols.nbytes + sc.slice_ptr.nbytes
                  + sc.slice_of.nbytes + sc.row_perm.nbytes
                  + sc.row_len.nbytes)
        assert sc.storage_bytes() == actual
    # empty matrix: the fixed-size arrays still count
    from repro.core import to_coo
    z = np.zeros(0, np.int32)
    se = M.coo_to_sellcs(to_coo(z, z, np.zeros(0, np.float32), (6, 4)), c=2)
    actual = (se.data.nbytes + se.cols.nbytes + se.slice_ptr.nbytes
              + se.slice_of.nbytes + se.row_perm.nbytes + se.row_len.nbytes)
    assert se.storage_bytes() == actual


def test_spmm_distributed_traffic_compact_x():
    """ISSUE 5 satellite: the compact_x X term is exactly nnz-proportional
    (min(nnz/P, n) rows via spmm_touched_fraction), never exceeds the
    replicated figure, honors a measured per-shard n_touched, and leaves
    the collective bytes alone (compaction shrinks reads, not the psum)."""
    from repro.roofline import (spmm_distributed_traffic,
                                spmm_touched_fraction)
    m = n = 100_000
    dt = 4
    P = 8
    mat_bytes = 1e6          # pinned so only the X term varies with nnz
    for sched in ("row", "merge"):
        hbm_rep, coll_rep = spmm_distributed_traffic(
            m, n, 64, P, sched, matrix_bytes=mat_bytes, nnz=80_000)
        prev = None
        for nnz in (0, 8_000, 80_000, 160_000):
            hbm_c, coll_c = spmm_distributed_traffic(
                m, n, 64, P, sched, matrix_bytes=mat_bytes, nnz=nnz,
                compact_x=True)
            # X term == min(nnz/P, n) * k * dt exactly — nnz-proportional
            expect = min(nnz / P, n) * 64 * dt
            base = hbm_c - expect
            if prev is None:
                prev = base
            assert base == pytest.approx(prev), (sched, nnz)
            assert hbm_c <= hbm_rep + 1e-9, (sched, nnz)
            assert coll_c == coll_rep, (sched, nnz)
        # saturated columns: nnz/P >= n caps at the replicated figure
        hbm_sat, _ = spmm_distributed_traffic(
            m, n, 64, P, sched, matrix_bytes=mat_bytes,
            nnz=100 * n * P, compact_x=True)
        assert hbm_sat == pytest.approx(hbm_rep)
    # measured n_touched overrides the nnz bound (and still caps at n)
    hbm_meas, _ = spmm_distributed_traffic(
        m, n, 64, P, "row", matrix_bytes=mat_bytes, nnz=80_000,
        compact_x=True, n_touched=500.0)
    hbm_model, _ = spmm_distributed_traffic(
        m, n, 64, P, "row", matrix_bytes=mat_bytes, nnz=80_000,
        compact_x=True)
    assert hbm_model - hbm_meas == pytest.approx(
        (80_000 / P - 500.0) * 64 * dt)
    assert spmm_touched_fraction(n, 80_000, P) == pytest.approx(
        80_000 / P / n)
    assert spmm_touched_fraction(n, 10**12, P) == 1.0
    assert spmm_touched_fraction(0, 10, P) == 0.0
    # the 2-D mesh composes: the compact X term divides by P_model too
    hbm1, _ = spmm_distributed_traffic(
        m, n, 64, P, "merge", matrix_bytes=mat_bytes, nnz=8_000,
        compact_x=True)
    hbm2, _ = spmm_distributed_traffic(
        m, n, 64, P, "merge", matrix_bytes=mat_bytes, nnz=8_000,
        compact_x=True, model_devices=2)
    x_and_y = (8_000 / P + m) * 64 * dt        # k-proportional terms
    assert hbm1 - hbm2 == pytest.approx(x_and_y / 2)


def test_select_distributed_compact_x_flip():
    """ISSUE 5 satellite: the selector flips to compaction on a
    highly-sparse-columns case (a shard touches far fewer than n columns)
    and refuses it on a dense-columns case (nnz/P >= n makes the gather a
    modelled wash — the tie keeps replication)."""
    from repro.core import select_distributed
    from repro.core.selector import MatrixStats
    # sparse columns: 8 shards x 50k nnz each touch <= 50k of 2M columns
    sparse = MatrixStats(m=2_000_000, n=2_000_000, nnz=400_000,
                         max_row_nnz=20, row_var=1.0)
    pick = select_distributed(sparse, k=64, num_devices=8)
    assert pick.algorithm == "sellcs" and pick.compact_x is True
    # dense columns: nnz/P >> n — compaction cannot shrink the X term
    dense = MatrixStats(m=230_000, n=230_000, nnz=270_000_000,
                        max_row_nnz=2_000, row_var=10.0)
    assert select_distributed(dense, k=64, num_devices=8).compact_x is False
    # single device keeps the degenerate default
    assert select_distributed(dense, k=1, num_devices=1).compact_x is False


def test_sharded_sellcs_storage_bytes_counts_col_map():
    """ISSUE 5 satellite: ShardedSellCS.storage_bytes must equal the
    summed nbytes of every member array — including the compact_x col_map
    / n_touched and any baked chunk plan — so the paper's "472
    multiplications to amortize" convert-cost comparisons stay honest."""
    from repro.spmm import partition_sellcs_nnz, partition_sellcs_rows

    def expected(sh):
        total = (sh.data.nbytes + sh.cols.nbytes + sh.slice_of.nbytes
                 + sh.slice_offset.nbytes + sh.row_perm.nbytes)
        for opt in (sh.row_counts, sh.col_map, sh.n_touched):
            if opt is not None:
                total += opt.nbytes
        if sh.chunk_plan is not None:
            for sp in sh.chunk_plan[1]:
                total += (sp.data.nbytes + sp.cols.nbytes
                          + sp.slice_of.nbytes)
                for opt in (sp.sub, sp.col_map, sp.n_touched):
                    if opt is not None:
                        total += opt.nbytes
            for opt in sh.chunk_plan[2:]:
                if opt is not None:
                    total += opt.nbytes
        return total

    for coo in _matrices().values():
        sc = M.coo_to_sellcs(coo, c=16, sigma=64)
        for cf in (False, True):
            for sh in (partition_sellcs_rows(sc, 4, compact_x=cf),
                       partition_sellcs_nnz(sc, 4, compact_x=cf),
                       partition_sellcs_nnz(sc, 4, num_chunks=3,
                                            compact_x=cf)):
                assert sh.storage_bytes() == expected(sh), cf
        # the col_map is real storage: compaction must cost more bytes
        assert partition_sellcs_rows(sc, 4, compact_x=True).storage_bytes() \
            > partition_sellcs_rows(sc, 4).storage_bytes()


def test_autotune_num_devices_records_schedule():
    from repro.core import CHUNK_CANDIDATES, autotune
    coo = to_coo(*matrices.uniform(150, 150, 1500, seed=4))
    best, results = autotune(coo, num_spmvs=3, reps=1, k=8, num_devices=8,
                             algorithms=("parcrs", "sellcs"))
    assert best.num_devices == 8
    assert all(r.schedule in ("row", "merge") for r in results)
    assert all(r.dist_model_s is not None and r.dist_model_s > 0
               for r in results)
    # ISSUE 3 acceptance: the tuner records a num_chunks choice — 1 for
    # the collective-free row schedule, a CHUNK_CANDIDATES entry for merge
    assert all(r.num_chunks == 1 for r in results if r.schedule == "row")
    assert all(r.num_chunks in CHUNK_CANDIDATES for r in results
               if r.schedule == "merge")
    assert best.num_chunks is not None and best.num_chunks >= 1
    # ISSUE 5: the tuner records the compact-gather choice; only sellcs
    # can execute it, so every other format must record False
    assert all(r.compact_x in (False, True) for r in results)
    assert all(r.compact_x is False for r in results
               if r.algorithm != "sellcs")


# --------------------------------------------------------------------------
# request batching (serve path)
# --------------------------------------------------------------------------
def test_batch_spmv_matches_individual():
    coo = _matrices()["mawi_like"]
    csr = coo_to_csr(coo)
    rng = np.random.default_rng(9)
    xs = [jnp.asarray(rng.standard_normal(coo.shape[1]).astype(np.float32))
          for _ in range(6)]
    ys = M.batch_spmv(csr, xs)
    for x, y in zip(xs, ys):
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(spmv_coo(coo, x)),
                                   rtol=RTOL, atol=ATOL)


def test_request_batcher_flush_and_padding():
    coo = _matrices()["uniform"]
    sc = M.coo_to_sellcs(coo, c=32, sigma=64)
    b = M.RequestBatcher(sc, max_batch=8)
    rng = np.random.default_rng(11)
    xs = [jnp.asarray(rng.standard_normal(coo.shape[1]).astype(np.float32))
          for _ in range(11)]
    rids = [b.submit(x) for x in xs]
    assert b.pending == 11
    out = b.drain()
    assert b.pending == 0 and b.flushes == 2 and b.served == 11
    assert sorted(out) == sorted(rids)
    for rid, x in zip(rids, xs):
        np.testing.assert_allclose(np.asarray(out[rid]),
                                   np.asarray(spmv_coo(coo, x)),
                                   rtol=RTOL, atol=ATOL)


def test_batcher_rejects_bad_shape():
    coo = _matrices()["uniform"]
    with pytest.raises(ValueError):
        M.batch_spmv(coo_to_csr(coo),
                     [jnp.zeros((coo.shape[1] + 1,), jnp.float32)])
    # submit() checks shape up front so a bad request can never corrupt a
    # flush batch that was already popped from the queue
    b = M.RequestBatcher(coo_to_csr(coo), max_batch=4)
    with pytest.raises(ValueError):
        b.submit(jnp.zeros((coo.shape[1] + 1,), jnp.float32))
    assert b.pending == 0


def test_batcher_partial_flush_and_interleaving():
    """A flush below max_batch serves exactly the queued requests; requests
    submitted after a flush land in the next one, in order."""
    coo = _matrices()["uniform"]
    csr = coo_to_csr(coo)
    b = M.RequestBatcher(csr, max_batch=8)
    rng = np.random.default_rng(21)
    xs = [jnp.asarray(rng.standard_normal(coo.shape[1]).astype(np.float32))
          for _ in range(5)]
    rids = [b.submit(x) for x in xs[:3]]
    out1 = b.flush()                      # partial: 3 of max 8
    assert sorted(out1) == sorted(rids) and b.pending == 0
    assert b.flushes == 1 and b.served == 3
    rids2 = [b.submit(x) for x in xs[3:]]
    out2 = b.flush()
    assert sorted(out2) == sorted(rids2) and b.served == 5
    for rid, x in zip(rids + rids2, xs):
        np.testing.assert_allclose(np.asarray((out1 | out2)[rid]),
                                   np.asarray(spmv_coo(coo, x)),
                                   rtol=RTOL, atol=ATOL)
    assert b.flush() == {}                # empty queue is a no-op


def test_batcher_scatter_order_is_per_ticket_not_fifo():
    """Result columns scatter back by ticket even when consumed out of
    submission order."""
    coo = _matrices()["mawi_like"]
    sc = M.coo_to_sellcs(coo, c=32, sigma=64)
    b = M.RequestBatcher(sc, max_batch=16)
    rng = np.random.default_rng(23)
    xs = [jnp.asarray(rng.standard_normal(coo.shape[1]).astype(np.float32))
          for _ in range(7)]
    rids = [b.submit(x) for x in xs]
    out = b.drain()
    for rid, x in sorted(zip(rids, xs), key=lambda t: -t[0]):  # reversed
        np.testing.assert_allclose(np.asarray(out[rid]),
                                   np.asarray(spmv_coo(coo, x)),
                                   rtol=RTOL, atol=ATOL)


def test_batcher_pad_pow2_off_uses_exact_k():
    coo = _matrices()["uniform"]
    seen = []

    def probe(_mat, X):
        seen.append(X.shape[1])
        return M.spmm_ref(_mat, X)

    b = M.RequestBatcher(coo_to_csr(coo), max_batch=8, pad_pow2=False,
                         spmm_fn=probe)
    rng = np.random.default_rng(29)
    xs = [jnp.asarray(rng.standard_normal(coo.shape[1]).astype(np.float32))
          for _ in range(3)]
    rids = [b.submit(x) for x in xs]
    out = b.drain()
    assert seen == [3]                    # exact k, no pow2 padding
    for rid, x in zip(rids, xs):
        np.testing.assert_allclose(np.asarray(out[rid]),
                                   np.asarray(spmv_coo(coo, x)),
                                   rtol=RTOL, atol=ATOL)


def test_batcher_mixed_dtype_queue_promotes():
    """Regression: flush() used to build X with batch[0]'s dtype, silently
    downcasting every later request — a float16 head request truncated its
    float32 neighbours. The batch dtype is now the promotion over the whole
    queue (and batch_spmv mirrors it)."""
    coo = _matrices()["uniform"]
    csr = coo_to_csr(coo)
    seen = []

    def probe(mat, X):
        seen.append(X.dtype)
        return M.spmm_ref(mat, X)

    rng = np.random.default_rng(37)
    x16 = jnp.asarray(rng.standard_normal(coo.shape[1]).astype(np.float16))
    x32 = jnp.asarray(rng.standard_normal(coo.shape[1]).astype(np.float32))
    b = M.RequestBatcher(csr, max_batch=8, spmm_fn=probe)
    r16, r32 = b.submit(x16), b.submit(x32)      # low-precision head
    out = b.flush()
    assert seen == [jnp.float32]
    # the f32 request keeps full precision (f16 truncation would miss)
    np.testing.assert_allclose(np.asarray(out[r32]),
                               np.asarray(spmv_coo(coo, x32)),
                               rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(out[r16]),
                               np.asarray(spmv_coo(coo, x16.astype(
                                   jnp.float32))),
                               rtol=1e-2, atol=1e-2)
    # batch_spmv takes the same promotion path
    seen.clear()
    ys = M.batch_spmv(csr, [x16, x32], spmm_fn=probe)
    assert seen == [jnp.float32]
    np.testing.assert_allclose(np.asarray(ys[1]),
                               np.asarray(spmv_coo(coo, x32)),
                               rtol=RTOL, atol=ATOL)


def test_batch_spmv_spmm_fn_override():
    """batch_spmv routes through a custom spmm_fn (the distributed serve
    path's hook) and still returns per-request results in input order."""
    coo = _matrices()["uniform"]
    csr = coo_to_csr(coo)
    calls = []

    def spmm_fn(mat, X):
        calls.append(X.shape)
        return M.spmm_ref(mat, X)

    rng = np.random.default_rng(31)
    xs = [jnp.asarray(rng.standard_normal(coo.shape[1]).astype(np.float32))
          for _ in range(4)]
    ys = M.batch_spmv(csr, xs, spmm_fn=spmm_fn)
    assert calls == [(coo.shape[1], 4)]
    for x, y in zip(xs, ys):
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(spmv_coo(coo, x)),
                                   rtol=RTOL, atol=ATOL)
