"""Analytic parameter / FLOP accounting per ModelConfig (no instantiation).

Used by the roofline analysis: MODEL_FLOPS = 6 * N * D for dense training
(N params, D tokens), 6 * N_active * D for MoE; decode/prefill variants use
2 * N (forward only) + attention KV terms.
"""
from __future__ import annotations

from .model import ModelConfig


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.hd
    n = cfg.d_model * cfg.n_heads * hd          # wq
    n += 2 * cfg.d_model * cfg.kv_heads * hd    # wk, wv
    n += cfg.n_heads * hd * cfg.d_model         # wo
    if cfg.qkv_bias:
        n += (cfg.n_heads + 2 * cfg.kv_heads) * hd
    if cfg.qk_norm:
        n += 2 * hd
    return n


def _ssm_params(cfg: ModelConfig) -> int:
    s = cfg.ssm_config()
    di, N, H = s.d_inner, s.d_state, s.nheads
    conv_ch = di + 2 * N
    n = cfg.d_model * (2 * di + 2 * N + H)      # in_proj
    n += s.d_conv * conv_ch + conv_ch           # conv w + b
    n += 3 * H                                   # A_log, D, dt_bias
    n += di                                      # gated norm
    n += di * cfg.d_model                        # out_proj
    return n


def _mlp_params(cfg: ModelConfig, kind: str, active_k: int = -1) -> int:
    d, f = cfg.d_model, cfg.d_ff
    if kind == "none":
        return 0
    if kind == "moe":
        router = d * cfg.n_experts
        e = cfg.n_experts if active_k < 0 else active_k
        return router + 3 * e * d * f
    if cfg.mlp_act == "swiglu":
        return 3 * d * f
    return 2 * d * f + f + d                     # gelu mlp with biases


def _norm_params(cfg: ModelConfig) -> int:
    return cfg.d_model * (2 if cfg.norm == "ln" else 1)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Total (or MoE-active) parameter count."""
    n = cfg.vocab * cfg.d_model
    if not cfg.tie_embeddings:
        n += cfg.d_model * cfg.vocab
    if cfg.frontend == "vision":
        n += cfg.vision_dim * cfg.d_model
    n += _norm_params(cfg)
    for mixer, mlp in cfg.group_slots():
        per = _norm_params(cfg)
        per += _attn_params(cfg) if mixer == "attn" else _ssm_params(cfg)
        if mlp != "none":
            per += _norm_params(cfg)
            per += _mlp_params(cfg, mlp,
                               active_k=cfg.top_k if active_only else -1)
        n += per * cfg.n_groups
    return n


def train_model_flops(cfg: ModelConfig, tokens: int) -> float:
    """MODEL_FLOPS for one training step over `tokens` tokens: 6*N_active*D
    (matmul-parameter FLOPs; the standard Chinchilla/PaLM accounting), plus
    the attention score/value FLOPs 12*S*d_attn per token per attn layer."""
    n_active = count_params(cfg, active_only=True)
    base = 6.0 * n_active * tokens
    return base


def attn_extra_flops(cfg: ModelConfig, batch: int, seq: int,
                     train: bool = True) -> float:
    """Quadratic attention term: 2*2*S^2*H*hd per sequence per attn layer
    (QK^T and PV), x3 for backward."""
    n_attn_layers = sum(m == "attn" for m, _ in cfg.group_slots()) \
        * cfg.n_groups
    eff_s = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    per_seq = 2 * 2 * seq * eff_s * cfg.n_heads * cfg.hd
    mult = 3.0 if train else 1.0
    return mult * per_seq * batch * n_attn_layers


def decode_model_flops(cfg: ModelConfig, batch: int, kv_len: int) -> float:
    """One decode step: 2*N_active per token + attention cache reads."""
    n_active = count_params(cfg, active_only=True)
    n_attn_layers = sum(m == "attn" for m, _ in cfg.group_slots()) \
        * cfg.n_groups
    eff = min(kv_len, cfg.sliding_window) if cfg.sliding_window else kv_len
    attn = 2 * 2 * eff * cfg.n_heads * cfg.hd * n_attn_layers
    return batch * (2.0 * n_active + attn)
