"""Roofline terms from compiled dry-run artifacts (TPU v5e targets).

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

cost_analysis() of the SPMD-partitioned executable reports *per-device*
FLOPs/bytes, so the per-chip terms divide by one chip's peaks directly.
collective_bytes is parsed from the post-optimization HLO text: we sum the
output bytes of every collective op (all-reduce counted twice — ring
all-reduce moves 2(g-1)/g x size; the (g-1)/g ≈ 1 approximation is applied
to every op kind)."""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

# TPU v5e hardware constants (per chip / per link)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64"
                       r"|u64|f64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: {'bytes': Σ output bytes, 'count': n}.
    Works on post-optimization HLO (sync or -start async forms)."""
    out = {k: {"bytes": 0.0, "count": 0} for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        for kind in COLLECTIVE_OPS:
            # match "<op>(" or "<op>-start(" as the instruction name
            if f" {kind}(" in line or f" {kind}-start(" in line:
                lhs = line.split("=", 1)[1]
                op_pos = lhs.find(kind)
                shapes = _SHAPE_RE.findall(lhs[:op_pos])
                nbytes = sum(_shape_bytes(d, s) for d, s in shapes)
                out[kind]["bytes"] += nbytes
                out[kind]["count"] += 1
                break
    return out


def collective_bytes_total(parsed: Dict[str, Dict[str, float]]) -> float:
    total = 0.0
    for kind, rec in parsed.items():
        mult = 2.0 if kind == "all-reduce" else 1.0
        total += mult * rec["bytes"]
    return total


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    model_flops: float = 0.0          # analytic 6*N_active*D (global)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline lower bound on step time = max of the three terms
        (perfect overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips): how much compiled compute is
        'useful' (catches remat/redundancy waste)."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU at the roofline bound: useful FLOPs / (chips x
        peak x step_time)."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS_BF16 * t)

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


# --------------------------------------------------------------------------
# SpMM (multi-RHS) roofline terms — used by repro.spmm to pick the k-tile
# and by benchmarks/spmm_sweep.py to print prediction next to measurement.
# --------------------------------------------------------------------------
def ridge_intensity(peak_flops: float = PEAK_FLOPS_BF16,
                    hbm_bw: float = HBM_BW) -> float:
    """FLOP/byte at the roofline ridge: intensity beyond this is
    compute-bound and more RHS reuse buys nothing."""
    return peak_flops / hbm_bw


def csr_stream_bytes(nnz: int, m: int, dtype_bytes: int = 4) -> int:
    """Ideal CSR matrix-stream footprint of one multiply: values + column
    indices + row pointer. The single source of truth for the traffic model
    (shared by choose_k_tile, the selector's k-scaling and the sweep)."""
    return nnz * (4 + dtype_bytes) + 4 * (m + 1)


def spmm_arithmetic_intensity(nnz: int, m: int, n: int, k: int,
                              matrix_bytes: Optional[int] = None,
                              dtype_bytes: int = 4) -> float:
    """Modelled FLOP/byte of one SpMM with k right-hand sides: every
    streamed matrix byte is reused across k columns, so intensity grows
    monotonically in k toward 2*nnz/(m+n)/dtype_bytes. ``matrix_bytes``
    defaults to the ideal CSR footprint."""
    if matrix_bytes is None:
        matrix_bytes = csr_stream_bytes(nnz, m, dtype_bytes)
    flops = 2.0 * nnz * k
    traffic = matrix_bytes + k * (m + n) * dtype_bytes
    return flops / max(traffic, 1)


def spmm_roofline_gflops(ai: float, peak_flops: float = PEAK_FLOPS_BF16,
                         hbm_bw: float = HBM_BW) -> float:
    """Attainable GFLOP/s at arithmetic intensity ``ai``."""
    return min(peak_flops, ai * hbm_bw) / 1e9


# --------------------------------------------------------------------------
# Distributed SpMM traffic model — used by core.selector.select_distributed
# and core.autotune(num_devices=) to score (format x schedule x k) jointly.
# --------------------------------------------------------------------------
def spmm_touched_fraction(n: int, nnz: int, num_devices: int = 1) -> float:
    """Modelled fraction of the ``n`` X rows one *data* shard's compacted
    gather reads: a shard holding ``nnz / P`` nonzeros touches at most that
    many distinct columns (and never more than ``n``) — the exactly
    nnz-proportional bound the ``compact_x`` traffic term prices when no
    measured per-shard ``n_touched`` is supplied."""
    if n <= 0:
        return 0.0
    P = max(int(num_devices), 1)
    return min(float(nnz) / P, float(n)) / float(n)


def spmm_distributed_traffic(m: int, n: int, k: int, num_devices: int,
                             schedule: str,
                             matrix_bytes: Optional[float] = None,
                             nnz: int = 0, dtype_bytes: int = 4,
                             max_row_nnz: int = 0, model_devices: int = 1,
                             compact_x: bool = False,
                             n_touched: Optional[float] = None,
                             op: str = "N",
                             structure: str = "general"
                             ) -> Tuple[float, float]:
    """(per-device HBM bytes, per-device collective bytes) of one k-RHS
    distributed SpMM under the two paper schedules.

    * ``"row"`` (BCOH banding): the slowest shard streams
      max(matrix_bytes/P, the dense-row footprint) — static banding never
      splits a row, so one mawi-style row lower-bounds the critical shard.
      X is fully replicated (every device reads all n*k X bytes per
      multiply — the paper's interleaved allocation priced honestly), Y is
      written shard-locally (~m/P rows). Zero collective bytes.

    * ``"merge"`` (equal-nnz spans): perfect nnz balance (matrix_bytes/P
      even with a dense row), but every device writes a full [m, k] partial
      and the carry-out fixup is an all-reduce on Y — 2*(P-1)/P*m*k bytes
      on the ring, ≈ 2*m*k (the same approximation ``collective_bytes_total``
      applies to compiled HLO). The bytes price the TRUE k: the kernel
      slices the k-tile padding (kp - k columns) off before the collective,
      so model and wire agree. Chunking the fixup does not change the bytes
      — only when they are paid; see ``spmm_distributed_collective_s``.

    ``num_devices`` counts the DATA mesh axis. ``model_devices > 1`` prices
    the 2-D (data, model) mesh of ``repro.spmm.distributed``: the X/Y
    k-slabs are column-sharded across ``model``, so every k-proportional
    term — the replicated-X read, the Y write, and the merge psum — divides
    by ``P_model`` exactly, while the matrix stream (replicated along
    ``model``) and the dense-row floor do not. Total devices are
    ``num_devices * model_devices``. The bytes price the ideal
    ``k / P_model`` column share; the executable's k_tile-aligned column
    split can ship up to ``k_tile * P_model`` extra padding columns —
    negligible at the k ≫ 128 sizes the model axis exists for.

    ``compact_x=True`` prices the sparsity-aware gather of
    ``repro.spmm.distributed``: each data shard reads only the X rows its
    nonzeros name, so the X term becomes ``min(n_touched, n) * kc``
    bytes — exactly nnz-proportional via :func:`spmm_touched_fraction`
    when no measured per-shard mean ``n_touched`` is supplied, and never
    above the replicated figure (near-dense columns cap at ``n``, where
    the gather is a wash and the selector keeps replication). The int32
    map read and the convert-time relabel are priced by
    ``ShardedSellCS.storage_bytes``, not per multiply — like the k-tile
    padding, they are below the model's resolution.

    ``op='T'`` prices ``Y = A^T X`` over the same stored stream: X is read
    in slot space (a dense ``m * kc`` read — the σ-permutation gather was
    paid when X entered slot order, and ``compact_x`` cannot shrink it),
    every data shard scatters a full ``[n, kc]`` column partial, and BOTH
    schedules pay a carry-out collective on it — column ownership is never
    banded, so the transpose adds ``2 * n * kc`` all-reduce bytes even to
    "row" (whose normal fixup is free). Under ``compact_x`` the partial
    lives in the shard's touched-column space instead: ``n`` shrinks to the
    touched count in the Y and wire terms (the stacked per-shard outputs
    are gathered and scatter-added once, not all-reduced).

    ``structure='symmetric'`` prices one-triangle storage (``m == n``
    required): the streamed matrix halves (plus a dense ``m`` diagonal) and
    the multiply pays the collectives of BOTH passes — the stored triangle
    must be carried out in row space (the N fixup) and column space (the T
    scatter fixup). The HBM vector terms are priced once: the model prices
    the fused one-pass ideal (each stored byte emits both contributions);
    the executable two-pass combine re-reads X — a gap the residual ledger
    measures rather than the model hiding the halved stream. ``op`` is
    moot under symmetry (``A^T == A``).

    ``num_devices == 1`` degrades to the single-device stream for both
    (per model shard when ``model_devices > 1``: full matrix stream, a
    ``k / P_model`` column slab, no collective — the psum axis is trivial).
    """
    if schedule not in ("row", "merge"):
        raise ValueError(f"schedule must be 'row' or 'merge', got "
                         f"{schedule!r}")
    if op not in ("N", "T"):
        raise ValueError(f"op must be 'N' or 'T', got {op!r}")
    if structure not in ("general", "symmetric"):
        raise ValueError(f"structure must be 'general' or 'symmetric', "
                         f"got {structure!r}")
    if matrix_bytes is None:
        matrix_bytes = float(csr_stream_bytes(nnz, m, dtype_bytes))
    if structure == "symmetric":
        if m != n:
            raise ValueError(f"structure='symmetric' needs a square "
                             f"matrix, got {m}x{n}")
        half = 0.5 * float(matrix_bytes) + float(m) * dtype_bytes
        hbm, coll_n = spmm_distributed_traffic(
            m, n, k, num_devices, schedule, matrix_bytes=half, nnz=nnz,
            dtype_bytes=dtype_bytes, max_row_nnz=max_row_nnz,
            model_devices=model_devices, compact_x=compact_x,
            n_touched=n_touched, op="N")
        _, coll_t = spmm_distributed_traffic(
            m, n, k, num_devices, schedule, matrix_bytes=half, nnz=nnz,
            dtype_bytes=dtype_bytes, max_row_nnz=max_row_nnz,
            model_devices=model_devices, compact_x=compact_x,
            n_touched=n_touched, op="T")
        return hbm, coll_n + coll_t
    P = max(int(num_devices), 1)
    Pm = max(int(model_devices), 1)
    kc = float(k) / Pm                   # X/Y columns owned per model shard
    if op == "T":
        x_bytes = float(m) * kc * dtype_bytes      # dense slot-space read
        if P == 1:
            return (matrix_bytes + x_bytes
                    + float(n) * kc * dtype_bytes), 0.0
        stream = matrix_bytes / P
        if schedule == "row":
            # banding splits the stream but not column ownership; the
            # dense-row floor still binds the critical shard's stream
            stream = max(stream, float(max_row_nnz) * (4 + dtype_bytes))
        if compact_x:
            nt = (min(float(n_touched), float(n)) if n_touched is not None
                  else spmm_touched_fraction(n, nnz, P) * float(n))
            # touched-space partial, gathered + scatter-added once
            return stream + x_bytes + nt * kc * dtype_bytes, \
                nt * kc * dtype_bytes
        y_bytes = float(n) * kc * dtype_bytes      # full column partial
        return stream + x_bytes + y_bytes, 2.0 * float(n) * kc * dtype_bytes
    if compact_x:
        nt = (min(float(n_touched), float(n)) if n_touched is not None
              else spmm_touched_fraction(n, nnz, P) * float(n))
        x_bytes = nt * kc * dtype_bytes
    else:
        x_bytes = float(n) * kc * dtype_bytes
    if P == 1:
        return matrix_bytes + x_bytes + float(m) * kc * dtype_bytes, 0.0
    if schedule == "row":
        stream = max(matrix_bytes / P,
                     float(max_row_nnz) * (4 + dtype_bytes))
        y_bytes = (float(m) / P) * kc * dtype_bytes
        return stream + x_bytes + y_bytes, 0.0
    stream = matrix_bytes / P
    y_bytes = float(m) * kc * dtype_bytes         # full partial per device
    psum_bytes = 2.0 * float(m) * kc * dtype_bytes
    return stream + x_bytes + y_bytes, psum_bytes


# Fixed cost of issuing one collective (launch + ring sync). Keeps the
# chunked model honest: more chunks shrink the exposed wire time but pay
# this per psum, so the modelled optimum is interior, not "always max".
COLLECTIVE_LAUNCH_S = 1e-6


def spmm_distributed_collective_s(m: int, n: int, k: int, num_devices: int,
                                  schedule: str,
                                  matrix_bytes: Optional[float] = None,
                                  nnz: int = 0, dtype_bytes: int = 4,
                                  max_row_nnz: int = 0, num_chunks: int = 1,
                                  hbm_bw: float = HBM_BW,
                                  link_bw: float = ICI_LINK_BW,
                                  model_devices: int = 1,
                                  compact_x: bool = False,
                                  n_touched: Optional[float] = None,
                                  op: str = "N",
                                  structure: str = "general") -> float:
    """EXPOSED collective seconds of one distributed multiply — the part of
    the wire time that does not hide under the slice stream.

    Monolithic (``num_chunks = 1``): the whole all-reduce serializes after
    all local compute, so everything is exposed (plus one launch).

    Chunked (``num_chunks = c``): the slice stream is split into c spans
    and each span's psum is issued while the next span computes — the
    standard communication/computation overlap of distributed-memory SpMV
    (Eckstein & Mátyásfalvi, arXiv:1812.00904). Per-chunk wire time
    ``tl = coll_s/c + launch`` overlaps per-chunk compute ``tc = hbm_s/c``;
    the pipeline exposes ``(c-1) * max(0, tl - tc) + tl``: the last chunk's
    collective always drains after the stream ends, earlier chunks only
    leak what compute cannot cover.
    """
    if num_chunks < 1:
        raise ValueError(f"num_chunks must be >= 1, got {num_chunks}")
    hbm, coll = spmm_distributed_traffic(
        m, n, k, num_devices, schedule, matrix_bytes=matrix_bytes, nnz=nnz,
        dtype_bytes=dtype_bytes, max_row_nnz=max_row_nnz,
        model_devices=model_devices, compact_x=compact_x,
        n_touched=n_touched, op=op, structure=structure)
    if coll <= 0.0:
        return 0.0                    # "row" / single device: no wire time
    c = int(num_chunks)
    tl = coll / link_bw / c + COLLECTIVE_LAUNCH_S
    tc = (hbm / hbm_bw) / c
    return (c - 1) * max(0.0, tl - tc) + tl


def spmm_distributed_gather_s(m: int, n: int, k: int, num_devices: int,
                              schedule: str,
                              matrix_bytes: Optional[float] = None,
                              nnz: int = 0, dtype_bytes: int = 4,
                              max_row_nnz: int = 0, num_chunks: int = 1,
                              hbm_bw: float = HBM_BW,
                              model_devices: int = 1,
                              compact_x: bool = False,
                              n_touched: Optional[float] = None,
                              op: str = "N",
                              structure: str = "general",
                              gather: str = "upfront") -> float:
    """EXPOSED gather seconds of one distributed multiply — the serialized
    latency of building the compact-X ``[n_touched, kc]`` slab that does
    not hide under the slice stream.

    The slab build reads the touched X rows and writes them back
    (``t_g = 2 * n_touched * kc * dtype_bytes / hbm_bw``); how much of it
    lands on the critical path depends on the schedule:

    * ``"upfront"``: one monolithic ``x_pad[col_map]`` ahead of the mesh
      region — fully exposed before the first kernel launch.
    * ``"overlap"`` (chunked merge only): each span rebuilds its own piece
      of the slab inside the span loop, so span i+1's gather hides under
      span i's kernel — exposed is span 0's share plus whatever per-span
      compute cannot cover: ``t_g/c + (c-1) * max(0, t_g/c - tc)`` with
      ``tc = (hbm_s)/c``, mirroring the psum pipeline model of
      :func:`spmm_distributed_collective_s`. Where the executable
      degenerates to up-front (row schedule, ``num_chunks == 1``), so does
      the price.
    * ``"fused"``: ``col_map`` rides the kernel's scalar prefetch and the
      stream indexes the full X directly — no slab, nothing exposed.

    Zero when the partition is not compact or ``op='T'`` (the transpose
    path has no X gather: X enters slot-permuted). By construction
    ``fused <= overlap <= upfront`` for any inputs, so a strict-< selector
    keeps ``upfront`` on ties.
    """
    if gather not in ("upfront", "overlap", "fused"):
        raise ValueError(f"gather must be 'upfront', 'overlap' or 'fused', "
                         f"got {gather!r}")
    if not compact_x or op == "T" or gather == "fused":
        return 0.0
    P = max(int(num_devices), 1)
    Pm = max(int(model_devices), 1)
    kc = float(k) / Pm
    nt = (min(float(n_touched), float(n)) if n_touched is not None
          else spmm_touched_fraction(n, nnz, P) * float(n))
    t_g = 2.0 * nt * kc * dtype_bytes / hbm_bw
    c = int(num_chunks)
    if gather == "overlap" and schedule == "merge" and c > 1:
        hbm, _ = spmm_distributed_traffic(
            m, n, k, num_devices, schedule, matrix_bytes=matrix_bytes,
            nnz=nnz, dtype_bytes=dtype_bytes, max_row_nnz=max_row_nnz,
            model_devices=model_devices, compact_x=compact_x,
            n_touched=n_touched, op=op, structure=structure)
        tc = (hbm / hbm_bw) / c
        return t_g / c + (c - 1) * max(0.0, t_g / c - tc)
    return t_g


def spmm_distributed_time(m: int, n: int, k: int, num_devices: int,
                          schedule: str,
                          matrix_bytes: Optional[float] = None,
                          nnz: int = 0, dtype_bytes: int = 4,
                          max_row_nnz: int = 0, num_chunks: int = 1,
                          hbm_bw: float = HBM_BW,
                          link_bw: float = ICI_LINK_BW,
                          model_devices: int = 1,
                          compact_x: bool = False,
                          n_touched: Optional[float] = None,
                          op: str = "N",
                          structure: str = "general",
                          gather: str = "upfront") -> float:
    """Modelled seconds per distributed multiply: HBM term + the *exposed*
    collective term + the *exposed* gather term. ``num_chunks = 1`` keeps
    the PR-2 no-overlap model (both terms on the Y critical path, plus one
    launch); ``num_chunks > 1`` prices the pipelined fixup of
    ``spmm_merge_distributed(num_chunks=)``; ``model_devices > 1`` prices
    the 2-D (data, model) mesh (k-proportional terms divide by
    ``P_model``); ``compact_x=True`` prices the sparsity-aware X gather
    (the X term becomes nnz-proportional — ``n_touched`` supplies a
    measured per-shard mean) with ``gather=`` scheduling its exposed
    latency (see :func:`spmm_distributed_gather_s`); ``op='T'`` prices the
    transpose scatter fixup; ``structure='symmetric'`` the one-triangle
    stream (see :func:`spmm_distributed_traffic`)."""
    hbm, _ = spmm_distributed_traffic(
        m, n, k, num_devices, schedule, matrix_bytes=matrix_bytes, nnz=nnz,
        dtype_bytes=dtype_bytes, max_row_nnz=max_row_nnz,
        model_devices=model_devices, compact_x=compact_x,
        n_touched=n_touched, op=op, structure=structure)
    return hbm / hbm_bw + spmm_distributed_collective_s(
        m, n, k, num_devices, schedule, matrix_bytes=matrix_bytes, nnz=nnz,
        dtype_bytes=dtype_bytes, max_row_nnz=max_row_nnz,
        num_chunks=num_chunks, hbm_bw=hbm_bw, link_bw=link_bw,
        model_devices=model_devices, compact_x=compact_x,
        n_touched=n_touched, op=op, structure=structure
    ) + spmm_distributed_gather_s(
        m, n, k, num_devices, schedule, matrix_bytes=matrix_bytes, nnz=nnz,
        dtype_bytes=dtype_bytes, max_row_nnz=max_row_nnz,
        num_chunks=num_chunks, hbm_bw=hbm_bw,
        model_devices=model_devices, compact_x=compact_x,
        n_touched=n_touched, op=op, structure=structure, gather=gather)


def from_compiled(compiled, chips: int, model_flops: float = 0.0,
                  hlo_text: Optional[str] = None) -> Roofline:
    """Roofline terms via the trip-count-aware HLO parser (hlo_parse).
    XLA's own cost_analysis() counts while bodies once — wrong for a
    scanned-layer model — so it is recorded only as a cross-check."""
    from . import hlo_parse
    text = hlo_text if hlo_text is not None else compiled.as_text()
    parsed = hlo_parse.analyze(text)
    return Roofline(flops_per_device=parsed["flops"],
                    bytes_per_device=parsed["bytes"],
                    collective_bytes_per_device=parsed["collective_bytes"],
                    chips=chips, model_flops=model_flops)
