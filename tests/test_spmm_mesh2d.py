"""2-D (data, model) mesh SpMM (repro.spmm.distributed) on 8 host-platform
devices: ISSUE 4 acceptance — both schedules over meshes (8,1), (4,2) and
(2,4) match the single-device oracle and the 1-D path for k in {8, 64, 256}
(mawi dense row included), under the jnp reference body and the Pallas
kernel body in interpret mode, and the traffic model prices the model axis
as an exact P_model division of the collective and replicated-X bytes.

Device-backed tests run in SUBPROCESSES (the device-count flag must be set
before jax initializes; the rest of the suite keeps seeing 1 device).
Model / validation tests are pure host code and run in-process.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_mesh2d_matches_oracle_and_1d_ref():
    """ISSUE 4 acceptance: meshes (8,1), (4,2), (2,4), k in {8, 64, 256},
    uniform + mawi dense-row, both schedules plus the chunked merge, all
    equal to the single-device spmm oracle — and the 2-D results equal the
    1-D (8,1) results to fp tolerance. The row schedule is compared
    tightly (the model axis only splits columns; per-column sums are
    identical), the merge schedule at oracle tolerance (a different
    P_data means a different psum summation order)."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.data import matrices
from repro.launch.mesh import make_spmm_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_coo,
                        spmm_merge_distributed, spmm_row_distributed)
for name, gen in [("uniform", matrices.uniform(500, 430, 4000, 0)),
                  ("mawi_like", matrices.mawi_like(400, 400, 3000, 0.4, 1))]:
    coo = to_coo(*gen)
    sc = coo_to_sellcs(coo, c=16, sigma=64)
    base = {}                    # (schedule, k) -> the 1-D (8,1) result
    for pd, pm in [(8, 1), (4, 2), (2, 4)]:
        mesh = make_spmm_mesh((pd, pm))
        row = partition_sellcs_rows(sc, pd)
        mrg = partition_sellcs_nnz(sc, pd)
        for k in (8, 64, 256):
            X = jnp.asarray(np.random.default_rng(k).standard_normal(
                (coo.shape[1], k)).astype(np.float32))
            yo = np.asarray(spmm_coo(coo, X))
            yr = np.asarray(spmm_row_distributed(row, X, mesh))
            ym = np.asarray(spmm_merge_distributed(mrg, X, mesh))
            yc = np.asarray(spmm_merge_distributed(mrg, X, mesh,
                                                   num_chunks=3))
            for tag, y in [("row", yr), ("merge", ym), ("chunked", yc)]:
                np.testing.assert_allclose(
                    y, yo, rtol=1e-5, atol=1e-4,
                    err_msg=f"{name} {tag} {pd}x{pm} k={k}")
            if pm == 1:
                base[("row", k)], base[("merge", k)] = yr, ym
            else:
                np.testing.assert_allclose(yr, base[("row", k)], rtol=1e-6,
                                           atol=1e-5, err_msg=f"{name} row")
                np.testing.assert_allclose(ym, base[("merge", k)],
                                           rtol=1e-5, atol=1e-4,
                                           err_msg=f"{name} merge")
    print(name, "mesh2d oracle OK")
"""))


def test_mesh2d_pallas_interpret_kernel_body():
    """The same PR-1 k-tiled Pallas kernel runs inside each (data, model)
    shard (interpret mode off-TPU): every mesh shape, k in {8, 64, 256},
    mawi dense row, monolithic and chunked merge."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.data import matrices
from repro.launch.mesh import make_spmm_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_coo,
                        spmm_merge_distributed, spmm_row_distributed)
coo = to_coo(*matrices.mawi_like(300, 280, 2400, 0.4, 3))
sc = coo_to_sellcs(coo, c=16, sigma=64)
for pd, pm in [(8, 1), (4, 2), (2, 4)]:
    mesh = make_spmm_mesh((pd, pm))
    row = partition_sellcs_rows(sc, pd)
    mrg = partition_sellcs_nnz(sc, pd)
    for k in (8, 64, 256):
        X = jnp.asarray(np.random.default_rng(k).standard_normal(
            (coo.shape[1], k)).astype(np.float32))
        yo = np.asarray(spmm_coo(coo, X))
        yr = np.asarray(spmm_row_distributed(
            row, X, mesh, impl="pallas_interpret", k_tile=4))
        ym = np.asarray(spmm_merge_distributed(
            mrg, X, mesh, impl="pallas_interpret", k_tile=4, num_chunks=2))
        np.testing.assert_allclose(yr, yo, rtol=1e-5, atol=1e-4,
                                   err_msg=f"row {pd}x{pm} k={k}")
        np.testing.assert_allclose(ym, yo, rtol=1e-5, atol=1e-4,
                                   err_msg=f"merge {pd}x{pm} k={k}")
    print(pd, pm, "interpret OK")
"""))


def test_mesh2d_k_smaller_than_model_axis():
    """Degenerate column split: k < P_model still answers correctly (some
    model shards own only padding columns), including the k = 1 SpMV ride-
    along."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.data import matrices
from repro.launch.mesh import make_spmm_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_coo,
                        spmm_merge_distributed, spmm_row_distributed)
coo = to_coo(*matrices.uniform(200, 180, 1500, 7))
sc = coo_to_sellcs(coo, c=16, sigma=64)
mesh = make_spmm_mesh((2, 4))
row = partition_sellcs_rows(sc, 2)
mrg = partition_sellcs_nnz(sc, 2)
for k in (1, 2, 3):
    X = jnp.asarray(np.random.default_rng(k).standard_normal(
        (coo.shape[1], k)).astype(np.float32))
    yo = np.asarray(spmm_coo(coo, X))
    np.testing.assert_allclose(np.asarray(spmm_row_distributed(
        row, X, mesh)), yo, rtol=1e-5, atol=1e-4, err_msg=f"k={k}")
    np.testing.assert_allclose(np.asarray(spmm_merge_distributed(
        mrg, X, mesh)), yo, rtol=1e-5, atol=1e-4, err_msg=f"k={k}")
x = jnp.asarray(np.random.default_rng(9).standard_normal(
    coo.shape[1]).astype(np.float32))
y = spmm_row_distributed(row, x, mesh)
assert y.ndim == 1
np.testing.assert_allclose(np.asarray(y), np.asarray(spmm_coo(coo, x)),
                           rtol=1e-5, atol=1e-4)
print("k < P_model OK")
"""))


# --------------------------------------------------------------------------
# Host-side: axis validation and the 2-D traffic model
# --------------------------------------------------------------------------
def test_model_axis_validation():
    import jax
    import numpy as np
    from repro.launch.mesh import make_mesh
    from repro.spmm import (coo_to_sellcs, partition_sellcs_rows,
                            spmm_row_distributed)
    from repro.core import to_coo
    if len(jax.devices()) != 1:
        return                       # in-process guard only needs 1 device
    coo = to_coo(np.array([0], np.int32), np.array([0], np.int32),
                 np.ones(1, np.float32), (2, 2))
    sc = coo_to_sellcs(coo, c=2)
    sharded = partition_sellcs_rows(sc, 1)
    mesh = make_mesh((1,), ("data",))
    X = np.ones((2, 3), np.float32)
    with pytest.raises(ValueError, match="model_axis"):
        spmm_row_distributed(sharded, X, mesh, model_axis="model")
    with pytest.raises(ValueError, match="collides"):
        spmm_row_distributed(sharded, X, mesh, model_axis="data")


def test_traffic_model_model_axis_divides_k_terms_exactly():
    """ISSUE 4 acceptance: collective bytes drop by exactly P_model, and
    so do the replicated-X read bytes; the matrix stream and the dense-row
    floor do not."""
    from repro.roofline import (spmm_distributed_time,
                                spmm_distributed_traffic)
    m = n = 100_000
    nnz = 10_000_000
    for pm in (2, 4, 8):
        _, coll1 = spmm_distributed_traffic(m, n, 256, 8, "merge", nnz=nnz)
        _, collm = spmm_distributed_traffic(m, n, 256, 8, "merge", nnz=nnz,
                                            model_devices=pm)
        assert coll1 / collm == pytest.approx(pm), pm
    # the X term: row schedule on a dense-row matrix — the stream floor is
    # pinned by the dense row, so the HBM delta between Pm=1 and Pm=pm is
    # exactly the (1 - 1/pm) replicated-X + Y saving
    hot = nnz // 2
    dt = 4
    hbm1, _ = spmm_distributed_traffic(m, n, 256, 8, "row", nnz=nnz,
                                       max_row_nnz=hot)
    hbm2, _ = spmm_distributed_traffic(m, n, 256, 8, "row", nnz=nnz,
                                       max_row_nnz=hot, model_devices=2)
    saved = (n * 256 * dt + (m / 8) * 256 * dt) / 2
    assert hbm1 - hbm2 == pytest.approx(saved, rel=1e-12)
    # at k >> 128 the model axis pays; at k = 1 the shallower stream split
    # makes it lose (uniform matrix)
    t1 = spmm_distributed_time(m, n, 1024, 8, "merge", nnz=nnz)
    t2 = spmm_distributed_time(m, n, 1024, 4, "merge", nnz=nnz,
                               model_devices=2)
    assert t2 < t1
    assert spmm_distributed_time(m, n, 1, 4, "merge", nnz=nnz,
                                 model_devices=2) > \
        spmm_distributed_time(m, n, 1, 8, "merge", nnz=nnz)


def test_mesh_factorizations_and_grid():
    from repro.core import mesh_factorizations
    from repro.core.selector import distributed_schedule_grid
    assert mesh_factorizations(8) == [(8, 1), (4, 2), (2, 4), (1, 8)]
    assert mesh_factorizations(1) == [(1, 1)]
    with pytest.raises(ValueError):
        mesh_factorizations(0)
    grid = distributed_schedule_grid(8)
    assert ("row", 1, (4, 2)) in grid and ("merge", 4, (2, 4)) in grid
    assert all(nc == 1 for s, nc, _ in grid if s == "row")
    pinned = distributed_schedule_grid(8, pinned_mesh=(4, 2))
    assert {mesh for _, _, mesh in pinned} == {(4, 2)}


def test_select_distributed_mesh_shape_recorded():
    """The joint grid records the winning (P_data, P_model): small k keeps
    the pure-data mesh (stream-split dominated), k >> 128 moves the win to
    a model-sharded shape; a pinned mesh_shape is honored."""
    from repro.core import select_distributed
    from repro.core.selector import MatrixStats
    uni = MatrixStats(m=230_000, n=230_000, nnz=270_000_000,
                      max_row_nnz=2_000, row_var=10.0)
    small = select_distributed(uni, k=1, num_devices=8)
    assert small.mesh_shape == (8, 1)
    big = select_distributed(uni, k=4096, num_devices=8)
    assert big.mesh_shape[1] > 1
    assert big.mesh_shape[0] * big.mesh_shape[1] == 8
    pinned = select_distributed(uni, k=4096, num_devices=8,
                                mesh_shape=(8, 1))
    assert pinned.mesh_shape == (8, 1)
