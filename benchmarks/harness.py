"""Benchmark harness: min-of-N timing (the paper times 550 executions and
reports the minimum, §5.2 — we use the same protocol with fewer reps on the
1-core container) + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, Iterable, List

import jax


def time_fn(fn: Callable, *args, reps: int = 20, warmup: int = 3) -> float:
    """Min wall time in seconds of fn(*args) (jax outputs block)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def time_host(fn: Callable, *args, reps: int = 5) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


class Csv:
    def __init__(self, title: str):
        self.title = title
        self.rows: List[str] = []
        print(f"# === {title} ===")
        print("name,us_per_call,derived")

    def row(self, name: str, seconds: float, derived: str = ""):
        line = f"{name},{seconds * 1e6:.1f},{derived}"
        self.rows.append(line)
        print(line)
