"""Paper Tables 6.4 / 6.5 analogue: storage-format conversion cost,
expressed as the number of ParCRS SpMV multiplications it equals (the
paper's break-even currency), plus the TiledSparse (TPU compute format)
conversion for the kernels path.

Standalone CLI (also driven by ``benchmarks.run``):
  PYTHONPATH=src python -m benchmarks.conversion --scale 0.05 --json out.json
"""
from __future__ import annotations

import argparse

import numpy as np
import jax.numpy as jnp

from repro.core import ALGORITHM_SPECS, convert, coo_to_csr, spmv, to_coo
from repro.core.selector import break_even_spmvs
from repro.data import matrices
from repro.kernels import coo_to_tiled

from .harness import Csv, time_fn, time_host

ALGOS = ["parcrs", "merge", "csb", "csbh", "bcoh", "bcohc", "bcohch",
         "bcohchp", "mergeb", "mergebh"]


def run(csv=None, suite_scale: float = 0.12):
    csv = csv or Csv("Tables 6.4/6.5: conversion cost (in ParCRS SpMVs)")
    suite = matrices.test_suite(suite_scale)
    for name, tm in suite.items():
        coo = to_coo(*tm.make())
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            coo.shape[1]).astype(np.float32))
        csr = coo_to_csr(coo)
        t_spmv = time_fn(lambda: spmv(csr, x, impl="ref"))
        for algo in ALGOS:
            kw = {}
            if ALGORITHM_SPECS[algo].blocked:
                kw = dict(beta=512)
                if ALGORITHM_SPECS[algo].scheduling == "static_rows":
                    kw["num_bands"] = 8
            t_conv = time_host(lambda: convert(coo, algo, **kw), reps=3)
            csv.row(f"convert.{name}.{algo}", t_conv,
                    f"parcrs_spmvs={t_conv / t_spmv:.1f}")
        # TPU compute-format conversion (beyond-paper: the tiling cost)
        t_tiled = time_host(lambda: coo_to_tiled(coo, "csb", beta=512),
                            reps=3)
        csv.row(f"convert.{name}.tiled8x128", t_tiled,
                f"parcrs_spmvs={t_tiled / t_spmv:.1f}")


def run_break_even(csv=None):
    """The paper's §7 arithmetic (472 SpMVs for BCOHC etc.), computed from
    the paper's own priors — validates selector.break_even_spmvs."""
    csv = csv or Csv("Break-even SpMV counts (paper §7 priors)")
    for algo, numa, low in [("bcohc", True, False), ("bcohch", True, False),
                            ("csb", False, True), ("csbh", False, True)]:
        n = break_even_spmvs(algo, numa_like=numa, low_density=low)
        csv.row(f"break_even.{algo}.{'numa' if numa else 'uma'}", 0.0,
                f"spmvs_to_amortize={n:.0f}")


def main(argv=None) -> None:
    from .harness import dump_json, reset_records
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.12,
                    help="matrix suite scale factor")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump all rows as JSON (harness schema)")
    ap.add_argument("--skip-break-even", action="store_true")
    args = ap.parse_args(argv)
    reset_records()
    run(suite_scale=args.scale)
    if not args.skip_break_even:
        run_break_even()
    if args.json:
        dump_json(args.json)


if __name__ == "__main__":
    main()
