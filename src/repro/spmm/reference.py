"""Pure-jnp SpMM oracles (``Y = A @ X``, ``X: [n, k]``).

These are the correctness baselines for every format's multi-RHS multiply
and the XLA fallback the dispatcher uses off-TPU. Each is the column-wise
generalization of the corresponding ``repro.core.spmv`` oracle: SpMV is
exactly the ``k = 1`` column of each of these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import COO, CSR, BlockedSparse
from .sellcs import SellCS

Array = jax.Array


def _as_2d(x: Array):
    """Return (X_2d, was_1d): SpMV inputs ride along as k = 1."""
    if x.ndim == 1:
        return x[:, None], True
    if x.ndim != 2:
        raise ValueError(f"X must be [n] or [n, k], got shape {x.shape}")
    return x, False


@jax.jit
def spmm_coo(coo: COO, x: Array) -> Array:
    x2, squeeze = _as_2d(x)
    m, _ = coo.shape
    k = x2.shape[1]
    dtype = jnp.promote_types(coo.data.dtype, x2.dtype)
    y = jnp.zeros((m, k), dtype)
    if coo.nnz:
        y = y.at[coo.rows].add(coo.data[:, None] * x2[coo.cols])
    return y[:, 0] if squeeze else y


@jax.jit
def spmm_csr(csr: CSR, x: Array) -> Array:
    x2, squeeze = _as_2d(x)
    m, _ = csr.shape
    k = x2.shape[1]
    dtype = jnp.promote_types(csr.data.dtype, x2.dtype)
    if csr.nnz == 0:
        y = jnp.zeros((m, k), dtype)
        return y[:, 0] if squeeze else y
    rows = csr.row_of_nnz()
    prod = csr.data[:, None] * x2[csr.col_ind]
    y = jax.ops.segment_sum(prod, rows, num_segments=m).astype(dtype)
    return y[:, 0] if squeeze else y


@jax.jit
def spmm_blocked(bs: BlockedSparse, x: Array) -> Array:
    x2, squeeze = _as_2d(x)
    m, _ = bs.shape
    k = x2.shape[1]
    dtype = jnp.promote_types(bs.data.dtype, x2.dtype)
    if bs.nnz == 0:
        y = jnp.zeros((m, k), dtype)
        return y[:, 0] if squeeze else y
    bid = bs.block_of_nnz()
    lr, lc = bs.local_rows_cols()
    rows = bs.block_rows[bid] * bs.beta + lr
    cols = bs.block_cols[bid] * bs.beta + lc
    prod = bs.data[:, None] * x2[cols]
    y = jax.ops.segment_sum(prod, rows, num_segments=m).astype(dtype)
    return y[:, 0] if squeeze else y


def sellcs_slots_ref(data: Array, cols: Array, slice_of: Array, x2: Array,
                     *, num_slices: int, chunk: int,
                     col_map: Array | None = None) -> Array:
    """Raw-array slot accumulation [num_slices*chunk, k] — the jnp twin of
    ``repro.spmm.kernels.sellcs_slots`` and the XLA body of the distributed
    schedules. No row permutation is applied. With ``col_map`` the stored
    ``cols`` are compact ids mapped through it before indexing ``x2``
    (the fused-gather mode; twin of ``_sellcs_fused_kernel``)."""
    dtype = jnp.promote_types(data.dtype, x2.dtype)
    k = x2.shape[1]
    if col_map is not None:
        cols = col_map[cols]
    xs = x2[cols]                                       # [W, C, k]
    contrib = data[:, :, None] * xs                     # [W, C, k]
    slot = (slice_of[:, None] * chunk
            + jnp.arange(chunk, dtype=jnp.int32)[None])  # [W, C]
    return jnp.zeros((num_slices * chunk, k), dtype).at[slot].add(contrib)


def sellcs_slots_chunk_ref(data: Array, cols: Array, slice_of: Array,
                           x2: Array, *, slice_start: int, num_slices: int,
                           chunk: int, col_map: Array | None = None) -> Array:
    """jnp twin of ``kernels.sellcs_slots_chunk``: slot accumulation over a
    chunk sub-stream whose ``slice_of`` is still global, rebased to the
    chunk-local slot space starting at ``slice_start``."""
    local = jnp.clip(slice_of.astype(jnp.int32) - slice_start, 0,
                     max(num_slices - 1, 0))
    return sellcs_slots_ref(data, cols, local, x2, num_slices=num_slices,
                            chunk=chunk, col_map=col_map)


def sellcs_slot_x(row_perm: Array, x2: Array, m: int) -> Array:
    """Permute X into slot space for the transpose pass: ``x_slots[s] =
    X[row_perm[s]]``, with padding slots (``row_perm == m``) reading a zero
    row. After this gather the transpose kernel's X reads are contiguous
    C-blocks — the structured access moves from X to the output scatter."""
    x_pad = jnp.concatenate(
        [x2, jnp.zeros((1, x2.shape[1]), x2.dtype)], axis=0)
    return x_pad[row_perm]


def sellcs_slots_t_ref(data: Array, cols: Array, slice_of: Array,
                       x_slots: Array, *, n_out: int, chunk: int) -> Array:
    """Transpose slot pass [n_out, k] — the jnp twin of
    ``kernels.sellcs_slots_t``: each width-row reads its C-block of the
    slot-permuted X and scatter-accumulates into per-column slots. Output
    is in natural column order — the σ-permutation was consumed by the
    ``sellcs_slot_x`` gather, so no unpermute follows. Padding entries
    carry data == 0, cols == 0 (a harmless add into column 0). ``slice_of``
    must index the slot space ``x_slots`` was built over (globalize local
    slice ids before calling)."""
    dtype = jnp.promote_types(data.dtype, x_slots.dtype)
    k = x_slots.shape[1]
    slot = (slice_of[:, None] * chunk
            + jnp.arange(chunk, dtype=jnp.int32)[None])  # [W, C]
    contrib = data[:, :, None] * x_slots[slot]           # [W, C, k]
    return jnp.zeros((n_out, k), dtype).at[cols].add(contrib)


@jax.jit
def spmm_sellcs(sc: SellCS, x: Array) -> Array:
    """Slice-structured SpMM: one gather + FMA per width-row, then a single
    permutation scatter back to original row order. Padding entries carry
    data == 0, cols == 0 — they contribute nothing. Symmetric one-triangle
    storage combines the normal and transpose passes over the stored
    triangle: ``A X = N(X) + T(X) - diag * X``."""
    x2, squeeze = _as_2d(x)
    m, n = sc.shape
    k = x2.shape[1]
    dtype = jnp.promote_types(sc.data.dtype, x2.dtype)
    if sc.nnz == 0 or sc.data.shape[0] == 0:
        # nnz == 0 stores no diagonal either: the zero answer is exact
        y = jnp.zeros((m, k), dtype)
        return y[:, 0] if squeeze else y
    y_slots = sellcs_slots_ref(sc.data, sc.cols, sc.slice_of, x2,
                               num_slices=sc.num_slices, chunk=sc.chunk)
    # undo the σ-sort permutation; padding slots scatter to row m (dropped)
    y = jnp.zeros((m + 1, k), dtype).at[sc.row_perm].add(y_slots)
    y = y[:m]
    if sc.structure == "symmetric":
        xs = sellcs_slot_x(sc.row_perm, x2, m)
        y = (y + sellcs_slots_t_ref(sc.data, sc.cols, sc.slice_of, xs,
                                    n_out=n, chunk=sc.chunk)
             - sc.diag[:, None] * x2)
    return y[:, 0] if squeeze else y


@jax.jit
def spmm_sellcs_t(sc: SellCS, x: Array) -> Array:
    """``Y = A^T X`` over the same stored stream (``X: [m, k]``,
    ``Y: [n, k]``). For symmetric storage ``A^T == A``, so this is exactly
    the symmetric forward multiply."""
    if sc.structure == "symmetric":
        return spmm_sellcs(sc, x)
    x2, squeeze = _as_2d(x)
    m, n = sc.shape
    k = x2.shape[1]
    dtype = jnp.promote_types(sc.data.dtype, x2.dtype)
    if sc.nnz == 0 or sc.data.shape[0] == 0:
        y = jnp.zeros((n, k), dtype)
        return y[:, 0] if squeeze else y
    xs = sellcs_slot_x(sc.row_perm, x2, m)
    y = sellcs_slots_t_ref(sc.data, sc.cols, sc.slice_of, xs,
                           n_out=n, chunk=sc.chunk)
    return y[:, 0] if squeeze else y


def spmm_coo_t(coo: COO, x: Array) -> Array:
    """``Y = A^T X`` oracle on triplets (the transpose is a relabeling)."""
    m, n = coo.shape
    return spmm_coo(COO(coo.cols, coo.rows, coo.data, (n, m)), x)


def spmm_ref(mat, x: Array, *, op: str = "N") -> Array:
    """Oracle dispatch over every supported storage format. ``op='T'``
    computes ``A^T X`` (supported for SellCS and COO)."""
    from repro.kernels.ref import bsr_spmm_ref
    from repro.kernels.tiling import TiledSparse
    if op not in ("N", "T"):
        raise ValueError(f"op must be 'N' or 'T', got {op!r}")
    if op == "T":
        if isinstance(mat, SellCS):
            return spmm_sellcs_t(mat, x)
        if isinstance(mat, COO):
            return spmm_coo_t(mat, x)
        raise TypeError(
            f"no transpose SpMM oracle for {type(mat).__name__}")
    if isinstance(mat, TiledSparse):
        x2, squeeze = _as_2d(x)
        y = bsr_spmm_ref(mat, x2)
        return y[:, 0] if squeeze else y
    if isinstance(mat, SellCS):
        return spmm_sellcs(mat, x)
    if isinstance(mat, COO):
        return spmm_coo(mat, x)
    if isinstance(mat, CSR):
        return spmm_csr(mat, x)
    if isinstance(mat, BlockedSparse):
        return spmm_blocked(mat, x)
    raise TypeError(f"no SpMM oracle for {type(mat).__name__}")
