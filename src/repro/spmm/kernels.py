"""Tiled Pallas SpMM kernels — CSR (merge-path), blocked (TiledSparse) and
SELL-C-σ, each with a column-block (k-tile) grid dimension.

Every kernel streams the matrix exactly once per k-tile and keeps an
``[·, KT]`` slab of X and Y VMEM-resident, so the arithmetic intensity of a
pass grows KT-fold over SpMV — the one lever that moves a memory-bound
SpMV up the roofline (paper §1; Schubert/Hager/Fehske). The k-tile is the
*leading, parallel* grid dimension: k-tiles touch disjoint X/Y columns, so
megacore (or a future multi-device grid) can split them freely, while the
matrix-stream dimension stays "arbitrary" (sequential accumulate).

``choose_k_tile`` picks KT from the roofline model in ``repro.roofline``:
grow KT until either the X/Y slabs stop fitting the VMEM budget or the
modelled intensity crosses the ridge (beyond which more reuse buys
nothing).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.convert import VMEM_BUDGET_BYTES
from repro.core.formats import CSR
from repro.kernels import merge_spmv as _merge
from repro.kernels.tiling import TILE_C, TILE_R, TiledSparse
from repro.roofline.analysis import csr_stream_bytes, ridge_intensity
from .sellcs import SellCS

LANE = 128
W_TILE = 8          # width-rows per SELL-C-σ grid step (sublane-sized)


def choose_k_tile(shape: Tuple[int, int], k: int, *,
                  nnz: Optional[int] = None, dtype_bytes: int = 4,
                  vmem_budget: int = VMEM_BUDGET_BYTES) -> int:
    """Roofline-guided k-tile: the largest KT <= k such that

    (a) the [n_pad, KT] X-slab and [m_pad, KT] Y-slab fit half the VMEM
        budget (the other half double-buffers the matrix stream), and
    (b) (given nnz) the modelled intensity at KT does not overshoot the
        ridge by more than one lane group — past the ridge the kernel is
        compute-bound and larger KT only bloats VMEM.

    KT is rounded down to a lane multiple once it exceeds one lane, and is
    always >= 1.
    """
    m, n = shape
    mp = -(-max(m, 1) // TILE_R) * TILE_R
    np_ = -(-max(n, 1) // LANE) * LANE
    slab_rows = (mp + np_) * dtype_bytes
    kt = max(min(k, (vmem_budget // 2) // max(slab_rows, 1)), 1)
    if nnz:
        # smallest KT whose intensity reaches the ridge
        ridge = ridge_intensity()
        mat_bytes = csr_stream_bytes(nnz, m, dtype_bytes)
        vec_bytes = (m + n) * dtype_bytes
        denom = 2.0 * nnz - ridge * vec_bytes
        if denom > 0:
            kt_ridge = int(ridge * mat_bytes / denom) + 1
            kt = min(kt, max(kt_ridge, 1))
    if kt >= LANE:
        kt = (kt // LANE) * LANE
    return max(min(kt, k), 1)


def _pad_k(x: jax.Array, kt: int) -> jax.Array:
    k = x.shape[1]
    kp = -(-k // kt) * kt
    if kp != k:
        x = jnp.pad(x, ((0, 0), (0, kp - k)))
    return x


# --------------------------------------------------------------------------
# TiledSparse (blocked formats' TPU compute form) SpMM, k-tiled grid
# --------------------------------------------------------------------------
def _tiled_kernel(tile_rows_ref, tile_cols_ref,    # scalar prefetch (SMEM)
                  tiles_ref, x_ref,                # VMEM in
                  y_ref,                           # VMEM out (revisited)
                  *, tiles_per_step: int):
    g = pl.program_id(1)

    @pl.when(g == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    def body(t, _):
        idx = g * tiles_per_step + t
        r = tile_rows_ref[idx]
        c = tile_cols_ref[idx]
        tile = tiles_ref[t]                                    # (8, 128)
        xs = x_ref[pl.ds(c * TILE_C, TILE_C), :]               # (128, KT)
        upd = jax.lax.dot_general(
            tile, xs.astype(tile.dtype), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                # (8, KT)
        cur = y_ref[pl.ds(r * TILE_R, TILE_R), :]
        y_ref[pl.ds(r * TILE_R, TILE_R), :] = cur + upd
        return _

    jax.lax.fori_loop(0, tiles_per_step, body, None)


@functools.partial(jax.jit,
                   static_argnames=("k_tile", "tiles_per_step", "interpret"))
def tiled_spmm(ts: TiledSparse, x: jax.Array, *,
               k_tile: Optional[int] = None, tiles_per_step: int = 8,
               interpret: bool = False) -> jax.Array:
    """Y = A @ X over the dense-mini-tile stream, grid = (k_tiles, tile
    batches). Serves every blocked paper format (their TPU compute form is
    TiledSparse) and is the k-generalization of kernels.bsr_spmv."""
    m, n = ts.shape
    mp, np_ = ts.padded_shape()
    k = x.shape[1]
    kt = k_tile or choose_k_tile(ts.shape, k, nnz=ts.nnz)
    x_pad = jnp.zeros((np_, k), x.dtype).at[:n].set(x)
    x_pad = _pad_k(x_pad, kt)
    nk = x_pad.shape[1] // kt

    T = ts.num_tiles
    TB = tiles_per_step
    T_pad = -(-T // TB) * TB
    tiles, tile_rows, tile_cols = ts.tiles, ts.tile_rows, ts.tile_cols
    if T_pad != T:
        pad = T_pad - T
        tiles = jnp.concatenate(
            [tiles, jnp.zeros((pad,) + tiles.shape[1:], tiles.dtype)])
        tile_rows = jnp.concatenate(
            [tile_rows, jnp.zeros((pad,), tile_rows.dtype)])
        tile_cols = jnp.concatenate(
            [tile_cols, jnp.zeros((pad,), tile_cols.dtype)])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nk, T_pad // TB),
        in_specs=[
            pl.BlockSpec((TB, TILE_R, TILE_C), lambda j, g, *_: (g, 0, 0)),
            pl.BlockSpec((np_, kt), lambda j, g, *_: (0, j)),
        ],
        out_specs=pl.BlockSpec((mp, kt), lambda j, g, *_: (0, j)),
    )
    y = pl.pallas_call(
        functools.partial(_tiled_kernel, tiles_per_step=TB),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mp, x_pad.shape[1]), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tile_rows, tile_cols, tiles, x_pad)
    return y[:m, :k]


# --------------------------------------------------------------------------
# CSR merge-path SpMM, k-tiled grid
# --------------------------------------------------------------------------
def _merge_kernel(cols_ref, vals_ref, seg_ref, x_ref, out_ref, *,
                  r_width: int):
    cols = cols_ref[0]                           # (D,)
    vals = vals_ref[0].astype(jnp.float32)       # (D,)
    seg = seg_ref[0]                             # (D,)
    xs = jnp.take(x_ref[...], cols, axis=0,
                  mode="clip").astype(jnp.float32)            # (D, KT)
    prod = vals[:, None] * xs                                  # (D, KT)
    onehot = (seg[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, r_width), 1)
              ).astype(jnp.float32)                            # (D, R)
    out_ref[0] = jax.lax.dot_general(
        onehot, prod, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (R, KT)


@functools.partial(jax.jit,
                   static_argnames=("r_width", "k_tile", "interpret"))
def _merge_spmm_partials(plan_cols, plan_vals, plan_seg, x_pad, *,
                         r_width: int, k_tile: int,
                         interpret: bool = False):
    P, D = plan_cols.shape
    np_ = x_pad.shape[0]
    nk = x_pad.shape[1] // k_tile
    grid_spec = pl.GridSpec(
        grid=(nk, P),
        in_specs=[
            pl.BlockSpec((1, D), lambda j, p: (p, 0)),
            pl.BlockSpec((1, D), lambda j, p: (p, 0)),
            pl.BlockSpec((1, D), lambda j, p: (p, 0)),
            pl.BlockSpec((np_, k_tile), lambda j, p: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, r_width, k_tile),
                               lambda j, p: (p, 0, j)),
    )
    return pl.pallas_call(
        functools.partial(_merge_kernel, r_width=r_width),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((P, r_width, x_pad.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(plan_cols, plan_vals, plan_seg, x_pad)


def csr_spmm(csr: CSR, x: jax.Array, *,
             plan: Optional[_merge.MergePlan] = None,
             num_spans: Optional[int] = None,
             k_tile: Optional[int] = None,
             interpret: bool = False) -> jax.Array:
    """Merge-path SpMM on flat CSR: per-span one-hot matmul produces an
    (R, KT) partial block; the sequential carry-out fixup is a single
    scatter-add epilogue (same plan object as the SpMV kernel — build it
    once at convert time)."""
    m, n = csr.shape
    k = x.shape[1]
    if plan is None:
        if num_spans is None:
            num_spans = _merge.default_num_spans(m, csr.nnz)
        plan = _merge.merge_plan(csr, num_spans)
    kt = k_tile or choose_k_tile(csr.shape, k, nnz=csr.nnz)
    np_ = -(-n // LANE) * LANE
    x_pad = jnp.zeros((np_, k), x.dtype).at[:n].set(x)
    x_pad = _pad_k(x_pad, kt)
    partials = _merge_spmm_partials(
        plan.cols, plan.vals, plan.seg, x_pad, r_width=plan.r_width,
        k_tile=kt, interpret=interpret)                     # (P, R, Kp)
    return _merge.carry_out_fixup(partials, plan.row_starts, m)[:, :k]


# --------------------------------------------------------------------------
# SELL-C-σ SpMM, k-tiled grid
# --------------------------------------------------------------------------
def _sellcs_kernel(slice_of_ref,                  # scalar prefetch (SMEM)
                   data_ref, cols_ref, x_ref,     # VMEM in
                   y_ref,                         # VMEM out (revisited)
                   *, w_tile: int, chunk: int):
    g = pl.program_id(1)

    @pl.when(g == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    cols = cols_ref[...]                                       # (WT, C)
    xs = jnp.take(x_ref[...], cols.reshape(-1), axis=0,
                  mode="clip")                                 # (WT*C, KT)
    kt = xs.shape[1]
    contrib = (data_ref[...].astype(jnp.float32).reshape(-1)[:, None]
               * xs.astype(jnp.float32)
               ).reshape(w_tile, chunk, kt)                    # (WT, C, KT)

    def body(w, _):
        s = slice_of_ref[g * w_tile + w]
        cur = y_ref[pl.ds(s * chunk, chunk), :]
        y_ref[pl.ds(s * chunk, chunk), :] = cur + contrib[w]
        return _

    jax.lax.fori_loop(0, w_tile, body, None)


def _sellcs_fused_kernel(slice_of_ref, col_map_ref,  # scalar prefetch (SMEM)
                         data_ref, cols_ref, x_ref,  # VMEM in
                         y_ref,                      # VMEM out (revisited)
                         *, w_tile: int, chunk: int):
    """``_sellcs_kernel`` with the compact-X gather fused into the stream:
    stored ``cols`` are compact ids, ``col_map`` (riding the scalar prefetch
    next to ``slice_of``) maps them to rows of the full padded X, so no
    up-front slab materialization happens outside the kernel."""
    g = pl.program_id(1)

    @pl.when(g == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    cols = cols_ref[...]                                       # (WT, C)
    gcols = jnp.take(col_map_ref[...], cols.reshape(-1),
                     mode="clip")                              # (WT*C,)
    xs = jnp.take(x_ref[...], gcols, axis=0, mode="clip")      # (WT*C, KT)
    kt = xs.shape[1]
    contrib = (data_ref[...].astype(jnp.float32).reshape(-1)[:, None]
               * xs.astype(jnp.float32)
               ).reshape(w_tile, chunk, kt)                    # (WT, C, KT)

    def body(w, _):
        s = slice_of_ref[g * w_tile + w]
        cur = y_ref[pl.ds(s * chunk, chunk), :]
        y_ref[pl.ds(s * chunk, chunk), :] = cur + contrib[w]
        return _

    jax.lax.fori_loop(0, w_tile, body, None)


@functools.partial(jax.jit, static_argnames=("num_slices", "chunk",
                                             "k_tile", "interpret"))
def sellcs_slots(data: jax.Array, cols: jax.Array, slice_of: jax.Array,
                 x_pad: jax.Array, *, num_slices: int, chunk: int,
                 k_tile: int, interpret: bool = False,
                 col_map: jax.Array | None = None) -> jax.Array:
    """Raw-array slot-space SpMM over a SELL-C-σ width-row stream.

    Accumulates into row slots ``[num_slices * chunk, Kp]`` without applying
    any row permutation. This is the shard-local compute of the distributed
    schedules (``repro.spmm.distributed``): a shard's slice stream is just a
    shorter width-row stream with its own ``slice_of``/``num_slices``, so
    the same k-tiled Pallas kernel serves one device or a mesh body.

    With ``col_map`` (int32[Ntc], LANE-padded, padding pointing at row 0)
    the stored ``cols`` are compact ids and the gather into the full
    ``x_pad`` fuses into the kernel via a second scalar-prefetch operand —
    the ``gather="fused"`` mode of the distributed multiplies.
    """
    C = chunk
    S = num_slices
    W = data.shape[0]
    Wp = max(-(-W // W_TILE) * W_TILE, W_TILE)
    if Wp != W:
        pad = Wp - W
        data = jnp.concatenate([data, jnp.zeros((pad, C), data.dtype)])
        cols = jnp.concatenate([cols, jnp.zeros((pad, C), cols.dtype)])
        # padding width-rows carry data == 0; aim them at slice 0 harmlessly
        slice_of = jnp.concatenate(
            [slice_of, jnp.zeros((pad,), slice_of.dtype)])

    np_, Kp = x_pad.shape
    nk = Kp // k_tile
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2 if col_map is not None else 1,
        grid=(nk, Wp // W_TILE),
        in_specs=[
            pl.BlockSpec((W_TILE, C), lambda j, g, *_: (g, 0)),
            pl.BlockSpec((W_TILE, C), lambda j, g, *_: (g, 0)),
            pl.BlockSpec((np_, k_tile), lambda j, g, *_: (0, j)),
        ],
        out_specs=pl.BlockSpec((S * C, k_tile), lambda j, g, *_: (0, j)),
    )
    if col_map is not None:
        kernel = functools.partial(_sellcs_fused_kernel,
                                   w_tile=W_TILE, chunk=C)
        operands = (slice_of, col_map, data, cols, x_pad)
    else:
        kernel = functools.partial(_sellcs_kernel, w_tile=W_TILE, chunk=C)
        operands = (slice_of, data, cols, x_pad)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S * C, Kp), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


def sellcs_slots_chunk(data: jax.Array, cols: jax.Array,
                       slice_of: jax.Array, x_pad: jax.Array, *,
                       slice_start: int, num_slices: int, chunk: int,
                       k_tile: int, interpret: bool = False,
                       col_map: jax.Array | None = None) -> jax.Array:
    """``sellcs_slots`` over one *chunk sub-stream* of the slice stream.

    The chunked distributed merge schedule (``repro.spmm.distributed``)
    splits the σ-sorted stream into spans of ``num_slices`` consecutive
    slices so each span's psum can overlap the next span's compute.
    ``slice_of`` stays GLOBAL in the sub-stream; this entry point rebases it
    to the chunk-local slot space ``[num_slices * chunk, Kp]`` starting at
    global slice ``slice_start``. Padding width-rows (zero data) may carry
    any slice id — they are clipped into range and contribute nothing.
    """
    local = jnp.clip(slice_of.astype(jnp.int32) - slice_start, 0,
                     max(num_slices - 1, 0))
    return sellcs_slots(data, cols, local, x_pad, num_slices=num_slices,
                        chunk=chunk, k_tile=k_tile, interpret=interpret,
                        col_map=col_map)


def _sellcs_spmm_slots(sc: SellCS, x_pad: jax.Array, *, k_tile: int,
                       interpret: bool = False) -> jax.Array:
    """Accumulate into σ-sorted row slots [S*C, Kp]; the caller undoes the
    permutation."""
    return sellcs_slots(sc.data, sc.cols, sc.slice_of, x_pad,
                        num_slices=sc.num_slices, chunk=sc.chunk,
                        k_tile=k_tile, interpret=interpret)


# --------------------------------------------------------------------------
# SELL-C-σ transpose SpMM (Y = A^T X), k-tiled grid
# --------------------------------------------------------------------------
def _sellcs_t_kernel(slice_of_ref,                # scalar prefetch (SMEM)
                     data_ref, cols_ref, xs_ref,  # VMEM in
                     y_ref,                       # VMEM out (revisited)
                     *, w_tile: int, chunk: int, n_pad: int):
    g = pl.program_id(1)

    @pl.when(g == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    def body(w, _):
        s = slice_of_ref[g * w_tile + w]
        # the slot-permuted X makes the read side structured: one
        # contiguous C-block per width-row, no gather
        xb = xs_ref[pl.ds(s * chunk, chunk), :]            # (C, KT)
        prod = (data_ref[w].astype(jnp.float32)[:, None]
                * xb.astype(jnp.float32))                  # (C, KT)
        # scatter to columns via one-hot contraction (MXU-friendly — the
        # same idiom as the merge kernel's per-span row scatter)
        onehot = (cols_ref[w][:, None] ==
                  jax.lax.broadcasted_iota(jnp.int32, (1, n_pad), 1)
                  ).astype(jnp.float32)                    # (C, n_pad)
        y_ref[...] += jax.lax.dot_general(
            onehot, prod, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # (n_pad, KT)
        return _

    jax.lax.fori_loop(0, w_tile, body, None)


@functools.partial(jax.jit, static_argnames=("n_out", "chunk", "k_tile",
                                             "interpret"))
def sellcs_slots_t(data: jax.Array, cols: jax.Array, slice_of: jax.Array,
                   x_slots: jax.Array, *, n_out: int, chunk: int,
                   k_tile: int, interpret: bool = False) -> jax.Array:
    """Raw-array transpose pass over a SELL-C-σ width-row stream.

    ``x_slots`` is X permuted into slot space (``reference.sellcs_slot_x``):
    each width-row then reads a *contiguous* C-block at ``slice_of[w] *
    chunk`` and scatter-accumulates ``data[w] * x`` into its column
    indices. The output ``[n_out, Kp]`` is in natural column order — the
    σ-permutation was consumed by the slot gather, so no unpermute
    follows. ``slice_of`` must index the slot space ``x_slots`` spans;
    globalize shard-local slice ids (add ``slice_offset``) before calling.
    Padding entries carry data == 0, cols == 0 (harmless add into column
    0); padding width-rows may carry any in-range slice id.
    """
    C = chunk
    W = data.shape[0]
    Wp = max(-(-W // W_TILE) * W_TILE, W_TILE)
    if Wp != W:
        pad = Wp - W
        data = jnp.concatenate([data, jnp.zeros((pad, C), data.dtype)])
        cols = jnp.concatenate([cols, jnp.zeros((pad, C), cols.dtype)])
        slice_of = jnp.concatenate(
            [slice_of, jnp.zeros((pad,), slice_of.dtype)])

    n_pad = -(-max(n_out, 1) // LANE) * LANE
    SC, Kp = x_slots.shape
    nk = Kp // k_tile
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nk, Wp // W_TILE),
        in_specs=[
            pl.BlockSpec((W_TILE, C), lambda j, g, *_: (g, 0)),
            pl.BlockSpec((W_TILE, C), lambda j, g, *_: (g, 0)),
            pl.BlockSpec((SC, k_tile), lambda j, g, *_: (0, j)),
        ],
        out_specs=pl.BlockSpec((n_pad, k_tile), lambda j, g, *_: (0, j)),
    )
    y = pl.pallas_call(
        functools.partial(_sellcs_t_kernel, w_tile=W_TILE, chunk=C,
                          n_pad=n_pad),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, Kp), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(slice_of, data, cols, x_slots)
    return y[:n_out]


def _slot_x_pad(row_perm: jax.Array, x: jax.Array, m: int,
                kt: int) -> jax.Array:
    """Slot-space X for the transpose pass, k-padded for the k-tile grid.
    Padding slots (``row_perm == m``) read a zero row."""
    x_pad = jnp.concatenate(
        [x, jnp.zeros((1, x.shape[1]), x.dtype)], axis=0)
    return _pad_k(x_pad[row_perm], kt)


def sellcs_spmm(sc: SellCS, x: jax.Array, *, k_tile: Optional[int] = None,
                interpret: bool = False, op: str = "N") -> jax.Array:
    """SELL-C-σ SpMM: each grid step broadcasts W_TILE width-vectors of the
    slice stream against the VMEM-resident X slab — uniform work quanta
    regardless of row-length skew (the σ-sorted answer to the paper's mawi
    pathology), with the x-gather as the only irregular access.

    ``op='T'`` computes ``Y = A^T X`` (``X: [m, k]``) via the transpose
    kernel; symmetric one-triangle storage combines both passes over the
    stored triangle (``A X = N(X) + T(X) - diag * X``), for which
    ``op='T'`` and ``op='N'`` coincide.
    """
    if op not in ("N", "T"):
        raise ValueError(f"op must be 'N' or 'T', got {op!r}")
    m, n = sc.shape
    k = x.shape[1]
    kt = k_tile or choose_k_tile(sc.shape, k, nnz=sc.nnz)
    sym = sc.structure == "symmetric"
    if op == "T" and not sym:
        if sc.nnz == 0:
            return jnp.zeros((n, k), jnp.float32)
        xs = _slot_x_pad(sc.row_perm, x, m, kt)
        y = sellcs_slots_t(sc.data, sc.cols, sc.slice_of, xs, n_out=n,
                           chunk=sc.chunk, k_tile=kt, interpret=interpret)
        return y[:, :k]
    np_ = -(-max(n, 1) // LANE) * LANE
    x_pad = jnp.zeros((np_, k), x.dtype).at[:n].set(x)
    x_pad = _pad_k(x_pad, kt)
    if sc.nnz == 0:
        return jnp.zeros((m, k), jnp.float32)
    y_slots = _sellcs_spmm_slots(sc, x_pad, k_tile=kt,
                                 interpret=interpret)     # (S*C, Kp)
    Kp = y_slots.shape[1]
    y = jnp.zeros((m + 1, Kp), jnp.float32).at[sc.row_perm].add(y_slots)
    y = y[:m]
    if sym:
        xs = _slot_x_pad(sc.row_perm, x, m, kt)
        y = (y + sellcs_slots_t(sc.data, sc.cols, sc.slice_of, xs,
                                n_out=n, chunk=sc.chunk, k_tile=kt,
                                interpret=interpret)
             - _pad_k(sc.diag[:, None] * x, kt))
    return y[:m, :k]
