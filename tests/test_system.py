"""End-to-end behaviour tests for the paper's system."""
import numpy as np
import jax.numpy as jnp


def test_spmv_pipeline_end_to_end():
    """generate -> select -> convert -> multiply -> validate, via the
    public API only (the quickstart path)."""
    from repro.core import (MachineSpec, convert, matrix_stats,
                            select_algorithm, spmv, spmv_dense_oracle,
                            to_coo)
    from repro.data import matrices

    coo = to_coo(*matrices.powerlaw(512, 512, 6000, seed=0))
    stats = matrix_stats(coo)
    algo = select_algorithm(stats, MachineSpec(num_devices=256),
                            num_spmvs=1000)
    assert algo in ("parcrs", "merge", "csb", "csbh", "bcoh", "bcohc",
                    "bcohch", "bcohchp", "mergeb", "mergebh")
    kw = dict(beta=64) if algo not in ("parcrs", "merge") else {}
    mat = convert(coo, algo, **kw)
    x = jnp.asarray(np.random.default_rng(1)
                    .standard_normal(512).astype(np.float32))
    np.testing.assert_allclose(np.asarray(spmv(mat, x, impl="ref")),
                               np.asarray(spmv_dense_oracle(coo, x)),
                               rtol=1e-3, atol=1e-3)


def test_train_cli_end_to_end(tmp_path):
    """The real training driver: loss falls on the structured pipeline."""
    from repro.launch import train as train_cli
    final = train_cli.main([
        "--arch", "llama3.2-1b", "--reduced", "--steps", "25",
        "--batch", "8", "--seq", "48", "--lr", "3e-3",
        "--ckpt-dir", str(tmp_path / "ck"), "--save-every", "10",
        "--log-every", "100"])
    assert np.isfinite(final)
    assert final < np.log(256) + 0.5        # below ~uniform entropy


def test_serve_cli_end_to_end():
    from repro.launch import serve as serve_cli
    gen = serve_cli.main(["--arch", "granite-moe-1b-a400m", "--reduced",
                          "--batch", "2", "--prompt-len", "12",
                          "--gen", "6"])
    assert gen.shape == (2, 6)


def test_grad_accumulation_parity():
    """grad_accum=4 must reproduce the grad_accum=1 update (within fp
    reassociation tolerance)."""
    import jax
    from repro.configs import get_config
    from repro.models.model import init_params
    from repro.optim import constant_lr, make_optimizer
    from repro.launch.steps import TrainState, make_train_step

    cfg = get_config("llama3.2-1b", reduced=True)
    opt = make_optimizer("adamw", constant_lr(1e-2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                cfg.vocab)
    s1, m1 = jax.jit(make_train_step(cfg, opt))(
        TrainState(params, opt.init(params)), {"tokens": tokens})
    s4, m4 = jax.jit(make_train_step(cfg, opt, grad_accum=4))(
        TrainState(params, opt.init(params)), {"tokens": tokens})
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    w1 = np.asarray(jax.tree_util.tree_leaves(s1.params)[0])
    w4 = np.asarray(jax.tree_util.tree_leaves(s4.params)[0])
    np.testing.assert_allclose(w1, w4, rtol=2e-4, atol=1e-6)
