"""Beyond-paper benchmark: the paper's load-balancing insight applied to MoE
dispatch (DESIGN §4).

The token->expert matrix is the 'unstructured sparse matrix'; routing skew
(zipf temperature) plays the role of the degree distribution. Compares:
  * dropless sorted grouped GEMM (merge-balanced quanta; ragged_dot),
  * capacity-factor dense dispatch (the static row-band analogue: pads every
    expert to max load -> wasted FLOPs at skew, drops at overflow).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .harness import Csv, time_fn


def _route(T, E, skew, seed=0):
    rng = np.random.default_rng(seed)
    w = np.arange(1, E + 1, dtype=np.float64) ** (-skew)
    w /= w.sum()
    return rng.choice(E, size=T, p=w).astype(np.int32)


@jax.jit
def dropless(tokens, wdown, expert_of_token, group_sizes):
    order = jnp.argsort(expert_of_token)
    xs = tokens[order]
    out = jax.lax.ragged_dot(xs, wdown, group_sizes)
    return jnp.zeros_like(out).at[order].set(out)


def capacity_dense(tokens, wdown, expert_of_token, capacity):
    T, K = tokens.shape
    E = wdown.shape[0]

    @jax.jit
    def fn(tokens, expert_of_token):
        onehot = jax.nn.one_hot(expert_of_token, E, dtype=tokens.dtype)
        pos = (jnp.cumsum(onehot, axis=0) - 1) * onehot       # [T, E]
        keep = pos.max(-1) < capacity
        slot = pos.max(-1).astype(jnp.int32)
        buf = jnp.zeros((E, capacity, K), tokens.dtype)
        buf = buf.at[expert_of_token, slot].add(
            tokens * keep[:, None].astype(tokens.dtype))
        out = jnp.einsum("eck,ekn->ecn", buf, wdown)
        return out[expert_of_token, slot] * keep[:, None].astype(
            tokens.dtype), keep
    return fn(tokens, expert_of_token)


def run(csv=None):
    csv = csv or Csv("MoE dispatch: merge-balanced dropless vs capacity")
    T, E, K, N = 8192, 32, 256, 256
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.standard_normal((T, K)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((E, K, N)).astype(np.float32) * .05)
    for skew in [0.0, 0.8, 1.5]:
        e_of_t = _route(T, E, skew)
        counts = np.bincount(e_of_t, minlength=E)
        gs = jnp.asarray(counts.astype(np.int32))
        eot = jnp.asarray(e_of_t)
        t_drop = time_fn(lambda: dropless(tokens, w, eot, gs), reps=10)
        cap = int(np.ceil(T / E * 1.25))
        out, keep = capacity_dense(tokens, w, eot, cap)
        t_cap = time_fn(
            lambda: capacity_dense(tokens, w, eot, cap)[0], reps=10)
        dropped = float(1.0 - np.asarray(keep).mean())
        imb = counts.max() / counts.mean()
        csv.row(f"moe.skew{skew}.dropless", t_drop,
                f"imbalance={imb:.2f};dropped=0.000")
        csv.row(f"moe.skew{skew}.capacity1.25", t_cap,
                f"imbalance={imb:.2f};dropped={dropped:.3f};"
                f"padding_flops_waste={cap * E / T - 1:.2f}")
