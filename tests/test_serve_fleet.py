"""Multi-tenant serve fleet (ISSUE 8): the COO fingerprint, the Fleet
plan cache, per-lane backpressure, the FleetBatcher flush scheduler,
device-loss re-deal (redeal_sellcs + Fleet.handle_device_loss), the
elastic reshard guard, serve --mode fleet end-to-end, and the
smoke_check SLO gate.

Device-backed mesh tests run in SUBPROCESSES (the host-platform device
count must be set before jax initializes); everything else runs
in-process on the suite's single device.
"""
import json
import threading

import numpy as np
import pytest

from tests.test_spmm_distributed import run_sub


def _coo(m=300, n=300, nnz=2400, seed=0):
    from repro.core import to_coo
    from repro.data import matrices
    return to_coo(*matrices.uniform(m, n, nnz, seed))


# -------------------------------------------------------------------------
# coo_fingerprint: the plan-cache key
# -------------------------------------------------------------------------

def test_fingerprint_deterministic_and_content_sensitive():
    from repro.core.formats import COO
    from repro.spmm import coo_fingerprint
    coo = _coo()
    fp = coo_fingerprint(coo)
    assert fp == coo_fingerprint(_coo())            # rebuilt: same bytes
    assert len(fp) == 32                            # blake2b-128 hex
    # one perturbed value is a different matrix
    vals = np.asarray(coo.data).copy()
    vals[7] += 1.0
    assert coo_fingerprint(COO(coo.rows, coo.cols, vals,
                               coo.shape)) != fp
    # one moved nonzero is a different matrix
    cols = np.asarray(coo.cols).copy()
    cols[3] = (cols[3] + 1) % coo.shape[1]
    assert coo_fingerprint(COO(coo.rows, cols, coo.data,
                               coo.shape)) != fp
    # a different shape over the same triplets is a different matrix
    bigger = (coo.shape[0] + 1, coo.shape[1])
    assert coo_fingerprint(COO(coo.rows, coo.cols, coo.data,
                               bigger)) != fp


def test_fingerprint_permutation_stable():
    """The triplet stream's storage order is presentation, not content —
    any permutation of (rows, cols, vals) hashes identically."""
    from repro.core.formats import COO
    from repro.spmm import coo_fingerprint
    coo = _coo(nnz=500)
    fp = coo_fingerprint(coo)
    rng = np.random.default_rng(11)
    for _ in range(3):
        p = rng.permutation(len(np.asarray(coo.rows)))
        shuffled = COO(np.asarray(coo.rows)[p], np.asarray(coo.cols)[p],
                       np.asarray(coo.data)[p], coo.shape)
        assert coo_fingerprint(shuffled) == fp


def test_fingerprint_permutation_property():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    from repro.core.formats import COO
    from repro.spmm import coo_fingerprint
    coo = _coo(m=40, n=40, nnz=60, seed=5)
    rows = np.asarray(coo.rows)
    cols = np.asarray(coo.cols)
    vals = np.asarray(coo.data)
    fp = coo_fingerprint(coo)

    @hypothesis.given(st.permutations(list(range(len(rows)))))
    @hypothesis.settings(max_examples=25, deadline=None)
    def prop(perm):
        p = np.asarray(perm)
        assert coo_fingerprint(
            COO(rows[p], cols[p], vals[p], coo.shape)) == fp

    prop()


# -------------------------------------------------------------------------
# Bounded-queue backpressure on RequestBatcher
# -------------------------------------------------------------------------

def test_backpressure_raise_policy():
    from repro import obs
    from repro.spmm import QueueFull, RequestBatcher, spmm_coo
    coo = _coo(m=50, n=50, nnz=200)
    x = np.ones(50, np.float32)
    reg = obs.install(obs.MetricRegistry())
    try:
        b = RequestBatcher(coo, max_batch=8, max_pending=3, name="a")
        for _ in range(3):
            b.submit(x)
        assert reg.gauge("batcher/pending", {"tenant": "a"}).value == 3
        with pytest.raises(QueueFull) as exc:
            b.submit(x)
        assert (exc.value.tenant, exc.value.pending,
                exc.value.max_pending) == ("a", 3, 3)
        assert b.rejected == 1
        assert reg.counter("batcher/rejected",
                           {"tenant": "a"}).value == 1
        # a flush makes room and nothing queued was lost
        out = b.flush()
        assert len(out) == 3 and b.pending == 0
        b.submit(x)
        yo = np.asarray(spmm_coo(coo, x[:, None]))[:, 0]
        for y in out.values():
            np.testing.assert_allclose(np.asarray(y), yo, rtol=1e-5,
                                       atol=1e-5)
    finally:
        obs.uninstall()
    with pytest.raises(ValueError):
        RequestBatcher(coo, max_pending=0)
    with pytest.raises(ValueError):
        RequestBatcher(coo, overflow="drop")


def test_backpressure_block_policy():
    """An over-bound submit under overflow='block' parks the submitter
    until a flush opens a slot — the request is delayed, never dropped."""
    from repro.spmm import RequestBatcher
    coo = _coo(m=50, n=50, nnz=200)
    x = np.ones(50, np.float32)
    b = RequestBatcher(coo, max_batch=2, max_pending=2, overflow="block")
    b.submit(x)
    b.submit(x)
    unblocked = threading.Event()

    def blocked_submit():
        b.submit(x)
        unblocked.set()

    t = threading.Thread(target=blocked_submit)
    t.start()
    assert not unblocked.wait(0.2), "submit must block while full"
    served = b.flush()
    assert unblocked.wait(5.0), "flush must wake the blocked submitter"
    t.join()
    assert len(served) == 2 and b.pending == 1
    assert len(b.flush()) == 1
    assert b.rejected == 0


# -------------------------------------------------------------------------
# FleetBatcher: the urgency x efficiency flush scheduler
# -------------------------------------------------------------------------

class _Op:
    """Minimal matmul-only stand-in so scheduler tests stay pure host."""

    def __init__(self, coo):
        self.coo = coo
        self.shape = coo.shape

    def matmul(self, X):
        from repro.spmm import spmm_coo
        return spmm_coo(self.coo, X)


def test_fleet_batcher_scheduler_order():
    from repro.spmm import FleetBatcher
    coo = _coo(m=20, n=20, nnz=60)
    t = [0.0]
    fb = FleetBatcher(clock=lambda: t[0])
    fb.add_tenant("a", _Op(coo), max_batch=4, slo_s=1.0)
    fb.add_tenant("b", _Op(coo), max_batch=4, slo_s=1.0)
    x = np.ones(20, np.float32)
    assert fb.next_tenant() is None
    t[0] = 0.0
    fb.submit("a", x)                       # 1 old request
    t[0] = 0.5
    for _ in range(4):                      # a full fresh batch
        fb.submit("b", x)
    # at t=0.6 age still dominates: a = 0.6*(1/4), b = 0.1*(4/4)
    assert fb.next_tenant(now=0.6) == "a"
    # at t=0.9 the full batch wins: a = 0.9*0.25 < b = 0.4*1.0
    assert fb.next_tenant(now=0.9) == "b"
    t[0] = 0.9
    tenant, res = fb.flush_next()
    assert tenant == "b" and len(res) == 4
    # only a remains; starvation-proof: it wins at any later now
    assert fb.next_tenant(now=100.0) == "a"
    t[0] = 2.0                              # flushed 2s after a 1s SLO
    assert len(fb.flush("a")) == 1
    assert fb.lane("a").slo_violations == 1
    assert fb.lane("b").slo_violations == 0


def test_fleet_batcher_tiebreak_and_validation():
    from repro.spmm import FleetBatcher
    coo = _coo(m=20, n=20, nnz=60)
    t = [0.0]
    fb = FleetBatcher(clock=lambda: t[0])
    fb.add_tenant("young", _Op(coo), max_batch=2, slo_s=1.0)
    fb.add_tenant("old", _Op(coo), max_batch=2, slo_s=1.0)
    x = np.ones(20, np.float32)
    t[0] = 0.0
    fb.submit("old", x)
    t[0] = 0.5
    fb.submit("young", x)
    # equal scores are impossible here (ages differ) but scale the young
    # lane's age to force a score tie: same slo, same efficiency, the
    # older oldest-arrival must win
    assert fb.next_tenant(now=1.0) == "old"
    with pytest.raises(ValueError):
        fb.add_tenant("old", _Op(coo))
    with pytest.raises(ValueError):
        fb.add_tenant("zero", _Op(coo), slo_s=0.0)


def test_fleet_batcher_drain_never_drops():
    """ISSUE acceptance: every queued ticket is served exactly once, with
    the right answer, whatever order the scheduler picks."""
    from repro.spmm import FleetBatcher, spmm_coo
    rng = np.random.default_rng(3)
    coos = {name: _coo(m=40, n=40, nnz=200, seed=i)
            for i, name in enumerate(["a", "b", "c"])}
    t = [0.0]
    fb = FleetBatcher(clock=lambda: t[0])
    for i, (name, coo) in enumerate(coos.items()):
        fb.add_tenant(name, _Op(coo), max_batch=2 + i, slo_s=0.05 * (i + 1))
    sent = {}
    for j in range(30):
        name = ["a", "b", "c"][j % 3]
        x = rng.standard_normal(40).astype(np.float32)
        t[0] = 0.01 * j
        rid = fb.submit(name, x)
        sent[(name, rid)] = x
    assert fb.total_pending == 30
    results = fb.drain()
    assert fb.total_pending == 0
    got = {(name, rid) for name in results for rid in results[name]}
    assert got == set(sent), "drain dropped or duplicated tickets"
    for (name, rid), x in sent.items():
        yo = np.asarray(spmm_coo(coos[name], x[:, None]))[:, 0]
        np.testing.assert_allclose(np.asarray(results[name][rid]), yo,
                                   rtol=1e-4, atol=1e-4)
    assert sum(lane.served for lane in
               (fb.lane(n) for n in fb.tenants())) == 30


# -------------------------------------------------------------------------
# Fleet: the fingerprint-keyed plan cache
# -------------------------------------------------------------------------

def test_fleet_plan_cache_hit_and_miss():
    from repro.core.formats import COO
    from repro.spmm import Fleet, spmm_coo
    coo = _coo()
    fleet = Fleet(impl="ref")
    op1 = fleet.register("t0", coo)
    op2 = fleet.register("t1", _coo())      # same content, fresh arrays
    assert op2.plan is op1.plan, "identical COO must hit the plan cache"
    assert (fleet.stats.plan_cache_hits,
            fleet.stats.plan_cache_misses) == (1, 1)
    # a returning tenant's operator still answers correctly
    x = np.ones(coo.shape[1], np.float32)
    yo = np.asarray(spmm_coo(coo, x[:, None]))[:, 0]
    np.testing.assert_allclose(np.asarray(op2.matmul(x)), yo,
                               rtol=1e-4, atol=1e-4)
    # a perturbed matrix is a different fingerprint: full build
    vals = np.asarray(coo.data).copy()
    vals[0] += 0.5
    op3 = fleet.register("t2", COO(coo.rows, coo.cols, vals, coo.shape))
    assert op3.plan is not op1.plan
    assert fleet.stats.plan_cache_misses == 2
    # a different k-hint is a different cache line
    op4 = fleet.register("t3", _coo(), k_hint=8)
    assert op4.plan is not op1.plan
    assert fleet.stats.plan_cache_misses == 3
    with pytest.raises(ValueError):
        fleet.register("t0", coo)
    assert set(fleet.tenants()) == {"t0", "t1", "t2", "t3"}
    assert "t0" in fleet and len(fleet) == 4


def test_fleet_eviction_and_capacity():
    from repro.spmm import Fleet
    coo_a, coo_b = _coo(seed=1), _coo(seed=2)
    fleet = Fleet(impl="ref")
    fleet.register("a1", coo_a)
    fleet.register("a2", _coo(seed=1))
    fleet.register("b", coo_b)
    # evicting one sharer keeps the fingerprint's artifacts for the other
    fleet.evict("a1")
    assert len(fleet._artifacts) == 2
    fleet.evict("a2")
    assert len(fleet._artifacts) == 1       # last user gone -> freed
    assert fleet.stats.evictions == 2
    # capacity: LRU (insertion order) eviction on overflow
    small = Fleet(impl="ref", capacity=2)
    small.register("x", coo_a)
    small.register("y", coo_b)
    small.register("z", _coo(seed=3))
    assert set(small.tenants()) == {"y", "z"}
    assert small.stats.evictions == 1
    with pytest.raises(ValueError):
        Fleet(capacity=0)


def test_fleet_memory_budget_eviction():
    """ISSUE 9 satellite: max_bytes= evicts by accumulated plan
    storage_bytes (LRU), counts the freed bytes, and never evicts the
    tenant being registered (one over-budget matrix still serves)."""
    from repro import obs
    from repro.obs import MetricRegistry
    from repro.spmm import Fleet
    coo_a, coo_b, coo_c = _coo(seed=1), _coo(seed=2), _coo(seed=3)
    # budget of 1 byte: every arrival busts it, yet the newest survives
    reg = obs.install(MetricRegistry())
    try:
        tiny = Fleet(impl="ref", max_bytes=1)
        tiny.register("a", coo_a)
        assert tiny.tenants() == ["a"]
        tiny.register("b", coo_b)
        assert tiny.tenants() == ["b"]         # LRU "a" evicted
        assert tiny.stats.evictions == 1
        assert tiny.stats.evicted_bytes > 0
        assert reg.counter("fleet/evicted_bytes").value == \
            tiny.stats.evicted_bytes
    finally:
        obs.uninstall()
    # a budget that fits two of three: registering the third evicts
    # exactly the oldest
    roomy = Fleet(impl="ref")
    roomy.register("a", _coo(seed=1))
    roomy.register("b", _coo(seed=2))
    budget = roomy.total_storage_bytes()
    fleet = Fleet(impl="ref", max_bytes=budget)
    fleet.register("a", coo_a)
    fleet.register("b", coo_b)
    assert set(fleet.tenants()) == {"a", "b"}  # fits, nothing evicted
    assert fleet.stats.evictions == 0
    fleet.register("c", coo_c)
    assert "a" not in fleet and "c" in fleet
    assert fleet.total_storage_bytes() <= budget
    assert fleet.stats.evicted_bytes > 0
    with pytest.raises(ValueError):
        Fleet(max_bytes=0)


# -------------------------------------------------------------------------
# runtime.elastic: reshard flattens once and rejects stale specs
# -------------------------------------------------------------------------

def test_reshard_single_flatten_and_axis_guard():
    from jax.sharding import PartitionSpec
    from repro.runtime.elastic import build_mesh, reshard
    mesh = build_mesh([1], ["data"])
    tree = {"w": np.ones((4, 2), np.float32),
            "b": {"inner": np.zeros(3, np.float32)}}
    seen = []

    def spec_fn(key, leaf):
        seen.append(key)
        return PartitionSpec()

    out = reshard(tree, mesh, spec_fn)
    # one spec_fn call per leaf (the old implementation flattened twice)
    assert len(seen) == 2 and len(set(seen)) == 2
    assert out["w"].shape == (4, 2) and out["b"]["inner"].shape == (3,)
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])

    # a rule written for the pre-shrink mesh names a dead axis: rejected
    # up front with the leaf path and the surviving axis names
    def stale_fn(key, leaf):
        return PartitionSpec("model")

    with pytest.raises(ValueError, match="model"):
        reshard(tree, mesh, stale_fn)

    # tuple-of-names entries are checked too
    def tuple_fn(key, leaf):
        return PartitionSpec(("data", "gone"))

    with pytest.raises(ValueError, match="gone"):
        reshard({"w": np.ones(4)}, mesh, tuple_fn)


def test_largest_feasible_mesh_policy():
    from repro.runtime.elastic import largest_feasible_mesh
    assert largest_feasible_mesh(8, 2) == (4, 2)
    assert largest_feasible_mesh(7, 2) == (3, 2)    # absorb on data axis
    assert largest_feasible_mesh(7, 1) == (7, 1)
    with pytest.raises(ValueError):
        largest_feasible_mesh(1, 2)


# -------------------------------------------------------------------------
# redeal_sellcs: device loss re-deal == fresh partition, byte for byte
# -------------------------------------------------------------------------

def test_redeal_matches_fresh_partition_8_to_7():
    print(run_sub("""
import numpy as np
from repro.core import to_coo
from repro.data import matrices
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, rechunk_sellcs,
                        redeal_sellcs)
coo = to_coo(*matrices.uniform(600, 600, 6000, 0))
sc = coo_to_sellcs(coo, c=8, sigma=64)

def eq(a, b, where):
    if a is None or b is None:
        assert a is None and b is None, where
        return
    if isinstance(a, (tuple, list)):
        assert len(a) == len(b), where
        for i, (x, y) in enumerate(zip(a, b)):
            eq(x, y, f"{where}[{i}]")
        return
    if isinstance(a, (int, float, str)):
        assert a == b, (where, a, b)
        return
    an, bn = np.asarray(a), np.asarray(b)
    assert an.shape == bn.shape and an.tobytes() == bn.tobytes(), where

for part, kw in ((partition_sellcs_rows, {}),
                 (partition_sellcs_nnz, {}),
                 (partition_sellcs_nnz, {"compact_x": True})):
    base8 = part(sc, 8, **kw)
    for nc in (1, 3):
        if part is partition_sellcs_rows and nc != 1:
            continue
        src = base8 if nc == 1 else rechunk_sellcs(base8, nc)
        redone = redeal_sellcs(src, 7, num_chunks=nc)
        fresh = part(sc, 7, **kw)
        if nc != 1:
            fresh = rechunk_sellcs(fresh, nc)
        for name in fresh._fields:
            eq(getattr(redone, name), getattr(fresh, name),
               f"{part.__name__}/{kw}/nc={nc}/{name}")
print("REDEAL_BYTE_IDENTICAL")
"""))


def test_redeal_rejects_legacy_shards():
    """A ShardedSellCS without row_counts cannot be re-dealt (padding is
    indistinguishable from real width-rows) — the error must say so."""
    from repro.spmm import coo_to_sellcs, partition_sellcs_nnz
    from repro.spmm.distributed import redeal_sellcs
    sc = coo_to_sellcs(_coo(), c=4, sigma=32)
    sharded = partition_sellcs_nnz(sc, 2)
    legacy = sharded._replace(row_counts=None)
    with pytest.raises(ValueError, match="row_counts"):
        redeal_sellcs(legacy, 1)
    with pytest.raises(ValueError):
        redeal_sellcs(sharded, 0)


# -------------------------------------------------------------------------
# Fleet.handle_device_loss on a real 8-device host mesh
# -------------------------------------------------------------------------

def test_fleet_device_loss_redeal_8dev():
    """ISSUE acceptance: kill one data-shard device mid-stream; every
    distributed plan re-deals across the survivors and keeps matching the
    to_coo oracle. The cache-hit tenant pays zero builds on arrival."""
    print(run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import PlanSpec, to_coo
from repro.data import matrices
from repro.spmm import Fleet, spmm_coo
coo = to_coo(*matrices.uniform(600, 600, 6000, 0))
coo2 = to_coo(*matrices.uniform(500, 500, 5000, 1))
fleet = Fleet(impl="ref")
spec = PlanSpec(num_devices=8)
op = fleet.register("t0", coo, spec)
hit = fleet.register("t1", to_coo(*matrices.uniform(600, 600, 6000, 0)),
                     spec)
other = fleet.register("t2", coo2, spec)
assert hit.plan is op.plan
assert (hit.stats.sellcs_builds, hit.stats.partition_builds) == (0, 0), \\
    repr(hit.stats)
assert op.stats.sellcs_builds >= 1 and op.stats.partition_builds >= 1
assert fleet.stats.plan_cache_hits == 1
rng = np.random.default_rng(2)
X = jnp.asarray(rng.standard_normal((600, 4)).astype(np.float32))
X2 = jnp.asarray(rng.standard_normal((500, 4)).astype(np.float32))
yo, yo2 = np.asarray(spmm_coo(coo, X)), np.asarray(spmm_coo(coo2, X2))
np.testing.assert_allclose(np.asarray(op @ X), yo, rtol=1e-4, atol=1e-4)
pre_devices = op.plan.spec.num_devices
redone = fleet.handle_device_loss([7])
assert sorted(redone) == ["t0", "t1", "t2"], redone
assert fleet.failed_devices == [7]
assert fleet.stats.device_losses == 1
for o in (op, hit, other):
    nd = o.plan.spec.num_devices
    assert nd < pre_devices, (nd, pre_devices, o.plan.label)
np.testing.assert_allclose(np.asarray(op @ X), yo, rtol=1e-4, atol=1e-4)
np.testing.assert_allclose(np.asarray(hit @ X), yo, rtol=1e-4, atol=1e-4)
np.testing.assert_allclose(np.asarray(other @ X2), yo2, rtol=1e-4,
                           atol=1e-4)
# every surviving cached plan names only live meshes: a returning tenant
# gets the re-dealt plan, not a dead-mesh one
back = fleet.register("t3", to_coo(*matrices.uniform(600, 600, 6000, 0)),
                      spec)
assert back.plan is op.plan
np.testing.assert_allclose(np.asarray(back @ X), yo, rtol=1e-4, atol=1e-4)
print("DEVICE_LOSS_OK")
"""))


# -------------------------------------------------------------------------
# serve --mode fleet end-to-end + the smoke_check SLO gate
# -------------------------------------------------------------------------

def test_serve_fleet_device_loss_e2e(tmp_path):
    """[CI acceptance] the bench-smoke scenario: 3 tenants, device 7 dies
    mid-stream, every request is served and oracle-checked, and the
    emitted document passes check_slo."""
    path = str(tmp_path / "fleet.json")
    run_sub(f"""
from repro.launch import serve
serve.main(["--mode", "fleet", "--tenants", "3", "--slo-ms", "50",
            "--matrix", "mawi_like", "--requests", "12", "--max-batch",
            "4", "--devices", "8", "--impl", "ref", "--fail-device",
            "auto", "--metrics", {path!r}])
""")
    doc = json.loads(open(path).read())
    assert doc["labels"]["mode"] == "fleet"
    assert doc["labels"]["fail_device"] == "7"
    counters = {(c["name"], c["labels"].get("tenant")): c["value"]
                for c in doc["counters"]}
    assert counters[("fleet/device_losses", None)] >= 1
    for t in ("t0", "t1", "t2"):
        assert counters[("batcher/served", t)] >= 4
    assert counters[("fleet/plan_cache_misses", None)] == 2
    assert counters[("fleet/plan_cache_hits", None)] == 1
    hists = {(h["name"], h["labels"].get("tenant")) for h in
             doc["histograms"]}
    assert any(n == "fleet/redeal_s" for n, _ in hists)
    assert any(n == "fleet/flush_postloss_s" for n, _ in hists)
    import benchmarks.smoke_check as sk
    assert sk.check_slo(doc, "fleet.json") == []
    assert sk.check_obs_document(doc, "fleet.json") == []
    assert sk.main([path]) == 0


def test_serve_fleet_rejects_bad_args():
    from repro.launch import serve
    with pytest.raises(SystemExit):
        serve.main(["--mode", "fleet", "--tenants", "0",
                    "--matrix", "mawi_like"])
    with pytest.raises(SystemExit):
        # --fail-device needs a mesh to kill a device from
        serve.main(["--mode", "fleet", "--tenants", "2",
                    "--matrix", "mawi_like", "--fail-device", "auto"])


# -------------------------------------------------------------------------
# smoke_check.check_slo unit gates
# -------------------------------------------------------------------------

def _fleet_doc(**over):
    labels = {"mode": "fleet", "tenants": "2", "requests": "4",
              "slo_ms": "50.0", "backend": "cpu", "fail_device": "7"}
    labels.update(over.pop("labels", {}))
    hist = [{"name": "fleet/flush_s", "labels": {"tenant": t},
             "count": 2, "p50": 0.001} for t in ("t0", "t1")]
    hist += [{"name": "fleet/redeal_s", "labels": {"tenant": "t0"},
              "count": 1},
             {"name": "fleet/flush_postloss_s", "labels": {"tenant": "t0"},
              "count": 1}]
    doc = {"schema": "repro.obs/v1", "labels": labels,
           "counters": [{"name": "batcher/served",
                         "labels": {"tenant": t}, "value": 4.0}
                        for t in ("t0", "t1")] +
                       [{"name": "fleet/device_losses", "labels": {},
                         "value": 1.0}],
           "gauges": [], "histograms": hist, "residuals": []}
    doc.update(over)
    return doc


def test_check_slo_green_and_disarmed():
    import benchmarks.smoke_check as sk
    assert sk.check_slo(_fleet_doc(), "x") == []
    # any non-fleet document passes untouched
    assert sk.check_slo(_fleet_doc(labels={"mode": "spmv"}), "x") == []
    assert sk.check_slo({"labels": {}}, "x") == []
    # no injected loss: the loss gates disarm
    ok = _fleet_doc(labels={"fail_device": ""})
    ok["histograms"] = [h for h in ok["histograms"]
                        if h["name"] == "fleet/flush_s"]
    ok["counters"] = [c for c in ok["counters"]
                      if c["name"] != "fleet/device_losses"]
    assert sk.check_slo(ok, "x") == []


def test_check_slo_gates_fire():
    import benchmarks.smoke_check as sk
    # a dropped request
    doc = _fleet_doc()
    doc["counters"][1]["value"] = 3.0
    assert any("dropped" in p for p in sk.check_slo(doc, "x"))
    # a tenant that never served
    doc = _fleet_doc()
    doc["histograms"] = [h for h in doc["histograms"]
                         if h["labels"].get("tenant") != "t1"
                         or h["name"] != "fleet/flush_s"]
    assert any("never served" in p for p in sk.check_slo(doc, "x"))
    # an unhandled loss / a missing re-deal / no post-loss flushes
    doc = _fleet_doc()
    doc["counters"] = doc["counters"][:2]
    probs = sk.check_slo(doc, "x")
    assert any("never handled" in p for p in probs)
    doc = _fleet_doc()
    doc["histograms"] = [h for h in doc["histograms"]
                         if h["name"] != "fleet/redeal_s"]
    assert any("re-dealt" in p for p in sk.check_slo(doc, "x"))
    doc = _fleet_doc()
    doc["histograms"] = [h for h in doc["histograms"]
                         if h["name"] != "fleet/flush_postloss_s"]
    assert any("after the loss" in p for p in sk.check_slo(doc, "x"))
    # the p50-vs-budget comparison arms only off cpu
    doc = _fleet_doc()
    for h in doc["histograms"]:
        if h["name"] == "fleet/flush_s":
            h["p50"] = 9.0
    assert sk.check_slo(doc, "x") == []
    doc["labels"]["backend"] = "tpu"
    assert any("exceeds" in p for p in sk.check_slo(doc, "x"))
    # a fleet doc without a tenants label is itself a problem
    assert sk.check_slo({"labels": {"mode": "fleet"}}, "x")
