"""repro.data — deterministic data pipeline + synthetic matrix generators."""
from .pipeline import TokenPipeline, make_batch_iterator
from . import matrices

__all__ = ["TokenPipeline", "make_batch_iterator", "matrices"]
