"""Restarted GMRES over the sparse core — the paper's §1 motivating workload
("iterative methods for sparse linear systems such as GMRES").

Solves (I + 0.05·A_norm) x = b on an RMAT graph with GMRES(20), then the
adjoint system (I + 0.05·A_norm^T) y = b — both through ONE
``repro.spmm.SparseOperator`` handle: the selector picks the plan once,
``op @ v`` drives the forward solve and ``op.T @ v`` the transposed one
over the same stored stream (no second conversion, no second partition —
the operator stats prove it). The conversion cost amortizes over all
inner iterations of both solves (the §7 economics again).

Run:  PYTHONPATH=src python examples/gmres.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import PlanSpec, to_coo
from repro.data import matrices
from repro.spmm import SparseOperator

rows, cols, vals, shape = matrices.rmat(scale=12, edge_factor=10, seed=0)
n = shape[0]
deg = np.bincount(cols, minlength=n).astype(np.float32)
coo = to_coo(rows, cols, 1.0 / np.maximum(deg[cols], 1.0), shape)

t0 = time.perf_counter()
A = SparseOperator.from_coo(coo, PlanSpec(num_devices=1), impl="ref",
                            k_hint=1, num_spmvs=500)
print(f"operator plan: {A.plan.label} "
      f"({(time.perf_counter() - t0) * 1e3:.0f} ms to realize)")


def shifted(op):
    """(I + 0.05 op) v — diagonally dominant, guaranteed convergence."""
    return lambda v: v + 0.05 * (op @ v)


def gmres(op, b, m=20, restarts=10, tol=1e-8):
    x = jnp.zeros_like(b)
    for outer in range(restarts):
        r = b - op(x)
        beta = float(jnp.linalg.norm(r))
        if beta < tol:
            break
        V = [r / beta]
        H = np.zeros((m + 1, m))
        for j in range(m):
            w = op(V[j])
            for i in range(j + 1):                 # modified Gram-Schmidt
                H[i, j] = float(jnp.vdot(V[i], w))
                w = w - H[i, j] * V[i]
            H[j + 1, j] = float(jnp.linalg.norm(w))
            if H[j + 1, j] < 1e-12:
                m = j + 1
                break
            V.append(w / H[j + 1, j])
        e1 = np.zeros(m + 1)
        e1[0] = beta
        y, *_ = np.linalg.lstsq(H[: m + 1, :m], e1, rcond=None)
        x = x + jnp.stack(V[:m], axis=1) @ jnp.asarray(y, jnp.float32)
        res = float(jnp.linalg.norm(b - op(x)))
        print(f"  restart {outer}: residual {res:.3e}")
        if res < tol:
            break
    return x


b = jnp.asarray(np.random.default_rng(1).standard_normal(n)
                .astype(np.float32))
t0 = time.perf_counter()
x = gmres(shifted(A), b)
res = float(jnp.linalg.norm(b - shifted(A)(x)) / jnp.linalg.norm(b))
print(f"forward GMRES done in {time.perf_counter() - t0:.2f}s, "
      f"relative residual {res:.2e}")
assert res < 1e-5

# adjoint solve through the SAME plan: op.T shares the realized stream,
# so the stats show one build total across both solves
builds_before = A.stats.sellcs_builds
t0 = time.perf_counter()
y = gmres(shifted(A.T), b)
res_t = float(jnp.linalg.norm(b - shifted(A.T)(y)) / jnp.linalg.norm(b))
print(f"adjoint GMRES done in {time.perf_counter() - t0:.2f}s, "
      f"relative residual {res_t:.2e}")
assert res_t < 1e-5
assert A.stats.sellcs_builds == builds_before, "transpose must not rebuild"
print(f"operator stats: {A.stats}")
print("gmres OK")
