"""StarCoder2-7B [arXiv:2402.19173]: GQA(kv=4), RoPE, LayerNorm, GELU 4x MLP."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", n_layers=32, d_model=4608, n_heads=36, kv_heads=4,
    d_ff=18432, vocab=49152, head_dim=128, rope_theta=1e5,
    norm="ln", mlp_act="gelu",
    block_pattern=("attn",), mlp_pattern=("dense",))

REDUCED = ModelConfig(
    name="starcoder2-7b-reduced", n_layers=2, d_model=72, n_heads=6,
    kv_heads=2, d_ff=288, vocab=256, head_dim=16, norm="ln", mlp_act="gelu",
    block_pattern=("attn",), mlp_pattern=("dense",),
    compute_dtype=jnp.float32, loss_chunk=16)
