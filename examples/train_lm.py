"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
on the synthetic bigram-structured pipeline and watch the loss fall well
below the unigram entropy (proof of learning, not just running), then
train a sparse graph-mixer head whose backward pass runs end-to-end
through one ``repro.spmm.SparseOperator`` (forward ``A @ h``, cotangent
``A^T g`` via the operator's transpose multiply — no dense A, ever).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(~100M params; on this 1-core CPU container use --small for a quick pass.)
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import train as train_cli
from repro.models.model import ModelConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--small", action="store_true",
                help="8M params / fewer steps (CI-friendly)")
args = ap.parse_args()

if args.small:
    # ~8M params
    cfg_args = ["--arch", "llama3.2-1b", "--reduced",
                "--steps", str(min(args.steps, 60)),
                "--batch", "8", "--seq", "64", "--lr", "3e-3"]
else:
    # ~100M params: register an ad-hoc config through the llama file's
    # REDUCED slot is not enough — drive train.py with a custom config
    import repro.configs.llama3_2_1b as mod
    cfg100 = ModelConfig(
        name="llama-100m", n_layers=8, d_model=512, n_heads=8, kv_heads=4,
        d_ff=2048, vocab=32768, head_dim=64, tie_embeddings=True,
        block_pattern=("attn",), mlp_pattern=("dense",),
        compute_dtype=jnp.float32, loss_chunk=64)
    mod.REDUCED = cfg100          # temporarily alias for the CLI
    cfg_args = ["--arch", "llama3.2-1b", "--reduced",
                "--steps", str(args.steps), "--batch", "8", "--seq", "128",
                "--lr", "1e-3", "--log-every", "10"]

final_loss = train_cli.main(cfg_args + ["--ckpt-dir", "/tmp/train_lm_ckpt",
                                        "--save-every", "50"])
print(f"[example] final loss: {final_loss:.3f}")

# ---------------------------------------------------------------------------
# Sparse backward through the operator: a fixed unstructured mixing graph
# sits inside the loss; both directions of the gradient flow run through
# the ONE realized plan (forward = op.matmul, cotangent = op.rmatmul).
# ---------------------------------------------------------------------------
from repro.core import PlanSpec, to_coo
from repro.data import matrices
from repro.spmm import SparseOperator, sparse_matmul

print("[example] sparse-mixer phase: backward via the operator transpose")
g_rows, g_cols, _, g_shape = matrices.rmat(scale=9, edge_factor=8, seed=3)
n_nodes = g_shape[0]
deg = np.bincount(g_cols, minlength=n_nodes).astype(np.float32)
A = SparseOperator.from_coo(
    to_coo(g_rows, g_cols, 1.0 / np.maximum(deg[g_cols], 1.0), g_shape),
    PlanSpec(num_devices=1), impl="ref", k_hint=16, num_spmvs=200)

rng = np.random.default_rng(0)
d_feat, d_out = 32, 16
feats = jnp.asarray(rng.standard_normal((n_nodes, d_feat)), jnp.float32)
w_true = jnp.asarray(rng.standard_normal((d_feat, d_out)), jnp.float32)
targets = sparse_matmul(A, feats @ w_true)         # realizable optimum
w = jnp.zeros((d_feat, d_out), jnp.float32)


def mixer_loss(w):
    pred = sparse_matmul(A, feats @ w)             # bwd: A^T g via rmatmul
    return jnp.mean((pred - targets) ** 2)


# step size 1/L via power iteration on the quadratic's Hessian map
# H(v) = 2/(n·d_out) · F^T A^T A F v — itself four operator multiplies
v = jnp.asarray(rng.standard_normal((d_feat, d_out)), jnp.float32)
for _ in range(8):
    v = v / jnp.linalg.norm(v)
    hv = feats.T @ sparse_matmul(A.T, sparse_matmul(A, feats @ v))
    v = 2.0 / (n_nodes * d_out) * hv
lr = 1.0 / float(jnp.linalg.norm(v))

grad_fn = jax.value_and_grad(mixer_loss)
loss0, _ = grad_fn(w)
for step in range(60):
    loss, g = grad_fn(w)
    w = w - lr * g
print(f"[example] sparse-mixer loss {float(loss0):.4f} -> {float(loss):.4f} "
      f"({A.stats.multiplies} operator multiplies)")
assert float(loss) < 0.1 * float(loss0), "sparse backward failed to learn"
print("[example] sparse backward through the operator OK")
