"""repro.spmm — the multi-RHS SpMM engine (``Y = A @ X``, ``X: [n, k]``).

Layers (one module each):

  ``sellcs``     SELL-C-σ storage: lane-height slices, σ-window row sorting
  ``reference``  pure-jnp oracles per format (the XLA fallback path)
  ``kernels``    tiled Pallas kernels with a k-tile grid dimension
  ``batching``   request batching for the serve path (k SpMVs -> 1 SpMM)
  ``distributed``  shard_map schedules over a mesh (row bands / merge spans)
  ``operator``   SparseOperator: the stable partition-once/multiply-many
                 handle with an atomic plan swap (online format migration)
  ``fleet``      Fleet: multi-tenant operator registry — fingerprint-keyed
                 plan cache, device-loss re-deal onto the survivors

SpMV is the k = 1 special case throughout; ``repro.core.spmv`` remains the
single-vector entry point and routes SELL-C-σ matrices here.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.formats import COO, CSR, BlockedSparse
from . import reference
from .batching import (FleetBatcher, QueueFull, RequestBatcher,
                       SpmvRequest, batch_spmv)
from .distributed import (ShardedSellCS, partition_sellcs_nnz,
                          partition_sellcs_rows, rechunk_sellcs,
                          redeal_sellcs, spmm_merge_distributed,
                          spmm_row_distributed)
from .kernels import choose_k_tile, csr_spmm, sellcs_spmm, tiled_spmm
from .operator import (OperatorStats, RealizedPlan, SparseOperator,
                       TransposedOperator, coo_fingerprint, sparse_matmul)
from .fleet import Fleet, FleetStats
from .reference import (spmm_blocked, spmm_coo, spmm_coo_t, spmm_csr,
                        spmm_ref, spmm_sellcs, spmm_sellcs_t)
from .sellcs import SellCS, coo_to_sellcs


def spmm(mat, x: jax.Array, *, impl: str = "auto",
         k_tile: Optional[int] = None, op: str = "N") -> jax.Array:
    """Multiply ``Y = A @ X`` for any supported format.

    impl in {"auto", "ref", "pallas", "pallas_interpret"} — same contract
    as ``core.spmv.spmv``: "auto" takes the Pallas path on TPU for formats
    with a kernel, the XLA reference otherwise.

    ``op='T'`` computes ``Y = A^T X`` over the same stored stream
    (``X: [m, k]``, ``Y: [n, k]``); the Pallas path supports it on
    SELL-C-σ (the scatter-accumulate transpose kernel), the reference
    path on SELL-C-σ and COO. A symmetric one-triangle SELL-C-σ matrix
    accepts either op (``A^T == A``).
    """
    from repro.kernels.tiling import TiledSparse
    if op not in ("N", "T"):
        raise ValueError(f"op must be 'N' or 'T', got {op!r}")
    if impl in ("pallas", "pallas_interpret"):
        interpret = impl == "pallas_interpret"
        x2 = x[:, None] if x.ndim == 1 else x
        if op == "T" and not isinstance(mat, SellCS):
            raise TypeError(
                f"no transpose SpMM kernel for {type(mat).__name__}; "
                "convert with coo_to_sellcs")
        if isinstance(mat, TiledSparse):
            y = tiled_spmm(mat, x2, k_tile=k_tile, interpret=interpret)
        elif isinstance(mat, CSR):
            y = csr_spmm(mat, x2, k_tile=k_tile, interpret=interpret)
        elif isinstance(mat, SellCS):
            y = sellcs_spmm(mat, x2, k_tile=k_tile, interpret=interpret,
                            op=op)
        else:
            raise TypeError(
                f"no SpMM kernel for {type(mat).__name__}; convert with "
                "coo_to_sellcs / repro.kernels.coo_to_tiled / coo_to_csr")
        return y[:, 0] if x.ndim == 1 else y
    if impl == "auto":
        on_tpu = jax.default_backend() == "tpu"
        if on_tpu and isinstance(mat, (TiledSparse, CSR, SellCS)) and \
                (op == "N" or isinstance(mat, SellCS)):
            return spmm(mat, x, impl="pallas", k_tile=k_tile, op=op)
    return spmm_ref(mat, x, op=op)


__all__ = [
    "SellCS", "coo_to_sellcs", "spmm", "choose_k_tile",
    "tiled_spmm", "csr_spmm", "sellcs_spmm",
    "spmm_ref", "spmm_coo", "spmm_csr", "spmm_blocked", "spmm_sellcs",
    "spmm_sellcs_t", "spmm_coo_t",
    "RequestBatcher", "FleetBatcher", "QueueFull", "SpmvRequest",
    "batch_spmv", "reference",
    "ShardedSellCS", "partition_sellcs_rows", "partition_sellcs_nnz",
    "rechunk_sellcs", "redeal_sellcs",
    "spmm_row_distributed", "spmm_merge_distributed",
    "SparseOperator", "TransposedOperator", "RealizedPlan",
    "OperatorStats", "coo_fingerprint", "sparse_matmul",
    "Fleet", "FleetStats",
    "COO", "CSR", "BlockedSparse",
]
