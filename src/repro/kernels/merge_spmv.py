"""Pallas TPU kernel: merge-path SpMV on flat CSR (paper §3.3).

Merrill & Garland's algorithm cuts the merge path over (row ends, nonzeros)
into P equal-diagonal spans, so every program does exactly the same number of
(FMA | row-close) operations — perfect load balance for any row distribution,
including the mawi single-dense-row pathology.

TPU adaptation (DESIGN §2): the binary searches and the row walk move to
*convert time* (merge_plan below) — each span becomes a fixed-shape record
(cols, vals, seg) of D nonzeros with its local row offsets seg. In-kernel,
the per-row reduction is a one-hot matmul (D x R) — MXU work instead of a
scatter. Each program writes its partial rows to its own output slab; the
paper's sequential carry-out fixup becomes a jnp scatter-add epilogue over
the (P, R) partials (ops.merge_spmv).

The only irregular memory op left is the x-gather (x[cols]) from a
VMEM-resident x — a dynamic VMEM gather, the one pattern Mosaic supports for
this (and trivially correct in interpret mode).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.formats import CSR
from repro.core.mergepath import merge_path_partition_np


def default_num_spans(m: int, nnz: int) -> int:
    """Span-count heuristic shared by the SpMV and SpMM merge paths: one
    span per ~4096 merge items, clamped to [8, 1024]."""
    return max(min((m + nnz) // 4096, 1024), 8)


def carry_out_fixup(partials: jax.Array, row_starts: jax.Array,
                    m: int) -> jax.Array:
    """The paper's sequential carry-out fixup as one scatter-add: place each
    span's local rows at its row_start offset (span boundaries overlap by
    <= 1 row, which the add resolves). ``partials`` is (P, R) for SpMV or
    (P, R, K) for SpMM; returns (m,) / (m, K)."""
    R = partials.shape[1]
    idx = row_starts[:-1, None] + jnp.arange(R, dtype=jnp.int32)[None]
    y = jnp.zeros((m + R,) + partials.shape[2:], jnp.float32)
    return y.at[idx].add(partials)[:m]


class MergePlan(NamedTuple):
    cols: jax.Array        # int32[P, D]
    vals: jax.Array        # f32[P, D]
    seg: jax.Array         # int32[P, D] — row index local to the span
    row_starts: jax.Array  # int32[P+1]
    r_width: int           # R — padded local row width (static)


def merge_plan(csr: CSR, num_spans: int) -> MergePlan:
    """Convert-time planning: equal-diagonal merge spans -> fixed-shape
    per-span records."""
    row_ptr = np.asarray(csr.row_ptr, np.int64)
    col_ind = np.asarray(csr.col_ind)
    data = np.asarray(csr.data)
    m = row_ptr.shape[0] - 1
    nnz = int(row_ptr[-1])
    P = num_spans
    D = max(-(-(m + nnz) // P), 1)
    R = max(-(-(D + 1) // 128) * 128, 128)

    row_starts, nnz_starts = merge_path_partition_np(row_ptr, P)
    row_of_nnz = (np.searchsorted(row_ptr, np.arange(nnz), side="right") - 1
                  ).astype(np.int64) if nnz else np.zeros(0, np.int64)

    cols = np.zeros((P, D), np.int32)
    vals = np.zeros((P, D), data.dtype if data.size else np.float32)
    seg = np.zeros((P, D), np.int32)
    for p in range(P):
        j0, j1 = int(nnz_starts[p]), int(nnz_starts[p + 1])
        ln = j1 - j0
        if ln == 0:
            continue
        cols[p, :ln] = col_ind[j0:j1]
        vals[p, :ln] = data[j0:j1]
        seg[p, :ln] = row_of_nnz[j0:j1] - row_starts[p]
    return MergePlan(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(seg),
                     jnp.asarray(np.asarray(row_starts, np.int32)), int(R))


def _kernel(cols_ref, vals_ref, seg_ref, x_ref, out_ref, *, r_width: int):
    cols = cols_ref[0]                       # (D,) int32
    vals = vals_ref[0].astype(jnp.float32)   # (D,)
    seg = seg_ref[0]                         # (D,) int32
    xs = jnp.take(x_ref[...], cols, axis=0,
                  mode="clip").astype(jnp.float32)       # VMEM gather
    prod = vals * xs                                      # (D,)
    # one-hot (D, R) matmul replaces the scatter — MXU-native reduction
    onehot = (seg[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, r_width), 1)
              ).astype(jnp.float32)                       # (D, R)
    out_ref[0] = jax.lax.dot_general(
        prod, onehot, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (R,)


@functools.partial(jax.jit, static_argnames=("r_width", "interpret"))
def merge_spmv_partials(plan_cols, plan_vals, plan_seg, x_pad, *,
                        r_width: int, interpret: bool = False):
    P, D = plan_cols.shape
    np_ = x_pad.shape[0]
    grid_spec = pl.GridSpec(
        grid=(P,),
        in_specs=[
            pl.BlockSpec((1, D), lambda p: (p, 0)),
            pl.BlockSpec((1, D), lambda p: (p, 0)),
            pl.BlockSpec((1, D), lambda p: (p, 0)),
            pl.BlockSpec((np_,), lambda p: (0,)),
        ],
        out_specs=pl.BlockSpec((1, r_width), lambda p: (p, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, r_width=r_width),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((P, r_width), jnp.float32),
        interpret=interpret,
    )(plan_cols, plan_vals, plan_seg, x_pad)
