"""Sharding rules: param-path -> PartitionSpec (2D TP x FSDP), batch and
cache specs per input shape.

Conventions (single pod; the multi-pod "pod" axis is pure DP and only
carries the batch):
  * weights are 2D-sharded: the TP dimension (heads / ffn / experts / vocab)
    over "model", the other matrix dimension over "data" (FSDP — GSPMD
    all-gathers shards at use, reduce-scatters grads, so optimizer state is
    ZeRO-sharded for free);
  * any dimension not divisible by its axis size falls back to replication
    on that axis (guarded here, so every assigned arch lowers);
  * decode KV caches shard batch over DP and sequence over "model"
    (context-parallel decode); for long_500k (batch=1) sequence is sharded
    over EVERY axis.
"""
from __future__ import annotations

import re
from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import dp_axes


def path_str(path) -> str:
    """Normalize a jax key path to 'a/b/0/c' (rules match on this form)."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= _axis_size(mesh, n)
        return out
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def _guard(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop axes that don't divide their dimension."""
    fixed = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            fixed.append(None)
        elif dim % _axis_size(mesh, ax) == 0:
            fixed.append(ax)
        else:
            fixed.append(None)
    return P(*fixed)


# rules: regex on the jax keystr path; entries are spec TEMPLATES where the
# leading group-stack dimension is added automatically for group params.
_PARAM_RULES = [
    (r"embed", ("model", "data")),
    (r"unembed/w$", ("data", "model")),
    (r"vision_proj/w$", (None, "model")),
    (r"(wq|wk|wv)/w$", ("data", "model")),
    (r"(wq|wk|wv)/b$", ("model",)),
    (r"wo/w$", ("model", "data")),
    (r"wo/b$", (None,)),
    # MoE experts [E, d, f] / [E, f, d]: expert-parallel over "model" when E
    # divides, else ffn-parallel (guard handles the fallback ordering below)
    (r"mlp/w_gate$", ("model", "data", None)),
    (r"mlp/w_up$", ("model", "data", None)),
    (r"mlp/w_down$", ("model", None, "data")),
    (r"router/w$", ("data", None)),
    # dense MLP
    (r"mlp/(w_gate|w_up|w_in)/w$", ("data", "model")),
    (r"mlp/(w_in|w_gate|w_up)/b$", ("model",)),
    (r"mlp/(w_down|w_out)/w$", ("model", "data")),
    (r"mlp/(w_down|w_out)/b$", (None,)),
    # SSM
    (r"in_proj/w$", ("data", "model")),
    (r"out_proj/w$", ("model", "data")),
    (r"conv/w$", (None, "model")),
    (r"conv/b$", ("model",)),
    (r"(A_log|D|dt_bias|norm_scale)$", ("model",)),
]


def _moe_fallback(template, shape, mesh):
    """If experts don't divide "model", switch to ffn-parallel."""
    if len(shape) == 3 and shape[0] % _axis_size(mesh, "model") != 0:
        if template == ("model", "data", None):       # w_gate/w_up [E,d,f]
            return (None, "data", "model")
        if template == ("model", None, "data"):       # w_down [E,f,d]
            return (None, "model", "data")
    return template


def param_spec_for(key: str, leaf_shape: Tuple[int, ...], mesh: Mesh,
                   grouped: bool, profile: str = "tp") -> P:
    core_shape = leaf_shape[1:] if grouped else leaf_shape
    if profile == "fsdp":
        # FSDP-only: no tensor parallelism — every >=2D weight shards its
        # largest dimension over the WHOLE mesh (ZeRO-3); activations are
        # fully batch-parallel. Right trade for models whose per-layer
        # matmuls are too small to amortize TP collectives (§Perf iter 2).
        if len(core_shape) >= 2:
            all_axes = tuple(mesh.axis_names)
            dim = int(max(range(len(core_shape)),
                          key=lambda i: core_shape[i]))
            spec = [None] * len(core_shape)
            if core_shape[dim] % _axis_size(mesh, all_axes) == 0:
                spec[dim] = all_axes
            elif core_shape[dim] % _axis_size(mesh, "model") == 0:
                spec[dim] = "model"
            out = P(*spec)
            return P(*((None,) + tuple(out))) if grouped else out
        return P(*((None,) * len(leaf_shape)))
    for pat, template in _PARAM_RULES:
        if re.search(pat, key):
            if len(template) != len(core_shape):
                continue
            if "mlp" in key and len(core_shape) == 3:
                template = _moe_fallback(template, core_shape, mesh)
            spec = _guard(template, core_shape, mesh)
            return P(*((None,) + tuple(spec))) if grouped else spec
    # norms, scalars, anything unmatched: replicate
    return P(*((None,) * len(leaf_shape)))


def param_shardings(params_shape: Any, mesh: Mesh,
                    profile: str = "tp") -> Any:
    """Map an eval_shape pytree of params -> NamedShardings."""
    def one(path, leaf):
        key = path_str(path)
        grouped = "groups" in key
        spec = param_spec_for(key, leaf.shape, mesh, grouped, profile)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_state_shardings(opt_state_shape: Any, params_shape: Any,
                        mesh: Mesh, profile: str = "tp") -> Any:
    """Optimizer state mirrors param shardings (m/v/vr/vc); scalars
    replicate. Matching is by shape suffix: a state leaf either has the
    same shape as some param (m, v) or a reduced shape (adafactor factors,
    step) -> replicate reduced leaves."""
    param_specs = {}

    def collect(path, leaf):
        key = path_str(path)
        grouped = "groups" in key
        param_specs[leaf.shape] = param_spec_for(key, leaf.shape, mesh,
                                                 grouped, profile)
    jax.tree_util.tree_map_with_path(collect, params_shape)

    def one(path, leaf):
        spec = param_specs.get(leaf.shape)
        if spec is None:
            spec = P(*((None,) * len(leaf.shape)))
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, opt_state_shape)


def batch_sharding(mesh: Mesh, batch: int) -> NamedSharding:
    dp = dp_axes(mesh)
    if batch % _axis_size(mesh, tuple(dp)) != 0:
        return NamedSharding(mesh, P())            # e.g. long_500k B=1
    return NamedSharding(mesh, P(dp, None))


def cache_shardings(cache_shape: Any, mesh: Mesh, batch: int) -> Any:
    """KV caches [G,B,S,Hkv,D] / SSM states [G,B,...]: batch over DP when it
    divides, else the sequence dimension over everything."""
    dp = dp_axes(mesh)
    dp_size = _axis_size(mesh, tuple(dp))
    batch_sharded = batch % dp_size == 0

    def one(path, leaf):
        shape = leaf.shape
        spec: list = [None] * len(shape)
        if len(shape) >= 2:
            if batch_sharded:
                spec[1] = dp                                 # B over DP
                if len(shape) == 5 and shape[2] % _axis_size(
                        mesh, "model") == 0:
                    spec[2] = "model"                        # KV seq
                elif len(shape) == 5 and shape[2] % _axis_size(
                        mesh, "model") != 0:
                    # ssm_state [G,B,H,P,N]: heads over model
                    if shape[2] % _axis_size(mesh, "model") == 0:
                        spec[2] = "model"
                elif len(shape) == 4 and shape[3] % _axis_size(
                        mesh, "model") == 0:
                    spec[3] = "model"                        # conv channels
            else:
                # B=1 (long_500k): shard the long axis over every axis
                all_axes = tuple(mesh.axis_names)
                long_dim = max(range(len(shape)), key=lambda i: shape[i])
                if shape[long_dim] % _axis_size(mesh, all_axes) == 0:
                    spec[long_dim] = all_axes
                elif shape[long_dim] % _axis_size(mesh, "model") == 0:
                    spec[long_dim] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def with_shardings(shape_tree: Any, sharding_tree: Any) -> Any:
    """Attach shardings to a ShapeDtypeStruct pytree."""
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shape_tree, sharding_tree)
