"""Distributed paths on 8 host-platform devices.

These run in SUBPROCESSES because the device-count flag must be set before
jax initializes, and the rest of the suite must keep seeing 1 device."""
import os
import subprocess
import sys


ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_spmv_row_and_merge_distributed():
    print(run_sub("""
import jax, numpy as np, jax.numpy as jnp
from repro.core import to_coo, spmv_dense_oracle
from repro.core.distributed import (partition_rows, partition_nnz,
                                    spmv_row_distributed,
                                    spmv_merge_distributed)
from repro.data import matrices
from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ("data",))
for gen in [matrices.uniform(500, 430, 4000, 0),
            matrices.mawi_like(400, 400, 3000, 0.4, 1),
            matrices.mesh2d(21)]:
    coo = to_coo(*gen)
    x = jnp.asarray(np.random.default_rng(3).standard_normal(
        coo.shape[1]).astype(np.float32))
    yo = spmv_dense_oracle(coo, x)
    y1 = spmv_row_distributed(partition_rows(coo, 8), x, mesh)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(yo),
                               rtol=1e-4, atol=1e-4)
    y2 = spmv_merge_distributed(partition_nnz(coo, 8), x, mesh)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(yo),
                               rtol=1e-4, atol=1e-4)
print("distributed spmv OK")
"""))


def test_sharded_train_step_matches_single_device():
    """Same math: 2x4 mesh train step == single-device train step."""
    print(run_sub("""
import dataclasses
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.models.model import init_params
from repro.optim import make_optimizer, constant_lr
from repro.launch.mesh import make_mesh
from repro.launch.steps import TrainState, make_train_step
from repro.launch import shardings as shd

cfg = get_config("llama3.2-1b", reduced=True)
cfg = dataclasses.replace(cfg, d_model=64, n_heads=4, kv_heads=2)
opt = make_optimizer("adamw", constant_lr(1e-2))
params = init_params(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)

# single device
step1 = jax.jit(make_train_step(cfg, opt))
s1, m1 = step1(TrainState(params, opt.init(params)), {"tokens": tokens})

# 2x4 mesh
mesh = make_mesh((2, 4), ("data", "model"))
cfg2 = dataclasses.replace(cfg, batch_axes=("data",))
with mesh:
    p2 = jax.device_put(params, shd.param_shardings(params, mesh))
    st2 = TrainState(p2, opt.init(p2))
    step2 = jax.jit(make_train_step(cfg2, opt))
    s2, m2 = step2(st2, {"tokens": jax.device_put(
        tokens, shd.batch_sharding(mesh, 8))})
np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                           rtol=2e-3)
w1 = np.asarray(jax.tree_util.tree_leaves(s1.params)[0], np.float32)
w2 = np.asarray(jax.tree_util.tree_leaves(s2.params)[0], np.float32)
np.testing.assert_allclose(w1, w2, rtol=2e-2, atol=2e-4)
print("sharded == single-device train step OK")
"""))


def test_elastic_reshard_and_shrink():
    print(run_sub("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.runtime.elastic import (build_mesh, largest_feasible_mesh,
                                   reshard)
assert largest_feasible_mesh(8, 4) == (2, 4)
assert largest_feasible_mesh(6, 2) == (3, 2)
mesh8 = build_mesh((2, 4), ("data", "model"))
mesh4 = build_mesh((1, 4), ("data", "model"), devices=jax.devices()[:4])
tree = {"w": jnp.arange(64.0).reshape(8, 8)}
spec_fn = lambda key, leaf: P("data", "model")
t8 = reshard(tree, mesh8, spec_fn)
t4 = reshard(t8, mesh4, spec_fn)
np.testing.assert_array_equal(np.asarray(t4["w"]), np.asarray(tree["w"]))
print("elastic reshard OK")
"""))


def test_dryrun_entry_small_mesh():
    """The dryrun module itself (flag handling + lower + compile) on a tiny
    mesh via direct function use."""
    print(run_sub("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + \
    os.environ.get("XLA_FLAGS", "")
import jax
from repro.launch.mesh import make_mesh
from repro.launch.steps import lower_cell, cell_config
import dataclasses
mesh = make_mesh((2, 4), ("data", "model"))
import repro.configs.llama3_2_1b as mod
cfg = dataclasses.replace(mod.REDUCED, batch_axes=("data",))
# reuse the real lower_cell machinery on the reduced config
from repro.launch import steps
from repro.configs.base import SHAPES
spec = SHAPES["train_4k"]
lowered = lower_cell("llama3.2-1b", "train_4k", mesh, cfg=dataclasses.replace(
    cfg, loss_chunk=64))
compiled = lowered.compile()
assert compiled.memory_analysis() is not None
print("mini dryrun OK")
""", devices=8))
