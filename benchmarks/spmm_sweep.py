"""SpMM k-sweep: GFLOP/s and achieved arithmetic intensity vs the roofline
prediction, per format, for k in 1..256 (powers of two).

The point of the table: the matrix stream is paid once per multiply, so
intensity — and with it the attainable fraction of peak — must climb
monotonically with k until the ridge. ``ai`` uses each format's *actual*
``storage_bytes()`` (fill-in and padding included); ``ai_ideal`` is the
roofline model's ideal-CSR prediction from ``repro.roofline``.

  PYTHONPATH=src python -m benchmarks.spmm_sweep --scale 0.02 --json out.json

``--devices P`` additionally times the distributed SELL-C-σ schedules
(``repro.spmm.distributed``) on a P-device mesh per k; when jax has not
been imported yet the host-platform device count is forced automatically.
``--chunks 1,2,8`` sweeps the merge-psum pipelining depth too — one
``chunks=<c>`` row per count, so ``benchmarks.smoke_check`` can gate the
chunked rows against the monolithic (``chunks=1``) baseline.

``--mesh 8x1,4x2`` sweeps 2-D (data, model) mesh factorizations instead of
(or next to) the 1-D ``--devices`` mesh: one ``@PdxPmmesh`` row group per
shape, each with the 2-D traffic model's ``model_us`` prediction, so
``smoke_check`` can gate the model-sharded rows against the pure-data
(``Pm = 1``) baseline wherever the model says the model axis pays.

``--compact-x on,off`` adds a sparsity-aware X gather column to every
distributed row group: one ``cx=on`` row (per-shard column compaction,
gathered ``[n_touched, kc]`` slabs) next to each ``cx=off`` row
(replicated X), each priced by the compact traffic model with the
partitioner's *measured* mean ``n_touched``, so
``smoke_check.check_compact_regressions`` can gate the compacted rows
wherever the model says the gather pays (disarmed on ``backend=cpu``
like the mesh gate — a host-platform mesh shares one X buffer).

``--gather upfront,overlap`` sweeps the compact-X gather schedule next to
the up-front one: each compacted (``cx=on``) row grows a ``gx=<mode>``
sibling per non-default mode (``overlap`` double-buffers the per-span
gather against the merge chunk stream, ``fused`` folds the indirection
into the Pallas kernel's scalar prefetch), each priced by the
exposed-gather roofline term (``spmm_distributed_gather_s``) and stamped
with ``exposed_gather_us=`` so ``smoke_check.check_gather_overlap`` can
gate the hidden-gather rows against their up-front baseline wherever the
model says hiding pays (disarmed on ``backend=cpu`` like the other mesh
gates).

``--op N,T`` adds the transpose multiply (``A^T X``, X read at [m, k])
next to each forward row of every distributed group: one ``op=T`` row per
``op=N`` row, each priced by the op-aware traffic model (dense slot-space
X read, full-column partial, scatter psum), so
``smoke_check.check_transpose_regressions`` can gate the transpose rows
against the model-predicted N-to-T slowdown (disarmed on ``backend=cpu``
like the other mesh gates).

Emits the same CSV columns and JSON schema as ``benchmarks.run``.
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np


def sweep_matrix(name: str, coo, ks, impl: str, reps: int, csv) -> None:
    import jax.numpy as jnp
    from repro.core import coo_to_csr
    from repro.kernels.tiling import coo_to_tiled
    from repro.roofline import (spmm_arithmetic_intensity,
                                spmm_roofline_gflops)
    from repro.spmm import coo_to_sellcs, spmm
    from . import harness

    m, n = coo.shape
    nnz = coo.nnz
    formats = {"csr": coo_to_csr(coo), "sellcs": coo_to_sellcs(coo)}
    try:
        formats["tiled_csb"] = coo_to_tiled(coo, "csb")
    except MemoryError:
        pass                       # too sparse for dense mini-tiles
    rng = np.random.default_rng(0)
    for fmt, mat in formats.items():
        for k in ks:
            X = jnp.asarray(rng.standard_normal((n, k)).astype(np.float32))
            sec = harness.time_fn(lambda: spmm(mat, X, impl=impl),
                                  reps=reps, warmup=1)
            flops = 2.0 * nnz * k
            gflops = flops / sec / 1e9
            ai = spmm_arithmetic_intensity(
                nnz, m, n, k, matrix_bytes=mat.storage_bytes())
            ai_ideal = spmm_arithmetic_intensity(nnz, m, n, k)
            roof = spmm_roofline_gflops(ai)
            csv.row(f"{name}/{fmt}/k={k}", sec,
                    f"gflops={gflops:.4g};ai={ai:.4f};"
                    f"ai_ideal={ai_ideal:.4f};roof_gflops={roof:.1f}")


def _sweep_shapes(name: str, coo, ks, mesh_shapes, reps: int, csv,
                  chunk_counts, tag_of, compact_flags=(False,),
                  ops=("N",), gathers=("upfront",)) -> None:
    """Shared measurement core of ``sweep_distributed`` / ``sweep_mesh2d``:
    both schedules per (P_data, P_model) shape (ref impl bodies — the
    host-platform mesh has no TPU cores to feed the Pallas path), the
    merge schedule once per ``chunk_counts`` entry, each row priced by the
    (2-D) traffic model. ``tag_of(pd, pm)`` renders the mesh part of the
    row name; sweeping ``compact_flags`` beyond the plain ``(False,)``
    appends a ``/cx=on|off`` segment and prices the compact rows with the
    partitioner's measured mean ``n_touched``; sweeping ``ops`` beyond
    ``("N",)`` appends an ``/op=N|T`` segment — the transpose rows read X
    at [m, k] and are priced by the op-aware traffic model, giving
    ``smoke_check.check_transpose_regressions`` its same-config op=N
    baseline; sweeping ``gathers`` beyond ``("upfront",)`` appends a
    ``/gx=<mode>`` segment to the non-default compacted rows (the
    up-front baseline keeps its unsuffixed name) so
    ``smoke_check.check_gather_overlap`` can pair them.
    """
    import jax
    import jax.numpy as jnp
    from repro.launch.mesh import make_spmm_mesh
    from repro.roofline import (spmm_distributed_gather_s,
                                spmm_distributed_time,
                                spmm_distributed_traffic)
    from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                            partition_sellcs_rows, spmm_merge_distributed,
                            spmm_row_distributed)
    from . import harness

    m, n = coo.shape
    nnz = coo.nnz
    max_row = int(np.bincount(np.asarray(coo.rows), minlength=m).max()) \
        if nnz else 0
    sc = coo_to_sellcs(coo)
    rng = np.random.default_rng(1)
    # the mesh/compact gates need to know whether the mesh had per-device
    # memory: on a host-platform (cpu) mesh the "replicated" X is one
    # shared buffer and neither column-sharding nor compacting it saves
    # anything, so measured rows there are recorded but never gated
    # (smoke_check.check_mesh_regressions / check_compact_regressions)
    backend = jax.default_backend()
    tag_cx = tuple(compact_flags) != (False,)
    tag_op = tuple(ops) != ("N",)
    for pd, pm in mesh_shapes:
        mesh = make_spmm_mesh((pd, pm))
        for cf in compact_flags:
            def mean_nt(sh):
                # the map the multiply EXECUTES: a baked chunk plan
                # gathers through its re-dealt map, not the base one
                if not cf:
                    return None
                src = (sh.chunk_plan[3] if sh.chunk_plan is not None
                       else sh.n_touched)
                return float(np.mean(np.asarray(src)))
            row_sharded = partition_sellcs_rows(sc, pd, compact_x=cf)
            # one shared merge partition for every replicated depth: the
            # span re-deal happens at trace time inside the jitted
            # closure, so no per-depth copies of the base device-dealt
            # arrays stay alive. Compacted depths > 1 bake the plan
            # instead — its re-dealt col_map is what the multiply gathers
            # through, and the model must price THAT map's n_touched
            mrg_sharded = partition_sellcs_nnz(sc, pd, compact_x=cf)
            # the gather schedule is a compact-only knob: replicated-X
            # rows have no X gather to hide, so they sweep "upfront" only
            gs = tuple(gathers) if cf else ("upfront",)
            variants = []
            for opv in ops:
                for g in gs:
                    variants.append(
                        ("row", None, mean_nt(row_sharded), opv, g,
                         jax.jit(lambda X, rs=row_sharded, me=mesh, o=opv,
                                 g=g:
                                 spmm_row_distributed(rs, X, me, op=o,
                                                      gather=g))))
                for c in chunk_counts:
                    ms = mrg_sharded
                    if cf and int(c) > 1:
                        ms = partition_sellcs_nnz(sc, pd, num_chunks=int(c),
                                                  compact_x=True)
                    for g in gs:
                        variants.append(
                            ("merge", int(c), mean_nt(ms), opv, g,
                             jax.jit(lambda X, ms=ms, me=mesh, c=int(c),
                                     o=opv, g=g:
                                     spmm_merge_distributed(ms, X, me,
                                                            num_chunks=c,
                                                            op=o,
                                                            gather=g))))
            cx = f"/cx={'on' if cf else 'off'}" if tag_cx else ""
            for sched, nc, n_touched, opv, g, jitted in variants:
                gx = f"/gx={g}" if g != "upfront" else ""
                tag = f"{name}/sellcs+{sched}{tag_of(pd, pm)}" + \
                    (f"/chunks={nc}" if nc is not None else "") + cx + \
                    gx + (f"/op={opv}" if tag_op else "")
                for k in ks:
                    X = jnp.asarray(rng.standard_normal(
                        (m if opv == "T" else n, k)).astype(np.float32))
                    sec = harness.time_fn(lambda: jitted(X), reps=reps,
                                          warmup=1)
                    gflops = 2.0 * nnz * k / sec / 1e9
                    hbm, coll = spmm_distributed_traffic(
                        m, n, k, pd, sched, nnz=nnz, max_row_nnz=max_row,
                        model_devices=pm, compact_x=cf,
                        n_touched=n_touched, op=opv)
                    model_s = spmm_distributed_time(
                        m, n, k, pd, sched, nnz=nnz, max_row_nnz=max_row,
                        num_chunks=nc or 1, model_devices=pm,
                        compact_x=cf, n_touched=n_touched, op=opv,
                        gather=g)
                    # residual = observed/modeled — the same quantity the
                    # serve-path ResidualLedger records, stamped per row
                    # so smoke_check's residual gate reads sweep JSON and
                    # serve metrics dumps identically
                    derived = (f"gflops={gflops:.4g};"
                               f"hbm_mb={hbm / 1e6:.4g};"
                               f"coll_mb={coll / 1e6:.4g};"
                               f"model_us={model_s * 1e6:.4g};"
                               f"residual={sec / model_s:.4g};"
                               f"backend={backend}")
                    if cf:
                        exposed_s = spmm_distributed_gather_s(
                            m, n, k, pd, sched, nnz=nnz,
                            max_row_nnz=max_row, num_chunks=nc or 1,
                            model_devices=pm, compact_x=cf,
                            n_touched=n_touched, op=opv, gather=g)
                        derived += (f";n_touched={n_touched:.4g}"
                                    f";exposed_gather_us="
                                    f"{exposed_s * 1e6:.4g}")
                    csv.row(f"{tag}/k={k}", sec, derived)


def sweep_distributed(name: str, coo, ks, devices: int, reps: int,
                      csv, chunk_counts=(1,), compact_flags=(False,),
                      ops=("N",), gathers=("upfront",)) -> None:
    """Distributed schedules on a 1-D `devices`-wide data mesh: the
    ``@{P}dev`` row family ``smoke_check``'s chunk gate consumes."""
    _sweep_shapes(name, coo, ks, ((devices, 1),), reps, csv, chunk_counts,
                  lambda pd, pm: f"@{pd}dev", compact_flags=compact_flags,
                  ops=ops, gathers=gathers)


def sweep_mesh2d(name: str, coo, ks, mesh_shapes, reps: int, csv,
                 chunk_counts=(1,), compact_flags=(False,),
                 ops=("N",), gathers=("upfront",)) -> None:
    """Both schedules over 2-D (data, model) mesh factorizations: the
    ``@{Pd}x{Pm}mesh`` row family — include a ``Pm = 1`` shape to give
    ``smoke_check``'s model-axis gate its pure-data baseline."""
    _sweep_shapes(name, coo, ks, mesh_shapes, reps, csv, chunk_counts,
                  lambda pd, pm: f"@{pd}x{pm}mesh",
                  compact_flags=compact_flags, ops=ops, gathers=gathers)


def run(suite_scale: float = 0.02, kmax: int = 256, impl: str = "ref",
        reps: int = 3, matrices_only=None, devices: int = 1,
        chunk_counts=(1,), mesh_shapes=(), compact_flags=(False,),
        ops=("N",), gathers=("upfront",)) -> None:
    from repro.data import matrices
    from . import harness

    ks = []
    k = 1
    while k <= kmax:
        ks.append(k)
        k *= 2
    suite = matrices.test_suite(scale=suite_scale)
    names = matrices_only or ["hhh_like", "livejournal_like", "mawi_like"]
    extra = ""
    if devices > 1:
        extra += f", devices={devices}, chunks={list(chunk_counts)}"
    if mesh_shapes:
        extra += f", meshes={['%dx%d' % s for s in mesh_shapes]}"
    if tuple(compact_flags) != (False,):
        extra += (", compact_x="
                  f"{[('on' if f else 'off') for f in compact_flags]}")
    if tuple(ops) != ("N",):
        extra += f", ops={list(ops)}"
    if tuple(gathers) != ("upfront",):
        extra += f", gathers={list(gathers)}"
    title = f"SpMM k-sweep (impl={impl}, k in {ks}{extra})"
    csv = harness.Csv(title)
    for name in names:
        if name not in suite:
            raise SystemExit(f"unknown matrix {name}; one of {sorted(suite)}")
        coo = matrices.as_coo(suite[name].make())
        sweep_matrix(name, coo, ks, impl, reps, csv)
        if devices > 1:
            sweep_distributed(name, coo, ks, devices, reps, csv,
                              chunk_counts=chunk_counts,
                              compact_flags=compact_flags, ops=ops,
                              gathers=gathers)
        if mesh_shapes:
            sweep_mesh2d(name, coo, ks, mesh_shapes, reps, csv,
                         chunk_counts=chunk_counts,
                         compact_flags=compact_flags, ops=ops,
                         gathers=gathers)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--kmax", type=int, default=256)
    ap.add_argument("--impl", default="ref",
                    choices=("auto", "ref", "pallas", "pallas_interpret"))
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--matrices", default=None,
                    help="comma-separated subset of the matrix suite")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump all rows as JSON (harness schema)")
    ap.add_argument("--devices", type=int, default=1,
                    help="also sweep the distributed schedules over a mesh "
                         "of this many devices")
    ap.add_argument("--chunks", default="1",
                    help="comma-separated merge-psum pipelining depths to "
                         "sweep (with --devices); each count emits its own "
                         "chunks=<c> rows next to the monolithic chunks=1")
    ap.add_argument("--mesh", default=None,
                    help="comma-separated 2-D (data, model) mesh shapes to "
                         "sweep as PdxPm, e.g. 8x1,4x2 — include a Pm=1 "
                         "shape so smoke_check's model-axis gate has its "
                         "pure-data baseline")
    ap.add_argument("--compact-x", default="off", dest="compact_x",
                    help="comma-separated on/off: sweep the sparsity-aware "
                         "X gather next to replication — 'on,off' emits a "
                         "cx=on row per cx=off row so smoke_check's "
                         "compact gate has its replicated baseline")
    ap.add_argument("--gather", default="upfront",
                    help="comma-separated subset of upfront,overlap,fused: "
                         "sweep the compact-X gather schedule (needs "
                         "--compact-x on) — 'upfront,overlap' emits a "
                         "gx=overlap row per compacted baseline row so "
                         "smoke_check's gather gate can pair them")
    ap.add_argument("--op", default="N",
                    help="comma-separated subset of N,T: sweep the "
                         "transpose multiply (A^T X) next to the forward "
                         "one — 'N,T' emits an op=T row per op=N row so "
                         "smoke_check's transpose gate has its forward "
                         "baseline")
    args = ap.parse_args(argv)
    try:
        chunk_counts = tuple(int(c) for c in args.chunks.split(",") if c)
    except ValueError:
        raise SystemExit(f"--chunks must be comma-separated ints, got "
                         f"{args.chunks!r}")
    if not chunk_counts or any(c < 1 for c in chunk_counts):
        raise SystemExit(f"--chunks entries must be >= 1, got {args.chunks!r}")
    cx_entries = tuple(s for s in args.compact_x.split(",") if s)
    if not cx_entries or any(s not in ("on", "off") for s in cx_entries):
        raise SystemExit(f"--compact-x must be comma-separated on/off "
                         f"entries, got {args.compact_x!r}")
    compact_flags = tuple(s == "on" for s in cx_entries)
    ops = tuple(s for s in args.op.split(",") if s)
    if not ops or any(o not in ("N", "T") for o in ops):
        raise SystemExit(f"--op must be comma-separated N/T entries, "
                         f"got {args.op!r}")
    gathers = tuple(s for s in args.gather.split(",") if s)
    if not gathers or any(g not in ("upfront", "overlap", "fused")
                          for g in gathers):
        raise SystemExit(f"--gather must be comma-separated "
                         f"upfront/overlap/fused entries, got "
                         f"{args.gather!r}")
    if gathers != ("upfront",) and True not in compact_flags:
        raise SystemExit("--gather beyond 'upfront' needs --compact-x on "
                         "rows — a replicated-X stream has no X gather "
                         "to hide")
    mesh_shapes = ()
    if args.mesh:
        try:
            mesh_shapes = tuple(
                tuple(int(p) for p in s.split("x"))
                for s in args.mesh.split(",") if s)
        except ValueError:
            raise SystemExit(f"--mesh must be comma-separated PdxPm "
                             f"entries, got {args.mesh!r}")
        if any(len(s) != 2 or s[0] < 1 or s[1] < 1 for s in mesh_shapes):
            raise SystemExit(f"--mesh entries must be PdxPm with both "
                             f">= 1, got {args.mesh!r}")

    need = max([args.devices] + [pd * pm for pd, pm in mesh_shapes])
    if need > 1 and "jax" not in sys.modules:
        # must happen before the first jax import anywhere in the process
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={need} "
            + os.environ.get("XLA_FLAGS", ""))
    if need > 1:
        import jax
        if len(jax.devices()) < need:
            raise SystemExit(
                f"the sweep needs {need} devices but jax sees "
                f"{len(jax.devices())}; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={need} "
                "before any jax import")

    from . import harness
    harness.reset_records()
    run(suite_scale=args.scale, kmax=args.kmax, impl=args.impl,
        reps=args.reps,
        matrices_only=args.matrices.split(",") if args.matrices else None,
        devices=args.devices, chunk_counts=chunk_counts,
        mesh_shapes=mesh_shapes, compact_flags=compact_flags, ops=ops,
        gathers=gathers)
    if args.json:
        harness.dump_json(args.json)


if __name__ == "__main__":
    main()
