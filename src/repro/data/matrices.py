"""Synthetic unstructured sparse matrix generators.

The paper's test set (Table 5.1) comes from the SuiteSparse/Florida
collection plus two random matrices (HHH, LHH). Offline we regenerate each
*class* of matrix with matched statistics (density regime, row-length
variance, pathological skew):

  uniform        — HHH / LHH / cage15 (low row variance, uniform)
  rmat           — kron_g500, com-Orkut (power-law, heavy skew)
  powerlaw       — LiveJournal, ljournal-2008, uk-2002 (degree power law)
  mesh2d         — road_usa, hugetrace, hugebubbles (bounded degree, local)
  mawi_like      — mawi_201512020130 (ONE near-dense row; breaks
                   row-distributed balancing, paper Table 6.3)

Generators are deterministic in ``seed`` and return host numpy triplets;
``as_coo`` moves them to device.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np

from repro.core import COO, to_coo


def _dedupe(rows, cols, m, n):
    key = rows.astype(np.int64) * n + cols.astype(np.int64)
    key = np.unique(key)
    return (key // n).astype(np.int32), (key % n).astype(np.int32)


def uniform(m: int, n: int, nnz: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, m, nnz, dtype=np.int64)
    cols = rng.integers(0, n, nnz, dtype=np.int64)
    rows, cols = _dedupe(rows, cols, m, n)
    vals = rng.standard_normal(rows.size).astype(np.float32)
    return rows, cols, vals, (m, n)


def rmat(scale: int, edge_factor: int = 16, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19):
    """Recursive MATrix (Graph500 kron generator): power-law degrees."""
    rng = np.random.default_rng(seed)
    m = n = 1 << scale
    nnz = edge_factor * m
    rows = np.zeros(nnz, np.int64)
    cols = np.zeros(nnz, np.int64)
    for bit in range(scale):
        r = rng.random(nnz)
        quad_ab = r < a + b           # top half
        quad_ac_given = rng.random(nnz)
        go_right_top = (r >= a) & quad_ab
        go_right_bot = quad_ac_given >= (c / max(1 - a - b, 1e-9))
        right = np.where(quad_ab, go_right_top, go_right_bot)
        down = ~quad_ab
        rows |= down.astype(np.int64) << bit
        cols |= right.astype(np.int64) << bit
    rows, cols = _dedupe(rows, cols, m, n)
    vals = rng.standard_normal(rows.size).astype(np.float32)
    return rows, cols, vals, (m, n)


def powerlaw(m: int, n: int, nnz: int, alpha: float = 1.8, seed: int = 0):
    """Degree-sequence model: row degrees ~ Zipf(alpha), columns uniform."""
    rng = np.random.default_rng(seed)
    w = (np.arange(1, m + 1, dtype=np.float64) ** (-alpha))
    rng.shuffle(w)
    w /= w.sum()
    rows = rng.choice(m, size=nnz, p=w).astype(np.int64)
    cols = rng.integers(0, n, nnz, dtype=np.int64)
    rows, cols = _dedupe(rows, cols, m, n)
    vals = rng.standard_normal(rows.size).astype(np.float32)
    return rows, cols, vals, (m, n)


def mesh2d(side: int, seed: int = 0):
    """5-point stencil on a side x side grid: the paper's road/hugetrace
    class (max 3-5 nnz/row, tiny variance)."""
    rng = np.random.default_rng(seed)
    m = n = side * side
    idx = np.arange(m, dtype=np.int64)
    r, c = idx // side, idx % side
    nbrs = []
    for dr, dc in ((0, 0), (0, 1), (0, -1), (1, 0), (-1, 0)):
        rr, cc = r + dr, c + dc
        ok = (rr >= 0) & (rr < side) & (cc >= 0) & (cc < side)
        nbrs.append((idx[ok], (rr * side + cc)[ok]))
    rows = np.concatenate([a for a, _ in nbrs])
    cols = np.concatenate([b for _, b in nbrs])
    vals = rng.standard_normal(rows.size).astype(np.float32)
    return rows.astype(np.int32), cols.astype(np.int32), vals, (m, n)


def mawi_like(m: int, n: int, nnz: int, dense_row_frac: float = 0.3,
              seed: int = 0):
    """Background uniform sparsity + ONE row holding ``dense_row_frac`` of
    all nonzeros (paper: mawi has a row with 1.2e8 of 2.7e8 nnz)."""
    rng = np.random.default_rng(seed)
    hot = int(nnz * dense_row_frac)
    hot_row = int(rng.integers(0, m))
    r1 = np.full(hot, hot_row, np.int64)
    c1 = rng.choice(n, size=min(hot, n), replace=False).astype(np.int64)
    r1 = r1[: c1.size]
    r2 = rng.integers(0, m, nnz - c1.size, dtype=np.int64)
    c2 = rng.integers(0, n, nnz - c1.size, dtype=np.int64)
    rows = np.concatenate([r1, r2])
    cols = np.concatenate([c1, c2])
    rows, cols = _dedupe(rows, cols, m, n)
    vals = rng.standard_normal(rows.size).astype(np.float32)
    return rows, cols, vals, (m, n)


def as_coo(gen_result, dtype=np.float32) -> COO:
    rows, cols, vals, shape = gen_result
    return to_coo(rows, cols, vals.astype(dtype), shape)


@dataclasses.dataclass(frozen=True)
class TestMatrix:
    name: str
    density_class: str          # "low" | "high" | "skewed"
    make: Callable[[], tuple]


def _suite(scale: float = 1.0) -> Dict[str, TestMatrix]:
    """Scaled-down analogues of Table 5.1 (names reference the originals)."""
    s = scale

    def S(x):
        return max(int(x * s), 64)

    return {
        # --- low density class (density < 1e-6 in the paper) ---
        "europe_osm_like": TestMatrix(
            "europe_osm_like", "low",
            lambda: mesh2d(int(np.sqrt(S(262144))))),
        "road_like": TestMatrix(
            "road_like", "low", lambda: mesh2d(int(np.sqrt(S(131072))), 1)),
        "lhh_like": TestMatrix(
            "lhh_like", "low",
            lambda: uniform(S(262144), S(262144), S(524288), 2)),
        # --- higher density class ---
        "kron_like": TestMatrix(
            "kron_like", "high",
            lambda: rmat(max(int(np.log2(S(16384))), 8), 24, 3)),
        "livejournal_like": TestMatrix(
            "livejournal_like", "high",
            lambda: powerlaw(S(32768), S(32768), S(393216), 1.8, 4)),
        "hhh_like": TestMatrix(
            "hhh_like", "high",
            lambda: uniform(S(16384), S(16384), S(196608), 5)),
        "orkut_like": TestMatrix(
            "orkut_like", "high",
            lambda: rmat(max(int(np.log2(S(8192))), 8), 48, 6)),
        # --- pathological ---
        "mawi_like": TestMatrix(
            "mawi_like", "skewed",
            lambda: mawi_like(S(65536), S(65536), S(262144), 0.3, 7)),
    }


def test_suite(scale: float = 1.0) -> Dict[str, TestMatrix]:
    return _suite(scale)
