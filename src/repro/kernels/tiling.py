"""TiledSparse — the TPU compute format for unstructured SpMV.

Hardware adaptation (DESIGN.md §2): the paper's CPU algorithms do per-nonzero
``y[r] += v * x[c]`` — a scatter/gather pattern with no efficient TPU
lowering (the VPU has no cheap vector scatter; the MXU wants dense tiles).
The TPU dialect of the paper's *blocked* formats is therefore hierarchical:

  level 0  (paper: sparse block, cache-sized)   macro block, beta x beta
  level 1  (new, hardware)                      dense 8 x 128 mini-tiles
                                                 (VREG sublane x lane shape)

Only nonempty mini-tiles are stored (dense, zero-filled). SpMV per mini-tile
is a dense (8,128) @ (128,) matvec — pure MXU/VPU work, no scatter. What
survives of each paper algorithm:

  * blocking       -> beta chooses the x/y slab reuse distance;
  * nonzero order  -> the mini-tile visit order (row / Morton / Hilbert at
                      both macro and in-macro level) controls how often the
                      x- and y-windows move => Pallas elides copies for
                      consecutive same-index windows (the cache-reuse story,
                      measurable as window-switch counts);
  * load balancing -> uniform work quanta (every tile = same FLOPs) plus
                      merge-path spans over tiles; a single dense row is
                      split across many tiles (the mawi fix).

The price is fill-in: ``fill_ratio`` = nnz / (1024 * num_tiles). For very
sparse matrices fill-in makes the XLA gather path cheaper — the paper's
density-dependent algorithm choice, reappearing on TPU (see selector +
EXPERIMENTS).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import COO
from repro.core.convert import ALGORITHM_SPECS, block_size_for
from repro.core.curves import hilbert_key_np
from repro.core.formats import static_field, _pytree_dataclass
from repro.core.mergepath import balanced_row_bands

TILE_R = 8      # sublane dimension
TILE_C = 128    # lane dimension


def _morton_key_np(rows, cols, bits):
    r = np.asarray(rows, np.uint64)
    c = np.asarray(cols, np.uint64)
    key = np.zeros(r.shape, np.uint64)
    for b in range(bits):
        key |= ((r >> np.uint64(b)) & np.uint64(1)) << np.uint64(2 * b + 1)
        key |= ((c >> np.uint64(b)) & np.uint64(1)) << np.uint64(2 * b)
    return key.astype(np.int64)


@_pytree_dataclass
class TiledSparse:
    """Dense 8x128 mini-tiles of an unstructured sparse matrix."""
    tiles: jax.Array        # f32[T, 8, 128]
    tile_rows: jax.Array    # int32[T] — global tile-row index (row // 8)
    tile_cols: jax.Array    # int32[T] — global tile-col index (col // 128)
    shape: Tuple[int, int] = static_field()
    beta: int = static_field()           # macro block size used for ordering
    order: str = static_field()          # algorithm preset name
    nnz: int = static_field()            # true nonzeros (before fill-in)

    @property
    def num_tiles(self) -> int:
        return self.tiles.shape[0]

    @property
    def fill_ratio(self) -> float:
        t = self.num_tiles
        return self.nnz / (t * TILE_R * TILE_C) if t else 0.0

    def padded_shape(self) -> Tuple[int, int]:
        m, n = self.shape
        return (-(-m // TILE_R) * TILE_R, -(-n // TILE_C) * TILE_C)

    def window_switches(self) -> Tuple[int, int]:
        """(#x-window moves, #y-window moves) across the tile visit order —
        the TPU proxy for the paper's cache-miss counts."""
        tr = np.asarray(self.tile_rows)
        tc = np.asarray(self.tile_cols)
        if tr.size <= 1:
            return (tr.size, tr.size)
        return (int(np.sum(tc[1:] != tc[:-1]) + 1),
                int(np.sum(tr[1:] != tr[:-1]) + 1))

    def storage_bytes(self) -> int:
        return int(self.tiles.size * self.tiles.dtype.itemsize
                   + 2 * 4 * self.num_tiles)


def coo_to_tiled(coo: COO, algorithm: str = "csb", *,
                 beta: Optional[int] = None, num_bands: int = 0,
                 dtype=jnp.float32,
                 max_bytes: int = 8 * 2 ** 30) -> TiledSparse:
    """Convert COO -> TiledSparse with the visit order of ``algorithm``
    (any blocked ALGORITHM_SPECS key; flat 'merge'/'parcrs' get row order)."""
    spec = ALGORITHM_SPECS[algorithm]
    m, n = coo.shape
    if beta is None:
        beta = block_size_for(coo.shape,
                              in_block_format=spec.in_block_format)
    beta = max(beta, TILE_C)            # a macro block holds >=1 tile column

    rows = np.asarray(coo.rows, np.int64)
    cols = np.asarray(coo.cols, np.int64)
    vals = np.asarray(coo.data)

    tr, tc = rows // TILE_R, cols // TILE_C           # mini-tile coords
    Nt_c = -(-n // TILE_C)
    tile_key = tr * Nt_c + tc                          # tile identity

    # ordering key: (band, macro curve key, in-macro tile curve key)
    mb_r, mb_c = rows // beta, cols // beta
    Mb, Nb = -(-m // beta), -(-n // beta)
    grid_bits = max(int(np.ceil(np.log2(max(Mb, Nb, 2)))), 1)
    # tile coords within macro block
    ltr = tr - mb_r * (beta // TILE_R)
    ltc = tc - mb_c * (beta // TILE_C)
    loc_bits = max(int(np.ceil(np.log2(max(beta // TILE_R,
                                           beta // TILE_C, 2)))), 1)

    border = spec.block_order if spec.blocked else "row"
    iorder = spec.in_block_order if spec.blocked else "row"
    if border == "hilbert":
        mkey = hilbert_key_np(mb_r, mb_c, grid_bits)
    elif border == "morton":
        mkey = _morton_key_np(mb_r, mb_c, grid_bits)
    else:
        mkey = mb_r * Nb + mb_c
    if iorder == "hilbert":
        lkey = hilbert_key_np(ltr, ltc, loc_bits)
    elif iorder == "morton":
        lkey = _morton_key_np(ltr, ltc, loc_bits)
    else:
        lkey = ltr * (beta // TILE_C + 1) + ltc

    if num_bands > 0:
        Mbr = -(-m // beta)
        blk_row_ptr = np.zeros(Mbr + 1, np.int64)
        np.cumsum(np.bincount(mb_r, minlength=Mbr), out=blk_row_ptr[1:])
        bands = balanced_row_bands(blk_row_ptr, num_bands)
        band = np.searchsorted(bands, mb_r, side="right") - 1
    else:
        band = np.zeros(rows.size, np.int64)

    perm = np.lexsort((lkey, mkey, band))
    rows, cols, vals = rows[perm], cols[perm], vals[perm]
    tile_key = tile_key[perm]

    # unique tiles in first-visit order
    first_seen, inv = {}, np.zeros(rows.size, np.int64)
    uniq, first_idx = np.unique(tile_key, return_index=True)
    # order tiles by first occurrence in the sorted stream
    order_of_uniq = np.argsort(first_idx, kind="stable")
    rank = np.empty(uniq.size, np.int64)
    rank[order_of_uniq] = np.arange(uniq.size)
    inv = rank[np.searchsorted(uniq, tile_key)]

    T = uniq.size
    if T * TILE_R * TILE_C * 4 > max_bytes:
        raise MemoryError(
            f"TiledSparse would need {T * TILE_R * TILE_C * 4 / 2**30:.1f} "
            f"GiB (fill ratio {rows.size / max(T * 1024, 1):.2e}); use the "
            "XLA gather path for this density (selector does this).")

    tiles = np.zeros((max(T, 1), TILE_R, TILE_C), np.float32)
    lr = (rows % TILE_R).astype(np.int64)
    lc = (cols % TILE_C).astype(np.int64)
    np.add.at(tiles, (inv, lr, lc), vals.astype(np.float32))

    uniq_in_order = uniq[order_of_uniq]
    tile_rows = (uniq_in_order // Nt_c).astype(np.int32)
    tile_cols = (uniq_in_order % Nt_c).astype(np.int32)
    if T == 0:
        tile_rows = np.zeros(1, np.int32)
        tile_cols = np.zeros(1, np.int32)

    return TiledSparse(
        tiles=jnp.asarray(tiles, dtype), tile_rows=jnp.asarray(tile_rows),
        tile_cols=jnp.asarray(tile_cols), shape=coo.shape, beta=int(beta),
        order=algorithm, nnz=int(rows.size))
