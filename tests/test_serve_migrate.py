"""Online format migration (ISSUE 7): the SparseOperator handle, the
PlanSpec carrier and its kwargs shims, the ledger-fed re-selection, the
serve --migrate controller, and the smoke_check migration gate.

Device-backed mesh tests run in SUBPROCESSES (the host-platform device
count must be set before jax initializes); everything else runs in-process
on the suite's single device.
"""
import dataclasses
import json
import math
import threading

import numpy as np
import pytest

from tests.test_spmm_distributed import run_sub


def _coo(m=300, n=300, nnz=2400, seed=0):
    from repro.core import to_coo
    from repro.data import matrices
    return to_coo(*matrices.uniform(m, n, nnz, seed))


# -------------------------------------------------------------------------
# PlanSpec: canonicalization and the kwargs shims
# -------------------------------------------------------------------------

def test_plan_spec_canonical_rules():
    from repro.core import PlanSpec
    sp = PlanSpec(mesh_shape=(4, 2)).canonical()
    assert sp.num_devices == 8 and sp.mesh_shape == (4, 2)
    assert PlanSpec().canonical().num_devices == 1
    # --chunks 0 convention: 0 means unpinned
    assert PlanSpec(num_chunks=0).canonical().num_chunks is None
    with pytest.raises(ValueError):
        PlanSpec(num_devices=4, mesh_shape=(4, 2)).canonical()
    with pytest.raises(ValueError):
        PlanSpec(schedule="diagonal").canonical()
    with pytest.raises(ValueError):
        PlanSpec(num_chunks=-1).canonical()
    # unpinned axes become label wildcards; pinned axes are stamped
    lab = PlanSpec(mesh_shape=(4, 2), schedule="merge").labels()
    assert lab == {"schedule": "merge", "mesh": "4x2"}


def test_grid_spec_equals_kwargs_shim():
    from repro.core import PlanSpec
    from repro.core.selector import distributed_schedule_grid
    assert distributed_schedule_grid(8) == \
        distributed_schedule_grid(spec=PlanSpec(num_devices=8))
    assert distributed_schedule_grid(8, pinned_chunks=4,
                                     pinned_mesh=(4, 2)) == \
        distributed_schedule_grid(spec=PlanSpec(
            num_devices=8, num_chunks=4, mesh_shape=(4, 2)))
    # a schedule pin restricts that axis (no kwargs equivalent existed)
    grid = distributed_schedule_grid(spec=PlanSpec(num_devices=8,
                                                   schedule="row"))
    assert grid and all(s == "row" and nc == 1 for s, nc, _ in grid)


def test_select_distributed_spec_equals_kwargs_shim():
    from repro.core import PlanSpec, matrix_stats
    from repro.core.selector import select_distributed
    stats = matrix_stats(_coo())
    for k in (1, 64):
        old = select_distributed(stats, k=k, num_devices=8,
                                 mesh_shape=(4, 2))
        new = select_distributed(stats, k=k,
                                 spec=PlanSpec(mesh_shape=(4, 2)))
        assert old == new
    # spec pins land in the choice verbatim
    ch = select_distributed(stats, k=8, spec=PlanSpec(
        num_devices=8, schedule="merge", num_chunks=4, compact_x=True,
        algorithm="sellcs"))
    assert (ch.algorithm, ch.schedule, ch.num_chunks, ch.compact_x) == \
        ("sellcs", "merge", 4, True)
    with pytest.raises(ValueError):
        select_distributed(stats, spec=PlanSpec(num_devices=8,
                                                algorithm="csb"))


def test_autotune_spec_equals_kwargs_shim():
    from repro.core import PlanSpec
    from repro.core.autotune import autotune
    coo = _coo(200, 200, 1500)
    best_old, _ = autotune(coo, algorithms=("parcrs",), reps=1, k=8,
                           num_devices=8)
    best_new, _ = autotune(coo, algorithms=("parcrs",), reps=1, k=8,
                           spec=PlanSpec(num_devices=8))
    assert (best_old.schedule, best_old.num_chunks, best_old.mesh_shape,
            best_old.compact_x) == (best_new.schedule, best_new.num_chunks,
                                    best_new.mesh_shape, best_new.compact_x)
    # pins restrict the rescoring grid
    best_pin, _ = autotune(coo, algorithms=("parcrs",), reps=1, k=8,
                           spec=PlanSpec(num_devices=8, schedule="merge",
                                         num_chunks=2, mesh_shape=(4, 2)))
    assert (best_pin.schedule, best_pin.num_chunks, best_pin.mesh_shape) \
        == ("merge", 2, (4, 2))


def test_autotune_measure_delegates_to_time_min_of_n(monkeypatch):
    """[bugfix] autotune's timing must go through the repo-wide
    obs.timing.time_min_of_n protocol, not a private perf_counter loop."""
    import repro.obs.timing as timing
    from repro.core.autotune import _measure
    calls = []
    real = timing.time_min_of_n

    def spy(fn, reps=5, warmup=2, **kw):
        calls.append((reps, warmup))
        return real(fn, reps=reps, warmup=warmup, **kw)

    monkeypatch.setattr(timing, "time_min_of_n", spy)
    out = _measure(lambda: None, reps=3, warmup=1)
    assert calls == [(3, 1)] and out >= 0.0


# -------------------------------------------------------------------------
# Ledger feedback into the online re-selection
# -------------------------------------------------------------------------

def test_select_distributed_feedback_flips_choice():
    from repro import obs
    from repro.core import PlanSpec, matrix_stats
    from repro.core.selector import select_distributed
    stats = matrix_stats(_coo())
    spec = PlanSpec(num_devices=8)
    base = select_distributed(stats, k=32, spec=spec)
    # rig the ledger: the modeled winner measured 1000x worse than modeled
    ledger = obs.ResidualLedger()
    ledger.record("rig", 1000.0, 1.0, **obs.choice_labels(
        schedule=base.schedule, num_chunks=base.num_chunks,
        mesh_shape=base.mesh_shape, compact_x=base.compact_x))
    redo = select_distributed(stats, k=32, spec=spec, feedback=ledger)
    assert redo != base, "a 1000x residual on the winner must flip it"
    # and the flip respects pins: pin the old winner's knobs, it stays
    pinned = select_distributed(stats, k=32, feedback=ledger,
                                spec=dataclasses.replace(
                                    spec, schedule=base.schedule,
                                    mesh_shape=base.mesh_shape,
                                    num_chunks=base.num_chunks,
                                    compact_x=base.compact_x))
    assert (pinned.schedule, pinned.mesh_shape) == \
        (base.schedule, base.mesh_shape)


# -------------------------------------------------------------------------
# SparseOperator: oracle equivalence and the atomic swap
# -------------------------------------------------------------------------

def test_sparse_operator_matches_oracle_across_swaps():
    import jax.numpy as jnp
    from repro.core import PlanSpec
    from repro.core.selector import ZERO_CONVERSION_ALGO
    from repro.spmm import SparseOperator, spmm_coo
    coo = _coo()
    op = SparseOperator.from_coo(
        coo, PlanSpec(num_devices=1, algorithm=ZERO_CONVERSION_ALGO),
        impl="ref")
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.standard_normal((coo.shape[1], 8)).astype(
        np.float32))
    x1 = jnp.asarray(rng.standard_normal(coo.shape[1]).astype(np.float32))
    yo = np.asarray(spmm_coo(coo, X))
    pre = np.asarray(op.matmul(X))
    np.testing.assert_allclose(pre, yo, rtol=1e-5, atol=1e-4)
    assert op.matmul(x1).ndim == 1      # SpMV rides along
    assert op.plan.spec.algorithm == ZERO_CONVERSION_ALGO
    op.swap(PlanSpec(num_devices=1, algorithm="sellcs"))
    assert op.plan.spec.algorithm == "sellcs"
    post = np.asarray(op @ X)
    np.testing.assert_allclose(post, yo, rtol=1e-5, atol=1e-4)
    # multiplies count SpMV-equivalents (served columns), the break-even
    # unit; swaps and calls are bookkept too
    assert op.stats.multiplies == 8 + 1 + 8
    assert op.stats.calls == 3 and op.stats.swaps == 1
    assert op.stats.last_swap_unix_s is not None
    with pytest.raises(TypeError):
        op.swap("sellcs")


def test_swap_atomicity_under_concurrent_matmul():
    """Hammer matmul from worker threads while the main thread swaps
    between two realized plans: every result must be a correct multiply
    (either plan computes the same matrix), never a torn mix."""
    import jax.numpy as jnp
    from repro.core import PlanSpec
    from repro.spmm import SparseOperator, spmm_coo
    coo = _coo(200, 180, 1500, seed=7)
    op = SparseOperator.from_coo(
        coo, PlanSpec(num_devices=1, algorithm="merge"), impl="ref")
    plan_a = op.plan
    plan_b = op.realize(PlanSpec(num_devices=1, algorithm="sellcs"))
    X = jnp.asarray(np.random.default_rng(0).standard_normal(
        (coo.shape[1], 4)).astype(np.float32))
    yo = np.asarray(spmm_coo(coo, X))
    errors, results = [], []
    stop = threading.Event()

    def hammer():
        try:
            while not stop.is_set():
                results.append(np.asarray(op.matmul(X)))
        except Exception as e:           # pragma: no cover - fail signal
            errors.append(e)

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    for i in range(60):
        op.swap(plan_b if i % 2 == 0 else plan_a)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors
    assert len(results) > 0 and op.stats.swaps == 60
    for y in results:
        np.testing.assert_allclose(y, yo, rtol=1e-5, atol=1e-4)


def test_operator_mesh_swap_reuses_partitions():
    """On an 8-device mesh: realize/swap across chunk depths stays
    bitwise-stable against the oracle, and a chunks-only change reuses
    the cached base partition (rechunk, not repartition)."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import PlanSpec, to_coo
from repro.data import matrices
from repro.spmm import SparseOperator, spmm_coo
coo = to_coo(*matrices.uniform(400, 400, 3000, 0))
op = SparseOperator.from_coo(coo, PlanSpec(num_devices=8), impl="ref")
X = jnp.asarray(np.random.default_rng(1).standard_normal(
    (400, 8)).astype(np.float32))
yo = np.asarray(spmm_coo(coo, X))
np.testing.assert_allclose(np.asarray(op @ X), yo, rtol=1e-5, atol=1e-4)
m2 = op.swap(PlanSpec(num_devices=8, mesh_shape=(8, 1), schedule="merge",
                      num_chunks=4))
assert m2.spec.num_chunks == 4, m2.spec
np.testing.assert_allclose(np.asarray(op @ X), yo, rtol=1e-5, atol=1e-4)
base_ids = {k: id(v) for k, v in op._cache.partitions.items()}
m3 = op.swap(PlanSpec(num_devices=8, mesh_shape=(8, 1), schedule="merge",
                      num_chunks=2))
assert m3.spec.num_chunks == 2, m3.spec
assert {k: id(v) for k, v in op._cache.partitions.items()} == base_ids
np.testing.assert_allclose(np.asarray(op @ X), yo, rtol=1e-5, atol=1e-4)
assert op.stats.swaps == 2
print("MESH_SWAP_OK")
"""))


# -------------------------------------------------------------------------
# serve --migrate end-to-end
# -------------------------------------------------------------------------

def test_serve_migrate_below_breakeven_never_converts(tmp_path):
    """[ISSUE acceptance] traffic below the break-even must never trigger
    a conversion in auto mode — and every served column is counted."""
    from repro.launch import serve
    path = str(tmp_path / "m.json")
    serve.main(["--mode", "spmv", "--matrix", "mawi_like", "--requests",
                "8", "--max-batch", "4", "--impl", "ref", "--reps", "1",
                "--migrate", "auto", "--metrics", path])
    doc = json.loads(open(path).read())
    counters = {c["name"]: c["value"] for c in doc["counters"]}
    gauges = {g["name"]: g["value"] for g in doc["gauges"]}
    assert counters.get("serve/plan_swaps", 0) == 0
    assert counters["serve/multiplies_total"] == 8
    assert "serve/breakeven_estimate" in gauges
    import benchmarks.smoke_check as sk
    assert sk.check_migration(doc, "m.json") == []
    assert sk.check_obs_document(doc, "m.json") == []


def test_serve_migrate_force_swaps_single_device(tmp_path):
    from repro.launch import serve
    path = str(tmp_path / "m.json")
    serve.main(["--mode", "spmv", "--matrix", "mawi_like", "--requests",
                "16", "--max-batch", "4", "--impl", "ref", "--reps", "1",
                "--migrate", "force", "--metrics", path])
    doc = json.loads(open(path).read())
    counters = {c["name"]: c["value"] for c in doc["counters"]}
    gauges = {g["name"]: g["value"] for g in doc["gauges"]}
    assert counters["serve/plan_swaps"] >= 1
    assert counters["serve/multiplies_total"] == 16
    assert gauges["serve/convert_s"] > 0
    assert math.isfinite(gauges["serve/breakeven_estimate"])
    assert gauges["serve/breakeven_estimate"] > 0
    assert doc["labels"]["migrate"] == "force"
    import benchmarks.smoke_check as sk
    assert sk.check_migration(doc, "m.json") == []
    assert sk.check_obs_document(doc, "m.json") == []


def test_serve_migrate_rejects_pinned_algorithm():
    from repro.launch import serve
    with pytest.raises(SystemExit):
        serve.main(["--mode", "spmv", "--matrix", "mawi_like",
                    "--requests", "8", "--migrate", "auto",
                    "--algorithm", "csb"])


def test_serve_migrate_force_mesh_8dev(tmp_path):
    """[CI acceptance] the bench-smoke scenario: forced migration onto an
    8-device mesh, decision inputs in the metrics doc, smoke gate green."""
    path = str(tmp_path / "mesh.json")
    run_sub(f"""
from repro.launch import serve
serve.main(["--mode", "spmv", "--matrix", "mawi_like", "--requests", "32",
            "--max-batch", "8", "--devices", "8", "--impl", "ref",
            "--reps", "1", "--migrate", "force", "--metrics", {path!r}])
""")
    doc = json.loads(open(path).read())
    counters = {c["name"]: c["value"] for c in doc["counters"]}
    assert counters["serve/plan_swaps"] >= 1
    assert counters["serve/multiplies_total"] >= 32
    import benchmarks.smoke_check as sk
    assert sk.check_migration(doc, str(path)) == []
    assert sk.check_obs_document(doc, str(path)) == []
    assert sk.main([str(path)]) == 0


# -------------------------------------------------------------------------
# smoke_check.check_migration unit gates
# -------------------------------------------------------------------------

def _doc(labels=None, counters=(), gauges=(), hists=()):
    return {"schema": "repro.obs/v1", "labels": labels or {},
            "counters": [{"name": n, "value": v} for n, v in counters],
            "gauges": [{"name": n, "value": v} for n, v in gauges],
            "histograms": list(hists), "residuals": []}


def test_check_migration_disarmed_without_label():
    import benchmarks.smoke_check as sk
    assert sk.check_migration(_doc(labels={"migrate": "off"}), "x") == []
    assert sk.check_migration(_doc(), "x") == []


def test_check_migration_gates():
    import benchmarks.smoke_check as sk
    ok_auto = _doc(labels={"migrate": "auto", "requests": "8"},
                   counters=[("serve/multiplies_total", 8.0)],
                   gauges=[("serve/breakeven_estimate", math.inf)])
    # auto may honestly never convert; an inf estimate is legitimate
    assert sk.check_migration(ok_auto, "x") == []
    # undercounted traffic fails
    short = _doc(labels={"migrate": "auto", "requests": "8"},
                 counters=[("serve/multiplies_total", 4.0)],
                 gauges=[("serve/breakeven_estimate", 10.0)])
    assert any("uncounted" in p for p in sk.check_migration(short, "x"))
    # a missing counter fails
    missing = _doc(labels={"migrate": "auto", "requests": "8"},
                   gauges=[("serve/breakeven_estimate", 10.0)])
    assert any("never counted" in p
               for p in sk.check_migration(missing, "x"))
    # force without a landed swap / measured conversion fails on each gate
    noswap = _doc(labels={"migrate": "force", "requests": "8"},
                  counters=[("serve/multiplies_total", 8.0)],
                  gauges=[("serve/breakeven_estimate", math.inf)])
    probs = sk.check_migration(noswap, "x")
    assert any("never landed" in p for p in probs)
    assert any("convert_s" in p for p in probs)
    assert any("breakeven_estimate" in p for p in probs)
    ok_force = _doc(labels={"migrate": "force", "requests": "8"},
                    counters=[("serve/multiplies_total", 8.0),
                              ("serve/plan_swaps", 1.0)],
                    gauges=[("serve/breakeven_estimate", 12.0),
                            ("serve/swap_unix_s", 1.7e9),
                            ("serve/convert_s", 0.01)])
    assert sk.check_migration(ok_force, "x") == []


def test_check_migration_latency_gate_cpu_disarmed():
    import benchmarks.smoke_check as sk
    hist = [{"name": "serve/flush_premigrate_s", "count": 3, "sum": 0.003,
             "min": 0.001, "max": 0.001, "mean": 0.001, "p50": 0.001,
             "p95": 0.001, "p99": 0.001},
            {"name": "serve/flush_postmigrate_s", "count": 3, "sum": 3.0,
             "min": 1.0, "max": 1.0, "mean": 1.0, "p50": 1.0, "p95": 1.0,
             "p99": 1.0}]
    base = dict(labels={"migrate": "force", "requests": "8"},
                counters=[("serve/multiplies_total", 8.0),
                          ("serve/plan_swaps", 1.0)],
                gauges=[("serve/breakeven_estimate", 12.0),
                        ("serve/swap_unix_s", 1.7e9),
                        ("serve/convert_s", 0.01)])
    cpu = _doc(**base)
    cpu["labels"]["backend"] = "cpu"
    cpu["histograms"] = hist
    assert sk.check_migration(cpu, "x") == []   # cpu: disarmed
    tpu = _doc(**base)
    tpu["labels"] = dict(tpu["labels"], backend="tpu", migrate="force")
    tpu["histograms"] = [dict(h) for h in hist]
    probs = sk.check_migration(tpu, "x")
    assert any("made serving slower" in p for p in probs)
