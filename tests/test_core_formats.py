"""Format round-trips + SpMV equality for every paper algorithm's storage
format, against the dense oracle."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (ALGORITHM_SPECS, convert, coo_to_bicrs, coo_to_csr,
                        coo_to_icrs, spmv, spmv_dense_oracle, to_coo)
from repro.data import matrices


def _small_cases():
    cases = {
        "uniform": matrices.uniform(97, 83, 500, seed=0),
        "square_pow2": matrices.uniform(128, 128, 900, seed=1),
        "mesh": matrices.mesh2d(12),
        "powerlaw": matrices.powerlaw(150, 150, 1200, seed=2),
        "mawi": matrices.mawi_like(120, 120, 800, seed=3),
        "single_row": ([0, 0, 0], [1, 5, 63], [1.0, 2.0, 3.0], (64, 64)),
        "single_col": ([1, 5, 63], [2, 2, 2], [1.0, 2.0, 3.0], (64, 64)),
        "one_elem": ([7], [9], [4.2], (16, 16)),
        "empty": (np.zeros(0, np.int32), np.zeros(0, np.int32),
                  np.zeros(0, np.float32), (32, 32)),
        "tall": matrices.uniform(400, 30, 600, seed=4),
        "wide": matrices.uniform(30, 400, 600, seed=5),
    }
    return cases


CASES = _small_cases()


@pytest.fixture(params=list(CASES), scope="module")
def coo_case(request):
    rows, cols, vals, shape = CASES[request.param]
    return to_coo(rows, cols, np.asarray(vals, np.float32), shape)


def test_coo_dense_roundtrip(coo_case):
    d = coo_case.todense()
    assert d.shape == coo_case.shape
    assert int(jnp.sum(d != 0)) <= coo_case.nnz


@pytest.mark.parametrize("fmt", ["csr", "icrs", "bicrs_row", "bicrs_hilbert",
                                 "bicrs_morton"])
def test_flat_roundtrip(coo_case, fmt):
    if fmt == "csr":
        mat = coo_to_csr(coo_case)
    elif fmt == "icrs":
        mat = coo_to_icrs(coo_case)
    else:
        mat = coo_to_bicrs(coo_case, order=fmt.split("_")[1])
    back = mat.to_coo().todense()
    np.testing.assert_allclose(np.asarray(back),
                               np.asarray(coo_case.todense()), rtol=1e-6)


@pytest.mark.parametrize("algo", list(ALGORITHM_SPECS))
def test_spmv_matches_oracle(coo_case, algo):
    kw = {}
    if ALGORITHM_SPECS[algo].blocked:
        kw = dict(beta=16,
                  num_bands=4 if ALGORITHM_SPECS[algo].scheduling ==
                  "static_rows" else 0)
    mat = convert(coo_case, algo, **kw)
    x = jnp.asarray(np.random.default_rng(9).standard_normal(
        coo_case.shape[1]).astype(np.float32))
    y = spmv(mat, x, impl="ref")
    y_ref = spmv_dense_oracle(coo_case, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("algo", [a for a, s in ALGORITHM_SPECS.items()
                                  if s.blocked])
def test_blocked_storage_invariants(coo_case, algo):
    bs = convert(coo_case, algo, beta=16)
    nb = bs.num_blocks
    assert bs.block_ptr.shape[0] == nb + 1
    assert int(bs.block_ptr[-1]) == bs.nnz
    ptr = np.asarray(bs.block_ptr)
    assert np.all(np.diff(ptr) > 0), "blocks must be non-empty"
    # local indices within beta
    lr, lc = bs.local_rows_cols()
    if bs.nnz:
        assert int(jnp.max(lr)) < bs.beta and int(jnp.max(lc)) < bs.beta
    # block coords within grid
    if nb:
        assert int(jnp.max(bs.block_rows)) < bs.grid[0]
        assert int(jnp.max(bs.block_cols)) < bs.grid[1]
    assert bs.storage_bytes() > 0 or bs.nnz == 0


def test_spmv_bf16():
    rows, cols, vals, shape = CASES["uniform"]
    coo = to_coo(rows, cols, np.asarray(vals, np.float32), shape)
    coo16 = to_coo(rows, cols, np.asarray(vals, np.float32), shape,
                   dtype=jnp.bfloat16)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(shape[1]),
                    jnp.bfloat16)
    y16 = spmv(convert(coo16, "csb", beta=16), x, impl="ref")
    y32 = spmv_dense_oracle(coo, x.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(y16, np.float32),
                               np.asarray(y32), rtol=0.1, atol=0.5)


def test_storage_cost_ordering():
    """Paper §4.2: packed-COO in-block costs more than ICRS; BCOHCHP's dense
    pointer beats block-BICRS only when the block grid is dense."""
    rows, cols, vals, shape = matrices.uniform(256, 256, 8192, seed=0)
    coo = to_coo(rows, cols, vals, shape)
    bcoh = convert(coo, "bcoh", beta=16)
    bcohc = convert(coo, "bcohc", beta=16)
    assert bcoh.storage_bytes() < bcohc.storage_bytes()
