"""repro.obs — observability for the SpMM serving stack.

Layers (one module each):

  ``metrics``    process-local ``MetricRegistry``: counters, gauges,
                 reservoir histograms (exact p50/p95/p99 on small N,
                 bounded memory on large N), JSON ``dump()``
  ``trace``      ``span("gather_x")`` phase tracing — host wall time into
                 the registry + ``jax.named_scope`` /
                 ``jax.profiler.TraceAnnotation`` so device traces carry
                 the same names
  ``residuals``  ``ResidualLedger``: observed-vs-modeled pairings that
                 close the roofline loop (``autotune(feedback=)``)
  ``timing``     the paper's §5.2 min-of-N protocol, shared by the bench
                 harness, autotune, and the serve headline

Default state is OFF: until ``install(MetricRegistry(...))`` runs, every
instrumented call site is a no-op and ``span()`` returns an
allocation-free singleton — the serve hot path pays nothing for carrying
its instrumentation (asserted in ``tests/test_obs.py``).
"""
from __future__ import annotations

from .metrics import (Counter, Gauge, Histogram, MetricRegistry,
                      current_registry, enabled, install, uninstall)
from .residuals import (ResidualLedger, ResidualRecord, choice_labels)
from .timing import TimingResult, time_min_of_n
from .trace import maybe_block, span

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricRegistry",
    "current_registry", "enabled", "install", "uninstall",
    "ResidualLedger", "ResidualRecord", "choice_labels",
    "TimingResult", "time_min_of_n",
    "maybe_block", "span",
]
