"""repro.spmm.operator — the stable partition-once/multiply-many handle.

Every repeated-multiply consumer in this repo (the serve batcher, the
iterative examples) used to re-spell the same dance: convert COO to a
format, maybe partition it over a mesh, close a jitted multiply over the
result, and keep the whole plan in ad-hoc locals — which made "change the
format mid-stream" (the paper's §7 break-even economics, ~472 multiplies
to amortize a conversion) impossible without tearing the caller apart.

:class:`SparseOperator` is that seam. It owns the immutable COO source and
a single *current* :class:`RealizedPlan`; ``op.matmul(X)`` multiplies with
whatever plan is installed, and ``op.swap(new_plan)`` replaces it
atomically — the plan is one immutable object read exactly once per
multiply, so a concurrent flush sees either the old plan or the new one,
never a torn mix. ``op.realize(spec)`` builds a plan *without* installing
it, which is what the serve migration controller runs in its background
thread before swapping between flushes.

Convert-time artifacts are cached per operator (the SELL-C-σ stream and
each (schedule, P_data, compact_x) base partition), so a swap that only
changes the psum pipelining depth reuses the existing partition through
:func:`repro.spmm.distributed.rechunk_sellcs` instead of repartitioning.
"""
from __future__ import annotations

import hashlib
import threading
import time
from typing import Callable, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.formats import COO
from repro.core.selector import (MachineSpec, MatrixStats, PlanSpec,
                                 _matrix_bytes_est, matrix_stats, select,
                                 select_distributed)


def coo_fingerprint(coo: COO) -> str:
    """Stable content hash of a COO matrix — the fleet plan-cache key.

    The nonzeros are hashed in the canonical ``(rows, cols, values)``
    lexicographic order, so any permutation of the same triplet stream
    (including duplicate (row, col) entries, which SpMM sums — order
    irrelevant) maps to the same fingerprint, while any value or pattern
    change maps elsewhere. Shape and value dtype are part of the hash: a
    float64 copy of a float32 matrix is a different operator."""
    rows = np.asarray(coo.rows, np.int64)
    cols = np.asarray(coo.cols, np.int64)
    vals = np.asarray(coo.data)
    order = np.lexsort((vals, cols, rows))
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((tuple(int(s) for s in coo.shape),
                   str(vals.dtype))).encode())
    h.update(rows[order].tobytes())
    h.update(cols[order].tobytes())
    h.update(vals[order].tobytes())
    return h.hexdigest()


def _pick_chunk(m: int, num_devices: int, default: int = 128) -> int:
    """Largest power-of-two slice height <= default that still gives every
    device at least one slice to own (small demo matrices on big meshes)."""
    c = default
    while c > 8 and -(-m // c) < num_devices:
        c //= 2
    return c


def _resolve_impl(impl: str) -> str:
    """The serve convention: "auto" means the Pallas kernels on TPU and
    the jnp reference everywhere else."""
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


class RealizedPlan(NamedTuple):
    """One executable multiply plan: the resolved :class:`PlanSpec`, the
    execution-side matrix (a partitioned ``ShardedSellCS`` on a mesh, the
    converted single-device format otherwise), the jitted multiply
    closure, and everything observability needs to price it (the roofline
    ``model_s(k)`` closure, the compact-gather ``n_touched``, the measured
    build seconds). Immutable — :meth:`SparseOperator.swap` installs a
    whole plan in one reference assignment."""
    spec: PlanSpec               # fully resolved (no None knobs on a mesh)
    label: str                   # e.g. "sellcs+merge@4x2mesh/chunks=2"
    matrix: object               # what the multiply executes against
    local_matrix: object         # single-device form for sequential
                                 #   baselines (the pre-partition stream
                                 #   on a mesh; == matrix off one)
    multiply: Callable           # X -> Y, jitted where distributed
    eager: Optional[Callable]    # un-jitted X -> Y (mesh only) — the
                                 #   phase-profile pass --metrics runs
    impl: str                    # resolved kernel impl ("ref"/"pallas")
    n_touched: Optional[float]   # mean touched columns per shard
                                 #   (compact_x plans only)
    model_s: Callable            # k -> roofline seconds for one k-RHS
                                 #   flush under exactly these knobs
    build_s: float               # measured convert+partition seconds —
                                 #   the numerator of the live break-even
    multiply_t: Optional[Callable] = None
                                 # X -> A^T X over the SAME plan artifacts
                                 #   (jitted where distributed); every plan
                                 #   carries one — rmatmul never builds a
                                 #   second partition
    eager_t: Optional[Callable] = None
                                 # un-jitted transpose twin of ``eager``

    def labels(self, **extra) -> Dict[str, str]:
        """Canonical residual-ledger labels for this plan's knobs; the
        single-device case keeps the historical ``schedule=single``
        stamping of the serve metrics pass."""
        from repro.obs.residuals import choice_labels
        sp = self.spec
        if (sp.num_devices or 1) > 1:
            return choice_labels(schedule=sp.schedule,
                                 num_chunks=sp.num_chunks or 1,
                                 mesh_shape=sp.mesh_shape,
                                 compact_x=bool(sp.compact_x),
                                 structure=sp.structure or "general",
                                 gather=((sp.gather or "upfront")
                                         if sp.compact_x else None),
                                 **extra)
        return choice_labels(schedule="single", num_chunks=1,
                             mesh_shape=(1, 1), compact_x=None, **extra)


class OperatorStats:
    """Mutable multiply/swap accounting, updated under the operator lock.
    ``multiplies`` counts SpMV-equivalents (served columns), the unit of
    the paper's "472 multiplications" break-even. The build counters
    (``sellcs_builds``/``partition_builds``: conversions and device deals
    actually paid; ``plan_cache_hits``: artifact-cache reuses) are what
    the fleet tests assert on — a returning tenant's operator must show
    zero builds."""
    __slots__ = ("multiplies", "calls", "swaps", "last_swap_unix_s",
                 "sellcs_builds", "partition_builds", "plan_cache_hits")

    def __init__(self):
        self.multiplies = 0
        self.calls = 0
        self.swaps = 0
        self.last_swap_unix_s: Optional[float] = None
        self.sellcs_builds = 0
        self.partition_builds = 0
        self.plan_cache_hits = 0

    def __repr__(self):
        return (f"OperatorStats(multiplies={self.multiplies}, "
                f"calls={self.calls}, swaps={self.swaps}, "
                f"sellcs_builds={self.sellcs_builds}, "
                f"partition_builds={self.partition_builds}, "
                f"plan_cache_hits={self.plan_cache_hits})")


class _PlanCache:
    """Per-operator convert-time artifact reuse across swaps: the
    SELL-C-σ stream per slice height, and each base partition per
    (schedule, P_data, compact_x) — a chunks-only swap then pays one span
    re-deal (``rechunk_sellcs``), not a repartition."""

    def __init__(self):
        # sellcs keyed by (slice height, structure); partitions by
        # (schedule, P_data, compact_x, structure)
        self.sellcs: Dict[Tuple[int, str], object] = {}
        self.partitions: Dict[Tuple[str, int, bool, str], object] = {}


class SparseOperator:
    """Partition-once / multiply-many handle over one sparse matrix.

    ::

        op = SparseOperator.from_coo(coo, PlanSpec(num_devices=8))
        y = op.matmul(x)          # or: op @ x
        op.swap(PlanSpec(num_devices=8, num_chunks=4))   # atomic
        op.plan, op.spec, op.stats, op.shape

    ``matmul`` reads the current plan exactly once, so a ``swap`` from
    another thread (the serve migration controller's background build)
    can never interleave half-updated state into a flush; pre- and
    post-swap results agree with the oracle bitwise because every plan
    multiplies the same COO nonzeros.
    """

    def __init__(self, coo: COO, plan=None, *,
                 impl: str = "auto", k_hint: int = 32,
                 num_spmvs: int = 1000, feedback=None,
                 cache: Optional[_PlanCache] = None):
        self._coo = coo
        self._mstats = matrix_stats(coo)
        self._impl = impl
        self._k_hint = max(int(k_hint), 1)
        self._num_spmvs = num_spmvs
        self._cache = cache if cache is not None else _PlanCache()
        self._lock = threading.Lock()
        self._build_lock = threading.Lock()
        self.stats = OperatorStats()
        if isinstance(plan, RealizedPlan):
            # fleet plan-cache hit: a returning tenant installs the cached
            # plan directly — no conversion, no partition, no selection
            self._plan = plan
        else:
            self._plan = self.realize(plan or PlanSpec(),
                                      feedback=feedback)

    @classmethod
    def from_coo(cls, coo: COO, plan=None, *,
                 impl: str = "auto", k_hint: int = 32,
                 num_spmvs: int = 1000, feedback=None,
                 cache: Optional[_PlanCache] = None) -> "SparseOperator":
        """Build the handle and realize its initial plan. ``plan`` is a
        :class:`PlanSpec` (None = single-device, format chosen by
        ``core.select`` for ``k_hint`` right-hand sides amortized over
        ``num_spmvs`` multiplies) or an already-built
        :class:`RealizedPlan`, which is installed as-is (the fleet's
        returning-tenant path). ``cache`` shares convert-time artifacts
        (SELL-C-σ stream, base partitions) across operators of the same
        matrix."""
        return cls(coo, plan, impl=impl, k_hint=k_hint,
                   num_spmvs=num_spmvs, feedback=feedback, cache=cache)

    # -- read side ---------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, int]:
        return self._coo.shape

    @property
    def matrix_stats(self) -> MatrixStats:
        return self._mstats

    @property
    def plan(self) -> RealizedPlan:
        return self._plan

    @property
    def spec(self) -> PlanSpec:
        return self._plan.spec

    def matmul(self, x: jax.Array) -> jax.Array:
        """``Y = A @ X`` under the currently installed plan. The plan
        reference is read once — concurrent swaps are invisible within a
        single multiply."""
        rp = self._plan
        y = rp.multiply(x)
        k = 1 if getattr(x, "ndim", 1) == 1 else int(x.shape[1])
        with self._lock:
            self.stats.calls += 1
            self.stats.multiplies += k
        return y

    __matmul__ = matmul

    def rmatmul(self, x: jax.Array) -> jax.Array:
        """``Y = A^T X`` (``X: [m, k]``, ``Y: [n, k]``) under the SAME
        installed plan: both directions share one set of convert-time
        artifacts — the transpose multiplies the stored stream with the
        roles of the row permutation and the column scatter exchanged, so
        no second partition exists to drift out of sync with the forward
        one. Counts toward the same break-even ``multiplies``."""
        rp = self._plan
        if rp.multiply_t is None:
            raise ValueError(
                f"plan {rp.label!r} carries no transpose multiply; "
                "re-realize it (pre-transpose plans cannot rmatmul)")
        y = rp.multiply_t(x)
        k = 1 if getattr(x, "ndim", 1) == 1 else int(x.shape[1])
        with self._lock:
            self.stats.calls += 1
            self.stats.multiplies += k
        return y

    @property
    def T(self) -> "TransposedOperator":
        """Transpose view: ``op.T @ x`` is ``op.rmatmul(x)``. A view, not
        a copy — it reads the operator's current plan at each multiply, so
        swaps show through and ``op.T.T is op``."""
        return TransposedOperator(self)

    def storage_bytes(self) -> int:
        """Execution-side footprint of the installed plan — what the
        multiply actually keeps resident (the partitioned
        ``ShardedSellCS`` on a mesh, the converted format off one; the
        COO triplet estimate only for formats that report no
        ``storage_bytes``). The fleet's ``max_bytes`` budget sums this."""
        rp = self._plan
        for mat in (rp.matrix, rp.local_matrix):
            fn = getattr(mat, "storage_bytes", None)
            if fn is not None:
                return int(fn())
        coo = self._coo
        return int(8 * np.asarray(coo.rows).size
                   + np.asarray(coo.data).nbytes)

    # -- write side --------------------------------------------------------
    def realize(self, spec: PlanSpec, feedback=None) -> RealizedPlan:
        """Build an executable plan for ``spec`` WITHOUT installing it —
        safe to call from a background thread while ``matmul`` traffic
        runs on the current plan. ``feedback`` (a ``ResidualLedger``)
        reaches ``select_distributed`` so unpinned knobs are chosen with
        ledger-corrected scores."""
        with self._build_lock:
            return _realize_plan(self._coo, self._mstats, spec,
                                 impl=self._impl, k_hint=self._k_hint,
                                 num_spmvs=self._num_spmvs,
                                 feedback=feedback, cache=self._cache,
                                 op_stats=self.stats)

    def swap(self, new_plan, feedback=None) -> RealizedPlan:
        """Atomically install ``new_plan`` (a :class:`RealizedPlan`, or a
        :class:`PlanSpec` realized on the spot) as the current plan; the
        next ``matmul`` call uses it. Returns the installed plan."""
        if isinstance(new_plan, PlanSpec):
            new_plan = self.realize(new_plan, feedback=feedback)
        if not isinstance(new_plan, RealizedPlan):
            raise TypeError("swap takes a RealizedPlan or PlanSpec, got "
                            f"{type(new_plan).__name__}")
        with self._lock:
            self._plan = new_plan
            self.stats.swaps += 1
            self.stats.last_swap_unix_s = time.time()
        return new_plan

    def shrink_to(self, devices: Sequence, *,
                  num_chunks: Optional[int] = None) -> RealizedPlan:
        """Device-loss path: re-deal the current distributed plan's
        width-row stream over ``devices`` (the survivors) and atomically
        install the shrunken plan. The global stream is reconstructed from
        the existing shards (:func:`repro.spmm.distributed.redeal_sellcs`)
        — no σ-sort, no COO→SELL-C-σ conversion — and the mesh is rebuilt
        with the :func:`repro.runtime.elastic.largest_feasible_mesh`
        policy: the model axis keeps its width, the loss is absorbed on
        the data axis. Returns the installed plan."""
        from repro.launch.mesh import make_spmm_mesh
        from repro.roofline import spmm_distributed_time
        from repro.runtime.elastic import largest_feasible_mesh
        from repro.spmm.distributed import redeal_sellcs
        rp = self._plan
        sp = rp.spec
        if (sp.num_devices or 1) <= 1:
            raise ValueError(
                "shrink_to needs a distributed plan; the current plan is "
                f"single-device ({rp.label!r})")
        _, pm = sp.mesh_shape
        pd, pm = largest_feasible_mesh(len(devices), pm)
        nc = int(num_chunks) if num_chunks is not None else (sp.num_chunks
                                                            or 1)
        t0 = time.perf_counter()
        with self._build_lock:
            sharded = redeal_sellcs(rp.matrix, pd, num_chunks=nc)
            mesh = make_spmm_mesh((pd, pm), devices=list(devices)[:pd * pm])
            compact = bool(sp.compact_x)
            # survivors' partition replaces the stale artifact so a later
            # chunks-only swap re-deals from the live device count
            self._cache.partitions[(sp.schedule, pd, compact,
                                    sp.structure or "general")] = sharded
            with self._lock:
                self.stats.partition_builds += 1
            plan = _mesh_plan(sharded, rp.local_matrix, self._mstats, mesh,
                              schedule=sp.schedule, chunks=nc, pd=pd, pm=pm,
                              compact=compact, impl_r=rp.impl,
                              time_fn=spmm_distributed_time, t0=t0,
                              gather=((sp.gather or "upfront") if compact
                                      else "upfront"))
        return self.swap(plan)


class TransposedOperator:
    """Zero-copy transpose view over a :class:`SparseOperator` — the
    ``op.T`` surface. Shares the parent's plan (and therefore its swap
    atomicity and break-even accounting); only the multiply direction and
    the reported shape flip."""

    def __init__(self, base: SparseOperator):
        self._base = base

    @property
    def shape(self) -> Tuple[int, int]:
        m, n = self._base.shape
        return n, m

    @property
    def plan(self) -> RealizedPlan:
        return self._base.plan

    @property
    def T(self) -> SparseOperator:
        return self._base

    def matmul(self, x: jax.Array) -> jax.Array:
        return self._base.rmatmul(x)

    __matmul__ = matmul

    def rmatmul(self, x: jax.Array) -> jax.Array:
        return self._base.matmul(x)


def sparse_matmul(op: SparseOperator, x: jax.Array) -> jax.Array:
    """Differentiable ``Y = op @ x``: the forward multiply runs through the
    operator's realized plan and the backward cotangent through the SAME
    plan's transpose multiply (``d loss/d x = op.rmatmul(g)``, i.e.
    ``A^T g`` over the one stored stream). This is the training-surface
    entry point — drop a fixed sparse mixing matrix inside a loss and
    ``jax.grad`` flows through both ops of the operator."""

    @jax.custom_vjp
    def f(x):
        return op.matmul(x)

    def fwd(x):
        return op.matmul(x), None

    def bwd(_, g):
        return (op.rmatmul(g),)

    f.defvjp(fwd, bwd)
    return f(x)


def _realize_plan(coo: COO, stats: MatrixStats, spec: PlanSpec, *,
                  impl: str, k_hint: int, num_spmvs: int, feedback=None,
                  cache: Optional[_PlanCache] = None,
                  op_stats: Optional[OperatorStats] = None) -> RealizedPlan:
    from repro.roofline import spmm_distributed_time
    spec = spec.canonical()
    cache = cache or _PlanCache()
    t0 = time.perf_counter()
    if spec.num_devices == 1:
        return _realize_single(coo, stats, spec, impl=impl, k_hint=k_hint,
                               num_spmvs=num_spmvs, t0=t0,
                               time_fn=spmm_distributed_time)
    return _realize_mesh(coo, stats, spec, impl=impl, k_hint=k_hint,
                         num_spmvs=num_spmvs, feedback=feedback,
                         cache=cache, t0=t0,
                         time_fn=spmm_distributed_time,
                         op_stats=op_stats)


def _realize_single(coo, stats, spec, *, impl, k_hint, num_spmvs, t0,
                    time_fn):
    from repro.core.convert import convert
    import dataclasses
    algo = spec.algorithm or select(stats, MachineSpec(1),
                                    num_spmvs=num_spmvs, k=k_hint)
    structure = spec.structure or "general"
    if structure == "symmetric" and algo != "sellcs":
        raise ValueError(
            "structure='symmetric' (one-triangle storage) is executable "
            f"only on the SELL-C-σ stream, not {algo!r}")
    if algo == "sellcs" and structure != "general":
        from repro.spmm import coo_to_sellcs
        mat = coo_to_sellcs(coo, structure=structure)
    else:
        mat = convert(coo, algo)
    mat_bytes = _matrix_bytes_est(algo, stats)

    def multiply(X):
        from repro.spmm import spmm
        return spmm(mat, X, impl=impl)

    from repro.spmm.sellcs import SellCS as _SellCS
    if isinstance(mat, (_SellCS, COO)):
        def multiply_t(X):
            from repro.spmm import spmm
            return spmm(mat, X, impl=impl, op="T")
    else:
        # formats without a transpose path fall back to the immutable COO
        # source the operator already owns — correct, just unamortized
        def multiply_t(X):
            from repro.spmm.reference import spmm_ref
            return spmm_ref(coo, X, op="T")

    def model_s(k):
        # the distributed model at P=1 degenerates to the plain
        # streaming-bytes roofline for this format
        return time_fn(stats.m, stats.n, k, 1, "row",
                       matrix_bytes=mat_bytes,
                       max_row_nnz=stats.max_row_nnz, nnz=stats.nnz,
                       structure=structure)

    resolved = dataclasses.replace(spec, algorithm=algo,
                                   structure=structure)
    return RealizedPlan(resolved, algo, mat, mat, multiply, None,
                        _resolve_impl(impl), None, model_s,
                        time.perf_counter() - t0,
                        multiply_t=multiply_t)


def _realize_mesh(coo, stats, spec, *, impl, k_hint, num_spmvs, feedback,
                  cache, t0, time_fn, op_stats=None):
    import dataclasses
    from repro.launch.mesh import make_spmm_mesh
    from repro.spmm import coo_to_sellcs
    from repro.spmm.distributed import (partition_sellcs_nnz,
                                        partition_sellcs_rows,
                                        rechunk_sellcs)
    total = spec.num_devices
    ndev = len(jax.devices())
    if ndev < total:
        raise RuntimeError(
            f"the mesh needs {total} devices but jax sees only {ndev}; on "
            "CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{total} before launching")
    if spec.algorithm not in (None, "sellcs"):
        raise ValueError(
            f"algorithm {spec.algorithm!r} cannot run on a mesh: the "
            "distributed multiply executes the SELL-C-σ slice stream "
            "(repro.spmm.distributed)")
    # joint (schedule × chunks × mesh × gather) choice under the spec's
    # pins; conversion cost is shared by every candidate so it drops out
    # of the argmin — the old serve grid-min, through one entry point
    choice = select_distributed(
        stats, k=k_hint, num_spmvs=num_spmvs,
        spec=dataclasses.replace(spec, algorithm="sellcs"),
        feedback=feedback)
    schedule, chunks = choice.schedule, choice.num_chunks
    (pd, pm), compact = choice.mesh_shape, choice.compact_x
    structure = choice.structure
    gather = choice.gather if compact else "upfront"
    mesh = make_spmm_mesh((pd, pm))
    c = _pick_chunk(stats.m, pd)
    skey = (c, structure)
    sc = cache.sellcs.get(skey)
    if sc is None:
        sc = cache.sellcs.setdefault(
            skey, coo_to_sellcs(coo, c=c, structure=structure))
        if op_stats is not None:
            op_stats.sellcs_builds += 1
    elif op_stats is not None:
        op_stats.plan_cache_hits += 1
    impl_r = _resolve_impl(impl)
    key = (schedule, pd, compact, structure)
    base = cache.partitions.get(key)
    if base is None:
        part = (partition_sellcs_rows if schedule == "row"
                else partition_sellcs_nnz)
        base = cache.partitions.setdefault(
            key, part(sc, pd, compact_x=compact))
        if op_stats is not None:
            op_stats.partition_builds += 1
    elif op_stats is not None:
        op_stats.plan_cache_hits += 1
    if schedule == "row":
        sharded = base
    else:
        # partition reuse across swaps: only the span plan is re-baked
        sharded = rechunk_sellcs(base, chunks)
    return _mesh_plan(sharded, sc, stats, mesh, schedule=schedule,
                      chunks=chunks, pd=pd, pm=pm, compact=compact,
                      impl_r=impl_r, time_fn=time_fn, t0=t0, gather=gather)


def _mesh_plan(sharded, sc, stats, mesh, *, schedule, chunks, pd, pm,
               compact, impl_r, time_fn, t0, gather="upfront"):
    """Close a :class:`RealizedPlan` over an already-partitioned stream —
    the shared tail of the convert-time realize and the device-loss
    ``shrink_to`` re-deal (which brings its own survivors' mesh)."""
    from repro.spmm.distributed import (spmm_merge_distributed,
                                        spmm_row_distributed)
    structure = getattr(sharded, "structure", "general")
    gx = gather if compact else None
    if schedule == "row":
        eager = lambda X: spmm_row_distributed(sharded, X, mesh,
                                               impl=impl_r, gather=gx)
        eager_t = lambda X: spmm_row_distributed(sharded, X, mesh,
                                                 impl=impl_r, op="T",
                                                 gather=gx)
    else:
        eager = lambda X: spmm_merge_distributed(sharded, X, mesh,
                                                 impl=impl_r,
                                                 num_chunks=chunks,
                                                 gather=gx)
        eager_t = lambda X: spmm_merge_distributed(sharded, X, mesh,
                                                   impl=impl_r,
                                                   num_chunks=chunks,
                                                   op="T", gather=gx)
    # the jitted closure keeps repeated flushes of one batch shape from
    # retracing the shard_map body
    jitted = jax.jit(eager)
    jitted_t = jax.jit(eager_t)
    mesh_tag = f"{pd}x{pm}mesh" if pm > 1 else f"{pd}dev"
    cx_tag = "/cx=on" if compact else ""
    gx_tag = f"/gx={gather}" if compact and gather != "upfront" else ""
    sym_tag = "/sym" if structure == "symmetric" else ""
    if schedule == "row":
        label = f"sellcs+row@{mesh_tag}{cx_tag}{gx_tag}{sym_tag}"
    else:
        label = (f"sellcs+merge@{mesh_tag}/chunks={chunks}"
                 f"{cx_tag}{gx_tag}{sym_tag}")
    # price the gather with the map the multiply EXECUTES: the chunked
    # merge gathers through the chunk plan's re-dealt map, not the base
    # partition's
    n_touched = None
    if compact:
        nt_src = (sharded.chunk_plan[3]
                  if sharded.chunk_plan is not None else sharded.n_touched)
        n_touched = float(np.mean(np.asarray(nt_src)))
    sellcs_bytes = _matrix_bytes_est("sellcs", stats)

    def model_s(k):
        return time_fn(stats.m, stats.n, k, pd, schedule,
                       matrix_bytes=sellcs_bytes,
                       max_row_nnz=stats.max_row_nnz, num_chunks=chunks,
                       model_devices=pm, compact_x=compact,
                       n_touched=n_touched, nnz=stats.nnz,
                       structure=structure,
                       gather=gather if compact else "upfront")

    resolved = PlanSpec(num_devices=pd * pm, mesh_shape=(pd, pm),
                        num_chunks=chunks, compact_x=compact,
                        schedule=schedule, algorithm="sellcs",
                        structure=structure,
                        gather=gather if compact else None)
    return RealizedPlan(resolved, label, sharded, sc, jitted, eager,
                        impl_r, n_touched, model_s,
                        time.perf_counter() - t0,
                        multiply_t=jitted_t, eager_t=eager_t)


__all__ = ["SparseOperator", "TransposedOperator", "RealizedPlan",
           "OperatorStats", "PlanSpec", "coo_fingerprint", "sparse_matmul"]
