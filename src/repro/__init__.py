"""repro — "Algorithms for Parallel Shared-Memory Sparse Matrix-Vector
Multiplication on Unstructured Matrices", grown into a JAX/Pallas system.

Module map
----------
``repro.core``       the paper's contribution: storage formats (COO/CSR/
                     ICRS/BICRS/BlockedSparse), space-filling-curve
                     orderings, merge-path balancing, conversion pipeline,
                     the §7 algorithm selector (k-aware ``select``) and the
                     §8 autotuner.
``repro.spmm``       the multi-RHS SpMM engine: SELL-C-σ storage
                     (``sellcs``; ``structure="symmetric"`` stores one
                     triangle + diagonal), pure-jnp oracles
                     (``reference``), tiled Pallas kernels with a k-tile
                     grid dimension plus the scatter-accumulate transpose
                     kernel (``kernels``), request batching for the serve
                     path (``batching``), the shard_map mesh schedules —
                     row bands / merge spans over the slice stream, both
                     op-aware (``op="N"|"T"``, ``distributed``) — and
                     ``SparseOperator`` (``operator``): the stable
                     partition-once/multiply-many handle whose atomic plan
                     swap carries the serve path's online format
                     migration, with ``rmatmul``/``.T`` running ``A^T X``
                     over the same stored plan and ``sparse_matmul``
                     making both ends differentiable, and ``Fleet``
                     (``fleet``): the multi-tenant operator registry —
                     fingerprint-keyed plan cache, device-loss re-deal via
                     ``redeal_sellcs``, LRU eviction under a
                     ``max_bytes`` storage budget. SpMV is the k = 1
                     special case.
``repro.kernels``    Pallas TPU kernels for the single-vector compute
                     paths: blocked SpMV (``bsr_spmv``), merge-path SpMV
                     (``merge_spmv``), MoE grouped GEMM, plus the
                     TiledSparse 8x128 mini-tile compute format.
``repro.roofline``   roofline terms from compiled HLO + the SpMM intensity
                     model that picks k-tiles.
``repro.data``       synthetic matrix generators matched to the paper's
                     test-set classes (uniform/rmat/powerlaw/mesh2d/
                     ``mawi_like`` skew) and the token pipeline.
``repro.models``     the LM stack (attention/SSM/MoE) whose sparse pieces
                     exercise the kernels at scale.
``repro.configs``    model architecture presets.
``repro.obs``        observability: the process-local ``MetricRegistry``
                     (phase spans, exact percentile histograms), the
                     observed-vs-modeled ``ResidualLedger`` that feeds
                     ``select_distributed``/``autotune(feedback=)``, and
                     the §5.2 ``time_min_of_n`` protocol.
``repro.launch``     meshes, shardings, train/serve/dryrun entry points —
                     ``launch.serve --mode spmv`` drives the SpMM request
                     batcher through one ``SparseOperator`` handle, with
                     ``--migrate auto|force`` running the online
                     break-even format migration behind it;
                     ``--mode fleet`` serves N tenants through a
                     ``Fleet`` + ``FleetBatcher`` front end and survives
                     an injected mid-stream device loss.
``repro.optim``      optimizers.
``repro.checkpoint`` checkpointing.
``repro.runtime``    elasticity + fault tolerance: ``elastic`` rebuilds
                     meshes from the live device set
                     (``largest_feasible_mesh``, the guard-checked
                     ``reshard``) and ``fault_tolerance`` watches step
                     times (``StragglerMonitor``) — both wired into the
                     serve fleet's device-loss path.
``repro.compat``     shims over jax/Pallas API renames.

Submodules import lazily (nothing heavy happens at ``import repro``).
"""
__version__ = "0.1.0"

__all__ = [
    "core", "spmm", "kernels", "roofline", "data", "models", "configs",
    "obs", "launch", "optim", "checkpoint", "runtime", "compat",
]
