"""PageRank on an RMAT graph — the paper's motivating SpMV workload (§1).

Power iteration: r <- d * A^T_norm r + (1-d)/n, served through one
``repro.spmm.SparseOperator`` handle: the loop multiplies against
``op @ r`` while the handle starts in the zero-conversion merge-path
format and is swapped to SELL-C-σ mid-stream — the §7 break-even
argument in action (conversion cost amortized over the iterations), and
a live demonstration that an atomic plan swap never changes the math.

Run:  PYTHONPATH=src python examples/pagerank.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import PlanSpec, to_coo
from repro.core.selector import ZERO_CONVERSION_ALGO
from repro.data import matrices
from repro.spmm import SparseOperator

# RMAT graph, column-normalized adjacency (column-stochastic)
rows, cols, vals, shape = matrices.rmat(scale=13, edge_factor=12, seed=0)
n = shape[0]
out_deg = np.bincount(cols, minlength=n).astype(np.float32)
norm_vals = 1.0 / np.maximum(out_deg[cols], 1.0)
coo = to_coo(rows, cols, norm_vals, shape)

DAMP, ITERS = 0.85, 50


def pagerank(op, label):
    t0 = time.perf_counter()
    r = jnp.full((n,), 1.0 / n, jnp.float32)
    for _ in range(ITERS):
        r = DAMP * (op @ r) + (1 - DAMP) / n
        r = r / jnp.sum(r)
    r.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"  {label:16s} {ITERS} iterations in {dt * 1e3:.0f} ms "
          f"({dt / ITERS * 1e3:.2f} ms/iter)")
    return r


# zero-conversion start: merge-path CSR costs one row-sort, nothing else
t0 = time.perf_counter()
op = SparseOperator.from_coo(
    coo, PlanSpec(num_devices=1, algorithm=ZERO_CONVERSION_ALGO),
    impl="ref", k_hint=1, num_spmvs=ITERS)
t_start = time.perf_counter() - t0
r1 = pagerank(op, op.plan.label)

# mid-stream format migration: build the SELL-C-σ plan and swap it in
# atomically — the next multiply uses it, the math never changes
t0 = time.perf_counter()
op.swap(PlanSpec(num_devices=1, algorithm="sellcs"))
t_swap = time.perf_counter() - t0
print(f"conversion: {ZERO_CONVERSION_ALGO} {t_start * 1e3:.0f} ms at "
      f"start, sellcs {t_swap * 1e3:.0f} ms swapped in after "
      f"{op.stats.multiplies} multiplies")
r2 = pagerank(op, op.plan.label)
np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-5)
print(f"operator stats: {op.stats}")

top = np.argsort(-np.asarray(r1))[:5]
print(f"top-5 nodes: {top.tolist()}")
print(f"rank mass of top-5: {float(jnp.sum(r1[top])):.4f}")
print("pagerank OK")
