"""Granite-3.0-1B-A400M [hf:ibm-granite]: MoE 32e top-8, GQA(kv=8)."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
    kv_heads=8, d_ff=512, vocab=49155, head_dim=64, rope_theta=1e4,
    n_experts=32, top_k=8, tie_embeddings=True,
    block_pattern=("attn",), mlp_pattern=("moe",))

REDUCED = ModelConfig(
    name="granite-moe-1b-a400m-reduced", n_layers=2, d_model=64, n_heads=4,
    kv_heads=2, d_ff=64, vocab=256, head_dim=16, n_experts=8, top_k=4,
    tie_embeddings=True, block_pattern=("attn",), mlp_pattern=("moe",),
    compute_dtype=jnp.float32, loss_chunk=16)
