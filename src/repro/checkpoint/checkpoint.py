"""Sharded checkpointing with atomic commit, async flush and retention.

Layout:  <dir>/step_<N>/
             manifest.json          (step, tree-def, leaf index, meta)
             shard_<host>.npz       (flattened leaves owned by this host)
         <dir>/step_<N>.COMMITTED   (rename-commit marker)

Restart safety: a checkpoint is visible to ``latest_step`` only after its
COMMITTED marker exists; the marker is written with os.replace (atomic on
POSIX), so a crash mid-save never yields a half checkpoint. Combined with
the step-keyed data pipeline, restore -> replay is bit-exact (verified by
tests/test_fault_tolerance.py)."""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any, *, host: int = 0,
         meta: Optional[Dict] = None, blocking: bool = True,
         keep: int = 3) -> threading.Thread:
    """Save ``tree`` (any pytree of arrays) for ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    leaves = _flatten_with_paths(tree)
    # pull to host memory synchronously (cheap), flush async
    arrays = {f"leaf_{i}": np.asarray(leaf) for i, (_, leaf)
              in enumerate(leaves)}
    manifest = {
        "step": step,
        "keys": [k for k, _ in leaves],
        "meta": meta or {},
        "num_hosts": 1,
    }

    def flush():
        os.makedirs(tmp_dir, exist_ok=True)
        np.savez(os.path.join(tmp_dir, f"shard_{host:05d}.npz"), **arrays)
        with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(step_dir):
            shutil.rmtree(step_dir)
        os.replace(tmp_dir, step_dir)
        # commit marker (atomic)
        marker_tmp = step_dir + ".marker"
        with open(marker_tmp, "w") as f:
            f.write(str(step))
        os.replace(marker_tmp, step_dir + ".COMMITTED")
        _apply_retention(ckpt_dir, keep)

    t = threading.Thread(target=flush)
    t.start()
    if blocking:
        t.join()
    return t


def _committed_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.endswith(".COMMITTED"):
            steps.append(int(name[len("step_"):-len(".COMMITTED")]))
    return sorted(steps)


def _apply_retention(ckpt_dir: str, keep: int):
    steps = _committed_steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        sd = os.path.join(ckpt_dir, f"step_{s:08d}")
        shutil.rmtree(sd, ignore_errors=True)
        try:
            os.remove(sd + ".COMMITTED")
        except OSError:
            pass


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, target_tree: Any, *,
            host: int = 0) -> Any:
    """Restore into the structure of ``target_tree`` (shapes validated)."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(step_dir, f"shard_{host:05d}.npz"))
    leaves_t, treedef = jax.tree_util.tree_flatten(target_tree)
    keys = manifest["keys"]
    assert len(keys) == len(leaves_t), \
        f"checkpoint has {len(keys)} leaves, target {len(leaves_t)}"
    new_leaves = []
    for i, tgt in enumerate(leaves_t):
        arr = data[f"leaf_{i}"]
        assert arr.shape == tuple(tgt.shape), \
            f"leaf {keys[i]}: ckpt {arr.shape} vs target {tgt.shape}"
        new_leaves.append(
            jax.device_put(arr.astype(tgt.dtype),
                           getattr(tgt, "sharding", None)))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def restore_meta(ckpt_dir: str, step: int) -> Dict:
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        return json.load(f)["meta"]
