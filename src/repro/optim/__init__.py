"""repro.optim — ZeRO-shardable optimizers + LR schedules."""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp

from .adafactor import AdafactorState, adafactor
from .adamw import (AdamWState, Optimizer, adamw, clip_by_global_norm,
                    global_norm)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1) -> Callable:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(math.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule


def constant_lr(lr: float) -> Callable:
    return lambda step: jnp.full((), lr, jnp.float32)


def make_optimizer(name: str, lr_schedule: Callable, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr_schedule, **kw)
    if name == "adafactor":
        return adafactor(lr_schedule, **kw)
    raise ValueError(f"unknown optimizer {name!r}")


__all__ = ["Optimizer", "AdamWState", "AdafactorState", "adamw",
           "adafactor", "warmup_cosine", "constant_lr", "make_optimizer",
           "global_norm", "clip_by_global_norm"]
