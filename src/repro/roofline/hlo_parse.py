"""HloCostAnalysis-lite with while-loop trip-count multipliers.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``lax.scan`` of N layers reports 1/N of the real FLOPs/bytes (verified in
EXPERIMENTS §Dry-run). Since this framework scans over layer groups, loss
chunks and flash-attention chunks, we parse the post-optimization HLO text
ourselves:

  * build the computation call graph (fusions, while bodies/conds,
    conditionals);
  * extract each while's trip count from the s32 constant in its condition;
  * multiply each computation's costs by the product of enclosing trip
    counts;
  * per instruction: dot FLOPs = 2 * |output| * |contracting dims|,
    elementwise FLOPs = |output|, bytes = operands + output,
    collective bytes = output bytes (all-reduce x2 in the roofline model).

Validated against unrolled references in tests/test_roofline.py.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_TOKEN = re.compile(
    r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)"
    r"\[([0-9,]*)\]")

_INSTR = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")

_CALL_ATTRS = ("calls=", "to_apply=", "body=", "condition=")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "exponential", "log", "tanh", "negate", "power", "rsqrt", "sqrt",
    "select", "compare", "and", "or", "xor", "convert", "floor", "ceil",
    "cosine", "sine", "logistic", "expm1", "log1p", "remainder", "sign",
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_list(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_TOKEN.findall(text):
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _nelems(shapes) -> int:
    total = 0
    for _, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


class Instruction:
    __slots__ = ("name", "op", "out_shapes", "operands", "attrs", "line")

    def __init__(self, name, op, out_shapes, operands, attrs, line):
        self.name = name
        self.op = op
        self.out_shapes = out_shapes
        self.operands = operands
        self.attrs = attrs
        self.line = line


class Computation:
    def __init__(self, name):
        self.name = name
        self.instructions: Dict[str, Instruction] = {}
        self.order: List[str] = []

    def add(self, instr: Instruction):
        self.instructions[instr.name] = instr
        self.order.append(instr.name)


_OP_RE = re.compile(r"([\w\-]+)\(")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        # computation header: "%name (args) -> type {" or "ENTRY ..."
        if stripped.endswith("{") and ("->" in stripped
                                       or stripped.startswith("ENTRY")):
            m = re.search(r"%?([\w.\-]+)\s*\(", stripped)
            name = m.group(1) if m else f"comp{len(comps)}"
            cur = Computation(name)
            comps[name] = cur
            if stripped.startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if stripped == "}" or cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name = m.group(2)
        rhs = m.group(3)
        # split "type op(operands), attrs"
        om = _OP_RE.search(rhs)
        if not om:
            continue
        op = om.group(1)
        out_shapes = _shape_list(rhs[:om.start()])
        # operand names: %refs inside the first (...) after op
        paren = rhs[om.end():]
        depth, end = 1, 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_text = paren[:end]
        operands = re.findall(r"%([\w.\-]+)", operand_text)
        attrs = paren[end + 1:]
        cur.add(Instruction(name, op, out_shapes, operands, attrs, stripped))
    return comps


def _callees(instr: Instruction) -> List[str]:
    out = []
    text = instr.attrs + " " + instr.line
    for attr in _CALL_ATTRS:
        for m in re.finditer(re.escape(attr) + r"\s*%?([\w.\-]+)", text):
            out.append(m.group(1))
    for m in re.finditer(r"branch_computations=\{([^}]*)\}", text):
        out += re.findall(r"%?([\w.\-]+)", m.group(1))
    return out


def _trip_count_deep(cond: Computation, comps: Dict[str, "Computation"],
                     depth: int = 0) -> int:
    """Trip count constant may sit inside a fusion called by the cond."""
    best = _trip_count(cond)
    if depth < 3:
        for iname in cond.order:
            for c in _callees(cond.instructions[iname]):
                if c in comps:
                    best = max(best,
                               _trip_count_deep(comps[c], comps, depth + 1))
    return best


def _trip_count(cond: Computation) -> int:
    """Largest s32/u32 constant in the while condition — scans lower to
    `iter < C`. Dynamic conditions fall back to 1 (flagged upstream)."""
    best = 1
    for iname in cond.order:
        ins = cond.instructions[iname]
        if ins.op == "constant" and ins.out_shapes and \
                ins.out_shapes[0][0] in ("s32", "u32", "s64", "u64"):
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(instr: Instruction, symtab) -> float:
    out_elems = _nelems(instr.out_shapes)
    lhs = symtab.get(instr.operands[0]) if instr.operands else None
    if lhs is None:
        return 2.0 * out_elems
    m = re.search(r"lhs_contracting_dims=\{([^}]*)\}",
                  instr.attrs + instr.line)
    contracted = 1
    if m and lhs:
        dims = [int(d) for d in m.group(1).split(",") if d.strip()]
        _, lshape = lhs[0]
        for d in dims:
            if d < len(lshape):
                contracted *= lshape[d]
    return 2.0 * out_elems * contracted


def analyze(text: str) -> Dict[str, float]:
    """Whole-module costs with trip-count multipliers."""
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                "collectives": {}}

    # resolve multipliers by DFS from entry
    mult: Dict[str, float] = defaultdict(float)

    def visit(comp: Computation, m: float):
        mult[comp.name] += m
        for iname in comp.order:
            ins = comp.instructions[iname]
            if ins.op == "while":
                text = ins.attrs + " " + ins.line
                cm = re.search(r"condition=%?([\w.\-]+)", text)
                bm = re.search(r"body=%?([\w.\-]+)", text)
                cond = comps.get(cm.group(1)) if cm else None
                body = comps.get(bm.group(1)) if bm else None
                trips = _trip_count_deep(cond, comps) if cond else 1
                if cond is not None:
                    visit(cond, m * (trips + 1))
                if body is not None:
                    visit(body, m * trips)
            else:
                for c in _callees(ins):
                    if c in comps:
                        visit(comps[c], m)

    visit(entry, 1.0)

    # computations that are fusion bodies: their internals never touch HBM
    # (XLA materializes only fusion inputs/outputs), so they contribute
    # FLOPs but not bytes.
    fused: set = set()
    for comp in comps.values():
        for iname in comp.order:
            ins = comp.instructions[iname]
            if "fusion" in ins.op:
                for c in _callees(ins):
                    fused.add(c)

    flops = 0.0
    nbytes = 0.0
    coll_bytes = 0.0
    coll_detail: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"bytes": 0.0, "count": 0.0})

    for key, comp in comps.items():
        if key == "__entry__":      # alias of the ENTRY computation
            continue
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        symtab = {i.name: i.out_shapes for i in comp.instructions.values()}
        for iname in comp.order:
            ins = comp.instructions[iname]
            out_b = _nbytes(ins.out_shapes)
            op_b = sum(_nbytes(symtab.get(o, [])) for o in ins.operands)
            if comp.name not in fused and ins.op not in (
                    "tuple", "get-tuple-element", "parameter", "constant",
                    "bitcast", "while", "conditional"):
                nbytes += m * (out_b + op_b)
            if ins.op in ("dot", "convolution"):
                flops += m * _dot_flops(ins, symtab)
            elif ins.op in _ELEMENTWISE:
                flops += m * _nelems(ins.out_shapes)
            for kind in COLLECTIVES:
                if ins.op == kind or ins.op == kind + "-start":
                    coll_detail[kind]["bytes"] += m * out_b
                    coll_detail[kind]["count"] += m
                    mul = 2.0 if kind == "all-reduce" else 1.0
                    coll_bytes += mul * m * out_b
                    break

    return {"flops": flops, "bytes": nbytes,
            "collective_bytes": coll_bytes,
            "collectives": {k: dict(v) for k, v in coll_detail.items()}}
