"""Pure-jnp SpMM oracles (``Y = A @ X``, ``X: [n, k]``).

These are the correctness baselines for every format's multi-RHS multiply
and the XLA fallback the dispatcher uses off-TPU. Each is the column-wise
generalization of the corresponding ``repro.core.spmv`` oracle: SpMV is
exactly the ``k = 1`` column of each of these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import COO, CSR, BlockedSparse
from .sellcs import SellCS

Array = jax.Array


def _as_2d(x: Array):
    """Return (X_2d, was_1d): SpMV inputs ride along as k = 1."""
    if x.ndim == 1:
        return x[:, None], True
    if x.ndim != 2:
        raise ValueError(f"X must be [n] or [n, k], got shape {x.shape}")
    return x, False


@jax.jit
def spmm_coo(coo: COO, x: Array) -> Array:
    x2, squeeze = _as_2d(x)
    m, _ = coo.shape
    k = x2.shape[1]
    dtype = jnp.promote_types(coo.data.dtype, x2.dtype)
    y = jnp.zeros((m, k), dtype)
    if coo.nnz:
        y = y.at[coo.rows].add(coo.data[:, None] * x2[coo.cols])
    return y[:, 0] if squeeze else y


@jax.jit
def spmm_csr(csr: CSR, x: Array) -> Array:
    x2, squeeze = _as_2d(x)
    m, _ = csr.shape
    k = x2.shape[1]
    dtype = jnp.promote_types(csr.data.dtype, x2.dtype)
    if csr.nnz == 0:
        y = jnp.zeros((m, k), dtype)
        return y[:, 0] if squeeze else y
    rows = csr.row_of_nnz()
    prod = csr.data[:, None] * x2[csr.col_ind]
    y = jax.ops.segment_sum(prod, rows, num_segments=m).astype(dtype)
    return y[:, 0] if squeeze else y


@jax.jit
def spmm_blocked(bs: BlockedSparse, x: Array) -> Array:
    x2, squeeze = _as_2d(x)
    m, _ = bs.shape
    k = x2.shape[1]
    dtype = jnp.promote_types(bs.data.dtype, x2.dtype)
    if bs.nnz == 0:
        y = jnp.zeros((m, k), dtype)
        return y[:, 0] if squeeze else y
    bid = bs.block_of_nnz()
    lr, lc = bs.local_rows_cols()
    rows = bs.block_rows[bid] * bs.beta + lr
    cols = bs.block_cols[bid] * bs.beta + lc
    prod = bs.data[:, None] * x2[cols]
    y = jax.ops.segment_sum(prod, rows, num_segments=m).astype(dtype)
    return y[:, 0] if squeeze else y


def sellcs_slots_ref(data: Array, cols: Array, slice_of: Array, x2: Array,
                     *, num_slices: int, chunk: int) -> Array:
    """Raw-array slot accumulation [num_slices*chunk, k] — the jnp twin of
    ``repro.spmm.kernels.sellcs_slots`` and the XLA body of the distributed
    schedules. No row permutation is applied."""
    dtype = jnp.promote_types(data.dtype, x2.dtype)
    k = x2.shape[1]
    xs = x2[cols]                                       # [W, C, k]
    contrib = data[:, :, None] * xs                     # [W, C, k]
    slot = (slice_of[:, None] * chunk
            + jnp.arange(chunk, dtype=jnp.int32)[None])  # [W, C]
    return jnp.zeros((num_slices * chunk, k), dtype).at[slot].add(contrib)


def sellcs_slots_chunk_ref(data: Array, cols: Array, slice_of: Array,
                           x2: Array, *, slice_start: int, num_slices: int,
                           chunk: int) -> Array:
    """jnp twin of ``kernels.sellcs_slots_chunk``: slot accumulation over a
    chunk sub-stream whose ``slice_of`` is still global, rebased to the
    chunk-local slot space starting at ``slice_start``."""
    local = jnp.clip(slice_of.astype(jnp.int32) - slice_start, 0,
                     max(num_slices - 1, 0))
    return sellcs_slots_ref(data, cols, local, x2, num_slices=num_slices,
                            chunk=chunk)


@jax.jit
def spmm_sellcs(sc: SellCS, x: Array) -> Array:
    """Slice-structured SpMM: one gather + FMA per width-row, then a single
    permutation scatter back to original row order. Padding entries carry
    data == 0, cols == 0 — they contribute nothing."""
    x2, squeeze = _as_2d(x)
    m, _ = sc.shape
    k = x2.shape[1]
    dtype = jnp.promote_types(sc.data.dtype, x2.dtype)
    if sc.nnz == 0 or sc.data.shape[0] == 0:
        y = jnp.zeros((m, k), dtype)
        return y[:, 0] if squeeze else y
    y_slots = sellcs_slots_ref(sc.data, sc.cols, sc.slice_of, x2,
                               num_slices=sc.num_slices, chunk=sc.chunk)
    # undo the σ-sort permutation; padding slots scatter to row m (dropped)
    y = jnp.zeros((m + 1, k), dtype).at[sc.row_perm].add(y_slots)
    y = y[:m]
    return y[:, 0] if squeeze else y


def spmm_ref(mat, x: Array) -> Array:
    """Oracle dispatch over every supported storage format."""
    from repro.kernels.ref import bsr_spmm_ref
    from repro.kernels.tiling import TiledSparse
    if isinstance(mat, TiledSparse):
        x2, squeeze = _as_2d(x)
        y = bsr_spmm_ref(mat, x2)
        return y[:, 0] if squeeze else y
    if isinstance(mat, SellCS):
        return spmm_sellcs(mat, x)
    if isinstance(mat, COO):
        return spmm_coo(mat, x)
    if isinstance(mat, CSR):
        return spmm_csr(mat, x)
    if isinstance(mat, BlockedSparse):
        return spmm_blocked(mat, x)
    raise TypeError(f"no SpMM oracle for {type(mat).__name__}")
