"""Sparsity-aware X gather (per-shard column compaction) for the
distributed SpMM (repro.spmm.distributed), locked down against the
``SellCS.to_coo`` oracle on 8 host-platform devices: ISSUE 5 acceptance —
compacted-vs-replicated equivalence for k in {1, 8, 64}, meshes (8,1) and
(4,2), both schedules, num_chunks in {1, 4}, uniform + mawi-style skewed
matrices, under both the jnp reference body and the Pallas kernel body in
interpret mode; degenerate cases (nnz==0 shard, a shard touching all n
columns, n_touched < c).

Device-backed tests run in SUBPROCESSES (the device-count flag must be set
before jax initializes; the rest of the suite keeps seeing 1 device).
col_map invariants and knob validation are pure host code and run
in-process.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_compact_matches_replicated_and_to_coo_oracle():
    """ISSUE 5 acceptance: compacted and replicated partitions answer
    identically (the gather is a pure re-indexing) and both match the
    SellCS.to_coo round-trip oracle, across meshes (8,1)/(4,2), both
    schedules, num_chunks in {1, 4}, k in {1, 8, 64}, uniform + mawi."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.data import matrices
from repro.launch.mesh import make_spmm_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_coo,
                        spmm_merge_distributed, spmm_row_distributed)
for name, gen in [("uniform", matrices.uniform(500, 430, 4000, 0)),
                  ("mawi_like", matrices.mawi_like(400, 400, 3000, 0.4, 1))]:
    coo = to_coo(*gen)
    sc = coo_to_sellcs(coo, c=16, sigma=64)
    for pd, pm in [(8, 1), (4, 2)]:
        mesh = make_spmm_mesh((pd, pm))
        row_p = partition_sellcs_rows(sc, pd)
        row_c = partition_sellcs_rows(sc, pd, compact_x=True)
        mrg_p = partition_sellcs_nnz(sc, pd)
        mrg_c = partition_sellcs_nnz(sc, pd, compact_x=True)
        for k in (1, 8, 64):
            X = jnp.asarray(np.random.default_rng(k).standard_normal(
                (coo.shape[1], k)).astype(np.float32))
            # the oracle is the format's own exact round-trip
            yo = np.asarray(spmm_coo(sc.to_coo(), X))
            for tag, y in [
                ("row", spmm_row_distributed(row_c, X, mesh)),
                ("merge", spmm_merge_distributed(mrg_c, X, mesh)),
                ("merge/c4", spmm_merge_distributed(mrg_c, X, mesh,
                                                    num_chunks=4)),
            ]:
                np.testing.assert_allclose(
                    np.asarray(y), yo, rtol=1e-5, atol=1e-4,
                    err_msg=f"{name} {tag} {pd}x{pm} k={k} vs oracle")
            # compacted == replicated BITWISE per schedule: the gather
            # only re-indexes X rows, the fp summation order is identical
            np.testing.assert_array_equal(
                np.asarray(spmm_row_distributed(row_c, X, mesh)),
                np.asarray(spmm_row_distributed(row_p, X, mesh)),
                err_msg=f"{name} row {pd}x{pm} k={k}")
            np.testing.assert_array_equal(
                np.asarray(spmm_merge_distributed(mrg_c, X, mesh)),
                np.asarray(spmm_merge_distributed(mrg_p, X, mesh)),
                err_msg=f"{name} merge {pd}x{pm} k={k}")
            np.testing.assert_array_equal(
                np.asarray(spmm_merge_distributed(mrg_c, X, mesh,
                                                  num_chunks=4)),
                np.asarray(spmm_merge_distributed(mrg_p, X, mesh,
                                                  num_chunks=4)),
                err_msg=f"{name} merge/c4 {pd}x{pm} k={k}")
        # SpMV rides along as k = 1 squeezed
        x = jnp.asarray(np.random.default_rng(9).standard_normal(
            coo.shape[1]).astype(np.float32))
        y = spmm_row_distributed(row_c, x, mesh)
        assert y.ndim == 1
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(spmm_coo(coo, x)),
                                   rtol=1e-5, atol=1e-4)
    print(name, "compact oracle OK")
"""))


def test_compact_pallas_interpret_kernel_body():
    """The PR-1 k-tiled Pallas kernel consumes the gathered [n_touched, kc]
    slab unchanged (interpret mode off-TPU): both meshes, both schedules,
    chunked merge, mawi dense row."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.data import matrices
from repro.launch.mesh import make_spmm_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_coo,
                        spmm_merge_distributed, spmm_row_distributed)
coo = to_coo(*matrices.mawi_like(300, 280, 2400, 0.4, 3))
sc = coo_to_sellcs(coo, c=16, sigma=64)
for pd, pm in [(8, 1), (4, 2)]:
    mesh = make_spmm_mesh((pd, pm))
    row = partition_sellcs_rows(sc, pd, compact_x=True)
    mrg = partition_sellcs_nnz(sc, pd, compact_x=True)
    for k in (1, 8, 64):
        X = jnp.asarray(np.random.default_rng(k).standard_normal(
            (coo.shape[1], k)).astype(np.float32))
        yo = np.asarray(spmm_coo(sc.to_coo(), X))
        yr = np.asarray(spmm_row_distributed(
            row, X, mesh, impl="pallas_interpret", k_tile=4))
        ym = np.asarray(spmm_merge_distributed(
            mrg, X, mesh, impl="pallas_interpret", k_tile=4, num_chunks=4))
        np.testing.assert_allclose(yr, yo, rtol=1e-5, atol=1e-4,
                                   err_msg=f"row {pd}x{pm} k={k}")
        np.testing.assert_allclose(ym, yo, rtol=1e-5, atol=1e-4,
                                   err_msg=f"merge {pd}x{pm} k={k}")
    print(pd, pm, "compact interpret OK")
"""))


def test_compact_degenerate_cases_on_mesh():
    """ISSUE 5 acceptance degenerates: an all-zero matrix, shards left
    empty by the band split (nnz == 0 shard), a shard touching ALL n
    columns, and n_touched < c (fewer distinct columns than the slice
    height) — every one answers correctly under compaction."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.data import matrices
from repro.launch.mesh import make_spmm_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_coo,
                        spmm_merge_distributed, spmm_row_distributed)
mesh = make_spmm_mesh((8, 1))
z = np.zeros(0, np.int32)

# 1. nnz == 0 matrix: the early return keeps shape/dtype
empty = to_coo(z, z, np.zeros(0, np.float32), (6, 4))
se = coo_to_sellcs(empty, c=2, sigma=4)
X4 = jnp.ones((4, 3), jnp.float32)
assert np.abs(np.asarray(spmm_row_distributed(
    partition_sellcs_rows(se, 8, compact_x=True), X4, mesh))).max() == 0
assert np.abs(np.asarray(spmm_merge_distributed(
    partition_sellcs_nnz(se, 8, compact_x=True), X4, mesh))).max() == 0

# 2. more devices than slices: empty shards carry n_touched == 0 and an
# all-padding col_map row, and contribute exactly nothing
tiny = to_coo(np.array([0, 1, 2], np.int32), np.array([0, 1, 2], np.int32),
              np.ones(3, np.float32), (3, 3))
st = coo_to_sellcs(tiny, c=2, sigma=2)
row = partition_sellcs_rows(st, 8, compact_x=True)
assert int(np.asarray(row.n_touched).min()) == 0     # empty shards exist
I3 = jnp.eye(3, dtype=jnp.float32)
np.testing.assert_allclose(np.asarray(spmm_row_distributed(
    row, I3, mesh)), np.eye(3), atol=1e-6)
np.testing.assert_allclose(np.asarray(spmm_merge_distributed(
    partition_sellcs_nnz(st, 8, compact_x=True), I3, mesh)),
    np.eye(3), atol=1e-6)

# 3. a shard touching ALL n columns: mawi-style dense rows on a narrow
# matrix — col_map degenerates to the identity and the gather is a wash,
# but the answer must not move
coo = to_coo(*matrices.mawi_like(64, 8, 512, 0.5, 5))
sc = coo_to_sellcs(coo, c=8, sigma=16)
mrg = partition_sellcs_nnz(sc, 8, compact_x=True)
assert int(np.asarray(mrg.n_touched).max()) == 8     # touches all n
X = jnp.asarray(np.random.default_rng(0).standard_normal(
    (8, 8)).astype(np.float32))
np.testing.assert_allclose(
    np.asarray(spmm_merge_distributed(mrg, X, mesh)),
    np.asarray(spmm_coo(sc.to_coo(), X)), rtol=1e-5, atol=1e-4)
np.testing.assert_allclose(
    np.asarray(spmm_row_distributed(
        partition_sellcs_rows(sc, 8, compact_x=True), X, mesh)),
    np.asarray(spmm_coo(sc.to_coo(), X)), rtol=1e-5, atol=1e-4)

# 4. n_touched < c: 4 distinct columns under a c=16 slice height — the
# gathered slab is shorter than one slice is tall
coo = to_coo(*matrices.uniform(100, 4, 300, 11))
sc = coo_to_sellcs(coo, c=16, sigma=32)
row = partition_sellcs_rows(sc, 8, compact_x=True)
assert int(np.asarray(row.n_touched).max()) <= 4 < 16
X = jnp.asarray(np.random.default_rng(1).standard_normal(
    (4, 8)).astype(np.float32))
np.testing.assert_allclose(
    np.asarray(spmm_row_distributed(row, X, mesh)),
    np.asarray(spmm_coo(sc.to_coo(), X)), rtol=1e-5, atol=1e-4)
np.testing.assert_allclose(
    np.asarray(spmm_merge_distributed(
        partition_sellcs_nnz(sc, 8, num_chunks=4, compact_x=True), X,
        mesh, num_chunks=4)),
    np.asarray(spmm_coo(sc.to_coo(), X)), rtol=1e-5, atol=1e-4)
# the pallas_interpret body handles the short slab (row pad to LANE)
np.testing.assert_allclose(
    np.asarray(spmm_row_distributed(row, X, mesh,
                                    impl="pallas_interpret", k_tile=4)),
    np.asarray(spmm_coo(sc.to_coo(), X)), rtol=1e-5, atol=1e-4)
print("compact degenerates OK")
"""))


def test_compact_explicit_zero_width_rows_survive():
    """Explicit-zero width-rows (all-zero values, real column indices —
    the PR-4 regression surface) keep their columns in the touched set:
    compaction must treat them as real reads, and the chunked re-deal must
    keep answering through its own map."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.launch.mesh import make_spmm_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz, spmm_coo,
                        spmm_merge_distributed)
rows = np.array([0, 0, 0] + list(range(1, 16)), np.int32)
cols = np.array([0, 2, 3] + [r % 4 for r in range(1, 16)], np.int32)
vals = np.array([1.0, 0.0, 0.0] + [float(r) for r in range(1, 16)],
                np.float32)
coo = to_coo(rows, cols, vals, (16, 4))
mesh = make_spmm_mesh((8, 1))
sc = coo_to_sellcs(coo, c=4, sigma=16)
mrg = partition_sellcs_nnz(sc, 8, compact_x=True)
X = jnp.asarray(np.random.default_rng(0).standard_normal(
    (4, 8)).astype(np.float32))
yo = np.asarray(spmm_coo(sc.to_coo(), X))
for c in (1, 2, 3, 9):
    yc = np.asarray(spmm_merge_distributed(mrg, X, mesh, num_chunks=c))
    np.testing.assert_allclose(yc, yo, rtol=1e-5, atol=1e-5,
                               err_msg=f"chunks={c}")
print("explicit-zero compact OK")
"""))


# --------------------------------------------------------------------------
# Host-side: col_map invariants, chunk-plan maps, knob validation
# --------------------------------------------------------------------------
def _mawi_sellcs(c=8, sigma=32):
    from repro.core import to_coo
    from repro.data import matrices
    from repro.spmm import coo_to_sellcs
    coo = to_coo(*matrices.mawi_like(200, 180, 1500, 0.3, 2))
    return coo_to_sellcs(coo, c=c, sigma=sigma)


def test_col_map_relabel_roundtrip_and_n_touched():
    """Deterministic analog of the hypothesis round-trip (test_property):
    per shard, col_map is sorted-unique, un-relabeling through it
    reproduces the uncompacted partition's cols exactly over the real
    width-rows, and n_touched is the true distinct-column count."""
    from repro.spmm import partition_sellcs_nnz, partition_sellcs_rows
    sc = _mawi_sellcs()
    for part in (partition_sellcs_rows, partition_sellcs_nnz):
        for P in (1, 3, 8):
            plain = part(sc, P)
            comp = part(sc, P, compact_x=True)
            cm = np.asarray(comp.col_map)
            nt = np.asarray(comp.n_touched)
            counts = np.asarray(comp.row_counts)
            for p in range(P):
                ln = int(counts[p])
                t = cm[p, :int(nt[p])]
                assert np.all(np.diff(t) > 0)        # sorted, unique
                pc = np.asarray(plain.cols)[p, :ln]
                cc = np.asarray(comp.cols)[p, :ln]
                assert int(nt[p]) == np.unique(pc).size if ln else \
                    int(nt[p]) == 0
                if ln:
                    assert cc.max() < int(nt[p])     # compacted index space
                    np.testing.assert_array_equal(cm[p][cc], pc)
            # data/slice structure untouched by compaction
            np.testing.assert_array_equal(np.asarray(plain.data),
                                          np.asarray(comp.data))
            np.testing.assert_array_equal(np.asarray(plain.slice_of),
                                          np.asarray(comp.slice_of))


def test_chunk_plan_carries_its_own_col_map():
    """The span re-deal changes row ownership, so the baked chunk plan
    must carry its own touched map — un-relabeling each span's cols
    through it reproduces the uncompacted plan's spans exactly."""
    from repro.spmm import partition_sellcs_nnz
    sc = _mawi_sellcs()
    plain = partition_sellcs_nnz(sc, 8, num_chunks=3)
    comp = partition_sellcs_nnz(sc, 8, num_chunks=3, compact_x=True)
    assert plain.chunk_plan[2] is None
    cm = np.asarray(comp.chunk_plan[2])
    nt = np.asarray(comp.chunk_plan[3])
    assert cm.shape[0] == 8 and nt.shape == (8,)
    for sp_p, sp_c in zip(plain.chunk_plan[1], comp.chunk_plan[1]):
        assert (sp_p.slice_start, sp_p.num_slices) == \
            (sp_c.slice_start, sp_c.num_slices)
        np.testing.assert_array_equal(np.asarray(sp_p.data),
                                      np.asarray(sp_c.data))
        pc = np.asarray(sp_p.cols)
        cc = np.asarray(sp_c.cols)
        # real rows: un-relabel through the plan map; padding rows carry
        # data == 0 on both sides and need no column agreement
        real = np.any(np.asarray(sp_p.data) != 0, axis=-1)
        for p in range(8):
            if real[p].any():
                np.testing.assert_array_equal(cm[p][cc[p][real[p]]],
                                              pc[p][real[p]])


def test_compact_knob_validation():
    """compact_x= at multiply time only asserts the partition-time choice;
    a mismatch in either direction is a ValueError naming the fix."""
    import jax
    from repro.launch.mesh import make_mesh
    from repro.spmm import (partition_sellcs_nnz, partition_sellcs_rows,
                            spmm_merge_distributed, spmm_row_distributed)
    if len(jax.devices()) != 1:
        return                       # in-process guard only needs 1 device
    sc = _mawi_sellcs()
    mesh = make_mesh((1,), ("data",))
    X = np.ones((180, 2), np.float32)
    plain_r = partition_sellcs_rows(sc, 1)
    comp_r = partition_sellcs_rows(sc, 1, compact_x=True)
    with pytest.raises(ValueError, match="compact_x"):
        spmm_row_distributed(plain_r, X, mesh, compact_x=True)
    with pytest.raises(ValueError, match="compact_x"):
        spmm_row_distributed(comp_r, X, mesh, compact_x=False)
    with pytest.raises(ValueError, match="compact_x"):
        spmm_merge_distributed(partition_sellcs_nnz(sc, 1), X, mesh,
                               compact_x=True)
    # None (the default) follows the partition on both kinds
    y_plain = spmm_row_distributed(plain_r, X, mesh)
    y_comp = spmm_row_distributed(comp_r, X, mesh, compact_x=True)
    np.testing.assert_array_equal(np.asarray(y_plain), np.asarray(y_comp))


def test_compact_payload_conserved():
    """Both partitioners conserve the nonzero payload under compaction
    (the compacted stream is the same stream, re-indexed)."""
    from repro.spmm import partition_sellcs_nnz, partition_sellcs_rows
    sc = _mawi_sellcs()
    total = float(np.abs(np.asarray(sc.data)).sum())
    for part in (partition_sellcs_rows, partition_sellcs_nnz):
        for P in (1, 3, 8, 64):
            sh = part(sc, P, compact_x=True)
            got = float(np.abs(np.asarray(sh.data)).sum())
            assert abs(got - total) < 1e-3, (part.__name__, P)
            assert sh.col_map is not None and sh.n_touched is not None
            assert sh.col_map.shape[0] == P
