"""Pallas TPU kernel: blocked SpMV over TiledSparse (8x128 mini-tiles).

Grid = batches of TB mini-tiles. Scalar-prefetched (tile_rows, tile_cols)
drive dynamic VMEM addressing; x and y are VMEM-resident (the paper's
"x/y region fits in L2" precondition, Eq. 3.1, promoted to VMEM — the
selector only routes matrices here when 4*(m+n) fits the VMEM budget).

Per mini-tile the body does a dense (8,128)@(128,) matvec and accumulates
into y at a dynamic sublane offset — no scatter, no gather, MXU/VPU only.
The tile *visit order* (row / Morton / Hilbert, per paper algorithm) is
preserved from conversion; on hardware it controls VREG/VMEM locality, and
we report it via TiledSparse.window_switches() in the benchmarks.

The grid dimension is declared "arbitrary" (sequential) because every step
accumulates into the same y buffer — the same discipline the paper needs for
false-sharing avoidance, transplanted to megacore semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from .tiling import TILE_C, TILE_R, TiledSparse

DEFAULT_TILES_PER_STEP = 8


def _kernel(tile_rows_ref, tile_cols_ref,   # scalar prefetch (SMEM)
            tiles_ref, x_ref,               # VMEM in
            y_ref,                          # VMEM out (revisited every step)
            *, tiles_per_step: int):
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    def body(t, _):
        idx = g * tiles_per_step + t
        r = tile_rows_ref[idx]
        c = tile_cols_ref[idx]
        tile = tiles_ref[t]                                    # (8, 128)
        xs = x_ref[pl.ds(c * TILE_C, TILE_C)]                  # (128,)
        upd = jax.lax.dot_general(
            tile, xs.astype(tile.dtype), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                # (8,)
        cur = y_ref[pl.ds(r * TILE_R, TILE_R)]
        y_ref[pl.ds(r * TILE_R, TILE_R)] = cur + upd
        return _

    jax.lax.fori_loop(0, tiles_per_step, body, None)


@functools.partial(jax.jit, static_argnames=("tiles_per_step", "interpret"))
def bsr_spmv(ts: TiledSparse, x: jax.Array, *,
             tiles_per_step: int = DEFAULT_TILES_PER_STEP,
             interpret: bool = False) -> jax.Array:
    """y = A @ x for A in TiledSparse form. Returns f32[m]."""
    m, n = ts.shape
    mp, np_ = ts.padded_shape()
    T = ts.num_tiles
    TB = tiles_per_step
    T_pad = -(-T // TB) * TB

    tiles = ts.tiles
    tile_rows = ts.tile_rows
    tile_cols = ts.tile_cols
    if T_pad != T:
        pad = T_pad - T
        tiles = jnp.concatenate(
            [tiles, jnp.zeros((pad,) + tiles.shape[1:], tiles.dtype)])
        # padding tiles are all-zero; point them at row/col 0 harmlessly
        tile_rows = jnp.concatenate(
            [tile_rows, jnp.zeros((pad,), tile_rows.dtype)])
        tile_cols = jnp.concatenate(
            [tile_cols, jnp.zeros((pad,), tile_cols.dtype)])

    x_pad = jnp.zeros((np_,), x.dtype).at[:n].set(x)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T_pad // TB,),
        in_specs=[
            pl.BlockSpec((TB, TILE_R, TILE_C), lambda g, *_: (g, 0, 0)),
            pl.BlockSpec((np_,), lambda g, *_: (0,)),
        ],
        out_specs=pl.BlockSpec((mp,), lambda g, *_: (0,)),
    )
    params = tpu_compiler_params(dimension_semantics=("arbitrary",))

    y = pl.pallas_call(
        functools.partial(_kernel, tiles_per_step=TB),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.float32),
        compiler_params=params,
        interpret=interpret,
    )(tile_rows, tile_cols, tiles, x_pad)
    return y[:m]


def _kernel_spmm(tile_rows_ref, tile_cols_ref, tiles_ref, x_ref, y_ref, *,
                 tiles_per_step: int):
    """Multi-RHS variant: x [n_pad, R], y [m_pad, R]."""
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    def body(t, _):
        idx = g * tiles_per_step + t
        r = tile_rows_ref[idx]
        c = tile_cols_ref[idx]
        tile = tiles_ref[t]                                    # (8, 128)
        xs = x_ref[pl.ds(c * TILE_C, TILE_C), :]               # (128, R)
        upd = jax.lax.dot_general(
            tile, xs.astype(tile.dtype), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                # (8, R)
        cur = y_ref[pl.ds(r * TILE_R, TILE_R), :]
        y_ref[pl.ds(r * TILE_R, TILE_R), :] = cur + upd
        return _

    jax.lax.fori_loop(0, tiles_per_step, body, None)


@functools.partial(jax.jit, static_argnames=("tiles_per_step", "interpret"))
def bsr_spmm(ts: TiledSparse, x: jax.Array, *,
             tiles_per_step: int = DEFAULT_TILES_PER_STEP,
             interpret: bool = False) -> jax.Array:
    """Y = A @ X for X [n, R] (multi-RHS: iterative solver blocks, GNN
    feature matrices). Same tile stream as bsr_spmv; the MXU matvec becomes
    a (8,128)@(128,R) matmul — arithmetic intensity grows R-fold, which is
    exactly why SpMM is the preferred form on TPU (DESIGN §2)."""
    m, n = ts.shape
    mp, np_ = ts.padded_shape()
    R = x.shape[1]
    T = ts.num_tiles
    TB = tiles_per_step
    T_pad = -(-T // TB) * TB

    tiles, tile_rows, tile_cols = ts.tiles, ts.tile_rows, ts.tile_cols
    if T_pad != T:
        pad = T_pad - T
        tiles = jnp.concatenate(
            [tiles, jnp.zeros((pad,) + tiles.shape[1:], tiles.dtype)])
        tile_rows = jnp.concatenate(
            [tile_rows, jnp.zeros((pad,), tile_rows.dtype)])
        tile_cols = jnp.concatenate(
            [tile_cols, jnp.zeros((pad,), tile_cols.dtype)])
    x_pad = jnp.zeros((np_, R), x.dtype).at[:n].set(x)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T_pad // TB,),
        in_specs=[
            pl.BlockSpec((TB, TILE_R, TILE_C), lambda g, *_: (g, 0, 0)),
            pl.BlockSpec((np_, R), lambda g, *_: (0, 0)),
        ],
        out_specs=pl.BlockSpec((mp, R), lambda g, *_: (0, 0)),
    )
    params = tpu_compiler_params(dimension_semantics=("arbitrary",))
    y = pl.pallas_call(
        functools.partial(_kernel_spmm, tiles_per_step=TB),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mp, R), jnp.float32),
        compiler_params=params,
        interpret=interpret,
    )(tile_rows, tile_cols, tiles, x_pad)
    return y[:m]
