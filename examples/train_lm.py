"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
on the synthetic bigram-structured pipeline and watch the loss fall well
below the unigram entropy (proof of learning, not just running).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(~100M params; on this 1-core CPU container use --small for a quick pass.)
"""
import argparse

import jax.numpy as jnp

from repro.launch import train as train_cli
from repro.models.model import ModelConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--small", action="store_true",
                help="8M params / fewer steps (CI-friendly)")
args = ap.parse_args()

if args.small:
    # ~8M params
    cfg_args = ["--arch", "llama3.2-1b", "--reduced",
                "--steps", str(min(args.steps, 60)),
                "--batch", "8", "--seq", "64", "--lr", "3e-3"]
else:
    # ~100M params: register an ad-hoc config through the llama file's
    # REDUCED slot is not enough — drive train.py with a custom config
    import repro.configs.llama3_2_1b as mod
    cfg100 = ModelConfig(
        name="llama-100m", n_layers=8, d_model=512, n_heads=8, kv_heads=4,
        d_ff=2048, vocab=32768, head_dim=64, tie_embeddings=True,
        block_pattern=("attn",), mlp_pattern=("dense",),
        compute_dtype=jnp.float32, loss_chunk=64)
    mod.REDUCED = cfg100          # temporarily alias for the CLI
    cfg_args = ["--arch", "llama3.2-1b", "--reduced",
                "--steps", str(args.steps), "--batch", "8", "--seq", "128",
                "--lr", "1e-3", "--log-every", "10"]

final_loss = train_cli.main(cfg_args + ["--ckpt-dir", "/tmp/train_lm_ckpt",
                                        "--save-every", "50"])
print(f"[example] final loss: {final_loss:.3f}")
