"""Storage-format tour: how each paper format encodes the SAME 8x8 matrix,
printed for inspection (the didactic companion to quickstart.py).

Run:  PYTHONPATH=src python examples/spmv_tour.py
"""
import numpy as np

from repro.core import (convert, coo_to_bicrs, coo_to_csr, coo_to_icrs,
                        curve_key, to_coo)

# the 8x8 example matrix
rows = [0, 0, 1, 2, 3, 3, 4, 5, 6, 7, 7]
cols = [1, 7, 2, 0, 3, 4, 6, 5, 2, 0, 7]
vals = [float(v) for v in range(1, 12)]
coo = to_coo(rows, cols, np.asarray(vals, np.float32), (8, 8))
print("dense:\n", np.asarray(coo.todense()).astype(int))

csr = coo_to_csr(coo)
print("\nCSR  row_ptr:", np.asarray(csr.row_ptr).tolist())
print("CSR  col_ind:", np.asarray(csr.col_ind).tolist())

icrs = coo_to_icrs(coo)
print("\nICRS col_start:", int(icrs.col_start),
      "col_inc:", np.asarray(icrs.col_inc).tolist())
print("ICRS row_jump:", np.asarray(icrs.row_jump).tolist(),
      " (overflow past n=8 signals a row change)")

bic = coo_to_bicrs(coo, order="hilbert")
print("\nBICRS (Hilbert order) col_inc:",
      np.asarray(bic.col_inc).tolist())
print("BICRS row_jump:", np.asarray(bic.row_jump).tolist(),
      " (negative jumps = bidirectional)")

hk = curve_key(np.asarray(rows), np.asarray(cols), "hilbert", 3)
order = np.argsort(np.asarray(hk))
print("\nHilbert visit order of the nonzeros:",
      [(rows[i], cols[i]) for i in order])

bs = convert(coo, "csb", beta=4)
print(f"\nCSB: grid {bs.grid}, beta={bs.beta}, "
      f"{bs.num_blocks} non-empty blocks")
print("  block coords:", list(zip(np.asarray(bs.block_rows).tolist(),
                                  np.asarray(bs.block_cols).tolist())))
lr, lc = bs.local_rows_cols()
print("  packed in-block (row,col):",
      list(zip(np.asarray(lr).tolist(), np.asarray(lc).tolist())))
print("  storage bytes:", bs.storage_bytes(), "vs CSR:",
      csr.storage_bytes())
print("\nspmv_tour OK")
