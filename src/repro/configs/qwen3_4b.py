"""Qwen3-4B [hf:Qwen/Qwen3-*]: GQA(kv=8), qk-norm, decoupled head_dim=128."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", n_layers=36, d_model=2560, n_heads=32, kv_heads=8,
    d_ff=9728, vocab=151936, head_dim=128, rope_theta=1e6, qk_norm=True,
    block_pattern=("attn",), mlp_pattern=("dense",))

REDUCED = ModelConfig(
    name="qwen3-4b-reduced", n_layers=2, d_model=64, n_heads=4, kv_heads=2,
    d_ff=160, vocab=256, head_dim=16, qk_norm=True,
    block_pattern=("attn",), mlp_pattern=("dense",),
    compute_dtype=jnp.float32, loss_chunk=16)
