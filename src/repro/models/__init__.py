"""repro.models — composable decoder LM zoo (attention/SSM/MoE/hybrid)."""
from .model import (ModelConfig, decode_step, forward, init_cache,
                    init_params, loss_fn, logits_from_hidden, prefill)
from .accounting import (attn_extra_flops, count_params, decode_model_flops,
                         train_model_flops)

__all__ = ["ModelConfig", "decode_step", "forward", "init_cache",
           "init_params", "loss_fn", "logits_from_hidden", "prefill",
           "count_params", "train_model_flops", "attn_extra_flops",
           "decode_model_flops"]
