"""Op-aware SpMM (ISSUE 9): transpose (``A^T X``) and symmetric
one-triangle storage, locked down against the ``to_coo`` dense oracle on
8 host-platform devices — k in {1, 8, 64} × both schedules × compact on
and off × meshes (8,1)/(4,2) × num_chunks {1, 4}; the ``SparseOperator``
``rmatmul``/``.T`` surface sharing one plan for both ops; symmetric
storage at ≤ 55% of the general stream; the differentiable
``sparse_matmul`` backward; a GMRES convergence run through the operator
(forward and adjoint solves on one plan); and the degenerate corners
(nnz == 0 shard, asymmetric-input raises, explicit-zero width-rows).

Device-backed tests run in SUBPROCESSES (the device-count flag must be
set before jax initializes; the rest of the suite keeps seeing 1 device).
Storage accounting, validation, and single-device autodiff run
in-process.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def _sym_coo_np(m, nnz_half, seed):
    r = np.random.default_rng(seed)
    rows = r.integers(0, m, nnz_half)
    cols = r.integers(0, m, nnz_half)
    vals = r.standard_normal(nnz_half).astype(np.float32)
    return (np.concatenate([rows, cols]).astype(np.int32),
            np.concatenate([cols, rows]).astype(np.int32),
            np.concatenate([vals, vals]), (m, m))


def test_transpose_matches_to_coo_oracle_distributed():
    """ISSUE 9 acceptance: op='T' through both schedules × chunks × 2-D
    mesh × compact_x equals the ``to_coo`` dense oracle for k in
    {1, 8, 64}; the Pallas kernel body (interpret mode) rides one cell."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.data import matrices
from repro.launch.mesh import make_spmm_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_coo_t,
                        spmm_merge_distributed, spmm_row_distributed)
coo = to_coo(*matrices.mawi_like(500, 430, 4000, 0.4, 0))
sc = coo_to_sellcs(coo, c=16, sigma=64)
m = coo.shape[0]
for k in (1, 8, 64):
    X = jnp.asarray(np.random.default_rng(k).standard_normal(
        (m, k)).astype(np.float32))
    yo = np.asarray(spmm_coo_t(sc.to_coo(), X))
    for pd, pm in [(8, 1), (4, 2)]:
        mesh = make_spmm_mesh((pd, pm))
        for compact in (False, True):
            row = partition_sellcs_rows(sc, pd, compact_x=compact)
            np.testing.assert_allclose(
                np.asarray(spmm_row_distributed(row, X, mesh, op="T")),
                yo, rtol=1e-5, atol=1e-4,
                err_msg=f"row {pd}x{pm} k={k} compact={compact}")
            for nc in (1, 4):
                mrg = partition_sellcs_nnz(sc, pd, num_chunks=nc,
                                           compact_x=compact)
                np.testing.assert_allclose(
                    np.asarray(spmm_merge_distributed(
                        mrg, X, mesh, op="T", num_chunks=nc)),
                    yo, rtol=1e-5, atol=1e-4,
                    err_msg=f"merge {pd}x{pm} k={k} nc={nc} "
                            f"compact={compact}")
    # kernel body in interpret mode, one cell per k
    row = partition_sellcs_rows(sc, 8, compact_x=True)
    np.testing.assert_allclose(
        np.asarray(spmm_row_distributed(
            row, X, make_spmm_mesh((8, 1)), op="T",
            impl="pallas_interpret", k_tile=4)),
        yo, rtol=1e-5, atol=1e-4, err_msg=f"row interpret k={k}")
# SpMV transpose rides along as k = 1 squeezed
x = jnp.asarray(np.random.default_rng(9).standard_normal(m)
                .astype(np.float32))
mesh = make_spmm_mesh((8, 1))
y = spmm_row_distributed(partition_sellcs_rows(sc, 8), x, mesh, op="T")
assert y.ndim == 1
np.testing.assert_allclose(np.asarray(y),
                           np.asarray(spmm_coo_t(sc.to_coo(), x)),
                           rtol=1e-5, atol=1e-4)
print("transpose oracle OK")
"""))


def test_symmetric_one_triangle_distributed_and_roundtrip():
    """Symmetric one-triangle storage answers identically under op='N'
    and op='T' (A == A^T) through both schedules, chunks, the 2-D mesh,
    and compaction, against the full-matrix ``to_coo`` oracle."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core.formats import COO
from repro.launch.mesh import make_spmm_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_coo,
                        spmm_merge_distributed, spmm_row_distributed)
r = np.random.default_rng(5)
m, nh = 300, 2500
rows = r.integers(0, m, nh); cols = r.integers(0, m, nh)
vals = r.standard_normal(nh).astype(np.float32)
coo = COO(jnp.asarray(np.concatenate([rows, cols]).astype(np.int32)),
          jnp.asarray(np.concatenate([cols, rows]).astype(np.int32)),
          jnp.asarray(np.concatenate([vals, vals])), (m, m))
sym = coo_to_sellcs(coo, c=16, sigma=64, structure="symmetric")
full = sym.to_coo()
assert full.nnz > sym.row_len.sum()        # the mirror really unfolds
for k in (1, 8):
    X = jnp.asarray(r.standard_normal((m, k)).astype(np.float32))
    yo = np.asarray(spmm_coo(full, X))
    for pd, pm in [(8, 1), (4, 2)]:
        mesh = make_spmm_mesh((pd, pm))
        for compact in (False, True):
            row = partition_sellcs_rows(sym, pd, compact_x=compact)
            for op in ("N", "T"):
                np.testing.assert_allclose(
                    np.asarray(spmm_row_distributed(row, X, mesh, op=op)),
                    yo, rtol=1e-5, atol=1e-4,
                    err_msg=f"sym row {pd}x{pm} k={k} op={op} "
                            f"compact={compact}")
            for nc in (1, 4):
                mrg = partition_sellcs_nnz(sym, pd, num_chunks=nc,
                                           compact_x=compact)
                for op in ("N", "T"):
                    np.testing.assert_allclose(
                        np.asarray(spmm_merge_distributed(
                            mrg, X, mesh, op=op, num_chunks=nc)),
                        yo, rtol=1e-5, atol=1e-4,
                        err_msg=f"sym merge {pd}x{pm} k={k} nc={nc} "
                                f"op={op} compact={compact}")
print("symmetric distributed OK")
"""))


def test_operator_rmatmul_and_T_share_one_plan():
    """ISSUE 9 acceptance: SparseOperator.rmatmul equals the ``to_coo``
    dense oracle on the mesh under both schedules × compaction × 2-D mesh
    × chunks; ``op.T`` is a zero-copy view (``.T.T is op``), and the
    transpose multiply never rebuilds (stats prove one plan)."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import PlanSpec, to_coo
from repro.core.formats import COO
from repro.data import matrices
from repro.spmm import (SparseOperator, TransposedOperator, spmm_coo,
                        spmm_coo_t)
coo = to_coo(*matrices.uniform(300, 250, 2500, 5))
X = jnp.asarray(np.random.default_rng(0).standard_normal(
    (300, 8)).astype(np.float32))
ref_t = np.asarray(spmm_coo_t(coo, X))
for sched in ("row", "merge"):
    for compact in (False, True):
        op = SparseOperator(coo, PlanSpec(num_devices=8,
                                          algorithm="sellcs",
                                          schedule=sched,
                                          compact_x=compact),
                            impl="ref", k_hint=8)
        builds = op.stats.sellcs_builds
        np.testing.assert_allclose(np.asarray(op.rmatmul(X)), ref_t,
                                   rtol=1e-5, atol=1e-4,
                                   err_msg=f"{sched} compact={compact}")
        tv = op.T
        assert isinstance(tv, TransposedOperator)
        assert tv.T is op and tv.shape == (250, 300)
        np.testing.assert_allclose(np.asarray(tv @ X), ref_t,
                                   rtol=1e-5, atol=1e-4)
        assert op.stats.sellcs_builds == builds  # no rebuild for T
# 2-D mesh + chunked merge through the operator
op = SparseOperator(coo, PlanSpec(num_devices=8, mesh_shape=(4, 2),
                                  algorithm="sellcs", schedule="merge",
                                  num_chunks=2), impl="ref", k_hint=8)
np.testing.assert_allclose(np.asarray(op.rmatmul(X)), ref_t,
                           rtol=1e-5, atol=1e-4, err_msg="4x2 chunked")
# symmetric structure end-to-end: matmul == rmatmul == dense oracle
r = np.random.default_rng(6)
m, nh = 256, 2000
rows = r.integers(0, m, nh); cols = r.integers(0, m, nh)
vals = r.standard_normal(nh).astype(np.float32)
scoo = COO(jnp.asarray(np.concatenate([rows, cols]).astype(np.int32)),
           jnp.asarray(np.concatenate([cols, rows]).astype(np.int32)),
           jnp.asarray(np.concatenate([vals, vals])), (m, m))
ops = SparseOperator(scoo, PlanSpec(num_devices=8, algorithm="sellcs",
                                    structure="symmetric"),
                     impl="ref", k_hint=8)
assert ops.plan.spec.structure == "symmetric"
Xs = jnp.asarray(r.standard_normal((m, 8)).astype(np.float32))
ys = np.asarray(spmm_coo(scoo, Xs))
np.testing.assert_allclose(np.asarray(ops.matmul(Xs)), ys,
                           rtol=1e-5, atol=1e-4)
np.testing.assert_allclose(np.asarray(ops.rmatmul(Xs)), ys,
                           rtol=1e-5, atol=1e-4)
print("operator rmatmul OK")
"""))


def test_transpose_degenerate_cases():
    """Degenerates: an nnz == 0 shard answers zeros at the right shape
    under op='T'; explicit-zero width-rows (all-zero values, real column
    indices) stay harmless through the transpose scatter and the chunked
    re-deal."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.launch.mesh import make_spmm_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_coo_t,
                        spmm_merge_distributed, spmm_row_distributed)
mesh = make_spmm_mesh((8, 1))
z = np.zeros(0, np.int32)
empty = to_coo(z, z, np.zeros(0, np.float32), (64, 48))
se = coo_to_sellcs(empty, c=16, sigma=16)
X = jnp.ones((64, 4), jnp.float32)
y = spmm_row_distributed(partition_sellcs_rows(se, 8), X, mesh, op="T")
assert y.shape == (48, 4) and float(np.abs(np.asarray(y)).max()) == 0
y = spmm_merge_distributed(partition_sellcs_nnz(se, 8), X, mesh, op="T")
assert y.shape == (48, 4) and float(np.abs(np.asarray(y)).max()) == 0

# explicit-zero width-rows: zero values with real column indices must
# contribute nothing to the scattered columns, under every chunking
rows = np.array([0, 0, 0] + list(range(1, 16)), np.int32)
cols = np.array([0, 2, 3] + [r % 4 for r in range(1, 16)], np.int32)
vals = np.array([1.0, 0.0, 0.0] + [float(r) for r in range(1, 16)],
                np.float32)
coo = to_coo(rows, cols, vals, (16, 4))
sc = coo_to_sellcs(coo, c=4, sigma=16)
X = jnp.asarray(np.random.default_rng(0).standard_normal(
    (16, 8)).astype(np.float32))
yo = np.asarray(spmm_coo_t(sc.to_coo(), X))
for compact in (False, True):
    mrg = partition_sellcs_nnz(sc, 8, compact_x=compact)
    for nc in (1, 2, 3, 9):
        yc = np.asarray(spmm_merge_distributed(mrg, X, mesh, op="T",
                                               num_chunks=nc))
        np.testing.assert_allclose(yc, yo, rtol=1e-5, atol=1e-5,
                                   err_msg=f"nc={nc} compact={compact}")
    np.testing.assert_allclose(
        np.asarray(spmm_row_distributed(
            partition_sellcs_rows(sc, 8, compact_x=compact), X, mesh,
            op="T")),
        yo, rtol=1e-5, atol=1e-5, err_msg=f"row compact={compact}")
print("transpose degenerates OK")
"""))


def test_gmres_converges_through_operator():
    """Satellite (a): restarted GMRES over ``(I + 0.05 A)`` driven by a
    SparseOperator converges below 1e-5 relative residual, and so does
    the adjoint system through ``op.T`` — both on the one realized plan
    (8-device mesh)."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import PlanSpec, to_coo
from repro.data import matrices
from repro.spmm import SparseOperator
rows, cols, vals, shape = matrices.rmat(scale=9, edge_factor=8, seed=0)
n = shape[0]
deg = np.bincount(cols, minlength=n).astype(np.float32)
coo = to_coo(rows, cols, 1.0 / np.maximum(deg[cols], 1.0), shape)
A = SparseOperator.from_coo(
    coo, PlanSpec(num_devices=8, algorithm="sellcs"), impl="ref",
    k_hint=1, num_spmvs=500)

def gmres(op, b, m=20, restarts=10, tol=1e-8):
    x = jnp.zeros_like(b)
    for outer in range(restarts):
        r = b - op(x)
        beta = float(jnp.linalg.norm(r))
        if beta < tol:
            break
        V = [r / beta]
        H = np.zeros((m + 1, m))
        mm = m
        for j in range(mm):
            w = op(V[j])
            for i in range(j + 1):
                H[i, j] = float(jnp.vdot(V[i], w))
                w = w - H[i, j] * V[i]
            H[j + 1, j] = float(jnp.linalg.norm(w))
            if H[j + 1, j] < 1e-12:
                mm = j + 1
                break
            V.append(w / H[j + 1, j])
        e1 = np.zeros(mm + 1); e1[0] = beta
        y, *_ = np.linalg.lstsq(H[: mm + 1, :mm], e1, rcond=None)
        x = x + jnp.stack(V[:mm], axis=1) @ jnp.asarray(y, jnp.float32)
        if float(jnp.linalg.norm(b - op(x))) < tol:
            break
    return x

b = jnp.asarray(np.random.default_rng(1).standard_normal(n)
                .astype(np.float32))
for tag, op in [("forward", A), ("adjoint", A.T)]:
    f = lambda v: v + 0.05 * (op @ v)
    x = gmres(f, b)
    res = float(jnp.linalg.norm(b - f(x)) / jnp.linalg.norm(b))
    assert res < 1e-5, (tag, res)
    print(tag, "residual", res)
assert A.stats.sellcs_builds <= 1          # one plan serves both solves
print("gmres operator OK")
"""))


# --------------------------------------------------------------------------
# Host-side (1 device): storage accounting, validation, autodiff surface
# --------------------------------------------------------------------------
def test_symmetric_storage_at_most_55_percent():
    """ISSUE 9 acceptance: one-triangle storage reports <= ~55% of the
    general-format ``storage_bytes()`` on a (dense-ish) symmetric test
    matrix, and the SellCS ``to_coo`` round-trip is exact."""
    import jax.numpy as jnp
    from repro.core.formats import COO
    from repro.spmm import coo_to_sellcs, spmm_ref
    ar, ac, av, shape = _sym_coo_np(512, 40000, seed=0)
    coo = COO(jnp.asarray(ar), jnp.asarray(ac), jnp.asarray(av), shape)
    gen = coo_to_sellcs(coo, c=32, structure="general")
    sym = coo_to_sellcs(coo, c=32, structure="symmetric")
    ratio = sym.storage_bytes() / gen.storage_bytes()
    assert ratio <= 0.55, f"one-triangle ratio {ratio:.3f} > 0.55"
    # the mirror round-trips: both formats multiply identically
    X = jnp.asarray(np.random.default_rng(1).standard_normal(
        (512, 4)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(spmm_ref(sym, X)),
                               np.asarray(spmm_ref(gen, X)),
                               rtol=1e-5, atol=1e-4)


def test_symmetric_requires_symmetric_input():
    """Asymmetric input + structure='symmetric' raises at conversion and
    at the operator surface; rectangular input raises on shape alone."""
    import jax.numpy as jnp
    from repro.core import PlanSpec
    from repro.core.formats import COO
    from repro.spmm import SparseOperator, coo_to_sellcs
    r = np.random.default_rng(2)
    rows = jnp.asarray(r.integers(0, 50, 300).astype(np.int32))
    cols = jnp.asarray(r.integers(0, 50, 300).astype(np.int32))
    vals = jnp.asarray(r.standard_normal(300).astype(np.float32))
    asym = COO(rows, cols, vals, (50, 50))
    with pytest.raises(ValueError):
        coo_to_sellcs(asym, structure="symmetric")
    with pytest.raises(ValueError):
        SparseOperator(asym, PlanSpec(num_devices=1, algorithm="sellcs",
                                      structure="symmetric"), impl="ref")
    rect = COO(rows, cols, vals, (50, 60))
    with pytest.raises(ValueError):
        coo_to_sellcs(rect, structure="symmetric")
    # symmetric structure is a sellcs capability only
    ar, ac, av, shape = _sym_coo_np(50, 200, seed=3)
    sym = COO(jnp.asarray(ar), jnp.asarray(ac), jnp.asarray(av), shape)
    with pytest.raises(ValueError):
        SparseOperator(sym, PlanSpec(num_devices=1, algorithm="parcrs",
                                     structure="symmetric"), impl="ref")


def test_selector_picks_symmetric_structure():
    """matrix_stats detects A == A^T; select_distributed only offers the
    one-triangle axis for sellcs on symmetric inputs, and a PlanSpec pin
    is respected."""
    import jax.numpy as jnp
    from repro.core.formats import COO
    from repro.core.selector import (PlanSpec, matrix_stats,
                                     select_distributed)
    ar, ac, av, shape = _sym_coo_np(200, 900, seed=4)
    sym = COO(jnp.asarray(ar), jnp.asarray(ac), jnp.asarray(av), shape)
    r = np.random.default_rng(5)
    gen = COO(jnp.asarray(r.integers(0, 200, 1500).astype(np.int32)),
              jnp.asarray(r.integers(0, 200, 1500).astype(np.int32)),
              jnp.asarray(r.standard_normal(1500).astype(np.float32)),
              (200, 200))
    assert matrix_stats(sym).symmetric is True
    assert matrix_stats(gen).symmetric is False
    ch = select_distributed(matrix_stats(gen), k=32, num_devices=8)
    assert ch.structure == "general"
    ch = select_distributed(
        matrix_stats(sym), k=32, num_devices=8,
        spec=PlanSpec(num_devices=8, algorithm="sellcs",
                      structure="symmetric"))
    assert ch.structure == "symmetric"
    with pytest.raises(ValueError):
        PlanSpec(structure="banded").canonical()


def test_sparse_matmul_backward_through_operator():
    """The differentiable surface: jax.grad through ``sparse_matmul``
    equals the dense-matrix gradient (forward = matmul, cotangent =
    rmatmul over the one plan)."""
    import jax
    import jax.numpy as jnp
    from repro.core import PlanSpec
    from repro.core.formats import COO
    from repro.spmm import SparseOperator, sparse_matmul
    r = np.random.default_rng(7)
    m, n, nnz = 60, 40, 500
    rows = r.integers(0, m, nnz).astype(np.int32)
    cols = r.integers(0, n, nnz).astype(np.int32)
    vals = r.standard_normal(nnz).astype(np.float32)
    coo = COO(jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
              (m, n))
    dense = np.zeros((m, n), np.float32)
    np.add.at(dense, (rows, cols), vals)
    op = SparseOperator(coo, PlanSpec(num_devices=1, algorithm="sellcs"),
                        impl="ref", k_hint=4)
    X = jnp.asarray(r.standard_normal((n, 4)).astype(np.float32))
    T = jnp.asarray(r.standard_normal((m, 4)).astype(np.float32))

    def loss(x):
        return jnp.sum((sparse_matmul(op, x) - T) ** 2)

    def loss_dense(x):
        return jnp.sum((jnp.asarray(dense) @ x - T) ** 2)

    np.testing.assert_allclose(np.asarray(jax.grad(loss)(X)),
                               np.asarray(jax.grad(loss_dense)(X)),
                               rtol=1e-4, atol=1e-3)
    # pre-transpose plans cannot rmatmul: the error names the fix
    from repro.spmm.operator import RealizedPlan
    rp = op.plan._replace(multiply_t=None)
    op2 = SparseOperator(coo, rp, impl="ref")
    with pytest.raises(ValueError, match="re-realize"):
        op2.rmatmul(T)


def test_spmm_dispatcher_op_validation():
    """The one-call surface: bad op rejected; op='T' on a kernel-less
    format raises under impl='pallas'; the reference path covers COO."""
    import jax.numpy as jnp
    from repro.core.formats import COO
    from repro.spmm import coo_to_sellcs, spmm, spmm_coo_t
    r = np.random.default_rng(8)
    coo = COO(jnp.asarray(r.integers(0, 30, 200).astype(np.int32)),
              jnp.asarray(r.integers(0, 20, 200).astype(np.int32)),
              jnp.asarray(r.standard_normal(200).astype(np.float32)),
              (30, 20))
    X = jnp.asarray(r.standard_normal((30, 4)).astype(np.float32))
    with pytest.raises(ValueError, match="op"):
        spmm(coo, X, op="X")
    with pytest.raises(TypeError, match="transpose"):
        spmm(coo, X, impl="pallas_interpret", op="T")
    yo = np.asarray(spmm_coo_t(coo, X))
    np.testing.assert_allclose(np.asarray(spmm(coo, X, op="T")), yo,
                               rtol=1e-5, atol=1e-4)
    sc = coo_to_sellcs(coo, c=8, sigma=16)
    np.testing.assert_allclose(
        np.asarray(spmm(sc, X, impl="pallas_interpret", op="T")),
        np.asarray(spmm_coo_t(sc.to_coo(), X)), rtol=1e-5, atol=1e-4)
