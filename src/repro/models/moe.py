"""Mixture-of-Experts FFN with dropless, sort-based dispatch.

This is where the paper's contribution enters the LM stack (DESIGN §4): the
token->expert assignment is an unstructured sparse matrix whose row lengths
(tokens per expert) are as skewed as a power-law graph's degrees. Dispatch =
sort tokens by expert (the conversion phase) + grouped GEMM over equal-cost
tiles (the balanced multiply phase). Two compute paths:

  * XLA:     jax.lax.ragged_dot (differentiable, shardable under GSPMD)
  * Pallas:  repro.kernels.ops.moe_group_matmul (serving path / TPU)
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from .layers import dense_init

Array = jax.Array


class MoEConfig(NamedTuple):
    d_model: int
    d_ff: int                 # per-expert hidden
    n_experts: int
    top_k: int
    use_kernel: bool = False  # Pallas grouped GEMM instead of ragged_dot
    router_aux_weight: float = 0.01


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "router": dense_init(ks[0], d, E, dtype=dtype),
        "w_gate": jax.random.normal(ks[1], (E, d, f), dtype) * s_in,
        "w_up": jax.random.normal(ks[2], (E, d, f), dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (E, f, d), dtype) * s_out,
    }


def _grouped_matmul(xs: Array, w: Array, group_sizes: Array,
                    use_kernel: bool) -> Array:
    if use_kernel:
        from repro.kernels import ops as kops
        interpret = jax.default_backend() != "tpu"
        return kops.moe_group_matmul(xs, w, group_sizes,
                                     interpret=interpret)
    return jax.lax.ragged_dot(xs, w, group_sizes.astype(jnp.int32))


def moe_apply(p, cfg: MoEConfig, x: Array) -> Tuple[Array, Array]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    T = B * S
    k = cfg.top_k
    E = cfg.n_experts
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # [T, E]
    top_p, top_e = jax.lax.top_k(probs, k)                        # [T, k]
    top_w = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- conversion phase: sort (token, slot) pairs by expert ----
    slot_expert = top_e.reshape(-1)                               # [T*k]
    slot_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    order = jnp.argsort(slot_expert)                              # stable
    xs = xf[slot_token[order]]                                    # [T*k, d]
    group_sizes = jnp.zeros((E,), jnp.int32).at[slot_expert].add(1)

    # ---- balanced multiply phase: grouped GEMMs (SwiGLU expert FFN) ----
    g = _grouped_matmul(xs, p["w_gate"], group_sizes, cfg.use_kernel)
    u = _grouped_matmul(xs, p["w_up"], group_sizes, cfg.use_kernel)
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
         ).astype(xs.dtype)
    out_slots = _grouped_matmul(h, p["w_down"], group_sizes, cfg.use_kernel)

    # ---- carry-out fixup: weighted scatter back to tokens ----
    w_sorted = top_w.reshape(-1)[order].astype(jnp.float32)
    tok_sorted = slot_token[order]
    y = jnp.zeros((T, d), jnp.float32).at[tok_sorted].add(
        out_slots.astype(jnp.float32) * w_sorted[:, None])

    # switch-style load-balance loss (the paper's imbalance metric as a
    # differentiable penalty)
    frac_tokens = group_sizes.astype(jnp.float32) / jnp.maximum(T * k, 1)
    mean_prob = probs.mean(axis=0)
    aux = cfg.router_aux_weight * E * jnp.sum(frac_tokens * mean_prob)
    return y.reshape(B, S, d).astype(x.dtype), aux


def expert_load_stats(p, cfg: MoEConfig, x: Array) -> dict:
    """Routing imbalance diagnostics (max/mean tokens per expert etc.) — the
    MoE analogue of the paper's nnz-per-row variance (Table 5.1)."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32)
    _, top_e = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
    counts = jnp.zeros((cfg.n_experts,), jnp.int32
                       ).at[top_e.reshape(-1)].add(1)
    mean = counts.mean()
    return {"counts": counts,
            "max_over_mean": counts.max() / jnp.maximum(mean, 1),
            "variance": jnp.var(counts.astype(jnp.float32))}


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (beyond-paper optimization, EXPERIMENTS §Perf)
# ---------------------------------------------------------------------------
def moe_apply_ep(p, cfg: MoEConfig, x: Array, *, ep_axis: str = "model",
                 batch_axes: Tuple[str, ...] = ("data",),
                 capacity_factor: float = 1.3) -> Tuple[Array, Array]:
    """shard_map EP dispatch: experts live sharded over ``ep_axis``;
    activations are already replicated across it, so each EP rank selects
    the (token, slot) pairs routed to ITS experts (a fixed local capacity =
    the merge-path 'uniform quantum' trick: every rank does the same-shape
    work), runs the grouped GEMMs locally, and one psum over ``ep_axis``
    plays the paper's carry-out combine. Replaces the global argsort+gather
    that GSPMD lowers to catastrophic all-to-alls (baseline cells in
    EXPERIMENTS §Roofline)."""
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k

    def local(xl, router_w, w_gate, w_up, w_down):
        # xl [B_loc, S, d]; w_* [E_loc, ...]; router replicated
        ep_rank = jax.lax.axis_index(ep_axis)
        n_ep = jax.lax.axis_size(ep_axis)
        e_loc = w_gate.shape[0]
        Bl = xl.shape[0]
        T = Bl * S
        xf = xl.reshape(T, d)
        logits = xf.astype(jnp.float32) @ router_w.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_w = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        slot_e = top_e.reshape(-1)                       # [T*k]
        slot_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
        slot_w = top_w.reshape(-1).astype(jnp.float32)
        mine = (slot_e >= ep_rank * e_loc) & (slot_e < (ep_rank + 1) * e_loc)
        local_e = jnp.where(mine, slot_e - ep_rank * e_loc, e_loc)
        # fixed local capacity: same-shape work on every rank
        cap = int(capacity_factor * T * k / max(E // e_loc, 1))
        cap = min(max(-(-cap // 128) * 128, 128), T * k)
        order = jnp.argsort(jnp.where(mine, local_e, e_loc + 1))[:cap]
        sel_e = local_e[order]
        sel_valid = sel_e < e_loc
        xs = xf[slot_t[order]] * sel_valid[:, None].astype(xf.dtype)
        group_sizes = jnp.zeros((e_loc,), jnp.int32).at[sel_e].add(
            sel_valid.astype(jnp.int32))
        g = jax.lax.ragged_dot(xs, w_gate, group_sizes)
        u = jax.lax.ragged_dot(xs, w_up, group_sizes)
        h = (jax.nn.silu(g.astype(jnp.float32))
             * u.astype(jnp.float32)).astype(xs.dtype)
        out = jax.lax.ragged_dot(h, w_down, group_sizes)
        w_sel = slot_w[order] * sel_valid.astype(jnp.float32)
        y = jnp.zeros((T, d), jnp.float32).at[slot_t[order]].add(
            out.astype(jnp.float32) * w_sel[:, None])
        y = jax.lax.psum(y, ep_axis)                     # combine
        # aux loss: routing stats are identical across EP ranks but LOCAL to
        # each dp shard — pmean over the batch axes gives the exact global
        # token-averages (equal shard sizes)
        frac = jnp.zeros((E,), jnp.float32).at[slot_e].add(1.0) \
            / jnp.maximum(T * k, 1)
        mean_prob = probs.mean(0)
        if batch_axes:
            frac = jax.lax.pmean(frac, batch_axes)
            mean_prob = jax.lax.pmean(mean_prob, batch_axes)
        aux = cfg.router_aux_weight * E * jnp.sum(frac * mean_prob)
        # drop accounting: slots routed to me beyond cap are dropped
        dropped = jnp.maximum(mine.sum() - sel_valid.sum(), 0)
        dropped = jax.lax.psum(dropped, ep_axis)
        return y.reshape(Bl, S, d).astype(xl.dtype), aux, dropped

    bspec = P(batch_axes, None, None) if batch_axes else P(None, None, None)
    y, aux, dropped = shard_map(
        local,
        in_specs=(bspec, P(None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None), P(ep_axis, None, None)),
        out_specs=(bspec, P(), P()),
        check_vma=False,
    )(x, p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux


def moe_apply_ep_tp(p, cfg: MoEConfig, x: Array, *, ep_axis: str = "model",
                    batch_axes: Tuple[str, ...] = ("data",)
                    ) -> Tuple[Array, Array]:
    """Expert-TP dispatch for archs whose expert count does NOT divide the
    model axis (mixtral: 8e on a 16-wide axis): every rank holds a 1/n_ep
    slice of EVERY expert's d_ff, the dispatch (sort + ragged_dot) runs
    fully locally and losslessly, and the partial w_down outputs psum over
    the axis. Same single-collective structure as moe_apply_ep, zero drops,
    at the cost of every rank sorting all local slots."""
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k

    def local(xl, router_w, w_gate, w_up, w_down):
        Bl = xl.shape[0]
        T = Bl * S
        xf = xl.reshape(T, d)
        logits = xf.astype(jnp.float32) @ router_w.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_w = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        slot_e = top_e.reshape(-1)
        slot_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
        order = jnp.argsort(slot_e)
        xs = xf[slot_t[order]]
        group_sizes = jnp.zeros((E,), jnp.int32).at[slot_e].add(1)
        g = jax.lax.ragged_dot(xs, w_gate, group_sizes)   # [T*k, f_loc]
        u = jax.lax.ragged_dot(xs, w_up, group_sizes)
        h = (jax.nn.silu(g.astype(jnp.float32))
             * u.astype(jnp.float32)).astype(xs.dtype)
        out = jax.lax.ragged_dot(h, w_down, group_sizes)  # partial over f
        w_sel = top_w.reshape(-1)[order].astype(jnp.float32)
        y = jnp.zeros((T, d), jnp.float32).at[slot_t[order]].add(
            out.astype(jnp.float32) * w_sel[:, None])
        y = jax.lax.psum(y, ep_axis)
        frac = group_sizes.astype(jnp.float32) / jnp.maximum(T * k, 1)
        mean_prob = probs.mean(0)
        if batch_axes:
            frac = jax.lax.pmean(frac, batch_axes)
            mean_prob = jax.lax.pmean(mean_prob, batch_axes)
        aux = cfg.router_aux_weight * E * jnp.sum(frac * mean_prob)
        return y.reshape(Bl, S, d).astype(xl.dtype), aux

    bspec = P(batch_axes, None, None) if batch_axes else P(None, None, None)
    y, aux = shard_map(
        local,
        in_specs=(bspec, P(None, None), P(None, None, ep_axis),
                  P(None, None, ep_axis), P(None, ep_axis, None)),
        out_specs=(bspec, P()),
        check_vma=False,
    )(x, p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux
