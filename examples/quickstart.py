"""Quickstart: the paper's pipeline end-to-end on one unstructured matrix.

  1. generate an unstructured (power-law) sparse matrix;
  2. inspect its stats and let the paper's §7 selector pick an algorithm;
  3. convert (the paper's conversion phase) and multiply (9 algorithms);
  4. validate everything against the dense oracle;
  5. show the TPU tiled format + Pallas kernel (interpret mode on CPU).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (ALGORITHM_SPECS, MachineSpec, convert, matrix_stats,
                        select_algorithm, spmv, spmv_dense_oracle, to_coo)
from repro.data import matrices
from repro.kernels import coo_to_tiled, ops

# 1. an unstructured matrix (LiveJournal-like power-law rows)
rows, cols, vals, shape = matrices.powerlaw(4096, 4096, 65536, seed=0)
coo = to_coo(rows, cols, vals, shape)
x = jnp.asarray(np.random.default_rng(1).standard_normal(shape[1])
                .astype(np.float32))
y_ref = spmv_dense_oracle(coo, x)

# 2. stats + algorithm selection (the paper's decision procedure)
stats = matrix_stats(coo)
print(f"matrix: {shape}, nnz={stats.nnz}, density={stats.density:.2e}, "
      f"max_row={stats.max_row_nnz}, var={stats.row_var:.1f}")
pick_numa = select_algorithm(stats, MachineSpec(num_devices=256),
                             num_spmvs=1000)
pick_uma = select_algorithm(stats, MachineSpec(num_devices=1),
                            num_spmvs=1000)
print(f"selector: mesh(256 devices) -> {pick_numa!r}; "
      f"single device -> {pick_uma!r}")

# 3+4. convert + multiply with every algorithm, validate
for algo, spec in ALGORITHM_SPECS.items():
    kw = dict(beta=256) if spec.blocked else {}
    if spec.scheduling == "static_rows":
        kw["num_bands"] = 8
    mat = convert(coo, algo, **kw)
    y = spmv(mat, x, impl="ref")
    err = float(jnp.max(jnp.abs(y - y_ref)))
    extra = f" storage={mat.storage_bytes() / 1e6:.2f}MB" \
        if hasattr(mat, "storage_bytes") else ""
    print(f"  {algo:8s} ok (max err {err:.2e}){extra}  [{spec.note}]")

# 5. the TPU compute format + Pallas kernel (interpret mode on CPU)
ts = coo_to_tiled(coo, "csbh", beta=256)
xsw, ysw = ts.window_switches()
print(f"tiled: {ts.num_tiles} 8x128 tiles, fill={ts.fill_ratio:.3f}, "
      f"window switches x={xsw} y={ysw}")
y_k = ops.bsr_spmv(ts, x, interpret=True)
print(f"pallas bsr_spmv max err: {float(jnp.max(jnp.abs(y_k - y_ref))):.2e}")
print("quickstart OK")
