"""Request batching: many single-vector SpMV requests -> one SpMM call.

The serve-path story of this subsystem: each user request is one ``A @ x``
— memory-bound, wasting the matrix stream on a single vector. Aggregating
queued requests into a ``[n, k]`` block before multiplying reuses every
streamed nonzero k times (arithmetic intensity grows k-fold; see
``repro.roofline.spmm_arithmetic_intensity``) at zero cost to correctness:
column j of the SpMM *is* request j's SpMV.

``RequestBatcher`` is the queueing front-end ``launch.serve`` drives; k is
padded to the next power of two (capped at ``max_batch``) so a server sees
O(log max_batch) distinct compiled shapes instead of one per queue depth.

Serve metrics (``repro.obs``): when a registry is installed, every flush
records its phases — ``batcher/flush`` (whole flush, blocking on Y so the
latency is real), ``batcher/pad`` (queue pop + dtype promotion + the
power-of-two pad), ``batcher/multiply`` (the SpMM itself), and
``batcher/scatter`` (result columns back to tickets) — plus a
``batcher/queue_wait_s`` histogram (submit-to-flush seconds per request),
``batcher/flushes`` / ``batcher/served`` counters and a
``batcher/pending`` depth gauge. The flush percentiles
``launch.serve --metrics`` prints are the ``batcher/flush`` series. With
no registry installed none of this runs: the spans are shared no-op
singletons and the submit path takes one ``enabled()`` branch — the hot
path stays allocation-free (asserted in ``tests/test_obs.py``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import obs
from repro.obs import maybe_block, span

Array = jax.Array

# Pluggable SpMM: (matrix, X[n, k]) -> Y[m, k]. The distributed serve path
# passes a closure over (sharded matrix, mesh) here so the batcher drives a
# whole mesh exactly the way it drives one device.
SpmmFn = Callable[[object, Array], Array]


@dataclasses.dataclass(frozen=True)
class SpmvRequest:
    """One queued ``A @ x`` request."""
    rid: int
    x: Array


def _next_pow2(k: int) -> int:
    p = 1
    while p < k:
        p <<= 1
    return p


def batch_spmv(matrix, requests: Sequence, *, impl: str = "auto",
               k_tile: Optional[int] = None,
               spmm_fn: Optional[SpmmFn] = None) -> List[Array]:
    """Answer a batch of single-vector requests with ONE SpMM.

    ``requests`` holds ``SpmvRequest``s or bare ``[n]`` vectors. Returns
    the per-request results in input order. ``spmm_fn`` overrides the
    multiply (e.g. a ``spmm_row_distributed`` closure over a mesh).
    """
    from . import spmm
    if not requests:
        return []
    xs = [r.x if isinstance(r, SpmvRequest) else r for r in requests]
    n = matrix.shape[1]
    for x in xs:
        if x.shape != (n,):
            raise ValueError(
                f"request vector shape {x.shape} != matrix n ({n},)")
    # promote across the whole batch: one low-precision request must not
    # downcast its neighbours' columns
    dtype = jnp.result_type(*xs)
    X = jnp.stack([x.astype(dtype) for x in xs], axis=1)   # [n, k]
    if spmm_fn is not None:
        Y = spmm_fn(matrix, X)                      # [m, k]
    else:
        Y = spmm(matrix, X, impl=impl, k_tile=k_tile)
    return [Y[:, j] for j in range(len(xs))]


class RequestBatcher:
    """Aggregates queued SpMV requests and answers them with one SpMM.

    >>> b = RequestBatcher(matrix, max_batch=64)
    >>> rid = b.submit(x)            # enqueue, returns a ticket
    >>> results = b.flush()          # one SpMM; {rid: y}
    """

    def __init__(self, matrix, *, max_batch: int = 128, impl: str = "auto",
                 pad_pow2: bool = True, spmm_fn: Optional[SpmmFn] = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.matrix = matrix
        self.max_batch = max_batch
        self.impl = impl
        self.pad_pow2 = pad_pow2
        self.spmm_fn = spmm_fn
        self._queue: List[SpmvRequest] = []
        self._next_rid = 0
        # serving telemetry
        self.flushes = 0
        self.served = 0
        # submit timestamps for the queue-wait histogram; only written
        # while an obs registry is installed
        self._submit_t: Dict[int, float] = {}

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def pending(self) -> int:
        return len(self._queue)

    def submit(self, x: Array) -> int:
        """Enqueue one request; returns its ticket id. Shape-checked here so
        a bad request can never poison an already-popped flush batch."""
        x = jnp.asarray(x)
        n = self.matrix.shape[1]
        if x.shape != (n,):
            raise ValueError(
                f"request vector shape {x.shape} != matrix n ({n},)")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(SpmvRequest(rid, x))
        if obs.enabled():
            self._submit_t[rid] = time.perf_counter()
            reg = obs.current_registry()
            reg.counter("batcher/submitted").inc()
            reg.gauge("batcher/pending").set(len(self._queue))
        return rid

    def flush(self) -> Dict[int, Array]:
        """Serve up to ``max_batch`` queued requests with one SpMM call and
        scatter the result columns back to their tickets.

        With an obs registry installed the flush is phase-traced (pad /
        multiply / scatter) and blocks on its outputs so the recorded
        ``batcher/flush`` latency is execution time, not dispatch time —
        the one behavioral difference metrics mode buys its numbers with.
        """
        if not self._queue:
            return {}
        with span("batcher/flush"):
            batch, self._queue = (self._queue[:self.max_batch],
                                  self._queue[self.max_batch:])
            k = len(batch)
            n = self.matrix.shape[1]
            kp = min(_next_pow2(k), self.max_batch) if self.pad_pow2 else k
            with span("batcher/pad"):
                # the batch dtype is the promotion over every queued
                # request, not whatever the first one happened to be — a
                # mixed-dtype queue must not silently downcast later
                # columns
                dtype = jnp.result_type(*(r.x for r in batch))
                X = jnp.zeros((n, kp), dtype)
                X = maybe_block(X.at[:, :k].set(
                    jnp.stack([r.x.astype(dtype) for r in batch], axis=1)))
            with span("batcher/multiply"):
                if self.spmm_fn is not None:
                    Y = self.spmm_fn(self.matrix, X)
                else:
                    from . import spmm
                    Y = spmm(self.matrix, X, impl=self.impl)
                Y = maybe_block(Y)
            with span("batcher/scatter"):
                out = {r.rid: Y[:, j] for j, r in enumerate(batch)}
            self.flushes += 1
            self.served += k
            if obs.enabled():
                reg = obs.current_registry()
                now = time.perf_counter()
                waits = reg.histogram("batcher/queue_wait_s")
                for r in batch:
                    t0 = self._submit_t.pop(r.rid, None)
                    if t0 is not None:
                        waits.observe(now - t0)
                reg.counter("batcher/flushes").inc()
                reg.counter("batcher/served").inc(k)
                reg.gauge("batcher/batch_k").set(k)
                reg.gauge("batcher/pending").set(len(self._queue))
            return out

    def drain(self) -> Dict[int, Array]:
        """Flush until the queue is empty."""
        out: Dict[int, Array] = {}
        while self._queue:
            out.update(self.flush())
        return out
