"""InternVL2-2B [arXiv:2404.16821]: InternLM2-1.8B backbone + InternViT stub.

Frontend stub per assignment: input_specs supplies precomputed ViT patch
embeddings [B, 256, 1024]; a linear projector maps them into the token
stream ahead of the text."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", n_layers=24, d_model=2048, n_heads=16, kv_heads=8,
    d_ff=8192, vocab=92553, head_dim=128, rope_theta=1e6,
    frontend="vision", vision_tokens=256, vision_dim=1024,
    block_pattern=("attn",), mlp_pattern=("dense",))

REDUCED = ModelConfig(
    name="internvl2-2b-reduced", n_layers=2, d_model=64, n_heads=4,
    kv_heads=2, d_ff=160, vocab=256, head_dim=16,
    frontend="vision", vision_tokens=8, vision_dim=32,
    block_pattern=("attn",), mlp_pattern=("dense",),
    compute_dtype=jnp.float32, loss_chunk=16)
