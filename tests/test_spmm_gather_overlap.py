"""Hiding the compact-X gather under the chunked slice stream (ISSUE 10):
``gather="overlap"`` rebuilds the gathered slab per span inside the mesh
body so XLA can run span i+1's gather under span i's kernel/psum, and
``gather="fused"`` folds the indirection into the Pallas kernel's scalar
prefetch. Both must be BITWISE identical to the up-front gather — they
move the same bytes at a different time, in the same fp summation order —
across schedules x chunks {1,2,4} x meshes (8,1)/(4,2) x op N/T x
uniform/mawi, under the jnp reference body and the Pallas kernel body in
interpret mode, plus the degenerates (nnz==0 shard, a shard touching all
n columns, n_touched < LANE).

Also locked down here: the exposed-gather roofline term's ordering
(fused <= overlap <= upfront, zero off the compact path), the selector's
gather axis (PlanSpec pin, validation), the baked per-span touched-column
split's invariants (LANE-padded col_map, the row-0 padding pair), and the
``_symmetric_combine`` mixed-dtype regression (a wider stored diagonal
must not promote the output dtype).

Device-backed tests run in SUBPROCESSES (the device-count flag must be
set before jax initializes; the rest of the suite keeps seeing 1 device).
Model/selector/plan invariants are pure host code and run in-process.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_gather_modes_bitwise_equal_and_oracle():
    """ISSUE 10 acceptance: overlapped and fused gathers answer BITWISE
    identically to the up-front gather (and all three match the
    ``SellCS.to_coo`` oracle) across meshes (8,1)/(4,2), both schedules,
    num_chunks in {1, 2, 4}, op N/T, uniform + mawi."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.data import matrices
from repro.launch.mesh import make_spmm_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_coo,
                        spmm_merge_distributed, spmm_row_distributed)
for name, gen in [("uniform", matrices.uniform(500, 430, 4000, 0)),
                  ("mawi_like", matrices.mawi_like(400, 400, 3000, 0.4, 1))]:
    coo = to_coo(*gen)
    sc = coo_to_sellcs(coo, c=16, sigma=64)
    for pd, pm in [(8, 1), (4, 2)]:
        mesh = make_spmm_mesh((pd, pm))
        row = partition_sellcs_rows(sc, pd, compact_x=True)
        mrgs = {c: partition_sellcs_nnz(sc, pd, num_chunks=c,
                                        compact_x=True)
                for c in (1, 2, 4)}
        for k in (1, 8):
            X = jnp.asarray(np.random.default_rng(k).standard_normal(
                (coo.shape[1], k)).astype(np.float32))
            yo = np.asarray(spmm_coo(sc.to_coo(), X))
            y_up = np.asarray(spmm_row_distributed(row, X, mesh,
                                                   gather="upfront"))
            np.testing.assert_allclose(y_up, yo, rtol=1e-5, atol=1e-4,
                                       err_msg=f"{name} row {pd}x{pm}")
            for g in ("overlap", "fused"):
                np.testing.assert_array_equal(
                    np.asarray(spmm_row_distributed(row, X, mesh,
                                                    gather=g)),
                    y_up, err_msg=f"{name} row {pd}x{pm} k={k} gx={g}")
            for c, mrg in mrgs.items():
                y_up = np.asarray(spmm_merge_distributed(
                    mrg, X, mesh, num_chunks=c, gather="upfront"))
                np.testing.assert_allclose(
                    y_up, yo, rtol=1e-5, atol=1e-4,
                    err_msg=f"{name} merge/c{c} {pd}x{pm}")
                for g in ("overlap", "fused"):
                    np.testing.assert_array_equal(
                        np.asarray(spmm_merge_distributed(
                            mrg, X, mesh, num_chunks=c, gather=g)),
                        y_up,
                        err_msg=f"{name} merge/c{c} {pd}x{pm} k={k} "
                                f"gx={g}")
            # op=T has no compact-X gather (X is read dense in slot
            # space) — gather= is accepted and ignored, bitwise
            XT = jnp.asarray(np.random.default_rng(k + 7).standard_normal(
                (coo.shape[0], k)).astype(np.float32))
            yt = np.asarray(spmm_merge_distributed(mrgs[2], XT, mesh,
                                                   num_chunks=2, op="T"))
            for g in ("overlap", "fused"):
                np.testing.assert_array_equal(
                    np.asarray(spmm_merge_distributed(
                        mrgs[2], XT, mesh, num_chunks=2, op="T",
                        gather=g)),
                    yt, err_msg=f"{name} op=T {pd}x{pm} k={k} gx={g}")
    print(name, "gather modes OK")
"""))


def test_gather_modes_pallas_interpret():
    """The fused mode's real body: the Pallas kernel takes the LANE-padded
    global col_map as a second scalar-prefetch operand and does the
    two-level take itself (interpret mode off-TPU). Fused and overlapped
    results must stay bitwise equal to up-front under the kernel body,
    and all match the oracle."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.data import matrices
from repro.launch.mesh import make_spmm_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_coo,
                        spmm_merge_distributed, spmm_row_distributed)
coo = to_coo(*matrices.mawi_like(300, 280, 2400, 0.4, 3))
sc = coo_to_sellcs(coo, c=16, sigma=64)
for pd, pm in [(8, 1), (4, 2)]:
    mesh = make_spmm_mesh((pd, pm))
    row = partition_sellcs_rows(sc, pd, compact_x=True)
    mrg = partition_sellcs_nnz(sc, pd, num_chunks=4, compact_x=True)
    for k in (1, 8):
        X = jnp.asarray(np.random.default_rng(k).standard_normal(
            (coo.shape[1], k)).astype(np.float32))
        yo = np.asarray(spmm_coo(sc.to_coo(), X))
        y_up = np.asarray(spmm_row_distributed(
            row, X, mesh, impl="pallas_interpret", k_tile=4,
            gather="upfront"))
        np.testing.assert_allclose(y_up, yo, rtol=1e-5, atol=1e-4)
        np.testing.assert_array_equal(
            np.asarray(spmm_row_distributed(
                row, X, mesh, impl="pallas_interpret", k_tile=4,
                gather="fused")),
            y_up, err_msg=f"row fused {pd}x{pm} k={k}")
        m_up = np.asarray(spmm_merge_distributed(
            mrg, X, mesh, impl="pallas_interpret", k_tile=4,
            num_chunks=4, gather="upfront"))
        np.testing.assert_allclose(m_up, yo, rtol=1e-5, atol=1e-4)
        for g in ("overlap", "fused"):
            np.testing.assert_array_equal(
                np.asarray(spmm_merge_distributed(
                    mrg, X, mesh, impl="pallas_interpret", k_tile=4,
                    num_chunks=4, gather=g)),
                m_up, err_msg=f"merge {g} {pd}x{pm} k={k}")
    print(pd, pm, "gather interpret OK")
"""))


def test_gather_degenerate_cases_on_mesh():
    """Degenerates under every gather mode: an nnz==0 matrix (empty
    shards), a shard touching ALL n columns (col_map == identity), and
    n_touched < LANE (the slab pad dominates the map)."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.data import matrices
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_coo,
                        spmm_merge_distributed, spmm_row_distributed)
from repro.launch.mesh import make_spmm_mesh
mesh = make_spmm_mesh((8, 1))
z = np.zeros(0, np.int32)

# 1. nnz == 0: every shard is empty, every gather mode answers zero
empty = to_coo(z, z, np.zeros(0, np.float32), (6, 4))
se = coo_to_sellcs(empty, c=2, sigma=4)
X4 = jnp.ones((4, 3), jnp.float32)
for g in ("upfront", "overlap", "fused"):
    assert np.abs(np.asarray(spmm_row_distributed(
        partition_sellcs_rows(se, 8, compact_x=True), X4, mesh,
        gather=g))).max() == 0, g
    assert np.abs(np.asarray(spmm_merge_distributed(
        partition_sellcs_nnz(se, 8, num_chunks=2, compact_x=True), X4,
        mesh, num_chunks=2, gather=g))).max() == 0, g

# 2. a shard touching ALL n columns: identity map, answer must not move
coo = to_coo(*matrices.mawi_like(64, 8, 512, 0.5, 5))
sc = coo_to_sellcs(coo, c=8, sigma=16)
mrg = partition_sellcs_nnz(sc, 8, num_chunks=4, compact_x=True)
assert int(np.asarray(mrg.chunk_plan[3]).max()) == 8
X = jnp.asarray(np.random.default_rng(0).standard_normal(
    (8, 8)).astype(np.float32))
yo = np.asarray(spmm_coo(sc.to_coo(), X))
y_up = np.asarray(spmm_merge_distributed(mrg, X, mesh, num_chunks=4,
                                         gather="upfront"))
np.testing.assert_allclose(y_up, yo, rtol=1e-5, atol=1e-4)
for g in ("overlap", "fused"):
    np.testing.assert_array_equal(
        np.asarray(spmm_merge_distributed(mrg, X, mesh, num_chunks=4,
                                          gather=g)), y_up, g)

# 3. n_touched < LANE everywhere (4 distinct columns): the slab is pure
# pad beyond row 4 and every mode must read only the real rows
coo = to_coo(*matrices.uniform(100, 4, 300, 11))
sc = coo_to_sellcs(coo, c=16, sigma=32)
row = partition_sellcs_rows(sc, 8, compact_x=True)
assert int(np.asarray(row.n_touched).max()) <= 4
mrg = partition_sellcs_nnz(sc, 8, num_chunks=4, compact_x=True)
X = jnp.asarray(np.random.default_rng(1).standard_normal(
    (4, 8)).astype(np.float32))
yo = np.asarray(spmm_coo(sc.to_coo(), X))
for g in ("upfront", "overlap", "fused"):
    np.testing.assert_allclose(
        np.asarray(spmm_row_distributed(row, X, mesh, gather=g)),
        yo, rtol=1e-5, atol=1e-4, err_msg=g)
    np.testing.assert_allclose(
        np.asarray(spmm_merge_distributed(mrg, X, mesh, num_chunks=4,
                                          gather=g)),
        yo, rtol=1e-5, atol=1e-4, err_msg=g)
    np.testing.assert_allclose(
        np.asarray(spmm_row_distributed(row, X, mesh,
                                        impl="pallas_interpret",
                                        k_tile=4, gather=g)),
        yo, rtol=1e-5, atol=1e-4, err_msg=g)
print("gather degenerates OK")
"""))


# --------------------------------------------------------------------------
# Host-side: knob validation, baked span maps, model term, selector axis
# --------------------------------------------------------------------------
def _mawi_sellcs(c=8, sigma=32):
    from repro.core import to_coo
    from repro.data import matrices
    from repro.spmm import coo_to_sellcs
    coo = to_coo(*matrices.mawi_like(200, 180, 1500, 0.3, 2))
    return coo_to_sellcs(coo, c=c, sigma=sigma)


def test_gather_knob_validation():
    """overlap/fused need a compact partition (a replicated-X stream has
    no X gather to hide); an unknown mode is a ValueError naming the
    choices."""
    import jax
    from repro.launch.mesh import make_mesh
    from repro.spmm import (partition_sellcs_nnz, partition_sellcs_rows,
                            spmm_merge_distributed, spmm_row_distributed)
    if len(jax.devices()) != 1:
        return                       # in-process guard only needs 1 device
    sc = _mawi_sellcs()
    mesh = make_mesh((1,), ("data",))
    X = np.ones((180, 2), np.float32)
    plain = partition_sellcs_rows(sc, 1)
    comp = partition_sellcs_rows(sc, 1, compact_x=True)
    for g in ("overlap", "fused"):
        with pytest.raises(ValueError, match="compact"):
            spmm_row_distributed(plain, X, mesh, gather=g)
        with pytest.raises(ValueError, match="compact"):
            spmm_merge_distributed(partition_sellcs_nnz(sc, 1), X, mesh,
                                   gather=g)
    with pytest.raises(ValueError, match="gather"):
        spmm_row_distributed(comp, X, mesh, gather="bogus")
    # on one device every mode is the same single gather — bitwise
    y_up = np.asarray(spmm_row_distributed(comp, X, mesh))
    for g in ("overlap", "fused"):
        np.testing.assert_array_equal(
            np.asarray(spmm_row_distributed(comp, X, mesh, gather=g)),
            y_up)


def test_span_maps_lane_padded_and_row0_invariant():
    """The baked per-span touched split: every span of a compact chunked
    plan carries (sub, col_map, n_touched); the plan-level col_map is
    LANE-padded (the hot path is a single ``x_pad[col_map]``, no
    per-multiply concatenate) with all-zero padding beyond the touched
    prefix; span padding entries carry the consistent pair
    (sub == 0, col_map == plan col_map[:, 0]) so duplicate scatter writes
    agree."""
    from repro.spmm import partition_sellcs_nnz
    from repro.spmm.kernels import LANE
    sc = _mawi_sellcs()
    sh = partition_sellcs_nnz(sc, 8, num_chunks=3, compact_x=True)
    nc, spans, plan_cm, plan_nt = sh.chunk_plan
    assert nc == 3 and plan_cm is not None and plan_nt is not None
    cm = np.asarray(plan_cm)
    nt = np.asarray(plan_nt)
    assert cm.shape[1] % LANE == 0          # baked pad, not a hot-path one
    for p in range(cm.shape[0]):
        assert not cm[p, int(nt[p]):].any()  # padding is all row 0
    assert len(spans) == 3
    for sp in spans:
        assert sp.sub is not None and sp.col_map is not None \
            and sp.n_touched is not None
        sub = np.asarray(sp.sub)
        scm = np.asarray(sp.col_map)
        snt = np.asarray(sp.n_touched)
        for p in range(cm.shape[0]):
            t = int(snt[p])
            # real entries: plan-space positions resolving to the same
            # global columns the span recorded
            np.testing.assert_array_equal(cm[p][sub[p, :t]], scm[p, :t])
            # padding entries: the consistent (0, plan col_map[p, 0]) pair
            assert not sub[p, t:].any()
            assert (scm[p, t:] == cm[p, 0]).all()


def test_exposed_gather_roofline_term():
    """fused <= overlap <= upfront always; overlap strictly wins only
    where there are spans to hide behind (merge, num_chunks > 1); the
    term is zero off the compact path and for op=T."""
    from repro.roofline import spmm_distributed_gather_s
    kw = dict(nnz=40_000, max_row_nnz=64, model_devices=1,
              compact_x=True, n_touched=900.0)
    up = spmm_distributed_gather_s(5000, 4000, 32, 8, "merge",
                                   num_chunks=4, gather="upfront", **kw)
    ov = spmm_distributed_gather_s(5000, 4000, 32, 8, "merge",
                                   num_chunks=4, gather="overlap", **kw)
    fu = spmm_distributed_gather_s(5000, 4000, 32, 8, "merge",
                                   num_chunks=4, gather="fused", **kw)
    assert fu == 0.0 and fu <= ov <= up and ov < up
    # no spans to hide behind: overlap degenerates to up-front
    for sched, nc in (("row", 1), ("merge", 1)):
        u = spmm_distributed_gather_s(5000, 4000, 32, 8, sched,
                                      num_chunks=nc, gather="upfront",
                                      **kw)
        o = spmm_distributed_gather_s(5000, 4000, 32, 8, sched,
                                      num_chunks=nc, gather="overlap",
                                      **kw)
        assert u == o > 0.0
    # nothing to gather: replicated X, or the transpose's dense read
    assert spmm_distributed_gather_s(5000, 4000, 32, 8, "merge",
                                     num_chunks=4, nnz=40_000) == 0.0
    assert spmm_distributed_gather_s(5000, 4000, 32, 8, "merge",
                                     num_chunks=4, gather="overlap",
                                     op="T", **kw) == 0.0
    with pytest.raises(ValueError, match="gather"):
        spmm_distributed_gather_s(5000, 4000, 32, 8, "merge",
                                  gather="bogus", **kw)


def test_selector_gather_axis_and_spec_pin():
    """select_distributed scores the gather axis on compact sellcs
    candidates, respects a PlanSpec.gather pin, and rejects a pin without
    compact_x (a replicated-X plan has no gather to schedule)."""
    from repro.core import (GATHER_CANDIDATES, MatrixStats, PlanSpec,
                            select_distributed)
    assert GATHER_CANDIDATES == ("upfront", "overlap", "fused")
    stats = MatrixStats(m=20000, n=20000, nnz=300000, max_row_nnz=64,
                        row_var=0.4, symmetric=False)
    ch = select_distributed(stats, k=64, num_devices=8)
    assert ch.gather in GATHER_CANDIDATES
    if not ch.compact_x:
        assert ch.gather == "upfront"
    pinned = select_distributed(
        stats, k=64, num_devices=8,
        spec=PlanSpec(num_devices=8, algorithm="sellcs", compact_x=True,
                      gather="overlap"))
    assert pinned.compact_x and pinned.gather == "overlap"
    with pytest.raises(ValueError, match="gather"):
        PlanSpec(num_devices=8, gather="bogus").canonical()
    with pytest.raises(ValueError, match="compact"):
        PlanSpec(num_devices=8, compact_x=False,
                 gather="fused").canonical()


def test_symmetric_combine_mixed_dtype_regression():
    """A wider stored diagonal must not promote the symmetric combine's
    output dtype: with a bf16 stream and a f32 diag, the one-triangle
    answer keeps the kernel-path dtype and matches the general-storage
    answer."""
    import jax
    import jax.numpy as jnp
    from repro.core import to_coo
    from repro.launch.mesh import make_mesh
    from repro.spmm import (coo_to_sellcs, partition_sellcs_rows,
                            spmm_row_distributed)
    if len(jax.devices()) != 1:
        return                       # in-process guard only needs 1 device
    rng = np.random.default_rng(3)
    b = np.zeros((12, 12), np.float32)
    idx = rng.integers(0, 12, size=(40, 2))
    b[idx[:, 0], idx[:, 1]] = rng.standard_normal(40).astype(np.float32)
    a = b + b.T + np.diag(np.arange(1.0, 13.0, dtype=np.float32))
    r, c = np.nonzero(a)
    coo = to_coo(r.astype(np.int32), c.astype(np.int32),
                 a[r, c].astype(np.float32), (12, 12))
    mesh = make_mesh((1,), ("data",))
    X = jnp.asarray(rng.standard_normal((12, 4)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    sym = partition_sellcs_rows(
        coo_to_sellcs(coo, c=4, sigma=8, structure="symmetric"), 1)
    sym = sym._replace(data=sym.data.astype(jnp.bfloat16),
                       diag=sym.diag.astype(jnp.float32))
    gen = partition_sellcs_rows(coo_to_sellcs(coo, c=4, sigma=8), 1)
    gen = gen._replace(data=gen.data.astype(jnp.bfloat16))
    y_gen = spmm_row_distributed(gen, X, mesh, impl="ref")
    y_sym = spmm_row_distributed(sym, X, mesh, impl="ref")
    assert y_sym.dtype == y_gen.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(y_sym, dtype=np.float32),
        np.asarray(y_gen, dtype=np.float32), rtol=0.1, atol=0.3)
