"""Distributed SpMV over a JAX mesh (the paper's multi-socket dimension,
scaled from 2 CPUs to pods).

Two strategies, mirroring the paper's two winning scheduling families:

* ``row_distributed``  (BCOH, §3.2): rows are statically banded so each
  device owns ~nnz/P nonzeros. x is replicated (the paper's interleaved
  allocation), y is written shard-locally — **zero collectives on y**. Wins
  when no single row dominates; this is why BCOH wins on NUMA machines.

* ``merge_distributed`` (Merge, §3.3): equal-nnz spans regardless of row
  boundaries; partial y contributions are combined with one ``psum`` — the
  carry-out fixup across devices. Survives the mawi single-dense-row case
  at the cost of an all-reduce on y.

Both are expressed with shard_map so the same code drives 8 host-platform
devices in tests and a 512-chip production mesh in the dry-run.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from .formats import COO
from .mergepath import balanced_row_bands


class ShardedCOO(NamedTuple):
    """Per-device padded COO shards, stacked along a leading device axis."""
    rows: jax.Array        # int32[Pdev, nnz_pad] — LOCAL row indices
    cols: jax.Array        # int32[Pdev, nnz_pad] — global col indices
    vals: jax.Array        # f32[Pdev, nnz_pad]  — zero-padded
    row_offset: jax.Array  # int32[Pdev] — first global row of the shard
    shape: Tuple[int, int]
    rows_per_shard: int    # static: padded local row count


def partition_rows(coo: COO, num_devices: int) -> ShardedCOO:
    """BCOH static banding: equal-nnz row bands, zero-padded to uniform
    shard shapes (host-side, convert time)."""
    m, n = coo.shape
    rows = np.asarray(coo.rows)
    cols = np.asarray(coo.cols)
    vals = np.asarray(coo.data)
    order = np.argsort(rows, kind="stable")
    rows, cols, vals = rows[order], cols[order], vals[order]
    row_ptr = np.zeros(m + 1, np.int64)
    np.cumsum(np.bincount(rows, minlength=m), out=row_ptr[1:])
    bands = balanced_row_bands(row_ptr, num_devices)
    nnz_start = row_ptr[bands]
    nnz_per = np.diff(nnz_start)
    nnz_pad = max(int(nnz_per.max()) if nnz_per.size else 1, 1)
    rows_per = max(int(np.diff(bands).max()), 1)

    R = np.zeros((num_devices, nnz_pad), np.int32)
    C = np.zeros((num_devices, nnz_pad), np.int32)
    V = np.zeros((num_devices, nnz_pad), vals.dtype)
    for p in range(num_devices):
        a, b = int(nnz_start[p]), int(nnz_start[p + 1])
        ln = b - a
        R[p, :ln] = rows[a:b] - bands[p]       # local row ids
        C[p, :ln] = cols[a:b]
        V[p, :ln] = vals[a:b]
    return ShardedCOO(jnp.asarray(R), jnp.asarray(C), jnp.asarray(V),
                      jnp.asarray(bands[:-1].astype(np.int32)),
                      (m, n), rows_per)


def partition_nnz(coo: COO, num_devices: int) -> ShardedCOO:
    """Merge-style equal-nnz spans (rows may straddle devices)."""
    m, n = coo.shape
    rows = np.asarray(coo.rows)
    cols = np.asarray(coo.cols)
    vals = np.asarray(coo.data)
    order = np.argsort(rows, kind="stable")
    rows, cols, vals = rows[order], cols[order], vals[order]
    nnz = rows.size
    bounds = (np.arange(num_devices + 1, dtype=np.int64) * nnz
              ) // num_devices
    nnz_pad = max(int(np.diff(bounds).max()), 1)
    R = np.zeros((num_devices, nnz_pad), np.int32)
    C = np.zeros((num_devices, nnz_pad), np.int32)
    V = np.zeros((num_devices, nnz_pad), vals.dtype)
    offs = np.zeros(num_devices, np.int32)
    for p in range(num_devices):
        a, b = int(bounds[p]), int(bounds[p + 1])
        ln = b - a
        if ln:
            offs[p] = rows[a]
            R[p, :ln] = rows[a:b] - rows[a]
            C[p, :ln] = cols[a:b]
            V[p, :ln] = vals[a:b]
    # padded entries: vals 0 at local row 0 — harmless
    span_rows = max(int((R.max(axis=1) + 1).max()) if nnz else 1, 1)
    return ShardedCOO(jnp.asarray(R), jnp.asarray(C), jnp.asarray(V),
                      jnp.asarray(offs), (m, n), span_rows)


def spmv_row_distributed(sharded: ShardedCOO, x: jax.Array, mesh: Mesh,
                         axis: str = "data") -> jax.Array:
    """y = A @ x with BCOH row banding: x replicated, y shard-local."""
    m, n = sharded.shape
    ndev = sharded.rows.shape[0]
    rp = sharded.rows_per_shard

    def local(rows, cols, vals, x_rep):
        # rows/cols/vals: [1, nnz_pad] local shard; x replicated
        y_loc = jnp.zeros((1, rp), vals.dtype)
        contrib = vals[0] * x_rep[cols[0]]
        return y_loc.at[0, rows[0]].add(contrib)

    yb = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P()),
        out_specs=P(axis, None))(
            sharded.rows, sharded.cols, sharded.vals, x)
    # reassemble: band p covers global rows [row_offset[p], +rows_in_band)
    idx = sharded.row_offset[:, None] + jnp.arange(rp, dtype=jnp.int32)[None]
    valid_len = jnp.concatenate(
        [sharded.row_offset[1:], jnp.array([m], jnp.int32)]
    ) - sharded.row_offset
    mask = jnp.arange(rp, dtype=jnp.int32)[None] < valid_len[:, None]
    y = jnp.zeros((m,), yb.dtype).at[jnp.where(mask, idx, m - 1)].add(
        jnp.where(mask, yb, 0))
    return y


def spmv_merge_distributed(sharded: ShardedCOO, x: jax.Array, mesh: Mesh,
                           axis: str = "data") -> jax.Array:
    """y = A @ x with merge spans: per-device partials + psum fixup."""
    m, n = sharded.shape
    rp = sharded.rows_per_shard

    def local(rows, cols, vals, offs, x_rep):
        contrib = vals[0] * x_rep[cols[0]]
        # scatter directly at global rows (offs + local row); padded entries
        # carry vals == 0 so they add nothing. One psum = the cross-device
        # carry-out fixup.
        y_loc = jnp.zeros((m,), vals.dtype).at[offs[0] + rows[0]].add(contrib)
        return jax.lax.psum(y_loc, axis)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P(axis), P()),
        out_specs=P())(
            sharded.rows, sharded.cols, sharded.vals,
            sharded.row_offset[:, None], x)
