"""Training entry point.

CPU-scale run (reduced config, real execution):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 30 --batch 8 --seq 64 --ckpt-dir /tmp/repro_ckpt --resume auto

Production (TPU pod): the same driver with --mesh 16x16 / 2x16x16 — the step
function, shardings and checkpoint layout are identical; only the mesh and
the per-host data shards change.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.pipeline import TokenPipeline
from repro.models.model import init_params
from repro.optim import make_optimizer, warmup_cosine
from repro.runtime import Supervisor
from repro.compat import set_mesh
from .mesh import make_mesh
from .steps import TrainState, make_train_step
from . import shardings as shd


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--resume", default="fresh", choices=["fresh", "auto"])
    ap.add_argument("--mesh", default="1x1",
                    help="DATAxMODEL, e.g. 16x16 on a pod")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    dshape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(dshape, ("data", "model"))
    if int(np.prod(dshape)) > 1:
        cfg = dataclasses.replace(cfg, batch_axes=("data",))

    optimizer = make_optimizer(
        args.optimizer, warmup_cosine(args.lr, max(args.steps // 10, 1),
                                      args.steps))
    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                         seed=args.seed)

    with set_mesh(mesh):
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        params = jax.device_put(params,
                                shd.param_shardings(params, mesh))
        state = TrainState(params, optimizer.init(params))
        step_fn = jax.jit(make_train_step(cfg, optimizer),
                          donate_argnums=(0,))

        sup = Supervisor(args.ckpt_dir, save_every=args.save_every,
                         heartbeat_path=args.ckpt_dir + "/heartbeat.json")
        start = 0
        if args.resume == "auto":
            restored, start = sup.restore(state)
            if restored is not None:
                state = restored
                print(f"[train] resumed from step {start}")

        t_last = time.perf_counter()
        for step in range(start, args.steps):
            batch = {"tokens": jnp.asarray(pipe.batch_at(step)["tokens"])}
            if cfg.frontend == "vision":
                batch["vision_embeds"] = jax.random.normal(
                    jax.random.PRNGKey(step), (args.batch,
                                               cfg.vision_tokens,
                                               cfg.vision_dim),
                    jnp.bfloat16)
            state, metrics = step_fn(state, batch)
            sup.monitor.observe(step, time.perf_counter() - t_last)
            t_last = time.perf_counter()
            sup.heartbeat(step, {k: float(v) for k, v in metrics.items()})
            if step % args.log_every == 0:
                print(f"[train] step {step} loss={float(metrics['loss']):.4f}"
                      f" ce={float(metrics['ce']):.4f}"
                      f" gnorm={float(metrics['grad_norm']):.3f}")
            sup.maybe_save(step + 1, state)
        sup.finalize(args.steps, state)
        print(f"[train] done; final loss {float(metrics['loss']):.4f}; "
              f"checkpoints in {args.ckpt_dir}")
        return float(metrics["loss"])


if __name__ == "__main__":
    main()
