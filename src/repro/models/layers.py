"""Primitive layers (pure functions over param pytrees, no framework dep)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x: Array, compute_dtype=None) -> Array:
    """Matmul in the activation dtype: params (stored f32 master) are cast
    to x.dtype — or to an explicit compute_dtype — at use."""
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    else:
        w = w.astype(x.dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float = 10000.0) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)            # [half]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                        # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# short causal conv (Mamba)
# ---------------------------------------------------------------------------
def causal_conv1d_init(key, channels: int, width: int, dtype=jnp.float32):
    return {"w": jax.random.normal(key, (width, channels), dtype)
            * (width ** -0.5),
            "b": jnp.zeros((channels,), dtype)}


def causal_conv1d(p, x: Array, state: Optional[Array] = None
                  ) -> Tuple[Array, Array]:
    """x: [B, S, C] -> (y [B, S, C], new_state [B, width-1, C]).
    state carries the last (width-1) inputs for streaming decode."""
    w, b = p["w"], p["b"]
    width = w.shape[0]
    B, S, C = x.shape
    if state is None:
        state = jnp.zeros((B, width - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)                # [B, S+w-1, C]
    y = jnp.zeros((B, S, C), jnp.promote_types(x.dtype, jnp.float32))
    for i in range(width):
        y = y + xp[:, i:i + S, :].astype(y.dtype) * w[i].astype(y.dtype)
    y = (y + b.astype(y.dtype)).astype(x.dtype)
    new_state = xp[:, S:, :]
    return y, new_state


def softcap(x: Array, cap: float) -> Array:
    return cap * jnp.tanh(x / cap)
