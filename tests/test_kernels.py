"""Pallas kernels vs pure-jnp oracles (interpret mode), swept over
shapes/dtypes/orderings."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import coo_to_csr, spmv_dense_oracle, to_coo
from repro.data import matrices
from repro.kernels import coo_to_tiled, merge_plan, ops, ref


def _rand_x(n, dtype=np.float32, seed=7):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(n)
                       .astype(dtype))


SHAPES = [
    ("square", matrices.uniform(256, 256, 2000, 0)),
    ("tall", matrices.uniform(700, 120, 2600, 1)),
    ("wide", matrices.uniform(120, 700, 2600, 2)),
    ("mesh", matrices.mesh2d(17)),
    ("powerlaw", matrices.powerlaw(300, 300, 3000, 1.7, 3)),
    ("mawi", matrices.mawi_like(260, 260, 2200, 0.35, 4)),
    ("tiny", matrices.uniform(8, 128, 30, 5)),
    ("empty", (np.zeros(0, np.int32), np.zeros(0, np.int32),
               np.zeros(0, np.float32), (64, 256))),
]


@pytest.mark.parametrize("name,gen", SHAPES, ids=[s[0] for s in SHAPES])
@pytest.mark.parametrize("algo", ["csb", "csbh", "bcohch", "mergeb"])
def test_bsr_spmv_vs_ref(name, gen, algo):
    coo = to_coo(*gen)
    ts = coo_to_tiled(coo, algo, beta=128)
    x = _rand_x(coo.shape[1])
    y_ref = ref.bsr_spmv_ref(ts, x)
    np.testing.assert_allclose(np.asarray(y_ref),
                               np.asarray(spmv_dense_oracle(coo, x)),
                               rtol=1e-4, atol=1e-4)
    y = ops.bsr_spmv(ts, x, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("tiles_per_step", [1, 4, 16])
def test_bsr_spmv_tiles_per_step(tiles_per_step):
    coo = to_coo(*matrices.uniform(256, 256, 2000, 0))
    ts = coo_to_tiled(coo, "csb", beta=128)
    x = _rand_x(coo.shape[1])
    y = ops.bsr_spmv(ts, x, interpret=True, tiles_per_step=tiles_per_step)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.bsr_spmv_ref(ts, x)),
                               rtol=1e-5, atol=1e-5)


def test_bsr_spmv_bf16_tiles():
    coo = to_coo(*matrices.uniform(256, 256, 2000, 0))
    ts = coo_to_tiled(coo, "csb", beta=128, dtype=jnp.bfloat16)
    x = _rand_x(coo.shape[1])
    y = ops.bsr_spmv(ts, x, interpret=True)
    yo = spmv_dense_oracle(to_coo(*matrices.uniform(256, 256, 2000, 0)), x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yo),
                               rtol=5e-2, atol=5e-1)


@pytest.mark.parametrize("name,gen", SHAPES, ids=[s[0] for s in SHAPES])
@pytest.mark.parametrize("spans", [4, 32])
def test_merge_spmv_vs_ref(name, gen, spans):
    coo = to_coo(*gen)
    csr = coo_to_csr(coo)
    x = _rand_x(coo.shape[1])
    plan = merge_plan(csr, spans)
    y = ops.merge_spmv(csr, x, plan=plan, interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.merge_spmv_ref(csr, x)),
                               rtol=1e-4, atol=1e-4)


def test_merge_plan_balance():
    """Every span consumes the same number of merge operations (+-1 step)."""
    coo = to_coo(*matrices.mawi_like(260, 260, 2200, 0.35, 4))
    csr = coo_to_csr(coo)
    P = 16
    plan = merge_plan(csr, P)
    starts = np.asarray(plan.row_starts)
    nnz_counts = np.sum(np.asarray(plan.vals) != 0, axis=1)
    m, nnz = csr.shape[0], csr.nnz
    D = -(-(m + nnz) // P)
    # diag budget: rows closed + nnz consumed <= D per span
    rows_per = np.diff(starts)
    assert np.all(rows_per + nnz_counts <= D + 1)
    assert nnz_counts.sum() == nnz


@pytest.mark.parametrize("sizes", [
    [10, 200, 0, 90], [0, 0, 300, 0], [75, 75, 75, 75], [300, 0, 0, 0]])
def test_moe_group_matmul(sizes):
    rng = np.random.default_rng(0)
    E, K, N = 4, 256, 384
    T = int(np.sum(sizes))
    tokens = jnp.asarray(rng.standard_normal((T, K)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((E, K, N)).astype(np.float32) * .1)
    out = ops.moe_group_matmul(tokens, w, jnp.asarray(sizes, jnp.int32),
                               interpret=True)
    outr = ref.moe_group_matmul_ref(tokens, w, jnp.asarray(sizes, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(outr),
                               rtol=2e-4, atol=2e-4)


def test_tiled_fill_and_switches():
    """Hilbert ordering must not increase x-window switches vs row order
    on a matrix with 2D locality (the paper's locality claim, TPU proxy)."""
    coo = to_coo(*matrices.mesh2d(40))
    ts_row = coo_to_tiled(coo, "mergeb", beta=256)   # row-major order
    ts_hil = coo_to_tiled(coo, "bcohch", beta=256)   # hilbert both levels
    xr, yr = ts_row.window_switches()
    xh, yh = ts_hil.window_switches()
    assert ts_row.num_tiles == ts_hil.num_tiles
    assert xh + yh <= (xr + yr) * 1.5
    assert 0 < ts_row.fill_ratio <= 1
