"""Distributed multi-RHS SpMM (repro.spmm.distributed) on 8 host-platform
devices, plus the degenerate-input guards of both partitioner families.

Device-backed tests run in SUBPROCESSES (the device-count flag must be set
before jax initializes; the rest of the suite keeps seeing 1 device).
Partitioner guard tests are pure host code and run in-process.
"""
import os
import subprocess
import sys

import numpy as np

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_spmm_distributed_matches_oracle_k_1_8_64():
    """ISSUE acceptance: both schedules match the spmm.reference oracle on
    8 devices for k in {1, 8, 64}, including the mawi skewed case."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.data import matrices
from repro.launch.mesh import make_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_coo,
                        spmm_merge_distributed, spmm_row_distributed)
mesh = make_mesh((8,), ("data",))
for name, gen in [("uniform", matrices.uniform(500, 430, 4000, 0)),
                  ("mawi_like", matrices.mawi_like(400, 400, 3000, 0.4, 1))]:
    coo = to_coo(*gen)
    sc = coo_to_sellcs(coo, c=16, sigma=64)
    row = partition_sellcs_rows(sc, 8)
    mrg = partition_sellcs_nnz(sc, 8)
    for k in (1, 8, 64):
        X = jnp.asarray(np.random.default_rng(k).standard_normal(
            (coo.shape[1], k)).astype(np.float32))
        yo = np.asarray(spmm_coo(coo, X))
        yr = np.asarray(spmm_row_distributed(row, X, mesh))
        ym = np.asarray(spmm_merge_distributed(mrg, X, mesh))
        np.testing.assert_allclose(yr, yo, rtol=1e-5, atol=1e-4,
                                   err_msg=f"{name} row k={k}")
        np.testing.assert_allclose(ym, yo, rtol=1e-5, atol=1e-4,
                                   err_msg=f"{name} merge k={k}")
    # SpMV rides along as the 1-D k=1 special case
    x = jnp.asarray(np.random.default_rng(9).standard_normal(
        coo.shape[1]).astype(np.float32))
    y = spmm_row_distributed(row, x, mesh)
    assert y.ndim == 1
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(spmm_coo(coo, x)),
                               rtol=1e-5, atol=1e-4)
print("distributed spmm oracle OK")
"""))


def test_spmm_distributed_pallas_interpret_kernel_body():
    """The shard_map bodies reuse the PR-1 k-tiled Pallas kernel
    (interpret mode off-TPU)."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.data import matrices
from repro.launch.mesh import make_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_coo,
                        spmm_merge_distributed, spmm_row_distributed)
mesh = make_mesh((8,), ("data",))
coo = to_coo(*matrices.mawi_like(300, 280, 2400, 0.4, 3))
sc = coo_to_sellcs(coo, c=16, sigma=64)
X = jnp.asarray(np.random.default_rng(5).standard_normal(
    (coo.shape[1], 8)).astype(np.float32))
yo = np.asarray(spmm_coo(coo, X))
yr = np.asarray(spmm_row_distributed(
    partition_sellcs_rows(sc, 8), X, mesh, impl="pallas_interpret",
    k_tile=4))
ym = np.asarray(spmm_merge_distributed(
    partition_sellcs_nnz(sc, 8), X, mesh, impl="pallas_interpret",
    k_tile=4))
np.testing.assert_allclose(yr, yo, rtol=1e-5, atol=1e-4)
np.testing.assert_allclose(ym, yo, rtol=1e-5, atol=1e-4)
print("distributed pallas kernel body OK")
"""))


def test_spmm_merge_chunked_matches_monolithic():
    """ISSUE 3 acceptance: the chunked/pipelined merge schedule is
    summation-equivalent (within fp tolerance) to the monolithic one for
    num_chunks in {1, 2, 8}, k in {1, 8, 64}, including the mawi dense-row
    case and the num_chunks > S degenerate setting."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.data import matrices
from repro.launch.mesh import make_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz, spmm_coo,
                        spmm_merge_distributed)
mesh = make_mesh((8,), ("data",))
for name, gen in [("uniform", matrices.uniform(500, 430, 4000, 0)),
                  ("mawi_like", matrices.mawi_like(400, 400, 3000, 0.4, 1))]:
    coo = to_coo(*gen)
    sc = coo_to_sellcs(coo, c=16, sigma=64)
    mrg = partition_sellcs_nnz(sc, 8)
    S = sc.num_slices
    for k in (1, 8, 64):
        X = jnp.asarray(np.random.default_rng(k).standard_normal(
            (coo.shape[1], k)).astype(np.float32))
        yo = np.asarray(spmm_coo(coo, X))
        y1 = np.asarray(spmm_merge_distributed(mrg, X, mesh, num_chunks=1))
        np.testing.assert_allclose(y1, yo, rtol=1e-5, atol=1e-4,
                                   err_msg=f"{name} k={k} monolithic")
        for c in (2, 8, S + 5):        # S + 5 > S: empty tail chunks
            yc = np.asarray(spmm_merge_distributed(mrg, X, mesh,
                                                   num_chunks=c))
            np.testing.assert_allclose(yc, y1, rtol=1e-6, atol=1e-5,
                                       err_msg=f"{name} k={k} chunks={c}")
    # the Pallas kernel body chunks identically (interpret mode off-TPU)
    X = jnp.asarray(np.random.default_rng(3).standard_normal(
        (coo.shape[1], 8)).astype(np.float32))
    yc = np.asarray(spmm_merge_distributed(
        mrg, X, mesh, impl="pallas_interpret", k_tile=4, num_chunks=3))
    np.testing.assert_allclose(yc, np.asarray(spmm_coo(coo, X)),
                               rtol=1e-5, atol=1e-4)
    # partition-time span plan (the serve path) gives the same answer
    baked = partition_sellcs_nnz(sc, 8, num_chunks=2)
    assert baked.chunk_plan is not None and baked.chunk_plan[0] == 2
    yb = np.asarray(spmm_merge_distributed(baked, X, mesh, num_chunks=2))
    np.testing.assert_allclose(yb, np.asarray(spmm_coo(coo, X)),
                               rtol=1e-5, atol=1e-4, err_msg=name)
import pytest
with pytest.raises(ValueError):
    spmm_merge_distributed(mrg, X, mesh, num_chunks=0)
print("chunked merge equivalence OK")
"""))


def test_spmm_distributed_dtype_follows_kernel():
    """Regression: the nnz == 0 early-returns used to hardcode float32;
    they must produce the dtype the nonzero kernel path would — the
    (data, X) promotion on the ref path, which is also what the spmm_coo
    oracle reports. An empty matrix (data stored float32) multiplied by a
    complex64 X must come out complex64, not float32."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.launch.mesh import make_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_coo,
                        spmm_merge_distributed, spmm_row_distributed)
mesh = make_mesh((8,), ("data",))
z = np.zeros(0, np.int32)
empty = to_coo(z, z, np.zeros(0, np.float32), (6, 4))
tiny = to_coo(np.array([0, 1, 2], np.int32), np.array([0, 1, 2], np.int32),
              np.ones(3, np.float32), (6, 4))
X16 = jnp.ones((4, 3), jnp.float16)
Xc = jnp.ones((4, 3), jnp.complex64)
se = coo_to_sellcs(empty, c=2, sigma=4)
st = coo_to_sellcs(tiny, c=2, sigma=4)
for part, fn in [(partition_sellcs_rows, spmm_row_distributed),
                 (partition_sellcs_nnz, spmm_merge_distributed)]:
    # the nonzero path and the oracle agree on the (data, X) promotion
    y16 = fn(part(st, 8), X16, mesh)
    assert y16.dtype == spmm_coo(tiny, X16).dtype, (fn.__name__, y16.dtype)
    # nnz == 0 must take the same promotion, not hardcoded float32: with a
    # complex64 X the nonzero path yields complex64, and so must this
    ye = fn(part(se, 8), Xc, mesh)
    assert ye.dtype == spmm_coo(empty, Xc).dtype == jnp.complex64, \\
        (fn.__name__, ye.dtype)
    assert np.abs(np.asarray(ye)).max() == 0
# chunked merge keeps the same dtype contract as the monolithic schedule
yc = spmm_merge_distributed(partition_sellcs_nnz(st, 8), X16, mesh,
                            num_chunks=2)
assert yc.dtype == spmm_merge_distributed(
    partition_sellcs_nnz(st, 8), X16, mesh).dtype
print("distributed dtype contract OK")
"""))


def test_sharded_coo_multi_rhs_and_batcher_distributed():
    """core.distributed accepts [n, k] X; RequestBatcher drives a
    distributed spmm_fn closure (partial last flush included)."""
    print(run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import to_coo
from repro.core.distributed import (partition_nnz, partition_rows,
                                    spmv_merge_distributed,
                                    spmv_row_distributed)
from repro.data import matrices
from repro.launch.mesh import make_mesh
from repro.spmm import (RequestBatcher, coo_to_sellcs,
                        partition_sellcs_rows, spmm_coo,
                        spmm_row_distributed)
mesh = make_mesh((8,), ("data",))
coo = to_coo(*matrices.mawi_like(260, 240, 2400, 0.3, 1))
for k in (1, 8, 64):
    X = jnp.asarray(np.random.default_rng(k).standard_normal(
        (coo.shape[1], k)).astype(np.float32))
    yo = np.asarray(spmm_coo(coo, X))
    y1 = np.asarray(spmv_row_distributed(partition_rows(coo, 8), X, mesh))
    y2 = np.asarray(spmv_merge_distributed(partition_nnz(coo, 8), X, mesh))
    np.testing.assert_allclose(y1, yo, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(y2, yo, rtol=1e-5, atol=1e-4)

# batcher over the mesh: 11 requests, max_batch 8 -> one full + one
# partial flush, every ticket answered from the right column
sc = coo_to_sellcs(coo, c=16, sigma=64)
sharded = partition_sellcs_rows(sc, 8)
calls = []
def spmm_fn(_mat, X):
    calls.append(X.shape[1])
    return spmm_row_distributed(sharded, X, mesh)
b = RequestBatcher(sc, max_batch=8, spmm_fn=spmm_fn)
rng = np.random.default_rng(11)
xs = [jnp.asarray(rng.standard_normal(coo.shape[1]).astype(np.float32))
      for _ in range(11)]
rids = [b.submit(x) for x in xs]
out = b.drain()
assert b.flushes == 2 and b.served == 11 and sorted(out) == sorted(rids)
assert calls == [8, 4], calls   # pow2-padded partial flush
for rid, x in zip(rids, xs):
    np.testing.assert_allclose(np.asarray(out[rid]),
                               np.asarray(spmm_coo(coo, x)),
                               rtol=1e-5, atol=1e-4)
print("sharded COO k + distributed batcher OK")
"""))


def test_spmm_distributed_degenerate_on_mesh():
    """Empty matrices and meshes wider than the matrix stay correct."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.core.distributed import (partition_nnz, partition_rows,
                                    spmv_merge_distributed,
                                    spmv_row_distributed)
from repro.launch.mesh import make_mesh
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                        partition_sellcs_rows, spmm_merge_distributed,
                        spmm_row_distributed)
mesh = make_mesh((8,), ("data",))
z = np.zeros(0, np.int32)
empty = to_coo(z, z, np.zeros(0, np.float32), (5, 4))
tiny = to_coo(np.array([0, 1, 2], np.int32), np.array([0, 1, 2], np.int32),
              np.ones(3, np.float32), (3, 3))
X4 = jnp.ones((4, 3), jnp.float32)
I3 = jnp.eye(3, dtype=jnp.float32)
# SELL-C-σ schedules
se = coo_to_sellcs(empty, c=2, sigma=4)
assert np.abs(np.asarray(spmm_row_distributed(
    partition_sellcs_rows(se, 8), X4, mesh))).max() == 0
assert np.abs(np.asarray(spmm_merge_distributed(
    partition_sellcs_nnz(se, 8), X4, mesh))).max() == 0
st = coo_to_sellcs(tiny, c=2, sigma=2)    # more devices than slices
np.testing.assert_allclose(np.asarray(spmm_row_distributed(
    partition_sellcs_rows(st, 8), I3, mesh)), np.eye(3), atol=1e-6)
np.testing.assert_allclose(np.asarray(spmm_merge_distributed(
    partition_sellcs_nnz(st, 8), I3, mesh)), np.eye(3), atol=1e-6)
# COO schedules: num_devices > m and nnz == 0
assert np.abs(np.asarray(spmv_row_distributed(
    partition_rows(empty, 8), X4, mesh))).max() == 0
assert np.abs(np.asarray(spmv_merge_distributed(
    partition_nnz(empty, 8), X4, mesh))).max() == 0
np.testing.assert_allclose(np.asarray(spmv_row_distributed(
    partition_rows(tiny, 8), I3, mesh)), np.eye(3), atol=1e-6)
print("degenerate mesh cases OK")
"""))


# --------------------------------------------------------------------------
# Partitioner guards — host-side, no devices needed
# --------------------------------------------------------------------------
def _empty_coo(m=5, n=4):
    from repro.core import to_coo
    z = np.zeros(0, np.int32)
    return to_coo(z, z, np.zeros(0, np.float32), (m, n))


def test_partition_guards_reject_bad_device_count():
    import pytest
    from repro.core.distributed import partition_nnz, partition_rows
    from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                            partition_sellcs_rows)
    coo = _empty_coo()
    sc = coo_to_sellcs(coo, c=2)
    for fn, arg in [(partition_rows, coo), (partition_nnz, coo),
                    (partition_sellcs_rows, sc), (partition_sellcs_nnz, sc)]:
        with pytest.raises(ValueError):
            fn(arg, 0)
        with pytest.raises(ValueError):
            fn(arg, -3)


def test_partition_rows_empty_matrix_keeps_sane_shard_shapes():
    """Regression: a zero-nnz matrix used to put every row in the last
    band, inflating rows_per_shard to m; now bands split evenly."""
    from repro.core.distributed import partition_nnz, partition_rows
    coo = _empty_coo(m=64, n=16)
    s = partition_rows(coo, 8)
    assert s.rows.shape == (8, 1)
    assert s.rows_per_shard == 8            # == m / P, not m
    assert np.asarray(s.row_offset).tolist() == list(range(0, 64, 8))
    s2 = partition_nnz(coo, 8)
    assert s2.rows.shape == (8, 1) and s2.rows_per_shard == 1


def test_partition_more_devices_than_rows():
    from repro.core import to_coo
    from repro.core.distributed import partition_nnz, partition_rows
    coo = to_coo(np.array([0, 1, 2], np.int32),
                 np.array([0, 1, 2], np.int32),
                 np.ones(3, np.float32), (3, 3))
    for part in (partition_rows, partition_nnz):
        s = part(coo, 8)
        assert s.rows.shape[0] == 8
        assert s.rows_per_shard >= 1
        # local row ids stay inside the shard buffer
        assert int(np.asarray(s.rows).max()) < s.rows_per_shard
        # every shard offset is a valid global row (or 0 for empty shards)
        offs = np.asarray(s.row_offset)
        assert offs.min() >= 0 and offs.max() < 3


def test_partition_sellcs_roundtrip_covers_all_nnz():
    """Both SELL-C-σ partitioners must conserve the nonzero payload."""
    from repro.core import to_coo
    from repro.data import matrices
    from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                            partition_sellcs_rows)
    coo = to_coo(*matrices.mawi_like(200, 180, 1500, 0.3, 2))
    sc = coo_to_sellcs(coo, c=8, sigma=32)
    total = float(np.abs(np.asarray(sc.data)).sum())
    for part in (partition_sellcs_rows, partition_sellcs_nnz):
        for P in (1, 3, 8, 64):
            sh = part(sc, P)
            got = float(np.abs(np.asarray(sh.data)).sum())
            assert abs(got - total) < 1e-3, (part.__name__, P)
            assert sh.data.shape[0] == P


def _explicit_zero_coo():
    """m=16, c=4: row 0 stores 3 entries, two of them EXPLICIT ZEROS at
    cols 2 and 3; rows 1..15 store one entry each. After the σ-sort, slice
    0 has width 3 and its width-rows j=1,2 carry ONLY row 0's explicit
    zeros — all-zero data with real column indices, exactly what
    SellCS.to_coo round-trips and a value-based padding mask destroys."""
    from repro.core import to_coo
    rows = np.array([0, 0, 0] + list(range(1, 16)), np.int32)
    cols = np.array([0, 2, 3] + [r % 4 for r in range(1, 16)], np.int32)
    vals = np.array([1.0, 0.0, 0.0] + [float(r) for r in range(1, 16)],
                    np.float32)
    return to_coo(rows, cols, vals, (16, 4))


def test_chunk_plan_preserves_explicit_zero_width_rows():
    """Regression for the ``np.any(data != 0)`` padding mask in
    ``_chunk_substreams``: the span plan must rebuild the stream from the
    partitioner's recorded real-row counts, so (a) the slice spans equal
    ``balanced_row_bands`` over the TRUE per-slice widths and (b) the
    explicit-zero width-rows survive into the spans with their column
    payload. The old mask dropped them, shifting both."""
    from repro.core import balanced_row_bands
    from repro.spmm import coo_to_sellcs, partition_sellcs_nnz
    sc = coo_to_sellcs(_explicit_zero_coo(), c=4, sigma=16)
    widths = np.diff(np.asarray(sc.slice_ptr, np.int64))
    assert widths.tolist() == [3, 1, 1, 1]       # slice 0 holds the zeros
    sharded = partition_sellcs_nnz(sc, 3, num_chunks=2)
    assert np.asarray(sharded.row_counts).sum() == sc.data.shape[0]
    spans = sharded.chunk_plan[1]
    # (a) spans tile [0, S) at the band bounds of the TRUE widths — the
    # old mask saw widths [1, 1, 1, 1] and cut the stream elsewhere
    bounds = balanced_row_bands(np.asarray(sc.slice_ptr, np.int64), 2)
    expect = [(int(a), int(b - a)) for a, b in zip(bounds, bounds[1:])
              if b > a]
    assert [(sp.slice_start, sp.num_slices) for sp in spans] == expect
    # (b) the two explicit-zero width-rows (all-zero values, nonzero cols)
    # crossed into the spans — the old mask left zero of them
    zero_rows = sum(
        int((np.all(np.asarray(sp.data) == 0, axis=-1)
             & np.any(np.asarray(sp.cols) != 0, axis=-1)).sum())
        for sp in spans)
    assert zero_rows == 2


def test_chunked_merge_equivalence_with_explicit_zeros():
    """ISSUE 4 satellite: chunked-vs-monolithic merge equivalence on a COO
    matrix containing explicit-zero entries, on a real 8-device mesh."""
    print(run_sub("""
import numpy as np, jax.numpy as jnp
from repro.core import to_coo
from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz, spmm_coo,
                        spmm_merge_distributed)
from repro.launch.mesh import make_mesh
rows = np.array([0, 0, 0] + list(range(1, 16)), np.int32)
cols = np.array([0, 2, 3] + [r % 4 for r in range(1, 16)], np.int32)
vals = np.array([1.0, 0.0, 0.0] + [float(r) for r in range(1, 16)],
                np.float32)
coo = to_coo(rows, cols, vals, (16, 4))
mesh = make_mesh((8,), ("data",))
sc = coo_to_sellcs(coo, c=4, sigma=16)
mrg = partition_sellcs_nnz(sc, 8)
X = jnp.asarray(np.random.default_rng(0).standard_normal(
    (4, 8)).astype(np.float32))
yo = np.asarray(spmm_coo(coo, X))
y1 = np.asarray(spmm_merge_distributed(mrg, X, mesh, num_chunks=1))
np.testing.assert_allclose(y1, yo, rtol=1e-5, atol=1e-5)
for c in (2, 3, 9):
    yc = np.asarray(spmm_merge_distributed(mrg, X, mesh, num_chunks=c))
    np.testing.assert_allclose(yc, y1, rtol=1e-6, atol=1e-6,
                               err_msg=f"chunks={c}")
print("explicit-zero chunked merge OK")
"""))


def test_partitioners_record_row_counts():
    """Both partitioners record per-device real width-row counts (the only
    trustworthy padding mask — see _chunk_substreams)."""
    from repro.core import to_coo
    from repro.data import matrices
    from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                            partition_sellcs_rows)
    coo = to_coo(*matrices.mawi_like(200, 180, 1500, 0.3, 2))
    sc = coo_to_sellcs(coo, c=8, sigma=32)
    W = sc.data.shape[0]
    for part in (partition_sellcs_rows, partition_sellcs_nnz):
        for P in (1, 3, 8):
            sh = part(sc, P)
            counts = np.asarray(sh.row_counts)
            assert counts.shape == (P,) and counts.sum() == W
            assert counts.min() >= 0
            assert counts.max() <= sh.data.shape[1]


def test_distributed_schedule_mismatch_raises():
    import pytest
    import jax
    from repro.launch.mesh import make_mesh
    from repro.spmm import (coo_to_sellcs, partition_sellcs_rows,
                            spmm_merge_distributed)
    if len(jax.devices()) != 1:
        return                       # in-process guard only needs 1 device
    mesh = make_mesh((1,), ("data",))
    sc = coo_to_sellcs(_empty_coo(), c=2)
    sharded = partition_sellcs_rows(sc, 1)
    with pytest.raises(ValueError, match="schedule"):
        spmm_merge_distributed(sharded, np.ones((4, 2), np.float32), mesh)


def test_rechunk_sellcs_equals_partition_time_plan():
    """rechunk_sellcs (the SparseOperator swap path's partition reuse) must
    bake exactly the chunk plan partition_sellcs_nnz would have baked at
    partition time, for every depth — and reject non-merge partitions."""
    import pytest
    from repro.core import to_coo
    from repro.data import matrices
    from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                            partition_sellcs_rows, rechunk_sellcs)
    coo = to_coo(*matrices.mawi_like(300, 280, 2500, 0.3, 1))
    sc = coo_to_sellcs(coo, c=8, sigma=32)
    for compact in (False, True):
        base = partition_sellcs_nnz(sc, 4, compact_x=compact)
        assert base.chunk_plan is None
        for nc in (2, 4):
            re = rechunk_sellcs(base, nc)
            fresh = partition_sellcs_nnz(sc, 4, num_chunks=nc,
                                         compact_x=compact)
            assert re.chunk_plan is not None
            assert re.chunk_plan[0] == fresh.chunk_plan[0] == nc
            # span count may clamp below nc when slices run out; the two
            # paths must clamp identically. col_map/n_touched are arrays
            # when compact, None otherwise
            assert len(re.chunk_plan[1]) == len(fresh.chunk_plan[1])
            for got, want in zip(re.chunk_plan[1], fresh.chunk_plan[1]):
                # _ChunkSpan fields mix ints and arrays — compare each
                for g, w in zip(got, want):
                    np.testing.assert_array_equal(np.asarray(g),
                                                  np.asarray(w))
            for got, want in zip(re.chunk_plan[2:], fresh.chunk_plan[2:]):
                assert (got is None) == (want is None)
                if got is not None:
                    np.testing.assert_array_equal(np.asarray(got),
                                                  np.asarray(want))
        # idempotence: same depth returns the same object, depth 1 strips
        re4 = rechunk_sellcs(base, 4)
        assert rechunk_sellcs(re4, 4) is re4
        assert rechunk_sellcs(re4, 1).chunk_plan is None
    with pytest.raises(ValueError, match="merge"):
        rechunk_sellcs(partition_sellcs_rows(sc, 4), 2)
    with pytest.raises(ValueError):
        rechunk_sellcs(partition_sellcs_nnz(sc, 4), 0)
