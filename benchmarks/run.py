"""Benchmark driver — one section per paper table/figure. Prints
``name,us_per_call,derived`` CSV (see harness.Csv)."""
from __future__ import annotations

import argparse


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: low,high,skewed,"
                         "conversion,breakeven,sweep,moe,roofline")
    ap.add_argument("--scale", type=float, default=0.12,
                    help="matrix suite scale factor")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump all rows as JSON (harness schema)")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from . import conversion, grid_sweep, harness, moe_dispatch, \
        roofline_table, spmv_tables
    harness.reset_records()

    def want(name):
        return only is None or name in only

    if want("low"):
        spmv_tables.run_low()
    if want("high"):
        spmv_tables.run_high()
    if want("skewed"):
        spmv_tables.run_skewed()
    if want("conversion"):
        conversion.run(suite_scale=args.scale)
    if want("breakeven"):
        conversion.run_break_even()
    if want("sweep"):
        grid_sweep.run()
    if want("moe"):
        moe_dispatch.run()
    if want("roofline"):
        roofline_table.run()
    if args.json:
        harness.dump_json(args.json)


if __name__ == "__main__":
    main()
