"""Elastic scaling: rebuild the mesh from the live device set and reshard.

When nodes join/leave, a 1000-node deployment (a) checkpoints, (b) rebuilds
the mesh over the surviving devices, (c) re-places every array under the new
sharding. Because our sharding is rule-based (launch/sharding.py maps param
paths -> PartitionSpec independent of mesh size), step (c) is a single
``jax.device_put`` per pytree — no reshape of the math, only of the layout.
Data-parallel batch is re-split by the pipeline's dp_size argument.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def build_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str],
               devices: Optional[Sequence] = None) -> Mesh:
    devices = list(devices if devices is not None else jax.devices())
    need = int(np.prod(axis_sizes))
    if len(devices) < need:
        raise ValueError(f"need {need} devices, have {len(devices)}")
    arr = np.array(devices[:need]).reshape(tuple(axis_sizes))
    return Mesh(arr, tuple(axis_names))


def largest_feasible_mesh(num_devices: int, model_parallel: int,
                          axis_names: Tuple[str, str] = ("data", "model")
                          ) -> Tuple[int, int]:
    """Shrink policy: keep TP fixed (it matches the model's head/ffn
    divisibility), absorb node loss on the data axis."""
    data = num_devices // model_parallel
    if data < 1:
        raise ValueError("fewer devices than one model replica")
    return (data, model_parallel)


def reshard(tree: Any, mesh: Mesh, spec_fn: Callable[[str, Any],
            PartitionSpec]) -> Any:
    """Re-place every leaf under ``mesh`` with rule-derived specs. A spec
    naming an axis the target mesh does not carry (a rule written for the
    pre-shrink mesh) is rejected up front — ``device_put`` would otherwise
    fail deep inside XLA with an unhelpful message."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for (path, leaf) in flat:
        key = "/".join(str(p) for p in path)
        spec = spec_fn(key, leaf)
        for entry in spec:
            names = entry if isinstance(entry, tuple) else (entry,)
            for name in names:
                if name is not None and name not in mesh.axis_names:
                    raise ValueError(
                        f"spec for {key!r} names axis {name!r}, but the "
                        f"target mesh only has {tuple(mesh.axis_names)}")
        out.append(jax.device_put(leaf, NamedSharding(mesh, spec)))
    return jax.tree_util.tree_unflatten(treedef, out)
