"""repro.configs — assigned architecture configs + shape registry."""
from .base import (ARCH_IDS, SHAPES, ShapeSpec, cells, get_config,
                   long_context_capable, registry)

__all__ = ["ARCH_IDS", "SHAPES", "ShapeSpec", "cells", "get_config",
           "long_context_capable", "registry"]
