"""Benchmark harness: min-of-N timing (the paper times 550 executions and
reports the minimum, §5.2 — we use the same protocol with fewer reps on the
1-core container) + CSV emission, with an optional JSON sink shared by
every driver (``benchmarks.run --json``, ``benchmarks.spmm_sweep --json``).

JSON schema: a list of ``{"section": <table title>, "name": <row name>,
"us_per_call": <float>, "derived": <free-form string>, "backend": <str>,
"reps": <int>, "warmup": <int>}`` records — the CSV columns plus the
measurement provenance: which XLA backend produced the number and the
min-of-N protocol parameters that timed it, so a downstream gate (or a
human diffing two CI artifacts) can tell a min-of-20 CPU row from a
first-flush TPU fluke without parsing free-form ``derived`` strings.
Analytic rows (``seconds <= 0``, e.g. break-even counts) carry the
backend but no reps/warmup — nothing timed them."""
from __future__ import annotations

import json
from typing import Callable, Dict, List

import jax

from repro.obs import time_min_of_n

# module-level record sink shared by all Csv instances (reset per driver)
_RECORDS: List[Dict] = []

# protocol parameters of the most recent time_fn/time_host call; Csv.row
# stamps them into the records of timed rows. Sticky by design: drivers
# call time_fn immediately before row() and every driver in this repo
# times a whole table with one protocol.
_PROTOCOL: Dict[str, int] = {}


def reset_records() -> None:
    _RECORDS.clear()
    _PROTOCOL.clear()


def records() -> List[Dict]:
    return list(_RECORDS)


def dump_json(path: str) -> None:
    """Write every record emitted since reset_records() as JSON."""
    with open(path, "w") as f:
        json.dump(_RECORDS, f, indent=1)
    print(f"# wrote {len(_RECORDS)} records to {path}")


def time_fn(fn: Callable, *args, reps: int = 20, warmup: int = 3) -> float:
    """Min wall time in seconds of fn(*args) (jax outputs block) — the
    paper's §5.2 protocol via ``repro.obs.time_min_of_n``."""
    _PROTOCOL.update(reps=reps, warmup=warmup)
    return time_min_of_n(fn, *args, reps=reps, warmup=warmup).best_s


def time_host(fn: Callable, *args, reps: int = 5) -> float:
    _PROTOCOL.update(reps=reps, warmup=0)
    return time_min_of_n(fn, *args, reps=reps, warmup=0,
                         block=False).best_s


class Csv:
    def __init__(self, title: str):
        self.title = title
        self.rows: List[str] = []
        print(f"# === {title} ===")
        print("name,us_per_call,derived")

    def row(self, name: str, seconds: float, derived: str = ""):
        line = f"{name},{seconds * 1e6:.1f},{derived}"
        self.rows.append(line)
        rec = {"section": self.title, "name": name,
               "us_per_call": seconds * 1e6, "derived": derived,
               "backend": jax.default_backend()}
        if seconds > 0 and _PROTOCOL:
            rec.update(_PROTOCOL)
        _RECORDS.append(rec)
        print(line)
