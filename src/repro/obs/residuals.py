"""The observed-vs-modeled residual ledger — closing the roofline loop.

Every selector and autotune decision in this repo is scored by the
``repro.roofline`` traffic model (``spmm_distributed_time``), and until
now nothing ever checked the model against a measurement. The ledger is
that check: each entry pairs one *measured* timing (a serve flush, a
sweep row) with the model's prediction for the same
``core.selector.DistributedChoice`` knobs and stores

    residual = observed_s / modeled_s

so ``residual == 1`` means the model nailed it, ``> 1`` means reality is
slower than the streaming-bytes story (launch overhead, gather on the
critical path, allocator noise), ``< 1`` means the model over-prices
(overlap the model does not credit). The paper's own min-of-550 timing
discipline (§5.2) exists because SpMV is memory-bound and measured time
routinely diverges from predicted bytes — the ledger makes that
divergence a first-class, queryable quantity.

Consumers:

* ``core.autotune(feedback=ledger)`` rescales each grid candidate's
  modeled score by ``ledger.correction(**choice_labels(...))`` — the
  geometric mean of matching residuals — turning repeated tune calls
  into an online feedback loop (``TuneResult.residual``).
* ``benchmarks.smoke_check`` gates dumped residuals: finite, > 0, and
  flagged when the model is off by more than 10x on a backend where the
  model claims to apply.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple


def choice_labels(schedule: Optional[str] = None,
                  num_chunks: Optional[int] = None,
                  mesh_shape: Optional[Tuple[int, int]] = None,
                  compact_x: Optional[bool] = None,
                  gather: Optional[str] = None,
                  **extra) -> Dict[str, str]:
    """Canonical label dict for a ``DistributedChoice``-shaped config, so
    the serve path (which *records*) and autotune (which *queries*) key
    residuals identically: ``schedule``, ``num_chunks``, ``mesh``
    (``"PdxPm"``), ``compact_x`` (``"on"``/``"off"``), ``gather``
    (``"upfront"``/``"overlap"``/``"fused"``), plus any extras (matrix
    name, k, backend)."""
    labels: Dict[str, str] = {}
    if schedule is not None:
        labels["schedule"] = str(schedule)
    if num_chunks is not None:
        labels["num_chunks"] = str(int(num_chunks))
    if mesh_shape is not None:
        labels["mesh"] = f"{int(mesh_shape[0])}x{int(mesh_shape[1])}"
    if compact_x is not None:
        labels["compact_x"] = "on" if compact_x else "off"
    if gather is not None:
        labels["gather"] = str(gather)
    for k, v in extra.items():
        labels[str(k)] = str(v)
    return labels


@dataclasses.dataclass(frozen=True)
class ResidualRecord:
    """One measured-vs-modeled pairing. ``residual`` is always exactly
    ``observed_s / modeled_s`` (asserted in the tests)."""
    name: str
    observed_s: float
    modeled_s: float
    residual: float
    labels: Tuple[Tuple[str, str], ...] = ()

    def label_dict(self) -> Dict[str, str]:
        return dict(self.labels)


class ResidualLedger:
    """Append-only store of :class:`ResidualRecord` with label-matched
    correction queries."""

    def __init__(self):
        self._records: List[ResidualRecord] = []

    def __len__(self) -> int:
        return len(self._records)

    def record(self, name: str, observed_s: float, modeled_s: float,
               **labels) -> ResidualRecord:
        """Pair one measurement with its model prediction. Both sides
        must be finite and > 0 — a zero or NaN on either side means the
        caller measured (or modeled) nothing, and storing it would poison
        every correction query downstream."""
        obs_s = float(observed_s)
        mod_s = float(modeled_s)
        if not (math.isfinite(obs_s) and obs_s > 0):
            raise ValueError(f"observed_s must be finite and > 0, got "
                             f"{observed_s!r}")
        if not (math.isfinite(mod_s) and mod_s > 0):
            raise ValueError(f"modeled_s must be finite and > 0, got "
                             f"{modeled_s!r}")
        rec = ResidualRecord(
            name, obs_s, mod_s, obs_s / mod_s,
            tuple(sorted((str(k), str(v)) for k, v in labels.items())))
        self._records.append(rec)
        return rec

    def records(self) -> List[ResidualRecord]:
        return list(self._records)

    def _matching(self, query: Dict[str, str]) -> List[ResidualRecord]:
        out = []
        for rec in self._records:
            lab = rec.label_dict()
            if all(lab.get(k, v) == v for k, v in query.items()):
                out.append(rec)
        return out

    def correction(self, default: float = 1.0, **labels) -> float:
        """Geometric-mean residual over records matching ``labels``.

        A record matches when every queried key it *carries* agrees;
        keys the record never stored are wildcards (a record labelled
        only ``schedule=merge`` corrects every merge candidate). With no
        matching record the query returns ``default`` — no evidence, no
        correction. The geometric mean is the right average for a
        multiplicative correction factor: corrections of 2x and 0.5x
        cancel to exactly 1."""
        query = {str(k): str(v) for k, v in labels.items()}
        matches = self._matching(query)
        if not matches:
            return float(default)
        log_sum = sum(math.log(r.residual) for r in matches)
        return math.exp(log_sum / len(matches))

    def as_dicts(self) -> List[dict]:
        return [{"name": r.name, "observed_s": r.observed_s,
                 "modeled_s": r.modeled_s, "residual": r.residual,
                 "labels": r.label_dict()} for r in self._records]
