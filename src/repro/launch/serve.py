"""Serving entry points.

LM mode — batched prefill + greedy decode with KV caches (CPU-scale demo,
reduced config, real execution):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --batch 4 --prompt-len 32 --gen 16

SpMV mode — the repro.spmm request batcher serving single-vector requests:
queued ``A @ x`` requests aggregate into one SpMM per flush (matrix stream
amortized over the batch), measured against serving them one by one:
  PYTHONPATH=src python -m repro.launch.serve --mode spmv \
      --matrix mawi_like --requests 64 --max-batch 32

Mesh serving — ``--devices P`` answers each flush with a *distributed*
SpMM over a P-device mesh (``repro.spmm.distributed``); format,
cross-device schedule and the merge-psum pipelining depth come from the
``core.select_distributed`` grid (``--chunks c`` pins the depth).
``--mesh Pd,Pm`` pins a 2-D (data, model) factorization instead: the model
axis column-shards the X/Y k-slabs so per-device psum and replicated-X
bytes drop by Pm — the k ≫ 128 scaling axis. ``--compact-x on`` partitions
with per-shard column compaction (each data shard gathers only the X rows
its nonzeros touch instead of reading the replicated slab; ``auto`` asks
the traffic model whether the gather pays). On CPU, force host-platform
devices first:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --mode spmv --matrix mawi_like \
      --requests 64 --max-batch 32 --mesh 4,2 --impl ref --chunks 4

Observability — ``--metrics out.json`` installs a ``repro.obs`` registry
for the run and dumps it at the end: per-flush phase spans (the
``batcher/*`` series plus, on a mesh, an eager phase-profile pass through
``spmm/gather_x`` / ``spmm/mesh`` / ``spmm/kernel`` / ``spmm/psum`` /
``spmm/fixup``), p50/p95/p99 flush latency (``serve/flush_s``, exact
order statistics at serve batch counts), and one ``ResidualLedger``
record per flush pairing the measured wall time with the roofline
prediction (``spmm_distributed_time``) for the chosen
``DistributedChoice`` — the observed-vs-modeled residuals that feed
``core.autotune(feedback=)``. Headline timings follow the paper's §5.2
min-of-N protocol (``--reps``), never a single ``perf_counter`` pair.
"""
from __future__ import annotations

import argparse
import time
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models.model import decode_step, init_params, prefill


def _pick_chunk(m: int, num_devices: int, default: int = 128) -> int:
    """Largest power-of-two slice height <= default that still gives every
    device at least one slice to own (small demo matrices on big meshes)."""
    c = default
    while c > 8 and -(-m // c) < num_devices:
        c //= 2
    return c


class _DistPlan(NamedTuple):
    """Everything the --devices / --mesh serve path needs to know about
    the distributed multiply it chose."""
    matrix: object               # the SELL-C-σ stream (pre-partition)
    spmm_fn: Callable            # jitted (matrix, X) -> Y flush closure
    eager_fn: Callable           # un-jitted X -> Y — the phase-profile
                                 #   pass --metrics runs (spans time real
                                 #   eager execution, not tracing)
    label: str
    schedule: str
    chunks: int
    mesh_shape: Tuple[int, int]
    compact: bool
    n_touched: Optional[float]
    modeled_s: float             # roofline seconds per k=max_batch flush
                                 #   for exactly these knobs


def _make_distributed_spmm(coo, stats, args, mesh_shape) -> "_DistPlan":
    """Build the :class:`_DistPlan` for the --devices / --mesh path.
    ``mesh_shape`` is a (P_data, P_model) factorization, or None to let
    the traffic model keep the 1-D mesh (the --devices behavior)."""
    from repro.core.selector import (_matrix_bytes_est,
                                     distributed_schedule_grid)
    from repro.launch.mesh import make_spmm_mesh
    from repro.roofline import spmm_distributed_time
    from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                            partition_sellcs_rows, spmm_merge_distributed,
                            spmm_row_distributed)

    total = args.devices
    ndev = len(jax.devices())
    if ndev < total:
        raise SystemExit(
            f"the mesh needs {total} devices but jax sees only {ndev}; on "
            "CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{total} before launching")
    if args.algorithm and args.algorithm != "sellcs":
        raise SystemExit(
            f"--algorithm {args.algorithm} cannot be served on a mesh: the "
            "--devices path multiplies the SELL-C-σ slice stream "
            "(repro.spmm.distributed); drop --algorithm or pass sellcs")
    # the executable mesh format is the SELL-C-σ slice stream, so score the
    # (schedule × mesh × chunks) grid with sellcs's own byte footprint
    # (conversion cost is shared by every candidate, so it drops out);
    # --chunks pins the merge psum pipelining depth and --mesh the
    # (P_data, P_model) factorization instead of modelling them
    sellcs_bytes = _matrix_bytes_est("sellcs", stats)
    grid = distributed_schedule_grid(
        total, pinned_chunks=args.chunks if args.chunks > 0 else None,
        pinned_mesh=mesh_shape or (total, 1))
    # --compact-x on/off pins the sparsity-aware X gather; auto lets the
    # traffic model decide (off is scored first, so a modelled tie —
    # near-dense columns — refuses the gather)
    compacts = {"auto": (False, True), "on": (True,),
                "off": (False,)}[args.compact_x]
    (schedule, chunks, mesh_shape, compact) = min(
        ((t[0], t[1], t[2], cf) for t in grid for cf in compacts),
        key=lambda q: spmm_distributed_time(
            stats.m, stats.n, args.max_batch, q[2][0], q[0],
            matrix_bytes=sellcs_bytes, max_row_nnz=stats.max_row_nnz,
            num_chunks=q[1], model_devices=q[2][1], compact_x=q[3],
            nnz=stats.nnz))
    pd, pm = mesh_shape
    mesh = make_spmm_mesh(mesh_shape)
    sc = coo_to_sellcs(coo, c=_pick_chunk(stats.m, pd))
    impl = "ref" if args.impl == "auto" and \
        jax.default_backend() != "tpu" else args.impl
    if impl == "auto":
        impl = "pallas"
    mesh_tag = f"{pd}x{pm}mesh" if pm > 1 else f"{pd}dev"
    cx_tag = "/cx=on" if compact else ""
    if schedule == "row":
        sharded = partition_sellcs_rows(sc, pd, compact_x=compact)
        eager = lambda X: spmm_row_distributed(sharded, X, mesh, impl=impl)
        label = f"sellcs+row@{mesh_tag}{cx_tag}"
    else:
        # the span plan is baked at partition time; the multiply reuses it
        sharded = partition_sellcs_nnz(sc, pd, num_chunks=chunks,
                                       compact_x=compact)
        eager = lambda X: spmm_merge_distributed(sharded, X, mesh,
                                                 impl=impl,
                                                 num_chunks=chunks)
        label = f"sellcs+merge@{mesh_tag}/chunks={chunks}{cx_tag}"
    jitted = jax.jit(eager)
    # the jitted closure keeps repeated flushes of one batch shape from
    # retracing the shard_map body.
    # price the gather with the map the multiply EXECUTES: the chunked
    # merge gathers through the chunk plan's re-dealt map, not the base
    # partition's (the re-deal hands every device rows of every span, so
    # the two touched sets differ)
    n_touched = None
    if compact:
        nt_src = (sharded.chunk_plan[3]
                  if sharded.chunk_plan is not None else sharded.n_touched)
        n_touched = float(np.mean(np.asarray(nt_src)))
    modeled_s = spmm_distributed_time(
        stats.m, stats.n, args.max_batch, pd, schedule,
        matrix_bytes=sellcs_bytes, max_row_nnz=stats.max_row_nnz,
        num_chunks=chunks, model_devices=pm, compact_x=compact,
        n_touched=n_touched, nnz=stats.nnz)

    def spmm_fn(_mat, X):
        return jitted(X)
    return _DistPlan(sc, spmm_fn, eager, label, schedule, chunks,
                     mesh_shape, compact, n_touched, modeled_s)


def _metrics_pass(reg, mat, xs, args, spmm_fn, plan, stats, algo):
    """The --metrics measurement pass: per-flush wall times into the
    ``serve/flush_s`` histogram and one :class:`~repro.obs.ResidualRecord`
    per flush pairing the measured latency with the roofline prediction
    for the served knobs — the observed side of the selector's model."""
    from repro.obs import choice_labels
    from repro.roofline import spmm_distributed_time
    from repro.spmm import RequestBatcher
    from repro.core.selector import _matrix_bytes_est

    batcher = RequestBatcher(mat, max_batch=args.max_batch, impl=args.impl,
                             spmm_fn=spmm_fn)
    for x in xs:
        batcher.submit(x)
    flush_h = reg.histogram("serve/flush_s")
    labels = choice_labels(
        schedule=plan.schedule if plan else "single",
        num_chunks=plan.chunks if plan else 1,
        mesh_shape=plan.mesh_shape if plan else (1, 1),
        compact_x=plan.compact if plan else None,
        matrix=args.matrix, algo=algo, backend=jax.default_backend())
    while batcher.pending:
        k = min(batcher.pending, args.max_batch)
        t0 = time.perf_counter()
        out = batcher.flush()
        jax.block_until_ready(list(out.values()))
        dt = time.perf_counter() - t0
        flush_h.observe(dt)
        if plan is not None:
            modeled = plan.modeled_s if k == args.max_batch else \
                spmm_distributed_time(
                    stats.m, stats.n, k, plan.mesh_shape[0], plan.schedule,
                    matrix_bytes=_matrix_bytes_est("sellcs", stats),
                    max_row_nnz=stats.max_row_nnz, num_chunks=plan.chunks,
                    model_devices=plan.mesh_shape[1],
                    compact_x=plan.compact, n_touched=plan.n_touched,
                    nnz=stats.nnz)
        else:
            # single device: the distributed model at P=1 degenerates to
            # the plain streaming-bytes roofline for this format
            modeled = spmm_distributed_time(
                stats.m, stats.n, k, 1, "row",
                matrix_bytes=_matrix_bytes_est(algo, stats),
                max_row_nnz=stats.max_row_nnz, nnz=stats.nnz)
        reg.ledger.record("serve/flush", dt, modeled, k=k, **labels)


def _print_metrics_summary(reg):
    flush = reg.histogram("serve/flush_s")
    if flush.count:
        p = flush.percentiles()
        print(f"[serve-spmv] flush latency over {flush.count} flushes: "
              f"p50 {p['p50']*1e3:.2f} ms, p95 {p['p95']*1e3:.2f} ms, "
              f"p99 {p['p99']*1e3:.2f} ms"
              f"{' (exact)' if flush.exact else ''}")
    phases = [h for h in reg.histograms()
              if h.count and (h.name.startswith("spmm/")
                              or h.name.startswith("batcher/"))]
    for h in sorted(phases, key=lambda h: h.name):
        print(f"[serve-spmv]   phase {h.name:<24} n={h.count:<4} "
              f"mean {h.mean*1e3:8.3f} ms  p95 "
              f"{h.quantile(0.95)*1e3:8.3f} ms")
    ledger = reg.ledger
    if len(ledger):
        corr = ledger.correction()
        print(f"[serve-spmv] residual (observed/modeled) over "
              f"{len(ledger)} flushes: geomean {corr:.3g} — the factor "
              "autotune(feedback=) will apply to this config's score")


def serve_spmv(args):
    """Sparse serving demo: batched (one SpMM per flush) vs sequential,
    optionally over a --devices mesh. Headline numbers use the paper's
    §5.2 min-of-N discipline; ``--metrics`` additionally records phase
    spans, flush-latency percentiles and observed-vs-modeled residuals,
    then dumps them as one ``repro.obs/v1`` JSON document."""
    from repro import obs
    from repro.core import MachineSpec, convert, matrix_stats, select, spmv
    from repro.data import matrices
    from repro.roofline import spmm_arithmetic_intensity
    from repro.spmm import RequestBatcher

    suite = matrices.test_suite(scale=args.scale)
    if args.matrix not in suite:
        raise SystemExit(f"--matrix must be one of {sorted(suite)}")
    coo = matrices.as_coo(suite[args.matrix].make())
    stats = matrix_stats(coo)
    # num_spmvs counts k-RHS multiplies: batching turns `requests` SpMVs
    # into ceil(requests / max_batch) SpMM calls
    num_spmms = -(-args.requests // args.max_batch)
    spmm_fn = None
    plan = None
    mesh_shape = None
    if args.mesh:
        from repro.launch.mesh import parse_mesh_shape
        mesh_shape = parse_mesh_shape(args.mesh)
        args.devices = mesh_shape[0] * mesh_shape[1]
    if args.devices > 1:
        plan = _make_distributed_spmm(coo, stats, args, mesh_shape)
        mat, spmm_fn, algo = plan.matrix, plan.spmm_fn, plan.label
        mesh_shape = plan.mesh_shape
    else:
        algo = args.algorithm or select(stats, MachineSpec(1),
                                        num_spmvs=num_spmms,
                                        k=args.max_batch)
        mat = convert(coo, algo)
    print(f"[serve-spmv] matrix={args.matrix} m={stats.m} n={stats.n} "
          f"nnz={stats.nnz} algo={algo} max_batch={args.max_batch}")

    rng = np.random.default_rng(args.seed)
    xs = [jnp.asarray(rng.standard_normal(stats.n).astype(np.float32))
          for _ in range(args.requests)]

    reg = None
    if args.metrics:
        reg = obs.install(obs.MetricRegistry(
            backend=jax.default_backend(), mode="spmv",
            matrix=args.matrix, algo=algo, devices=args.devices,
            max_batch=args.max_batch))

    # headline timing, the paper's §5.2 way: min over --reps runs after a
    # warmup/compile run — never a single first-flush perf_counter pair
    def batched_run():
        b = RequestBatcher(mat, max_batch=args.max_batch, impl=args.impl,
                           spmm_fn=spmm_fn)
        rids = [b.submit(x) for x in xs]
        return b.drain(), rids, b.flushes

    t_b = obs.time_min_of_n(batched_run, reps=args.reps, warmup=1)
    out, rids, num_flushes = t_b.last_result
    t_batched = t_b.best_s

    t_s = obs.time_min_of_n(
        lambda: [spmv(mat, x, impl=args.impl) for x in xs],
        reps=args.reps, warmup=1)
    seq, t_seq = t_s.last_result, t_s.best_s

    for rid, y in zip(rids, seq):
        np.testing.assert_allclose(np.asarray(out[rid]), np.asarray(y),
                                   rtol=2e-4, atol=2e-4)
    ai1 = spmm_arithmetic_intensity(stats.nnz, stats.m, stats.n, 1)
    aik = spmm_arithmetic_intensity(stats.nnz, stats.m, stats.n,
                                    args.max_batch)
    print(f"[serve-spmv] batched {t_batched*1e3:.1f} ms "
          f"({num_flushes} SpMM calls) vs sequential "
          f"{t_seq*1e3:.1f} ms ({len(xs)} SpMV calls) — "
          f"speedup {t_seq/max(t_batched, 1e-9):.2f}x "
          f"(min of {t_b.reps}, warmup {t_b.warmup})")
    print(f"[serve-spmv] modelled intensity {ai1:.3f} -> {aik:.3f} "
          f"flop/byte at k={args.max_batch}")
    if plan is not None:
        from repro.roofline import (spmm_distributed_collective_s,
                                    spmm_distributed_traffic)
        sched, chunks = plan.schedule, plan.chunks
        compact, n_touched = plan.compact, plan.n_touched
        pd, pm = mesh_shape
        hbm, coll = spmm_distributed_traffic(
            stats.m, stats.n, args.max_batch, pd, sched,
            nnz=stats.nnz, max_row_nnz=stats.max_row_nnz, model_devices=pm,
            compact_x=compact, n_touched=n_touched)
        print(f"[serve-spmv] modelled per-device traffic: {hbm / 1e6:.2f} MB "
              f"HBM + {coll / 1e6:.2f} MB collective per flush "
              f"(mesh=({pd},{pm}), schedule={sched}, chunks={chunks}, "
              f"compact_x={'on' if compact else 'off'})")
        if compact:
            hbm_rep, _ = spmm_distributed_traffic(
                stats.m, stats.n, args.max_batch, pd, sched,
                nnz=stats.nnz, max_row_nnz=stats.max_row_nnz,
                model_devices=pm)
            print(f"[serve-spmv] compact gather: mean n_touched "
                  f"{n_touched:.0f} of n={stats.n} rows per shard — "
                  f"{(hbm_rep - hbm) / 1e6:.2f} MB HBM saved vs "
                  "replicated X per flush")
        if sched == "merge":
            mono, over = (spmm_distributed_collective_s(
                stats.m, stats.n, args.max_batch, pd, sched,
                nnz=stats.nnz, max_row_nnz=stats.max_row_nnz, num_chunks=c,
                model_devices=pm)
                for c in (1, chunks))
            print(f"[serve-spmv] exposed collective_s: {mono * 1e6:.2f} us "
                  f"monolithic -> {over * 1e6:.2f} us with {chunks} "
                  "chunk(s) pipelined under the slice stream")

    if reg is not None:
        # the measured side: per-flush latencies + residual ledger records
        # against the roofline prediction for the served knobs
        _metrics_pass(reg, mat, xs, args, spmm_fn, plan, stats, algo)
        if plan is not None:
            # one eager pass so the spmm/* phase spans time real execution
            # (inside the jitted flush they only see tracing)
            with obs.span("serve/eager_profile"):
                jax.block_until_ready(plan.eager_fn(
                    jnp.stack([x for x in xs[:args.max_batch]], axis=1)))
        _print_metrics_summary(reg)
        reg.dump(args.metrics)
        print(f"[serve-spmv] metrics -> {args.metrics}")
        obs.uninstall()
    return t_batched, t_seq


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "spmv"), default="lm")
    ap.add_argument("--arch")
    # spmv-mode arguments (repro.spmm request batching)
    ap.add_argument("--matrix", default="mawi_like")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--algorithm", default=None,
                    help="force a format (default: core.select with k)")
    ap.add_argument("--devices", type=int, default=1,
                    help="serve each flush with a distributed SpMM over a "
                         "1-D data mesh of this many devices (schedule "
                         "chosen by core.select_distributed)")
    ap.add_argument("--mesh", default=None, metavar="Pd,Pm",
                    help="pin a 2-D (data, model) mesh factorization for "
                         "the distributed SpMM, e.g. 4,2 — the model axis "
                         "column-shards the X/Y k-slabs so per-device psum "
                         "and replicated-X bytes drop by Pm (overrides "
                         "--devices with Pd*Pm)")
    ap.add_argument("--chunks", type=int, default=0,
                    help="pipeline the merge-schedule psum into this many "
                         "chunks (0 = pick by the roofline overlap model; "
                         "ignored by the row schedule)")
    ap.add_argument("--compact-x", default="auto",
                    choices=("auto", "on", "off"), dest="compact_x",
                    help="sparsity-aware X gather for the distributed SpMM:"
                         " partition with per-shard column compaction so "
                         "each data shard gathers only the X rows its "
                         "nonzeros touch (auto = let the traffic model "
                         "decide when the gather beats replication)")
    ap.add_argument("--impl", default="auto",
                    choices=("auto", "ref", "pallas", "pallas_interpret"))
    ap.add_argument("--metrics", default=None, metavar="OUT.json",
                    help="install a repro.obs registry for the run and dump "
                         "it here: phase spans, p50/p95/p99 flush latency, "
                         "and observed-vs-modeled residuals (repro.obs/v1)")
    ap.add_argument("--reps", type=int, default=5,
                    help="min-of-N repetitions for the headline batched-vs-"
                         "sequential timing (the paper's §5.2 protocol)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.mode == "spmv":
        return serve_spmv(args)
    if not args.arch:
        ap.error("--arch is required in lm mode")

    cfg = get_config(args.arch, reduced=args.reduced)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    S_max = P + G + (cfg.vision_tokens if cfg.frontend == "vision" else 0)
    rng = jax.random.PRNGKey(args.seed + 1)
    prompts = jax.random.randint(rng, (B, P), 0, cfg.vocab)
    vis = None
    if cfg.frontend == "vision":
        vis = jax.random.normal(rng, (B, cfg.vision_tokens, cfg.vision_dim))

    prefill_fn = jax.jit(lambda p, t, v: prefill(
        p, cfg, t, S_max, cache_dtype=jnp.float32, vision_embeds=v))
    decode_fn = jax.jit(lambda p, tok, c, pos: decode_step(
        p, cfg, tok, c, pos))

    t0 = time.perf_counter()
    logits, caches = prefill_fn(params, prompts, vis)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    offset = cfg.vision_tokens if cfg.frontend == "vision" else 0
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(G - 1):
        pos = jnp.full((B,), offset + P + i, jnp.int32)
        logits, caches = decode_fn(params, tok, caches, pos)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    tok.block_until_ready()
    t_decode = time.perf_counter() - t0

    gen = np.asarray(jnp.concatenate(out_tokens, axis=1))
    tps = B * (G - 1) / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} batch={B} prompt={P} gen={G}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; decode "
          f"{t_decode*1e3:.1f} ms ({tps:.1f} tok/s incl. compile)")
    print(f"[serve] sample generations (first 2 rows): {gen[:2].tolist()}")
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab)
    return gen


if __name__ == "__main__":
    main()
