"""SELL-C-σ storage (sliced ELLPACK with σ-window row sorting).

The paper's row-distributed algorithms break on row-length skew (the mawi
pathology, Table 6.3); the survey literature's standard fix is SELL-C-σ
[Kreutzer et al.; Gao et al., arXiv:2404.06047 §4]: group rows into slices
of height C, pad each slice only to *its own* longest row, and sort rows by
length inside windows of σ rows so that similar-length rows share a slice —
padding collapses and every slice is a uniform work quantum.

TPU mapping: C defaults to the Pallas lane width (128) so one width-step of
a slice is one (C,)-lane vector: the SpMM kernel broadcasts it against a
(C, k) block of X and accumulates into C output rows — VPU work with no
scatter. Row sorting is a *permutation*, recorded in ``row_perm`` and undone
by a single scatter at the end of the multiply.

Layout (width-major, slice-concatenated):

  ``data[w, l]`` / ``cols[w, l]`` — the ``j``-th nonzero of the row in lane
  ``l`` of slice ``slice_of[w]``, where ``j = w - slice_ptr[slice_of[w]]``.
  Padding entries carry ``data == 0`` and ``cols == 0`` (harmless FMA).

Symmetric one-triangle mode (``structure="symmetric"``): for ``A == A^T``
only the lower triangle (``row >= col``, diagonal included) enters the
slice stream, halving the streamed bytes of the memory-bound multiply. A
dense ``diag`` vector rides along so the multiply can combine the normal
and transpose passes over the one stored triangle:
``A X = N-pass(X) + T-pass(X) - diag * X`` (the diagonal is counted by
both passes, so it is subtracted once). ``to_coo`` mirrors the
off-diagonal entries back out, so the round trip is dense-equivalent to
the full matrix and every oracle keeps working unchanged.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import COO, static_field, _pytree_dataclass

Array = jax.Array

DEFAULT_C = 128          # Pallas lane width
DEFAULT_SIGMA_SLICES = 16   # default σ = 16 slices' worth of rows


@_pytree_dataclass
class SellCS:
    """SELL-C-σ matrix as a JAX pytree (see module docstring for layout)."""
    data: Array            # f32[W, C] — padded values, width-major
    cols: Array            # int32[W, C] — padded column indices
    slice_ptr: Array       # int32[S+1] — width offset of each slice
    slice_of: Array        # int32[W] — owning slice of each width-row
    row_perm: Array        # int32[S*C] — permuted slot -> original row
                           #   (padding slots point at m, dropped on scatter)
    row_len: Array         # int32[S*C] — true nnz of each permuted slot
    diag: Optional[Array]  # f32[m] dense diagonal (symmetric mode), else None
    shape: Tuple[int, int] = static_field()
    chunk: int = static_field()          # C — slice height
    sigma: int = static_field()          # σ — sorting window (rows)
    nnz: int = static_field()            # stored nonzeros before padding
                                         #   (one triangle in symmetric mode)
    structure: str = static_field(default="general")   # "general"|"symmetric"

    @property
    def num_slices(self) -> int:
        return self.slice_ptr.shape[0] - 1

    @property
    def padded_nnz(self) -> int:
        return int(self.data.shape[0]) * self.chunk

    @property
    def fill_ratio(self) -> float:
        """nnz / padded entries — 1.0 means σ-sorting removed all padding."""
        p = self.padded_nnz
        return self.nnz / p if p else 0.0

    def storage_bytes(self) -> int:
        """Faithful SELL-C-σ cost: every int32/value array the format
        actually stores — padded values + padded column indices + slice
        pointers + per-width-row slice ids + the row permutation + per-slot
        true row lengths. Kept equal to the sum of the member arrays'
        ``nbytes`` (asserted in the tests) so conversion-amortization
        comparisons never flatter this format."""
        W = self.data.shape[0]
        b = int(W * self.chunk * (self.data.dtype.itemsize + 4)
                + self.slice_ptr.shape[0] * 4
                + self.slice_of.shape[0] * 4
                + self.row_perm.shape[0] * 4
                + self.row_len.shape[0] * 4)
        if self.diag is not None:
            b += int(self.diag.shape[0] * self.diag.dtype.itemsize)
        return b

    def to_coo(self) -> COO:
        """Exact round-trip (host-side), including explicit zeros. In
        symmetric mode the stored lower triangle is mirrored back out, so
        the result is dense-equivalent to the full matrix."""
        m, n = self.shape
        C = self.chunk
        data = np.asarray(self.data)
        cols = np.asarray(self.cols)
        slice_ptr = np.asarray(self.slice_ptr, np.int64)
        slice_of = np.asarray(self.slice_of, np.int64)
        row_perm = np.asarray(self.row_perm, np.int64)
        row_len = np.asarray(self.row_len, np.int64)
        W = data.shape[0]
        if W == 0 or self.nnz == 0:
            z = jnp.zeros((0,), jnp.int32)
            return COO(z, z, jnp.zeros((0,), self.data.dtype), self.shape)
        j = np.arange(W, dtype=np.int64) - slice_ptr[slice_of]      # [W]
        slot = slice_of[:, None] * C + np.arange(C, dtype=np.int64)  # [W, C]
        valid = j[:, None] < row_len[slot]
        rows = row_perm[slot][valid]
        vals = data[valid]
        ccols = cols[valid].astype(np.int64)
        if self.structure == "symmetric":
            off = rows != ccols                  # strict lower triangle
            rows, ccols = (np.concatenate([rows, ccols[off]]),
                           np.concatenate([ccols, rows[off]]))
            vals = np.concatenate([vals, vals[off]])
        return COO(jnp.asarray(rows.astype(np.int32)),
                   jnp.asarray(ccols.astype(np.int32)),
                   jnp.asarray(vals), self.shape)


def _dedup_sums(keys: np.ndarray, vals: np.ndarray):
    """Coordinate-summed (key, value) pairs in sorted key order."""
    order = np.argsort(keys, kind="stable")
    k, v = keys[order], vals[order].astype(np.float64)
    uk, start = np.unique(k, return_index=True)
    return uk, np.add.reduceat(v, start) if v.size else v


def _symmetric_lower(coo: COO):
    """Validate ``A == A^T`` (pattern and values, after summing duplicate
    coordinates) and return the lower-triangle stream + dense diagonal.
    Raises ``ValueError`` on a non-square or asymmetric input."""
    m, n = coo.shape
    if m != n:
        raise ValueError(
            f"structure='symmetric' needs a square matrix, got {m}x{n}")
    rows = np.asarray(coo.rows, np.int64)
    cols = np.asarray(coo.cols, np.int64)
    vals = np.asarray(coo.data)
    ka, va = _dedup_sums(rows * n + cols, vals)
    kb, vb = _dedup_sums(cols * n + rows, vals)
    # pattern must match exactly; summed duplicate values only to fp-sum
    # reassociation tolerance (the two sides add duplicates in different
    # orders)
    scale = float(np.abs(va).max()) if va.size else 1.0
    if ka.shape != kb.shape or not np.array_equal(ka, kb) \
            or not np.allclose(va, vb, rtol=1e-6, atol=1e-9 * max(scale, 1.0)):
        raise ValueError(
            "structure='symmetric' requires A == A^T (pattern and values); "
            "store the full matrix with structure='general' instead")
    keep = rows >= cols                       # one triangle, diagonal kept
    dtype = np.float32 if vals.size == 0 else vals.dtype
    diag = np.zeros(m, dtype)
    on_d = rows == cols
    np.add.at(diag, rows[on_d], vals[on_d])
    return rows[keep], cols[keep], vals[keep], diag


def coo_to_sellcs(coo: COO, *, c: int = DEFAULT_C,
                  sigma: Optional[int] = None,
                  structure: str = "general") -> SellCS:
    """Convert COO -> SELL-C-σ (host-side, like every conversion here).

    ``sigma`` is the row-sorting window in rows; it is rounded up to a
    multiple of ``c``. ``sigma=None`` uses ``DEFAULT_SIGMA_SLICES * c``;
    ``sigma >= m`` gives a single global sort (maximal padding reduction,
    maximal permutation scatter); ``sigma = c`` sorts only within slices.

    ``structure="symmetric"`` stores one triangle (``row >= col``) plus a
    dense diagonal; the input must satisfy ``A == A^T`` exactly (pattern
    and values) or a ``ValueError`` is raised.
    """
    m, n = coo.shape
    if c < 1:
        raise ValueError(f"slice height C must be >= 1, got {c}")
    if structure not in ("general", "symmetric"):
        raise ValueError(f"structure must be 'general' or 'symmetric', "
                         f"got {structure!r}")
    if sigma is None:
        sigma = DEFAULT_SIGMA_SLICES * c
    sigma = max(-(-sigma // c) * c, c)

    diag = None
    if structure == "symmetric":
        rows, cols, vals, diag = _symmetric_lower(coo)
    else:
        rows = np.asarray(coo.rows, np.int64)
        cols = np.asarray(coo.cols, np.int64)
        vals = np.asarray(coo.data)

    row_len_orig = (np.bincount(rows, minlength=m).astype(np.int64)
                    if m else np.zeros(0, np.int64))
    # σ-window sort: rows ordered by (window, -length, row) — stable, so
    # equal-length rows keep their relative order (reproducible).
    ridx = np.arange(m, dtype=np.int64)
    window = ridx // sigma
    order = np.lexsort((ridx, -row_len_orig, window))   # perm pos -> row

    S = max(-(-m // c), 1)
    slots = S * c
    row_perm = np.full(slots, m, np.int64)
    row_perm[:m] = order
    row_len = np.zeros(slots, np.int64)
    row_len[:m] = row_len_orig[order]

    widths = row_len.reshape(S, c).max(axis=1)          # per-slice width
    slice_ptr = np.zeros(S + 1, np.int64)
    np.cumsum(widths, out=slice_ptr[1:])
    W = int(slice_ptr[-1])
    slice_of = np.repeat(np.arange(S, dtype=np.int64), widths)

    data = np.zeros((W, c), np.float32 if vals.size == 0 else vals.dtype)
    col_arr = np.zeros((W, c), np.int64)
    if rows.size:
        inv = np.empty(m, np.int64)
        inv[order] = np.arange(m)
        p = inv[rows]                                   # permuted position
        sort2 = np.lexsort((cols, p))
        p, cc, vv = p[sort2], cols[sort2], vals[sort2]
        row_start = np.zeros(slots + 1, np.int64)
        np.cumsum(row_len, out=row_start[1:])
        j = np.arange(p.size, dtype=np.int64) - row_start[p]
        wrow = slice_ptr[p // c] + j
        lane = p % c
        data[wrow, lane] = vv
        col_arr[wrow, lane] = cc

    return SellCS(
        data=jnp.asarray(data),
        cols=jnp.asarray(col_arr.astype(np.int32)),
        slice_ptr=jnp.asarray(slice_ptr.astype(np.int32)),
        slice_of=jnp.asarray(slice_of.astype(np.int32)),
        row_perm=jnp.asarray(row_perm.astype(np.int32)),
        row_len=jnp.asarray(row_len.astype(np.int32)),
        diag=None if diag is None else jnp.asarray(diag),
        shape=coo.shape, chunk=int(c), sigma=int(sigma),
        nnz=int(rows.size), structure=structure)
