"""Deterministic, shardable synthetic token pipeline.

Design requirements at scale (DESIGN §5):
  * step-keyed determinism — batch(step) is a pure function of (seed, step),
    so restart-after-failure replays identical data with no state to
    checkpoint beyond the step counter;
  * host-sharded loading — each data-parallel host materializes only its
    slice (``dp_rank``/``dp_size``), never the global batch;
  * background prefetch — a depth-2 thread queue overlaps host generation
    with device compute.

The token distribution is a Zipf mixture with Markov bigram structure so the
CE loss is learnable (used by the fault-tolerance tests to check bit-exact
resume and by examples/train_lm.py to show loss going down).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    batch: int                 # GLOBAL batch
    seq: int
    seed: int = 0
    dp_rank: int = 0
    dp_size: int = 1
    zipf_a: float = 1.3

    @property
    def local_batch(self) -> int:
        assert self.batch % self.dp_size == 0
        return self.batch // self.dp_size

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, dp_rank): the local batch shard."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.dp_rank]))
        B, S, V = self.local_batch, self.seq, self.vocab
        # zipf-ish marginal
        base = rng.zipf(self.zipf_a, size=(B, S)).astype(np.int64)
        base = (base - 1) % max(V - 2, 1)
        # inject learnable bigram structure: even positions predict t+1
        tokens = base.copy()
        tokens[:, 1::2] = (tokens[:, 0::2][:, : tokens[:, 1::2].shape[1]]
                           * 31 + 7) % max(V - 2, 1)
        return {"tokens": tokens.astype(np.int32), "step": step}


def make_batch_iterator(pipe: TokenPipeline, start_step: int = 0,
                        prefetch: int = 2,
                        stop_step: Optional[int] = None
                        ) -> Iterator[Dict[str, np.ndarray]]:
    """Background-prefetched iterator starting at ``start_step`` (resume)."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    sentinel = object()

    def producer():
        step = start_step
        while stop_step is None or step < stop_step:
            q.put(pipe.batch_at(step))
            step += 1
        q.put(sentinel)

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is sentinel:
            return
        yield item
