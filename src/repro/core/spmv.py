"""SpMV multiplication algorithms (paper §2-§4) — pure-JAX reference paths.

Every storage format lowers to the same contraction y[r] += v * x[c]; what the
paper's nine algorithms change is *storage layout*, *traversal order* and
*scheduling*. On TPU the jnp implementations below are the correctness oracles
and the XLA baseline; the performance path is `repro.kernels` (Pallas) and the
distributed path is `core.distributed` (shard_map).
"""
from __future__ import annotations

from typing import Union

import jax
import jax.numpy as jnp

from .formats import BICRS, COO, CSR, ICRS, BlockedSparse

Array = jax.Array
Matrix = Union[COO, CSR, ICRS, BICRS, BlockedSparse]


# --------------------------------------------------------------------------
# references
# --------------------------------------------------------------------------
@jax.jit
def spmv_coo(coo: COO, x: Array) -> Array:
    """Triplet-format SpMV (paper §2): y[row[i]] += data[i] * x[col[i]]."""
    m, _ = coo.shape
    y = jnp.zeros((m,), jnp.promote_types(coo.data.dtype, x.dtype))
    if coo.nnz == 0:
        return y
    return y.at[coo.rows].add(coo.data * x[coo.cols])


@jax.jit
def spmv_csr(csr: CSR, x: Array) -> Array:
    """CRS SpMV (Algorithm 2.1). Row loop -> vectorized decompress + one
    segment reduction; this is what ParCRS lowers to on an accelerator."""
    m, _ = csr.shape
    dtype = jnp.promote_types(csr.data.dtype, x.dtype)
    if csr.nnz == 0:
        return jnp.zeros((m,), dtype)
    rows = csr.row_of_nnz()
    prod = csr.data * x[csr.col_ind]
    return jax.ops.segment_sum(prod, rows, num_segments=m).astype(dtype)


@jax.jit
def spmv_incremental(mat: Union[ICRS, BICRS], x: Array) -> Array:
    """Faithful Algorithm 2.2: sequential increment-decoded traversal as a
    lax.scan. This is the *oracle* for the (B)ICRS encodings — DESIGN §2.4
    explains why it is not a TPU compute path."""
    m, n = mat.shape
    dtype = jnp.promote_types(mat.data.dtype, x.dtype)
    y0 = jnp.zeros((m,), dtype)
    if mat.nnz == 0:
        return y0

    col_inc, row_jump, data = mat.col_inc, mat.row_jump, mat.data

    def step(carry, k):
        y, j, i, r = carry
        y = y.at[i].add(data[k] * x[j])
        j = j + col_inc[k]
        overflow = j >= n
        j = jnp.where(overflow, j - n, j)
        i = jnp.where(
            overflow,
            i + row_jump[jnp.minimum(r + 1, row_jump.shape[0] - 1)], i)
        r = jnp.where(overflow, r + 1, r)
        return (y, j, i, r), None

    init = (y0, mat.col_start.astype(jnp.int32),
            row_jump[0].astype(jnp.int32), jnp.int32(0))
    (y, _, _, _), _ = jax.lax.scan(
        step, init, jnp.arange(mat.nnz, dtype=jnp.int32))
    return y


@jax.jit
def spmv_blocked(bs: BlockedSparse, x: Array) -> Array:
    """Blocked-format SpMV, XLA path: decode (block, local) -> global
    coordinates, gather/FMA, segment-reduce. Traversal order (Morton/Hilbert/
    row) is preserved in storage order — XLA sees the same stream a CPU
    would."""
    m, _ = bs.shape
    dtype = jnp.promote_types(bs.data.dtype, x.dtype)
    if bs.nnz == 0:
        return jnp.zeros((m,), dtype)
    bid = bs.block_of_nnz()
    lr, lc = bs.local_rows_cols()
    rows = bs.block_rows[bid] * bs.beta + lr
    cols = bs.block_cols[bid] * bs.beta + lc
    prod = bs.data * x[cols]
    return jax.ops.segment_sum(prod, rows, num_segments=m).astype(dtype)


def spmv_dense_oracle(mat: Matrix, x: Array) -> Array:
    """Densify + matmul. Only for small test matrices."""
    coo = mat if isinstance(mat, COO) else mat.to_coo()
    return coo.todense() @ x


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------
def spmv(mat: Matrix, x: Array, impl: str = "auto") -> Array:
    """Multiply. impl in {"auto", "ref", "pallas", "pallas_interpret"}.

    "auto" uses the Pallas kernel for blocked/CSR formats when running on
    TPU, otherwise the XLA reference. Kernels live in repro.kernels (imported
    lazily to keep the core dependency-light)."""
    from repro.kernels.tiling import TiledSparse
    from repro.spmm.sellcs import SellCS   # late import: core <- spmm
    if impl in ("pallas", "pallas_interpret"):
        interpret = impl == "pallas_interpret"
        from repro.kernels import ops as kops
        if isinstance(mat, TiledSparse):
            return kops.bsr_spmv(mat, x, interpret=interpret)
        if isinstance(mat, CSR):
            return kops.merge_spmv(mat, x, interpret=interpret)
        if isinstance(mat, SellCS):
            from repro.spmm.kernels import sellcs_spmm
            return sellcs_spmm(mat, x[:, None], interpret=interpret)[:, 0]
        raise TypeError(
            f"no kernel path for {type(mat).__name__}; convert with "
            "repro.kernels.coo_to_tiled for the blocked kernel")
    if impl == "auto":
        on_tpu = jax.default_backend() == "tpu"
        if on_tpu and isinstance(mat, (TiledSparse, CSR, SellCS)):
            return spmv(mat, x, impl="pallas")
    if isinstance(mat, TiledSparse):
        from repro.kernels.ref import bsr_spmv_ref
        return bsr_spmv_ref(mat, x)
    if isinstance(mat, SellCS):
        from repro.spmm.reference import spmm_sellcs
        return spmm_sellcs(mat, x)         # [n] in -> [m] out (k=1 case)
    if isinstance(mat, COO):
        return spmv_coo(mat, x)
    if isinstance(mat, CSR):
        return spmv_csr(mat, x)
    if isinstance(mat, (ICRS, BICRS)):
        return spmv_incremental(mat, x)
    if isinstance(mat, BlockedSparse):
        return spmv_blocked(mat, x)
    raise TypeError(f"unknown matrix type {type(mat).__name__}")
