"""CI gate over benchmark JSON emissions (the ``BENCH_*.json`` trajectory).

A benchmark that runs but emits NaN timings or zero GFLOP/s rows is worse
than one that crashes — it seeds the perf history with garbage that later
regression checks would diff against. This checker fails the job instead:

  python -m benchmarks.smoke_check BENCH_*.json

Rules, per record ({"section", "name", "us_per_call", "derived"}):
  * ``us_per_call`` must be finite and >= 0 (exactly 0 is allowed only for
    analytic rows such as the break-even table, which report no timing);
  * every ``gflops=<v>`` field in ``derived`` must be finite and > 0;
  * a file with zero records fails (an empty emission means the benchmark
    silently did nothing).

``spmvs_to_amortize=inf`` and friends are legitimate (a format that never
breaks even), so only the keys named above are validated.
"""
from __future__ import annotations

import json
import math
import sys
from typing import Iterator, List, Tuple

# derived keys that must be finite and strictly positive
_POSITIVE_KEYS = ("gflops",)
# row-name prefixes whose us_per_call is analytic (no timing collected)
_ANALYTIC_PREFIXES = ("break_even.",)


def _derived_fields(derived: str) -> Iterator[Tuple[str, str]]:
    for part in derived.split(";"):
        if "=" in part:
            key, val = part.split("=", 1)
            yield key.strip(), val.strip()


def check_records(records: List[dict], origin: str) -> List[str]:
    """Return a list of human-readable violations (empty == clean)."""
    problems = []
    if not records:
        problems.append(f"{origin}: no records — benchmark emitted nothing")
    for rec in records:
        name = f"{origin}:{rec.get('section', '?')}/{rec.get('name', '?')}"
        us = rec.get("us_per_call")
        if not isinstance(us, (int, float)) or not math.isfinite(us):
            problems.append(f"{name}: us_per_call={us!r} is not finite")
        elif us < 0:
            problems.append(f"{name}: us_per_call={us} is negative")
        elif us == 0 and not str(rec.get("name", "")).startswith(
                _ANALYTIC_PREFIXES):
            problems.append(f"{name}: us_per_call is 0 for a timed row")
        for key, val in _derived_fields(str(rec.get("derived", ""))):
            if key not in _POSITIVE_KEYS:
                continue
            try:
                v = float(val)
            except ValueError:
                problems.append(f"{name}: {key}={val!r} is not a number")
                continue
            if not math.isfinite(v) or v <= 0:
                problems.append(f"{name}: {key}={val} must be finite and "
                                "> 0")
    return problems


def main(argv=None) -> int:
    paths = list(argv if argv is not None else sys.argv[1:])
    if not paths:
        print("usage: python -m benchmarks.smoke_check BENCH_*.json",
              file=sys.stderr)
        return 2
    problems: List[str] = []
    total = 0
    for path in paths:
        try:
            with open(path) as f:
                records = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            problems.append(f"{path}: unreadable ({e})")
            continue
        total += len(records)
        problems.extend(check_records(records, path))
    if problems:
        print(f"smoke_check: {len(problems)} problem(s) in {len(paths)} "
              "file(s):", file=sys.stderr)
        for p in problems:
            print(f"  FAIL {p}", file=sys.stderr)
        return 1
    print(f"smoke_check: {total} records across {len(paths)} file(s) OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
