"""Mamba-2 (SSD — state-space duality) layer [arXiv:2405.21060].

Chunked SSD algorithm: within a chunk the recurrence is computed in its
"attention dual" form (C B^T masked by the decay kernel), across chunks a
[H, P, N] state is carried — O(S L) work, O(S/L) sequential steps. Decode
carries (conv_state, ssm_state) and costs O(1) per token.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import causal_conv1d, causal_conv1d_init, dense, dense_init

Array = jax.Array


class SSMConfig(NamedTuple):
    d_model: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def nheads(self) -> int:
        return self.d_inner // self.headdim


def ssm_init(key, cfg: SSMConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    di, N, H = cfg.d_inner, cfg.d_state, cfg.nheads
    d_in_proj = 2 * di + 2 * N + H           # z, x, B, C, dt (ngroups=1)
    conv_ch = di + 2 * N
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, d_in_proj, dtype=dtype),
        "conv": causal_conv1d_init(ks[1], conv_ch, cfg.d_conv, dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(dtype)),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,),
                                       minval=jnp.log(1e-3),
                                       maxval=jnp.log(1e-1))))).astype(dtype),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[3], di, cfg.d_model, dtype=dtype),
    }


def _split_proj(p, cfg: SSMConfig, u: Array):
    di, N, H = cfg.d_inner, cfg.d_state, cfg.nheads
    zxbcdt = dense(p["in_proj"], u)
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]
    return z, xBC, dt


def _gated_norm(p, y: Array, z: Array, eps: float = 1e-6) -> Array:
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps)
            * p["norm_scale"].astype(jnp.float32)).astype(y.dtype)


def ssm_forward(p, cfg: SSMConfig, u: Array,
                initial_state: Optional[Array] = None) -> Array:
    """u: [B, S, d_model] -> [B, S, d_model] (training / prefill)."""
    B, S, _ = u.shape
    di, N, H, P = cfg.d_inner, cfg.d_state, cfg.nheads, cfg.headdim
    L = min(cfg.chunk, S)
    nc = -(-S // L)
    Sp = nc * L

    z, xBC, dt = _split_proj(p, cfg, u)
    xBC, _ = causal_conv1d(p["conv"], xBC)
    xBC = jax.nn.silu(xBC.astype(jnp.float32))
    x = xBC[..., :di].reshape(B, S, H, P)
    Bm = xBC[..., di:di + N]                       # [B, S, N] (ngroups=1)
    Cm = xBC[..., di + N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # [B, S, H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # [H]
    loga = dt * A[None, None]                                  # [B, S, H]

    # pad to chunk multiple (decay 0 contributions for padded steps)
    pad = Sp - S
    x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
    Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(a):
        return a.reshape((B, nc, L) + a.shape[2:]).swapaxes(0, 1)

    xc, Bc, Cc = to_chunks(x), to_chunks(Bm), to_chunks(Cm)
    dtc, lac = to_chunks(dt_p), to_chunks(loga)

    def chunk_step(state, inp):
        # state: [B, H, P, N]; xc [B,L,H,P], Bc/Cc [B,L,N], dtc/lac [B,L,H]
        xk, Bk, Ck, dtk, lak = inp
        cs = jnp.cumsum(lak, axis=1)                           # [B, L, H]
        # intra-chunk (attention-dual): score[i,j] = (C_i . B_j)
        #   * exp(cs_i - cs_j) * dt_j for j <= i
        cb = jnp.einsum("bin,bjn->bij", Ck, Bk)                # [B, L, L]
        decay = jnp.exp(cs[:, :, None] - cs[:, None])          # [B, L, L, H]
        causal = jnp.tril(jnp.ones((L, L), bool))
        scr = cb[..., None] * decay * dtk[:, None]             # [B,L,L,H]
        scr = jnp.where(causal[None, ..., None], scr, 0.0)
        y_intra = jnp.einsum("bijh,bjhp->bihp", scr, xk)
        # inter-chunk: y_i += exp(cs_i) * C_i . state
        y_inter = jnp.einsum("bin,bhpn->bihp", Ck, state) \
            * jnp.exp(cs)[..., None]
        # state update: S' = exp(cs_L) S + sum_j exp(cs_L - cs_j) dt_j x_j B_j
        tail = jnp.exp(cs[:, -1:] - cs) * dtk                  # [B, L, H]
        upd = jnp.einsum("bjh,bjhp,bjn->bhpn", tail, xk, Bk)
        state = state * jnp.exp(cs[:, -1])[..., None, None] + upd
        return state, y_intra + y_inter

    s0 = initial_state if initial_state is not None else \
        jnp.zeros((B, H, P, N), jnp.float32)
    # checkpoint: the [B, L, L, H] decay kernel is recomputed in backward
    _, ys = jax.lax.scan(jax.checkpoint(chunk_step), s0,
                         (xc, Bc, Cc, dtc, lac))
    y = ys.swapaxes(0, 1).reshape(B, Sp, H, P)[:, :S]          # [B,S,H,P]
    y = y + x[:, :S].reshape(B, S, H, P) * p["D"].astype(jnp.float32)[
        None, None, :, None]
    y = _gated_norm(p, y.reshape(B, S, di), z)
    return dense(p["out_proj"], y.astype(u.dtype))


class SSMCache(NamedTuple):
    conv_state: Array     # [B, d_conv-1, conv_ch]
    ssm_state: Array      # [B, H, P, N] f32

    @classmethod
    def init(cls, B: int, cfg: SSMConfig, dtype=jnp.float32):
        conv_ch = cfg.d_inner + 2 * cfg.d_state
        return cls(jnp.zeros((B, cfg.d_conv - 1, conv_ch), dtype),
                   jnp.zeros((B, cfg.nheads, cfg.headdim, cfg.d_state),
                             jnp.float32))


def ssm_decode(p, cfg: SSMConfig, u: Array, cache: SSMCache
               ) -> Tuple[Array, SSMCache]:
    """u: [B, 1, d_model] one token; O(1) state update."""
    B = u.shape[0]
    di, N, H, P = cfg.d_inner, cfg.d_state, cfg.nheads, cfg.headdim
    z, xBC, dt = _split_proj(p, cfg, u)
    xBC, conv_state = causal_conv1d(p["conv"], xBC, cache.conv_state)
    xBC = jax.nn.silu(xBC.astype(jnp.float32))
    x = xBC[:, 0, :di].reshape(B, H, P)
    Bm = xBC[:, 0, di:di + N]
    Cm = xBC[:, 0, di + N:]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # [B, H]
    a = jnp.exp(dt * -jnp.exp(p["A_log"].astype(jnp.float32)))  # [B, H]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, x, Bm)
    state = cache.ssm_state * a[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm, state) \
        + x * p["D"].astype(jnp.float32)[None, :, None]
    y = _gated_norm(p, y.reshape(B, 1, di), z)
    out = dense(p["out_proj"], y.astype(u.dtype))
    return out, SSMCache(conv_state, state)


def ssm_forward_naive(p, cfg: SSMConfig, u: Array) -> Array:
    """Step-by-step recurrence oracle (tests only)."""
    B, S, _ = u.shape
    cache = SSMCache.init(B, cfg, u.dtype)
    outs = []
    for t in range(S):
        o, cache = ssm_decode(p, cfg, u[:, t:t + 1], cache)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
