"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; asserts shapes and finiteness.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, long_context_capable
from repro.models.accounting import count_params
from repro.models.model import (decode_step, forward, init_params, loss_fn,
                                prefill)


def _inputs(cfg, B=2, S=24, seed=0):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    vis = None
    if cfg.frontend == "vision":
        vis = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                (B, cfg.vision_tokens, cfg.vision_dim))
    return tokens, vis


@pytest.fixture(params=ARCH_IDS, scope="module")
def arch(request):
    return request.param


@pytest.fixture(scope="module")
def setup(arch):
    cfg = get_config(arch, reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_forward_shapes_finite(setup):
    cfg, params = setup
    tokens, vis = _inputs(cfg)
    h, aux = forward(params, cfg, tokens, vis)
    S_expected = tokens.shape[1] + (cfg.vision_tokens
                                    if cfg.frontend == "vision" else 0)
    assert h.shape == (2, S_expected, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(h, np.float32)))
    assert np.isfinite(float(aux))


def test_train_step(setup):
    cfg, params = setup
    tokens, vis = _inputs(cfg)
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, tokens, vis)
    assert np.isfinite(float(loss))
    # a priori CE should be near log(vocab) at init
    assert float(metrics["ce"]) < np.log(cfg.vocab) + 2.0
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves and all(
        np.all(np.isfinite(np.asarray(g, np.float32))) for g in leaves)
    # one SGD step must change the loss
    new_params = jax.tree_util.tree_map(lambda p, g: p - 1e-2 * g,
                                        params, grads)
    loss2, _ = loss_fn(new_params, cfg, tokens, vis)
    assert np.isfinite(float(loss2)) and float(loss2) != float(loss)


def test_decode_step(setup):
    cfg, params = setup
    tokens, vis = _inputs(cfg)
    B = tokens.shape[0]
    lg, caches = prefill(params, cfg, tokens[:, :16], S_max=32,
                         cache_dtype=jnp.float32, vision_embeds=vis)
    assert lg.shape == (B, cfg.vocab)
    pos0 = 16 + (cfg.vision_tokens if cfg.frontend == "vision" else 0)
    lg2, caches = decode_step(params, cfg, tokens[:, 16:17], caches,
                              jnp.full((B,), pos0, jnp.int32))
    assert lg2.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(lg2)))


def test_full_config_accounting(arch):
    """Analytic param count of the FULL config is in the right ballpark for
    the published model size (catches config typos without instantiation)."""
    cfg = get_config(arch)
    n = count_params(cfg)
    expected = {
        "starcoder2-7b": 7e9, "qwen2.5-3b": 3e9, "qwen3-4b": 4e9,
        "llama3.2-1b": 1.2e9, "mamba2-1.3b": 1.3e9,
        "granite-moe-1b-a400m": 1.3e9, "mixtral-8x22b": 141e9,
        "musicgen-large": 3.3e9, "jamba-1.5-large-398b": 398e9,
        "internvl2-2b": 1.9e9,
    }[arch]
    assert 0.5 * expected < n < 2.0 * expected, \
        f"{arch}: {n / 1e9:.2f}B params vs expected ~{expected / 1e9:.0f}B"


def test_active_params_moe(arch):
    cfg = get_config(arch)
    n_all = count_params(cfg)
    n_act = count_params(cfg, active_only=True)
    if cfg.n_experts > 0:
        assert n_act < n_all
    else:
        assert n_act == n_all


def test_long_context_capability_flags(arch):
    cfg = get_config(arch)
    expected = {
        "starcoder2-7b": False, "qwen2.5-3b": False, "qwen3-4b": False,
        "llama3.2-1b": False, "mamba2-1.3b": True,
        "granite-moe-1b-a400m": False, "mixtral-8x22b": True,
        "musicgen-large": False, "jamba-1.5-large-398b": True,
        "internvl2-2b": False,
    }[arch]
    assert long_context_capable(cfg) == expected
