"""repro.spmm.fleet — the operator registry behind ``serve --mode fleet``.

One serve process, many matrices: each tenant registers a COO and gets a
:class:`repro.spmm.SparseOperator` back. The fleet's value over a dict of
operators is twofold:

**Plan cache.** Realized plans are keyed on ``(matrix fingerprint, plan
spec, k-hint, impl)`` where the fingerprint is a stable content hash of
the canonically-ordered (rows, cols, values) triplet stream
(:func:`repro.spmm.operator.coo_fingerprint`). A returning tenant — same
matrix, same knobs — installs the cached :class:`RealizedPlan` directly
and skips selection, conversion, AND partitioning (asserted via
``OperatorStats``: zero builds on the hit path). Tenants with the same
matrix but different knobs still share convert-time artifacts through a
per-fingerprint :class:`_PlanCache` (the SELL-C-σ stream and each base
partition), so only the cheap tail of the build is paid. The paper's
break-even economics (§7: ~472 multiplies to amortize one conversion)
make this cache the difference between a fleet that converts per tenant
arrival and one that converts per distinct matrix.

**Device-loss handling.** ``handle_device_loss(failed)`` re-deals every
distributed operator's width-row stream across the survivors
(``SparseOperator.shrink_to`` → ``redeal_sellcs``: no σ-sort, no
conversion — the partitioning is the durable asset) under the
``largest_feasible_mesh`` policy and atomically swaps the shrunken plans;
serving continues mid-stream. Re-deal latency lands in the
``fleet/redeal_s`` histogram per tenant.

A :class:`repro.runtime.fault_tolerance.StragglerMonitor` watches flush
times via ``observe_flush``; anomalies land in ``fleet/straggler_flags``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro import obs
from repro.core.formats import COO
from repro.core.selector import PlanSpec
from repro.runtime.fault_tolerance import StragglerMonitor
from repro.spmm.operator import (RealizedPlan, SparseOperator, _PlanCache,
                                 coo_fingerprint)


class FleetStats:
    """Fleet-level accounting (the per-operator build counters live on
    each operator's ``OperatorStats``)."""
    __slots__ = ("registered", "plan_cache_hits", "plan_cache_misses",
                 "evictions", "evicted_bytes", "device_losses")

    def __init__(self):
        self.registered = 0
        self.plan_cache_hits = 0
        self.plan_cache_misses = 0
        self.evictions = 0
        self.evicted_bytes = 0
        self.device_losses = 0

    def __repr__(self):
        return (f"FleetStats(registered={self.registered}, "
                f"hits={self.plan_cache_hits}, "
                f"misses={self.plan_cache_misses}, "
                f"evictions={self.evictions}, "
                f"evicted_bytes={self.evicted_bytes}, "
                f"device_losses={self.device_losses})")


def _spec_key(spec: Optional[PlanSpec]) -> Tuple:
    """Hashable identity of the plan knobs (canonicalized so equivalent
    spellings share a cache line)."""
    if spec is None:
        return ()
    sp = spec.canonical()
    return (sp.num_devices, sp.mesh_shape, sp.num_chunks, sp.compact_x,
            sp.schedule, sp.algorithm, sp.structure)


class Fleet:
    """Registry of :class:`SparseOperator` tenants with plan caching and
    device-loss re-deal.

    ::

        fleet = Fleet(impl="ref")
        op = fleet.register("tenant-a", coo, PlanSpec(num_devices=8))
        y = op.matmul(x)
        fleet.handle_device_loss([7])      # re-deal onto the survivors

    ``capacity`` bounds the tenant COUNT, ``max_bytes`` the accumulated
    execution-side plan footprint (``SparseOperator.storage_bytes``);
    either triggers LRU eviction at register time, and the freed bytes
    are accounted in ``fleet/evicted_bytes``.
    """

    def __init__(self, *, impl: str = "auto", feedback=None,
                 monitor: Optional[StragglerMonitor] = None,
                 capacity: Optional[int] = None,
                 max_bytes: Optional[int] = None):
        if capacity is not None and capacity < 1:
            raise ValueError("capacity must be >= 1 (or None)")
        if max_bytes is not None and max_bytes < 1:
            raise ValueError("max_bytes must be >= 1 (or None)")
        self._impl = impl
        self._feedback = feedback
        self._capacity = capacity
        self._max_bytes = max_bytes
        self._ops: Dict[str, SparseOperator] = {}      # insertion = LRU age
        self._fingerprints: Dict[str, str] = {}        # tenant -> fp
        self._plan_keys: Dict[str, Tuple] = {}         # tenant -> cache key
        self._plans: Dict[Tuple, RealizedPlan] = {}
        self._artifacts: Dict[str, _PlanCache] = {}    # fp -> shared cache
        self._failed: set = set()
        self._flush_seq = 0
        self.monitor = monitor if monitor is not None else StragglerMonitor()
        self.stats = FleetStats()

    # -- registry ----------------------------------------------------------
    def tenants(self) -> List[str]:
        return list(self._ops)

    def get(self, tenant: str) -> SparseOperator:
        return self._ops[tenant]

    def __contains__(self, tenant: str) -> bool:
        return tenant in self._ops

    def __len__(self) -> int:
        return len(self._ops)

    def register(self, tenant: str, coo: COO,
                 spec: Optional[PlanSpec] = None, *, k_hint: int = 32,
                 num_spmvs: int = 1000) -> SparseOperator:
        """Build (or cache-hit) an operator for ``tenant``. The plan cache
        key is ``(fingerprint(coo), spec, k_hint, impl)``; on a hit the
        cached :class:`RealizedPlan` is installed directly — the new
        operator's ``OperatorStats`` shows zero sellcs/partition builds."""
        if tenant in self._ops:
            raise ValueError(f"tenant {tenant!r} already registered")
        fp = coo_fingerprint(coo)
        key = (fp, _spec_key(spec), int(k_hint), self._impl)
        cached = self._plans.get(key)
        artifacts = self._artifacts.setdefault(fp, _PlanCache())
        if cached is not None:
            op = SparseOperator(coo, cached, impl=self._impl,
                                k_hint=k_hint, num_spmvs=num_spmvs,
                                cache=artifacts)
            self.stats.plan_cache_hits += 1
            if obs.enabled():
                obs.current_registry().counter("fleet/plan_cache_hits").inc()
        else:
            op = SparseOperator(coo, spec, impl=self._impl, k_hint=k_hint,
                                num_spmvs=num_spmvs,
                                feedback=self._feedback, cache=artifacts)
            self._plans[key] = op.plan
            self.stats.plan_cache_misses += 1
            if obs.enabled():
                obs.current_registry().counter(
                    "fleet/plan_cache_misses").inc()
        self._ops[tenant] = op
        self._fingerprints[tenant] = fp
        self._plan_keys[tenant] = key
        self.stats.registered += 1
        if obs.enabled():
            obs.current_registry().gauge("fleet/tenants").set(
                len(self._ops))
        if self._capacity is not None:
            while len(self._ops) > self._capacity:
                self.evict(next(iter(self._ops)))
        if self._max_bytes is not None:
            # LRU under the memory budget: free oldest tenants until the
            # accumulated execution-side footprint fits; the newest tenant
            # itself is never evicted (a single over-budget matrix still
            # serves — the budget bounds the fleet, not one tenant)
            while (len(self._ops) > 1
                   and self.total_storage_bytes() > self._max_bytes):
                victim = next(t for t in self._ops if t != tenant)
                self.evict(victim)
        return op

    def total_storage_bytes(self) -> int:
        """Accumulated execution-side footprint of every resident plan
        (``SparseOperator.storage_bytes``: the partitioned stream on a
        mesh, the converted format off one)."""
        return sum(op.storage_bytes() for op in self._ops.values())

    def evict(self, tenant: str) -> None:
        """Drop a tenant; per-fingerprint artifacts are freed with their
        last user (cached plans for that fingerprint go too). The freed
        plan bytes land in ``fleet/evicted_bytes``."""
        freed = self._ops[tenant].storage_bytes()
        self._ops.pop(tenant)
        fp = self._fingerprints.pop(tenant)
        self._plan_keys.pop(tenant, None)
        self.stats.evictions += 1
        self.stats.evicted_bytes += freed
        if fp not in self._fingerprints.values():
            self._artifacts.pop(fp, None)
            for key in [k for k in self._plans if k[0] == fp]:
                del self._plans[key]
        if obs.enabled():
            reg = obs.current_registry()
            reg.counter("fleet/evictions").inc()
            reg.counter("fleet/evicted_bytes").inc(float(freed))
            reg.gauge("fleet/tenants").set(len(self._ops))

    # -- fault tolerance ---------------------------------------------------
    @property
    def failed_devices(self) -> List[int]:
        return sorted(self._failed)

    def handle_device_loss(self, failed: Sequence[int]) -> List[str]:
        """Re-deal every distributed tenant across the survivors of
        ``failed`` (device indices into ``jax.devices()``) and atomically
        swap the shrunken plans. Single-device tenants are untouched.
        Returns the tenants whose plans were re-dealt. Cached plans over
        the old device set are invalidated — a returning tenant must not
        be handed a mesh containing a dead device."""
        self._failed.update(int(i) for i in failed)
        survivors = [d for i, d in enumerate(jax.devices())
                     if i not in self._failed]
        if not survivors:
            raise RuntimeError("no surviving devices")
        self.stats.device_losses += 1
        reg = obs.current_registry() if obs.enabled() else None
        if reg is not None:
            reg.counter("fleet/device_losses").inc()
        redone: List[str] = []
        shrunk: Dict[int, RealizedPlan] = {}   # id(old plan) -> new plan
        for tenant, op in self._ops.items():
            if (op.spec.num_devices or 1) <= 1:
                continue
            # tenants that shared a cached plan keep sharing after the
            # loss: the first pays the re-deal, the rest just swap it in
            old_id = id(op.plan)
            prior = shrunk.get(old_id)
            t0 = time.perf_counter()
            plan = (op.swap(prior) if prior is not None
                    else op.shrink_to(survivors))
            dt = time.perf_counter() - t0
            shrunk[old_id] = plan
            # refresh under the tenant's REGISTRATION key (the original
            # knobs), not the shrunken spec's: a returning tenant asking
            # for the pre-loss configuration must get the survivors'
            # plan, never a fresh deal over a mesh with the dead device
            self._plans[self._plan_keys[tenant]] = plan
            redone.append(tenant)
            if reg is not None:
                reg.histogram("fleet/redeal_s",
                              {"tenant": tenant}).observe(dt)
        # drop every cached plan not refreshed above: their meshes may
        # name the dead device (identity check — RealizedPlan holds jax
        # arrays, so == would be elementwise)
        live = {id(op.plan) for op in self._ops.values()}
        for key in [k for k, p in self._plans.items()
                    if id(p) not in live]:
            del self._plans[key]
        return redone

    def observe_flush(self, tenant: str, dt: float) -> bool:
        """Feed one flush latency to the straggler monitor; a flagged
        anomaly lands in ``fleet/straggler_flags``."""
        self._flush_seq += 1
        slow = self.monitor.observe(self._flush_seq, dt)
        if slow and obs.enabled():
            obs.current_registry().counter(
                "fleet/straggler_flags", {"tenant": tenant}).inc()
        return slow


__all__ = ["Fleet", "FleetStats"]
