"""Optimizers, data pipeline, checkpointing, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.data.pipeline import TokenPipeline, make_batch_iterator
from repro.optim import (adamw, constant_lr, global_norm, make_optimizer,
                         warmup_cosine)
from repro.runtime import StragglerMonitor, Supervisor


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_descends_quadratic(name):
    target = jnp.asarray(np.random.default_rng(0)
                         .standard_normal((8, 16)).astype(np.float32))
    params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
    opt = make_optimizer(name, constant_lr(0.05))
    state = opt.init(params)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2) + jnp.mean(p["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state, metrics = opt.update(grads, state, params)
    assert float(loss(params)) < 0.05 * l0
    assert np.isfinite(float(metrics["grad_norm"]))


def test_warmup_cosine_shape():
    sched = warmup_cosine(1e-3, 10, 100)
    lrs = [float(sched(jnp.asarray(s))) for s in range(0, 100, 5)]
    assert lrs[0] < lrs[1]                       # warmup rises
    assert max(lrs) <= 1e-3 + 1e-9
    assert lrs[-1] < lrs[3]                      # decays
    assert lrs[-1] >= 1e-4 - 1e-9                # min_ratio floor


def test_clip_global_norm():
    g = {"a": jnp.full((4,), 100.0)}
    from repro.optim import clip_by_global_norm
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(200.0)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_pipeline_determinism_and_sharding():
    pipe = TokenPipeline(vocab=97, batch=8, seq=16, seed=3, dp_rank=0,
                         dp_size=2)
    b1 = pipe.batch_at(5)
    b2 = pipe.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)         # local shard
    other = TokenPipeline(vocab=97, batch=8, seq=16, seed=3, dp_rank=1,
                          dp_size=2).batch_at(5)
    assert not np.array_equal(b1["tokens"], other["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 97


def test_pipeline_iterator_resume():
    pipe = TokenPipeline(vocab=31, batch=2, seq=8, seed=0)
    it = make_batch_iterator(pipe, start_step=0, stop_step=6)
    seq = [b["step"] for b in it]
    assert seq == list(range(6))
    it2 = make_batch_iterator(pipe, start_step=3, stop_step=6)
    resumed = list(it2)
    np.testing.assert_array_equal(resumed[0]["tokens"],
                                  pipe.batch_at(3)["tokens"])


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "step": jnp.asarray(7)}
    save(d, 7, tree, blocking=True)
    assert latest_step(d) == 7
    target = jax.tree_util.tree_map(jnp.zeros_like, tree)
    back = restore(d, 7, target)
    np.testing.assert_array_equal(np.asarray(back["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_checkpoint_retention_and_latest(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"x": jnp.zeros((2,))}
    for s in [10, 20, 30, 40]:
        save(d, s, tree, blocking=True, keep=2)
    assert latest_step(d) == 40
    remaining = sorted(f for f in os.listdir(d) if f.endswith("COMMITTED"))
    assert len(remaining) == 2


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    save(d, 1, {"w": jnp.zeros((3,))}, blocking=True)
    with pytest.raises(AssertionError):
        restore(d, 1, {"w": jnp.zeros((4,))})


# ---------------------------------------------------------------------------
# fault tolerance: crash + resume is bit-exact
# ---------------------------------------------------------------------------
def _toy_training(ckpt_dir, num_steps, fail_at=None, start_fresh=True):
    """Tiny linear-regression train loop driven by the Supervisor."""
    pipe = TokenPipeline(vocab=64, batch=4, seq=9, seed=1)
    opt = adamw(constant_lr(0.05))
    params = {"w": jnp.zeros((8, 8))}
    state = {"params": params, "opt": opt.init(params)}

    @jax.jit
    def step_fn(state, batch):
        tokens = jnp.asarray(batch["tokens"], jnp.float32)
        x, y = tokens[:, :-1], tokens[:, 1:]

        def loss(p):
            pred = x.T @ x @ p["w"]
            return jnp.mean((pred - y.T @ y) ** 2)

        grads = jax.grad(loss)(state["params"])
        new_p, new_opt, m = opt.update(grads, state["opt"], state["params"])
        return {"params": new_p, "opt": new_opt}, {"loss": m["grad_norm"]}

    sup = Supervisor(ckpt_dir, save_every=2, keep=5)
    start = None
    if not start_fresh:
        # restore() returns the step consistent with the restored state —
        # pass it through rather than letting run() re-read latest_step()
        # (an in-flight async save from the crashed process could land in
        # between, which is exactly the kind of race a supervisor must not
        # have)
        restored, resume = sup.restore(state)
        if restored is not None:
            state, start = restored, resume
    return sup.run(state, num_steps, step_fn,
                   lambda s: pipe.batch_at(s), fail_at=fail_at,
                   start_step=start)


def test_crash_resume_bit_exact(tmp_path):
    d1 = str(tmp_path / "nofail")
    final_ref = _toy_training(d1, 9)

    d2 = str(tmp_path / "fail")
    with pytest.raises(RuntimeError, match="injected failure"):
        _toy_training(d2, 9, fail_at=5)
    # restart: resumes from latest committed ckpt and replays
    final_resumed = _toy_training(d2, 9, start_fresh=False)
    np.testing.assert_array_equal(np.asarray(final_ref["params"]["w"]),
                                  np.asarray(final_resumed["params"]["w"]))


def test_straggler_monitor():
    mon = StragglerMonitor(alpha=0.5, threshold=2.0)
    assert not mon.observe(0, 1.0)
    assert not mon.observe(1, 1.1)
    assert mon.observe(2, 10.0)          # 10x slower -> flagged
    assert len(mon.slow_steps) == 1
