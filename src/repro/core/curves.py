"""Space-filling curves used to order nonzeros / blocks for locality.

The paper (§3.1, §3.2, §4) uses two curves:
  * Z-Morton  — bit interleave of (row, col); used by CSB.
  * Hilbert   — orientation-preserving curve; used by BCOH and the *H hybrids.

Both are implemented as vectorized jnp bit manipulations so they can run
inside jit (conversion is benchmarked as a first-class operation, Tables
6.4/6.5 of the paper). All functions accept/return integer arrays and are
exact for coordinates < 2**MAX_ORDER.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# 16 bits per coordinate == the paper's compressed-index width (16+16 packed
# into a 32-bit integer, §3.1). Curve keys therefore fit in uint32/int64.
MAX_ORDER = 16


def _part1by1(v):
    """Spread the low 16 bits of ``v`` so there is a zero between each bit."""
    v = v.astype(jnp.uint32)
    v = (v | (v << 8)) & jnp.uint32(0x00FF00FF)
    v = (v | (v << 4)) & jnp.uint32(0x0F0F0F0F)
    v = (v | (v << 2)) & jnp.uint32(0x33333333)
    v = (v | (v << 1)) & jnp.uint32(0x55555555)
    return v


def _compact1by1(v):
    """Inverse of :func:`_part1by1`."""
    v = v.astype(jnp.uint32) & jnp.uint32(0x55555555)
    v = (v | (v >> 1)) & jnp.uint32(0x33333333)
    v = (v | (v >> 2)) & jnp.uint32(0x0F0F0F0F)
    v = (v | (v >> 4)) & jnp.uint32(0x00FF00FF)
    v = (v | (v >> 8)) & jnp.uint32(0x0000FFFF)
    return v


def morton_key(row, col):
    """Z-Morton key. Row bits are the *high* bits of each interleaved pair so
    the curve sweeps quadrants top-left, top-right, bottom-left, bottom-right
    (Fig. 3.1 of the paper)."""
    r = _part1by1(jnp.asarray(row))
    c = _part1by1(jnp.asarray(col))
    return ((r << 1) | c).astype(jnp.uint32)


def morton_decode(key):
    """Inverse of :func:`morton_key` -> (row, col)."""
    key = jnp.asarray(key).astype(jnp.uint32)
    row = _compact1by1(key >> 1)
    col = _compact1by1(key)
    return row.astype(jnp.int32), col.astype(jnp.int32)


def hilbert_key(row, col, order: int = MAX_ORDER):
    """Hilbert curve index of (row, col) on a 2**order x 2**order grid.

    Vectorized version of the classic xy->d algorithm [Hilbert 1891; see the
    paper Fig. 3.2]. ``order`` iterations of rotate-and-accumulate; each
    iteration is branch-free (jnp.where) so the whole thing jit-compiles to
    pure VPU bit ops.
    """
    if order > 16:
        raise ValueError("order > 16 would overflow the uint32 key")
    u = jnp.uint32
    x = jnp.asarray(col).astype(u)
    y = jnp.asarray(row).astype(u)
    d = jnp.zeros_like(x, dtype=u)
    n = u(1 << order)
    s = 1 << (order - 1)
    for _ in range(order):
        su = u(s)
        rx = jnp.where((x & su) > 0, u(1), u(0))
        ry = jnp.where((y & su) > 0, u(1), u(0))
        # true key < 2**32, so uint32 modular accumulation is exact
        d = d + u(s) * u(s) * ((u(3) * rx) ^ ry)
        # rotate quadrant: when ry == 0, optionally flip (within the full
        # n-grid — high bits are already consumed so flipping them is
        # harmless, and this keeps coordinates non-negative), then swap x/y.
        x_new = jnp.where(ry == 0, jnp.where(rx == 1, n - u(1) - y, y), x)
        y_new = jnp.where(ry == 0, jnp.where(rx == 1, n - u(1) - x, x), y)
        x, y = x_new, y_new
        s >>= 1
    return d


def hilbert_decode(key, order: int = MAX_ORDER):
    """Inverse of :func:`hilbert_key` -> (row, col)."""
    u = jnp.uint32
    t = jnp.asarray(key).astype(u)
    x = jnp.zeros_like(t)
    y = jnp.zeros_like(t)
    s = 1
    for _ in range(order):
        su = u(s)
        rx = (t >> 1) & u(1)
        ry = (t ^ rx) & u(1)
        # rotate (x, y < s here, so flipping within the s-square is exact)
        flip = (ry == 0) & (rx == 1)
        x_f = jnp.where(flip, su - u(1) - x, x)
        y_f = jnp.where(flip, su - u(1) - y, y)
        x, y = jnp.where(ry == 0, y_f, x_f), jnp.where(ry == 0, x_f, y_f)
        x = x + su * rx
        y = y + su * ry
        t = t >> 2
        s <<= 1
    return y.astype(jnp.int32), x.astype(jnp.int32)  # (row, col)


def curve_key(row, col, order: str = "hilbert", bits: int = MAX_ORDER):
    """Uniform entry point: ``order`` in {"row", "morton", "hilbert"}.

    "row" returns the row-major key (row * 2**bits + col), matching the
    paper's row-wise nonzero ordering used by CRS/BCOHC/MergeB.
    """
    row = jnp.asarray(row)
    col = jnp.asarray(col)
    if order == "row":
        # coordinates < 2**bits (bits <= 16), so the packed key fits uint32
        return (row.astype(jnp.uint32) << bits) | col.astype(jnp.uint32)
    if order == "morton":
        return morton_key(row, col)
    if order == "hilbert":
        return hilbert_key(row, col, bits)
    raise ValueError(f"unknown curve order {order!r}")


# numpy twin (used on the host-side conversion path and in tests)
def hilbert_key_np(row, col, order: int = MAX_ORDER):
    x = np.asarray(col, dtype=np.int64).copy()
    y = np.asarray(row, dtype=np.int64).copy()
    d = np.zeros_like(x)
    n = np.int64(1 << order)
    s = np.int64(1 << (order - 1))
    while s > 0:
        rx = ((x & s) > 0).astype(np.int64)
        ry = ((y & s) > 0).astype(np.int64)
        d += s * s * ((3 * rx) ^ ry)
        x_new = np.where(ry == 0, np.where(rx == 1, n - 1 - y, y), x)
        y_new = np.where(ry == 0, np.where(rx == 1, n - 1 - x, x), y)
        x, y = x_new, y_new
        s >>= 1
    return d
