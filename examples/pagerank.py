"""PageRank on an RMAT graph — the paper's motivating SpMV workload (§1).

Power iteration: r <- d * A^T_norm r + (1-d)/n, run with two of the paper's
storage formats; conversion cost is amortized over the iterations (the §7
break-even argument in action).

Run:  PYTHONPATH=src python examples/pagerank.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import convert, coo_to_csr, spmv, to_coo
from repro.data import matrices

# RMAT graph, column-normalized adjacency (column-stochastic)
rows, cols, vals, shape = matrices.rmat(scale=13, edge_factor=12, seed=0)
n = shape[0]
out_deg = np.bincount(cols, minlength=n).astype(np.float32)
norm_vals = 1.0 / np.maximum(out_deg[cols], 1.0)
coo = to_coo(rows, cols, norm_vals, shape)

DAMP, ITERS = 0.85, 50


def pagerank(mat, label):
    t0 = time.perf_counter()
    r = jnp.full((n,), 1.0 / n, jnp.float32)
    for _ in range(ITERS):
        r = DAMP * spmv(mat, r, impl="ref") + (1 - DAMP) / n
        r = r / jnp.sum(r)
    r.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"  {label:10s} {ITERS} iterations in {dt * 1e3:.0f} ms "
          f"({dt / ITERS * 1e3:.2f} ms/iter)")
    return r


t0 = time.perf_counter()
csr = coo_to_csr(coo)
t_csr = time.perf_counter() - t0
t0 = time.perf_counter()
bcohch = convert(coo, "bcohch", beta=256, num_bands=8)
t_bcohch = time.perf_counter() - t0
print(f"conversion: csr {t_csr * 1e3:.0f} ms, bcohch {t_bcohch * 1e3:.0f} ms")

r1 = pagerank(csr, "parcrs")
r2 = pagerank(bcohch, "bcohch")
np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-5)

top = np.argsort(-np.asarray(r1))[:5]
print(f"top-5 nodes: {top.tolist()}")
print(f"rank mass of top-5: {float(jnp.sum(r1[top])):.4f}")
print("pagerank OK")
