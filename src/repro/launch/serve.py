"""Serving entry points.

LM mode — batched prefill + greedy decode with KV caches (CPU-scale demo,
reduced config, real execution):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --batch 4 --prompt-len 32 --gen 16

SpMV mode — the repro.spmm request batcher serving single-vector requests:
queued ``A @ x`` requests aggregate into one SpMM per flush (matrix stream
amortized over the batch), measured against serving them one by one:
  PYTHONPATH=src python -m repro.launch.serve --mode spmv \
      --matrix mawi_like --requests 64 --max-batch 32

Mesh serving — ``--devices P`` answers each flush with a *distributed*
SpMM over a P-device mesh (``repro.spmm.distributed``); format,
cross-device schedule and the merge-psum pipelining depth come from the
``core.select_distributed`` grid (``--chunks c`` pins the depth).
``--mesh Pd,Pm`` pins a 2-D (data, model) factorization instead: the model
axis column-shards the X/Y k-slabs so per-device psum and replicated-X
bytes drop by Pm — the k ≫ 128 scaling axis. ``--compact-x on`` partitions
with per-shard column compaction (each data shard gathers only the X rows
its nonzeros touch instead of reading the replicated slab; ``auto`` asks
the traffic model whether the gather pays). On CPU, force host-platform
devices first:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --mode spmv --matrix mawi_like \
      --requests 64 --max-batch 32 --mesh 4,2 --impl ref --chunks 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models.model import decode_step, init_params, prefill


def _pick_chunk(m: int, num_devices: int, default: int = 128) -> int:
    """Largest power-of-two slice height <= default that still gives every
    device at least one slice to own (small demo matrices on big meshes)."""
    c = default
    while c > 8 and -(-m // c) < num_devices:
        c //= 2
    return c


def _make_distributed_spmm(coo, stats, args, mesh_shape):
    """Build (matrix, spmm_fn, label, schedule, chunks, mesh_shape) for
    the --devices / --mesh path. ``mesh_shape`` is a (P_data, P_model)
    factorization, or None to let the traffic model keep the 1-D mesh
    (the --devices behavior)."""
    from repro.core.selector import (_matrix_bytes_est,
                                     distributed_schedule_grid)
    from repro.launch.mesh import make_spmm_mesh
    from repro.roofline import spmm_distributed_time
    from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                            partition_sellcs_rows, spmm_merge_distributed,
                            spmm_row_distributed)

    total = args.devices
    ndev = len(jax.devices())
    if ndev < total:
        raise SystemExit(
            f"the mesh needs {total} devices but jax sees only {ndev}; on "
            "CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{total} before launching")
    if args.algorithm and args.algorithm != "sellcs":
        raise SystemExit(
            f"--algorithm {args.algorithm} cannot be served on a mesh: the "
            "--devices path multiplies the SELL-C-σ slice stream "
            "(repro.spmm.distributed); drop --algorithm or pass sellcs")
    # the executable mesh format is the SELL-C-σ slice stream, so score the
    # (schedule × mesh × chunks) grid with sellcs's own byte footprint
    # (conversion cost is shared by every candidate, so it drops out);
    # --chunks pins the merge psum pipelining depth and --mesh the
    # (P_data, P_model) factorization instead of modelling them
    sellcs_bytes = _matrix_bytes_est("sellcs", stats)
    grid = distributed_schedule_grid(
        total, pinned_chunks=args.chunks if args.chunks > 0 else None,
        pinned_mesh=mesh_shape or (total, 1))
    # --compact-x on/off pins the sparsity-aware X gather; auto lets the
    # traffic model decide (off is scored first, so a modelled tie —
    # near-dense columns — refuses the gather)
    compacts = {"auto": (False, True), "on": (True,),
                "off": (False,)}[args.compact_x]
    (schedule, chunks, mesh_shape, compact) = min(
        ((t[0], t[1], t[2], cf) for t in grid for cf in compacts),
        key=lambda q: spmm_distributed_time(
            stats.m, stats.n, args.max_batch, q[2][0], q[0],
            matrix_bytes=sellcs_bytes, max_row_nnz=stats.max_row_nnz,
            num_chunks=q[1], model_devices=q[2][1], compact_x=q[3],
            nnz=stats.nnz))
    pd, pm = mesh_shape
    mesh = make_spmm_mesh(mesh_shape)
    sc = coo_to_sellcs(coo, c=_pick_chunk(stats.m, pd))
    impl = "ref" if args.impl == "auto" and \
        jax.default_backend() != "tpu" else args.impl
    if impl == "auto":
        impl = "pallas"
    mesh_tag = f"{pd}x{pm}mesh" if pm > 1 else f"{pd}dev"
    cx_tag = "/cx=on" if compact else ""
    if schedule == "row":
        sharded = partition_sellcs_rows(sc, pd, compact_x=compact)
        jitted = jax.jit(lambda X: spmm_row_distributed(
            sharded, X, mesh, impl=impl))
        label = f"sellcs+row@{mesh_tag}{cx_tag}"
    else:
        # the span plan is baked at partition time; the multiply reuses it
        sharded = partition_sellcs_nnz(sc, pd, num_chunks=chunks,
                                       compact_x=compact)
        jitted = jax.jit(lambda X: spmm_merge_distributed(
            sharded, X, mesh, impl=impl, num_chunks=chunks))
        label = f"sellcs+merge@{mesh_tag}/chunks={chunks}{cx_tag}"
    # the jitted closure keeps repeated flushes of one batch shape from
    # retracing the shard_map body.
    # price the gather with the map the multiply EXECUTES: the chunked
    # merge gathers through the chunk plan's re-dealt map, not the base
    # partition's (the re-deal hands every device rows of every span, so
    # the two touched sets differ)
    n_touched = None
    if compact:
        nt_src = (sharded.chunk_plan[3]
                  if sharded.chunk_plan is not None else sharded.n_touched)
        n_touched = float(np.mean(np.asarray(nt_src)))

    def spmm_fn(_mat, X):
        return jitted(X)
    return (sc, spmm_fn, label, schedule, chunks, mesh_shape, compact,
            n_touched)


def serve_spmv(args):
    """Sparse serving demo: batched (one SpMM per flush) vs sequential,
    optionally over a --devices mesh."""
    from repro.core import MachineSpec, convert, matrix_stats, select, spmv
    from repro.data import matrices
    from repro.roofline import spmm_arithmetic_intensity
    from repro.spmm import RequestBatcher

    suite = matrices.test_suite(scale=args.scale)
    if args.matrix not in suite:
        raise SystemExit(f"--matrix must be one of {sorted(suite)}")
    coo = matrices.as_coo(suite[args.matrix].make())
    stats = matrix_stats(coo)
    # num_spmvs counts k-RHS multiplies: batching turns `requests` SpMVs
    # into ceil(requests / max_batch) SpMM calls
    num_spmms = -(-args.requests // args.max_batch)
    spmm_fn = sched = None
    chunks = 1
    mesh_shape = None
    compact, n_touched = False, None
    if args.mesh:
        from repro.launch.mesh import parse_mesh_shape
        mesh_shape = parse_mesh_shape(args.mesh)
        args.devices = mesh_shape[0] * mesh_shape[1]
    if args.devices > 1:
        (mat, spmm_fn, algo, sched, chunks, mesh_shape, compact,
         n_touched) = _make_distributed_spmm(coo, stats, args, mesh_shape)
    else:
        algo = args.algorithm or select(stats, MachineSpec(1),
                                        num_spmvs=num_spmms,
                                        k=args.max_batch)
        mat = convert(coo, algo)
    print(f"[serve-spmv] matrix={args.matrix} m={stats.m} n={stats.n} "
          f"nnz={stats.nnz} algo={algo} max_batch={args.max_batch}")

    rng = np.random.default_rng(args.seed)
    xs = [jnp.asarray(rng.standard_normal(stats.n).astype(np.float32))
          for _ in range(args.requests)]

    batcher = RequestBatcher(mat, max_batch=args.max_batch, impl=args.impl,
                             spmm_fn=spmm_fn)
    for x in xs:
        batcher.submit(x)
    jax.block_until_ready(list(batcher.drain().values()))  # warmup/compile
    batcher2 = RequestBatcher(mat, max_batch=args.max_batch, impl=args.impl,
                              spmm_fn=spmm_fn)
    rids = [batcher2.submit(x) for x in xs]
    t0 = time.perf_counter()
    out = batcher2.drain()
    jax.block_until_ready(list(out.values()))
    t_batched = time.perf_counter() - t0

    jax.block_until_ready(spmv(mat, xs[0], impl=args.impl))  # warmup
    t0 = time.perf_counter()
    seq = [spmv(mat, x, impl=args.impl) for x in xs]
    jax.block_until_ready(seq)
    t_seq = time.perf_counter() - t0

    for rid, y in zip(rids, seq):
        np.testing.assert_allclose(np.asarray(out[rid]), np.asarray(y),
                                   rtol=2e-4, atol=2e-4)
    ai1 = spmm_arithmetic_intensity(stats.nnz, stats.m, stats.n, 1)
    aik = spmm_arithmetic_intensity(stats.nnz, stats.m, stats.n,
                                    args.max_batch)
    print(f"[serve-spmv] batched {t_batched*1e3:.1f} ms "
          f"({batcher2.flushes} SpMM calls) vs sequential "
          f"{t_seq*1e3:.1f} ms ({len(xs)} SpMV calls) — "
          f"speedup {t_seq/max(t_batched, 1e-9):.2f}x")
    print(f"[serve-spmv] modelled intensity {ai1:.3f} -> {aik:.3f} "
          f"flop/byte at k={args.max_batch}")
    if args.devices > 1:
        from repro.roofline import (spmm_distributed_collective_s,
                                    spmm_distributed_traffic)
        pd, pm = mesh_shape
        hbm, coll = spmm_distributed_traffic(
            stats.m, stats.n, args.max_batch, pd, sched,
            nnz=stats.nnz, max_row_nnz=stats.max_row_nnz, model_devices=pm,
            compact_x=compact, n_touched=n_touched)
        print(f"[serve-spmv] modelled per-device traffic: {hbm / 1e6:.2f} MB "
              f"HBM + {coll / 1e6:.2f} MB collective per flush "
              f"(mesh=({pd},{pm}), schedule={sched}, chunks={chunks}, "
              f"compact_x={'on' if compact else 'off'})")
        if compact:
            hbm_rep, _ = spmm_distributed_traffic(
                stats.m, stats.n, args.max_batch, pd, sched,
                nnz=stats.nnz, max_row_nnz=stats.max_row_nnz,
                model_devices=pm)
            print(f"[serve-spmv] compact gather: mean n_touched "
                  f"{n_touched:.0f} of n={stats.n} rows per shard — "
                  f"{(hbm_rep - hbm) / 1e6:.2f} MB HBM saved vs "
                  "replicated X per flush")
        if sched == "merge":
            mono, over = (spmm_distributed_collective_s(
                stats.m, stats.n, args.max_batch, pd, sched,
                nnz=stats.nnz, max_row_nnz=stats.max_row_nnz, num_chunks=c,
                model_devices=pm)
                for c in (1, chunks))
            print(f"[serve-spmv] exposed collective_s: {mono * 1e6:.2f} us "
                  f"monolithic -> {over * 1e6:.2f} us with {chunks} "
                  "chunk(s) pipelined under the slice stream")
    return t_batched, t_seq


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "spmv"), default="lm")
    ap.add_argument("--arch")
    # spmv-mode arguments (repro.spmm request batching)
    ap.add_argument("--matrix", default="mawi_like")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--algorithm", default=None,
                    help="force a format (default: core.select with k)")
    ap.add_argument("--devices", type=int, default=1,
                    help="serve each flush with a distributed SpMM over a "
                         "1-D data mesh of this many devices (schedule "
                         "chosen by core.select_distributed)")
    ap.add_argument("--mesh", default=None, metavar="Pd,Pm",
                    help="pin a 2-D (data, model) mesh factorization for "
                         "the distributed SpMM, e.g. 4,2 — the model axis "
                         "column-shards the X/Y k-slabs so per-device psum "
                         "and replicated-X bytes drop by Pm (overrides "
                         "--devices with Pd*Pm)")
    ap.add_argument("--chunks", type=int, default=0,
                    help="pipeline the merge-schedule psum into this many "
                         "chunks (0 = pick by the roofline overlap model; "
                         "ignored by the row schedule)")
    ap.add_argument("--compact-x", default="auto",
                    choices=("auto", "on", "off"), dest="compact_x",
                    help="sparsity-aware X gather for the distributed SpMM:"
                         " partition with per-shard column compaction so "
                         "each data shard gathers only the X rows its "
                         "nonzeros touch (auto = let the traffic model "
                         "decide when the gather beats replication)")
    ap.add_argument("--impl", default="auto",
                    choices=("auto", "ref", "pallas", "pallas_interpret"))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.mode == "spmv":
        return serve_spmv(args)
    if not args.arch:
        ap.error("--arch is required in lm mode")

    cfg = get_config(args.arch, reduced=args.reduced)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    S_max = P + G + (cfg.vision_tokens if cfg.frontend == "vision" else 0)
    rng = jax.random.PRNGKey(args.seed + 1)
    prompts = jax.random.randint(rng, (B, P), 0, cfg.vocab)
    vis = None
    if cfg.frontend == "vision":
        vis = jax.random.normal(rng, (B, cfg.vision_tokens, cfg.vision_dim))

    prefill_fn = jax.jit(lambda p, t, v: prefill(
        p, cfg, t, S_max, cache_dtype=jnp.float32, vision_embeds=v))
    decode_fn = jax.jit(lambda p, tok, c, pos: decode_step(
        p, cfg, tok, c, pos))

    t0 = time.perf_counter()
    logits, caches = prefill_fn(params, prompts, vis)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    offset = cfg.vision_tokens if cfg.frontend == "vision" else 0
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(G - 1):
        pos = jnp.full((B,), offset + P + i, jnp.int32)
        logits, caches = decode_fn(params, tok, caches, pos)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    tok.block_until_ready()
    t_decode = time.perf_counter() - t0

    gen = np.asarray(jnp.concatenate(out_tokens, axis=1))
    tps = B * (G - 1) / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} batch={B} prompt={P} gen={G}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; decode "
          f"{t_decode*1e3:.1f} ms ({tps:.1f} tok/s incl. compile)")
    print(f"[serve] sample generations (first 2 rows): {gen[:2].tolist()}")
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab)
    return gen


if __name__ == "__main__":
    main()
