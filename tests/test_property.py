"""Hypothesis property tests on the system's invariants.

Skipped wholesale when hypothesis is not installed (the container image
pins the jax toolchain but does not ship hypothesis); the deterministic
analogues of these invariants run in test_spmm.py / test_core_formats.py.
"""
import numpy as np
import pytest
import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (convert, coo_to_csr, hilbert_decode, hilbert_key,
                        merge_path_partition_np, morton_decode, morton_key,
                        spmv, spmv_dense_oracle, to_coo)
from repro.core.mergepath import balanced_row_bands

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


coords = st.integers(min_value=0, max_value=2 ** 16 - 1)


@given(st.lists(st.tuples(coords, coords), min_size=1, max_size=64))
def test_morton_bijective(pairs):
    r = np.array([p[0] for p in pairs])
    c = np.array([p[1] for p in pairs])
    k = morton_key(r, c)
    r2, c2 = morton_decode(k)
    assert np.array_equal(np.asarray(r2), r)
    assert np.array_equal(np.asarray(c2), c)


@given(st.lists(st.tuples(coords, coords), min_size=1, max_size=64))
def test_hilbert_bijective(pairs):
    r = np.array([p[0] for p in pairs])
    c = np.array([p[1] for p in pairs])
    k = hilbert_key(r, c, 16)
    r2, c2 = hilbert_decode(k, 16)
    assert np.array_equal(np.asarray(r2), r)
    assert np.array_equal(np.asarray(c2), c)


@given(st.integers(2, 6))
def test_hilbert_unit_steps(order):
    """Consecutive Hilbert indices are Manhattan-adjacent (the locality
    property the paper exploits, §4.1)."""
    n = 1 << order
    r, c = hilbert_decode(np.arange(n * n, dtype=np.uint32), order)
    d = np.abs(np.diff(np.asarray(r).astype(int))) + \
        np.abs(np.diff(np.asarray(c).astype(int)))
    assert np.all(d == 1)


@st.composite
def sparse_matrix(draw):
    m = draw(st.integers(1, 80))
    n = draw(st.integers(1, 80))
    nnz = draw(st.integers(0, 200))
    seed = draw(st.integers(0, 2 ** 20))
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, n, nnz)
    vals = rng.standard_normal(nnz).astype(np.float32)
    return rows, cols, vals, (m, n)


@given(sparse_matrix(),
       st.sampled_from(["csb", "bcohch", "mergeb", "parcrs"]))
def test_spmv_equals_oracle(mat, algo):
    rows, cols, vals, shape = mat
    coo = to_coo(rows, cols, vals, shape)
    kw = dict(beta=16) if algo not in ("parcrs", "merge") else {}
    y = spmv(convert(coo, algo, **kw), jnp.ones((shape[1],), jnp.float32),
             impl="ref")
    y_ref = spmv_dense_oracle(coo, jnp.ones((shape[1],), jnp.float32))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)


@given(sparse_matrix())
def test_spmv_linearity(mat):
    """A(ax + by) == a Ax + b Ay."""
    rows, cols, vals, shape = mat
    coo = to_coo(rows, cols, vals, shape)
    csr = coo_to_csr(coo)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(shape[1]).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(shape[1]).astype(np.float32))
    lhs = spmv(csr, 2.0 * x - 3.0 * y, impl="ref")
    rhs = 2.0 * spmv(csr, x, impl="ref") - 3.0 * spmv(csr, y, impl="ref")
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-3, atol=1e-3)


@given(sparse_matrix(), st.integers(1, 17))
def test_merge_partition_invariants(mat, P):
    """Coverage, monotonicity, and the equal-diagonal balance bound."""
    rows, cols, vals, shape = mat
    coo = to_coo(rows, cols, vals, shape)
    csr = coo_to_csr(coo)
    row_ptr = np.asarray(csr.row_ptr)
    rs, js = merge_path_partition_np(row_ptr, P)
    m, nnz = shape[0], int(row_ptr[-1])
    assert rs[0] == 0 and js[0] == 0
    assert rs[-1] == m and js[-1] == nnz
    assert np.all(np.diff(rs) >= 0) and np.all(np.diff(js) >= 0)
    work = np.diff(rs) + np.diff(js)
    assert work.max() <= -(-(m + nnz) // P) + 1


@given(sparse_matrix(), st.integers(1, 9))
def test_row_bands_cover(mat, P):
    rows, cols, vals, shape = mat
    coo = to_coo(rows, cols, vals, shape)
    csr = coo_to_csr(coo)
    bands = balanced_row_bands(np.asarray(csr.row_ptr), P)
    assert bands[0] == 0 and bands[-1] == shape[0]
    assert np.all(np.diff(bands) >= 0)


@given(sparse_matrix(),
       st.sampled_from(["csb", "csbh", "bcohc", "bcohch", "mergebh"]))
def test_conversion_roundtrip(mat, algo):
    """Blocked conversion preserves exactly the nonzero set (dense equal)."""
    rows, cols, vals, shape = mat
    coo = to_coo(rows, cols, vals, shape)
    bs = convert(coo, algo, beta=16)
    np.testing.assert_allclose(np.asarray(bs.to_coo().todense()),
                               np.asarray(coo.todense()),
                               rtol=1e-5, atol=1e-5)


@given(sparse_matrix(), st.integers(1, 9),
       st.sampled_from(["rows", "nnz"]))
def test_compact_col_map_roundtrip(mat, P, part_name):
    """ISSUE 5 satellite: for random COO matrices, the compact_x col_map
    relabeling followed by the gather (un-relabel through the map)
    reproduces ``SellCS.to_coo`` exactly — the compacted stream carries
    the same (data, global column) payload as the uncompacted one — and
    ``n_touched`` equals the true per-shard distinct-column count."""
    from repro.spmm import (coo_to_sellcs, partition_sellcs_nnz,
                            partition_sellcs_rows)
    rows, cols, vals, shape = mat
    coo = to_coo(rows, cols, vals, shape)
    sc = coo_to_sellcs(coo, c=8, sigma=16)
    part = partition_sellcs_rows if part_name == "rows" else \
        partition_sellcs_nnz
    plain = part(sc, P)
    comp = part(sc, P, compact_x=True)
    cm = np.asarray(comp.col_map)
    nt = np.asarray(comp.n_touched)
    counts = np.asarray(comp.row_counts)
    for p in range(P):
        ln = int(counts[p])
        pc = np.asarray(plain.cols)[p, :ln]
        cc = np.asarray(comp.cols)[p, :ln]
        # n_touched == true distinct-column count of this shard's stream
        assert int(nt[p]) == np.unique(pc).size
        if ln:
            # relabel -> gather reproduces the global column ids exactly
            assert cc.max() < int(nt[p])
            np.testing.assert_array_equal(cm[p][cc], pc)
    # the payload of the compacted shards reassembles to_coo's dense form:
    # scatter each shard's (data, un-relabeled col) pairs by row slot
    m, n = sc.shape
    dense = np.zeros((m, n), np.float64)
    oracle = np.asarray(sc.to_coo().todense(), np.float64)
    data = np.asarray(comp.data)
    so = np.asarray(comp.slice_of, np.int64)
    offs = np.asarray(comp.slice_offset, np.int64)
    row_perm = np.asarray(sc.row_perm, np.int64)
    C = sc.chunk
    for p in range(P):
        for w in range(int(counts[p])):
            gslice = so[p, w] + (offs[p] if comp.schedule == "row" else 0)
            for lane in range(C):
                r = row_perm[gslice * C + lane]
                if r < m and data[p, w, lane] != 0:
                    dense[r, cm[p][np.asarray(comp.cols)[p, w, lane]]] += \
                        data[p, w, lane]
    np.testing.assert_allclose(dense, oracle, rtol=1e-6, atol=1e-6)
