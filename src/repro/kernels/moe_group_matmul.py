"""Pallas TPU kernel: grouped (expert-blocked) GEMM for MoE dispatch.

The paper's load-balancing insight applied to the LM stack: the token->expert
assignment is an unstructured sparse matrix whose "row lengths" (tokens per
expert) are as skewed as a power-law graph's degrees. We sort tokens by
expert (convert step == the paper's conversion phase), pad each group to the
M-tile, and run one GEMM whose m-tiles carry a scalar-prefetched expert id
that selects the weight block — MegaBlocks-style block-sparse compute, with
the paper's uniform-work-quantum balancing (every m-tile costs the same).

grid = (m_tiles, n_tiles, k_tiles), k innermost ("arbitrary"); the output
block is revisited across k and accumulated in VMEM (f32), written once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

M_TILE, N_TILE, K_TILE = 128, 128, 128


def _kernel(tile_expert_ref, lhs_ref, rhs_ref, out_ref, acc_ref, *,
            nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        lhs_ref[...], rhs_ref[0],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "out_dtype"))
def moe_group_matmul_padded(lhs: jax.Array, rhs: jax.Array,
                            tile_expert: jax.Array, *,
                            out_dtype=jnp.float32,
                            interpret: bool = False) -> jax.Array:
    """lhs f[T_pad, K] (tokens sorted by expert, group-padded to M_TILE),
    rhs f[E, K, N], tile_expert int32[T_pad // M_TILE] -> out [T_pad, N]."""
    T_pad, K = lhs.shape
    E, K2, N = rhs.shape
    assert K == K2 and T_pad % M_TILE == 0
    assert K % K_TILE == 0 and N % N_TILE == 0, (K, N)
    nm, nn, nk = T_pad // M_TILE, N // N_TILE, K // K_TILE

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((M_TILE, K_TILE), lambda i, j, k, te: (i, k)),
            pl.BlockSpec((1, K_TILE, N_TILE),
                         lambda i, j, k, te: (te[i], k, j)),
        ],
        out_specs=pl.BlockSpec((M_TILE, N_TILE),
                               lambda i, j, k, te: (i, j)),
        scratch_shapes=[pltpu.VMEM((M_TILE, N_TILE), jnp.float32)],
    )
    params = tpu_compiler_params(
        dimension_semantics=("parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T_pad, N), out_dtype),
        compiler_params=params,
        interpret=interpret,
    )(tile_expert, lhs, rhs)
