"""repro.checkpoint — atomic sharded checkpointing."""
from . import checkpoint
from .checkpoint import latest_step, restore, restore_meta, save

__all__ = ["checkpoint", "save", "restore", "restore_meta", "latest_step"]
