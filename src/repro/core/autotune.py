"""Automatic parameter tuning — the paper's §8 future work, implemented.

"We suspect that these optimizations can still provide a relevant speedup,
 but they will be largely machine-specific ... it would be interesting to
 look into automatically tuning these parameters, like performed in the
 pOSKI library." (paper, §8)

The tuner sweeps (algorithm, block size beta) over a measurement budget,
scoring each candidate with the paper's own economics: total cost =
conversion + num_spmvs × per-multiply, where per-multiply is either
measured (jitted XLA wall time on this backend) or modelled (the TPU
tile-stream roofline from benchmarks.spmv_tables) — pOSKI-style hybrid
offline/online tuning.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .convert import ALGORITHM_SPECS, block_size_for, convert
from .formats import COO
from .spmv import spmv

DEFAULT_ALGOS = ("parcrs", "csb", "csbh", "bcohc", "bcohch", "mergeb")


@dataclasses.dataclass(frozen=True)
class TuneResult:
    algorithm: str
    beta: Optional[int]
    convert_s: float
    spmv_s: float                # per-multiply (one SpMM when k > 1),
                                 #   measured on ONE device
    total_s: float               # convert + num_spmvs * spmv (modelled
                                 #   distributed per-multiply when
                                 #   num_devices > 1)
    tpu_model_s: Optional[float] = None
    k: int = 1                   # right-hand sides per multiply
    k_tile: Optional[int] = None  # roofline-chosen column block (k > 1)
    num_devices: int = 1          # mesh size the score targets
    schedule: Optional[str] = None  # "row" | "merge" (num_devices > 1)
    dist_model_s: Optional[float] = None  # modelled distributed multiply
    num_chunks: Optional[int] = None  # psum pipelining depth ("merge";
                                      #   1 = monolithic fixup)
    mesh_shape: Optional[Tuple[int, int]] = None
                                      # (P_data, P_model) factorization the
                                      #   distributed score picked
    compact_x: Optional[bool] = None  # sparsity-aware X gather picked by
                                      #   the distributed score (sellcs
                                      #   only; None off the mesh)
    structure: Optional[str] = None   # "symmetric" when one-triangle
                                      #   storage won the distributed score
                                      #   (sellcs on A == A^T only)
    gather: Optional[str] = None      # compact-X gather schedule the
                                      #   distributed score picked
                                      #   ("upfront"|"overlap"|"fused";
                                      #   None off the mesh)
    residual: Optional[float] = None  # observed/modeled correction the
                                      #   feedback ledger applied to this
                                      #   result's winning distributed
                                      #   score (None: no feedback, or no
                                      #   matching measurement yet)


def _measure(fn: Callable, reps: int = 5, warmup: int = 2) -> float:
    """One timing protocol for the whole repo: ``obs.timing.time_min_of_n``
    (the paper's §5.2 min-of-N discipline) — autotune measurements stamp
    the same reps/warmup semantics as the harness and serve headlines."""
    from repro.obs.timing import time_min_of_n
    return time_min_of_n(fn, reps=reps, warmup=warmup).best_s


def autotune(coo: COO, *, num_spmvs: int = 100,
             algorithms: Tuple[str, ...] = DEFAULT_ALGOS,
             betas: Optional[List[int]] = None,
             reps: int = 5, tpu_model: bool = False, k: int = 1,
             num_devices: int = 1, feedback=None, spec=None
             ) -> Tuple[TuneResult, List[TuneResult]]:
    """Return (best, all_results) over the candidate grid.

    ``k > 1`` tunes the SpMM engine instead: each measured multiply is one
    ``A @ X`` with ``X: [n, k]`` (via ``repro.spmm``), ``algorithms`` may
    include ``"sellcs"``, and every result records the roofline-chosen
    ``k_tile``. ``k = 1`` is byte-for-byte the original SpMV tuner.

    ``num_devices > 1`` scores the (format × schedule × k) grid jointly,
    pOSKI-style hybrid: the per-multiply time is still *measured* on this
    one device, then scaled by the ``repro.roofline`` distributed traffic
    model (replicated-X bytes, dense-row imbalance for "row", psum bytes
    for "merge") — the tuner cannot run the mesh it is tuning for, but the
    model ratio carries the measured stream rate across. Each result then
    records the winning cross-device ``schedule`` and the modelled
    distributed per-multiply seconds in ``dist_model_s``.

    ``feedback`` closes the loop: pass a ``repro.obs.ResidualLedger``
    (e.g. loaded from a ``serve --metrics`` run) and every distributed
    grid candidate's modelled seconds are multiplied by
    ``feedback.correction(**choice_labels(schedule, num_chunks,
    mesh_shape, compact_x))`` — the geometric-mean observed/modeled
    residual of matching measurements — before the grid min is taken, so
    a config the model flatters gets re-ranked by what the machine
    actually did. The applied factor is recorded in
    ``TuneResult.residual`` (None where no measurement matched).

    ``spec`` (a :class:`repro.core.PlanSpec`) carries the distributed pins
    in one object: its ``num_devices`` replaces the kwarg and its
    ``mesh_shape`` / ``num_chunks`` / ``schedule`` / ``compact_x`` fields
    restrict the rescoring grid — the old kwargs stay as shims."""
    if spec is not None:
        spec = spec.canonical()
        num_devices = spec.num_devices
    rng = np.random.default_rng(0)
    if k > 1:
        from repro.spmm import choose_k_tile, spmm
        x = jnp.asarray(rng.standard_normal(
            (coo.shape[1], k)).astype(np.float32))
        k_tile = choose_k_tile(coo.shape, k, nnz=coo.nnz)

        def measure(mat):
            return _measure(lambda: spmm(mat, x, impl="ref"), reps)
    else:
        x = jnp.asarray(rng.standard_normal(
            coo.shape[1]).astype(np.float32))
        k_tile = None

        def measure(mat):
            return _measure(lambda: spmv(mat, x, impl="ref"), reps)

    results: List[TuneResult] = []
    for algo in algorithms:
        aspec = ALGORITHM_SPECS[algo]
        if not aspec.blocked:
            t0 = time.perf_counter()
            mat = convert(coo, algo)
            conv_s = time.perf_counter() - t0
            spmv_s = measure(mat)
            results.append(TuneResult(algo, None, conv_s, spmv_s,
                                      conv_s + num_spmvs * spmv_s,
                                      k=k, k_tile=k_tile))
            continue
        base = block_size_for(coo.shape,
                              in_block_format=aspec.in_block_format)
        cand = betas or sorted({max(base // 4, 16), max(base // 2, 16),
                                base, min(base * 2, 1 << 16)})
        for beta in cand:
            kw = dict(beta=beta)
            if aspec.scheduling == "static_rows":
                kw["num_bands"] = 8
            t0 = time.perf_counter()
            mat = convert(coo, algo, **kw)
            conv_s = time.perf_counter() - t0
            spmv_s = measure(mat)
            model_s = None
            # the TPU tile-stream model prices a single-vector SpMV; at
            # k > 1 the measurement is one k-RHS SpMM — different units, so
            # the model is only recorded for the SpMV case.
            if tpu_model and k == 1:
                from repro.kernels.tiling import coo_to_tiled
                from benchmarks.spmv_tables import tpu_model_time
                try:
                    model_s = tpu_model_time(
                        coo_to_tiled(coo, algo, beta=max(beta, 128)))
                except MemoryError:
                    model_s = float("inf")
            results.append(TuneResult(algo, beta, conv_s, spmv_s,
                                      conv_s + num_spmvs * spmv_s,
                                      model_s, k=k, k_tile=k_tile))
    if num_devices > 1:
        from .selector import matrix_stats
        stats = matrix_stats(coo)       # one O(nnz) pass for all results
        results = [_rescore_distributed(r, stats, k, num_devices, num_spmvs,
                                        feedback=feedback, spec=spec)
                   for r in results]
    best = min(results, key=lambda r: r.total_s)
    return best, results


def _rescore_distributed(r: TuneResult, stats, k: int, num_devices: int,
                         num_spmvs: int, feedback=None,
                         spec=None) -> TuneResult:
    """Scale a measured single-device result across the mesh with the
    roofline traffic model and pick the best (schedule, mesh shape,
    num_chunks, compact_x) for it — "merge" sweeps the psum pipelining
    depths, "row" has no collective to chunk, both sweep every
    (P_data, P_model) factorization of the mesh, and the SELL-C-σ format
    additionally scores the sparsity-aware X gather (compact=False is
    scored first, so a dense-columns tie refuses compaction).

    With ``feedback`` (a ``repro.obs.ResidualLedger``), each candidate's
    modelled seconds are multiplied by the ledger's geometric-mean
    observed/modeled residual for that candidate's labels before the min
    — measured reality outvotes the streaming-bytes story wherever a
    measurement exists. The winning candidate's correction lands in
    ``TuneResult.residual``."""
    from repro.roofline.analysis import spmm_distributed_time
    from .selector import (GATHER_CANDIDATES, _matrix_bytes_est,
                           distributed_schedule_grid)
    mat_bytes = _matrix_bytes_est(r.algorithm, stats)
    base_s = spmm_distributed_time(stats.m, stats.n, k, 1, "row",
                                   matrix_bytes=mat_bytes)
    grid = distributed_schedule_grid(num_devices, spec=spec)
    compacts = (False, True) if r.algorithm == "sellcs" else (False,)
    if spec is not None and spec.compact_x is not None:
        compacts = ((spec.compact_x,) if r.algorithm == "sellcs"
                    else (False,))
    # one-triangle storage: executable on sellcs, convertible only when
    # A == A^T; "general" scored first so symmetry must strictly win
    structures = ("general",)
    if r.algorithm == "sellcs" and getattr(stats, "symmetric", False):
        structures = ("general", "symmetric")
    if spec is not None and spec.structure is not None:
        structures = ((spec.structure,) if r.algorithm == "sellcs"
                      else ("general",))

    def gathers_for(cf):
        # the gather schedule only exists on the compact SELL-C-σ path;
        # "upfront" first so min()'s first-wins tie-break refuses hiding
        # that buys nothing
        if not (cf and r.algorithm == "sellcs"):
            return ("upfront",)
        if spec is not None and spec.gather is not None:
            return (spec.gather,)
        return GATHER_CANDIDATES

    def corrected(s, nc, mesh, cf, st, gm):
        model_s = spmm_distributed_time(
            stats.m, stats.n, k, mesh[0], s, matrix_bytes=mat_bytes,
            max_row_nnz=stats.max_row_nnz, num_chunks=nc,
            model_devices=mesh[1], compact_x=cf, nnz=stats.nnz,
            structure=st, gather=gm)
        corr = 1.0
        if feedback is not None:
            from repro.obs import choice_labels
            corr = feedback.correction(**choice_labels(
                schedule=s, num_chunks=nc, mesh_shape=mesh, compact_x=cf,
                structure=st, gather=gm))
        return model_s * corr, corr

    ((schedule, num_chunks, mesh_shape, compact, structure, gmode),
     (model_s, corr)) = min(
        (((s, nc, mesh, cf, st, gm), corrected(s, nc, mesh, cf, st, gm))
         for s, nc, mesh in grid for cf in compacts for st in structures
         for gm in gathers_for(cf)),
        key=lambda t: t[1][0])
    per_multiply = r.spmv_s * (model_s / max(base_s, 1e-30))
    return dataclasses.replace(
        r, total_s=r.convert_s + num_spmvs * per_multiply,
        num_devices=num_devices, schedule=schedule, dist_model_s=model_s,
        num_chunks=num_chunks, mesh_shape=mesh_shape, compact_x=compact,
        structure=structure, gather=gmode if compact else None,
        residual=corr if feedback is not None and corr != 1.0 else None)
