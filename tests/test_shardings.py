"""Sharding rule unit tests (no devices needed beyond 1 — specs only)."""
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch import shardings as shd


class FakeMesh:
    """Duck-typed mesh: only axis_names/devices.shape are consulted."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


MESH = FakeMesh((16, 16), ("data", "model"))


def spec(key, shape, grouped=False, profile="tp"):
    return shd.param_spec_for(key, shape, MESH, grouped, profile)


def test_attention_weights_2d_sharded():
    assert spec("groups/0/mixer/wq/w", (16, 2048, 4096), True) == \
        P(None, "data", "model")
    assert spec("groups/0/mixer/wo/w", (16, 4096, 2048), True) == \
        P(None, "model", "data")
    assert spec("groups/0/mixer/wq/b", (16, 4096), True) == P(None, "model")


def test_embed_vocab_parallel_with_guard():
    assert spec("embed", (49152, 4608)) == P("model", "data")
    # 50280 % 16 != 0 -> vocab dim replicated, d survives
    assert spec("embed", (50280, 2048)) == P(None, "data")


def test_moe_expert_parallel_and_fallback():
    # 32 experts / 16 -> EP sharding
    assert spec("groups/0/mlp/w_gate", (24, 32, 1024, 512), True) == \
        P(None, "model", "data", None)
    # 8 experts / 16 -> ffn-parallel fallback
    assert spec("groups/0/mlp/w_gate", (56, 8, 6144, 16384), True) == \
        P(None, None, "data", "model")
    assert spec("groups/0/mlp/w_down", (56, 8, 16384, 6144), True) == \
        P(None, None, "model", "data")


def test_ssm_rules():
    assert spec("groups/0/mixer/in_proj/w", (48, 2048, 8500), True)[1] == \
        "data"
    assert spec("groups/0/mixer/A_log", (48, 64), True) == P(None, "model")
    assert spec("groups/0/mixer/conv/w", (48, 4, 4352), True) == \
        P(None, None, "model")


def test_norms_replicated():
    assert spec("groups/0/norm1/scale", (16, 2048), True) == P(None, None)
    assert spec("final_norm/scale", (2048,)) == P(None)


def test_fsdp_profile_shards_largest_dim_over_all():
    s = spec("groups/0/mixer/wq/w", (16, 2048, 4096), True, profile="fsdp")
    assert s == P(None, None, ("data", "model"))
    s2 = spec("embed", (128256, 2048), profile="fsdp")
    assert s2 == P(("data", "model"), None)
    # biases replicate
    assert spec("groups/0/mixer/wq/b", (16, 4096), True,
                profile="fsdp") == P(None, None)


def test_guard_never_emits_nondividing_axis():
    for shape in [(16, 2049, 4095), (16, 3, 5)]:
        s = spec("groups/0/mixer/wq/w", shape, True)
        for dim, ax in zip(shape[1:], tuple(s)[1:]):
            if ax is not None:
                size = 16
                assert dim % size == 0
