"""repro.kernels — Pallas TPU kernels for the perf-critical compute paths:
blocked SpMV (bsr_spmv), merge-path SpMV (merge_spmv) and the MoE grouped
GEMM (moe_group_matmul). Each ships a jit wrapper (ops) and a pure-jnp
oracle (ref)."""
from . import ops, ref
from .tiling import TILE_C, TILE_R, TiledSparse, coo_to_tiled
from .merge_spmv import MergePlan, merge_plan

__all__ = ["ops", "ref", "TiledSparse", "coo_to_tiled", "TILE_R", "TILE_C",
           "MergePlan", "merge_plan"]
