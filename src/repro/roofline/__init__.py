"""repro.roofline — roofline analysis from compiled dry-run artifacts."""
from . import analysis
from .analysis import (Roofline, collective_bytes_total, from_compiled,
                       parse_collective_bytes)

__all__ = ["analysis", "Roofline", "from_compiled",
           "parse_collective_bytes", "collective_bytes_total"]
