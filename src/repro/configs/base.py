"""Config registry: assigned architectures x input shapes.

Each architecture lives in its own module (``src/repro/configs/<id>.py``,
dashes/dots -> underscores) exporting ``CONFIG`` (exact published config) and
``REDUCED`` (CPU smoke-test scale). SHAPES are the assigned input shapes;
``long_500k`` only applies to sub-quadratic archs (DESIGN §4)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.models.model import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

ARCH_IDS = [
    "starcoder2-7b", "qwen2.5-3b", "qwen3-4b", "llama3.2-1b", "mamba2-1.3b",
    "granite-moe-1b-a400m", "mixtral-8x22b", "musicgen-large",
    "jamba-1.5-large-398b", "internvl2-2b",
]


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.REDUCED if reduced else mod.CONFIG


def registry(reduced: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, reduced) for a in ARCH_IDS}


def long_context_capable(cfg: ModelConfig) -> bool:
    """True unless the stack is *pure* full attention: SSM/hybrid stacks
    (attention is a bounded fraction of layers) and SWA stacks (window-
    bounded KV) run long_500k; pure full-attention archs skip it
    (DESIGN §4)."""
    pure_full_attn = all(m == "attn" for m in cfg.block_pattern) \
        and cfg.sliding_window == 0
    return not pure_full_attn


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skipped cells flagged."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            skip = s.name == "long_500k" and not long_context_capable(cfg)
            if include_skipped or not skip:
                out.append((a, s.name, skip))
    return out
