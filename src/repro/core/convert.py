"""Storage format conversion (paper §5.1, Tables 6.4/6.5).

Conversion = (1) sort nonzeros into the target ordering, (2) populate the
target arrays (compress indices, build pointers). Step (1) dominates —
O(nnz log nnz) — exactly as in the paper. Conversions run host-side (numpy)
as a preprocessing phase, mirroring the paper's separation of conversion from
multiplication; the resulting pytrees are device arrays ready for jit/Pallas.

The nine paper algorithms map to conversion presets in ``ALGORITHM_SPECS``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from . import curves
from .formats import (BICRS, BLOCK_STORAGE_BICRS, BLOCK_STORAGE_CSR,
                      BLOCK_STORAGE_DENSE_PTR, COO, CSR, ICRS,
                      IN_BLOCK_ICRS, IN_BLOCK_PACKED_COO, BlockedSparse)
from .mergepath import balanced_row_bands

# --------------------------------------------------------------------------
# Algorithm presets: the 3 state-of-the-art + 6 hybrids (paper §3, §4)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AlgorithmSpec:
    name: str
    blocked: bool
    block_storage: str = BLOCK_STORAGE_DENSE_PTR
    block_order: str = "row"            # storage/visit order of blocks
    in_block_format: str = IN_BLOCK_PACKED_COO
    in_block_order: str = "row"
    scheduling: str = "dynamic"         # dynamic | static_rows | merge
    note: str = ""


ALGORITHM_SPECS = {
    # CRS-based
    "parcrs": AlgorithmSpec("parcrs", blocked=False, scheduling="dynamic",
                            note="OpenMP-dynamic row loop -> row-chunk grid"),
    "merge": AlgorithmSpec("merge", blocked=False, scheduling="merge",
                           note="merge-path on flat CSR [Merrill&Garland]"),
    # CSB family
    "csb": AlgorithmSpec("csb", True, BLOCK_STORAGE_DENSE_PTR, "row",
                         IN_BLOCK_PACKED_COO, "morton", "dynamic",
                         "Buluc et al. 2009"),
    "csbh": AlgorithmSpec("csbh", True, BLOCK_STORAGE_DENSE_PTR, "row",
                          IN_BLOCK_PACKED_COO, "hilbert", "dynamic",
                          "hybrid #1: CSB with Hilbert inside blocks"),
    # BCOH family
    "bcoh": AlgorithmSpec("bcoh", True, BLOCK_STORAGE_BICRS, "hilbert",
                          IN_BLOCK_ICRS, "row", "static_rows",
                          "Yzelman&Roose 2014 (in-block ICRS: storage model "
                          "only on TPU, see DESIGN §2.4)"),
    "bcohc": AlgorithmSpec("bcohc", True, BLOCK_STORAGE_BICRS, "hilbert",
                           IN_BLOCK_PACKED_COO, "row", "static_rows",
                           "hybrid #2: BCOH with packed-COO compression"),
    "bcohch": AlgorithmSpec("bcohch", True, BLOCK_STORAGE_BICRS, "hilbert",
                            IN_BLOCK_PACKED_COO, "hilbert", "static_rows",
                            "hybrid #3: per-band global Hilbert sort"),
    "bcohchp": AlgorithmSpec("bcohchp", True, BLOCK_STORAGE_DENSE_PTR,
                             "hilbert", IN_BLOCK_PACKED_COO, "hilbert",
                             "static_rows",
                             "hybrid #4: dense Hilbert-ordered block ptr"),
    # Merge-blocked family
    "mergeb": AlgorithmSpec("mergeb", True, BLOCK_STORAGE_CSR, "row",
                            IN_BLOCK_PACKED_COO, "row", "merge",
                            "hybrid #5: merge-path over block CSR"),
    "mergebh": AlgorithmSpec("mergebh", True, BLOCK_STORAGE_CSR, "row",
                             IN_BLOCK_PACKED_COO, "hilbert", "merge",
                             "hybrid #6: + Hilbert inside blocks"),
    # SELL-C-σ (repro.spmm): the survey literature's row-sorted sliced-ELL
    # answer to row-length skew; the storage format of the multi-RHS engine.
    "sellcs": AlgorithmSpec("sellcs", blocked=False, scheduling="dynamic",
                            note="SELL-C-σ slices (Kreutzer et al.; "
                                 "Gao et al. arXiv:2404.06047) — "
                                 "converted by repro.spmm.sellcs"),
}

# VMEM working-set budget for choosing beta (the TPU analogue of "x and y
# regions fit comfortably in L2", paper §3.1). Conservative v5e figure.
VMEM_BUDGET_BYTES = 8 * 2 ** 20


def block_size_for(shape: Tuple[int, int], *, in_block_format: str,
                   dtype_bytes: int = 4,
                   vmem_budget: int = VMEM_BUDGET_BYTES,
                   min_beta: int = 1) -> int:
    """Paper Eq. (3.1) + constraints: start at the upper bound
    log2(beta) = 3 + ceil(log2(sqrt(n))) and lower until (a) packed indices
    fit 16 bits (15 for ICRS overflow headroom), (b) the x and y slabs fit
    the VMEM budget."""
    n = max(shape[1], 2)
    ub = 3 + math.ceil(math.log2(math.sqrt(n)))
    cap = 15 if in_block_format == IN_BLOCK_ICRS else 16
    log_beta = min(ub, cap)
    while log_beta > 0:
        beta = 1 << log_beta
        slabs = 2 * beta * dtype_bytes
        if slabs <= vmem_budget:
            break
        log_beta -= 1
    return max(1 << log_beta, min_beta)


# --------------------------------------------------------------------------
# Flat conversions
# --------------------------------------------------------------------------
def coo_canonicalize_np(rows, cols, vals, shape):
    """Sort row-major and sum duplicates (host)."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    if rows.size:
        key = rows * shape[1] + cols
        uniq, inv = np.unique(key, return_inverse=True)
        if uniq.size != rows.size:
            out = np.zeros(uniq.size, vals.dtype)
            np.add.at(out, inv, vals)
            rows, cols, vals = uniq // shape[1], uniq % shape[1], out
    return rows.astype(np.int32), cols.astype(np.int32), vals


def to_coo(rows, cols, vals, shape, dtype=jnp.float32) -> COO:
    r, c, v = coo_canonicalize_np(rows, cols, vals, shape)
    return COO(jnp.asarray(r), jnp.asarray(c),
               jnp.asarray(v, dtype), tuple(shape))


def coo_to_csr(coo: COO) -> CSR:
    m, n = coo.shape
    rows = np.asarray(coo.rows)
    cols = np.asarray(coo.cols)
    vals = np.asarray(coo.data)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    row_ptr = np.zeros(m + 1, np.int32)
    np.cumsum(np.bincount(rows, minlength=m), out=row_ptr[1:])
    return CSR(jnp.asarray(row_ptr), jnp.asarray(cols.astype(np.int32)),
               jnp.asarray(vals), coo.shape)


def _encode_incremental(rows, cols, n):
    """Shared ICRS/BICRS encoder. Returns (col_start, col_inc, row_jump).
    col_inc[k] = col(k+1) - col(k), plus n exactly once when the row changes
    (signals the decoder to consume the next row_jump). row_jump =
    [start_row, delta_1, ...]. The final increment is a dummy 0."""
    nnz = rows.size
    if nnz == 0:
        return 0, np.zeros(0, np.int32), np.zeros(1, np.int32)
    col_inc = np.zeros(nnz, np.int64)
    dcol = cols[1:].astype(np.int64) - cols[:-1].astype(np.int64)
    drow = rows[1:].astype(np.int64) - rows[:-1].astype(np.int64)
    change = drow != 0
    col_inc[:-1] = dcol + np.where(change, n, 0)
    row_jump = np.concatenate([[rows[0]], drow[change]])
    return int(cols[0]), col_inc.astype(np.int32), row_jump.astype(np.int32)


def coo_to_icrs(coo: COO) -> ICRS:
    rows = np.asarray(coo.rows)
    cols = np.asarray(coo.cols)
    vals = np.asarray(coo.data)
    order = np.lexsort((cols, rows))       # ICRS requires row-major order
    rows, cols, vals = rows[order], cols[order], vals[order]
    cs, ci, rj = _encode_incremental(rows, cols, coo.shape[1])
    assert np.all(ci >= 0) if ci.size else True
    return ICRS(jnp.int32(cs), jnp.asarray(ci), jnp.asarray(rj),
                jnp.asarray(vals), coo.shape)


def coo_to_bicrs(coo: COO, order: str = "hilbert") -> BICRS:
    rows = np.asarray(coo.rows)
    cols = np.asarray(coo.cols)
    vals = np.asarray(coo.data)
    if rows.size:
        bits = max(int(np.ceil(np.log2(max(max(coo.shape), 2)))), 1)
        if order == "hilbert":
            key = curves.hilbert_key_np(rows, cols, bits)
        elif order == "morton":
            key = _morton_key_np(rows, cols, bits)
        else:
            key = rows.astype(np.int64) * coo.shape[1] + cols
        perm = np.argsort(key, kind="stable")
        rows, cols, vals = rows[perm], cols[perm], vals[perm]
    cs, ci, rj = _encode_incremental(rows, cols, coo.shape[1])
    return BICRS(jnp.int32(cs), jnp.asarray(ci), jnp.asarray(rj),
                 jnp.asarray(vals), coo.shape)


def _morton_key_np(rows, cols, bits):
    r = np.asarray(rows, np.uint64)
    c = np.asarray(cols, np.uint64)
    key = np.zeros(r.shape, np.uint64)
    for b in range(bits):
        key |= ((r >> np.uint64(b)) & np.uint64(1)) << np.uint64(2 * b + 1)
        key |= ((c >> np.uint64(b)) & np.uint64(1)) << np.uint64(2 * b)
    return key


# --------------------------------------------------------------------------
# Blocked conversion (the heart of CSB/BCOH/hybrids)
# --------------------------------------------------------------------------
def coo_to_blocked(coo: COO, algorithm: str, *, beta: Optional[int] = None,
                   num_bands: int = 0) -> BlockedSparse:
    """Convert COO to the blocked format of ``algorithm`` (one of the
    blocked ALGORITHM_SPECS keys). ``num_bands`` > 0 enables the BCOH static
    row distribution (bands are block-row aligned so blocks never straddle
    bands)."""
    spec = ALGORITHM_SPECS[algorithm]
    if not spec.blocked:
        raise ValueError(f"{algorithm} is not a blocked algorithm")
    m, n = coo.shape
    if beta is None:
        beta = block_size_for(coo.shape, in_block_format=spec.in_block_format)
    Mb = -(-m // beta)
    Nb = -(-n // beta)

    rows = np.asarray(coo.rows).astype(np.int64)
    cols = np.asarray(coo.cols).astype(np.int64)
    vals = np.asarray(coo.data)
    br, bc = rows // beta, cols // beta
    lr, lc = rows % beta, cols % beta

    grid_bits = max(int(np.ceil(np.log2(max(Mb, Nb, 2)))), 1)
    local_bits = max(int(np.ceil(np.log2(max(beta, 2)))), 1)

    # ---- sort key: (band, block_key, in_block_key) ------------------------
    if num_bands > 0:
        # block-row-aligned equal-nnz bands (paper §3.2, adapted so a block
        # never straddles a band)
        blk_row_ptr = np.zeros(Mb + 1, np.int64)
        np.cumsum(np.bincount(br.astype(np.int64), minlength=Mb),
                  out=blk_row_ptr[1:])
        bands = balanced_row_bands(blk_row_ptr, num_bands)
        band_of_nnz = (np.searchsorted(bands, br, side="right") - 1)
    else:
        bands = np.array([0, Mb], np.int32)
        band_of_nnz = np.zeros(rows.size, np.int64)

    if spec.block_order == "hilbert":
        block_key = curves.hilbert_key_np(br, bc, grid_bits)
    elif spec.block_order == "morton":
        block_key = _morton_key_np(br, bc, grid_bits)
    else:
        block_key = br * Nb + bc

    if spec.in_block_order == "hilbert":
        if spec.block_order == "hilbert":
            # BCOHCH/BCOHCHP: one global Hilbert sort per band (paper §4.2).
            # Since beta is a power of two, every block is a contiguous,
            # aligned segment of the global curve, and the induced block
            # order equals the Hilbert order of the block grid — so sorting
            # by the global key yields both orders at once (the recursive
            # property the paper exploits).
            glob_bits = max(int(np.ceil(np.log2(max(m, n, 2)))), local_bits)
            in_key = curves.hilbert_key_np(rows, cols, glob_bits)
        else:
            in_key = curves.hilbert_key_np(lr, lc, local_bits)
    elif spec.in_block_order == "morton":
        in_key = _morton_key_np(lr, lc, local_bits)
    else:
        in_key = lr * beta + lc
    perm = np.lexsort((in_key, block_key, band_of_nnz))
    br, bc, lr, lc, vals = br[perm], bc[perm], lr[perm], lc[perm], vals[perm]
    block_key = block_key[perm]
    band_of_nnz = band_of_nnz[perm]

    # ---- canonical block arrays ------------------------------------------
    if rows.size:
        bkey_sorted = band_of_nnz * (1 << (2 * grid_bits + 2)) + \
            block_key.astype(np.int64)
        new_blk = np.empty(rows.size, bool)
        new_blk[0] = True
        new_blk[1:] = bkey_sorted[1:] != bkey_sorted[:-1]
        starts = np.flatnonzero(new_blk)
        block_rows = br[starts].astype(np.int32)
        block_cols = bc[starts].astype(np.int32)
        block_ptr = np.concatenate([starts, [rows.size]]).astype(np.int32)
    else:
        block_rows = np.zeros(0, np.int32)
        block_cols = np.zeros(0, np.int32)
        block_ptr = np.zeros(1, np.int32)
    packed = ((lr.astype(np.uint32) << np.uint32(16))
              | lc.astype(np.uint32))

    # ---- variant-specific storage arrays ----------------------------------
    grid_ptr = blk_col_inc = blk_row_jump = blk_row_ptr_arr = None
    if spec.block_storage == BLOCK_STORAGE_DENSE_PTR:
        # dense pointer per grid cell, in the storage block order
        gr, gc = np.divmod(np.arange(Mb * Nb, dtype=np.int64), Nb)
        if spec.block_order == "hilbert":
            cell_key = curves.hilbert_key_np(gr, gc, grid_bits)
        elif spec.block_order == "morton":
            cell_key = _morton_key_np(gr, gc, grid_bits).astype(np.int64)
        else:
            cell_key = gr * Nb + gc
        cell_rank = np.argsort(np.argsort(cell_key, kind="stable"))
        nnz_per_cell = np.zeros(Mb * Nb, np.int64)
        if rows.size:
            counts = (block_ptr[1:] - block_ptr[:-1]).astype(np.int64)
            cell_of_block = cell_rank[block_rows.astype(np.int64) * Nb
                                      + block_cols]
            nnz_per_cell[cell_of_block] = counts
        grid_ptr = np.zeros(Mb * Nb + 1, np.int64)
        np.cumsum(nnz_per_cell, out=grid_ptr[1:])
        grid_ptr = grid_ptr.astype(np.int32)
    elif spec.block_storage == BLOCK_STORAGE_BICRS:
        _, ci, rj = _encode_incremental(block_rows.astype(np.int64),
                                        block_cols.astype(np.int64), Nb)
        blk_col_inc, blk_row_jump = ci, rj
    else:  # block CSR (MergeB)
        blk_row_ptr_arr = np.zeros(Mb + 1, np.int64)
        np.cumsum(np.bincount(block_rows.astype(np.int64), minlength=Mb),
                  out=blk_row_ptr_arr[1:])
        blk_row_ptr_arr = blk_row_ptr_arr.astype(np.int32)

    z = np.zeros(0, np.int32)
    return BlockedSparse(
        block_rows=jnp.asarray(block_rows),
        block_cols=jnp.asarray(block_cols),
        block_ptr=jnp.asarray(block_ptr),
        packed=jnp.asarray(packed),
        data=jnp.asarray(vals),
        grid_ptr=jnp.asarray(grid_ptr if grid_ptr is not None else z),
        blk_col_inc=jnp.asarray(blk_col_inc if blk_col_inc is not None else z),
        blk_row_jump=jnp.asarray(
            blk_row_jump if blk_row_jump is not None else z),
        blk_row_ptr=jnp.asarray(
            blk_row_ptr_arr if blk_row_ptr_arr is not None else z),
        shape=coo.shape, beta=int(beta), grid=(int(Mb), int(Nb)),
        block_storage=spec.block_storage, block_order=spec.block_order,
        in_block_format=spec.in_block_format,
        in_block_order=spec.in_block_order,
        row_bands=tuple(int(b) for b in bands),
    )


def convert(coo: COO, algorithm: str, **kw):
    """Uniform entry point: COO -> the storage format ``algorithm`` needs.

    ``sellcs`` round-trips through ``repro.spmm.sellcs`` (kw: ``c``,
    ``sigma``); blocked algorithms take ``beta``/``num_bands``; the flat
    CRS-based algorithms ignore kw."""
    spec = ALGORITHM_SPECS[algorithm]
    if algorithm == "sellcs":
        from repro.spmm.sellcs import coo_to_sellcs   # late: core <- spmm
        return coo_to_sellcs(coo, **kw)
    if spec.blocked:
        return coo_to_blocked(coo, algorithm, **kw)
    return coo_to_csr(coo)
