"""Request batching: many single-vector SpMV requests -> one SpMM call.

The serve-path story of this subsystem: each user request is one ``A @ x``
— memory-bound, wasting the matrix stream on a single vector. Aggregating
queued requests into a ``[n, k]`` block before multiplying reuses every
streamed nonzero k times (arithmetic intensity grows k-fold; see
``repro.roofline.spmm_arithmetic_intensity``) at zero cost to correctness:
column j of the SpMM *is* request j's SpMV.

``RequestBatcher`` is the queueing front-end ``launch.serve`` drives; k is
padded to the next power of two (capped at ``max_batch``) so a server sees
O(log max_batch) distinct compiled shapes instead of one per queue depth.

Serve metrics (``repro.obs``): when a registry is installed, every flush
records its phases — ``batcher/flush`` (whole flush, blocking on Y so the
latency is real), ``batcher/pad`` (queue pop + dtype promotion + the
power-of-two pad), ``batcher/multiply`` (the SpMM itself), and
``batcher/scatter`` (result columns back to tickets) — plus a
``batcher/queue_wait_s`` histogram (submit-to-flush seconds per request),
``batcher/flushes`` / ``batcher/served`` counters and a
``batcher/pending`` depth gauge. The flush percentiles
``launch.serve --metrics`` prints are the ``batcher/flush`` series. With
no registry installed none of this runs: the spans are shared no-op
singletons and the submit path takes one ``enabled()`` branch — the hot
path stays allocation-free (asserted in ``tests/test_obs.py``).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.obs import maybe_block, span

Array = jax.Array


class QueueFull(RuntimeError):
    """A ``submit`` hit the tenant's ``max_pending`` bound under the
    ``overflow="raise"`` policy. Carries enough to let the caller shed load
    intelligently (retry-after, spill to another replica)."""

    def __init__(self, tenant: Optional[str], pending: int,
                 max_pending: int):
        self.tenant = tenant
        self.pending = pending
        self.max_pending = max_pending
        who = f"tenant {tenant!r}" if tenant else "batcher"
        super().__init__(
            f"{who} queue full: {pending} pending >= max_pending="
            f"{max_pending}")

# Pluggable SpMM: (matrix, X[n, k]) -> Y[m, k]. The distributed serve path
# passes a closure over (sharded matrix, mesh) here so the batcher drives a
# whole mesh exactly the way it drives one device.
SpmmFn = Callable[[object, Array], Array]


@dataclasses.dataclass(frozen=True)
class SpmvRequest:
    """One queued ``A @ x`` request."""
    rid: int
    x: Array


def _next_pow2(k: int) -> int:
    p = 1
    while p < k:
        p <<= 1
    return p


def batch_spmv(matrix, requests: Sequence, *, impl: str = "auto",
               k_tile: Optional[int] = None,
               spmm_fn: Optional[SpmmFn] = None) -> List[Array]:
    """Answer a batch of single-vector requests with ONE SpMM.

    ``requests`` holds ``SpmvRequest``s or bare ``[n]`` vectors. Returns
    the per-request results in input order. ``spmm_fn`` overrides the
    multiply (e.g. a ``spmm_row_distributed`` closure over a mesh).
    """
    from . import spmm
    if not requests:
        return []
    xs = [r.x if isinstance(r, SpmvRequest) else r for r in requests]
    n = matrix.shape[1]
    for x in xs:
        if x.shape != (n,):
            raise ValueError(
                f"request vector shape {x.shape} != matrix n ({n},)")
    # promote across the whole batch: one low-precision request must not
    # downcast its neighbours' columns
    dtype = jnp.result_type(*xs)
    X = jnp.stack([x.astype(dtype) for x in xs], axis=1)   # [n, k]
    if spmm_fn is not None:
        Y = spmm_fn(matrix, X)                      # [m, k]
    else:
        Y = spmm(matrix, X, impl=impl, k_tile=k_tile)
    return [Y[:, j] for j in range(len(xs))]


class RequestBatcher:
    """Aggregates queued SpMV requests and answers them with one SpMM.

    >>> b = RequestBatcher(matrix, max_batch=64)
    >>> rid = b.submit(x)            # enqueue, returns a ticket
    >>> results = b.flush()          # one SpMM; {rid: y}
    """

    def __init__(self, matrix, *, max_batch: int = 128, impl: str = "auto",
                 pad_pow2: bool = True, spmm_fn: Optional[SpmmFn] = None,
                 max_pending: Optional[int] = None,
                 overflow: str = "raise", name: Optional[str] = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_pending is not None and max_pending < 1:
            raise ValueError("max_pending must be >= 1 (or None)")
        if overflow not in ("raise", "block"):
            raise ValueError(
                f"overflow must be 'raise' or 'block', got {overflow!r}")
        self.matrix = matrix
        self.max_batch = max_batch
        self.impl = impl
        self.pad_pow2 = pad_pow2
        self.spmm_fn = spmm_fn
        self.max_pending = max_pending
        self.overflow = overflow
        self.name = name
        # every obs series this batcher emits carries the tenant label so
        # a fleet's lanes stay distinguishable in one registry
        self._labels = {"tenant": name} if name is not None else None
        self._queue: List[SpmvRequest] = []
        self._next_rid = 0
        # serving telemetry
        self.flushes = 0
        self.served = 0
        self.rejected = 0
        # guards the queue bound; "block" submitters wait here until a
        # flush makes room
        self._cond = threading.Condition()
        # submit timestamps for the queue-wait histogram; only written
        # while an obs registry is installed
        self._submit_t: Dict[int, float] = {}

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def pending(self) -> int:
        return len(self._queue)

    def submit(self, x: Array) -> int:
        """Enqueue one request; returns its ticket id. Shape-checked here so
        a bad request can never poison an already-popped flush batch.

        With ``max_pending`` set, a submit that would exceed the bound
        either raises :class:`QueueFull` (``overflow="raise"``, counted in
        ``rejected`` / the ``batcher/rejected`` series) or blocks until a
        flush makes room (``overflow="block"``)."""
        x = jnp.asarray(x)
        n = self.matrix.shape[1]
        if x.shape != (n,):
            raise ValueError(
                f"request vector shape {x.shape} != matrix n ({n},)")
        with self._cond:
            if self.max_pending is not None:
                if self.overflow == "block":
                    self._cond.wait_for(
                        lambda: len(self._queue) < self.max_pending)
                elif len(self._queue) >= self.max_pending:
                    self.rejected += 1
                    if obs.enabled():
                        obs.current_registry().counter(
                            "batcher/rejected", self._labels).inc()
                    raise QueueFull(self.name, len(self._queue),
                                    self.max_pending)
            rid = self._next_rid
            self._next_rid += 1
            self._queue.append(SpmvRequest(rid, x))
            depth = len(self._queue)
        if obs.enabled():
            self._submit_t[rid] = time.perf_counter()
            reg = obs.current_registry()
            reg.counter("batcher/submitted", self._labels).inc()
            reg.gauge("batcher/pending", self._labels).set(depth)
        return rid

    def flush(self) -> Dict[int, Array]:
        """Serve up to ``max_batch`` queued requests with one SpMM call and
        scatter the result columns back to their tickets.

        With an obs registry installed the flush is phase-traced (pad /
        multiply / scatter) and blocks on its outputs so the recorded
        ``batcher/flush`` latency is execution time, not dispatch time —
        the one behavioral difference metrics mode buys its numbers with.
        """
        if not self._queue:
            return {}
        with span("batcher/flush"):
            with self._cond:
                batch, self._queue = (self._queue[:self.max_batch],
                                      self._queue[self.max_batch:])
                # room opened up — wake "block"-policy submitters
                self._cond.notify_all()
            k = len(batch)
            n = self.matrix.shape[1]
            kp = min(_next_pow2(k), self.max_batch) if self.pad_pow2 else k
            with span("batcher/pad"):
                # the batch dtype is the promotion over every queued
                # request, not whatever the first one happened to be — a
                # mixed-dtype queue must not silently downcast later
                # columns
                dtype = jnp.result_type(*(r.x for r in batch))
                X = jnp.zeros((n, kp), dtype)
                X = maybe_block(X.at[:, :k].set(
                    jnp.stack([r.x.astype(dtype) for r in batch], axis=1)))
            with span("batcher/multiply"):
                if self.spmm_fn is not None:
                    Y = self.spmm_fn(self.matrix, X)
                else:
                    from . import spmm
                    Y = spmm(self.matrix, X, impl=self.impl)
                Y = maybe_block(Y)
            with span("batcher/scatter"):
                out = {r.rid: Y[:, j] for j, r in enumerate(batch)}
            self.flushes += 1
            self.served += k
            if obs.enabled():
                reg = obs.current_registry()
                now = time.perf_counter()
                waits = reg.histogram("batcher/queue_wait_s",
                                      self._labels)
                for r in batch:
                    t0 = self._submit_t.pop(r.rid, None)
                    if t0 is not None:
                        waits.observe(now - t0)
                reg.counter("batcher/flushes", self._labels).inc()
                reg.counter("batcher/served", self._labels).inc(k)
                reg.gauge("batcher/batch_k", self._labels).set(k)
                reg.gauge("batcher/pending",
                          self._labels).set(len(self._queue))
            return out

    def drain(self) -> Dict[int, Array]:
        """Flush until the queue is empty."""
        out: Dict[int, Array] = {}
        while self._queue:
            out.update(self.flush())
        return out


@dataclasses.dataclass
class _TenantLane:
    """One tenant's queue + SLO bookkeeping inside a :class:`FleetBatcher`."""
    name: str
    batcher: RequestBatcher
    slo_s: float
    arrivals: "collections.deque[float]" = dataclasses.field(
        default_factory=collections.deque)
    served: int = 0
    flushes: int = 0
    slo_violations: int = 0


class FleetBatcher:
    """Multi-tenant front end: one :class:`RequestBatcher` lane per tenant,
    one cross-tenant flush scheduler.

    The scheduler rule (``next_tenant``) scores every lane with pending
    work by **SLO-deadline urgency × batch-efficiency**:

    ``score = (age_oldest / slo_s) * (min(pending, max_batch) / max_batch)``

    The first factor grows past 1.0 as the lane's oldest request
    approaches its latency budget — an old request eventually wins no
    matter how small its batch (no starvation). The second factor reflects
    the paper's economics: a fuller batch reuses every streamed nonzero k
    times, so flushing a nearly-empty lane wastes the memory-bound matrix
    stream. Ties break toward the older oldest-arrival. ``flush_next`` /
    ``drain`` never drop a request: every queued ticket is eventually
    served (the fleet test asserts exactly this).

    Per-lane bounds (``max_pending``, ``overflow``) ride on the underlying
    :class:`RequestBatcher`; every obs series a lane emits carries its
    ``tenant`` label, and per-request SLO outcomes land in
    ``fleet/slo_violations``. ``clock`` is injectable for deterministic
    scheduler tests."""

    def __init__(self, *, clock: Callable[[], float] = time.perf_counter):
        self._lanes: Dict[str, _TenantLane] = {}
        self._clock = clock

    def add_tenant(self, name: str, op, *, max_batch: int = 128,
                   slo_s: float = 0.1, max_pending: Optional[int] = None,
                   overflow: str = "raise",
                   spmm_fn: Optional[SpmmFn] = None) -> _TenantLane:
        """Register a tenant lane over ``op`` (anything with ``matmul`` —
        a :class:`repro.spmm.SparseOperator` — or a raw matrix when
        ``spmm_fn`` is given)."""
        if name in self._lanes:
            raise ValueError(f"tenant {name!r} already registered")
        if slo_s <= 0:
            raise ValueError("slo_s must be > 0")
        if spmm_fn is None:
            spmm_fn = lambda _m, X: op.matmul(X)
        batcher = RequestBatcher(
            op, max_batch=max_batch, spmm_fn=spmm_fn,
            max_pending=max_pending, overflow=overflow, name=name)
        lane = _TenantLane(name, batcher, float(slo_s))
        self._lanes[name] = lane
        return lane

    def tenants(self) -> List[str]:
        return list(self._lanes)

    def lane(self, name: str) -> _TenantLane:
        return self._lanes[name]

    def submit(self, tenant: str, x: Array) -> int:
        """Enqueue one request on ``tenant``'s lane (its backpressure
        policy applies); arrival time feeds the flush scheduler."""
        lane = self._lanes[tenant]
        rid = lane.batcher.submit(x)     # QueueFull propagates pre-append
        lane.arrivals.append(self._clock())
        return rid

    @property
    def total_pending(self) -> int:
        return sum(lane.batcher.pending for lane in self._lanes.values())

    def next_tenant(self, now: Optional[float] = None) -> Optional[str]:
        """The scheduler rule: the lane with the highest
        urgency × efficiency score, or None when nothing is pending."""
        if now is None:
            now = self._clock()
        best = None
        best_key: Optional[Tuple[float, float]] = None
        for name, lane in self._lanes.items():
            pending = lane.batcher.pending
            if not pending:
                continue
            oldest = lane.arrivals[0] if lane.arrivals else now
            urgency = (now - oldest) / lane.slo_s
            mb = lane.batcher.max_batch
            efficiency = min(pending, mb) / mb
            key = (urgency * efficiency, now - oldest)
            if best_key is None or key > best_key:
                best, best_key = name, key
        return best

    def flush(self, tenant: str) -> Dict[int, Array]:
        """Flush one batch from ``tenant``'s lane; counts per-request SLO
        violations (queue wait past the lane's budget)."""
        lane = self._lanes[tenant]
        out = lane.batcher.flush()
        k = len(out)
        if k:
            now = self._clock()
            late = 0
            for _ in range(k):
                t0 = lane.arrivals.popleft()
                if now - t0 > lane.slo_s:
                    late += 1
            lane.served += k
            lane.flushes += 1
            if late:
                lane.slo_violations += late
                if obs.enabled():
                    obs.current_registry().counter(
                        "fleet/slo_violations",
                        {"tenant": tenant}).inc(late)
        return out

    def flush_next(self) -> Tuple[Optional[str], Dict[int, Array]]:
        """One scheduler step: pick the most urgent-and-efficient lane and
        flush it. Returns ``(tenant, results)`` — ``(None, {})`` when every
        lane is empty."""
        tenant = self.next_tenant()
        if tenant is None:
            return None, {}
        return tenant, self.flush(tenant)

    def drain(self) -> Dict[str, Dict[int, Array]]:
        """Flush, scheduler-ordered, until every lane is empty — no queued
        request is ever dropped."""
        out: Dict[str, Dict[int, Array]] = {t: {} for t in self._lanes}
        while self.total_pending:
            tenant, res = self.flush_next()
            if tenant is None:
                break
            out[tenant].update(res)
        return out
