import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell for the production meshes and record memory/cost/collective analysis.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run needs 512 placeholder host devices for the 2x16x16
multi-pod mesh (smoke tests and benches see 1 device — this env var is set
here only, never globally).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --arch all [--multi-pod both] --out DIR
"""
import argparse
import json
import sys
import time
import traceback


from repro.configs.base import SHAPES, cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_cell
from repro.models.accounting import (attn_extra_flops, decode_model_flops,
                                     train_model_flops)
from repro.roofline import analysis as ra


def model_flops_for(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    s = SHAPES[shape_name]
    if s.kind == "train":
        return train_model_flops(cfg, s.batch * s.seq) + \
            attn_extra_flops(cfg, s.batch, s.seq, train=True)
    if s.kind == "prefill":
        return train_model_flops(cfg, s.batch * s.seq) / 3.0 + \
            attn_extra_flops(cfg, s.batch, s.seq, train=False)
    return decode_model_flops(cfg, s.batch, s.seq)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             profile: str = "tp", grad_accum: int = 1) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name, "profile": profile,
           "grad_accum": grad_accum,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips}
    lowered = lower_cell(arch, shape_name, mesh, profile=profile,
                         grad_accum=grad_accum)
    rec["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                rec[k] = int(v)
        # bytes that must fit HBM per device: args (params/opt/cache shards)
        # + temps + outputs
        rec["hbm_bytes_per_device"] = sum(
            rec.get(k, 0) for k in ("argument_size_in_bytes",
                                    "output_size_in_bytes",
                                    "temp_size_in_bytes"))
    print(f"[{arch} x {shape_name} x {rec['mesh']}] memory_analysis:")
    print(" ", mem)

    hlo_text = compiled.as_text()
    roof = ra.from_compiled(compiled, chips,
                            model_flops=model_flops_for(arch, shape_name),
                            hlo_text=hlo_text)
    from repro.roofline import hlo_parse
    rec["collectives"] = hlo_parse.analyze(hlo_text)["collectives"]
    rec["roofline"] = roof.to_dict()
    # XLA's own numbers, recorded as a cross-check (known to undercount
    # while bodies — see EXPERIMENTS §Dry-run)
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    rec["xla_cost_analysis"] = {"flops": float(cost.get("flops", 0.0)),
                                "bytes": float(cost.get(
                                    "bytes accessed", 0.0))}
    print(f"[{arch} x {shape_name} x {rec['mesh']}] parsed: "
          f"flops={roof.flops_per_device:.3e} "
          f"bytes={roof.bytes_per_device:.3e} "
          f"(xla-once: flops={cost.get('flops', 0):.3e})")
    print(f"  roofline: compute={roof.compute_s:.4f}s "
          f"memory={roof.memory_s:.4f}s collective={roof.collective_s:.4f}s"
          f" bottleneck={roof.bottleneck} "
          f"useful={roof.useful_flops_fraction:.3f} "
          f"roofline_fraction={roof.roofline_fraction:.3f}")
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--multi-pod", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None,
                    help="directory for per-cell JSON records")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose JSON already exists (resume)")
    ap.add_argument("--profile", default="tp",
                    choices=["tp", "fsdp", "fsdp_seqp"],
                    help="sharding profile (fsdp = no TP, §Perf iter 2)")
    ap.add_argument("--grad-accum", type=int, default=1,
                    help="microbatches per step (§Perf iter 7)")
    args = ap.parse_args()

    if args.arch == "all":
        todo = [(a, s) for a, s, skip in cells() ]
    else:
        shapes = list(SHAPES) if args.shape == "all" else [args.shape]
        todo = [(args.arch, s) for s in shapes
                if (args.arch, s, False) in cells()]
    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.multi_pod]

    failures = 0
    for arch, shape in todo:
        for mp in pods:
            mesh_tag = "2_16_16" if mp else "16_16"
            if args.skip_existing and args.out and os.path.exists(
                    os.path.join(args.out,
                                 f"{arch}__{shape}__{mesh_tag}.json")):
                continue
            try:
                rec = run_cell(arch, shape, mp, profile=args.profile,
                               grad_accum=args.grad_accum)
            except Exception as e:
                failures += 1
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "error": repr(e)}
                traceback.print_exc()
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                name = f"{arch}__{shape}__{rec['mesh'].replace('x', '_')}"
                with open(os.path.join(args.out, name + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
