"""Distributed SpMV over a JAX mesh (the paper's multi-socket dimension,
scaled from 2 CPUs to pods).

Two strategies, mirroring the paper's two winning scheduling families:

* ``row_distributed``  (BCOH, §3.2): rows are statically banded so each
  device owns ~nnz/P nonzeros. x is replicated (the paper's interleaved
  allocation), y is written shard-locally — **zero collectives on y**. Wins
  when no single row dominates; this is why BCOH wins on NUMA machines.

* ``merge_distributed`` (Merge, §3.3): equal-nnz spans regardless of row
  boundaries; partial y contributions are combined with one ``psum`` — the
  carry-out fixup across devices. Survives the mawi single-dense-row case
  at the cost of an all-reduce on y.

Both are expressed with shard_map so the same code drives 8 host-platform
devices in tests and a 512-chip production mesh in the dry-run.

Multi-RHS: both multiply entry points accept ``x`` as ``[n]`` (SpMV,
today's behavior) or ``[n, k]`` (SpMM — each shard streams its nonzeros
once against the whole k-block, the same amortization ``repro.spmm``
exploits on one device). ``repro.spmm.distributed`` holds the SELL-C-σ
slice-stream versions of the same two schedules.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from .formats import COO
from .mergepath import balanced_row_bands


class ShardedCOO(NamedTuple):
    """Per-device padded COO shards, stacked along a leading device axis."""
    rows: jax.Array        # int32[Pdev, nnz_pad] — LOCAL row indices
    cols: jax.Array        # int32[Pdev, nnz_pad] — global col indices
    vals: jax.Array        # f32[Pdev, nnz_pad]  — zero-padded
    row_offset: jax.Array  # int32[Pdev] — first global row of the shard
    shape: Tuple[int, int]
    rows_per_shard: int    # static: padded local row count


def _check_devices(num_devices: int) -> None:
    if num_devices < 1:
        raise ValueError(f"num_devices must be >= 1, got {num_devices}")


def partition_rows(coo: COO, num_devices: int) -> ShardedCOO:
    """BCOH static banding: equal-nnz row bands, zero-padded to uniform
    shard shapes (host-side, convert time).

    Degenerate inputs are well-formed: ``num_devices > m`` yields empty
    bands (zero-filled shards), and ``nnz == 0`` falls back to an even row
    split so shard shapes stay ~m/P instead of one band swallowing every
    row (the balanced-band math puts all of a zero-nnz matrix in the last
    band, which used to inflate ``rows_per_shard`` to m).
    """
    _check_devices(num_devices)
    m, n = coo.shape
    rows = np.asarray(coo.rows)
    cols = np.asarray(coo.cols)
    vals = np.asarray(coo.data)
    order = np.argsort(rows, kind="stable")
    rows, cols, vals = rows[order], cols[order], vals[order]
    row_ptr = np.zeros(m + 1, np.int64)
    np.cumsum(np.bincount(rows, minlength=m), out=row_ptr[1:])
    if rows.size:
        bands = balanced_row_bands(row_ptr, num_devices)
    else:
        bands = ((np.arange(num_devices + 1, dtype=np.int64) * m)
                 // num_devices).astype(np.int32)
    nnz_start = row_ptr[bands]
    nnz_per = np.diff(nnz_start)
    nnz_pad = max(int(nnz_per.max()) if nnz_per.size else 1, 1)
    rows_per = max(int(np.diff(bands).max()) if m else 1, 1)

    R = np.zeros((num_devices, nnz_pad), np.int32)
    C = np.zeros((num_devices, nnz_pad), np.int32)
    V = np.zeros((num_devices, nnz_pad), vals.dtype)
    for p in range(num_devices):
        a, b = int(nnz_start[p]), int(nnz_start[p + 1])
        ln = b - a
        R[p, :ln] = rows[a:b] - bands[p]       # local row ids
        C[p, :ln] = cols[a:b]
        V[p, :ln] = vals[a:b]
    return ShardedCOO(jnp.asarray(R), jnp.asarray(C), jnp.asarray(V),
                      jnp.asarray(bands[:-1].astype(np.int32)),
                      (m, n), rows_per)


def partition_nnz(coo: COO, num_devices: int) -> ShardedCOO:
    """Merge-style equal-nnz spans (rows may straddle devices).

    ``num_devices > nnz`` (empty spans) and ``nnz == 0`` produce zero-filled
    shards whose padded entries target local row 0 with value 0 — harmless
    under the scatter-add, and ``span_rows`` is clamped to ≥ 1 so shard
    buffers never collapse to zero-size."""
    _check_devices(num_devices)
    m, n = coo.shape
    rows = np.asarray(coo.rows)
    cols = np.asarray(coo.cols)
    vals = np.asarray(coo.data)
    order = np.argsort(rows, kind="stable")
    rows, cols, vals = rows[order], cols[order], vals[order]
    nnz = rows.size
    bounds = (np.arange(num_devices + 1, dtype=np.int64) * nnz
              ) // num_devices
    nnz_pad = max(int(np.diff(bounds).max()), 1)
    R = np.zeros((num_devices, nnz_pad), np.int32)
    C = np.zeros((num_devices, nnz_pad), np.int32)
    V = np.zeros((num_devices, nnz_pad), vals.dtype)
    offs = np.zeros(num_devices, np.int32)
    for p in range(num_devices):
        a, b = int(bounds[p]), int(bounds[p + 1])
        ln = b - a
        if ln:
            offs[p] = rows[a]
            R[p, :ln] = rows[a:b] - rows[a]
            C[p, :ln] = cols[a:b]
            V[p, :ln] = vals[a:b]
    # padded entries: vals 0 at local row 0 — harmless
    span_rows = max(int((R.max(axis=1) + 1).max()) if nnz else 1, 1)
    return ShardedCOO(jnp.asarray(R), jnp.asarray(C), jnp.asarray(V),
                      jnp.asarray(offs), (m, n), span_rows)


def _as_2d(x: jax.Array):
    """[n] or [n, k] — SpMV rides along as the k = 1 column."""
    if x.ndim == 1:
        return x[:, None], True
    if x.ndim != 2:
        raise ValueError(f"x must be [n] or [n, k], got shape {x.shape}")
    return x, False


def spmv_row_distributed(sharded: ShardedCOO, x: jax.Array, mesh: Mesh,
                         axis: str = "data") -> jax.Array:
    """Y = A @ X with BCOH row banding: X replicated, Y shard-local.
    ``x`` may be ``[n]`` (SpMV) or ``[n, k]`` (multi-RHS)."""
    m, n = sharded.shape
    ndev = sharded.rows.shape[0]
    if ndev != mesh.shape[axis]:
        raise ValueError(f"matrix is partitioned over {ndev} devices but "
                         f"mesh axis {axis!r} has {mesh.shape[axis]}")
    rp = sharded.rows_per_shard
    x2, squeeze = _as_2d(x)
    k = x2.shape[1]

    def local(rows, cols, vals, x_rep):
        # rows/cols/vals: [1, nnz_pad] local shard; X replicated [n, k]
        y_loc = jnp.zeros((1, rp, k), vals.dtype)
        contrib = vals[0][:, None] * x_rep[cols[0]]          # [nnz_pad, k]
        return y_loc.at[0, rows[0]].add(contrib)

    yb = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None),
                  P(None, None)),
        out_specs=P(axis, None, None))(
            sharded.rows, sharded.cols, sharded.vals, x2)
    # reassemble: band p covers global rows [row_offset[p], +rows_in_band);
    # rows past a band's end scatter to the dump row m (dropped below)
    idx = sharded.row_offset[:, None] + jnp.arange(rp, dtype=jnp.int32)[None]
    valid_len = jnp.concatenate(
        [sharded.row_offset[1:], jnp.array([m], jnp.int32)]
    ) - sharded.row_offset
    mask = jnp.arange(rp, dtype=jnp.int32)[None] < valid_len[:, None]
    y = jnp.zeros((m + 1, k), yb.dtype).at[jnp.where(mask, idx, m)].add(
        jnp.where(mask[..., None], yb, 0))[:m]
    return y[:, 0] if squeeze else y


def spmv_merge_distributed(sharded: ShardedCOO, x: jax.Array, mesh: Mesh,
                           axis: str = "data") -> jax.Array:
    """Y = A @ X with merge spans: per-device partials + psum fixup.
    ``x`` may be ``[n]`` (SpMV) or ``[n, k]`` (multi-RHS)."""
    m, n = sharded.shape
    ndev = sharded.rows.shape[0]
    if ndev != mesh.shape[axis]:
        raise ValueError(f"matrix is partitioned over {ndev} devices but "
                         f"mesh axis {axis!r} has {mesh.shape[axis]}")
    x2, squeeze = _as_2d(x)

    def local(rows, cols, vals, offs, x_rep):
        contrib = vals[0][:, None] * x_rep[cols[0]]          # [nnz_pad, k]
        # scatter directly at global rows (offs + local row); padded entries
        # carry vals == 0 so they add nothing. One psum = the cross-device
        # carry-out fixup.
        y_loc = jnp.zeros((m, x_rep.shape[1]), vals.dtype
                          ).at[offs[0] + rows[0]].add(contrib)
        return jax.lax.psum(y_loc, axis)

    y = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P(axis),
                  P(None, None)),
        out_specs=P(None, None))(
            sharded.rows, sharded.cols, sharded.vals,
            sharded.row_offset[:, None], x2)
    return y[:, 0] if squeeze else y
