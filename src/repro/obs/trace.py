"""Phase-level tracing: ``span("gather_x")`` wraps a code region, records
its host wall time into the installed registry's histograms, and — when
jax is importable — nests the same name into ``jax.named_scope`` (so the
region's ops carry it in compiled HLO and device traces) and
``jax.profiler.TraceAnnotation`` (so a captured profile shows it on the
host timeline).

Nesting builds slash-joined paths: a ``span("multiply")`` opened inside
``span("flush")`` records into the ``"flush/multiply"`` histogram — the
phase breakdown ``launch.serve --metrics`` prints is exactly these
histograms grouped by prefix. A name that already contains a ``/`` is
*absolute*: it records under exactly that path and neither joins nor
extends the enclosing stack — library instrumentation
(``spmm/kernel``, ``batcher/flush``) uses absolute names so its series
stay stable no matter which caller spans are open (e.g. while a jitted
body containing them is being traced).

Two honesty caveats the instrumented call sites live by:

* Host wall time of a region that is being *traced* by ``jax.jit`` /
  ``shard_map`` is trace time, not device time — still useful (it names
  the phase in the dump and the scope in the HLO) but the number is only
  real execution time on the eager path. ``launch.serve --metrics`` runs
  one eager phase-profile pass for exactly this reason.
* jax dispatch is async: a span around a dispatch-only region would time
  the enqueue. ``maybe_block`` closes a span honestly — it blocks on the
  region's outputs when (and only when) a registry is installed, and is
  a silent no-op on tracers, so the same line is safe under ``jit``.

Zero-overhead default: with no registry installed ``span()`` returns a
process-wide singleton whose ``__enter__``/``__exit__`` do nothing — no
allocation, no perf_counter call, no jax import — asserted by the
micro-benchmark in ``tests/test_obs.py``.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from . import metrics as _metrics

try:                                    # obs must import without jax
    import jax as _jax
except Exception:                       # pragma: no cover - jax is a dep
    _jax = None


class _NullSpan:
    """The disabled path: a shared, stateless, allocation-free context
    manager."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()

_STACK = threading.local()


def _stack():
    s = getattr(_STACK, "names", None)
    if s is None:
        s = _STACK.names = []
    return s


class _Span:
    """An enabled span: perf_counter + named_scope + TraceAnnotation."""
    __slots__ = ("name", "registry", "labels", "path", "_t0", "_scopes",
                 "_pushed")

    def __init__(self, name, registry, labels):
        self.name = name
        self.registry = registry
        self.labels = labels
        self.path = None
        self._t0 = 0.0
        self._scopes = None
        self._pushed = False

    def __enter__(self):
        if "/" in self.name:            # absolute: stable series name
            self.path = self.name
        else:
            stack = _stack()
            stack.append(self.name)
            self._pushed = True
            self.path = "/".join(stack)
        self._scopes = []
        if _jax is not None:
            try:
                scope = _jax.named_scope(self.name)
                scope.__enter__()
                self._scopes.append(scope)
                ann = _jax.profiler.TraceAnnotation(self.path)
                ann.__enter__()
                self._scopes.append(ann)
            except Exception:           # profiler backends may be absent
                pass
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        dt = time.perf_counter() - self._t0
        for scope in reversed(self._scopes):
            try:
                scope.__exit__(exc_type, exc, tb)
            except Exception:
                pass
        if self._pushed:
            stack = _stack()
            if stack and stack[-1] == self.name:
                stack.pop()
        # record even on exception: a phase that died still spent the time
        self.registry.histogram(self.path, self.labels).observe(dt)
        return False

    @property
    def elapsed_s(self) -> float:
        """Seconds since ``__enter__`` (live) — for callers that want the
        duration they just measured without re-reading the histogram."""
        return time.perf_counter() - self._t0


def span(name: str, registry=None, labels: Optional[dict] = None):
    """Context manager timing one named phase.

    With no registry installed (and none passed) this is free: the
    returned object is a module-level singleton no-op. With a registry,
    the region's wall seconds land in the histogram named by the
    slash-joined span stack, and the name rides into device traces via
    ``jax.named_scope`` / ``jax.profiler.TraceAnnotation``.
    """
    reg = registry if registry is not None else _metrics._REGISTRY
    if reg is None:
        return _NULL_SPAN
    return _Span(name, reg, labels)


def maybe_block(x):
    """Block on jax outputs iff a registry is installed, so the enclosing
    span times execution instead of async dispatch. Returns ``x``.

    Safe inside ``jit``/``shard_map`` tracing: ``jax.block_until_ready``
    leaves tracers untouched, so instrumented library code needs no
    eager-vs-traced branch. The disabled path is one global load."""
    if _metrics._REGISTRY is None or _jax is None:
        return x
    try:
        return _jax.block_until_ready(x)
    except Exception:
        return x
