"""Qwen2.5-3B [hf:Qwen/Qwen2.5-*]: GQA(kv=2), QKV bias, RMSNorm, SwiGLU."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", n_layers=36, d_model=2048, n_heads=16, kv_heads=2,
    d_ff=11008, vocab=151936, head_dim=128, rope_theta=1e6, qkv_bias=True,
    block_pattern=("attn",), mlp_pattern=("dense",))

REDUCED = ModelConfig(
    name="qwen2.5-3b-reduced", n_layers=2, d_model=64, n_heads=4, kv_heads=2,
    d_ff=160, vocab=256, head_dim=16, qkv_bias=True,
    block_pattern=("attn",), mlp_pattern=("dense",),
    compute_dtype=jnp.float32, loss_chunk=16)
