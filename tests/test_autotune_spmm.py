"""Autotuner (paper §8 future work) + SpMM multi-RHS kernel."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import autotune, to_coo
from repro.data import matrices
from repro.kernels import coo_to_tiled, ops
from repro.kernels.ref import bsr_spmm_ref


def test_autotune_returns_consistent_best():
    coo = to_coo(*matrices.uniform(400, 400, 4000, 0))
    best, results = autotune(coo, num_spmvs=20, reps=2,
                             algorithms=("parcrs", "csb", "bcohc"),
                             betas=[64, 128])
    assert best.total_s == min(r.total_s for r in results)
    assert best.total_s == pytest.approx(
        best.convert_s + 20 * best.spmv_s)
    # flat algorithms carry beta=None; blocked ones a real beta
    assert any(r.beta is None for r in results)
    assert any(r.beta in (64, 128) for r in results)


def test_autotune_low_reuse_weights_conversion_only():
    coo = to_coo(*matrices.uniform(3000, 3000, 60000, 0))
    best1, results = autotune(coo, num_spmvs=0, reps=2,
                              algorithms=("parcrs", "bcohch"), betas=[256])
    # with zero reuse, total == conversion cost alone
    for r in results:
        assert r.total_s == pytest.approx(r.convert_s)
    # and the Hilbert sort costs strictly more to build than CSR
    conv = {r.algorithm: r.convert_s for r in results}
    assert conv["bcohch"] > conv["parcrs"]


@pytest.mark.parametrize("R", [1, 8, 33])
@pytest.mark.parametrize("algo", ["csb", "bcohch"])
def test_bsr_spmm_vs_dense(R, algo):
    coo = to_coo(*matrices.powerlaw(300, 260, 2600, seed=1))
    ts = coo_to_tiled(coo, algo, beta=128)
    X = jnp.asarray(np.random.default_rng(2)
                    .standard_normal((260, R)).astype(np.float32))
    Yd = np.asarray(coo.todense()) @ np.asarray(X)
    Yr = bsr_spmm_ref(ts, X)
    np.testing.assert_allclose(np.asarray(Yr), Yd, rtol=2e-4, atol=2e-4)
    Yk = ops.bsr_spmm(ts, X, interpret=True)
    np.testing.assert_allclose(np.asarray(Yk), np.asarray(Yr),
                               rtol=1e-5, atol=1e-5)


def test_spmm_columns_match_spmv():
    """Column j of SpMM == SpMV with x_j (consistency across kernels)."""
    coo = to_coo(*matrices.uniform(200, 220, 1800, 3))
    ts = coo_to_tiled(coo, "csb", beta=128)
    X = jnp.asarray(np.random.default_rng(4)
                    .standard_normal((220, 4)).astype(np.float32))
    Y = bsr_spmm_ref(ts, X)
    from repro.kernels.ref import bsr_spmv_ref
    for j in range(4):
        np.testing.assert_allclose(np.asarray(Y[:, j]),
                                   np.asarray(bsr_spmv_ref(ts, X[:, j])),
                                   rtol=1e-5, atol=1e-5)
