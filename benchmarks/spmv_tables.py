"""Paper Tables 6.1 / 6.2 / 6.3 analogues: SpMV algorithm comparison.

Two levels per (algorithm x matrix):
  * measured: wall time of the jitted XLA realization on this host (the
    paper's protocol: min over repetitions), reported as speedup vs the
    sequential-equivalent baseline (ParCRS XLA path);
  * derived (TPU roofline model): the TiledSparse visit stream gives
    #tiles (uniform MXU quanta), fill ratio, and x/y window switches; the
    modelled TPU time = max(compute, memory) with
      compute = tiles * 8*128*2 / peak,  memory = (tile bytes + switch
      slab traffic) / HBM_bw
    — this is where the paper's ordering/blocking effects show up on TPU.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import (ALGORITHM_SPECS, convert, coo_to_csr, spmv, to_coo)
from repro.data import matrices
from repro.kernels import coo_to_tiled, merge_plan
from repro.kernels.ref import merge_spmv_xla
from repro.kernels.tiling import TILE_C, TILE_R
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS_BF16

from .harness import Csv, time_fn

ALGOS = ["parcrs", "merge", "csb", "csbh", "bcoh", "bcohc", "bcohch",
         "bcohchp", "mergeb", "mergebh"]


def tpu_model_time(ts) -> float:
    """Roofline-modelled TPU time for one SpMV over the tile stream."""
    tiles = ts.num_tiles
    compute = tiles * (2 * TILE_R * TILE_C) / PEAK_FLOPS_BF16
    xsw, ysw = ts.window_switches()
    traffic = tiles * (TILE_R * TILE_C * ts.tiles.dtype.itemsize + 8) \
        + xsw * TILE_C * 4 + ysw * TILE_R * 4 * 2
    memory = traffic / HBM_BW
    return max(compute, memory)


def _spmv_time(coo, algo: str, x) -> float:
    """Measured XLA wall time for the algorithm's storage format."""
    if algo == "parcrs":
        mat = coo_to_csr(coo)
        return time_fn(lambda: spmv(mat, x, impl="ref"))
    if algo == "merge":
        csr = coo_to_csr(coo)
        P = max(min((csr.shape[0] + csr.nnz) // 4096, 256), 8)
        plan = merge_plan(csr, P)
        return time_fn(lambda: merge_spmv_xla(
            plan.cols, plan.vals, plan.seg, plan.row_starts,
            jnp.pad(x, (0, 128 - x.shape[0] % 128)),
            r_width=plan.r_width, m=csr.shape[0]))
    kw = dict(beta=512)
    if ALGORITHM_SPECS[algo].scheduling == "static_rows":
        kw["num_bands"] = 8
    mat = convert(coo, algo, **kw)
    return time_fn(lambda: spmv(mat, x, impl="ref"))


def run(csv: Csv, suite_scale: float = 0.12, density_class: str = "low"):
    suite = matrices.test_suite(suite_scale)
    base_times = {}
    for name, tm in suite.items():
        if tm.density_class != density_class:
            continue
        coo = to_coo(*tm.make())
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            coo.shape[1]).astype(np.float32))
        t_base = _spmv_time(coo, "parcrs", x)
        base_times[name] = t_base
        for algo in ALGOS:
            t = _spmv_time(coo, algo, x) if algo != "parcrs" else t_base
            derived = f"speedup_vs_parcrs={t_base / t:.3f}"
            if ALGORITHM_SPECS[algo].blocked:
                ts = coo_to_tiled(coo, algo, beta=512)
                xsw, ysw = ts.window_switches()
                derived += (f";tpu_model_us={tpu_model_time(ts)*1e6:.1f}"
                            f";fill={ts.fill_ratio:.4f}"
                            f";xswitch={xsw};yswitch={ysw}")
            csv.row(f"{density_class}.{name}.{algo}", t, derived)


def run_low(csv=None):
    run(csv or Csv("Table 6.1: low-density SpMV"), density_class="low")


def run_high(csv=None):
    run(csv or Csv("Table 6.2: higher-density SpMV"), density_class="high")


def run_skewed(csv=None):
    """Table 6.3: the mawi pathology. Also reports the worker-balance ratio
    (max work / mean work) for row-banded vs merge-path partitioning — the
    structural reason the row-distributed family collapses."""
    csv = csv or Csv("Table 6.3: mawi-like skewed matrix")
    from repro.core.mergepath import balanced_row_bands, \
        merge_path_partition_np
    suite = matrices.test_suite(0.12)
    tm = suite["mawi_like"]
    coo = to_coo(*tm.make())
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        coo.shape[1]).astype(np.float32))
    csr = coo_to_csr(coo)
    row_ptr = np.asarray(csr.row_ptr)
    P = 64
    bands = balanced_row_bands(row_ptr, P)
    nnz_band = np.diff(row_ptr[bands])
    rs, js = merge_path_partition_np(row_ptr, P)
    work_merge = np.diff(rs) + np.diff(js)
    t_base = _spmv_time(coo, "parcrs", x)
    for algo in ALGOS:
        t = _spmv_time(coo, algo, x) if algo != "parcrs" else t_base
        sched = ALGORITHM_SPECS[algo].scheduling
        if sched == "merge":
            bal = work_merge.max() / max(work_merge.mean(), 1)
        elif sched == "static_rows":
            bal = nnz_band.max() / max(nnz_band.mean(), 1)
        else:
            bal = 1.0   # dynamic over-decomposition bounds it by one block
        csv.row(f"skewed.mawi.{algo}", t,
                f"speedup_vs_parcrs={t_base / t:.3f};"
                f"worker_balance_max_over_mean={bal:.2f}")
