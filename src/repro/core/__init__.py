"""repro.core — the paper's contribution: parallel SpMV algorithms, storage
formats, space-filling-curve orderings, merge-path load balancing, conversion
pipeline and the §7 algorithm selector."""
from .formats import (BICRS, COO, CSR, ICRS, BlockedSparse,
                      BLOCK_STORAGE_BICRS, BLOCK_STORAGE_CSR,
                      BLOCK_STORAGE_DENSE_PTR, IN_BLOCK_ICRS,
                      IN_BLOCK_PACKED_COO)
from .convert import (ALGORITHM_SPECS, AlgorithmSpec, block_size_for,
                      convert, coo_to_bicrs, coo_to_blocked, coo_to_csr,
                      coo_to_icrs, to_coo)
from .curves import (curve_key, hilbert_decode, hilbert_key, hilbert_key_np,
                     morton_decode, morton_key)
from .mergepath import (MergePartition, balanced_row_bands,
                        merge_path_partition, merge_path_partition_np,
                        span_block_aligned)
from .selector import (CHUNK_CANDIDATES, GATHER_CANDIDATES, SCHEDULES,
                       DistributedChoice, MachineSpec, MatrixStats, PlanSpec,
                       amortized_cost, break_even_spmvs, matrix_stats,
                       mesh_factorizations, select, select_algorithm,
                       select_distributed, spmm_cost_scale)
from .autotune import TuneResult, autotune
from .spmv import (spmv, spmv_blocked, spmv_coo, spmv_csr, spmv_dense_oracle,
                   spmv_incremental)

__all__ = [
    "BICRS", "COO", "CSR", "ICRS", "BlockedSparse", "ALGORITHM_SPECS",
    "BLOCK_STORAGE_BICRS", "BLOCK_STORAGE_CSR", "BLOCK_STORAGE_DENSE_PTR",
    "IN_BLOCK_ICRS", "IN_BLOCK_PACKED_COO",
    "AlgorithmSpec", "block_size_for", "convert", "coo_to_bicrs",
    "coo_to_blocked", "coo_to_csr", "coo_to_icrs", "to_coo", "curve_key",
    "hilbert_decode", "hilbert_key", "hilbert_key_np", "morton_decode",
    "morton_key", "MergePartition", "balanced_row_bands",
    "merge_path_partition", "merge_path_partition_np", "span_block_aligned",
    "MachineSpec", "MatrixStats", "PlanSpec", "SCHEDULES",
    "CHUNK_CANDIDATES", "GATHER_CANDIDATES",
    "DistributedChoice", "amortized_cost", "mesh_factorizations",
    "break_even_spmvs", "matrix_stats", "select", "select_algorithm",
    "select_distributed", "spmm_cost_scale", "autotune",
    "TuneResult", "spmv", "spmv_blocked", "spmv_coo",
    "spmv_csr", "spmv_dense_oracle", "spmv_incremental",
]
