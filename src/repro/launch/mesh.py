"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.

Production target: TPU v5e pods. Single pod = 256 chips as (data=16,
model=16); multi-pod adds a leading pure-DP "pod" axis crossing DCI.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 explicit-sharding API; absent on the pinned 0.4.x
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False,
                         devices: Optional[Sequence] = None) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, devices=devices)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...],
              devices: Optional[Sequence] = None) -> Mesh:
    import numpy as np
    need = int(np.prod(shape))
    devs = list(devices if devices is not None else jax.devices())
    if len(devs) < need:
        raise ValueError(
            f"mesh {shape} needs {need} devices, found {len(devs)} "
            "(the dry-run must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import)")
    kw = {}
    if AxisType is not None:
        kw["axis_types"] = (AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devs[:need], **kw)


def make_spmm_mesh(mesh_shape: Tuple[int, int],
                   devices: Optional[Sequence] = None) -> Mesh:
    """Mesh for the distributed SpMM schedules from a (P_data, P_model)
    factorization: 1-D ``("data",)`` when the model axis is trivial (the
    pre-2-D layout every existing call site uses), 2-D ``("data", "model")``
    otherwise — ``repro.spmm.distributed`` auto-adopts the ``model`` axis
    and shards the X/Y k-slabs across it."""
    pd, pm = int(mesh_shape[0]), int(mesh_shape[1])
    if pd < 1 or pm < 1:
        raise ValueError(f"mesh_shape must be positive, got {mesh_shape}")
    if pm == 1:
        return make_mesh((pd,), ("data",), devices=devices)
    return make_mesh((pd, pm), ("data", "model"), devices=devices)


def parse_mesh_shape(spec: str) -> Tuple[int, int]:
    """Parse a ``"Pd,Pm"`` (or ``"PdxPm"``) CLI mesh argument."""
    parts = spec.replace("x", ",").split(",")
    try:
        pd, pm = (int(p) for p in parts)
    except ValueError:
        raise SystemExit(f"--mesh must be Pd,Pm (two ints), got {spec!r}")
    if pd < 1 or pm < 1:
        raise SystemExit(f"--mesh entries must be >= 1, got {spec!r}")
    return pd, pm


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes carrying the batch dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh: Mesh) -> str:
    return "model"
