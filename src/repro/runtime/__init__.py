"""repro.runtime — fault tolerance, straggler monitoring, elastic scaling."""
from .fault_tolerance import StragglerMonitor, Supervisor
from .elastic import build_mesh, largest_feasible_mesh, reshard

__all__ = ["Supervisor", "StragglerMonitor", "build_mesh",
           "largest_feasible_mesh", "reshard"]
