"""Sparse matrix storage formats (paper §2, §3, §4), as JAX pytrees.

Flat formats
------------
``COO``     triplet format (row_ind, col_ind, data)                   [§2]
``CSR``     compressed row storage (row_ptr, col_ind, data)           [§2]
``ICRS``    incremental CRS (col_inc with overflow row signaling)     [§2]
``BICRS``   bidirectional ICRS (negative increments allowed)          [§2]

Blocked formats
---------------
``BlockedSparse`` is a single parameterized container covering the paper's
CSB / BCOH families and all six hybrids. The *canonical* runtime arrays
(``block_rows``, ``block_cols``, ``block_ptr``, ``packed``, ``data``) are what
the Pallas kernel consumes; the storage-scheme-specific arrays (dense grid
pointer / block-level BICRS increments) are kept alongside so that storage
cost is measured faithfully per paper variant.

TPU note (DESIGN.md §2.4): in-block ICRS is kept as a *reference* encoding
(validated by a ``lax.scan`` decoder) but is not a compute format on TPU —
increment decoding is serial and cannot feed the VPU/MXU.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _pytree_dataclass(cls):
    """Register a dataclass as a pytree; fields with metadata static=True are
    aux data."""
    cls = dataclasses.dataclass(frozen=True)(cls)
    data_fields = [f.name for f in dataclasses.fields(cls)
                   if not f.metadata.get("static", False)]
    meta_fields = [f.name for f in dataclasses.fields(cls)
                   if f.metadata.get("static", False)]
    return jax.tree_util.register_dataclass(
        cls, data_fields=data_fields, meta_fields=meta_fields)


def static_field(**kw):
    return dataclasses.field(metadata={"static": True}, **kw)


# --------------------------------------------------------------------------
# Flat formats
# --------------------------------------------------------------------------
@_pytree_dataclass
class COO:
    rows: Array            # int32[nnz]
    cols: Array            # int32[nnz]
    data: Array            # float[nnz]
    shape: Tuple[int, int] = static_field()

    @property
    def nnz(self) -> int:
        return self.data.shape[0]

    def storage_bytes(self) -> int:
        return self.nnz * (4 + 4 + self.data.dtype.itemsize)

    def todense(self) -> Array:
        m, n = self.shape
        out = jnp.zeros((m, n), self.data.dtype)
        return out.at[self.rows, self.cols].add(self.data)


@_pytree_dataclass
class CSR:
    row_ptr: Array         # int32[m+1]
    col_ind: Array         # int32[nnz]
    data: Array            # float[nnz]
    shape: Tuple[int, int] = static_field()

    @property
    def nnz(self) -> int:
        return self.data.shape[0]

    def storage_bytes(self) -> int:
        return (self.row_ptr.shape[0] + self.col_ind.shape[0]) * 4 \
            + self.nnz * self.data.dtype.itemsize

    def row_of_nnz(self) -> Array:
        """int32[nnz] row index of each stored element (decompression)."""
        k = jnp.arange(self.nnz, dtype=jnp.int32)
        return (jnp.searchsorted(self.row_ptr, k, side="right") - 1
                ).astype(jnp.int32)

    def to_coo(self) -> COO:
        return COO(self.row_of_nnz(), self.col_ind, self.data, self.shape)


@_pytree_dataclass
class ICRS:
    """Incremental CRS [Koster 2002]. ``col_start`` is the column index of the
    first nonzero; ``col_inc[k]`` is the (possibly overflowed) increment
    applied *after* consuming nonzero k. ``row_jump[0]`` is the starting row;
    subsequent entries are row increments consumed at each overflow."""
    col_start: Array       # int32[] — column of first nonzero
    col_inc: Array         # int32[nnz] — increment applied after nnz k
    row_jump: Array        # int32[njumps] — [start_row, jump1, jump2, ...]
    data: Array            # float[nnz]
    shape: Tuple[int, int] = static_field()

    @property
    def nnz(self) -> int:
        return self.data.shape[0]

    def storage_bytes(self, index_bytes: int = 4) -> int:
        return (1 + self.col_inc.shape[0] + self.row_jump.shape[0]) \
            * index_bytes + self.nnz * self.data.dtype.itemsize

    def to_coo(self) -> COO:
        return _incremental_decode(self.col_start, self.col_inc,
                                   self.row_jump, self.data, self.shape)


@_pytree_dataclass
class BICRS:
    """Bidirectional ICRS [Yzelman & Bisseling 2012]: same encoding as ICRS
    but increments may be negative, enabling arbitrary nonzero orderings
    (Hilbert, Morton, ...)."""
    col_start: Array
    col_inc: Array         # int32[nnz] (signed)
    row_jump: Array        # int32[njumps] (signed; [start_row, ...])
    data: Array
    shape: Tuple[int, int] = static_field()

    @property
    def nnz(self) -> int:
        return self.data.shape[0]

    def storage_bytes(self, index_bytes: int = 4) -> int:
        return (1 + self.col_inc.shape[0] + self.row_jump.shape[0]) \
            * index_bytes + self.nnz * self.data.dtype.itemsize

    def to_coo(self) -> COO:
        return _incremental_decode(self.col_start, self.col_inc,
                                   self.row_jump, self.data, self.shape)


def _incremental_decode(col_start, col_inc, row_jump, data, shape) -> COO:
    """Faithful Algorithm 2.2 decoder via lax.scan: reconstruct (row, col) of
    every nonzero from the increment encoding. One overflow per row change
    (the encoder adds n exactly once per transition)."""
    m, n = shape
    nnz = data.shape[0]
    if nnz == 0:
        z = jnp.zeros((0,), jnp.int32)
        return COO(z, z, data, shape)

    def step(carry, k):
        j, i, r = carry
        # consume nonzero k at (i, j); then apply increment and handle
        # overflow by consuming a row jump.
        row_k, col_k = i, j
        j = j + col_inc[k]
        overflow = j >= n
        j = jnp.where(overflow, j - n, j)
        i = jnp.where(overflow, i + row_jump[jnp.minimum(r + 1,
                      row_jump.shape[0] - 1)], i)
        r = jnp.where(overflow, r + 1, r)
        return (j, i, r), (row_k, col_k)

    init = (col_start.astype(jnp.int32), row_jump[0].astype(jnp.int32),
            jnp.int32(0))
    _, (rows, cols) = jax.lax.scan(step, init,
                                   jnp.arange(nnz, dtype=jnp.int32))
    return COO(rows.astype(jnp.int32), cols.astype(jnp.int32), data, shape)


# --------------------------------------------------------------------------
# Blocked formats (CSB / BCOH families and hybrids)
# --------------------------------------------------------------------------
# block-level storage schemes (paper §3.1, §3.2, §4.2, §4.3)
BLOCK_STORAGE_DENSE_PTR = "dense_ptr"   # CSB / CSBH / BCOHCHP
BLOCK_STORAGE_BICRS = "bicrs"           # BCOH / BCOHC / BCOHCH
BLOCK_STORAGE_CSR = "csr"               # MergeB / MergeBH

IN_BLOCK_PACKED_COO = "packed_coo"      # 16+16 packed indices (CSB + hybrids)
IN_BLOCK_ICRS = "icrs"                  # compressed ICRS (original BCOH)


@_pytree_dataclass
class BlockedSparse:
    """Unified blocked sparse format.

    Canonical arrays (always present, consumed by kernels):
      block_rows/block_cols int32[nb] — block grid coordinates of the stored
        (non-empty, unless dense_ptr) blocks, in *storage order*;
      block_ptr int32[nb+1] — nnz offsets per block (prefix sum);
      packed uint32[nnz] — (local_row << 16) | local_col per nonzero;
      data float[nnz].

    Variant-specific storage (for faithful storage accounting + validation):
      dense_ptr: grid_ptr int32[Mb*Nb+1] in the chosen block order;
      bicrs: blk_col_inc / blk_row_jump int32 block-level increments;
      csr: blk_row_ptr int32[Mb+1] + block_cols acts as col_ind;
      icrs in-block: icrs_col_start/icrs_col_inc/icrs_row_jump_ptr/... arrays.
    """
    block_rows: Array
    block_cols: Array
    block_ptr: Array
    packed: Array
    data: Array
    # variant-specific (any may be zero-length placeholders)
    grid_ptr: Optional[Array]
    blk_col_inc: Optional[Array]
    blk_row_jump: Optional[Array]
    blk_row_ptr: Optional[Array]
    # static descriptors
    shape: Tuple[int, int] = static_field()
    beta: int = static_field()
    grid: Tuple[int, int] = static_field()          # (Mb, Nb)
    block_storage: str = static_field()
    block_order: str = static_field()               # "row"|"hilbert"|"morton"
    in_block_format: str = static_field()
    in_block_order: str = static_field()
    # thread bands for the BCOH static row distribution (start block-row per
    # band; length P+1). Stored as a plain tuple because it parameterizes
    # scheduling, not values.
    row_bands: Tuple[int, ...] = static_field(default=())

    @property
    def nnz(self) -> int:
        return self.data.shape[0]

    @property
    def num_blocks(self) -> int:
        return self.block_rows.shape[0]

    def local_rows_cols(self) -> Tuple[Array, Array]:
        lr = (self.packed >> 16).astype(jnp.int32)
        lc = (self.packed & jnp.uint32(0xFFFF)).astype(jnp.int32)
        return lr, lc

    def block_of_nnz(self) -> Array:
        k = jnp.arange(self.nnz, dtype=jnp.int32)
        return (jnp.searchsorted(self.block_ptr, k, side="right") - 1
                ).astype(jnp.int32)

    def to_coo(self) -> COO:
        bid = self.block_of_nnz()
        lr, lc = self.local_rows_cols()
        rows = self.block_rows[bid] * self.beta + lr
        cols = self.block_cols[bid] * self.beta + lc
        return COO(rows, cols, self.data, self.shape)

    def storage_bytes(self) -> int:
        """Paper-faithful storage cost of the *variant's own* scheme (not the
        canonical arrays): data + in-block indices + block-level structure."""
        b = self.nnz * self.data.dtype.itemsize
        if self.in_block_format == IN_BLOCK_PACKED_COO:
            b += self.nnz * 4                          # 16+16 packed
        else:                                          # in-block ICRS
            b += self.nnz * 2                          # 16-bit col_inc
            b += self.num_blocks * 2 * 2               # start + avg jumps
        if self.block_storage == BLOCK_STORAGE_DENSE_PTR:
            b += (self.grid[0] * self.grid[1] + 1) * 4
        elif self.block_storage == BLOCK_STORAGE_BICRS:
            b += self.num_blocks * 4                   # block_nnz 32-bit
            b += self.blk_col_inc.shape[0] * 2         # 16-bit increments
            b += self.blk_row_jump.shape[0] * 2
        else:                                          # block CSR
            b += (self.grid[0] + 1) * 4 + self.num_blocks * 4
            b += self.num_blocks * 4                   # block ptr data array
        return int(b)
