"""benchmarks.smoke_check — the CI gates over BENCH_*.json emissions,
including the chunked-psum overlap gate added with the pipelined merge
schedule: where the sweep's own roofline prediction (model_us) says a
pipelined depth beats the monolithic fixup, the best measured chunked row
must not regress >10% vs the chunks=1 row; where the model predicts
chunking loses (launch-dominated smoke sizes), nothing is gated."""
import benchmarks.smoke_check as sk


def _row(name, us, model_us=None, gflops=1.0, backend=None):
    derived = f"gflops={gflops}"
    if model_us is not None:
        derived += f";model_us={model_us}"
    if backend is not None:
        derived += f";backend={backend}"
    return {"section": "s", "name": name, "us_per_call": us,
            "derived": derived}


MERGE = "mawi_like/sellcs+merge@4dev"


def test_chunk_gate_passes_when_chunked_is_fast():
    records = [_row(f"{MERGE}/chunks=1/k=8", 100.0, model_us=10.0),
               _row(f"{MERGE}/chunks=2/k=8", 105.0, model_us=6.0),
               _row(f"{MERGE}/chunks=4/k=8", 140.0, model_us=5.0)]
    assert sk.check_chunk_regressions(records, "f.json") == []
    assert sk.check_records(records, "f.json") == []


def test_chunk_gate_fails_on_regression_where_model_pays():
    records = [_row(f"{MERGE}/chunks=1/k=8", 100.0, model_us=10.0),
               _row(f"{MERGE}/chunks=2/k=8", 120.0, model_us=6.0),
               _row(f"{MERGE}/chunks=4/k=8", 150.0, model_us=5.0)]
    problems = sk.check_chunk_regressions(records, "f.json")
    assert len(problems) == 1 and "chunks=2" in problems[0] \
        and "1.20x" in problems[0]
    # and the per-record rules surface it through check_records too
    assert any("chunks=2" in p for p in sk.check_records(records, "f.json"))


def test_chunk_gate_disarmed_when_model_predicts_loss():
    """The smoke-scale case: launch-dominated psums make the model itself
    predict chunking loses (model_us grows with depth) — a measured loss
    is then the physics the model prices, not a regression."""
    records = [_row(f"{MERGE}/chunks=1/k=8", 100.0, model_us=1.1),
               _row(f"{MERGE}/chunks=2/k=8", 250.0, model_us=2.1),
               _row(f"{MERGE}/chunks=4/k=8", 400.0, model_us=4.1)]
    assert sk.check_chunk_regressions(records, "f.json") == []


def test_chunk_gate_groups_by_matrix_and_k():
    """k=16 regresses (model pays), k=8 does not; only k=16 is reported.
    Rows of other schedules / old-format names never join a group."""
    records = [_row(f"{MERGE}/chunks=1/k=16", 100.0, model_us=10.0),
               _row(f"{MERGE}/chunks=2/k=16", 250.0, model_us=6.0),
               _row(f"{MERGE}/chunks=1/k=8", 100.0, model_us=10.0),
               _row(f"{MERGE}/chunks=2/k=8", 101.0, model_us=6.0),
               _row("mawi_like/sellcs+row@4dev/k=16", 999.0, model_us=1.0),
               _row("mawi_like/sellcs+merge@4dev/k=16", 999.0,
                    model_us=1.0)]                           # PR-2 name
    problems = sk.check_chunk_regressions(records, "f.json")
    assert len(problems) == 1 and "/k=16" in problems[0]


def test_chunk_gate_needs_baseline_and_model():
    """Chunked rows without a chunks=1 row, or rows missing the model_us
    field, gate nothing."""
    assert sk.check_chunk_regressions(
        [_row(f"{MERGE}/chunks=2/k=8", 500.0, model_us=1.0)], "f") == []
    assert sk.check_chunk_regressions(
        [_row(f"{MERGE}/chunks=1/k=8", 1.0, model_us=9.0)], "f") == []
    assert sk.check_chunk_regressions(
        [_row(f"{MERGE}/chunks=1/k=8", 100.0),
         _row(f"{MERGE}/chunks=2/k=8", 500.0)], "f") == []   # no model_us


# --------------------------------------------------------------------------
# 2-D mesh gate (spmm_sweep --mesh rows)
# --------------------------------------------------------------------------
M1 = "mawi_like/sellcs+merge@8x1mesh/chunks=1"
M2 = "mawi_like/sellcs+merge@4x2mesh/chunks=1"


def test_mesh_gate_fails_on_regression_where_model_pays():
    records = [_row(f"{M1}/k=64", 100.0, model_us=10.0, backend="tpu"),
               _row(f"{M2}/k=64", 200.0, model_us=5.0, backend="tpu")]
    problems = sk.check_mesh_regressions(records, "f.json")
    assert len(problems) == 1 and "4x2" in problems[0] \
        and "2.00x" in problems[0]
    assert any("4x2" in p for p in sk.check_records(records, "f.json"))


def test_mesh_gate_passes_within_tolerance():
    records = [_row(f"{M1}/k=64", 100.0, model_us=10.0, backend="tpu"),
               _row(f"{M2}/k=64", 105.0, model_us=5.0, backend="tpu")]
    assert sk.check_mesh_regressions(records, "f.json") == []


def test_mesh_gate_disarmed_when_model_predicts_loss():
    """Small-k / stream-dominated: the model itself says the model axis
    loses, so a measured loss is physics, not a regression."""
    records = [_row(f"{M1}/k=1", 100.0, model_us=5.0, backend="tpu"),
               _row(f"{M2}/k=1", 900.0, model_us=10.0, backend="tpu")]
    assert sk.check_mesh_regressions(records, "f.json") == []


def test_mesh_gate_disarmed_on_host_platform_mesh():
    """The CI case: a cpu host-platform mesh keeps the replicated X as one
    shared buffer, so the model-axis byte saving cannot appear in wall
    time — rows are recorded but never gated, even when the TPU byte model
    says the model axis pays. Rows with no backend field gate nothing."""
    records = [_row(f"{M1}/k=64", 100.0, model_us=10.0, backend="cpu"),
               _row(f"{M2}/k=64", 900.0, model_us=5.0, backend="cpu")]
    assert sk.check_mesh_regressions(records, "f.json") == []
    assert sk.check_records(records, "f.json") == []
    records = [_row(f"{M1}/k=64", 100.0, model_us=10.0),
               _row(f"{M2}/k=64", 900.0, model_us=5.0)]
    assert sk.check_mesh_regressions(records, "f.json") == []


def test_mesh_gate_groups_by_device_total_and_chunks():
    """A (4,2) row only compares against the Pm=1 row of the SAME device
    total and chunk depth; row-schedule and merge-schedule rows group
    separately; 1-D @Ndev rows never join a mesh group."""
    records = [
        _row(f"{M1}/k=8", 100.0, model_us=10.0, backend="tpu"),
        _row(f"{M2}/k=8", 250.0, model_us=6.0, backend="tpu"),
        # different total (16 devices) — its own group, no baseline
        _row("mawi_like/sellcs+merge@8x2mesh/chunks=1/k=8", 999.0,
             model_us=1.0, backend="tpu"),
        # different chunk depth — its own group, no baseline
        _row("mawi_like/sellcs+merge@4x2mesh/chunks=2/k=8", 999.0,
             model_us=1.0, backend="tpu"),
        # row schedule at the same total, within tolerance
        _row("mawi_like/sellcs+row@8x1mesh/k=8", 100.0, model_us=10.0,
             backend="tpu"),
        _row("mawi_like/sellcs+row@4x2mesh/k=8", 101.0, model_us=5.0,
             backend="tpu"),
        # legacy 1-D row name — not a mesh row
        _row(f"{MERGE}/chunks=1/k=8", 1.0, model_us=1.0)]
    problems = sk.check_mesh_regressions(records, "f.json")
    assert len(problems) == 1 and "sellcs+merge" in problems[0] \
        and "4x2" in problems[0]


def test_basic_rules_still_hold():
    """The pre-existing NaN / zero-GFLOP/s rules are untouched."""
    assert sk.check_records([], "f.json")                 # empty emission
    bad = sk.check_records([_row("x/k=1", float("nan"))], "f.json")
    assert any("not finite" in p for p in bad)
    bad = sk.check_records([_row("x/k=1", 1.0, gflops=0)], "f.json")
    assert any("must be finite and" in p for p in bad)


# --------------------------------------------------------------------------
# compact-gather gate (spmm_sweep --compact-x rows)
# --------------------------------------------------------------------------
CX1 = "mawi_like/sellcs+merge@4dev/chunks=1"


def test_compact_gate_fails_on_regression_where_model_pays():
    records = [_row(f"{CX1}/cx=off/k=8", 100.0, model_us=10.0,
                    backend="tpu"),
               _row(f"{CX1}/cx=on/k=8", 200.0, model_us=5.0,
                    backend="tpu")]
    problems = sk.check_compact_regressions(records, "f.json")
    assert len(problems) == 1 and "cx=on" in problems[0] \
        and "2.00x" in problems[0]
    assert any("cx=on" in p for p in sk.check_records(records, "f.json"))


def test_compact_gate_passes_within_tolerance():
    records = [_row(f"{CX1}/cx=off/k=8", 100.0, model_us=10.0,
                    backend="tpu"),
               _row(f"{CX1}/cx=on/k=8", 105.0, model_us=5.0,
                    backend="tpu")]
    assert sk.check_compact_regressions(records, "f.json") == []


def test_compact_gate_disarmed_when_model_predicts_loss():
    """The dense-columns wash: n_touched ~ n makes the model itself say
    the gather does not pay — a measured loss is gather overhead the
    model prices, not a regression."""
    records = [_row(f"{CX1}/cx=off/k=8", 100.0, model_us=5.0,
                    backend="tpu"),
               _row(f"{CX1}/cx=on/k=8", 900.0, model_us=6.0,
                    backend="tpu")]
    assert sk.check_compact_regressions(records, "f.json") == []


def test_compact_gate_disarmed_on_exact_model_tie():
    """Saturated columns make the modelled figures EXACTLY equal
    (n_touched caps at n) while the gather's overhead stays unpriced — a
    tie must not arm the gate, mirroring the selector's tie-refusal."""
    records = [_row(f"{CX1}/cx=off/k=8", 100.0, model_us=5.0,
                    backend="tpu"),
               _row(f"{CX1}/cx=on/k=8", 900.0, model_us=5.0,
                    backend="tpu")]
    assert sk.check_compact_regressions(records, "f.json") == []


def test_compact_gate_disarmed_on_host_platform_mesh():
    """The CI case: a cpu host-platform mesh keeps X as one shared
    buffer, so the gather's byte saving cannot appear in wall time —
    recorded, never gated. Rows without a backend field gate nothing."""
    records = [_row(f"{CX1}/cx=off/k=8", 100.0, model_us=10.0,
                    backend="cpu"),
               _row(f"{CX1}/cx=on/k=8", 900.0, model_us=5.0,
                    backend="cpu")]
    assert sk.check_compact_regressions(records, "f.json") == []
    assert sk.check_records(records, "f.json") == []
    records = [_row(f"{CX1}/cx=off/k=8", 100.0, model_us=10.0),
               _row(f"{CX1}/cx=on/k=8", 900.0, model_us=5.0)]
    assert sk.check_compact_regressions(records, "f.json") == []


def test_compact_gate_needs_both_rows_and_model():
    assert sk.check_compact_regressions(
        [_row(f"{CX1}/cx=on/k=8", 500.0, model_us=1.0, backend="tpu")],
        "f") == []
    assert sk.check_compact_regressions(
        [_row(f"{CX1}/cx=off/k=8", 1.0, model_us=9.0, backend="tpu")],
        "f") == []
    assert sk.check_compact_regressions(
        [_row(f"{CX1}/cx=off/k=8", 100.0, backend="tpu"),
         _row(f"{CX1}/cx=on/k=8", 500.0, backend="tpu")], "f") == []


def test_compact_gate_groups_mesh_and_row_schedule_rows():
    """cx pairs group per (base, k): 2-D mesh rows and row-schedule rows
    form their own pairs; a cx row never joins a plain (no-cx) group and
    the chunk/mesh gates keep cx=on rows apart from cx=off rows."""
    records = [
        _row("m/sellcs+row@4x2mesh/cx=off/k=8", 100.0, model_us=10.0,
             backend="tpu"),
        _row("m/sellcs+row@4x2mesh/cx=on/k=8", 250.0, model_us=6.0,
             backend="tpu"),
        # no-cx legacy row: never joins a compact pair
        _row("m/sellcs+row@4x2mesh/k=8", 1.0, model_us=1.0,
             backend="tpu"),
    ]
    problems = sk.check_compact_regressions(records, "f.json")
    assert len(problems) == 1 and "sellcs+row@4x2mesh" in problems[0]
    # the chunk gate compares cx=on rows only against cx=on rows
    records = [_row(f"{MERGE}/chunks=1/cx=on/k=8", 100.0, model_us=10.0),
               _row(f"{MERGE}/chunks=2/cx=on/k=8", 101.0, model_us=6.0),
               _row(f"{MERGE}/chunks=1/cx=off/k=8", 1.0, model_us=10.0),
               _row(f"{MERGE}/chunks=2/cx=off/k=8", 500.0, model_us=6.0)]
    problems = sk.check_chunk_regressions(records, "f.json")
    assert len(problems) == 1 and "/cx=off" in problems[0]


# ---------------------------------------------------------------------------
# transpose gate (spmm_sweep --op N,T rows)

T1 = "mawi_like/sellcs+merge@4dev/chunks=1"


def test_transpose_gate_fails_on_regression_where_model_pays():
    records = [_row(f"{T1}/op=N/k=8", 100.0, model_us=10.0,
                    backend="tpu"),
               _row(f"{T1}/op=T/k=8", 500.0, model_us=20.0,
                    backend="tpu")]
    # model predicts 2x; measured is 5x > 1.25 * 2x -> flagged
    problems = sk.check_transpose_regressions(records, "f.json")
    assert len(problems) == 1
    assert "op=T" in problems[0] and "5.00x" in problems[0]
    assert sk.check_records(records, "f.json") == problems


def test_transpose_gate_passes_within_predicted_factor():
    records = [_row(f"{T1}/op=N/k=8", 100.0, model_us=10.0,
                    backend="tpu"),
               _row(f"{T1}/op=T/k=8", 240.0, model_us=20.0,
                    backend="tpu")]
    # 2.4x measured <= 1.25 * 2x predicted
    assert sk.check_transpose_regressions(records, "f.json") == []
    # a model-predicted T *speedup* honoured the same way
    records = [_row(f"{T1}/op=N/k=8", 100.0, model_us=20.0,
                    backend="tpu"),
               _row(f"{T1}/op=T/k=8", 60.0, model_us=10.0,
                    backend="tpu")]
    assert sk.check_transpose_regressions(records, "f.json") == []


def test_transpose_gate_disarmed_on_host_platform():
    records = [_row(f"{T1}/op=N/k=8", 100.0, model_us=10.0,
                    backend="cpu"),
               _row(f"{T1}/op=T/k=8", 900.0, model_us=20.0,
                    backend="cpu")]
    assert sk.check_transpose_regressions(records, "f.json") == []
    # no backend tag at all -> equally disarmed
    records = [_row(f"{T1}/op=N/k=8", 100.0, model_us=10.0),
               _row(f"{T1}/op=T/k=8", 900.0, model_us=20.0)]
    assert sk.check_transpose_regressions(records, "f.json") == []


def test_transpose_gate_needs_both_rows_and_model():
    assert sk.check_transpose_regressions(
        [_row(f"{T1}/op=T/k=8", 900.0, model_us=20.0, backend="tpu")],
        "f") == []
    assert sk.check_transpose_regressions(
        [_row(f"{T1}/op=N/k=8", 1.0, model_us=10.0, backend="tpu")],
        "f") == []
    assert sk.check_transpose_regressions(
        [_row(f"{T1}/op=N/k=8", 100.0, backend="tpu"),
         _row(f"{T1}/op=T/k=8", 900.0, backend="tpu")], "f") == []


def test_transpose_gate_groups_by_schedule_chunks_and_k():
    """op pairs group per (base, k): a row-schedule op=T row never reads a
    merge op=N baseline, chunks=1 never pairs with chunks=2, k=8 never
    pairs with k=64."""
    records = [
        _row("m/sellcs+row@8dev/op=N/k=8", 100.0, model_us=10.0,
             backend="tpu"),
        _row("m/sellcs+row@8dev/op=T/k=8", 210.0, model_us=20.0,
             backend="tpu"),
        _row(f"{T1}/op=N/k=8", 100.0, model_us=10.0, backend="tpu"),
        _row("mawi_like/sellcs+merge@4dev/chunks=2/op=T/k=8", 900.0,
             model_us=20.0, backend="tpu"),
        _row(f"{T1}/op=T/k=64", 900.0, model_us=20.0, backend="tpu"),
    ]
    assert sk.check_transpose_regressions(records, "f.json") == []


def test_existing_gates_group_op_segments_apart():
    """The chunk/mesh/compact gates keep op=T rows apart from op=N rows:
    a chunked op=T row is judged against the chunks=1 op=T baseline, not
    the (faster) op=N one, and vice versa."""
    records = [_row(f"{MERGE}/chunks=1/op=N/k=8", 100.0, model_us=10.0),
               _row(f"{MERGE}/chunks=2/op=N/k=8", 101.0, model_us=6.0),
               _row(f"{MERGE}/chunks=1/op=T/k=8", 300.0, model_us=30.0),
               _row(f"{MERGE}/chunks=2/op=T/k=8", 900.0, model_us=18.0)]
    problems = sk.check_chunk_regressions(records, "f.json")
    assert len(problems) == 1 and "/op=T" in problems[0]
    records = [
        _row("m/sellcs+row@8x1mesh/op=T/k=8", 100.0, model_us=10.0,
             backend="tpu"),
        _row("m/sellcs+row@4x2mesh/op=T/k=8", 250.0, model_us=6.0,
             backend="tpu"),
        _row("m/sellcs+row@4x2mesh/op=N/k=8", 1.0, model_us=1.0,
             backend="tpu"),
    ]
    problems = sk.check_mesh_regressions(records, "f.json")
    assert len(problems) == 1 and "/op=T" in problems[0]
