"""The min-of-N timing protocol, factored to one place.

The paper times 550 executions and reports the minimum (§5.2): on a
memory-bound kernel the minimum is the reproducible number — everything
above it is scheduler noise, allocator stalls, and first-flush effects.
The repo used to implement this discipline twice (``benchmarks.harness``
and ``core.autotune``) while ``launch.serve`` printed single-shot
``perf_counter`` deltas for its headline speedup; now all three call
this helper, and the harness stamps the protocol parameters it ran into
every emitted record so downstream gates can tell a min-of-20 row from a
first-flush fluke.
"""
from __future__ import annotations

import time
from typing import Any, Callable, NamedTuple

try:                                    # importable without jax
    import jax as _jax
except Exception:                       # pragma: no cover - jax is a dep
    _jax = None


class TimingResult(NamedTuple):
    """One min-of-N measurement plus the protocol that produced it."""
    best_s: float          # minimum wall seconds over the timed reps
    reps: int
    warmup: int
    last_result: Any       # fn's return value from the final rep


def _block(out):
    if _jax is not None:
        try:
            return _jax.block_until_ready(out)
        except Exception:
            return out
    return out


def time_min_of_n(fn: Callable, *args, reps: int = 20, warmup: int = 3,
                  block: bool = True) -> TimingResult:
    """Min wall seconds of ``fn(*args)`` over ``reps`` timed runs after
    ``warmup`` untimed ones. ``block=True`` (default) blocks on jax
    outputs inside the timed region, so async dispatch cannot fake a
    fast row; host-only callables pass ``block=False``."""
    if reps < 1:
        raise ValueError(f"reps must be >= 1, got {reps}")
    if warmup < 0:
        raise ValueError(f"warmup must be >= 0, got {warmup}")
    out = None
    for _ in range(warmup):
        out = fn(*args)
        if block:
            _block(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        if block:
            _block(out)
        best = min(best, time.perf_counter() - t0)
    return TimingResult(best, reps, warmup, out)
