"""Mamba2-1.3B [arXiv:2405.21060]: attn-free SSD stack, state=128."""
import jax.numpy as jnp
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", n_layers=48, d_model=2048, n_heads=1, kv_heads=1,
    d_ff=0, vocab=50280, ssm_state=128, ssm_headdim=64, tie_embeddings=True,
    block_pattern=("ssm",), mlp_pattern=("none",))

REDUCED = ModelConfig(
    name="mamba2-1.3b-reduced", n_layers=2, d_model=64, n_heads=1,
    kv_heads=1, d_ff=0, vocab=256, ssm_state=16, ssm_headdim=16,
    ssm_chunk=16, tie_embeddings=True,
    block_pattern=("ssm",), mlp_pattern=("none",),
    compute_dtype=jnp.float32, loss_chunk=16)
