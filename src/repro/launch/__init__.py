"""repro.launch — mesh, sharding rules, step builders, dry-run, train/serve
entry points. NOTE: dryrun must be imported/run as __main__ first in a fresh
process (it sets XLA device-count flags)."""
from .mesh import dp_axes, make_mesh, make_production_mesh, model_axis

__all__ = ["make_production_mesh", "make_mesh", "dp_axes", "model_axis"]
