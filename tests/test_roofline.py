"""Roofline HLO parser: validated against unrolled references."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import hlo_parse
from repro.roofline.analysis import Roofline, parse_collective_bytes


def _flops(fn, *specs):
    txt = jax.jit(fn).lower(*specs).compile().as_text()
    return hlo_parse.analyze(txt)["flops"]


def test_scan_trip_count_multiplied():
    def body(c, w):
        return c @ w, None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    got = _flops(scanned, x, ws)
    expect = 8 * 2 * 128 ** 3
    assert abs(got / expect - 1) < 0.01


def test_nested_scan():
    def body(c, w):
        return c @ w, None

    def outer(x, ws):
        def ob(c, _):
            return jax.lax.scan(body, c, ws)[0], None
        return jax.lax.scan(ob, x, None, length=3)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 128, 128), jnp.float32)
    got = _flops(outer, x, ws)
    expect = 3 * 4 * 2 * 128 ** 3
    assert abs(got / expect - 1) < 0.01


def test_grad_flops_3x_forward():
    def body(c, w):
        return c @ w, None

    def loss(x, ws):
        return jnp.sum(jax.lax.scan(body, x, ws)[0] ** 2)

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    fwd = 8 * 2 * 128 ** 3
    got = _flops(jax.grad(loss, argnums=1), x, ws)
    assert 2.8 < got / fwd < 3.3


def test_dot_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    got = _flops(f, a, b)
    expect = 2 * 4 * 64 * 32 * 16
    assert abs(got / expect - 1) < 0.05


def test_collective_parse_shapes():
    txt = """
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %ag = f32[256,16]{1,0} all-gather(%p), dimensions={0}
  %ar = bf16[128]{0} all-reduce(%x), to_apply=%sum
  ROOT %r = f32[16,16]{1,0} add(%p, %p)
}
"""
    parsed = parse_collective_bytes(txt)
    assert parsed["all-gather"]["bytes"] == 256 * 16 * 4
    assert parsed["all-reduce"]["bytes"] == 128 * 2
    assert parsed["all-gather"]["count"] == 1


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops_per_device=197e12, bytes_per_device=819e9,
                 collective_bytes_per_device=0.0, chips=256,
                 model_flops=197e12 * 256)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 1.0) < 1e-9
    assert r.bottleneck in ("compute", "memory")
    assert 0.99 < r.useful_flops_fraction < 1.01
    r2 = Roofline(1e12, 1e9, 1e12, 256)
    assert r2.bottleneck == "collective"


def test_dryrun_records_if_present():
    """When the sweep has produced records, check their invariants."""
    import glob
    import json
    import os
    recs = glob.glob(os.path.join(os.path.dirname(__file__), "..",
                                  "results", "dryrun", "*.json"))
    if not recs:
        pytest.skip("no dry-run records yet")
    for f in recs:
        with open(f) as fh:
            r = json.load(fh)
        if "error" in r:
            pytest.fail(f"dry-run cell failed: {os.path.basename(f)}: "
                        f"{r['error']}")
        assert r["roofline"]["step_time_s"] > 0
        assert r["chips"] in (256, 512)
