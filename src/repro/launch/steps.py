"""Train / serve step builders + abstract input specs for every
(architecture x shape) cell — ShapeDtypeStruct stand-ins, no allocation."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, get_config
from repro.models.model import (ModelConfig, decode_step, init_cache,
                                init_params, loss_fn, prefill)
from repro.optim import Optimizer, make_optimizer, warmup_cosine
from repro.compat import set_mesh
from .mesh import dp_axes
from . import shardings as shd


class TrainState(NamedTuple):
    params: Any
    opt: Any


def default_optimizer(cfg: ModelConfig) -> Optimizer:
    # jamba-398B cannot hold AdamW state on v5e even ZeRO-sharded over a pod
    # (DESIGN §5): use factored second moments there.
    name = "adafactor" if cfg.d_model >= 8192 else "adamw"
    return make_optimizer(name, warmup_cosine(3e-4, 2000, 100_000))


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    grad_accum: int = 1):
    """Train step; grad_accum > 1 splits the batch into microbatches and
    accumulates grads under a scan — activation memory scales 1/n_micro
    while the collective schedule (one optimizer update, one grad
    reduction) is unchanged (§Perf iteration 7)."""
    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        tokens = batch["tokens"]
        vis = batch.get("vision_embeds")

        if grad_accum == 1:
            def lfn(params):
                return loss_fn(params, cfg, tokens, vision_embeds=vis)
            (loss, metrics), grads = jax.value_and_grad(
                lfn, has_aux=True)(state.params)
        else:
            B = tokens.shape[0]
            assert B % grad_accum == 0, (B, grad_accum)
            mb = B // grad_accum
            tok_m = tokens.reshape(grad_accum, mb, *tokens.shape[1:])
            vis_m = None if vis is None else vis.reshape(
                grad_accum, mb, *vis.shape[1:])

            def micro(carry, inp):
                g_acc, l_acc, ce_acc, aux_acc = carry
                t = inp[0]
                v = inp[1] if vis is not None else None

                def lfn(params):
                    return loss_fn(params, cfg, t, vision_embeds=v)
                (l, m), g = jax.value_and_grad(lfn, has_aux=True)(
                    state.params)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l, ce_acc + m["ce"],
                        aux_acc + m["aux"]), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            xs = (tok_m,) if vis is None else (tok_m, vis_m)
            (g_sum, l_sum, ce_sum, aux_sum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32),
                        jnp.zeros((), jnp.float32),
                        jnp.zeros((), jnp.float32)), xs)
            inv = 1.0 / grad_accum
            grads = jax.tree_util.tree_map(lambda g: g * inv, g_sum)
            loss = l_sum * inv
            metrics = {"ce": ce_sum * inv, "aux": aux_sum * inv}

        params, opt, om = optimizer.update(grads, state.opt, state.params)
        out = {"loss": loss, "ce": metrics["ce"], "aux": metrics["aux"],
               **om}
        return TrainState(params, opt), out

    return train_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, caches, token, pos):
        return decode_step(params, cfg, token, caches, pos)
    return serve_step


def make_prefill(cfg: ModelConfig, S_max: int):
    def prefill_step(params, tokens, vision_embeds=None):
        return prefill(params, cfg, tokens, S_max,
                       vision_embeds=vision_embeds)
    return prefill_step


# ---------------------------------------------------------------------------
# abstract inputs per cell
# ---------------------------------------------------------------------------
def _text_len(cfg: ModelConfig, seq: int) -> int:
    """VLM archs spend part of the context on vision tokens so the total
    context equals the assigned seq_len exactly."""
    return seq - (cfg.vision_tokens if cfg.frontend == "vision" else 0)


def abstract_params(cfg: ModelConfig, mesh: Mesh, profile: str = "tp"):
    shapes = jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    return shd.with_shardings(
        shapes, shd.param_shardings(shapes, mesh, profile))


def abstract_train_state(cfg: ModelConfig, optimizer: Optimizer,
                         mesh: Mesh, profile: str = "tp") -> TrainState:
    p = abstract_params(cfg, mesh, profile)
    opt_shape = jax.eval_shape(optimizer.init, p)
    opt = shd.with_shardings(
        opt_shape, shd.opt_state_shardings(opt_shape, p, mesh, profile))
    return TrainState(p, opt)


def input_specs(arch: str, shape_name: str, mesh: Mesh,
                cfg: Optional[ModelConfig] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, zero
    allocation) for every input of the cell's step function."""
    cfg = cfg or get_config(arch)
    spec = SHAPES[shape_name]
    B, S = spec.batch, spec.seq
    bs = shd.batch_sharding(mesh, B)
    out: Dict[str, Any] = {"kind": spec.kind, "cfg": cfg}

    if spec.kind == "train":
        St = _text_len(cfg, S)
        batch = {"tokens": jax.ShapeDtypeStruct((B, St), jnp.int32,
                                                sharding=bs)}
        if cfg.frontend == "vision":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.vision_dim), jnp.bfloat16,
                sharding=bs)
        out["batch"] = batch
    elif spec.kind == "prefill":
        St = _text_len(cfg, S)
        out["tokens"] = jax.ShapeDtypeStruct((B, St), jnp.int32,
                                             sharding=bs)
        if cfg.frontend == "vision":
            out["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.vision_dim), jnp.bfloat16,
                sharding=bs)
        out["s_max"] = S
    else:  # decode: one new token against a seq_len KV cache
        cache_shape = jax.eval_shape(
            lambda: init_cache(cfg, B, S, dtype=jnp.bfloat16))
        cache = shd.with_shardings(
            cache_shape, shd.cache_shardings(cache_shape, mesh, B))
        out["caches"] = cache
        out["token"] = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=bs)
        out["pos"] = jax.ShapeDtypeStruct(
            (B,), jnp.int32,
            sharding=NamedSharding(mesh, P(dp_axes(mesh))
                                   if B % shd._axis_size(
                                       mesh, tuple(dp_axes(mesh))) == 0
                                   else P()))
    return out


def _moe_mode(cfg, mesh, kind: str = "train") -> str:
    """EP when experts divide the model axis; dropless expert-TP otherwise
    (§Perf iterations 1 and 5). Decode keeps the baseline dispatch: a
    handful of tokens per device cannot amortize the shard_map dispatch
    (measured regression, §Perf iteration 6)."""
    if kind == "decode":
        return ""
    if cfg.n_experts <= 0 or not cfg.batch_axes or cfg.seq_axes:
        return ""
    if cfg.n_experts % shd._axis_size(mesh, "model") == 0:
        return "ep"
    if cfg.d_ff % shd._axis_size(mesh, "model") == 0:
        return "ep_tp"
    return ""


def cell_config(arch: str, shape_name: str, mesh: Mesh,
                profile: str = "tp") -> ModelConfig:
    """The full config specialized for this cell: batch-axis constraints
    applied when the batch is shardable over DP, MoE dispatch mode, and
    optional sequence parallelism."""
    cfg = get_config(arch)
    B = SHAPES[shape_name].batch
    S = SHAPES[shape_name].seq
    dp = dp_axes(mesh)
    if profile in ("fsdp", "fsdp_seqp"):
        all_axes = tuple(mesh.axis_names)
        if profile == "fsdp" and B % shd._axis_size(mesh, all_axes) == 0:
            cfg = dataclasses.replace(cfg, batch_axes=all_axes)
        elif B % shd._axis_size(mesh, tuple(dp)) == 0:
            cfg = dataclasses.replace(cfg, batch_axes=tuple(dp))
        if profile == "fsdp_seqp" and SHAPES[shape_name].kind != "decode" \
                and S % shd._axis_size(mesh, "model") == 0:
            # context sharding over the model axis (§Perf iteration 3)
            cfg = dataclasses.replace(
                cfg, seq_axes=("model",),
                seq_axes_size=shd._axis_size(mesh, "model"))
    elif B % shd._axis_size(mesh, tuple(dp)) == 0:
        cfg = dataclasses.replace(cfg, batch_axes=tuple(dp))
    return dataclasses.replace(
        cfg, moe_ep=_moe_mode(cfg, mesh, SHAPES[shape_name].kind))


def lower_cell(arch: str, shape_name: str, mesh: Mesh,
               cfg: Optional[ModelConfig] = None, profile: str = "tp",
               grad_accum: int = 1):
    """Lower (no compile) the step function of one cell on ``mesh``."""
    cfg = cfg or cell_config(arch, shape_name, mesh, profile)
    specs = input_specs(arch, shape_name, mesh, cfg)
    with set_mesh(mesh):
        if specs["kind"] == "train":
            optimizer = default_optimizer(cfg)
            state = abstract_train_state(cfg, optimizer, mesh, profile)
            step = make_train_step(cfg, optimizer, grad_accum=grad_accum)
            return jax.jit(step, donate_argnums=(0,)).lower(
                state, specs["batch"])
        params = abstract_params(cfg, mesh, profile)
        if specs["kind"] == "prefill":
            fn = make_prefill(cfg, specs["s_max"])
            args = (params, specs["tokens"])
            if "vision_embeds" in specs:
                args = args + (specs["vision_embeds"],)
            return jax.jit(fn).lower(*args)
        fn = make_decode_step(cfg)
        return jax.jit(fn, donate_argnums=(1,)).lower(
            params, specs["caches"], specs["token"], specs["pos"])
