"""Grouped-query attention with RoPE, optional QKV bias, qk-norm and sliding
window; full-sequence (train/prefill) and single-step (decode) paths."""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense, dense_init, rmsnorm, rmsnorm_init

Array = jax.Array


class AttnConfig(NamedTuple):
    d_model: int
    n_heads: int
    kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int = 0          # 0 => full causal attention


def attn_init(key, cfg: AttnConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.head_dim,
                         bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.kv_heads * cfg.head_dim,
                         bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.kv_heads * cfg.head_dim,
                         bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.head_dim, cfg.d_model,
                         dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.head_dim, dtype)
        p["k_norm"] = rmsnorm_init(cfg.head_dim, dtype)
    return p


def _project_qkv(p, cfg: AttnConfig, x: Array, positions: Array):
    B, S, _ = x.shape
    q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = dense(p["wk"], x).reshape(B, S, cfg.kv_heads, cfg.head_dim)
    v = dense(p["wv"], x).reshape(B, S, cfg.kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q: Array, k: Array, v: Array, mask: Array,
          cfg: AttnConfig) -> Array:
    """q [B,Sq,H,D]; k,v [B,Sk,Hkv,D]; mask [B or 1, 1, Sq, Sk] bool."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    groups = H // Hkv
    qg = q.reshape(B, Sq, Hkv, groups, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (D ** -0.5)
    logits = jnp.where(mask[:, :, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H * D)


def flash_sdpa(q: Array, k: Array, v: Array, cfg: AttnConfig, *,
               q_offset: int = 0, q_chunk: int = 1024,
               k_chunk: int = 1024, vmap_q: bool = False) -> Array:
    """Blockwise (FlashAttention-style) causal SDPA in pure JAX: online
    softmax over key chunks, scanned over query chunks. Memory is
    O(q_chunk * k_chunk) instead of O(Sq * Sk) — required for the 32k/500k
    shapes. Fully-masked key blocks are still computed (and masked); skipping
    them is a recorded §Perf optimization lever."""
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qc = min(q_chunk, Sq)
    kc = min(k_chunk, Sk)
    nq, nk = -(-Sq // qc), -(-Sk // kc)
    Sq_p, Sk_p = nq * qc, nk * kc
    scale = D ** -0.5

    qf = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    # [B, Hkv, g, nq, qc, D] / [B, Hkv, nk, kc, D]
    qf = qf.reshape(B, nq, qc, Hkv, g, D).transpose(1, 0, 3, 4, 2, 5)
    kf = kf.reshape(B, nk, kc, Hkv, D).transpose(1, 0, 3, 2, 4)
    vf = vf.reshape(B, nk, kc, Hkv, D).transpose(1, 0, 3, 2, 4)

    def one_q_chunk(carry, qi_and_chunk):
        qi, qb = qi_and_chunk              # qb: [B, Hkv, g, qc, D]
        qpos = q_offset + qi * qc + jnp.arange(qc)

        def one_k_chunk(state, ki_and_kv):
            m, l, acc = state
            ki, kb, vb = ki_and_kv
            kpos = ki * kc + jnp.arange(kc)
            logits = jnp.einsum("bhgqd,bhkd->bhgqk",
                                qb.astype(jnp.float32),
                                kb.astype(jnp.float32)) * scale
            mask = kpos[None, :] <= qpos[:, None]
            if cfg.sliding_window > 0:
                mask &= kpos[None, :] > qpos[:, None] - cfg.sliding_window
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * corr + p.sum(axis=-1)
            # PV product in bf16 (f32 accumulate): halves the dominant
            # traffic term of long prefill (§Perf iteration 4); max/sum
            # stats stay f32 so the online softmax is unaffected
            acc_new = acc * corr[..., None] + jax.lax.dot_general(
                p.astype(jnp.bfloat16), vb.astype(jnp.bfloat16),
                (((4,), (2,)), ((0, 1), (0, 1))),
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, qc), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, qc, D), jnp.float32)
        # checkpoint the chunk body: backward recomputes the [qc, kc] score
        # block instead of saving one per iteration (the flash memory win
        # must survive autodiff)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(one_k_chunk), (m0, l0, a0),
            (jnp.arange(nk), kf, vf))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return carry, out

    if vmap_q:
        # sequence parallelism: q chunks are independent — vmap keeps each
        # device's chunks local instead of a sequential (gathering) scan
        outs = jax.vmap(lambda qi, qb: one_q_chunk(None, (qi, qb))[1]
                        )(jnp.arange(nq), qf)
    else:
        _, outs = jax.lax.scan(one_q_chunk, None, (jnp.arange(nq), qf))
    # outs: [nq, B, Hkv, g, qc, D] -> [B, Sq, H*D]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq_p, H * D)
    return out[:, :Sq]


FLASH_THRESHOLD = 2048


def causal_mask(Sq: int, Sk: int, offset: int = 0,
                sliding_window: int = 0) -> Array:
    """[1, 1, Sq, Sk] bool; query i attends to keys <= i+offset, and within
    the window if sliding_window > 0."""
    qi = jnp.arange(Sq)[:, None] + offset
    ki = jnp.arange(Sk)[None, :]
    m = ki <= qi
    if sliding_window > 0:
        m &= ki > qi - sliding_window
    return m[None, None]


def attention(p, cfg: AttnConfig, x: Array,
              positions: Optional[Array] = None,
              vmap_q: bool = False) -> Array:
    """Full-sequence causal attention (train / prefill)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    if S >= FLASH_THRESHOLD:
        out = flash_sdpa(q, k, v, cfg, vmap_q=vmap_q)
    else:
        mask = causal_mask(S, S, 0, cfg.sliding_window)
        out = _sdpa(q, k, v, mask, cfg)
    return dense(p["wo"], out.astype(x.dtype))


class KVCache(NamedTuple):
    k: Array        # [B, S_max, Hkv, D]
    v: Array        # [B, S_max, Hkv, D]

    @classmethod
    def init(cls, B: int, S_max: int, cfg: AttnConfig, dtype=jnp.bfloat16):
        shape = (B, S_max, cfg.kv_heads, cfg.head_dim)
        return cls(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def attention_decode(p, cfg: AttnConfig, x: Array, cache: KVCache,
                     pos: Array) -> Tuple[Array, KVCache]:
    """One new token per sequence. x: [B, 1, d_model]; pos: [B] int32 index
    of the new token. Attends to cache[0:pos] + itself."""
    B, S1, _ = x.shape
    assert S1 == 1
    S_max = cache.k.shape[1]
    q, k, v = _project_qkv(p, cfg, x, pos[:, None])
    new_k = jax.vmap(
        lambda c, upd, i: jax.lax.dynamic_update_slice(c, upd, (i, 0, 0))
    )(cache.k, k.astype(cache.k.dtype), pos)
    new_v = jax.vmap(
        lambda c, upd, i: jax.lax.dynamic_update_slice(c, upd, (i, 0, 0))
    )(cache.v, v.astype(cache.v.dtype), pos)
    ki = jnp.arange(S_max)[None, :]                     # [1, S_max]
    m = ki <= pos[:, None]
    if cfg.sliding_window > 0:
        m &= ki > (pos[:, None] - cfg.sliding_window)
    mask = m[:, None, None, :]                          # [B, 1, 1, S_max]
    out = _sdpa(q, new_k, new_v, mask, cfg)
    return dense(p["wo"], out.astype(x.dtype)), KVCache(new_k, new_v)


def prefill_cache(p, cfg: AttnConfig, x: Array, S_max: int,
                  dtype=jnp.bfloat16, vmap_q: bool = False
                  ) -> Tuple[Array, KVCache]:
    """Run full attention over the prompt and return output + primed cache."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    if S >= FLASH_THRESHOLD:
        out = flash_sdpa(q, k, v, cfg, vmap_q=vmap_q)
    else:
        mask = causal_mask(S, S, 0, cfg.sliding_window)
        out = _sdpa(q, k, v, mask, cfg)
    cache = KVCache.init(B, S_max, cfg, dtype)
    cache = KVCache(cache.k.at[:, :S].set(k.astype(dtype)),
                    cache.v.at[:, :S].set(v.astype(dtype)))
    return dense(p["wo"], out.astype(x.dtype)), cache
