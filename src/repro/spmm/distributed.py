"""Distributed multi-RHS SpMM over shard_map — the product of the paper's
two winning parallel schedules (BCOH row banding §3.2, merge-path equal-nnz
spans §3.3) and the SpMM engine's SELL-C-σ slice stream.

The σ-sorted slice stream is already a sequence of uniform work quanta
(one width-row = C padded nonzeros), which makes both cross-device
schedules one-liners over it:

* ``partition_sellcs_rows`` + ``spmm_row_distributed`` — BCOH across the
  mesh: contiguous *slice* bands balanced by width-row count, the k-block X
  replicated per shard (the paper's interleaved x allocation), Y written
  shard-local in slot space — **zero collectives**. Loses only when one
  slice dominates (a mawi-style dense row never splits).

* ``partition_sellcs_nnz`` + ``spmm_merge_distributed`` — merge-path
  across the mesh: equal spans of *width-rows* regardless of slice
  boundaries (a dense row's slice is split mid-stream), partial slot
  contributions combined with one ``psum`` — the cross-device carry-out
  fixup, at the cost of an all-reduce on Y.

Both shard_map bodies reuse the PR-1 compute verbatim: the k-tiled Pallas
kernel (``kernels.sellcs_slots``) on TPU, its jnp twin
(``reference.sellcs_slots_ref``) off-TPU — a shard's slice stream is just a
shorter stream with its own ``slice_of`` relabeling. The σ-sort row
permutation is global, so it is undone once, *after* the mesh region, by
the same single scatter the single-device path uses.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.distributed import _check_devices
from repro.core.mergepath import balanced_row_bands
from .kernels import LANE, choose_k_tile, sellcs_slots
from .reference import _as_2d, sellcs_slots_ref
from .sellcs import SellCS


class ShardedSellCS(NamedTuple):
    """Per-device SELL-C-σ width-row shards, stacked on a leading device
    axis. ``schedule`` records which partitioner built it (the two
    schedules index slices differently)."""
    data: jax.Array          # f32[Pdev, Wp, C] — zero-padded width-rows
    cols: jax.Array          # int32[Pdev, Wp, C] — global column indices
    slice_of: jax.Array      # int32[Pdev, Wp] — LOCAL slice ids ("row")
                             #   or GLOBAL slice ids ("merge")
    slice_offset: jax.Array  # int32[Pdev] — first global slice per shard
                             #   ("row"; zeros for "merge")
    row_perm: jax.Array      # int32[S*C] — global σ-sort permutation
    shape: Tuple[int, int]
    chunk: int               # C — slice height
    num_slices: int          # S — GLOBAL slice count
    slices_per_shard: int    # local slot space height ("row"; S for "merge")
    nnz: int
    schedule: str            # "row" | "merge"


def partition_sellcs_rows(sc: SellCS, num_devices: int) -> ShardedSellCS:
    """BCOH banding over the slice stream: contiguous slice ranges balanced
    by width-row count (each width-row is C padded nonzeros, so equal width
    is equal work). Host-side, convert time.

    Slices own disjoint row slots, so slice bands shard the (σ-permuted)
    rows — Y needs no collective.
    """
    _check_devices(num_devices)
    C = sc.chunk
    S = sc.num_slices
    slice_ptr = np.asarray(sc.slice_ptr, np.int64)
    data = np.asarray(sc.data)
    cols = np.asarray(sc.cols)
    slice_of = np.asarray(sc.slice_of, np.int64)
    # slice_ptr IS the cumulative width — reuse the paper's band splitter
    # with "rows" = slices and "nnz" = width-rows.
    bounds = balanced_row_bands(slice_ptr, num_devices).astype(np.int64)
    w_start = slice_ptr[bounds]
    Wp = max(int(np.diff(w_start).max()) if num_devices else 1, 1)
    Sp = max(int(np.diff(bounds).max()), 1)

    D = np.zeros((num_devices, Wp, C), data.dtype if data.size else
                 np.float32)
    Cc = np.zeros((num_devices, Wp, C), np.int32)
    So = np.zeros((num_devices, Wp), np.int32)
    for p in range(num_devices):
        a, b = int(w_start[p]), int(w_start[p + 1])
        ln = b - a
        if ln:
            D[p, :ln] = data[a:b]
            Cc[p, :ln] = cols[a:b]
            So[p, :ln] = (slice_of[a:b] - bounds[p]).astype(np.int32)
    return ShardedSellCS(
        jnp.asarray(D), jnp.asarray(Cc), jnp.asarray(So),
        jnp.asarray(bounds[:-1].astype(np.int32)), sc.row_perm,
        sc.shape, C, S, Sp, sc.nnz, "row")


def partition_sellcs_nnz(sc: SellCS, num_devices: int) -> ShardedSellCS:
    """Merge-style equal spans over the width-row stream (slices — and with
    them dense rows — may straddle devices). ``slice_of`` stays global:
    every device scatters into the full slot space and the carry-out is
    fixed with one psum."""
    _check_devices(num_devices)
    C = sc.chunk
    S = sc.num_slices
    data = np.asarray(sc.data)
    cols = np.asarray(sc.cols)
    slice_of = np.asarray(sc.slice_of, np.int64)
    W = data.shape[0]
    bounds = (np.arange(num_devices + 1, dtype=np.int64) * W) // num_devices
    Wp = max(int(np.diff(bounds).max()), 1)

    D = np.zeros((num_devices, Wp, C), data.dtype if data.size else
                 np.float32)
    Cc = np.zeros((num_devices, Wp, C), np.int32)
    So = np.zeros((num_devices, Wp), np.int32)
    for p in range(num_devices):
        a, b = int(bounds[p]), int(bounds[p + 1])
        ln = b - a
        if ln:
            D[p, :ln] = data[a:b]
            Cc[p, :ln] = cols[a:b]
            So[p, :ln] = slice_of[a:b].astype(np.int32)
    return ShardedSellCS(
        jnp.asarray(D), jnp.asarray(Cc), jnp.asarray(So),
        jnp.zeros((num_devices,), jnp.int32), sc.row_perm,
        sc.shape, C, S, S, sc.nnz, "merge")


def _prep(sharded: ShardedSellCS, x: jax.Array, mesh: Mesh, axis: str,
          impl: str, k_tile: Optional[int], expect: str):
    if sharded.schedule != expect:
        raise ValueError(
            f"sharded matrix was partitioned for the {sharded.schedule!r} "
            f"schedule; build it with partition_sellcs_"
            f"{'rows' if expect == 'row' else 'nnz'} instead")
    ndev = sharded.data.shape[0]
    if ndev != mesh.shape[axis]:
        raise ValueError(
            f"matrix is partitioned over {ndev} devices but mesh axis "
            f"{axis!r} has {mesh.shape[axis]}")
    if impl not in ("ref", "pallas", "pallas_interpret"):
        raise ValueError(f"impl must be ref|pallas|pallas_interpret, "
                         f"got {impl!r}")
    x2, squeeze = _as_2d(x)
    n = sharded.shape[1]
    if x2.shape[0] != n:
        raise ValueError(f"X rows {x2.shape[0]} != matrix n {n}")
    k = x2.shape[1]
    use_pallas = impl != "ref"
    if use_pallas:
        kt = k_tile or choose_k_tile(sharded.shape, k, nnz=sharded.nnz)
        np_ = -(-max(n, 1) // LANE) * LANE
        kp = -(-k // kt) * kt
        x_pad = jnp.zeros((np_, kp), x2.dtype).at[:n, :k].set(x2)
    else:
        kt = k_tile
        x_pad = x2
    return x2, squeeze, k, kt, x_pad, use_pallas


def _local_slots(data, cols, slice_of, x_rep, *, num_slices, chunk,
                 use_pallas, k_tile, interpret):
    """Shard-local compute: the PR-1 k-tiled Pallas kernel, or its jnp twin
    off-TPU. Inputs carry a leading length-1 device-block axis."""
    if use_pallas:
        return sellcs_slots(data[0], cols[0], slice_of[0], x_rep,
                            num_slices=num_slices, chunk=chunk,
                            k_tile=k_tile, interpret=interpret)
    return sellcs_slots_ref(data[0], cols[0], slice_of[0], x_rep,
                            num_slices=num_slices, chunk=chunk)


def _unpermute(sharded: ShardedSellCS, y_slots: jax.Array, k: int,
               squeeze: bool) -> jax.Array:
    """Undo the global σ-sort with one scatter (padding slots target row m,
    which is dropped)."""
    m = sharded.shape[0]
    y = jnp.zeros((m + 1, y_slots.shape[1]), y_slots.dtype
                  ).at[sharded.row_perm].add(y_slots)[:m, :k]
    return y[:, 0] if squeeze else y


def spmm_row_distributed(sharded: ShardedSellCS, x: jax.Array, mesh: Mesh,
                         axis: str = "data", *, impl: str = "ref",
                         k_tile: Optional[int] = None) -> jax.Array:
    """Y = A @ X with slice banding: X replicated, Y shard-local slots,
    zero collectives inside the mesh region."""
    m, n = sharded.shape
    C, S, Sp = sharded.chunk, sharded.num_slices, sharded.slices_per_shard
    ndev = sharded.data.shape[0]
    x2, squeeze, k, kt, x_pad, use_pallas = _prep(
        sharded, x, mesh, axis, impl, k_tile, "row")
    if sharded.nnz == 0:
        y = jnp.zeros((m, k), jnp.float32)
        return y[:, 0] if squeeze else y

    def local(data, cols, slice_of, x_rep):
        return _local_slots(data, cols, slice_of, x_rep, num_slices=Sp,
                            chunk=C, use_pallas=use_pallas, k_tile=kt,
                            interpret=impl == "pallas_interpret")

    # pallas_call has no replication rule inside shard_map — skip the check
    yb = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None, None), P(axis, None),
                  P(None, None)),
        out_specs=P(axis, None),
        check_vma=False if use_pallas else None)(
            sharded.data, sharded.cols, sharded.slice_of, x_pad)
    yb = yb.reshape(ndev, Sp * C, -1)
    # shard p owns global slices [slice_offset[p], slice_offset[p+1]);
    # scatter its local slots there, dumping padding slots past S*C.
    offs = sharded.slice_offset
    valid_slices = jnp.concatenate(
        [offs[1:], jnp.array([S], jnp.int32)]) - offs           # [Pdev]
    local_slice = jnp.arange(Sp * C, dtype=jnp.int32) // C
    gslot = (offs[:, None] + local_slice[None]) * C \
        + (jnp.arange(Sp * C, dtype=jnp.int32) % C)[None]       # [Pdev, SpC]
    mask = local_slice[None] < valid_slices[:, None]
    y_slots = jnp.zeros((S * C + 1, yb.shape[-1]), yb.dtype).at[
        jnp.where(mask, gslot, S * C)].add(
            jnp.where(mask[..., None], yb, 0))[:S * C]
    return _unpermute(sharded, y_slots, k, squeeze)


def spmm_merge_distributed(sharded: ShardedSellCS, x: jax.Array, mesh: Mesh,
                           axis: str = "data", *, impl: str = "ref",
                           k_tile: Optional[int] = None) -> jax.Array:
    """Y = A @ X with equal-width spans: per-device slot partials + one
    psum carry-out fixup (the only collective). Survives the mawi dense-row
    pathology — the dense slice splits mid-stream."""
    m, n = sharded.shape
    C, S = sharded.chunk, sharded.num_slices
    x2, squeeze, k, kt, x_pad, use_pallas = _prep(
        sharded, x, mesh, axis, impl, k_tile, "merge")
    if sharded.nnz == 0:
        y = jnp.zeros((m, k), jnp.float32)
        return y[:, 0] if squeeze else y

    def local(data, cols, slice_of, x_rep):
        y_loc = _local_slots(data, cols, slice_of, x_rep, num_slices=S,
                             chunk=C, use_pallas=use_pallas, k_tile=kt,
                             interpret=impl == "pallas_interpret")
        return jax.lax.psum(y_loc, axis)

    y_slots = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None, None), P(axis, None),
                  P(None, None)),
        out_specs=P(None, None),
        check_vma=False if use_pallas else None)(
            sharded.data, sharded.cols, sharded.slice_of, x_pad)
    return _unpermute(sharded, y_slots, k, squeeze)
