"""Merge-path load balancing (paper §3.3, Merrill & Garland 2016).

The merge path runs over two "lists": A = row_ptr[1:] (row end offsets,
length m) and B = the natural numbers 0..nnz-1 (nonzero indices). Total path
length is m + nnz; cutting it into P equal diagonals gives every worker the
same number of (multiply-add | row-output) operations — *perfect* static load
balance for arbitrary row distributions, including the mawi-like single dense
row that breaks row-distributed schemes (paper Table 6.3).

At diagonal d the split (i, j), i + j = d, is the smallest i such that
A[i] + i >= d (g(i) = A[i] + i is strictly increasing, so a binary search /
``searchsorted`` finds it). This runs in O(P log m) once per matrix, not per
multiply — on TPU it is executed at convert time and the resulting spans are
scalar-prefetched into the kernel grid.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class MergePartition(NamedTuple):
    """Start coordinates per worker (length P+1; worker p owns
    [starts[p], starts[p+1]) on both axes)."""
    row_starts: Array    # int32[P+1] — first row each worker touches
    nnz_starts: Array    # int32[P+1] — first nonzero each worker consumes
    diagonals: Array     # int32[P+1] — the cut diagonals


def merge_path_partition(row_ptr: Array, num_parts: int) -> MergePartition:
    """Cut the merge path of a CSR structure into ``num_parts`` equal spans."""
    m = row_ptr.shape[0] - 1
    row_ptr = jnp.asarray(row_ptr, jnp.int32)
    nnz = row_ptr[-1]
    total = m + nnz
    p = jnp.arange(num_parts + 1, dtype=jnp.int32)
    # equal diagonals (last one clipped to the path end)
    diag = jnp.minimum(p * ((total + num_parts - 1) // num_parts),
                       total).astype(jnp.int32)
    keys = row_ptr[1:] + jnp.arange(m, dtype=jnp.int32)   # g(i) = A[i] + i
    i = jnp.searchsorted(keys, diag, side="left").astype(jnp.int32)
    j = diag - i
    return MergePartition(row_starts=i, nnz_starts=j, diagonals=diag)


def merge_path_partition_np(row_ptr: np.ndarray,
                            num_parts: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host twin used at convert time; returns (row_starts, nnz_starts)."""
    row_ptr = np.asarray(row_ptr, np.int64)
    m = row_ptr.shape[0] - 1
    nnz = int(row_ptr[-1])
    total = m + nnz
    step = -(-total // num_parts)
    diag = np.minimum(np.arange(num_parts + 1, dtype=np.int64) * step, total)
    keys = row_ptr[1:] + np.arange(m, dtype=np.int64)
    i = np.searchsorted(keys, diag, side="left")
    j = diag - i
    return i.astype(np.int32), j.astype(np.int32)


def balanced_row_bands(row_ptr: np.ndarray, num_bands: int) -> np.ndarray:
    """BCOH-style static distribution (paper §3.2): split *rows* so every band
    holds ~nnz/P nonzeros. Returns int32[num_bands+1] row boundaries.

    Unlike merge-path this never splits a row — a single dense row defeats it
    (paper Table 6.3) — but it needs no carry-out fixup and writes y
    shard-locally, which is why BCOH wins on NUMA machines (→ on the `data`
    mesh axis, row bands mean **zero collectives on y**)."""
    row_ptr = np.asarray(row_ptr, np.int64)
    nnz = int(row_ptr[-1])
    m = row_ptr.shape[0] - 1
    targets = (np.arange(num_bands + 1, dtype=np.int64) * nnz) // num_bands
    bounds = np.searchsorted(row_ptr, targets, side="left")
    bounds[0], bounds[-1] = 0, m
    return np.maximum.accumulate(bounds).astype(np.int32)


def span_block_aligned(block_ptr: np.ndarray, num_parts: int) -> np.ndarray:
    """Equal-nnz spans over *blocks* (never splits a block): for blocked
    kernels, worker p processes blocks [spans[p], spans[p+1]).

    This is the TPU replacement for CSB's dynamic tasking: over-decompose into
    num_parts ≫ cores spans; balance is static but the variance per span is
    bounded by the largest block, mirroring the paper's task-split rule."""
    block_ptr = np.asarray(block_ptr, np.int64)
    nb = block_ptr.shape[0] - 1
    nnz = int(block_ptr[-1])
    targets = (np.arange(num_parts + 1, dtype=np.int64) * nnz) // num_parts
    spans = np.searchsorted(block_ptr, targets, side="left")
    spans[0], spans[-1] = 0, nb
    return np.maximum.accumulate(spans).astype(np.int32)
