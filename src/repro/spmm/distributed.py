"""Distributed multi-RHS SpMM over shard_map — the product of the paper's
two winning parallel schedules (BCOH row banding §3.2, merge-path equal-nnz
spans §3.3) and the SpMM engine's SELL-C-σ slice stream.

The σ-sorted slice stream is already a sequence of uniform work quanta
(one width-row = C padded nonzeros), which makes both cross-device
schedules one-liners over it:

* ``partition_sellcs_rows`` + ``spmm_row_distributed`` — BCOH across the
  mesh: contiguous *slice* bands balanced by width-row count, the k-block X
  replicated per shard (the paper's interleaved x allocation), Y written
  shard-local in slot space — **zero collectives**. Loses only when one
  slice dominates (a mawi-style dense row never splits).

* ``partition_sellcs_nnz`` + ``spmm_merge_distributed`` — merge-path
  across the mesh: equal spans of *width-rows* regardless of slice
  boundaries (a dense row's slice is split mid-stream), partial slot
  contributions combined with a ``psum`` — the cross-device carry-out
  fixup, at the cost of an all-reduce on Y. With ``num_chunks > 1`` the
  fixup is *pipelined*: the slot space is split into spans of consecutive
  slices and each span's psum is issued right after its local compute, so
  the collective hides under the next span's slice stream instead of
  serializing after all of it (Eckstein & Mátyásfalvi, arXiv:1812.00904).

Both shard_map bodies reuse the PR-1 compute verbatim: the k-tiled Pallas
kernel (``kernels.sellcs_slots``) on TPU, its jnp twin
(``reference.sellcs_slots_ref``) off-TPU — a shard's slice stream is just a
shorter stream with its own ``slice_of`` relabeling. The σ-sort row
permutation is global, so it is undone once, *after* the mesh region, by
the same single scatter the single-device path uses.

2-D (``data``, ``model``) meshes — the k ≫ 128 scaling axis: when the mesh
carries a ``model`` axis, both multiplies additionally shard the padded X
and Y k-slabs across it. Each model shard owns ``kp / P_model`` columns of
X (and computes only those columns of Y), the slice stream is replicated
along ``model``, and every psum of the merge fixup runs on the ``data``
axis alone — so per-device collective bytes AND per-device replicated-X
read bytes both drop by ``P_model``. The column split composes with the
chunked pipeline orthogonally: columns are independent, so no extra
collective appears. This is the distributed-memory cure of Eckstein &
Mátyásfalvi applied to the vector dimension: shrink what crosses the wire
instead of pushing it harder.

Sparsity-aware X gather (``compact_x``) — the remaining un-shrunk traffic
term: a data shard's slice stream touches only the columns its nonzeros
name, yet the replicated X slab makes every shard read all ``n`` rows.
Partitioning with ``compact_x=True`` computes each shard's touched-column
map at convert time (``col_map``/``n_touched``), relabels the shard's
``cols`` into the compacted index space ``[0, n_touched)``, and the
multiply gathers the touched X rows once per call into a per-shard
``[n_touched, kc]`` slab (still column-sharded across ``model``) — the
replicated-X read becomes nnz-proportional on both mesh axes, the
hypergraph-partitioning move of Eckstein & Mátyásfalvi applied to the
vector reads. Compaction composes with ``num_chunks`` pipelining (the
span re-deal builds its own touched map over the re-dealt rows) and costs
one int32 map per shard, priced by ``ShardedSellCS.storage_bytes`` and
``roofline.spmm_distributed_traffic(compact_x=True)``.

Gather scheduling (``gather=``) — hiding the compact-X gather: the
up-front ``x_pad[col_map]`` slab build is one XLA gather serialized on
the critical path before the first kernel launch. ``gather="overlap"``
(chunked merge) rebuilds each span's piece of the slab inside the mesh
region from the plan's per-span touched split, so span ``i+1``'s gather
hides under span ``i``'s kernel/psum; ``gather="fused"`` skips the slab
entirely — ``col_map`` rides the Pallas scalar prefetch next to
``slice_of`` and the kernel indexes the full X directly. All modes are
bitwise-identical; ``roofline.spmm_distributed_gather_s`` prices the
exposed seconds of each so the selector can choose.

Phase tracing (``repro.obs``): both multiplies carry ``span()`` markers at
the phase boundaries the structure already has — ``spmm/gather_x`` (the
compact-X gather ahead of the mesh region; under ``gather="overlap"`` it
splits into per-span ``spmm/gather_x/span<i>`` sub-spans inside the mesh
body), ``spmm/mesh`` (the whole
shard_map region), ``spmm/kernel`` / ``spmm/psum`` (inside the mesh body
— host time there is trace time, but the names ride into compiled HLO
via ``jax.named_scope`` so device profiles show them), and
``spmm/fixup`` (the σ-unpermute scatter). With no registry installed the
spans are allocation-free no-ops; with one installed the host-level
spans additionally block on their outputs so they time execution, not
async dispatch (``obs.maybe_block``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.distributed import _check_devices
from repro.core.mergepath import balanced_row_bands
from repro.obs import maybe_block, span
from .kernels import (LANE, choose_k_tile, sellcs_slots, sellcs_slots_chunk,
                      sellcs_slots_t)
from .reference import (_as_2d, sellcs_slots_chunk_ref, sellcs_slots_ref,
                        sellcs_slots_t_ref)
from .sellcs import SellCS


class ShardedSellCS(NamedTuple):
    """Per-device SELL-C-σ width-row shards, stacked on a leading device
    axis. ``schedule`` records which partitioner built it (the two
    schedules index slices differently)."""
    data: jax.Array          # f32[Pdev, Wp, C] — zero-padded width-rows
    cols: jax.Array          # int32[Pdev, Wp, C] — global column indices
    slice_of: jax.Array      # int32[Pdev, Wp] — LOCAL slice ids ("row")
                             #   or GLOBAL slice ids ("merge")
    slice_offset: jax.Array  # int32[Pdev] — first global slice per shard
                             #   ("row"; zeros for "merge")
    row_perm: jax.Array      # int32[S*C] — global σ-sort permutation
    shape: Tuple[int, int]
    chunk: int               # C — slice height
    num_slices: int          # S — GLOBAL slice count
    slices_per_shard: int    # local slot space height ("row"; S for "merge")
    nnz: int
    schedule: str            # "row" | "merge"
    chunk_plan: Optional[Tuple] = None
                             # (num_chunks, spans, plan col_map, plan
                             #   n_touched) precomputed by
                             #   partition_sellcs_nnz(num_chunks=) so the
                             #   pipelined multiply never re-deals the
                             #   stream host-side per call; the map entries
                             #   are None unless compact_x (the span
                             #   re-deal owns different rows than the base
                             #   partition, hence its own map)
    row_counts: Optional[jax.Array] = None
                             # int32[Pdev] — REAL width-rows per shard,
                             #   recorded at partition time. The stream can
                             #   carry width-rows whose stored values are
                             #   all explicit zeros (SellCS.to_coo
                             #   round-trips them by design), so real vs
                             #   padding is NOT derivable from the values.
    col_map: Optional[jax.Array] = None
                             # int32[Pdev, Ntc] — sorted global column ids
                             #   each shard touches (compact_x=True only):
                             #   the multiply gathers X rows through this
                             #   map instead of replicating all n rows.
                             #   cols above are relabeled into its index
                             #   space; padding entries point at slot 0.
    n_touched: Optional[jax.Array] = None
                             # int32[Pdev] — true distinct-column count per
                             #   shard (the real prefix of each col_map row)
    structure: str = "general"
                             # "general" | "symmetric" — symmetric shards
                             #   carry one stored triangle (row >= col) and
                             #   the dense diagonal below; the multiply
                             #   combines the normal and transpose passes
    diag: Optional[jax.Array] = None
                             # f32[m] dense diagonal (symmetric mode only)

    def storage_bytes(self) -> int:
        """Faithful device-side cost of the partitioned stream: every
        member array, the ``compact_x`` column maps, and any baked chunk
        plan. Kept equal to the sum of the member arrays' ``nbytes``
        (asserted in the tests) so the paper's conversion-amortization
        comparisons ("472 multiplications" §7) never flatter the
        distributed format — the col_map is storage the compaction buys
        its gather with, not free metadata."""
        total = (self.data.nbytes + self.cols.nbytes + self.slice_of.nbytes
                 + self.slice_offset.nbytes + self.row_perm.nbytes)
        for opt in (self.row_counts, self.col_map, self.n_touched,
                    self.diag):
            if opt is not None:
                total += opt.nbytes
        if self.chunk_plan is not None:
            for sp in self.chunk_plan[1]:
                total += sp.data.nbytes + sp.cols.nbytes + sp.slice_of.nbytes
                for opt in (sp.sub, sp.col_map, sp.n_touched):
                    if opt is not None:
                        total += opt.nbytes
            for opt in self.chunk_plan[2:]:
                if opt is not None:
                    total += opt.nbytes
        return int(total)


def _compact_columns(Cc: np.ndarray, counts: np.ndarray):
    """Host-side, convert time: per-shard touched-column maps over the
    device-dealt ``cols`` blocks.

    ``Cc[p, :counts[p]]`` holds shard ``p``'s REAL width-rows (lane padding
    inside a real width-row carries col 0 with data 0 — the harmless-FMA
    convention — so col 0 joins the touched set whenever the shard is
    nonempty: the kernel really does read that X row). Returns
    ``(relabeled Cc, col_map int32[P, Ntc], n_touched int32[P])`` where
    ``col_map[p]`` is the sorted touched set (zero-padded to the widest
    shard) and ``Cc`` is rewritten in-place into its index space.
    Padding width-rows keep col 0 — in range of every gathered slab.
    """
    P = Cc.shape[0]
    touched = [np.unique(Cc[p, :int(counts[p])]) if int(counts[p])
               else np.zeros(0, np.int64) for p in range(P)]
    col_map, n_touched = _pack_maps(touched)
    for p, t in enumerate(touched):
        ln = int(counts[p])
        if ln:
            Cc[p, :ln] = np.searchsorted(t, Cc[p, :ln])
    return Cc, col_map, n_touched


def _pack_maps(touched):
    """Stack per-device sorted touched sets into the dense
    ``(col_map int64[P, Ntc], n_touched int64[P])`` pair (zero-padded to
    the widest shard; Ntc >= 1 so an all-empty mesh still gathers a
    1-row slab).

    Ntc is rounded up to the Pallas lane width HERE, at bake time, so the
    multiply-time gather is a single ``x_pad[col_map]`` — no per-call
    ``jnp.concatenate`` pad inside the jitted hot path. Padding entries
    point at row 0 (the harmless-FMA convention: only data == 0 lanes ever
    index them); the invariant is asserted host-side once, where it is
    cheap, instead of trusted inside every trace."""
    n_touched = np.array([t.size for t in touched], np.int64)
    Ntc = max(int(n_touched.max()) if len(touched) else 0, 1)
    Ntc = -(-Ntc // LANE) * LANE
    col_map = np.zeros((len(touched), Ntc), np.int64)
    for p, t in enumerate(touched):
        col_map[p, :t.size] = t
        assert not col_map[p, t.size:].any(), \
            "col_map padding must point at row 0"
    return col_map, n_touched


def _deal_slice_bands(data: np.ndarray, cols: np.ndarray,
                      slice_of: np.ndarray, slice_ptr: np.ndarray,
                      num_devices: int, C: int):
    """The BCOH deal over a global width-row stream: contiguous slice
    bands balanced by width-row count (``balanced_row_bands`` — slice_ptr
    IS the cumulative width, so "rows" = slices and "nnz" = width-rows).
    Slice ids come out LOCAL (rebased per band). Shared by the convert-time
    partitioner and the device-loss re-deal. Returns
    ``(D, Cc, So, bounds, Sp, counts)``."""
    bounds = balanced_row_bands(slice_ptr, num_devices).astype(np.int64)
    w_start = slice_ptr[bounds]
    Wp = max(int(np.diff(w_start).max()) if num_devices else 1, 1)
    Sp = max(int(np.diff(bounds).max()), 1)
    D = np.zeros((num_devices, Wp, C), data.dtype if data.size else
                 np.float32)
    Cc = np.zeros((num_devices, Wp, C), np.int32)
    So = np.zeros((num_devices, Wp), np.int32)
    for p in range(num_devices):
        a, b = int(w_start[p]), int(w_start[p + 1])
        ln = b - a
        if ln:
            D[p, :ln] = data[a:b]
            Cc[p, :ln] = cols[a:b]
            So[p, :ln] = (slice_of[a:b] - bounds[p]).astype(np.int32)
    return D, Cc, So, bounds, Sp, np.diff(w_start)


def _deal_width_rows(data: np.ndarray, cols: np.ndarray,
                     slice_of: np.ndarray, num_devices: int, C: int):
    """The merge deal over a global width-row stream: equal spans of
    width-rows regardless of slice boundaries; slice ids stay GLOBAL.
    Shared by the convert-time partitioner and the device-loss re-deal.
    Returns ``(D, Cc, So, counts)``."""
    W = data.shape[0]
    bounds = (np.arange(num_devices + 1, dtype=np.int64) * W) // num_devices
    Wp = max(int(np.diff(bounds).max()), 1)
    D = np.zeros((num_devices, Wp, C), data.dtype if data.size else
                 np.float32)
    Cc = np.zeros((num_devices, Wp, C), np.int32)
    So = np.zeros((num_devices, Wp), np.int32)
    for p in range(num_devices):
        a, b = int(bounds[p]), int(bounds[p + 1])
        ln = b - a
        if ln:
            D[p, :ln] = data[a:b]
            Cc[p, :ln] = cols[a:b]
            So[p, :ln] = slice_of[a:b].astype(np.int32)
    return D, Cc, So, np.diff(bounds)


def partition_sellcs_rows(sc: SellCS, num_devices: int, *,
                          compact_x: bool = False) -> ShardedSellCS:
    """BCOH banding over the slice stream: contiguous slice ranges balanced
    by width-row count (each width-row is C padded nonzeros, so equal width
    is equal work). Host-side, convert time.

    Slices own disjoint row slots, so slice bands shard the (σ-permuted)
    rows — Y needs no collective.

    ``compact_x=True`` additionally computes each shard's touched-column
    map and relabels ``cols`` into its compacted index space: the multiply
    then gathers only the X rows this shard's nonzeros name instead of
    reading the full replicated slab (see the module docstring).
    """
    _check_devices(num_devices)
    C = sc.chunk
    S = sc.num_slices
    D, Cc, So, bounds, Sp, counts = _deal_slice_bands(
        np.asarray(sc.data), np.asarray(sc.cols),
        np.asarray(sc.slice_of, np.int64),
        np.asarray(sc.slice_ptr, np.int64), num_devices, C)
    col_map = n_touched = None
    if compact_x:
        Cc, cm, nt = _compact_columns(Cc.astype(np.int64), counts)
        Cc = Cc.astype(np.int32)
        col_map = jnp.asarray(cm.astype(np.int32))
        n_touched = jnp.asarray(nt.astype(np.int32))
    return ShardedSellCS(
        jnp.asarray(D), jnp.asarray(Cc), jnp.asarray(So),
        jnp.asarray(bounds[:-1].astype(np.int32)), sc.row_perm,
        sc.shape, C, S, Sp, sc.nnz, "row",
        row_counts=jnp.asarray(counts.astype(np.int32)),
        col_map=col_map, n_touched=n_touched,
        structure=sc.structure, diag=sc.diag)


def partition_sellcs_nnz(sc: SellCS, num_devices: int, *,
                         num_chunks: int = 1,
                         compact_x: bool = False) -> ShardedSellCS:
    """Merge-style equal spans over the width-row stream (slices — and with
    them dense rows — may straddle devices). ``slice_of`` stays global:
    every device scatters into the full slot space and the carry-out is
    fixed with a psum.

    ``num_chunks > 1`` additionally precomputes the pipelined-fixup span
    plan (``_chunk_substreams``) here, at convert time, so
    ``spmm_merge_distributed(..., num_chunks=num_chunks)`` reuses it
    instead of re-dealing the stream host-side on every multiply.

    ``compact_x=True`` relabels each shard's ``cols`` through its
    touched-column map (see ``partition_sellcs_rows``); the chunk plan,
    which re-deals width-rows across devices, carries its *own* map over
    the re-dealt ownership.
    """
    _check_devices(num_devices)
    if num_chunks < 1:
        raise ValueError(f"num_chunks must be >= 1, got {num_chunks}")
    C = sc.chunk
    S = sc.num_slices
    D, Cc, So, counts = _deal_width_rows(
        np.asarray(sc.data), np.asarray(sc.cols),
        np.asarray(sc.slice_of, np.int64), num_devices, C)
    sharded = ShardedSellCS(
        jnp.asarray(D), jnp.asarray(Cc), jnp.asarray(So),
        jnp.zeros((num_devices,), jnp.int32), sc.row_perm,
        sc.shape, C, S, S, sc.nnz, "merge",
        row_counts=jnp.asarray(counts.astype(np.int32)),
        structure=sc.structure, diag=sc.diag)
    plan = None
    if num_chunks > 1:
        # baked BEFORE the base relabel: the plan needs global column ids
        # anyway (its own map covers the re-dealt ownership), so building
        # it first spares the relabel -> un-relabel round trip the
        # multiply-time recompute path has to pay
        plan = _chunk_substreams(sharded, num_chunks, compact=compact_x)
    if compact_x:
        Cc2, cm, nt = _compact_columns(Cc.astype(np.int64), counts)
        sharded = sharded._replace(
            cols=jnp.asarray(Cc2.astype(np.int32)),
            col_map=jnp.asarray(cm.astype(np.int32)),
            n_touched=jnp.asarray(nt.astype(np.int32)))
    if plan is not None:
        sharded = sharded._replace(
            chunk_plan=(int(num_chunks), plan.spans, plan.col_map,
                        plan.n_touched))
    return sharded


def rechunk_sellcs(sharded: ShardedSellCS,
                   num_chunks: int) -> ShardedSellCS:
    """Swap-path partition reuse: re-bake ONLY the pipelined-fixup span
    plan of an existing "merge" partition. The expensive convert-time
    artifacts — the device-dealt data/cols blocks, the σ permutation, the
    ``compact_x`` column maps — are reused untouched, so an online plan
    swap that changes just the psum pipelining depth
    (``launch.serve --migrate``, ``SparseOperator.swap``) costs one
    host-side span re-deal instead of a full repartition.

    ``num_chunks = 1`` drops the plan (the monolithic fixup needs none);
    a matching baked plan is returned as-is. The re-baked plan is
    byte-identical to what ``partition_sellcs_nnz(num_chunks=...)`` would
    have produced at convert time: ``_chunk_substreams`` re-deals the same
    global width-row stream either way (a compacted base is un-relabeled
    through its ``col_map`` first)."""
    if sharded.schedule != "merge":
        raise ValueError("rechunk_sellcs needs a 'merge' partition, got "
                         f"{sharded.schedule!r}")
    nc = int(num_chunks)
    if nc < 1:
        raise ValueError(f"num_chunks must be >= 1, got {num_chunks}")
    if nc == 1:
        return sharded._replace(chunk_plan=None)
    if sharded.chunk_plan is not None and sharded.chunk_plan[0] == nc:
        return sharded
    plan = _chunk_substreams(sharded, nc)
    return sharded._replace(chunk_plan=(nc, plan.spans, plan.col_map,
                                        plan.n_touched))


def redeal_sellcs(sharded: ShardedSellCS, num_devices: int, *,
                  num_chunks: Optional[int] = None) -> ShardedSellCS:
    """Device-loss re-deal: rebuild an existing partition over a NEW device
    count without the original ``SellCS``. The global σ-sorted width-row
    stream is reconstructed from the shards (``_global_stream``: un-relabel
    a compacted base, globalize "row" slice ids, mask padding via
    ``row_counts``) and dealt again with the same machinery the convert-time
    partitioners use — the result is byte-identical to what
    ``partition_sellcs_rows`` / ``partition_sellcs_nnz`` would have produced
    from the original stream at ``num_devices``, so a mid-flight shrink
    (``runtime/elastic``: a device dies, survivors absorb its spans) never
    pays the σ-sort or the COO→SELL-C-σ conversion again.

    ``compact_x`` state is inherited from the input (the re-dealt ownership
    gets fresh touched-column maps); ``num_chunks`` defaults to the input's
    baked chunk depth ("merge" only)."""
    _check_devices(num_devices)
    compact = sharded.col_map is not None
    g_data, g_cols, g_so = _global_stream(sharded)
    C = sharded.chunk
    S = sharded.num_slices
    if sharded.schedule == "row":
        widths = (np.bincount(g_so, minlength=S) if g_so.size
                  else np.zeros(S, np.int64))
        slice_ptr = np.zeros(S + 1, np.int64)
        np.cumsum(widths, out=slice_ptr[1:])
        D, Cc, So, bounds, Sp, counts = _deal_slice_bands(
            g_data, g_cols, g_so, slice_ptr, num_devices, C)
        col_map = n_touched = None
        if compact:
            Cc, cm, nt = _compact_columns(Cc.astype(np.int64), counts)
            Cc = Cc.astype(np.int32)
            col_map = jnp.asarray(cm.astype(np.int32))
            n_touched = jnp.asarray(nt.astype(np.int32))
        return ShardedSellCS(
            jnp.asarray(D), jnp.asarray(Cc), jnp.asarray(So),
            jnp.asarray(bounds[:-1].astype(np.int32)), sharded.row_perm,
            sharded.shape, C, S, Sp, sharded.nnz, "row",
            row_counts=jnp.asarray(counts.astype(np.int32)),
            col_map=col_map, n_touched=n_touched,
            structure=sharded.structure, diag=sharded.diag)
    nc = (int(num_chunks) if num_chunks is not None
          else (sharded.chunk_plan[0] if sharded.chunk_plan is not None
                else 1))
    if nc < 1:
        raise ValueError(f"num_chunks must be >= 1, got {num_chunks}")
    D, Cc, So, counts = _deal_width_rows(g_data, g_cols, g_so,
                                         num_devices, C)
    out = ShardedSellCS(
        jnp.asarray(D), jnp.asarray(Cc), jnp.asarray(So),
        jnp.zeros((num_devices,), jnp.int32), sharded.row_perm,
        sharded.shape, C, S, S, sharded.nnz, "merge",
        row_counts=jnp.asarray(counts.astype(np.int32)),
        structure=sharded.structure, diag=sharded.diag)
    plan = None
    if nc > 1:
        # same ordering as partition_sellcs_nnz: plan baked before the base
        # relabel, on global column ids
        plan = _chunk_substreams(out, nc, compact=compact)
    if compact:
        Cc2, cm, nt = _compact_columns(Cc.astype(np.int64), counts)
        out = out._replace(
            cols=jnp.asarray(Cc2.astype(np.int32)),
            col_map=jnp.asarray(cm.astype(np.int32)),
            n_touched=jnp.asarray(nt.astype(np.int32)))
    if plan is not None:
        out = out._replace(chunk_plan=(nc, plan.spans, plan.col_map,
                                       plan.n_touched))
    return out


def _resolve_model_axis(mesh: Mesh, axis: str,
                        model_axis: Optional[str]) -> Tuple[Optional[str],
                                                            int]:
    """(model axis name or None, P_model). An explicit ``model_axis`` must
    exist in the mesh; ``None`` auto-adopts a ``"model"`` mesh axis when
    present (the 2-D (data, model) mesh convention of ``launch.mesh``)."""
    if model_axis is None:
        model_axis = "model" if "model" in mesh.axis_names else None
    elif model_axis not in mesh.axis_names:
        raise ValueError(f"model_axis {model_axis!r} is not a mesh axis; "
                         f"mesh has {tuple(mesh.axis_names)}")
    if model_axis == axis:
        raise ValueError(f"model_axis {model_axis!r} collides with the "
                         f"data axis {axis!r}")
    return model_axis, (int(mesh.shape[model_axis]) if model_axis else 1)


def _prep(sharded: ShardedSellCS, x: jax.Array, mesh: Mesh, axis: str,
          impl: str, k_tile: Optional[int], expect: str,
          model_axis: Optional[str], compact_x: Optional[bool] = None,
          op: str = "N"):
    if op not in ("N", "T"):
        raise ValueError(f"op must be 'N' or 'T', got {op!r}")
    if sharded.schedule != expect:
        raise ValueError(
            f"sharded matrix was partitioned for the {sharded.schedule!r} "
            f"schedule; build it with partition_sellcs_"
            f"{'rows' if expect == 'row' else 'nnz'} instead")
    ndev = sharded.data.shape[0]
    if ndev != mesh.shape[axis]:
        raise ValueError(
            f"matrix is partitioned over {ndev} devices but mesh axis "
            f"{axis!r} has {mesh.shape[axis]}")
    compact = sharded.col_map is not None
    if compact_x is not None and compact_x != compact:
        # cols are relabeled (or not) at partition time — a multiply-time
        # override cannot re-derive the other index space
        raise ValueError(
            f"compact_x={compact_x} but the matrix was partitioned with "
            f"compact_x={compact}; repartition with partition_sellcs_"
            f"{'rows' if expect == 'row' else 'nnz'}(..., "
            f"compact_x={compact_x})")
    maxis, pm = _resolve_model_axis(mesh, axis, model_axis)
    if impl not in ("ref", "pallas", "pallas_interpret"):
        raise ValueError(f"impl must be ref|pallas|pallas_interpret, "
                         f"got {impl!r}")
    x2, squeeze = _as_2d(x)
    m, n = sharded.shape
    n_in = m if op == "T" else n      # A^T X consumes m-row inputs
    if x2.shape[0] != n_in:
        raise ValueError(f"X rows {x2.shape[0]} != expected {n_in} "
                         f"(op={op!r}, matrix {m}x{n})")
    k = x2.shape[1]
    use_pallas = impl != "ref"
    # kc = X/Y columns owned by ONE model shard. The k-tile (and with it the
    # Pallas k-grid) lives inside a model shard, so it is chosen for kc, not
    # the global k; kp = kc * pm is the padded global slab width.
    kc = -(-k // pm)
    if use_pallas:
        kt = k_tile or choose_k_tile(sharded.shape, kc, nnz=sharded.nnz)
        kc = -(-kc // kt) * kt
    else:
        kt = k_tile
    kp = kc * pm
    if op == "T":
        # permute X into slot space once, ahead of the mesh: every shard's
        # transpose kernel then reads contiguous C-blocks of it (padding
        # slots, row_perm == m, read a zero row); the σ-permutation is
        # consumed here, so the column-space output needs no unpermute
        xs = jnp.concatenate(
            [x2, jnp.zeros((1, k), x2.dtype)], axis=0)[sharded.row_perm]
        if kp != k:
            x_pad = jnp.zeros((xs.shape[0], kp), x2.dtype).at[:, :k].set(xs)
        else:
            x_pad = xs
    elif use_pallas:
        np_ = -(-max(n, 1) // LANE) * LANE
        x_pad = jnp.zeros((np_, kp), x2.dtype).at[:n, :k].set(x2)
    elif kp == k:
        x_pad = x2
    else:
        x_pad = jnp.zeros((n, kp), x2.dtype).at[:, :k].set(x2)
    return x2, squeeze, k, kt, x_pad, use_pallas, maxis, pm, compact


def _gather_x(x_pad: jax.Array, col_map: jax.Array) -> jax.Array:
    """The sparsity-aware X gather: one ``x_pad[col_map]`` per multiply
    builds the per-shard ``[Ntc, kp]`` compacted slabs, stacked on the
    device axis — each data shard reads only the X rows its relabeled
    ``cols`` name. The slab height was padded to the Pallas lane width at
    bake time (``_pack_maps``; padding map entries point at row 0 and only
    data==0 lanes ever index them), so the hot path is this one gather."""
    return x_pad[col_map]


def _out_dtype(sharded: ShardedSellCS, x2: jax.Array, use_pallas: bool):
    """The dtype the nonzero compute path would produce: the Pallas kernel
    accumulates in float32; the jnp twin promotes (data, X)."""
    if use_pallas:
        return jnp.float32
    return jnp.promote_types(sharded.data.dtype, x2.dtype)


class _ChunkSpan(NamedTuple):
    """One pipelined span of the slice stream: the merge partitioning
    applied to a slice range (every device holds an equal share of THIS
    span's width-rows, so all devices finish a span together and its psum
    overlaps the next span's compute).

    For a ``compact_x`` plan each span additionally carries its own
    touched-column split (the overlapped-gather feed): ``sub`` holds the
    sorted plan-space positions this span's re-dealt rows touch on each
    device, ``col_map`` the matching GLOBAL column ids
    (``col_map == plan col_map[sub]`` row-wise), and ``n_touched`` the true
    per-device count. The overlapped multiply rebuilds span ``i``'s piece
    of the gathered slab *inside* the mesh region —
    ``slab.at[sub].set(x[col_map])`` — so XLA can run span ``i+1``'s
    gather under span ``i``'s kernel/psum instead of serializing one
    monolithic gather ahead of the first launch. Padding entries carry the
    consistent pair (``sub == 0``, ``col_map == plan col_map[:, 0]``):
    duplicate scatter writes then all carry the identical value, keeping
    the slab deterministic and bitwise-equal to the up-front gather."""
    slice_start: int         # first global slice of the span
    num_slices: int          # slices in the span (> 0)
    data: jax.Array          # [P, Wc, C] — zero-padded equal shares
    cols: jax.Array          # int32[P, Wc, C]
    slice_of: jax.Array      # int32[P, Wc] — GLOBAL slice ids
    sub: Optional[jax.Array] = None
                             # int32[P, Nsub] — plan-space positions this
                             #   span touches (compact plans only)
    col_map: Optional[jax.Array] = None
                             # int32[P, Nsub] — their global column ids
    n_touched: Optional[jax.Array] = None
                             # int32[P] — true touched count per device


class _ChunkPlan(NamedTuple):
    """The pipelined span plan plus — for a ``compact_x`` stream — the
    touched-column map of the RE-DEALT ownership: the span deal gives each
    device different width-rows than the base partition, so the base
    ``col_map`` does not cover them; one map per device spans all its rows
    across every span (one gathered slab per multiply, not one per span).
    Each span also carries its own per-span split of that map (see
    ``_ChunkSpan``) so the gather can be overlapped with the span loop."""
    spans: Tuple[_ChunkSpan, ...]
    col_map: Optional[jax.Array]     # int32[P, Ntc'] — None when uncompacted
    n_touched: Optional[jax.Array]   # int32[P]


def _global_stream(sharded: ShardedSellCS):
    """Host-side: flatten a partitioned stream back into the global
    σ-sorted width-row stream it was dealt from. Device spans are
    contiguous and ordered, and the partitioner recorded how many REAL
    width-rows each shard holds. Real vs padding must come from those
    counts, never from the values — a width-row whose stored entries are
    all explicit zeros (SellCS.to_coo round-trips them by design) is real
    work with real column indices, and dropping it silently skews any
    downstream width accounting.

    A compacted base is un-relabeled through its ``col_map`` (the global
    stream must carry global column ids); "row" shards carry LOCAL slice
    ids, which are globalized back through ``slice_offset``. Returns
    ``(g_data [W', C], g_cols [W', C], g_so [W'])``."""
    data = np.asarray(sharded.data)                  # [P, Wp, C]
    cols = np.asarray(sharded.cols)
    if sharded.col_map is not None:
        # back to global ids: device p's relabeled cols index its own map
        cm = np.asarray(sharded.col_map, np.int64)
        cols = cm[np.arange(cm.shape[0])[:, None, None],
                  cols.astype(np.int64)]
    so = np.asarray(sharded.slice_of, np.int64)      # [P, Wp]
    if sharded.schedule == "row":
        so = so + np.asarray(sharded.slice_offset, np.int64)[:, None]
    if sharded.row_counts is None:
        raise ValueError(
            "sharded matrix carries no row_counts; rebuild it with "
            "partition_sellcs_nnz (older ShardedSellCS values cannot be "
            "chunked — real rows are not derivable from the stored values)")
    counts = np.asarray(sharded.row_counts, np.int64)          # [P]
    real = (np.arange(data.shape[1], dtype=np.int64)[None]
            < counts[:, None])                                 # [P, Wp]
    return data[real], cols[real], so[real]


def _chunk_substreams(sharded: ShardedSellCS, num_chunks: int, *,
                      compact: Optional[bool] = None) -> _ChunkPlan:
    """Host-side: split the σ-sorted slice stream into ``num_chunks``
    width-balanced slice spans (``balanced_row_bands`` over the cumulative
    width, the same splitter both partitioners use) and re-partition EACH
    span's width-rows equally across all devices.

    The per-span re-partitioning is what makes the pipeline honest: the
    merge psum sums slot partials over every device anyway, so a width-row
    may live on any device — giving each device ``W_span / P`` rows of
    every span keeps per-device compute at the monolithic ``W / P`` total
    (no cross-span padding blow-up) and lets all devices reach span ``i``'s
    psum at the same time, with span ``i+1``'s compute ready to hide it.

    ``num_chunks > S`` degenerates to one span per nonempty slice (empty
    bands are dropped); the spans exactly tile ``[0, S)`` in order.

    For a ``compact`` plan (default: follow the shard's own
    ``compact_x`` state; ``partition_sellcs_nnz`` passes it explicitly to
    bake plans before the base relabel) the finished spans are relabeled
    through a fresh per-device map over the re-dealt ownership
    (``_ChunkPlan.col_map``). A stream whose base is already compacted is
    first un-relabeled through its ``col_map`` — the global stream must
    carry global column ids.
    """
    if compact is None:
        compact = sharded.col_map is not None
    g_data, g_cols, g_so = _global_stream(sharded)
    Pdev = sharded.data.shape[0]
    C = sharded.chunk
    S = sharded.num_slices
    nc = int(num_chunks)
    widths = (np.bincount(g_so, minlength=S) if g_so.size
              else np.zeros(S, np.int64))
    slice_ptr = np.zeros(S + 1, np.int64)
    np.cumsum(widths, out=slice_ptr[1:])
    bounds = balanced_row_bands(slice_ptr, nc).astype(np.int64)
    raw = []                 # (s0, ns, D, Cc, So, per-device real lengths)
    for i in range(nc):
        s0, s1 = int(bounds[i]), int(bounds[i + 1])
        if s1 <= s0:
            continue                                 # empty band (nc > S)
        a, b = int(slice_ptr[s0]), int(slice_ptr[s1])
        Wi = b - a
        Wc = max(-(-Wi // Pdev), 1)
        D = np.zeros((Pdev, Wc, C), g_data.dtype)
        Cc = np.zeros((Pdev, Wc, C), np.int64)
        So = np.full((Pdev, Wc), s0, np.int32)       # padding rebases to 0
        db = (np.arange(Pdev + 1, dtype=np.int64) * Wi) // Pdev
        for p in range(Pdev):
            ln = int(db[p + 1] - db[p])
            if ln:
                D[p, :ln] = g_data[a + db[p]:a + db[p + 1]]
                Cc[p, :ln] = g_cols[a + db[p]:a + db[p + 1]]
                So[p, :ln] = g_so[a + db[p]:a + db[p + 1]].astype(np.int32)
        raw.append((s0, s1 - s0, D, Cc, So, np.diff(db)))
    plan_map = plan_nt = None
    span_maps = [() for _ in raw]
    if compact:
        # touched set of the RE-DEALT ownership: device p's rows across all
        # spans, then one searchsorted relabel per (span, device) block
        touched = []
        for p in range(Pdev):
            vals = [Cc[p, :int(lens[p])].ravel()
                    for _, _, _, Cc, _, lens in raw if int(lens[p])]
            touched.append(np.unique(np.concatenate(vals)) if vals
                           else np.zeros(0, np.int64))
        cm, nt = _pack_maps(touched)
        for _, _, _, Cc, _, lens in raw:
            for p in range(Pdev):
                ln = int(lens[p])
                if ln:
                    Cc[p, :ln] = np.searchsorted(touched[p], Cc[p, :ln])
        plan_map = jnp.asarray(cm.astype(np.int32))
        plan_nt = jnp.asarray(nt.astype(np.int32))
        # per-span touched split for the overlapped gather: the sorted
        # plan-space positions span i's rows touch on each device, plus
        # their global ids. Padding rows carry the consistent pair
        # (sub == 0, col_map == cm[p, 0]) so every duplicate scatter write
        # lands the same value (deterministic slab; see _ChunkSpan).
        span_maps = []
        for _, _, _, Cc, _, lens in raw:
            subs = [np.unique(Cc[p, :int(lens[p])].ravel())
                    if int(lens[p]) else np.zeros(0, np.int64)
                    for p in range(Pdev)]
            ns = np.array([s.size for s in subs], np.int64)
            Wsub = max(int(ns.max()), 1)
            sub = np.zeros((Pdev, Wsub), np.int64)
            gcm = np.zeros((Pdev, Wsub), np.int64)
            for p, s in enumerate(subs):
                sub[p, :s.size] = s
                gcm[p, :s.size] = cm[p][s]
                gcm[p, s.size:] = cm[p, 0]
            span_maps.append((jnp.asarray(sub.astype(np.int32)),
                              jnp.asarray(gcm.astype(np.int32)),
                              jnp.asarray(ns.astype(np.int32))))
    spans = tuple(
        _ChunkSpan(s0, ns, jnp.asarray(D), jnp.asarray(Cc.astype(np.int32)),
                   jnp.asarray(So), *sm)
        for (s0, ns, D, Cc, So, _), sm in zip(raw, span_maps))
    # spans nonempty: bounds pin [0, S] and S >= 1
    return _ChunkPlan(spans, plan_map, plan_nt)



GATHER_MODES = ("upfront", "overlap", "fused")


def _resolve_gather(gather: Optional[str], compact: bool) -> str:
    """Validate the gather-scheduling knob. ``None`` (the default) is the
    up-front gather — byte-identical to the pre-knob behavior. The
    overlapped and fused modes only exist where a gather exists: a
    replicated-X stream has nothing to hide."""
    if gather is None:
        return "upfront"
    if gather not in GATHER_MODES:
        raise ValueError(
            f"gather must be one of {GATHER_MODES} or None, got {gather!r}")
    if gather != "upfront" and not compact:
        raise ValueError(
            f"gather={gather!r} needs a compact_x partition — a "
            "replicated-X stream has no X gather to hide; repartition "
            "with compact_x=True")
    return gather


def _local_slots(data, cols, slice_of, x_rep, *, num_slices, chunk,
                 use_pallas, k_tile, interpret, col_map=None):
    """Shard-local compute: the PR-1 k-tiled Pallas kernel, or its jnp twin
    off-TPU. Inputs carry a leading length-1 device-block axis. With
    ``col_map`` the gather is fused into the kernel: ``x_rep`` is the full
    (ungathered) X and the kernel indexes it through the map."""
    if use_pallas:
        return sellcs_slots(data[0], cols[0], slice_of[0], x_rep,
                            num_slices=num_slices, chunk=chunk,
                            k_tile=k_tile, interpret=interpret,
                            col_map=col_map)
    return sellcs_slots_ref(data[0], cols[0], slice_of[0], x_rep,
                            num_slices=num_slices, chunk=chunk,
                            col_map=col_map)


def _local_slots_t(data, cols, slice_of, x_slots, *, n_out, chunk,
                   use_pallas, k_tile, interpret):
    """Shard-local transpose compute over one width-row block: the Pallas
    scatter-accumulate kernel on TPU, its jnp twin off-TPU. ``slice_of``
    must already be global (the callers globalize "row" shards through
    ``slice_offset``); ``x_slots`` is the slot-permuted X."""
    if use_pallas:
        return sellcs_slots_t(data, cols, slice_of, x_slots, n_out=n_out,
                              chunk=chunk, k_tile=k_tile,
                              interpret=interpret)
    return sellcs_slots_t_ref(data, cols, slice_of, x_slots, n_out=n_out,
                              chunk=chunk)


def _scatter_touched(yb: jax.Array, col_map: jax.Array,
                     n_touched: jax.Array, n: int, k: int,
                     squeeze: bool) -> jax.Array:
    """Post-mesh fixup for ``op='T'`` under ``compact_x``: the relabeled
    ``cols`` made each shard's transpose output land in its compacted
    index space ``[0, n_touched)`` — the touched-column map read the
    paper's gather forward now runs backward as a scatter-add into the
    global output rows. Padding map entries (past ``n_touched``) dump into
    row ``n``, which is dropped."""
    Pdev, ntc = col_map.shape
    yb = yb.reshape(Pdev, ntc, -1)
    mask = (jnp.arange(ntc, dtype=jnp.int32)[None]
            < n_touched[:, None])                               # [P, Ntc]
    tgt = jnp.where(mask, col_map, n)
    y = jnp.zeros((n + 1, yb.shape[-1]), yb.dtype).at[tgt].add(
        jnp.where(mask[..., None], yb, 0))[:n, :k]
    return y[:, 0] if squeeze else y


def _symmetric_combine(multiply, sharded: ShardedSellCS, x: jax.Array,
                       **kw) -> jax.Array:
    """One-triangle symmetric multiply: run the normal and transpose
    passes over the stored triangle and subtract the double-counted
    diagonal (``A X = N(X) + T(X) - diag * X``). ``op='N'`` and ``op='T'``
    coincide — ``A == A^T``.

    The diag term is cast to the kernel-path output dtype BEFORE the
    multiply: a wider stored diagonal (e.g. f64 diag over an f32 pallas
    result) must not out-promote the combine and silently hand back a
    different dtype than the general path would."""
    x2, squeeze = _as_2d(x)
    general = sharded._replace(structure="general")
    y_n = multiply(general, x2, op="N", **kw)
    y_t = multiply(general, x2, op="T", **kw)
    y = y_n + y_t - (sharded.diag.astype(y_n.dtype)[:, None]
                     * x2.astype(y_n.dtype))
    return y[:, 0] if squeeze else y


def _unpermute(sharded: ShardedSellCS, y_slots: jax.Array, k: int,
               squeeze: bool) -> jax.Array:
    """Undo the global σ-sort with one scatter (padding slots target row m,
    which is dropped)."""
    m = sharded.shape[0]
    y = jnp.zeros((m + 1, y_slots.shape[1]), y_slots.dtype
                  ).at[sharded.row_perm].add(y_slots)[:m, :k]
    return y[:, 0] if squeeze else y


def spmm_row_distributed(sharded: ShardedSellCS, x: jax.Array, mesh: Mesh,
                         axis: str = "data", *, impl: str = "ref",
                         k_tile: Optional[int] = None,
                         model_axis: Optional[str] = None,
                         compact_x: Optional[bool] = None,
                         op: str = "N",
                         gather: Optional[str] = None) -> jax.Array:
    """Y = A @ X with slice banding: X replicated along ``axis``, Y
    shard-local slots, zero collectives inside the mesh region.

    On a mesh carrying a ``model`` axis (or an explicit ``model_axis``),
    the X/Y k-slabs are additionally column-sharded across it: each model
    shard reads ``1/P_model`` of the replicated X and writes its own column
    block of Y — the slice stream itself is replicated along ``model``.

    A matrix partitioned with ``compact_x=True`` swaps the replicated X
    read for the sparsity-aware gather: ``_gather_x`` builds each shard's
    ``[n_touched, kc]`` slab once per call and the slab rides the ``data``
    axis next to the slice stream. ``compact_x=`` here only *asserts* the
    partition-time choice (None follows it) — the relabeled stream cannot
    consume a replicated X, nor the reverse.

    ``op='T'`` computes ``Y = A^T X`` (``X: [m, k]``, ``Y: [n, k]``) over
    the same partition: X is permuted into slot space ahead of the mesh,
    each shard scatter-accumulates into column space (its local slice ids
    globalized through ``slice_offset``), and — since column ownership
    overlaps arbitrarily across shards — the fixup is a psum on the data
    axis (the zero-collective property is a row-space property; transpose
    outputs live in column space). Under ``compact_x`` the relabeled cols
    make each shard's output land in its compacted index space, so the
    psum is replaced by a per-shard ``[n_touched, kc]`` stack that
    scatter-adds through the touched-column map after the mesh region —
    the touched-*column* map becomes a touched-*output-row* map.

    ``gather=`` schedules the compact-X gather: ``"upfront"`` (default)
    materializes the slab ahead of the mesh region, ``"fused"`` feeds the
    full X and lets the kernel index it through ``col_map`` directly (the
    map rides the Pallas scalar prefetch next to ``slice_of``), and
    ``"overlap"`` degenerates to up-front here — the row schedule has no
    span loop to hide the gather under. All modes are bitwise-identical;
    the knob only moves WHEN the touched rows are read. ``op='T'`` has no
    gather (X enters slot-permuted), so the knob is validated and ignored.

    Symmetric one-triangle partitions combine both passes over the stored
    triangle (``A X = N(X) + T(X) - diag * X``); ``op`` is then moot.
    """
    if sharded.structure == "symmetric":
        return _symmetric_combine(
            lambda s, xx, **kw: spmm_row_distributed(
                s, xx, mesh, axis, impl=impl, k_tile=k_tile,
                model_axis=model_axis, compact_x=compact_x, gather=gather,
                **kw),
            sharded, x)
    m, n = sharded.shape
    C, S, Sp = sharded.chunk, sharded.num_slices, sharded.slices_per_shard
    ndev = sharded.data.shape[0]
    x2, squeeze, k, kt, x_pad, use_pallas, maxis, pm, compact = _prep(
        sharded, x, mesh, axis, impl, k_tile, "row", model_axis, compact_x,
        op)
    gmode = _resolve_gather(gather, compact)
    if sharded.nnz == 0:
        y = jnp.zeros((n if op == "T" else m, k),
                      _out_dtype(sharded, x2, use_pallas))
        return y[:, 0] if squeeze else y
    interpret = impl == "pallas_interpret"
    if op == "T":
        k_keep = k if pm == 1 else x_pad.shape[1] // pm
        n_eff = int(sharded.col_map.shape[1]) if compact else n

        def local_t(data, cols, slice_of, offs, x_loc):
            gso = slice_of[0] + offs          # globalize the band's slices
            with span("spmm/kernel"):
                y_loc = _local_slots_t(data[0], cols[0], gso, x_loc,
                                       n_out=n_eff, chunk=C,
                                       use_pallas=use_pallas, k_tile=kt,
                                       interpret=interpret)
            if compact:
                return y_loc[:, :k_keep]
            with span("spmm/psum"):
                return jax.lax.psum(y_loc[:, :k_keep], axis)

        with span("spmm/mesh"):
            yb = maybe_block(shard_map(
                local_t, mesh=mesh,
                in_specs=(P(axis, None, None), P(axis, None, None),
                          P(axis, None), P(axis), P(None, maxis)),
                out_specs=P(axis, maxis) if compact else P(None, maxis),
                check_vma=False if use_pallas else None)(
                    sharded.data, sharded.cols, sharded.slice_of,
                    sharded.slice_offset, x_pad))
        with span("spmm/fixup"):
            if compact:
                return maybe_block(_scatter_touched(
                    yb, sharded.col_map, sharded.n_touched, n, k, squeeze))
            y = yb[:n, :k]
            return maybe_block(y[:, 0] if squeeze else y)
    if compact and gmode == "fused":
        # the full X rides the mesh replicated and the kernel gathers
        # through col_map in its own prefetch — no slab materializes
        def local(data, cols, slice_of, cmap, x_loc):
            with span("spmm/kernel"):
                return _local_slots(data, cols, slice_of, x_loc,
                                    num_slices=Sp, chunk=C,
                                    use_pallas=use_pallas, k_tile=kt,
                                    interpret=interpret, col_map=cmap[0])

        in_specs = (P(axis, None, None), P(axis, None, None),
                    P(axis, None), P(axis, None), P(None, maxis))
        args = (sharded.data, sharded.cols, sharded.slice_of,
                sharded.col_map, x_pad)
    else:
        if compact:
            # up-front gather ("overlap" degenerates here: no span loop)
            with span("spmm/gather_x"):
                x_feed = maybe_block(_gather_x(x_pad, sharded.col_map))
            x_spec = P(axis, None, maxis)
        else:
            x_feed, x_spec = x_pad, P(None, maxis)

        def local(data, cols, slice_of, x_loc):
            with span("spmm/kernel"):
                return _local_slots(data, cols, slice_of,
                                    x_loc[0] if compact else x_loc,
                                    num_slices=Sp, chunk=C,
                                    use_pallas=use_pallas, k_tile=kt,
                                    interpret=interpret)

        in_specs = (P(axis, None, None), P(axis, None, None),
                    P(axis, None), x_spec)
        args = (sharded.data, sharded.cols, sharded.slice_of, x_feed)

    # pallas_call has no replication rule inside shard_map — skip the check
    with span("spmm/mesh"):
        yb = maybe_block(shard_map(
            local, mesh=mesh,
            in_specs=in_specs,
            out_specs=P(axis, maxis),
            check_vma=False if use_pallas else None)(*args))
    with span("spmm/fixup"):
        yb = yb.reshape(ndev, Sp * C, -1)
        # shard p owns global slices [slice_offset[p], slice_offset[p+1]);
        # scatter its local slots there, dumping padding slots past S*C.
        offs = sharded.slice_offset
        valid_slices = jnp.concatenate(
            [offs[1:], jnp.array([S], jnp.int32)]) - offs       # [Pdev]
        local_slice = jnp.arange(Sp * C, dtype=jnp.int32) // C
        gslot = (offs[:, None] + local_slice[None]) * C \
            + (jnp.arange(Sp * C, dtype=jnp.int32) % C)[None]   # [Pdev, SpC]
        mask = local_slice[None] < valid_slices[:, None]
        y_slots = jnp.zeros((S * C + 1, yb.shape[-1]), yb.dtype).at[
            jnp.where(mask, gslot, S * C)].add(
                jnp.where(mask[..., None], yb, 0))[:S * C]
        return maybe_block(_unpermute(sharded, y_slots, k, squeeze))


def spmm_merge_distributed(sharded: ShardedSellCS, x: jax.Array, mesh: Mesh,
                           axis: str = "data", *, impl: str = "ref",
                           k_tile: Optional[int] = None,
                           num_chunks: int = 1,
                           model_axis: Optional[str] = None,
                           compact_x: Optional[bool] = None,
                           op: str = "N",
                           gather: Optional[str] = None) -> jax.Array:
    """Y = A @ X with equal-width spans: per-device slot partials + psum
    carry-out fixup (the only collective). Survives the mawi dense-row
    pathology — the dense slice splits mid-stream.

    ``num_chunks > 1`` pipelines the fixup: the slice stream is split into
    width-balanced spans of consecutive slices and each span's width-rows
    are re-dealt equally across the devices (``_chunk_substreams``), so
    every device reaches span ``i``'s psum together and XLA's async
    all-reduce of span ``i`` overlaps the kernel of span ``i+1`` instead of
    serializing after all local work. Only the true ``k`` columns cross the
    wire — the ``kp - k`` k-tile padding columns never enter the
    collective. Each slot is still reduced by exactly one psum, so the
    result equals the monolithic schedule up to fp summation order.
    ``num_chunks = 1`` is the monolithic schedule; ``num_chunks > S``
    degenerates to one span per nonempty slice.

    On a mesh carrying a ``model`` axis (or an explicit ``model_axis``),
    the X/Y k-slabs are column-sharded across it and **every psum runs on
    the data axis alone** — the model shards hold disjoint Y columns, so
    nothing of theirs needs reducing. Per-device collective bytes drop by
    ``P_model``: each device all-reduces only its own ``kc = kp / P_model``
    column block. Unlike the 1-D path, the tail padding columns (fewer
    than ``k_tile * P_model`` in aggregate, from rounding ``k`` up to a
    ``k_tile``-aligned per-shard width) DO ride the wire — a uniform local
    slice cannot single out the global column ``k`` — which is noise in
    the k ≫ 128 regime this axis targets; the roofline model prices the
    ideal ``k / P_model``.

    A matrix partitioned with ``compact_x=True`` feeds each shard a
    gathered ``[n_touched, kc]`` slab instead of the replicated X (see
    ``spmm_row_distributed``); with ``num_chunks > 1`` the gather runs
    through the chunk plan's own map — the span re-deal changes which
    device owns which width-rows, so the plan carries a touched set over
    the re-dealt ownership. The psum is untouched: compaction shrinks
    reads, not the carry-out. ``compact_x=`` only asserts the
    partition-time choice; ``None`` follows it.

    ``op='T'`` computes ``Y = A^T X`` over the same spans: X enters the
    mesh slot-permuted, each span scatter-accumulates into column space
    through its global slice ids, and each span's ``[n, kc]`` partial is
    psum'd on the data axis as soon as it is ready (the same pipelined
    overlap as the normal fixup) and summed — column ownership overlaps
    across spans, so partials add instead of concatenating. Under
    ``compact_x`` the span outputs live in the (plan) touched-column index
    space: they are summed locally, stacked per shard, and scatter-added
    through the map after the mesh region (see ``spmm_row_distributed``).
    Symmetric one-triangle partitions combine both passes; ``op`` is moot.

    ``gather=`` schedules the compact-X gather: ``"upfront"`` (default)
    materializes the per-shard slab ahead of the mesh region — one XLA
    gather serialized before the first kernel launch. ``"overlap"``
    (``num_chunks > 1`` only; degenerates to up-front otherwise) rebuilds
    each span's piece of the slab INSIDE the mesh region from the plan's
    per-span touched split (``_ChunkSpan.sub``/``col_map``) — the span
    slabs have no cross-span data dependency, so span ``i+1``'s gather
    runs under span ``i``'s kernel/psum, the same overlap the pipelined
    fixup already exploits. ``"fused"`` feeds the full X and lets the
    kernel index it through ``col_map`` in its scalar prefetch — no slab
    at all. All modes are bitwise-identical (the gather only re-indexes X
    rows; untouched slab positions are read only by data == 0 padding
    lanes); the knob moves WHEN the touched rows are read, and the
    roofline prices the exposed seconds of each choice
    (``spmm_distributed_gather_s``). ``op='T'`` has no gather, so the
    knob is validated and ignored.
    """
    if sharded.structure == "symmetric":
        return _symmetric_combine(
            lambda s, xx, **kw: spmm_merge_distributed(
                s, xx, mesh, axis, impl=impl, k_tile=k_tile,
                num_chunks=num_chunks, model_axis=model_axis,
                compact_x=compact_x, gather=gather, **kw),
            sharded, x)
    m, n = sharded.shape
    C, S = sharded.chunk, sharded.num_slices
    nc = int(num_chunks)
    if nc < 1:
        raise ValueError(f"num_chunks must be >= 1, got {num_chunks}")
    x2, squeeze, k, kt, x_pad, use_pallas, maxis, pm, compact = _prep(
        sharded, x, mesh, axis, impl, k_tile, "merge", model_axis,
        compact_x, op)
    gmode = _resolve_gather(gather, compact)
    if sharded.nnz == 0:
        y = jnp.zeros((n if op == "T" else m, k),
                      _out_dtype(sharded, x2, use_pallas))
        return y[:, 0] if squeeze else y
    interpret = impl == "pallas_interpret"
    # Columns to keep of each local slot block before its psum: with one
    # model shard the true k (the k-tile padding never crosses the wire);
    # with P_model > 1 every local column block is a distinct slice of the
    # global slab, so all kc local columns ship and the (kp - k) tail
    # padding is dropped after the mesh region by _unpermute.
    k_keep = k if pm == 1 else x_pad.shape[1] // pm

    if op == "T":
        if nc == 1:
            spans = None
            plan_map, plan_nt = sharded.col_map, sharded.n_touched
        else:
            if sharded.chunk_plan is not None and \
                    sharded.chunk_plan[0] == nc:
                spans, plan_map, plan_nt = (sharded.chunk_plan[1],
                                            sharded.chunk_plan[2],
                                            sharded.chunk_plan[3])
            else:
                plan = _chunk_substreams(sharded, nc)
                spans, plan_map, plan_nt = (plan.spans, plan.col_map,
                                            plan.n_touched)
        n_eff = int(plan_map.shape[1]) if compact else n

        def local_t(datas, colss, sos, x_loc):
            # one column-space partial per span; partials ADD (column
            # ownership overlaps across spans), each psum still issued
            # right after its span's kernel so it hides under the next
            total = None
            for data, cols, slice_of in zip(datas, colss, sos):
                with span("spmm/kernel"):
                    y_c = _local_slots_t(data[0], cols[0], slice_of[0],
                                         x_loc, n_out=n_eff, chunk=C,
                                         use_pallas=use_pallas, k_tile=kt,
                                         interpret=interpret)
                part = y_c[:, :k_keep]
                if not compact:
                    with span("spmm/psum"):
                        part = jax.lax.psum(part, axis)
                total = part if total is None else total + part
            return total

        if nc == 1:
            args = ((sharded.data,), (sharded.cols,), (sharded.slice_of,))
        else:
            args = (tuple(sp.data for sp in spans),
                    tuple(sp.cols for sp in spans),
                    tuple(sp.slice_of for sp in spans))
        nspan = len(args[0])
        blk = tuple(P(axis, None, None) for _ in range(nspan))
        with span("spmm/mesh"):
            yb = maybe_block(shard_map(
                local_t, mesh=mesh,
                in_specs=(blk, blk,
                          tuple(P(axis, None) for _ in range(nspan)),
                          P(None, maxis)),
                out_specs=P(axis, maxis) if compact else P(None, maxis),
                check_vma=False if use_pallas else None)(
                    *args, x_pad))
        with span("spmm/fixup"):
            if compact:
                return maybe_block(_scatter_touched(
                    yb, plan_map, plan_nt, n, k, squeeze))
            y = yb[:n, :k]
            return maybe_block(y[:, 0] if squeeze else y)

    if nc == 1:
        if compact and gmode == "fused":
            def local(data, cols, slice_of, cmap, x_loc):
                with span("spmm/kernel"):
                    y_loc = _local_slots(data, cols, slice_of, x_loc,
                                         num_slices=S, chunk=C,
                                         use_pallas=use_pallas, k_tile=kt,
                                         interpret=interpret,
                                         col_map=cmap[0])
                with span("spmm/psum"):
                    return jax.lax.psum(y_loc[:, :k_keep], axis)

            in_specs = (P(axis, None, None), P(axis, None, None),
                        P(axis, None), P(axis, None), P(None, maxis))
            args = (sharded.data, sharded.cols, sharded.slice_of,
                    sharded.col_map, x_pad)
        else:
            if compact:
                # up-front gather ("overlap" degenerates: no span loop)
                with span("spmm/gather_x"):
                    x_feed = maybe_block(_gather_x(x_pad, sharded.col_map))
                x_spec = P(axis, None, maxis)
            else:
                x_feed, x_spec = x_pad, P(None, maxis)

            def local(data, cols, slice_of, x_loc):
                with span("spmm/kernel"):
                    y_loc = _local_slots(data, cols, slice_of,
                                         x_loc[0] if compact else x_loc,
                                         num_slices=S, chunk=C,
                                         use_pallas=use_pallas, k_tile=kt,
                                         interpret=interpret)
                # carry-out fixup on the data axis ONLY: model shards own
                # disjoint Y columns and never enter the collective
                with span("spmm/psum"):
                    return jax.lax.psum(y_loc[:, :k_keep], axis)

            in_specs = (P(axis, None, None), P(axis, None, None),
                        P(axis, None), x_spec)
            args = (sharded.data, sharded.cols, sharded.slice_of, x_feed)

        with span("spmm/mesh"):
            y_slots = maybe_block(shard_map(
                local, mesh=mesh,
                in_specs=in_specs,
                out_specs=P(None, maxis),
                check_vma=False if use_pallas else None)(*args))
        with span("spmm/fixup"):
            return maybe_block(_unpermute(sharded, y_slots, k, squeeze))

    if sharded.chunk_plan is not None and sharded.chunk_plan[0] == nc:
        # precomputed at partition time (spans + re-deal column map)
        spans, plan_map = sharded.chunk_plan[1], sharded.chunk_plan[2]
    else:
        plan = _chunk_substreams(sharded, nc)
        spans, plan_map = plan.spans, plan.col_map
    meta = [(sp.slice_start, sp.num_slices) for sp in spans]
    span_spec = tuple(P(axis, None, None) for _ in spans)
    so_spec = tuple(P(axis, None) for _ in spans)
    span_args = (tuple(sp.data for sp in spans),
                 tuple(sp.cols for sp in spans),
                 tuple(sp.slice_of for sp in spans))

    def _span_kernel(data, cols, slice_of, x_loc, s0, ns, col_map=None):
        if use_pallas:
            return sellcs_slots_chunk(
                data[0], cols[0], slice_of[0], x_loc,
                slice_start=s0, num_slices=ns, chunk=C, k_tile=kt,
                interpret=interpret, col_map=col_map)
        return sellcs_slots_chunk_ref(
            data[0], cols[0], slice_of[0], x_loc,
            slice_start=s0, num_slices=ns, chunk=C, col_map=col_map)

    if compact and gmode == "overlap" and \
            all(sp.sub is not None for sp in spans):
        # the overlapped gather: each span rebuilds its own piece of the
        # plan-space slab inside the mesh region — no data dependency
        # between span slabs, so XLA runs span i+1's gather (and its
        # kernel) under span i's psum, exactly like the pipelined fixup.
        # Untouched slab positions stay 0 and are only ever read by
        # data == 0 padding lanes, so the answer is bitwise-identical to
        # the up-front gather.
        ntc_plan = int(plan_map.shape[1])

        def local(datas, colss, sos, subs, cmaps, x_loc):
            outs = []
            for i, ((s0, ns), data, cols, slice_of, sub, cmap) in \
                    enumerate(zip(meta, datas, colss, sos, subs, cmaps)):
                with span(f"spmm/gather_x/span{i}"):
                    slab = jnp.zeros(
                        (ntc_plan, x_loc.shape[1]), x_loc.dtype
                    ).at[sub[0]].set(x_loc[cmap[0]])
                with span("spmm/kernel"):
                    y_c = _span_kernel(data, cols, slice_of, slab, s0, ns)
                with span("spmm/psum"):
                    outs.append(jax.lax.psum(y_c[:, :k_keep], axis))
            return jnp.concatenate(outs, axis=0)

        map_spec = tuple(P(axis, None) for _ in spans)
        in_specs = (span_spec, span_spec, so_spec, map_spec, map_spec,
                    P(None, maxis))
        args = span_args + (tuple(sp.sub for sp in spans),
                            tuple(sp.col_map for sp in spans), x_pad)
    elif compact and gmode == "fused":
        def local(datas, colss, sos, cmap, x_loc):
            cm0 = cmap[0]
            outs = []
            for (s0, ns), data, cols, slice_of in zip(meta, datas, colss,
                                                      sos):
                with span("spmm/kernel"):
                    y_c = _span_kernel(data, cols, slice_of, x_loc, s0, ns,
                                       col_map=cm0)
                with span("spmm/psum"):
                    outs.append(jax.lax.psum(y_c[:, :k_keep], axis))
            return jnp.concatenate(outs, axis=0)

        in_specs = (span_spec, span_spec, so_spec, P(axis, None),
                    P(None, maxis))
        args = span_args + (plan_map, x_pad)
    else:
        if compact:
            # the spans' cols live in the chunk plan's index space, not
            # the base partition's — gather through the plan map
            with span("spmm/gather_x"):
                x_feed = maybe_block(_gather_x(x_pad, plan_map))
            x_spec = P(axis, None, maxis)
        else:
            x_feed, x_spec = x_pad, P(None, maxis)

        def local(datas, colss, sos, x_loc):
            # one (kernel -> psum) pair per span with no cross-span data
            # dependency: the span-i all-reduce-start can run under the
            # span-(i+1) kernel.
            x_loc = x_loc[0] if compact else x_loc
            outs = []
            for (s0, ns), data, cols, slice_of in zip(meta, datas, colss,
                                                      sos):
                with span("spmm/kernel"):
                    y_c = _span_kernel(data, cols, slice_of, x_loc, s0, ns)
                with span("spmm/psum"):
                    outs.append(jax.lax.psum(y_c[:, :k_keep], axis))
            # span i's rows sit at global slots [s0*C, (s0 + ns)*C); the
            # spans tile [0, S) in order, so concatenation IS the slot
            # array
            return jnp.concatenate(outs, axis=0)

        in_specs = (span_spec, span_spec, so_spec, x_spec)
        args = span_args + (x_feed,)

    with span("spmm/mesh"):
        y_slots = maybe_block(shard_map(
            local, mesh=mesh,
            in_specs=in_specs,
            out_specs=P(None, maxis),
            check_vma=False if use_pallas else None)(*args))
    with span("spmm/fixup"):
        return maybe_block(_unpermute(sharded, y_slots, k, squeeze))
